#!/usr/bin/env python3
"""Perf-regression gate over the committed BENCH_stream.json baseline.

Compares the streaming bench's fresh artifact against the committed
baseline and fails (exit 1) when the kernel regressed by more than
--max-regress (default 20%).

Two comparisons, by reliability:

  * local_vs_global_speedup — the local-block / global-walk diffusions/sec
    ratio, measured in the same binary on the same machine. It is close to
    machine-independent, so it is always enforced against the baseline.
  * absolute diffusions/sec — only enforced when the baseline was recorded
    in the same environment (the "environment" field matches), since raw
    throughput across different machines is noise, not signal.

A baseline with "measured": false is a bootstrap placeholder (the perf
trajectory has not recorded its first real run yet): the gate prints the
fresh numbers and exits 0 so the first CI run can seed the baseline from
its uploaded artifact.
"""

import argparse
import json
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def fmt(value, spec):
    """Format a possibly-absent metric without crashing on None."""
    return format(value, spec) if isinstance(value, (int, float)) else "n/a"


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="committed BENCH_stream.json")
    ap.add_argument("--current", required=True, help="freshly produced BENCH_stream.json")
    ap.add_argument("--max-regress", type=float, default=0.20,
                    help="allowed fractional regression (default 0.20)")
    args = ap.parse_args()

    base = load(args.baseline)
    cur = load(args.current)

    cur_speedup = cur.get("local_vs_global_speedup")
    cur_rate = (cur.get("local") or {}).get("init_diffusions_per_sec")
    print(f"current: speedup={fmt(cur_speedup, '.2f')}x  "
          f"local diffusions/sec={fmt(cur_rate, '.3e')}  env={cur.get('environment')}")

    if not base.get("measured", False):
        print("baseline is a bootstrap placeholder (measured=false): gate passes; "
              "seed it from this run's uploaded artifact to arm the gate.")
        return 0

    failures = []
    tol = 1.0 - args.max_regress

    base_speedup = base.get("local_vs_global_speedup")
    if base_speedup:
        floor = base_speedup * tol
        print(f"baseline speedup={base_speedup:.2f}x  (floor {floor:.2f}x)")
        if not isinstance(cur_speedup, (int, float)) or cur_speedup < floor:
            failures.append(
                f"local_vs_global_speedup regressed: {cur_speedup} < {floor:.2f} "
                f"(baseline {base_speedup:.2f}, tolerance {args.max_regress:.0%})")

    base_rate = (base.get("local") or {}).get("init_diffusions_per_sec")
    if base_rate and base.get("environment") == cur.get("environment"):
        floor = base_rate * tol
        print(f"baseline diffusions/sec={base_rate:.3e}  (floor {floor:.3e}, same env)")
        if not isinstance(cur_rate, (int, float)) or cur_rate < floor:
            failures.append(
                f"diffusions/sec regressed: {cur_rate} < {floor:.3e} "
                f"(baseline {base_rate:.3e}, tolerance {args.max_regress:.0%})")
    elif base_rate:
        print("baseline recorded in a different environment: absolute "
              "diffusions/sec not enforced (ratio gate above still applies)")

    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        return 1
    print("perf gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
