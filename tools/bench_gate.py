#!/usr/bin/env python3
"""Perf-regression gate over the committed BENCH_*.json baselines.

Compares a fresh bench artifact against its committed baseline and fails
(exit 1) when the tracked metric regressed by more than --max-regress
(default 20%). Two artifact kinds:

  * --kind stream (default) — `benches/streaming_churn.rs`:
      - local_vs_global_speedup: the local-block / global-walk
        diffusions/sec ratio, measured in the same binary on the same
        machine. Close to machine-independent, so always enforced.
      - rebase_local_vs_gather_speedup: the gather/local epoch-
        transition-latency ratio (how much faster the V1 halo rebase
        turns an epoch over than the leader gather/scatter), same-binary
        same-machine; always enforced once a measured baseline lands.
      - absolute diffusions/sec: only enforced when the baseline was
        recorded in the same environment (the "environment" field
        matches) — raw cross-machine throughput is noise, not signal.

  * --kind elastic — `benches/elastic_pool.rs`:
      - elastic_vs_fixed_speedup: elastic-pool vs fixed-K time-to-
        converge under the hotspot/straggler scenario, same-binary
        same-machine ratio; always enforced. It must also stay above
        1.0 — elastic slower than fixed-K is a correctness-grade
        regression of the pool scheduler, whatever the baseline says.

  * --kind hotpath — `benches/hotpath.rs`:
      - blocked_vs_local_speedup: the blocked / local-block kernel
        diffusions/sec ratio, same-binary same-machine; once a measured
        baseline lands it must stay above 1.0 — the batched, unrolled
        kernel existing *and being slower* than the kernel it batches is
        a hot-loop regression, whatever the baseline ratio says.
      - local_vs_global_speedup: ratio floor against the baseline, as
        in --kind stream.
      - blocked allocs_per_kupdate: only enforced same-environment, and
        only as a floor-style regression bound — allocator traffic in
        the hot loop creeping back up is exactly what this bench exists
        to catch.

  * --kind wire — `benches/wire_throughput.rs`:
      - batched_vs_unbatched_speedup: parcels/sec under the default
        batching FlushPolicy vs flush-per-frame (the pre-batching
        behaviour), same-binary same-machine; once a measured baseline
        lands it must stay above 1.0 — the vectored-write fast path
        existing *and being slower* than flushing every frame is a
        transport regression, whatever the baseline ratio says. Also
        gated as a ratio floor against the baseline.
      - batched parcels_per_sec: only enforced when the baseline was
        recorded in the same environment.
      - batched allocs_per_parcel: same-environment ceiling — allocator
        traffic creeping back into the pooled encode/decode cycle is
        exactly what this bench exists to catch (§8.8 target is 0).

  * --kind recovery — `benches/recovery.rs`:
      - recovery_vs_cold_speedup: crash recovery (detect → restore
        checkpoint H → recompute fluid → re-settle) vs restarting the
        solve from scratch, same-binary same-machine; once a measured
        baseline lands it must stay above 1.0 — recovery slower than a
        cold restart means the checkpoint machinery is pure overhead.
        Also gated as a ratio floor against the baseline.
      - checkpoint_overhead_ratio: the checkpointed/cold converge-wall
        ratio (the steady-state tax of the incremental journal),
        ceiling-gated against the baseline — the no-failure hot path
        creeping toward the failure path's cost is exactly what this
        bench exists to catch.
      - recovery_time_to_converge_secs: only enforced when the baseline
        was recorded in the same environment.

  * --kind serve — `benches/serve_throughput.rs`:
      - batched_vs_sequential_speedup: multi-lane query serving vs
        draining the same query load one lane at a time, same-binary
        same-machine; once a measured baseline lands it must stay above
        1.0 — fluid lanes existing *and being slower* than sequential
        serving means the multi-RHS hot path is pure overhead. Also
        gated as a ratio floor against the baseline.
      - batched queries_per_sec and p99 time-to-ε: only enforced when
        the baseline was recorded in the same environment.

A baseline with "measured": false is a bootstrap placeholder (the perf
trajectory has not recorded its first real run yet): the gate prints the
fresh numbers and exits 0 so the first CI run can seed the baseline from
its uploaded artifact.
"""

import argparse
import json
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def fmt(value, spec):
    """Format a possibly-absent metric without crashing on None."""
    return format(value, spec) if isinstance(value, (int, float)) else "n/a"


def gate_ratio(failures, name, base_value, cur_value, tol, max_regress):
    """Enforce a same-binary ratio metric against its baseline floor."""
    if not base_value:
        return
    floor = base_value * tol
    print(f"baseline {name}={base_value:.2f}x  (floor {floor:.2f}x)")
    if not isinstance(cur_value, (int, float)) or cur_value < floor:
        failures.append(
            f"{name} regressed: {cur_value} < {floor:.2f} "
            f"(baseline {base_value:.2f}, tolerance {max_regress:.0%})")


def gate_stream(base, cur, args, failures):
    tol = 1.0 - args.max_regress
    cur_speedup = cur.get("local_vs_global_speedup")
    cur_rebase = cur.get("rebase_local_vs_gather_speedup")
    cur_rate = (cur.get("local") or {}).get("init_diffusions_per_sec")
    print(f"current: speedup={fmt(cur_speedup, '.2f')}x  "
          f"rebase local/gather={fmt(cur_rebase, '.2f')}x  "
          f"local diffusions/sec={fmt(cur_rate, '.3e')}  env={cur.get('environment')}")
    if not base.get("measured", False):
        print("baseline is a bootstrap placeholder (measured=false): gate passes; "
              "seed it from this run's uploaded artifact to arm the gate.")
        return
    gate_ratio(failures, "local_vs_global_speedup",
               base.get("local_vs_global_speedup"), cur_speedup, tol,
               args.max_regress)
    gate_ratio(failures, "rebase_local_vs_gather_speedup",
               base.get("rebase_local_vs_gather_speedup"), cur_rebase, tol,
               args.max_regress)
    base_rate = (base.get("local") or {}).get("init_diffusions_per_sec")
    if base_rate and base.get("environment") == cur.get("environment"):
        floor = base_rate * tol
        print(f"baseline diffusions/sec={base_rate:.3e}  (floor {floor:.3e}, same env)")
        if not isinstance(cur_rate, (int, float)) or cur_rate < floor:
            failures.append(
                f"diffusions/sec regressed: {cur_rate} < {floor:.3e} "
                f"(baseline {base_rate:.3e}, tolerance {args.max_regress:.0%})")
    elif base_rate:
        print("baseline recorded in a different environment: absolute "
              "diffusions/sec not enforced (ratio gate above still applies)")


def gate_elastic(base, cur, args, failures):
    tol = 1.0 - args.max_regress
    cur_speedup = cur.get("elastic_vs_fixed_speedup")
    print(f"current: elastic_vs_fixed={fmt(cur_speedup, '.2f')}x  "
          f"spawned={cur.get('pool_spawned')}  peak={cur.get('pool_peak_live')}  "
          f"env={cur.get('environment')}")
    # elastic must beat fixed-K regardless of the baseline state — the
    # bench asserts this too, so only an artifact edited by hand or a
    # skipped assert could get here, but the gate is the last line
    if isinstance(cur_speedup, (int, float)) and cur_speedup <= 1.0:
        failures.append(
            f"elastic_vs_fixed_speedup {cur_speedup:.2f}x <= 1.0: the elastic "
            "pool no longer beats fixed-K under the hotspot scenario")
    if not base.get("measured", False):
        print("baseline is a bootstrap placeholder (measured=false): gate passes; "
              "seed it from this run's uploaded artifact to arm the gate.")
        return
    gate_ratio(failures, "elastic_vs_fixed_speedup",
               base.get("elastic_vs_fixed_speedup"), cur_speedup, tol,
               args.max_regress)


def gate_hotpath(base, cur, args, failures):
    tol = 1.0 - args.max_regress
    cur_blocked = cur.get("blocked_vs_local_speedup")
    cur_lvg = cur.get("local_vs_global_speedup")
    cur_allocs = (cur.get("blocked") or {}).get("allocs_per_kupdate")
    print(f"current: blocked_vs_local={fmt(cur_blocked, '.2f')}x  "
          f"local_vs_global={fmt(cur_lvg, '.2f')}x  "
          f"blocked allocs/kupd={fmt(cur_allocs, '.2f')}  "
          f"env={cur.get('environment')}")
    if not base.get("measured", False):
        print("baseline is a bootstrap placeholder (measured=false): gate passes; "
              "seed it from this run's uploaded artifact to arm the gate.")
        return
    # the blocked kernel must beat the kernel it batches, full stop —
    # a <= 1.0 ratio means the unrolled/journaled path is pure overhead
    if not isinstance(cur_blocked, (int, float)) or cur_blocked <= 1.0:
        failures.append(
            f"blocked_vs_local_speedup {fmt(cur_blocked, '.2f')}x <= 1.0: the "
            "blocked kernel no longer beats the local-block kernel it batches")
    gate_ratio(failures, "blocked_vs_local_speedup",
               base.get("blocked_vs_local_speedup"), cur_blocked, tol,
               args.max_regress)
    gate_ratio(failures, "local_vs_global_speedup",
               base.get("local_vs_global_speedup"), cur_lvg, tol,
               args.max_regress)
    base_allocs = (base.get("blocked") or {}).get("allocs_per_kupdate")
    if isinstance(base_allocs, (int, float)) and \
            base.get("environment") == cur.get("environment"):
        ceiling = base_allocs * (1.0 + args.max_regress) + 1.0
        print(f"baseline blocked allocs/kupd={base_allocs:.2f}  "
              f"(ceiling {ceiling:.2f}, same env)")
        if not isinstance(cur_allocs, (int, float)) or cur_allocs > ceiling:
            failures.append(
                f"blocked allocs_per_kupdate regressed: {cur_allocs} > "
                f"{ceiling:.2f} (baseline {base_allocs:.2f}) — allocator "
                "traffic is creeping back into the hot loop")
    elif isinstance(base_allocs, (int, float)):
        print("baseline recorded in a different environment: allocs/kupd "
              "not enforced (ratio gates above still apply)")


def gate_wire(base, cur, args, failures):
    tol = 1.0 - args.max_regress
    cur_speedup = cur.get("batched_vs_unbatched_speedup")
    cur_rate = (cur.get("batched") or {}).get("parcels_per_sec")
    cur_allocs = (cur.get("batched") or {}).get("allocs_per_parcel")
    cur_syscalls = (cur.get("batched") or {}).get("syscalls_per_kparcel")
    print(f"current: batched_vs_unbatched={fmt(cur_speedup, '.2f')}x  "
          f"batched parcels/sec={fmt(cur_rate, '.3e')}  "
          f"allocs/parcel={fmt(cur_allocs, '.3f')}  "
          f"syscalls/kparcel={fmt(cur_syscalls, '.1f')}  "
          f"env={cur.get('environment')}")
    if not base.get("measured", False):
        print("baseline is a bootstrap placeholder (measured=false): gate passes; "
              "seed it from this run's uploaded artifact to arm the gate.")
        return
    # batching must beat flush-per-frame, full stop — a <= 1.0 ratio
    # means the vectored-write queue is pure overhead
    if not isinstance(cur_speedup, (int, float)) or cur_speedup <= 1.0:
        failures.append(
            f"batched_vs_unbatched_speedup {fmt(cur_speedup, '.2f')}x <= 1.0: "
            "the batching fast path no longer beats flush-per-frame")
    gate_ratio(failures, "batched_vs_unbatched_speedup",
               base.get("batched_vs_unbatched_speedup"), cur_speedup, tol,
               args.max_regress)
    base_rate = (base.get("batched") or {}).get("parcels_per_sec")
    if base_rate and base.get("environment") == cur.get("environment"):
        floor = base_rate * tol
        print(f"baseline batched parcels/sec={base_rate:.3e}  "
              f"(floor {floor:.3e}, same env)")
        if not isinstance(cur_rate, (int, float)) or cur_rate < floor:
            failures.append(
                f"batched parcels/sec regressed: {cur_rate} < {floor:.3e} "
                f"(baseline {base_rate:.3e}, tolerance {args.max_regress:.0%})")
    elif base_rate:
        print("baseline recorded in a different environment: absolute "
              "parcels/sec not enforced (ratio gate above still applies)")
    base_allocs = (base.get("batched") or {}).get("allocs_per_parcel")
    if isinstance(base_allocs, (int, float)) and \
            base.get("environment") == cur.get("environment"):
        ceiling = base_allocs * (1.0 + args.max_regress) + 1.0
        print(f"baseline batched allocs/parcel={base_allocs:.3f}  "
              f"(ceiling {ceiling:.3f}, same env)")
        if not isinstance(cur_allocs, (int, float)) or cur_allocs > ceiling:
            failures.append(
                f"batched allocs_per_parcel regressed: {cur_allocs} > "
                f"{ceiling:.3f} (baseline {base_allocs:.3f}) — allocator "
                "traffic is creeping back into the wire fast path")
    elif isinstance(base_allocs, (int, float)):
        print("baseline recorded in a different environment: allocs/parcel "
              "not enforced (ratio gates above still apply)")


def gate_recovery(base, cur, args, failures):
    tol = 1.0 - args.max_regress
    cur_speedup = cur.get("recovery_vs_cold_speedup")
    cur_overhead = cur.get("checkpoint_overhead_ratio")
    cur_wall = cur.get("recovery_time_to_converge_secs")
    print(f"current: recovery_vs_cold={fmt(cur_speedup, '.2f')}x  "
          f"checkpoint_overhead={fmt(cur_overhead, '.2f')}x  "
          f"recovery wall={fmt(cur_wall, '.3f')}s  "
          f"env={cur.get('environment')}")
    # recovery must beat a cold restart, full stop — a <= 1.0 ratio
    # means restoring the checkpoint and recomputing fluid is pure
    # overhead versus just re-solving. This is a property of the CURRENT
    # run alone, so it is enforced even while the committed baseline is
    # still the bootstrap placeholder.
    if cur.get("measured", False) and (
            not isinstance(cur_speedup, (int, float)) or cur_speedup <= 1.0):
        failures.append(
            f"recovery_vs_cold_speedup {fmt(cur_speedup, '.2f')}x <= 1.0: "
            "crash recovery no longer beats restarting from scratch")
    if not base.get("measured", False):
        print("baseline is a bootstrap placeholder (measured=false): "
              "regression gates pass; seed it from this run's uploaded "
              "artifact to arm them.")
        return
    gate_ratio(failures, "recovery_vs_cold_speedup",
               base.get("recovery_vs_cold_speedup"), cur_speedup, tol,
               args.max_regress)
    base_overhead = base.get("checkpoint_overhead_ratio")
    if isinstance(base_overhead, (int, float)):
        ceiling = base_overhead * (1.0 + args.max_regress)
        print(f"baseline checkpoint_overhead={base_overhead:.2f}x  "
              f"(ceiling {ceiling:.2f}x)")
        if not isinstance(cur_overhead, (int, float)) or cur_overhead > ceiling:
            failures.append(
                f"checkpoint_overhead_ratio regressed: {cur_overhead} > "
                f"{ceiling:.2f} (baseline {base_overhead:.2f}) — the "
                "incremental journal is taxing the no-failure hot path")
    base_wall = base.get("recovery_time_to_converge_secs")
    if isinstance(base_wall, (int, float)) and \
            base.get("environment") == cur.get("environment"):
        ceiling = base_wall * (1.0 + args.max_regress)
        print(f"baseline recovery wall={base_wall:.3f}s  "
              f"(ceiling {ceiling:.3f}s, same env)")
        if not isinstance(cur_wall, (int, float)) or cur_wall > ceiling:
            failures.append(
                f"recovery_time_to_converge_secs regressed: {cur_wall} > "
                f"{ceiling:.3f}s (baseline {base_wall:.3f}s)")
    elif isinstance(base_wall, (int, float)):
        print("baseline recorded in a different environment: absolute "
              "recovery wall not enforced (ratio gates above still apply)")


def gate_serve(base, cur, args, failures):
    tol = 1.0 - args.max_regress
    cur_speedup = cur.get("batched_vs_sequential_speedup")
    cur_qps = cur.get("batched_queries_per_sec")
    cur_p99 = cur.get("p99_time_to_eps_secs")
    print(f"current: batched_vs_sequential={fmt(cur_speedup, '.2f')}x  "
          f"batched queries/sec={fmt(cur_qps, '.2f')}  "
          f"p99 time-to-eps={fmt(cur_p99, '.3f')}s  "
          f"env={cur.get('environment')}")
    # lanes must beat one-query-at-a-time, full stop — a <= 1.0 ratio
    # means the multi-RHS machinery is pure overhead. This is a property
    # of the CURRENT run alone, so it is enforced even while the
    # committed baseline is still the bootstrap placeholder.
    if cur.get("measured", False) and (
            not isinstance(cur_speedup, (int, float)) or cur_speedup <= 1.0):
        failures.append(
            f"batched_vs_sequential_speedup {fmt(cur_speedup, '.2f')}x <= 1.0: "
            "multi-lane serving no longer beats sequential one-query-at-a-time")
    if not base.get("measured", False):
        print("baseline is a bootstrap placeholder (measured=false): "
              "regression gates pass; seed it from this run's uploaded "
              "artifact to arm them.")
        return
    gate_ratio(failures, "batched_vs_sequential_speedup",
               base.get("batched_vs_sequential_speedup"), cur_speedup, tol,
               args.max_regress)
    base_qps = base.get("batched_queries_per_sec")
    if base_qps and base.get("environment") == cur.get("environment"):
        floor = base_qps * tol
        print(f"baseline batched queries/sec={base_qps:.2f}  "
              f"(floor {floor:.2f}, same env)")
        if not isinstance(cur_qps, (int, float)) or cur_qps < floor:
            failures.append(
                f"batched queries/sec regressed: {cur_qps} < {floor:.2f} "
                f"(baseline {base_qps:.2f}, tolerance {args.max_regress:.0%})")
    elif base_qps:
        print("baseline recorded in a different environment: absolute "
              "queries/sec not enforced (ratio gate above still applies)")
    base_p99 = base.get("p99_time_to_eps_secs")
    if isinstance(base_p99, (int, float)) and \
            base.get("environment") == cur.get("environment"):
        ceiling = base_p99 * (1.0 + args.max_regress)
        print(f"baseline p99 time-to-eps={base_p99:.3f}s  "
              f"(ceiling {ceiling:.3f}s, same env)")
        if not isinstance(cur_p99, (int, float)) or cur_p99 > ceiling:
            failures.append(
                f"p99 time-to-eps regressed: {cur_p99} > {ceiling:.3f}s "
                f"(baseline {base_p99:.3f}s) — completion latency is paying "
                "the coalescing tax again")
    elif isinstance(base_p99, (int, float)):
        print("baseline recorded in a different environment: p99 "
              "time-to-eps not enforced (ratio gate above still applies)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="committed BENCH_*.json")
    ap.add_argument("--current", required=True, help="freshly produced BENCH_*.json")
    ap.add_argument("--kind",
                    choices=["stream", "elastic", "hotpath", "wire", "serve",
                             "recovery"],
                    default="stream",
                    help="which bench artifact schema to gate (default stream)")
    ap.add_argument("--max-regress", type=float, default=0.20,
                    help="allowed fractional regression (default 0.20)")
    args = ap.parse_args()

    base = load(args.baseline)
    cur = load(args.current)
    failures = []
    if args.kind == "elastic":
        gate_elastic(base, cur, args, failures)
    elif args.kind == "hotpath":
        gate_hotpath(base, cur, args, failures)
    elif args.kind == "wire":
        gate_wire(base, cur, args, failures)
    elif args.kind == "serve":
        gate_serve(base, cur, args, failures)
    elif args.kind == "recovery":
        gate_recovery(base, cur, args, failures)
    else:
        gate_stream(base, cur, args, failures)

    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        return 1
    print("perf gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
