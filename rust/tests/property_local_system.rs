//! Property tests for the partition-local diffusion kernel: a
//! [`LocalSystem`] diffusion must be **bit-identical** to walking the
//! global CSC column and routing each entry by ownership — over random
//! partitions, random handoff (ownership-churn) sequences, and
//! dirty-column-patched streaming epochs.

use std::collections::HashMap;

use diter::graph::{power_law_web_graph, ChurnModel, MutableDigraph, MutationStream};
use diter::partition::Partition;
use diter::prop::{run_cases, Gen};
use diter::sparse::{CscMatrix, LocalSystem, SparseMatrix};

/// Per-destination slot interner mirroring the CoalesceBuffer's contract.
struct Interner {
    maps: Vec<HashMap<usize, u32>>,
    coords: Vec<Vec<usize>>,
}

impl Interner {
    fn new(k: usize) -> Interner {
        Interner {
            maps: (0..k).map(|_| HashMap::new()).collect(),
            coords: vec![Vec::new(); k],
        }
    }

    fn intern(&mut self, d: usize, j: usize) -> u32 {
        if let Some(&s) = self.maps[d].get(&j) {
            return s;
        }
        let s = self.coords[d].len() as u32;
        self.maps[d].insert(j, s);
        self.coords[d].push(j);
        s
    }
}

/// One PID's (owned, local_of, LocalSystem, interner) bundle.
type BuiltLocal = (Vec<usize>, Vec<usize>, LocalSystem, Interner);

/// Build one PID's local view off a partition.
fn build_for_pid(csc: &CscMatrix, part: &Partition, pid: usize) -> BuiltLocal {
    let n = part.n();
    let owned = part.part(pid).to_vec();
    let mut local_of = vec![usize::MAX; n];
    for &i in &owned {
        local_of[i] = part.slot(i);
    }
    let mut it = Interner::new(part.k());
    let sys = LocalSystem::build(csc, &owned, &local_of, part.owners(), |d, j| it.intern(d, j));
    (owned, local_of, sys, it)
}

/// Diffuse `fi` from owned slot `t` through the LocalSystem; returns
/// (local f additions, per-dest coordinate→mass maps).
fn diffuse_local(
    sys: &LocalSystem,
    it: &Interner,
    k: usize,
    m: usize,
    t: usize,
    fi: f64,
) -> (Vec<f64>, Vec<HashMap<usize, f64>>) {
    let mut f = vec![0.0; m];
    let mut out: Vec<HashMap<usize, f64>> = vec![HashMap::new(); k];
    let (rows, vals) = sys.block_col(t);
    for u in 0..rows.len() {
        f[rows[u] as usize] += vals[u] * fi;
    }
    let (dests, slots, vals) = sys.remnant_col(t);
    for u in 0..dests.len() {
        let d = dests[u] as usize;
        let coord = it.coords[d][slots[u] as usize];
        *out[d].entry(coord).or_insert(0.0) += vals[u] * fi;
    }
    (f, out)
}

/// Reference: walk the global CSC column, route by local_of/owner — the
/// exact operations the global-walk kernel performs.
fn diffuse_global(
    csc: &CscMatrix,
    part: &Partition,
    local_of: &[usize],
    m: usize,
    i: usize,
    fi: f64,
) -> (Vec<f64>, Vec<HashMap<usize, f64>>) {
    let mut f = vec![0.0; m];
    let mut out: Vec<HashMap<usize, f64>> = vec![HashMap::new(); part.k()];
    let (rows, vals) = csc.col(i);
    for u in 0..rows.len() {
        let j = rows[u];
        let contrib = vals[u] * fi;
        if local_of[j] != usize::MAX {
            f[local_of[j]] += contrib;
        } else {
            *out[part.owner(j)].entry(j).or_insert(0.0) += contrib;
        }
    }
    (f, out)
}

fn assert_diffusions_match(csc: &CscMatrix, part: &Partition, fi: f64) {
    for pid in 0..part.k() {
        let (owned, local_of, sys, it) = build_for_pid(csc, part, pid);
        for (t, &i) in owned.iter().enumerate() {
            let (fl, outl) = diffuse_local(&sys, &it, part.k(), owned.len(), t, fi);
            let (fg, outg) = diffuse_global(csc, part, &local_of, owned.len(), i, fi);
            assert_eq!(fl, fg, "local f mismatch, pid {pid}, coord {i}");
            assert_eq!(outl, outg, "remnant mismatch, pid {pid}, coord {i}");
        }
    }
}

/// The blocked kernel's 4-wide unrolled column walk (`chunks_exact(4)` +
/// remainder), mirrored exactly: same visit order, same multiply, same
/// accumulation target per entry.
fn diffuse_local_unrolled(sys: &LocalSystem, m: usize, t: usize, fi: f64) -> Vec<f64> {
    let mut f = vec![0.0; m];
    let (rows, vals) = sys.block_col(t);
    let mut rc = rows.chunks_exact(4);
    let mut vc = vals.chunks_exact(4);
    for (r4, v4) in (&mut rc).zip(&mut vc) {
        f[r4[0] as usize] += v4[0] * fi;
        f[r4[1] as usize] += v4[1] * fi;
        f[r4[2] as usize] += v4[2] * fi;
        f[r4[3] as usize] += v4[3] * fi;
    }
    for (&r, &v) in rc.remainder().iter().zip(vc.remainder()) {
        f[r as usize] += v * fi;
    }
    f
}

#[test]
fn unrolled_block_walk_is_bit_identical_to_the_scalar_walk() {
    // two invariants the blocked kernel's unroll rests on, over random
    // partitions: (1) a block column never repeats a local row — so the
    // four accumulations per step cannot alias, and reordering them could
    // never change a sum; (2) the unrolled walk produces bit-identical f
    // to the scalar walk (not merely ≈: same entries, same order, same
    // one-add-per-slot)
    run_cases(40, 0xB10CED, |g| {
        let n = g.usize_in(4, 48);
        let k = g.usize_in(2, n.min(6));
        let m = g.contraction_matrix(n, 4, 0.9);
        let sparse = SparseMatrix::from_csr(m);
        let part = random_partition(g, n, k);
        let fi = g.f64_in(0.1, 2.0);
        for pid in 0..part.k() {
            let (owned, _, sys, it) = build_for_pid(sparse.csc(), &part, pid);
            for t in 0..owned.len() {
                let (rows, _) = sys.block_col(t);
                let mut seen = vec![false; owned.len()];
                for &r in rows {
                    assert!(
                        !seen[r as usize],
                        "block column {t} (pid {pid}) repeats local row {r} — \
                         the 4-wide unroll would alias"
                    );
                    seen[r as usize] = true;
                }
                let scalar = diffuse_local(&sys, &it, part.k(), owned.len(), t, fi).0;
                let unrolled = diffuse_local_unrolled(&sys, owned.len(), t, fi);
                assert!(
                    scalar.iter().zip(&unrolled).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "unrolled walk diverged from the scalar walk (pid {pid}, slot {t})"
                );
            }
        }
    });
}

fn random_partition(g: &mut Gen, n: usize, k: usize) -> Partition {
    // random owner map with a guaranteed non-empty part for every PID
    let mut owner: Vec<usize> = (0..n).map(|i| i % k).collect();
    let perm = g.permutation(n);
    let shuffled: Vec<usize> = perm.iter().map(|&p| owner[p]).collect();
    owner = shuffled;
    Partition::from_owner(owner, k).unwrap()
}

#[test]
fn local_system_diffusion_equals_global_walk_over_random_partitions() {
    run_cases(40, 0x10CA1, |g| {
        let n = g.usize_in(4, 48);
        let k = g.usize_in(2, n.min(6));
        let m = g.contraction_matrix(n, 4, 0.9);
        let sparse = SparseMatrix::from_csr(m);
        let part = random_partition(g, n, k);
        assert_diffusions_match(sparse.csc(), &part, g.f64_in(0.1, 2.0));
    });
}

#[test]
fn local_system_diffusion_survives_random_handoff_sequences() {
    // ownership churn: after every transfer the rebuilt LocalSystems must
    // still agree with the global walk under the new owner map
    run_cases(20, 0xA4D0FF ^ 0xBEEF, |g| {
        let n = g.usize_in(8, 40);
        let k = g.usize_in(2, 4);
        let m = g.contraction_matrix(n, 3, 0.9);
        let sparse = SparseMatrix::from_csr(m);
        let mut part = Partition::contiguous(n, k).unwrap();
        for _ in 0..g.usize_in(1, 6) {
            // move a random chunk of a random part to a random other PID
            let from = g.usize_in(0, k - 1);
            let to = g.usize_in(0, k - 1);
            let members = part.part(from).to_vec();
            if from == to || members.len() < 2 {
                continue;
            }
            let take = g.usize_in(1, members.len() - 1);
            let Ok(next) = part.transfer(&members[..take], to) else {
                continue;
            };
            part = next;
            part.validate().unwrap();
            assert_diffusions_match(sparse.csc(), &part, 0.7385);
        }
    });
}

#[test]
fn ownership_patched_local_system_equals_fresh_build() {
    // the adopt/shed/retarget delta maintenance (ROADMAP's
    // `patch_handoff`): across a random sequence of ownership transfers,
    // every PID keeps its LocalSystem alive by patching — shipper sheds,
    // receiver adopts, bystanders retarget — and after every transfer the
    // patched system must diffuse exactly like a fresh build over the new
    // owner map. This is the invariant that makes spawn-time adoption
    // (an elastic worker growing from an empty Ω) safe.
    run_cases(20, 0xE1A511C, |g| {
        let n = g.usize_in(8, 40);
        let k = g.usize_in(2, 4);
        let m = g.contraction_matrix(n, 3, 0.9);
        let sparse = SparseMatrix::from_csr(m);
        let csc = sparse.csc();
        let mut part = Partition::contiguous(n, k).unwrap();
        // per-PID live state: (owned, local_of, LocalSystem, Interner)
        let mut built: Vec<BuiltLocal> =
            (0..k).map(|pid| build_for_pid(csc, &part, pid)).collect();
        for _ in 0..g.usize_in(1, 6) {
            let from = g.usize_in(0, k - 1);
            let to = g.usize_in(0, k - 1);
            let members = part.part(from).to_vec();
            if from == to || members.len() < 2 {
                continue;
            }
            let take = g.usize_in(1, members.len() - 1);
            let moved: Vec<usize> = members[..take].to_vec();
            let Ok(next) = part.transfer(&moved, to) else {
                continue;
            };
            part = next;
            // shipper sheds the moved slots
            {
                let (owned, local_of, sys, it) = &mut built[from];
                let shipped: Vec<bool> =
                    owned.iter().map(|i| moved.binary_search(i).is_ok()).collect();
                let mut new_slot = vec![u32::MAX; owned.len()];
                let mut s = 0u32;
                for (t, &sh) in shipped.iter().enumerate() {
                    if !sh {
                        new_slot[t] = s;
                        s += 1;
                    }
                }
                sys.shed(owned, &shipped, &new_slot, part.owners(), |d, j| {
                    it.intern(d, j)
                });
                let kept: Vec<usize> = owned
                    .iter()
                    .copied()
                    .filter(|i| moved.binary_search(i).is_err())
                    .collect();
                for &i in &moved {
                    local_of[i] = usize::MAX;
                }
                for (t, &i) in kept.iter().enumerate() {
                    local_of[i] = t;
                }
                *owned = kept;
            }
            // receiver adopts them (appended, like a handoff fold)
            {
                let (owned, local_of, sys, it) = &mut built[to];
                for &i in &moved {
                    local_of[i] = owned.len();
                    owned.push(i);
                }
                sys.adopt(csc, &moved, local_of, part.owners(), |d, j| {
                    it.intern(d, j)
                });
            }
            // bystanders retarget in place
            for pid in 0..k {
                if pid == from || pid == to {
                    continue;
                }
                let (_, local_of, sys, it) = &mut built[pid];
                assert!(
                    sys.retarget(local_of, part.owners(), |d, j| it.intern(d, j)),
                    "a bystander never needs a structural rebuild"
                );
            }
            // every PID's patched system ≡ a fresh build + fresh interner
            for pid in 0..k {
                let (owned, _, sys, it) = &built[pid];
                let (f_owned, _, fresh, fresh_it) = build_for_pid(csc, &part, pid);
                assert_eq!(owned.len(), f_owned.len(), "pid {pid} cover drifted");
                for t in 0..owned.len() {
                    // patched slots are in adoption order, fresh slots in
                    // sorted order: compare per *coordinate*
                    let ft = f_owned
                        .iter()
                        .position(|&i| i == owned[t])
                        .expect("same owned set");
                    let (fp, op) = diffuse_local(sys, it, k, owned.len(), t, 1.0);
                    let (ff, of) = diffuse_local(&fresh, &fresh_it, k, f_owned.len(), ft, 1.0);
                    // block additions land on local slots — map both back
                    // to coordinates before comparing
                    let mut by_coord_p: Vec<(usize, f64)> = fp
                        .iter()
                        .enumerate()
                        .filter(|(_, v)| **v != 0.0)
                        .map(|(s, &v)| (owned[s], v))
                        .collect();
                    let mut by_coord_f: Vec<(usize, f64)> = ff
                        .iter()
                        .enumerate()
                        .filter(|(_, v)| **v != 0.0)
                        .map(|(s, &v)| (f_owned[s], v))
                        .collect();
                    by_coord_p.sort_by(|a, b| a.partial_cmp(b).unwrap());
                    by_coord_f.sort_by(|a, b| a.partial_cmp(b).unwrap());
                    assert_eq!(by_coord_p, by_coord_f, "pid {pid} block diverged");
                    assert_eq!(op, of, "pid {pid} remnant diverged");
                }
            }
        }
    });
}

#[test]
fn patched_local_system_equals_fresh_build_across_epochs() {
    run_cases(15, 0xEF0C4, |g| {
        let n = g.usize_in(12, 40);
        let k = g.usize_in(2, 4);
        let web = power_law_web_graph(n, 4, 0.1, g.case_seed);
        let mut mg = MutableDigraph::from_digraph(&web, n);
        let sys0 = mg.pagerank_system(0.85, true).unwrap();
        let part = random_partition(g, n, k);
        // build every PID's LocalSystem on the epoch-0 matrix
        let mut built: Vec<BuiltLocal> = (0..k)
            .map(|pid| build_for_pid(sys0.matrix.csc(), &part, pid))
            .collect();
        // a few epochs of churn, patching after each rebuild
        let model = if g.bool() {
            ChurnModel::RandomRewire
        } else {
            ChurnModel::HotSpotBurst { burst: 6 }
        };
        let mut stream = MutationStream::new(model, g.case_seed ^ 0x5EED);
        for _ in 0..g.usize_in(1, 3) {
            let batch = stream.next_batch(&mg, g.usize_in(1, 10));
            for mutation in &batch {
                mg.apply(mutation);
            }
            let sys = mg.pagerank_system(0.85, true).unwrap();
            let dirty = mg
                .last_build_dirty()
                .expect("warm rebuild reports its dirty columns")
                .to_vec();
            for (pid, (owned, local_of, local, it)) in built.iter_mut().enumerate() {
                local.patch(sys.matrix.csc(), owned, local_of, part.owners(), &dirty, |d, j| {
                    it.intern(d, j)
                });
                // the patched system must behave exactly like a fresh build
                let (_, _, fresh, fresh_it) = build_for_pid(sys.matrix.csc(), &part, pid);
                for t in 0..owned.len() {
                    let (fp, op) = diffuse_local(local, it, k, owned.len(), t, 1.0);
                    let (ff, of) = diffuse_local(&fresh, &fresh_it, k, owned.len(), t, 1.0);
                    assert_eq!(fp, ff, "patched block diverged (pid {pid}, slot {t})");
                    assert_eq!(op, of, "patched remnant diverged (pid {pid}, slot {t})");
                }
            }
        }
    });
}
