//! Integration: the distributed schemes (threaded V1/V2 and the lockstep
//! simulator) against the sequential oracle across workloads, partitions,
//! sequences, latency and coalescing settings.

use std::time::Duration;

use diter::coordinator::{sim, v1, v2, DistributedConfig};
use diter::graph::{
    block_coupled_matrix, grid_digraph, pagerank_system, paper_matrix, power_law_web_graph,
};
use diter::linalg::vec_ops::{dist1, dist_inf, norm1};
use diter::partition::Partition;
use diter::solver::{FixedPointProblem, SequenceKind, SolveOptions, Solver};
use diter::sparse::SparseMatrix;
use diter::transport::CoalescePolicy;

fn block_problem(n: usize, k: usize, coupling: f64, seed: u64) -> FixedPointProblem {
    let p = block_coupled_matrix(n, k, 0.45, coupling, 5, seed);
    FixedPointProblem::new(SparseMatrix::from_csr(p), vec![1.0; n]).unwrap()
}

#[test]
fn v1_and_v2_agree_with_exact_across_k() {
    let n = 64;
    let problem = block_problem(n, 4, 0.15, 9);
    let exact = problem.exact_solution().unwrap();
    for k in [1usize, 2, 4, 8] {
        let cfg = DistributedConfig::new(Partition::contiguous(n, k).unwrap()).with_tol(1e-11);
        let s1 = v1::solve_v1(&problem, &cfg).unwrap();
        assert!(s1.converged, "v1 k={k} residual={}", s1.residual);
        assert!(dist_inf(&s1.x, &exact) < 1e-8, "v1 k={k}");
        let s2 = v2::solve_v2(&problem, &cfg).unwrap();
        assert!(s2.converged, "v2 k={k} residual={}", s2.residual);
        assert!(dist_inf(&s2.x, &exact) < 1e-8, "v2 k={k}");
    }
}

#[test]
fn v2_on_grid_graph_pagerank() {
    // grid torus: maximal locality, contiguous partition cuts only edges
    // at the strip boundaries
    let g = grid_digraph(20); // 400 nodes
    let sys = pagerank_system(&g, 0.85, true).unwrap();
    let problem = FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap();
    let cfg = DistributedConfig::new(Partition::contiguous(400, 4).unwrap()).with_tol(1e-10);
    let sol = v2::solve_v2(&problem, &cfg).unwrap();
    assert!(sol.converged);
    assert!((norm1(&sol.x) - 1.0).abs() < 1e-7);
}

#[test]
fn v2_greedy_on_web_graph_matches_sequential() {
    let g = power_law_web_graph(600, 6, 0.1, 31);
    let sys = pagerank_system(&g, 0.85, true).unwrap();
    let problem = FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap();
    let seq = diter::solver::DIteration::fluid_cyclic()
        .solve(
            &problem,
            &SolveOptions {
                tol: 1e-13,
                max_cost: 100_000.0,
                trace_every: 0.0,
                exact: None,
            },
        )
        .unwrap();
    let cfg = DistributedConfig::new(Partition::contiguous(600, 4).unwrap())
        .with_tol(1e-11)
        .with_sequence(SequenceKind::GreedyMaxFluid);
    let sol = v2::solve_v2(&problem, &cfg).unwrap();
    assert!(sol.converged);
    assert!(dist1(&sol.x, &seq.x) < 1e-7);
}

#[test]
fn round_robin_vs_contiguous_both_correct() {
    let n = 48;
    let problem = block_problem(n, 4, 0.1, 2);
    let exact = problem.exact_solution().unwrap();
    for part in [
        Partition::contiguous(n, 4).unwrap(),
        Partition::round_robin(n, 4).unwrap(),
        Partition::greedy_edge_cut(problem.matrix().csr(), 4, 0.3).unwrap(),
    ] {
        let cfg = DistributedConfig::new(part).with_tol(1e-11);
        let sol = v2::solve_v2(&problem, &cfg).unwrap();
        assert!(sol.converged);
        assert!(dist_inf(&sol.x, &exact) < 1e-8);
    }
}

#[test]
fn aggressive_coalescing_still_converges_exactly() {
    let n = 96;
    let problem = block_problem(n, 3, 0.25, 7);
    let exact = problem.exact_solution().unwrap();
    for min_mass in [1e-9, 1e-5, 1e-3] {
        let mut cfg =
            DistributedConfig::new(Partition::contiguous(n, 3).unwrap()).with_tol(1e-11);
        cfg.coalesce = CoalescePolicy {
            min_mass,
            max_entries: 8,
        };
        let sol = v2::solve_v2(&problem, &cfg).unwrap();
        assert!(sol.converged, "min_mass={min_mass}");
        assert!(dist_inf(&sol.x, &exact) < 1e-8, "min_mass={min_mass}");
    }
}

#[test]
fn latency_jitter_does_not_affect_the_fixed_point() {
    let n = 48;
    let problem = block_problem(n, 4, 0.2, 5);
    let exact = problem.exact_solution().unwrap();
    for (lo_us, hi_us) in [(10u64, 50u64), (100, 1000)] {
        let mut cfg =
            DistributedConfig::new(Partition::contiguous(n, 4).unwrap()).with_tol(1e-11);
        cfg.latency = Some((
            Duration::from_micros(lo_us),
            Duration::from_micros(hi_us),
        ));
        let sol = v2::solve_v2(&problem, &cfg).unwrap();
        assert!(sol.converged, "latency {lo_us}-{hi_us}µs");
        assert!(dist_inf(&sol.x, &exact) < 1e-8);
    }
}

#[test]
fn transport_metrics_are_recorded() {
    let n = 32;
    let problem = block_problem(n, 4, 0.3, 3);
    let cfg = DistributedConfig::new(Partition::contiguous(n, 4).unwrap()).with_tol(1e-10);
    let sol = v2::solve_v2(&problem, &cfg).unwrap();
    assert!(sol.converged);
    assert!(sol.metrics["msgs_sent"] > 0);
    assert_eq!(sol.metrics["msgs_sent"], sol.metrics["msgs_recv"]);
    assert!(sol.metrics["bytes_sent"] > 0);
    assert!(sol.total_updates > 0);
    assert!(sol.updates_per_sec() > 0.0);
}

#[test]
fn lockstep_sim_matches_threaded_fixed_point() {
    let problem = block_problem(32, 2, 0.2, 4);
    let exact = problem.exact_solution().unwrap();
    let snaps = sim::simulate_v1(
        &problem,
        &sim::SimConfig {
            partition: Partition::contiguous(32, 2).unwrap(),
            sweeps_per_share: 2,
            max_cost: 300,
            switch_at: None,
        },
    )
    .unwrap();
    assert!(dist1(&snaps.last().unwrap().x, &exact) < 1e-10);
}

#[test]
fn split_merge_partitions_remain_usable() {
    // §4.3 speed adaptation: split the slowest PID's set, merge the fastest
    let n = 40;
    let problem = block_problem(n, 4, 0.15, 8);
    let exact = problem.exact_solution().unwrap();
    let base = Partition::contiguous(n, 4).unwrap();
    let split = base.split_part(0).unwrap(); // now 5 parts
    let merged = split.merge_parts(1, 2).unwrap(); // back to 4
    for part in [split, merged] {
        let cfg = DistributedConfig::new(part).with_tol(1e-11);
        let sol = v2::solve_v2(&problem, &cfg).unwrap();
        assert!(sol.converged);
        assert!(dist_inf(&sol.x, &exact) < 1e-8);
    }
}

#[test]
fn monitor_trace_total_fluid_eventually_below_tol() {
    let problem = block_problem(32, 2, 0.2, 6);
    let cfg = DistributedConfig::new(Partition::contiguous(32, 2).unwrap()).with_tol(1e-10);
    let sol = v2::solve_v2(&problem, &cfg).unwrap();
    assert!(sol.converged);
    let last = sol.trace.points.last().unwrap();
    assert!(last.error < 1e-10, "final monitored fluid {}", last.error);
}

#[test]
fn paper_protocol_2pids_on_a1_through_threaded_v1() {
    let problem =
        FixedPointProblem::from_linear_system(&paper_matrix(1), &[1.0; 4]).unwrap();
    let exact = problem.exact_solution().unwrap();
    let cfg = DistributedConfig::new(Partition::contiguous(4, 2).unwrap()).with_tol(1e-12);
    let sol = v1::solve_v1(&problem, &cfg).unwrap();
    assert!(sol.converged);
    assert!(dist_inf(&sol.x, &exact) < 1e-10);
    // A(1) is block-diagonal w.r.t. this partition: V1 needs only the
    // final consistency shares, so message volume stays tiny
    assert!(sol.metrics["msgs_sent"] < 1000);
}
