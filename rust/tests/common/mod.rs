//! Shared machinery for the integration suites — the reusable half of
//! what `integration_elastic.rs` grew inline: the sequential reference
//! solve and the conservation/fixed-point assertions every streaming
//! scenario ends on.
//!
//! Included per test crate via `mod common;`, so each crate compiles its
//! own copy and only uses what it needs.
#![allow(dead_code)]

use diter::coordinator::StreamingEngine;
use diter::linalg::vec_ops::{dist1, norm1};
use diter::solver::{DIteration, FixedPointProblem, SolveOptions, Solver};

/// Reference fixed point: a tight sequential cold solve of `problem`.
pub fn cold_solution(problem: &FixedPointProblem) -> Vec<f64> {
    let opts = SolveOptions {
        tol: 1e-13,
        max_cost: 200_000.0,
        trace_every: 0.0,
        exact: None,
    };
    DIteration::fluid_cyclic().solve(problem, &opts).unwrap().x
}

/// The two invariants every streaming scenario must land on, whatever
/// interleaving of epochs, handoffs, spawns and retirements produced
/// `x`: exact fluid conservation (for patched PageRank, unit L1 mass)
/// and agreement with a sequential cold solve of the engine's current
/// system.
pub fn assert_fixed_point(engine: &StreamingEngine, x: &[f64], eps: f64, ctx: &str) {
    assert!(
        (norm1(x) - 1.0).abs() < eps,
        "[{ctx}] PageRank mass not conserved: ‖x‖₁ = {}",
        norm1(x)
    );
    let want = cold_solution(engine.problem());
    assert!(
        dist1(x, &want) < eps,
        "[{ctx}] fixed point off the cold solve: Δ₁ = {:.3e}",
        dist1(x, &want)
    );
}

/// Render a caught panic payload for failure reports.
pub fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "<non-string panic>".into())
}
