//! Integration: the multi-tenant serving layer (DESIGN.md §10).
//!
//! The load-bearing property is linearity: D-iteration is linear in B,
//! so L queries diffusing concurrently in separate fluid lanes of ONE
//! worker pool must land on exactly the fixed points that L independent
//! single-query solves produce. Admission control (queue-or-reject,
//! per-query deadline eviction) is exercised around that core.

use std::time::Duration;

use diter::coordinator::{
    DistributedConfig, Query, QueryState, ServeConfig, ServeEngine,
};
use diter::graph::{power_law_web_graph, MutableDigraph};
use diter::linalg::vec_ops::{dist1, norm1};
use diter::partition::Partition;
use diter::solver::{DIteration, FixedPointProblem, SolveOptions, Solver};

const N: usize = 400;
const K: usize = 3;
const DAMPING: f64 = 0.85;

fn serve_engine(query_lanes: usize, cfg: ServeConfig, seed: u64) -> ServeEngine {
    let g = power_law_web_graph(N, 6, 0.1, seed);
    let mg = MutableDigraph::from_digraph(&g, N);
    let dist = DistributedConfig::new(Partition::contiguous(N, K).unwrap())
        .with_tol(1e-9)
        .with_seed(seed);
    ServeEngine::new(mg, DAMPING, true, dist, cfg, query_lanes).unwrap()
}

/// Cold single-query reference: solve (P, b_q) alone, to far below the
/// serving ε, on the same matrix the engine is holding.
fn independent_solve(serve: &ServeEngine, seeds: &[usize]) -> Vec<f64> {
    let q = Query::ppr(seeds, DAMPING, 1e-8);
    let mut b = vec![0.0; N];
    for (c, m) in &q.seeds {
        b[*c] += m;
    }
    let single =
        FixedPointProblem::new(serve.engine().problem().matrix().clone(), b).unwrap();
    let opts = SolveOptions {
        tol: 1e-12,
        max_cost: 500_000.0,
        trace_every: 0.0,
        exact: None,
    };
    DIteration::fluid_cyclic().solve(&single, &opts).unwrap().x
}

/// Serving L queries through shared lanes ≡ L independent single-query
/// solves: every concurrent readout matches its own cold fixed point.
#[test]
fn multi_query_equals_independent_single_query_solves() {
    let eps = 1e-8;
    let mut serve = serve_engine(
        3,
        ServeConfig {
            queue_cap: 8,
            default_eps: eps,
            ..Default::default()
        },
        41,
    );
    // six queries over three lanes: the second trio queues behind the
    // first, so admission-from-queue is on the tested path too
    let seed_sets: [&[usize]; 6] = [&[3], &[17, 20], &[99], &[250, 251], &[7, 300], &[111]];
    let mut pending = Vec::new();
    for seeds in seed_sets {
        let qid = serve
            .submit(Query::ppr(seeds, DAMPING, eps))
            .expect("queue sized for all six");
        pending.push((qid, seeds));
    }
    let done = serve.drain(Duration::from_secs(120)).unwrap();
    assert_eq!(done.len(), seed_sets.len(), "every query must complete");
    for d in &done {
        assert_eq!(d.state, QueryState::Served);
        let x = d.x.as_ref().expect("served queries carry a readout");
        assert!(
            (norm1(x) - 1.0).abs() < 1e-5,
            "qid {}: unit PPR mass, got {}",
            d.qid,
            norm1(x)
        );
        // ε bounds the undelivered fluid, and ‖x − x*‖₁ ≤ ε/(1−d); the
        // graph is never mutated here, so the comparison is exact
        let seeds = pending.iter().find(|(q, _)| *q == d.qid).unwrap().1;
        let want = independent_solve(&serve, seeds);
        let delta = dist1(x, &want);
        assert!(
            delta < 1e-5,
            "qid {}: concurrent serve diverged from its independent solve \
             (Δ₁ = {delta:.3e})",
            d.qid
        );
    }
    let (admitted, served, rejected) = serve.counts();
    assert_eq!(admitted, 6);
    assert_eq!(served, 6);
    assert_eq!(rejected, 0);
    serve.finish().unwrap();
}

/// Queue-or-reject: with L lanes and a queue of Q, submissions past
/// L-in-flight queue up to Q deep and the rest are rejected — and every
/// admitted query is still served.
#[test]
fn admission_queues_then_rejects_past_capacity() {
    let mut serve = serve_engine(
        2,
        ServeConfig {
            queue_cap: 2,
            default_eps: 1e-7,
            ..Default::default()
        },
        43,
    );
    let mut admitted_qids = Vec::new();
    let mut rejections = 0usize;
    // 2 straight into lanes, 2 queued, the rest must bounce
    for i in 0..6 {
        match serve.submit(Query::ppr(&[i * 7 + 1], DAMPING, 1e-7)) {
            Some(qid) => admitted_qids.push(qid),
            None => rejections += 1,
        }
    }
    assert_eq!(admitted_qids.len(), 4, "2 lanes + 2 queue slots");
    assert_eq!(rejections, 2);
    assert_eq!(serve.queued(), 2);
    let done = serve.drain(Duration::from_secs(120)).unwrap();
    assert_eq!(done.len(), 4, "every accepted query completes");
    assert!(done.iter().all(|d| d.state == QueryState::Served));
    let (admitted, served, rejected) = serve.counts();
    assert_eq!(admitted, 4);
    assert_eq!(served, 4);
    assert_eq!(rejected, 2);
    serve.finish().unwrap();
}

/// Deadline policy: a tenant that cannot reach its ε is evicted when its
/// deadline lapses, the lane frees up, and the next query serves
/// normally out of the same lane.
#[test]
fn deadline_evicts_and_frees_the_lane() {
    let mut serve = serve_engine(
        1,
        ServeConfig {
            queue_cap: 4,
            default_eps: 1e-7,
            default_deadline: None,
            ..Default::default()
        },
        47,
    );
    // ε below anything the diffusion can reach quickly, with a deadline
    // shorter than any possible convergence (poll checks the deadline
    // before ε-stability, and serving needs stable_polls successive
    // sub-ε reads): this tenant can only leave by eviction
    let mut hopeless = Query::ppr(&[5], DAMPING, 1e-300);
    hopeless.deadline = Some(Duration::from_millis(1));
    let hopeless_qid = serve.submit(hopeless).unwrap();
    let viable_qid = serve.submit(Query::ppr(&[9], DAMPING, 1e-7)).unwrap();
    let done = serve.drain(Duration::from_secs(120)).unwrap();
    assert_eq!(done.len(), 2);
    let evicted = done.iter().find(|d| d.qid == hopeless_qid).unwrap();
    assert_eq!(evicted.state, QueryState::Evicted);
    assert!(evicted.x.is_none(), "evicted tenants get no readout");
    assert!(evicted.time_to_eps_secs.is_none());
    let served = done.iter().find(|d| d.qid == viable_qid).unwrap();
    assert_eq!(served.state, QueryState::Served, "freed lane serves the next query");
    assert!((norm1(served.x.as_ref().unwrap()) - 1.0).abs() < 1e-5);
    let (admitted, served_n, rejected) = serve.counts();
    assert_eq!((admitted, served_n, rejected), (2, 1, 0));
    let summary = serve.finish().unwrap();
    assert_eq!(summary.final_solution.metrics["queries_served"], 1);
    assert_eq!(summary.final_solution.metrics["queries_admitted"], 2);
}
