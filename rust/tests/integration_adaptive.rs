//! Integration: live adaptive repartitioning — §4.3 ownership handoff
//! across the coordinator, transport, and streaming layers.
//!
//! The load-bearing property throughout is **fluid conservation through
//! handoffs**: the solve must land on the exact fixed point no matter how
//! many `(H, B, F)` slices migrated between PIDs mid-flight. For patched
//! PageRank that is directly observable as `‖x‖₁ = 1` (any lost or
//! duplicated mass ε shifts the total by ε/(1−d)) plus agreement with a
//! cold sequential solve.

use std::time::Duration;

use diter::coordinator::{
    v2, AdaptiveConfig, AdaptivePolicy, DistributedConfig, StreamingEngine,
};
use diter::graph::{
    pagerank_system, power_law_web_graph, ChurnModel, MutableDigraph, MutationStream,
};
use diter::linalg::vec_ops::{dist1, norm1};
use diter::partition::Partition;
use diter::solver::{DIteration, FixedPointProblem, SequenceKind, SolveOptions, Solver};

fn cold_solution(problem: &FixedPointProblem) -> Vec<f64> {
    let opts = SolveOptions {
        tol: 1e-13,
        max_cost: 200_000.0,
        trace_every: 0.0,
        exact: None,
    };
    DIteration::fluid_cyclic().solve(problem, &opts).unwrap().x
}

fn pagerank_problem(n: usize, seed: u64) -> FixedPointProblem {
    let g = power_law_web_graph(n, 6, 0.1, seed);
    let sys = pagerank_system(&g, 0.85, true).unwrap();
    FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap()
}

fn aggressive_adaptive(interval_ms: u64) -> AdaptiveConfig {
    AdaptiveConfig {
        policy: AdaptivePolicy::default(),
        interval: Duration::from_millis(interval_ms),
        ..Default::default()
    }
}

#[test]
fn mid_flight_handoff_conserves_fluid() {
    // a heavily throttled PID plus a tight rebalance window forces
    // ownership handoffs while fluid is in flight; conservation means the
    // run still converges to the exact fixed point with unit mass
    let n = 400;
    let problem = pagerank_problem(n, 23);
    let cfg = DistributedConfig::new(Partition::contiguous(n, 4).unwrap())
        .with_tol(1e-10)
        .with_seed(23)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_straggler(1, 10_000.0)
        .with_adaptive(aggressive_adaptive(8));
    let sol = v2::solve_v2(&problem, &cfg).unwrap();
    assert!(sol.converged, "residual {:.3e}", sol.residual);
    assert!(
        sol.metrics["handoffs_total"] >= 1,
        "the straggler must have shed ownership at least once: {:?}",
        sol.metrics
    );
    assert!(
        (norm1(&sol.x) - 1.0).abs() < 1e-7,
        "PageRank mass must survive every handoff: ‖x‖₁ = {}",
        norm1(&sol.x)
    );
    let want = cold_solution(&problem);
    assert!(
        dist1(&sol.x, &want) < 1e-7,
        "adaptive vs cold Δ₁ = {:.3e}",
        dist1(&sol.x, &want)
    );
}

#[test]
fn handoffs_survive_latency_and_rerouting() {
    // with injected transport latency, fluid addressed to the *old* owner
    // keeps arriving after a handoff — the receiver must re-route it via
    // the ownership table without losing a unit
    let n = 300;
    let problem = pagerank_problem(n, 31);
    let mut cfg = DistributedConfig::new(Partition::contiguous(n, 4).unwrap())
        .with_tol(1e-10)
        .with_seed(31)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_straggler(0, 10_000.0)
        .with_adaptive(aggressive_adaptive(8));
    cfg.latency = Some((Duration::from_micros(50), Duration::from_micros(400)));
    let sol = v2::solve_v2(&problem, &cfg).unwrap();
    assert!(sol.converged, "residual {:.3e}", sol.residual);
    assert!(sol.metrics["handoffs_total"] >= 1, "{:?}", sol.metrics);
    assert!((norm1(&sol.x) - 1.0).abs() < 1e-7, "‖x‖₁ = {}", norm1(&sol.x));
    let want = cold_solution(&problem);
    assert!(dist1(&sol.x, &want) < 1e-7);
}

#[test]
fn adaptive_beats_static_on_a_straggler() {
    // the acceptance benchmark in test form: one PID throttled hard;
    // adaptive repartitioning must cut time-to-converge vs the static
    // partition (wide margin — the static run is sleep-dominated)
    let n = 800;
    let problem = pagerank_problem(n, 7);
    let base = DistributedConfig::new(Partition::contiguous(n, 4).unwrap())
        .with_tol(1e-9)
        .with_seed(7)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_straggler(0, 8_000.0);
    let mut slow = base.clone();
    slow.max_wall = Duration::from_secs(60);
    let static_sol = v2::solve_v2(&problem, &slow).unwrap();
    assert!(static_sol.converged, "static residual {:.3e}", static_sol.residual);

    let adaptive_cfg = slow.clone().with_adaptive(aggressive_adaptive(15));
    let adaptive_sol = v2::solve_v2(&problem, &adaptive_cfg).unwrap();
    assert!(
        adaptive_sol.converged,
        "adaptive residual {:.3e}",
        adaptive_sol.residual
    );
    // the deterministic signal: ownership actually moved off the
    // straggler (at least one half-split of its 200-coordinate share)
    assert!(adaptive_sol.metrics["handoffs_total"] >= 1);
    assert!(
        adaptive_sol.metrics["handoff_coords"] >= 50,
        "a real share of the straggler's Ω must have moved: {:?}",
        adaptive_sol.metrics
    );
    // the timing claim: the static run is sleep-dominated (the throttled
    // PID must grind through its full share at 8k upd/s), so adaptive
    // should win with a wide gap — asserted here without a margin factor
    // to stay robust on loaded CI runners; the quantified speedup claim
    // lives in benches/adaptive_straggler.rs
    assert!(
        adaptive_sol.wall_secs < static_sol.wall_secs,
        "adaptive {:.3}s must beat static {:.3}s",
        adaptive_sol.wall_secs,
        static_sol.wall_secs
    );
    // both land on the same fixed point
    assert!(dist1(&adaptive_sol.x, &static_sol.x) < 1e-6);
}

#[test]
fn streaming_engine_rebalances_across_epochs() {
    // the full stack: the streaming engine runs with a straggler and live
    // repartitioning, then a mutation batch forces an epoch rebase AFTER
    // ownership has moved — the freeze/quiesce protocol must hand the
    // complete history to the rebase, and the run must land on the cold
    // fixed point of the mutated graph
    let n = 300;
    let g = power_law_web_graph(n, 6, 0.1, 41);
    let mg = MutableDigraph::from_digraph(&g, n);
    let mut cfg = DistributedConfig::new(Partition::contiguous(n, 3).unwrap())
        .with_tol(1e-9)
        .with_seed(41)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_straggler(2, 10_000.0)
        .with_adaptive(aggressive_adaptive(8));
    cfg.max_wall = Duration::from_secs(60);
    let mut eng = StreamingEngine::new(mg, 0.85, true, cfg).unwrap();
    let init = eng.converge().unwrap();
    assert!(init.solution.converged, "residual {:.3e}", init.solution.residual);
    assert!(
        eng.handoffs_total() >= 1,
        "initial epoch must have rebalanced off the straggler"
    );
    let moved_ownership = eng.ownership();
    assert!(
        moved_ownership.part(2).len() < n / 3,
        "straggler PID 2 must hold less than its contiguous share, has {}",
        moved_ownership.part(2).len()
    );

    // epoch rebase across the rebalanced ownership
    let mut stream = MutationStream::new(ChurnModel::RandomRewire, 99);
    let batch = stream.next_batch(eng.graph(), 24);
    let report = eng.apply_batch(&batch).unwrap();
    assert!(report.solution.converged, "residual {:.3e}", report.solution.residual);
    assert!(
        (norm1(&report.solution.x) - 1.0).abs() < 1e-6,
        "mass through rebase + handoffs: ‖x‖₁ = {}",
        norm1(&report.solution.x)
    );
    let want = cold_solution(eng.problem());
    assert!(
        dist1(&report.solution.x, &want) < 1e-6,
        "streamed vs cold Δ₁ = {:.3e}",
        dist1(&report.solution.x, &want)
    );
    eng.finish().unwrap();
}
