//! End-to-end coverage for the partition-local hot path: the local-block,
//! blocked, and global-walk kernels must land on the same fixed point,
//! the SoA fluid parcels must conserve every unit of fluid under latency,
//! coalescing, live handoffs and streaming epochs, and the blocked
//! kernel's steady-state quantum must perform zero heap allocations —
//! asserted with a counting global allocator, not claimed.

use std::sync::Arc;
use std::time::Duration;

use diter::coordinator::monitor::MonitorState;
use diter::coordinator::worker::WorkerCore;
use diter::coordinator::{
    v2, AdaptiveConfig, DistributedConfig, KernelKind, Query, QuerySet, QueryState,
    StreamingEngine, WorkerMsg,
};
use diter::graph::{
    pagerank_system, power_law_web_graph, ChurnModel, MutableDigraph, MutationStream,
};
use diter::linalg::vec_ops::{dist1, dist_inf, norm1};
use diter::partition::{OwnershipTable, Partition};
use diter::perf::CountingAlloc;
use diter::solver::{DIteration, FixedPointProblem, SequenceKind, SolveOptions, Solver};
use diter::transport::{bus, BusConfig};

// Counts every heap allocation this test binary makes; the steady-state
// test below asserts a zero per-thread delta across diffusion steps.
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

fn pagerank_problem(n: usize, seed: u64) -> FixedPointProblem {
    let g = power_law_web_graph(n, 5, 0.1, seed);
    let sys = pagerank_system(&g, 0.85, true).unwrap();
    FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap()
}

#[test]
fn all_kernels_reach_the_same_fixed_point() {
    let p = pagerank_problem(300, 11);
    for seq in [SequenceKind::Cyclic, SequenceKind::GreedyMaxFluid] {
        let cfg = |kernel| {
            DistributedConfig::new(Partition::contiguous(300, 4).unwrap())
                .with_tol(1e-10)
                .with_sequence(seq)
                .with_kernel(kernel)
        };
        let reference = v2::solve_v2(&p, &cfg(KernelKind::LocalBlock)).unwrap();
        assert!(reference.converged, "local kernel residual {}", reference.residual);
        for kernel in [KernelKind::Blocked, KernelKind::GlobalWalk] {
            let sol = v2::solve_v2(&p, &cfg(kernel)).unwrap();
            assert!(sol.converged, "{} kernel residual {}", kernel.name(), sol.residual);
            assert!(
                dist_inf(&sol.x, &reference.x) < 1e-7,
                "{} kernel disagrees with local by {:.3e}",
                kernel.name(),
                dist_inf(&sol.x, &reference.x)
            );
        }
    }
}

#[test]
fn soa_parcels_conserve_fluid_under_latency_and_coalescing() {
    // coarse coalescing + injected latency keeps many SoA parcels in
    // flight; exact conservation means the PageRank mass still sums to 1
    let p = pagerank_problem(150, 13);
    let mut cfg = DistributedConfig::new(Partition::contiguous(150, 4).unwrap())
        .with_tol(1e-10)
        .with_sequence(SequenceKind::GreedyMaxFluid);
    cfg.latency = Some((Duration::from_micros(50), Duration::from_micros(400)));
    cfg.coalesce = diter::transport::CoalescePolicy {
        min_mass: 1e-4,
        max_entries: 32,
    };
    let sol = v2::solve_v2(&p, &cfg).unwrap();
    assert!(sol.converged, "residual {}", sol.residual);
    assert!(
        (norm1(&sol.x) - 1.0).abs() < 1e-7,
        "mass {} — SoA parcels lost fluid",
        norm1(&sol.x)
    );
    assert!(sol.metrics["msgs_sent"] > 0);
}

#[test]
fn soa_parcels_conserve_fluid_through_live_handoffs() {
    // straggler + aggressive rebalancing: fluid rides SoA parcels AND
    // handoff slices concurrently; the fixed point must still be exact
    // for every kernel that patches a LocalSystem across handoffs
    let p = pagerank_problem(200, 19);
    for kernel in [KernelKind::LocalBlock, KernelKind::Blocked] {
        let cfg = DistributedConfig::new(Partition::contiguous(200, 4).unwrap())
            .with_tol(1e-10)
            .with_sequence(SequenceKind::GreedyMaxFluid)
            .with_kernel(kernel)
            .with_straggler(0, 30_000.0)
            .with_adaptive(AdaptiveConfig {
                interval: Duration::from_millis(10),
                ..Default::default()
            });
        let sol = v2::solve_v2(&p, &cfg).unwrap();
        assert!(sol.converged, "[{}] residual {}", kernel.name(), sol.residual);
        assert!(
            (norm1(&sol.x) - 1.0).abs() < 1e-7,
            "[{}] mass {} — fluid must be conserved through handoffs",
            kernel.name(),
            norm1(&sol.x)
        );
    }
}

#[test]
fn streaming_epochs_patch_the_local_system_correctly() {
    // churn through several epochs (dirty-column LocalSystem patching on
    // every rebase) and check each reconverged state against a cold
    // solve, under both kernels that keep a patched LocalSystem
    let n = 120;
    for kernel in [KernelKind::LocalBlock, KernelKind::Blocked] {
        let g = power_law_web_graph(n, 5, 0.1, 23);
        let mg = MutableDigraph::from_digraph(&g, n);
        let cfg = DistributedConfig::new(Partition::contiguous(n, 3).unwrap())
            .with_tol(1e-10)
            .with_sequence(SequenceKind::GreedyMaxFluid)
            .with_kernel(kernel)
            .with_seed(23);
        let mut eng = StreamingEngine::new(mg, 0.85, true, cfg).unwrap();
        eng.converge().unwrap();
        let mut stream = MutationStream::new(ChurnModel::RandomRewire, 5);
        for _ in 0..3 {
            let batch = stream.next_batch(eng.graph(), 10);
            let report = eng.apply_batch(&batch).unwrap();
            assert!(
                report.solution.converged,
                "[{}] epoch {} residual {}",
                kernel.name(),
                report.epoch,
                report.solution.residual
            );
            let opts = SolveOptions {
                tol: 1e-13,
                max_cost: 200_000.0,
                trace_every: 0.0,
                exact: None,
            };
            let want = DIteration::fluid_cyclic()
                .solve(eng.problem(), &opts)
                .unwrap()
                .x;
            assert!(
                dist1(&report.solution.x, &want) < 1e-7,
                "[{}] epoch {}: Δ₁ = {}",
                kernel.name(),
                report.epoch,
                dist1(&report.solution.x, &want)
            );
        }
        eng.finish().unwrap();
    }
}

#[test]
fn blocked_kernel_steady_state_is_allocation_free() {
    // The zero-allocation claim, asserted: drive a single WorkerCore
    // (K = 1, in-process bus, greedy order, blocked kernel) through one
    // full cold descent to warm every scratch high-water mark — the
    // blocked batch + journal, the greedy queue's exponent buckets, the
    // transport's empty-drain path — then replay an identical descent
    // and require that it allocates NOTHING. The replay is exact because
    // the f-trajectory depends only on F (H merely accumulates),
    // `enter_epoch` reinstalls F₀ = B, and the heap resets in place.
    let n = 256;
    let problem = Arc::new(pagerank_problem(n, 31));
    let part = Partition::contiguous(n, 1).unwrap();
    let cfg = DistributedConfig::new(part.clone())
        .with_tol(1e-9)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_kernel(KernelKind::Blocked);
    let (mut eps, _metrics) = bus::<WorkerMsg>(1, &BusConfig::default());
    let table = OwnershipTable::new(part);
    let state = MonitorState::new(1);
    let mut core = WorkerCore::new(
        0,
        Box::new(eps.pop().unwrap()),
        problem.clone(),
        table,
        state,
        cfg,
    );

    let mut drained = false;
    for _ in 0..100_000 {
        if core.step().1 == 0.0 {
            drained = true;
            break;
        }
    }
    assert!(drained, "cold descent did not drain");

    let f0: Vec<f64> = core.owned().iter().map(|&i| problem.b()[i]).collect();
    core.enter_epoch(1, problem.clone(), f0, None);

    let a0 = CountingAlloc::thread_allocations();
    let mut worked = false;
    drained = false;
    for _ in 0..100_000 {
        let (_, r) = core.step();
        worked |= r > 0.0;
        if r == 0.0 {
            drained = true;
            break;
        }
    }
    let allocs = CountingAlloc::thread_allocations() - a0;
    assert!(worked, "the replayed epoch must diffuse real fluid");
    assert!(drained, "the replayed epoch did not drain");
    assert_eq!(
        allocs, 0,
        "steady-state blocked-kernel steps allocated {allocs} times; \
         the hot loop must not touch the allocator"
    );
}

#[test]
fn two_query_serve_steady_state_is_allocation_free() {
    // The zero-allocation claim extended to serving (DESIGN.md §10): the
    // same warm-then-replay structure, but with two PPR tenants riding
    // query lanes 1 and 2 on top of the base descent. Round 1 warms every
    // multi-lane high-water mark — the lane-blocked coalesce columns, the
    // seed-claim scratch, the per-lane publish scratch, the ε-endgame
    // flush. Round 2 admits two FRESH queries into the same lanes and
    // requires that the diffusion steps allocate nothing: serving more
    // tenants must cost lane-strided arithmetic, not allocator traffic.
    let n = 256;
    let lanes = 3; // base + 2 query lanes
    let problem = Arc::new(pagerank_problem(n, 37));
    let part = Partition::contiguous(n, 1).unwrap();
    let qs = Arc::new(QuerySet::new(lanes, 1));
    let mut cfg = DistributedConfig::new(part.clone())
        .with_tol(1e-9)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_kernel(KernelKind::Blocked);
    cfg.lanes = lanes;
    cfg.queries = Some(qs.clone());
    let (mut eps, _metrics) = bus::<WorkerMsg>(1, &BusConfig::default());
    let table = OwnershipTable::new(part);
    let state = MonitorState::new(1);
    let mut core = WorkerCore::new(
        0,
        Box::new(eps.pop().unwrap()),
        problem.clone(),
        table,
        state,
        cfg,
    );

    let q1 = qs.next_qid();
    let q2 = qs.next_qid();
    let l1 = qs.admit(Query::ppr(&[3, 9], 0.85, 1e-9), q1).unwrap();
    let l2 = qs.admit(Query::ppr(&[100], 0.85, 1e-9), q2).unwrap();
    let mut drained = false;
    for _ in 0..300_000 {
        if core.step().1 == 0.0 {
            drained = true;
            break;
        }
    }
    assert!(drained, "warm-up serve descent did not drain");
    qs.evict(l1, QueryState::Served, None);
    qs.evict(l2, QueryState::Served, None);
    let _ = qs.take_completed();

    // fresh epoch: base fluid reinstalled on lane 0, query lanes empty
    // until the new tenants' seeds are claimed
    let mut f0 = vec![0.0; core.owned().len() * lanes];
    for (t, &i) in core.owned().iter().enumerate() {
        f0[t * lanes] = problem.b()[i];
    }
    core.enter_epoch(1, problem.clone(), f0, None);
    let q3 = qs.next_qid();
    let q4 = qs.next_qid();
    qs.admit(Query::ppr(&[7, 41], 0.85, 1e-9), q3).unwrap();
    qs.admit(Query::ppr(&[200], 0.85, 1e-9), q4).unwrap();
    // admission is control plane: let the lane resync + seed claim land
    // before the measured window opens — then every remaining step is
    // pure multi-lane diffusion and must not touch the allocator
    for _ in 0..50 {
        core.step();
    }

    let a0 = CountingAlloc::thread_allocations();
    let mut worked = false;
    drained = false;
    for _ in 0..300_000 {
        let (_, r) = core.step();
        worked |= r > 0.0;
        if r == 0.0 {
            drained = true;
            break;
        }
    }
    let allocs = CountingAlloc::thread_allocations() - a0;
    assert!(worked, "the serve replay must diffuse real fluid");
    assert!(drained, "the serve replay did not drain");
    assert_eq!(
        allocs, 0,
        "steady-state 2-query serve steps allocated {allocs} times; \
         extra lanes must not reintroduce allocator traffic"
    );
}
