//! End-to-end coverage for the partition-local hot path: the local-block
//! and global-walk kernels must land on the same fixed point, and the SoA
//! fluid parcels must conserve every unit of fluid under latency,
//! coalescing, live handoffs and streaming epochs.

use std::time::Duration;

use diter::coordinator::{v2, AdaptiveConfig, DistributedConfig, KernelKind, StreamingEngine};
use diter::graph::{
    pagerank_system, power_law_web_graph, ChurnModel, MutableDigraph, MutationStream,
};
use diter::linalg::vec_ops::{dist1, dist_inf, norm1};
use diter::partition::Partition;
use diter::solver::{DIteration, FixedPointProblem, SequenceKind, SolveOptions, Solver};

fn pagerank_problem(n: usize, seed: u64) -> FixedPointProblem {
    let g = power_law_web_graph(n, 5, 0.1, seed);
    let sys = pagerank_system(&g, 0.85, true).unwrap();
    FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap()
}

#[test]
fn both_kernels_reach_the_same_fixed_point() {
    let p = pagerank_problem(300, 11);
    for seq in [SequenceKind::Cyclic, SequenceKind::GreedyMaxFluid] {
        let cfg = |kernel| {
            DistributedConfig::new(Partition::contiguous(300, 4).unwrap())
                .with_tol(1e-10)
                .with_sequence(seq)
                .with_kernel(kernel)
        };
        let local = v2::solve_v2(&p, &cfg(KernelKind::LocalBlock)).unwrap();
        let global = v2::solve_v2(&p, &cfg(KernelKind::GlobalWalk)).unwrap();
        assert!(local.converged, "local kernel residual {}", local.residual);
        assert!(global.converged, "global kernel residual {}", global.residual);
        assert!(
            dist_inf(&local.x, &global.x) < 1e-7,
            "kernels disagree by {:.3e}",
            dist_inf(&local.x, &global.x)
        );
    }
}

#[test]
fn soa_parcels_conserve_fluid_under_latency_and_coalescing() {
    // coarse coalescing + injected latency keeps many SoA parcels in
    // flight; exact conservation means the PageRank mass still sums to 1
    let p = pagerank_problem(150, 13);
    let mut cfg = DistributedConfig::new(Partition::contiguous(150, 4).unwrap())
        .with_tol(1e-10)
        .with_sequence(SequenceKind::GreedyMaxFluid);
    cfg.latency = Some((Duration::from_micros(50), Duration::from_micros(400)));
    cfg.coalesce = diter::transport::CoalescePolicy {
        min_mass: 1e-4,
        max_entries: 32,
    };
    let sol = v2::solve_v2(&p, &cfg).unwrap();
    assert!(sol.converged, "residual {}", sol.residual);
    assert!(
        (norm1(&sol.x) - 1.0).abs() < 1e-7,
        "mass {} — SoA parcels lost fluid",
        norm1(&sol.x)
    );
    assert!(sol.metrics["msgs_sent"] > 0);
}

#[test]
fn soa_parcels_conserve_fluid_through_live_handoffs() {
    // straggler + aggressive rebalancing: fluid rides SoA parcels AND
    // handoff slices concurrently; the fixed point must still be exact
    let p = pagerank_problem(200, 19);
    let cfg = DistributedConfig::new(Partition::contiguous(200, 4).unwrap())
        .with_tol(1e-10)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_straggler(0, 30_000.0)
        .with_adaptive(AdaptiveConfig {
            interval: Duration::from_millis(10),
            ..Default::default()
        });
    let sol = v2::solve_v2(&p, &cfg).unwrap();
    assert!(sol.converged, "residual {}", sol.residual);
    assert!(
        (norm1(&sol.x) - 1.0).abs() < 1e-7,
        "mass {} — fluid must be conserved through handoffs",
        norm1(&sol.x)
    );
}

#[test]
fn streaming_epochs_patch_the_local_system_correctly() {
    // churn through several epochs (dirty-column LocalSystem patching on
    // every rebase) and check each reconverged state against a cold solve
    let n = 120;
    let g = power_law_web_graph(n, 5, 0.1, 23);
    let mg = MutableDigraph::from_digraph(&g, n);
    let cfg = DistributedConfig::new(Partition::contiguous(n, 3).unwrap())
        .with_tol(1e-10)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_seed(23);
    let mut eng = StreamingEngine::new(mg, 0.85, true, cfg).unwrap();
    eng.converge().unwrap();
    let mut stream = MutationStream::new(ChurnModel::RandomRewire, 5);
    for _ in 0..3 {
        let batch = stream.next_batch(eng.graph(), 10);
        let report = eng.apply_batch(&batch).unwrap();
        assert!(
            report.solution.converged,
            "epoch {} residual {}",
            report.epoch,
            report.solution.residual
        );
        let opts = SolveOptions {
            tol: 1e-13,
            max_cost: 200_000.0,
            trace_every: 0.0,
            exact: None,
        };
        let want = DIteration::fluid_cyclic()
            .solve(eng.problem(), &opts)
            .unwrap()
            .x;
        assert!(
            dist1(&report.solution.x, &want) < 1e-7,
            "epoch {}: Δ₁ = {}",
            report.epoch,
            dist1(&report.solution.x, &want)
        );
    }
    eng.finish().unwrap();
}
