//! Integration: the elastic worker pool — live spawn/retire of PIDs
//! across the coordinator, transport, and partition layers.
//!
//! The load-bearing property, as for the fixed-pool rebalancer, is
//! **fluid conservation**: a worker spawned (or retired) mid-convergence
//! must not create, lose, or strand a single unit of fluid. For patched
//! PageRank that is directly observable as `‖x‖₁ = 1` plus agreement
//! with a cold sequential solve; for the custom-B retire scenario the
//! fixed point itself is the witness.
//!
//! The cold-solve reference and fixed-point assertions live in
//! `tests/common` — the shared harness the scenario matrix and the
//! conservation fuzz grew out of this file's machinery.

mod common;

use std::sync::Arc;
use std::time::Duration;

use common::cold_solution;
use diter::coordinator::{
    v2, DistributedConfig, ElasticConfig, StreamingEngine, WorkerPool,
};
use diter::graph::{
    block_coupled_matrix, pagerank_system, power_law_web_graph, ChurnModel, MutableDigraph,
    MutationStream,
};
use diter::linalg::vec_ops::{dist1, norm1};
use diter::partition::Partition;
use diter::solver::{FixedPointProblem, SequenceKind};
use diter::sparse::SparseMatrix;

fn pagerank_problem(n: usize, seed: u64) -> FixedPointProblem {
    let g = power_law_web_graph(n, 6, 0.1, seed);
    let sys = pagerank_system(&g, 0.85, true).unwrap();
    FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap()
}

fn elastic(max_workers: usize, interval_ms: u64, retire_idle_ms: u64) -> ElasticConfig {
    ElasticConfig {
        max_workers,
        spawn_threshold: 0.5,
        retire_idle: Duration::from_millis(retire_idle_ms),
        interval: Duration::from_millis(interval_ms),
        ..Default::default()
    }
}

#[test]
fn mid_flight_spawn_conserves_fluid_under_latency_and_coalescing() {
    // a heavily throttled PID forces the pool to spawn a worker while
    // fluid is in flight AND delayed AND coalesced; the spawn handoff
    // (adopt-from-empty) must conserve everything — the run lands on the
    // exact fixed point with unit mass
    let n = 400;
    let problem = pagerank_problem(n, 29);
    let mut cfg = DistributedConfig::new(Partition::contiguous(n, 2).unwrap())
        .with_tol(1e-10)
        .with_seed(29)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_straggler(0, 10_000.0)
        .with_elastic(elastic(4, 8, 10_000));
    cfg.latency = Some((Duration::from_micros(50), Duration::from_micros(400)));
    cfg.coalesce = diter::transport::CoalescePolicy {
        min_mass: 1e-4,
        max_entries: 64,
    };
    let sol = v2::solve_v2(&problem, &cfg).unwrap();
    assert!(sol.converged, "residual {:.3e}", sol.residual);
    assert!(
        sol.metrics["pool_spawned"] >= 1,
        "the straggler must have triggered a spawn: {:?}",
        sol.metrics
    );
    assert!(
        sol.metrics["handoffs_total"] >= 1,
        "spawning moves ownership over the handoff machinery"
    );
    assert!(
        (norm1(&sol.x) - 1.0).abs() < 1e-7,
        "PageRank mass must survive the spawn: ‖x‖₁ = {}",
        norm1(&sol.x)
    );
    let want = cold_solution(&problem);
    assert!(
        dist1(&sol.x, &want) < 1e-7,
        "elastic vs cold Δ₁ = {:.3e}",
        dist1(&sol.x, &want)
    );
}

#[test]
fn mid_flight_retire_conserves_fluid() {
    // a block-diagonal system where block 2 has B = 0: PID 2 drains
    // immediately and stays idle while the throttled PID 0 grinds — the
    // pool must retire it mid-convergence (ownership drained to a peer,
    // endpoint deregistered, thread joined) without disturbing the
    // still-running diffusion on PIDs 0/1
    let n = 120;
    let k = 3;
    let p = block_coupled_matrix(n, k, 0.5, 0.0, 6, 17);
    let b: Vec<f64> = (0..n).map(|i| if i < 2 * n / 3 { 1.0 } else { 0.0 }).collect();
    let problem = FixedPointProblem::new(SparseMatrix::from_csr(p), b).unwrap();
    // the throttled solve must outlast the retire-idle window by a wide
    // margin so the retirement reliably happens mid-convergence
    let mut cfg = DistributedConfig::new(Partition::contiguous(n, k).unwrap())
        .with_tol(1e-12)
        .with_seed(17)
        .with_straggler(0, 4_000.0)
        .with_elastic(elastic(4, 8, 30));
    cfg.latency = Some((Duration::from_micros(20), Duration::from_micros(150)));
    let sol = v2::solve_v2(&problem, &cfg).unwrap();
    assert!(sol.converged, "residual {:.3e}", sol.residual);
    assert!(
        sol.metrics["pool_retired"] >= 1,
        "the starved PID must have been retired mid-run: {:?}",
        sol.metrics
    );
    let want = cold_solution(&problem);
    assert!(
        dist1(&sol.x, &want) < 1e-8,
        "retire vs cold Δ₁ = {:.3e}",
        dist1(&sol.x, &want)
    );
}

#[test]
fn elastic_beats_fixed_k_under_hotspot_stream() {
    // the acceptance scenario: K = 2 with one throttled PID under a
    // hotspot mutation stream. Fixed-K leaves half the coordinate space
    // on the straggler forever; the elastic pool spawns extra workers to
    // absorb its load, so time-to-converge must drop.
    let n = 450;
    let build = || {
        let g = power_law_web_graph(n, 6, 0.1, 37);
        MutableDigraph::from_digraph(&g, n)
    };
    // the fixed run is sleep-dominated: the throttled PID must grind its
    // full 225-coordinate share at 5k upd/s, so the elastic win is a
    // mandatory-sleep gap (several-fold), not a scheduler-noise margin —
    // the same robustness argument as adaptive_beats_static; the
    // quantified speedup claim lives in benches/elastic_pool.rs
    let base = {
        let mut c = DistributedConfig::new(Partition::contiguous(n, 2).unwrap())
            .with_tol(1e-9)
            .with_seed(37)
            .with_sequence(SequenceKind::GreedyMaxFluid)
            .with_straggler(0, 5_000.0);
        c.max_wall = Duration::from_secs(120);
        c
    };
    let mut fixed = StreamingEngine::new(build(), 0.85, true, base.clone()).unwrap();
    let fixed_init = fixed.converge().unwrap();
    assert!(fixed_init.solution.converged);

    let elastic_cfg = base.clone().with_elastic(elastic(4, 10, 10_000));
    let mut eng = StreamingEngine::new(build(), 0.85, true, elastic_cfg).unwrap();
    let elastic_init = eng.converge().unwrap();
    assert!(elastic_init.solution.converged);
    let stats = eng.pool_stats();
    assert!(
        stats.spawned >= 1,
        "the straggler must have triggered a spawn: {stats:?}"
    );
    assert!(
        elastic_init.solution.wall_secs < fixed_init.solution.wall_secs,
        "elastic {:.3}s must beat fixed-K {:.3}s",
        elastic_init.solution.wall_secs,
        fixed_init.solution.wall_secs
    );

    // hotspot churn on the elastic engine: every epoch must reconverge to
    // the mutated graph's cold fixed point across the grown pool
    let mut stream = MutationStream::new(ChurnModel::HotSpotBurst { burst: 24 }, 99);
    for _ in 0..2 {
        let batch = stream.next_batch(eng.graph(), 24);
        let report = eng.apply_batch(&batch).unwrap();
        assert!(report.solution.converged, "residual {:.3e}", report.solution.residual);
        assert!(
            (norm1(&report.solution.x) - 1.0).abs() < 1e-6,
            "mass through rebase over the elastic pool: ‖x‖₁ = {}",
            norm1(&report.solution.x)
        );
    }
    let want = cold_solution(eng.problem());
    let got = eng.solution().unwrap();
    assert!(
        dist1(&got, &want) < 1e-6,
        "streamed-elastic vs cold Δ₁ = {:.3e}",
        dist1(&got, &want)
    );
    fixed.finish().unwrap();
    eng.finish().unwrap();
}

#[test]
fn retire_then_respawn_roundtrip_reaches_cold_fixed_point() {
    // drive the pool mechanics directly: spawn a third worker, retire it
    // again (its slot goes vacant), respawn into the same slot, then let
    // the diffusion drain — the assembled solution must be the same
    // fixed point a cold solve reaches, with unit mass
    let n = 240;
    let problem = pagerank_problem(n, 53);
    let problem = Arc::new(problem);
    let cfg = DistributedConfig::new(Partition::contiguous(n, 2).unwrap())
        .with_tol(1e-10)
        .with_seed(53)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_elastic(elastic(4, 10, 10_000));
    let mut pool = WorkerPool::new(problem.clone(), cfg).unwrap();
    let pid = pool.spawn_split(0).unwrap();
    assert!(pool.settle(Duration::from_secs(5)), "spawn settles");
    assert!(pool.retire(pid, 1));
    assert!(pool.settle(Duration::from_secs(5)), "retire settles");
    let pid2 = pool.spawn_split(1).unwrap();
    assert_eq!(pid, pid2, "respawn reuses the vacant slot");
    assert!(pool.settle(Duration::from_secs(5)));
    assert_eq!(pool.stats().spawned, 2);
    assert_eq!(pool.stats().retired, 1);
    // wait for the diffusion to drain through the reshaped pool
    let state = pool.state().clone();
    let mon = pool.monitor();
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    loop {
        let total = state.published_total() + mon.inflight_or_zero();
        if (total < 1e-10 && mon.undelivered() == 0) || std::time::Instant::now() >= deadline {
            break;
        }
        std::thread::sleep(Duration::from_micros(300));
    }
    state.request_stop();
    let mut x = vec![0.0; n];
    for (owned, values) in pool.finish().unwrap() {
        for (t, &i) in owned.iter().enumerate() {
            x[i] = values[t];
        }
    }
    assert!(
        (norm1(&x) - 1.0).abs() < 1e-7,
        "mass through retire + respawn: ‖x‖₁ = {}",
        norm1(&x)
    );
    let want = cold_solution(&problem);
    assert!(
        dist1(&x, &want) < 1e-7,
        "round-trip vs cold Δ₁ = {:.3e}",
        dist1(&x, &want)
    );
}
