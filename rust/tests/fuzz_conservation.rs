//! Conservation fuzz: seeded random interleavings of
//! {epoch rebase, ownership handoff, worker spawn, worker retire} fired
//! while fluid is genuinely mid-flight, under latency injection and
//! parcel coalescing — the union of everything that has ever moved state
//! between PIDs, shuffled.
//!
//! Each step first *stirs*: applies a mutation batch with a deliberately
//! tiny convergence deadline, so the epoch transition completes but its
//! fluid is still flying when the next event lands. Then one random
//! lifecycle/epoch event fires against that mid-flight diffusion, the
//! engine settles, and **total fluid is asserted invariant**: unit
//! PageRank mass and the mutated graph's cold fixed point, after every
//! single event. Events are driven directly through the pool (the
//! scheduler's policy is configured inert), so the interleaving is a
//! pure function of the seed and failures replay exactly.

mod common;

use std::time::Duration;

use diter::coordinator::{
    DistributedConfig, ElasticConfig, Query, QueryState, RebaseMode, ServeConfig, ServeEngine,
    StreamingEngine, TransportKind,
};
use diter::graph::{power_law_web_graph, ChurnModel, MutableDigraph, MutationStream};
use diter::linalg::vec_ops::norm1;
use diter::partition::{Partition, PidState};
use diter::prng::Xoshiro256pp;
use diter::solver::{FixedPointProblem, SequenceKind};
use diter::transport::{CoalescePolicy, FlushPolicy};

const N: usize = 220;
const K: usize = 3;
const STEPS: usize = 8;

/// Live PIDs whose Ω holds at least `min_len` coordinates.
fn live_pids_with(engine: &mut StreamingEngine, min_len: usize) -> Vec<usize> {
    let pool = engine.pool_mut();
    let table = pool.table().clone();
    let part = table.partition();
    pool.live_pids()
        .into_iter()
        .filter(|&p| table.liveness(p) == PidState::Live && part.part(p).len() >= min_len)
        .collect()
}

/// Split a random big-enough part onto a fresh worker (no-op when at
/// capacity or nothing is big enough — a refused event is still a step).
fn spawn_somewhere(engine: &mut StreamingEngine, rng: &mut Xoshiro256pp) {
    let candidates = live_pids_with(engine, 6);
    if candidates.is_empty() {
        return;
    }
    let from = candidates[rng.below(candidates.len())];
    let _ = engine.pool_mut().spawn_split(from);
}

/// Begin retiring a random live worker into a live absorber.
fn retire_somewhere(engine: &mut StreamingEngine, rng: &mut Xoshiro256pp) {
    let candidates = live_pids_with(engine, 0);
    if candidates.len() < 2 {
        return;
    }
    let pid = candidates[rng.below(candidates.len())];
    let absorber = *candidates.iter().find(|&&p| p != pid).unwrap();
    engine.pool_mut().retire(pid, absorber);
}

/// Install a leader-planned ownership move (half of one part) mid-flight.
fn handoff_somewhere(engine: &mut StreamingEngine, rng: &mut Xoshiro256pp) {
    let candidates = live_pids_with(engine, 4);
    if candidates.len() < 2 {
        return;
    }
    let from = candidates[rng.below(candidates.len())];
    let to = *candidates.iter().find(|&&p| p != from).unwrap();
    let table = engine.pool_mut().table().clone();
    let part = table.partition();
    let own = part.part(from);
    let half: Vec<usize> = own[..own.len() / 2].to_vec();
    if let Ok(next) = part.transfer_elastic(&half, to) {
        let _ = table.install_elastic(next);
    }
}

/// Crash a random live worker: no drain, no goodbye — the thread exits
/// with parcels still queued at it and retention unacked. The engine's
/// next poll detects the death and runs checkpoint recovery.
fn kill_somewhere(engine: &mut StreamingEngine, rng: &mut Xoshiro256pp) {
    let candidates = live_pids_with(engine, 0);
    if candidates.len() < 2 {
        return;
    }
    let pid = candidates[rng.below(candidates.len())];
    engine.pool_mut().kill(pid);
}

fn fuzz(rebase: RebaseMode, seed: u64) {
    fuzz_with(rebase, seed, None, None)
}

fn fuzz_with(
    rebase: RebaseMode,
    seed: u64,
    transport: Option<TransportKind>,
    wire_flush: Option<FlushPolicy>,
) {
    let g = power_law_web_graph(N, 5, 0.1, seed);
    let mg = MutableDigraph::from_digraph(&g, N);
    let mut cfg = DistributedConfig::new(Partition::contiguous(N, K).unwrap())
        .with_tol(1e-9)
        .with_seed(seed)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_rebase(rebase)
        // elastic plumbing with an inert policy: the pool can host
        // spawned workers and complete retirements, but never starts a
        // lifecycle operation on its own — the fuzz drives every event,
        // so the interleaving is a pure function of the seed
        .with_elastic(ElasticConfig {
            max_workers: K + 3,
            spawn_threshold: 0.0,
            retire_idle: Duration::from_secs(3600),
            interval: Duration::from_millis(5),
            min_part: 2,
            min_workers: 1,
            max_ops: 10_000,
        });
    cfg.latency = Some((Duration::from_micros(30), Duration::from_micros(300)));
    cfg.coalesce = CoalescePolicy {
        min_mass: 1e-4,
        max_entries: 48,
    };
    cfg.max_wall = Duration::from_secs(60);
    if let Some(t) = transport {
        cfg = cfg.with_transport(t);
    }
    if let Some(f) = wire_flush {
        cfg = cfg.with_wire_flush(f);
    }
    let mut engine = StreamingEngine::new(mg, 0.85, true, cfg).unwrap();
    let mut stream = MutationStream::new(ChurnModel::RandomRewire, seed ^ 0xF0);
    let mut burst = MutationStream::new(ChurnModel::HotSpotBurst { burst: 16 }, seed ^ 0xB0);
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    for step in 0..STEPS {
        // stir: inject a mutation epoch and return before it converges,
        // so the event below fires with fluid genuinely in flight
        engine.set_max_wall(Duration::from_millis(2));
        let batch = stream.next_batch(engine.graph(), 10);
        let _ = engine.apply_batch(&batch).unwrap();
        // step 0 is always a handoff, so the final lifecycle-happened
        // assertion cannot depend on the rng's event mix
        match if step == 0 { 3 } else { rng.below(4) } {
            0 => {
                // a second epoch rebase while the last one's fluid flies
                let b2 = burst.next_batch(engine.graph(), 8);
                let _ = engine.apply_batch(&b2).unwrap();
            }
            1 => spawn_somewhere(&mut engine, &mut rng),
            2 => retire_somewhere(&mut engine, &mut rng),
            _ => handoff_somewhere(&mut engine, &mut rng),
        }
        // settle, then assert EXACT conservation after this event
        engine.set_max_wall(Duration::from_secs(60));
        let report = engine.converge().unwrap();
        assert!(
            report.solution.converged,
            "step {step}: residual {:.3e}",
            report.solution.residual
        );
        assert!(
            (norm1(&report.solution.x) - 1.0).abs() < 1e-6,
            "step {step}: mass leaked — ‖x‖₁ = {}",
            norm1(&report.solution.x)
        );
    }
    let x = engine.solution().unwrap();
    common::assert_fixed_point(&engine, &x, 1e-6, "final");
    let pool_stats = engine.pool_stats();
    let summary = engine.finish().unwrap();
    assert!(summary.epochs >= STEPS as u64);
    // the lifecycle events must have actually happened — a regression
    // that silently refuses every spawn/retire/handoff would otherwise
    // turn this into a plain churn test (mutations don't count here;
    // handoffs_total covers installed transfers, spawn splits and
    // retirement drains alike, and the seeds are fixed so at least one
    // lifecycle event fires and succeeds)
    let handoffs = summary.final_solution.metrics["handoffs_total"];
    assert!(
        pool_stats.spawned + pool_stats.retired + handoffs > 0,
        "fuzz ran no lifecycle events at all: {pool_stats:?}"
    );
}

/// The crash-chaos half of the fuzz (DESIGN.md §11): the same event
/// storm, with **worker kills** stirred into the mix and checkpoint
/// recovery armed. A kill fires while fluid is genuinely mid-flight —
/// mid-diffusion, mid-handoff, or straight into the next epoch rebase —
/// and after every step the engine must land back on EXACT conservation
/// (unit mass) and, at the end, the cold fixed point of the mutated
/// graph. Fluid lost with the dead worker is recomputed from the
/// restored checkpoint H (`F = b − (I−P)·H`), never replayed, so the
/// recovered trajectory re-converges to the identical answer.
fn fuzz_kill(rebase: RebaseMode, seed: u64, transport: Option<TransportKind>) {
    let g = power_law_web_graph(N, 5, 0.1, seed);
    let mg = MutableDigraph::from_digraph(&g, N);
    let mut cfg = DistributedConfig::new(Partition::contiguous(N, K).unwrap())
        .with_tol(1e-9)
        .with_seed(seed)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_rebase(rebase)
        .with_checkpoint_every(Duration::from_millis(2))
        .with_heartbeat(Duration::from_millis(500))
        .with_elastic(ElasticConfig {
            max_workers: K + 3,
            spawn_threshold: 0.0,
            retire_idle: Duration::from_secs(3600),
            interval: Duration::from_millis(5),
            min_part: 2,
            min_workers: 1,
            max_ops: 10_000,
        });
    cfg.latency = Some((Duration::from_micros(30), Duration::from_micros(300)));
    cfg.coalesce = CoalescePolicy {
        min_mass: 1e-4,
        max_entries: 48,
    };
    cfg.max_wall = Duration::from_secs(60);
    if let Some(t) = transport {
        cfg = cfg.with_transport(t);
    }
    let mut engine = StreamingEngine::new(mg, 0.85, true, cfg).unwrap();
    let mut stream = MutationStream::new(ChurnModel::RandomRewire, seed ^ 0xF0);
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    for step in 0..STEPS {
        // stir: an unconverged epoch keeps fluid in flight when the kill
        // (or other event) lands
        engine.set_max_wall(Duration::from_millis(2));
        let batch = stream.next_batch(engine.graph(), 10);
        let _ = engine.apply_batch(&batch).unwrap();
        // step 0 is always a kill so the final crashed-and-recovered
        // assertion cannot depend on the rng's event mix; later steps
        // mix kills with handoffs, spawns and retires so a crash can
        // land mid-any-protocol
        match if step == 0 { 4 } else { rng.below(5) } {
            0 => {
                let b2 = stream.next_batch(engine.graph(), 8);
                let _ = engine.apply_batch(&b2).unwrap();
            }
            1 => spawn_somewhere(&mut engine, &mut rng),
            2 => retire_somewhere(&mut engine, &mut rng),
            3 => handoff_somewhere(&mut engine, &mut rng),
            _ => kill_somewhere(&mut engine, &mut rng),
        }
        engine.set_max_wall(Duration::from_secs(60));
        let report = engine.converge().unwrap();
        assert!(
            report.solution.converged,
            "step {step}: residual {:.3e}",
            report.solution.residual
        );
        assert!(
            (norm1(&report.solution.x) - 1.0).abs() < 1e-6,
            "step {step}: mass leaked through the crash — ‖x‖₁ = {}",
            norm1(&report.solution.x)
        );
    }
    let x = engine.solution().unwrap();
    common::assert_fixed_point(&engine, &x, 1e-6, "final-after-kills");
    let pool_stats = engine.pool_stats();
    engine.finish().unwrap();
    assert!(
        pool_stats.crashes >= 1,
        "the chaos ran no kills at all: {pool_stats:?}"
    );
    assert_eq!(
        pool_stats.recoveries, pool_stats.crashes,
        "every detected crash must be recovered: {pool_stats:?}"
    );
}

/// The per-lane half of the fuzz (DESIGN.md §10): PPR queries are
/// admitted and completed *while* the same event storm — churn epochs,
/// planned handoffs, elastic spawn/retire — runs underneath, under
/// latency injection and coalescing. After every event the step drains
/// its tenants and asserts per-qid conservation exactly: each served
/// query's readout carries unit PPR mass and is the fixed point of its
/// own `(P, b_q)` system on the *current* (post-churn) matrix. A leak in
/// any lane's accounting either never completes (caught by the drain
/// deadline) or completes wrong (caught by the mass/fixed-point check).
fn fuzz_serve(seed: u64, transport: Option<TransportKind>) {
    const LANES: usize = 2;
    const EPS: f64 = 1e-7;
    let steps = 5usize;
    let g = power_law_web_graph(N, 5, 0.1, seed);
    let mg = MutableDigraph::from_digraph(&g, N);
    let mut cfg = DistributedConfig::new(Partition::contiguous(N, K).unwrap())
        .with_tol(1e-9)
        .with_seed(seed)
        .with_elastic(ElasticConfig {
            max_workers: K + 3,
            spawn_threshold: 0.0,
            retire_idle: Duration::from_secs(3600),
            interval: Duration::from_millis(5),
            min_part: 2,
            min_workers: 1,
            max_ops: 10_000,
        });
    cfg.latency = Some((Duration::from_micros(30), Duration::from_micros(300)));
    cfg.coalesce = CoalescePolicy {
        min_mass: 1e-4,
        max_entries: 48,
    };
    cfg.max_wall = Duration::from_secs(60);
    if let Some(t) = transport {
        cfg = cfg.with_transport(t);
    }
    let serve_cfg = ServeConfig {
        queue_cap: 16,
        default_eps: EPS,
        ..Default::default()
    };
    let mut serve = ServeEngine::new(mg, 0.85, true, cfg, serve_cfg, LANES).unwrap();
    let mut stream = MutationStream::new(ChurnModel::RandomRewire, seed ^ 0xF0);
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let qs = serve.query_set().clone();
    let mut served_total = 0usize;
    for step in 0..steps {
        // admit a full complement of tenants, then fire the event storm
        // while their fluid is genuinely mid-flight
        let mut expected = Vec::new();
        for _ in 0..LANES {
            let seeds = [rng.below(N), rng.below(N)];
            let qid = serve
                .submit(Query::ppr(&seeds, 0.85, EPS))
                .expect("queue has room");
            expected.push((qid, seeds.to_vec()));
        }
        let batch = stream.next_batch(serve.engine().graph(), 10);
        serve.apply_mutations(&batch).unwrap();
        match if step == 0 { 3 } else { rng.below(4) } {
            0 => {
                let b2 = stream.next_batch(serve.engine().graph(), 8);
                serve.apply_mutations(&b2).unwrap();
            }
            1 => spawn_somewhere(serve.engine_mut(), &mut rng),
            2 => retire_somewhere(serve.engine_mut(), &mut rng),
            _ => handoff_somewhere(serve.engine_mut(), &mut rng),
        }
        // mid-flight: every active lane's account is finite and errs
        // high (a negative total would mean a release outran its charge)
        for lane in 1..qs.lanes() {
            let t = qs.lane_total(lane);
            assert!(t.is_finite() && t >= -1e-9, "step {step} lane {lane}: total {t}");
        }
        let done = serve.drain(Duration::from_secs(60)).unwrap();
        assert_eq!(done.len(), expected.len(), "step {step}: tenants wedged mid-storm");
        let problem = serve.engine().problem();
        for d in &done {
            assert_eq!(d.state, QueryState::Served, "step {step}: no deadlines configured");
            let x = d.x.as_ref().expect("served queries carry a readout");
            assert!(
                (norm1(x) - 1.0).abs() < 1e-5,
                "step {step} qid {}: PPR mass leaked — ‖x‖₁ = {}",
                d.qid,
                norm1(x)
            );
            let seeds = &expected.iter().find(|(q, _)| *q == d.qid).unwrap().1;
            let q = Query::ppr(seeds, 0.85, EPS);
            let mut b = vec![0.0; N];
            for (c, m) in &q.seeds {
                b[*c] += m;
            }
            let single = FixedPointProblem::new(problem.matrix().clone(), b).unwrap();
            let res = single.residual_norm(x);
            assert!(
                res < 1e-5,
                "step {step} qid {}: not the fixed point of its own system \
                 (residual {res:.3e})",
                d.qid
            );
            served_total += 1;
        }
    }
    assert_eq!(served_total, steps * LANES);
    let pool_stats = serve.engine().pool_stats();
    let summary = serve.finish().unwrap();
    let handoffs = summary.final_solution.metrics["handoffs_total"];
    assert!(
        pool_stats.spawned + pool_stats.retired + handoffs > 0,
        "serve fuzz ran no lifecycle events at all: {pool_stats:?}"
    );
    assert_eq!(summary.final_solution.metrics["queries_served"], served_total as u64);
}

#[test]
fn fuzz_conservation_per_lane_serving() {
    fuzz_serve(0xFA57_0005, None);
}

/// The per-lane fuzz with every parcel (and its `qids` column) crossing
/// a real TCP socket: tag 0x13 round-trips under the same event storm.
#[test]
fn fuzz_conservation_per_lane_serving_wire() {
    fuzz_serve(0xFA57_0006, Some(TransportKind::Wire));
}

#[test]
fn fuzz_conservation_gather_protocol() {
    fuzz(RebaseMode::Gather, 0xFA57_0001);
}

/// Kill chaos over the in-process bus: crashes land mid-diffusion,
/// mid-handoff, mid-spawn/retire and straight into gather rebases.
#[test]
fn fuzz_conservation_kill_recovery_bus() {
    fuzz_kill(RebaseMode::Gather, 0xFA57_0007, None);
}

/// Kill chaos with every parcel, handoff, retention ack and recovery
/// reconnect crossing a real TCP socket.
#[test]
fn fuzz_conservation_kill_recovery_wire() {
    fuzz_kill(RebaseMode::Gather, 0xFA57_0008, Some(TransportKind::Wire));
}

#[test]
fn fuzz_conservation_local_protocol() {
    fuzz(RebaseMode::Local, 0xFA57_0002);
}

/// The same fuzz, but every parcel, handoff, and halo slice crosses a
/// real TCP socket: the loopback wire harness (DESIGN.md §8.5) must
/// preserve exact conservation under the identical event storm. (The
/// whole suite re-runs over the wire via `DITER_TRANSPORT=wire` in CI;
/// this cell keeps one wire run in the default suite.)
#[test]
fn fuzz_conservation_wire_loopback() {
    fuzz_with(RebaseMode::Local, 0xFA57_0003, Some(TransportKind::Wire), None);
}

/// The wire fuzz again under an adversarially tiny flush policy: every
/// bound trips on every send (1-byte budget, 1-frame cap, zero
/// deadline), so frames flush one syscall at a time through the exact
/// degenerate path the batching fast path is supposed to subsume.
/// Conservation must be bit-for-bit indifferent to *when* queued frames
/// reach the socket.
#[test]
fn fuzz_conservation_wire_degenerate_flush() {
    fuzz_with(
        RebaseMode::Local,
        0xFA57_0004,
        Some(TransportKind::Wire),
        Some(FlushPolicy {
            max_bytes: 1,
            max_frames: 1,
            deadline: Duration::ZERO,
        }),
    );
}
