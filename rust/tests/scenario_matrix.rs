//! Deterministic cross-protocol scenario matrix:
//! {churn: grow | rewire | hotspot} × {kernel: local | blocked | global}
//! × {rebase: local | gather} × {elastic on | off} × {latency on | off}.
//!
//! Every cell runs the streaming engine through seeded mutation epochs
//! and asserts the two invariants the whole system rests on — exact
//! fluid conservation (unit PageRank mass) and fixed-point equality with
//! a sequential cold solve — plus the epoch-protocol contract observed
//! through the bus metrics: the local path routes **zero** coordinates
//! through the leader's gather/scatter; the gather path routes all of
//! them.
//!
//! Seeds are fixed per cell and baked into the scenario name
//! (`model-kernel-rebase-pool-bus-sSEED`), so any failure is
//! reproducible by name alone. When `DITER_MATRIX_FAIL_FILE` is set
//! (the CI `test-matrix` step), failing names are appended there and
//! uploaded as a build artifact.

mod common;

use std::fs::OpenOptions;
use std::io::Write;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Duration;

use diter::coordinator::{
    DistributedConfig, ElasticConfig, KernelKind, RebaseMode, StreamingEngine,
};
use diter::graph::{power_law_web_graph, ChurnModel, MutableDigraph, MutationStream};
use diter::partition::Partition;
use diter::solver::SequenceKind;

const N: usize = 130;
const K: usize = 3;
const BATCHES: usize = 2;
const BATCH_SIZE: usize = 12;

#[derive(Clone, Copy)]
struct Scenario {
    kernel: KernelKind,
    rebase: RebaseMode,
    elastic: bool,
    latency: bool,
    seed: u64,
}

fn scenario_name(model: &ChurnModel, s: &Scenario) -> String {
    format!(
        "{}-{}-{}-{}-{}-s{}",
        model.name(),
        s.kernel.name(),
        s.rebase.name(),
        if s.elastic { "elastic" } else { "fixed" },
        if s.latency { "latency" } else { "instant" },
        s.seed
    )
}

fn run_scenario(model: ChurnModel, s: Scenario) {
    // growth needs dormant headroom; the other models run at capacity
    let seed_nodes = match model {
        ChurnModel::PreferentialGrowth { .. } => N - 20,
        _ => N,
    };
    let g = power_law_web_graph(seed_nodes, 5, 0.1, s.seed);
    let mg = MutableDigraph::from_digraph(&g, N);
    let mut cfg = DistributedConfig::new(Partition::contiguous(N, K).unwrap())
        .with_tol(1e-9)
        .with_seed(s.seed)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_kernel(s.kernel)
        .with_rebase(s.rebase);
    cfg.max_wall = Duration::from_secs(60);
    if s.latency {
        cfg.latency = Some((Duration::from_micros(40), Duration::from_micros(250)));
    }
    if s.elastic {
        // live policy: the scheduler may spawn and retire on its own
        // while the epochs run — conservation must hold regardless
        cfg = cfg.with_elastic(ElasticConfig {
            max_workers: K + 2,
            interval: Duration::from_millis(10),
            ..Default::default()
        });
    }
    let mut engine = StreamingEngine::new(mg, 0.85, true, cfg).unwrap();
    let init = engine.converge().unwrap();
    assert!(init.solution.converged, "init residual {:.3e}", init.solution.residual);
    let mut stream = MutationStream::new(model, s.seed ^ 0xD117);
    let mut applied_any = false;
    let mut last_metrics = init.solution.metrics;
    for b in 0..BATCHES {
        let batch = stream.next_batch(engine.graph(), BATCH_SIZE);
        let report = engine.apply_batch(&batch).unwrap();
        applied_any |= report.mutations_applied > 0;
        assert!(report.solution.converged, "batch {b}: {:.3e}", report.solution.residual);
        // exact fluid conservation + cold-solve equality, every epoch
        common::assert_fixed_point(&engine, &report.solution.x, 1e-6, "epoch");
        last_metrics = report.solution.metrics;
    }
    // the epoch-protocol contract, observed through the bus metrics
    match s.rebase {
        RebaseMode::Local => assert_eq!(
            last_metrics["rebase_gather_coords"],
            0,
            "leader gather/scatter ran on the local path"
        ),
        RebaseMode::Gather => {
            if applied_any {
                assert!(
                    last_metrics["rebase_gather_coords"] > 0,
                    "the gather path must route coords through the leader"
                );
            }
            assert_eq!(
                last_metrics["halo_slices_sent"],
                0,
                "halo machinery ran on the gather path"
            );
        }
    }
    engine.finish().unwrap();
}

/// Append failing scenario names to the CI artifact file, if configured.
fn record_failures(failures: &[String]) {
    let Ok(path) = std::env::var("DITER_MATRIX_FAIL_FILE") else {
        return;
    };
    if let Ok(mut f) = OpenOptions::new().create(true).append(true).open(&path) {
        for line in failures {
            let _ = writeln!(f, "{line}");
        }
    }
}

/// Run all 24 {kernel × rebase × elastic × latency} cells of one churn
/// model, collecting every failure (not just the first) so one CI run
/// reports the whole failing set by name.
fn run_grid(model: ChurnModel, base_seed: u64) {
    let mut failures: Vec<String> = Vec::new();
    let mut idx = 0u64;
    for kernel in [
        KernelKind::LocalBlock,
        KernelKind::Blocked,
        KernelKind::GlobalWalk,
    ] {
        for rebase in [RebaseMode::Local, RebaseMode::Gather] {
            for elastic in [false, true] {
                for latency in [false, true] {
                    idx += 1;
                    let s = Scenario {
                        kernel,
                        rebase,
                        elastic,
                        latency,
                        seed: base_seed + idx,
                    };
                    let name = scenario_name(&model, &s);
                    let m = model.clone();
                    if let Err(payload) = catch_unwind(AssertUnwindSafe(|| run_scenario(m, s))) {
                        failures.push(format!("{name}: {}", common::panic_message(payload)));
                    }
                }
            }
        }
    }
    if !failures.is_empty() {
        record_failures(&failures);
        panic!("{} scenario(s) failed:\n{}", failures.len(), failures.join("\n"));
    }
}

#[test]
fn matrix_grow() {
    run_grid(ChurnModel::PreferentialGrowth { links_per_node: 3 }, 0x6A00);
}

#[test]
fn matrix_rewire() {
    run_grid(ChurnModel::RandomRewire, 0x4E00);
}

#[test]
fn matrix_hotspot() {
    run_grid(ChurnModel::HotSpotBurst { burst: 12 }, 0x1500);
}
