//! Deterministic cross-protocol scenario matrix:
//! {churn: grow | rewire | hotspot} × {kernel: local | blocked | global}
//! × {rebase: local | gather} × {elastic on | off} × {latency on | off}.
//!
//! Every cell runs the streaming engine through seeded mutation epochs
//! and asserts the two invariants the whole system rests on — exact
//! fluid conservation (unit PageRank mass) and fixed-point equality with
//! a sequential cold solve — plus the epoch-protocol contract observed
//! through the bus metrics: the local path routes **zero** coordinates
//! through the leader's gather/scatter; the gather path routes all of
//! them.
//!
//! Seeds are fixed per cell and baked into the scenario name
//! (`model-kernel-rebase-pool-bus-sSEED`), so any failure is
//! reproducible by name alone. When `DITER_MATRIX_FAIL_FILE` is set
//! (the CI `test-matrix` step), failing names are appended there and
//! uploaded as a build artifact.

mod common;

use std::fs::OpenOptions;
use std::io::Write;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Duration;

use diter::coordinator::{
    DistributedConfig, ElasticConfig, KernelKind, Query, QueryState, RebaseMode, ServeConfig,
    ServeEngine, StreamingEngine, TransportKind,
};
use diter::graph::{power_law_web_graph, ChurnModel, MutableDigraph, MutationStream};
use diter::linalg::vec_ops::norm1;
use diter::partition::Partition;
use diter::solver::SequenceKind;

const N: usize = 130;
const K: usize = 3;
const BATCHES: usize = 2;
const BATCH_SIZE: usize = 12;

#[derive(Clone, Copy)]
struct Scenario {
    kernel: KernelKind,
    rebase: RebaseMode,
    elastic: bool,
    latency: bool,
    seed: u64,
}

fn scenario_name(model: &ChurnModel, s: &Scenario) -> String {
    format!(
        "{}-{}-{}-{}-{}-s{}",
        model.name(),
        s.kernel.name(),
        s.rebase.name(),
        if s.elastic { "elastic" } else { "fixed" },
        if s.latency { "latency" } else { "instant" },
        s.seed
    )
}

fn run_scenario(model: ChurnModel, s: Scenario) {
    // growth needs dormant headroom; the other models run at capacity
    let seed_nodes = match model {
        ChurnModel::PreferentialGrowth { .. } => N - 20,
        _ => N,
    };
    let g = power_law_web_graph(seed_nodes, 5, 0.1, s.seed);
    let mg = MutableDigraph::from_digraph(&g, N);
    let mut cfg = DistributedConfig::new(Partition::contiguous(N, K).unwrap())
        .with_tol(1e-9)
        .with_seed(s.seed)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_kernel(s.kernel)
        .with_rebase(s.rebase);
    cfg.max_wall = Duration::from_secs(60);
    if s.latency {
        cfg.latency = Some((Duration::from_micros(40), Duration::from_micros(250)));
    }
    if s.elastic {
        // live policy: the scheduler may spawn and retire on its own
        // while the epochs run — conservation must hold regardless
        cfg = cfg.with_elastic(ElasticConfig {
            max_workers: K + 2,
            interval: Duration::from_millis(10),
            ..Default::default()
        });
    }
    let mut engine = StreamingEngine::new(mg, 0.85, true, cfg).unwrap();
    let init = engine.converge().unwrap();
    assert!(init.solution.converged, "init residual {:.3e}", init.solution.residual);
    let mut stream = MutationStream::new(model, s.seed ^ 0xD117);
    let mut applied_any = false;
    let mut last_metrics = init.solution.metrics;
    for b in 0..BATCHES {
        let batch = stream.next_batch(engine.graph(), BATCH_SIZE);
        let report = engine.apply_batch(&batch).unwrap();
        applied_any |= report.mutations_applied > 0;
        assert!(report.solution.converged, "batch {b}: {:.3e}", report.solution.residual);
        // exact fluid conservation + cold-solve equality, every epoch
        common::assert_fixed_point(&engine, &report.solution.x, 1e-6, "epoch");
        last_metrics = report.solution.metrics;
    }
    // the epoch-protocol contract, observed through the bus metrics
    match s.rebase {
        RebaseMode::Local => assert_eq!(
            last_metrics["rebase_gather_coords"],
            0,
            "leader gather/scatter ran on the local path"
        ),
        RebaseMode::Gather => {
            if applied_any {
                assert!(
                    last_metrics["rebase_gather_coords"] > 0,
                    "the gather path must route coords through the leader"
                );
            }
            assert_eq!(
                last_metrics["halo_slices_sent"],
                0,
                "halo machinery ran on the gather path"
            );
        }
    }
    engine.finish().unwrap();
}

/// Append failing scenario names to the CI artifact file, if configured.
fn record_failures(failures: &[String]) {
    let Ok(path) = std::env::var("DITER_MATRIX_FAIL_FILE") else {
        return;
    };
    if let Ok(mut f) = OpenOptions::new().create(true).append(true).open(&path) {
        for line in failures {
            let _ = writeln!(f, "{line}");
        }
    }
}

/// Run all 24 {kernel × rebase × elastic × latency} cells of one churn
/// model, collecting every failure (not just the first) so one CI run
/// reports the whole failing set by name.
fn run_grid(model: ChurnModel, base_seed: u64) {
    let mut failures: Vec<String> = Vec::new();
    let mut idx = 0u64;
    for kernel in [
        KernelKind::LocalBlock,
        KernelKind::Blocked,
        KernelKind::GlobalWalk,
    ] {
        for rebase in [RebaseMode::Local, RebaseMode::Gather] {
            for elastic in [false, true] {
                for latency in [false, true] {
                    idx += 1;
                    let s = Scenario {
                        kernel,
                        rebase,
                        elastic,
                        latency,
                        seed: base_seed + idx,
                    };
                    let name = scenario_name(&model, &s);
                    let m = model.clone();
                    if let Err(payload) = catch_unwind(AssertUnwindSafe(|| run_scenario(m, s))) {
                        failures.push(format!("{name}: {}", common::panic_message(payload)));
                    }
                }
            }
        }
    }
    if !failures.is_empty() {
        record_failures(&failures);
        panic!("{} scenario(s) failed:\n{}", failures.len(), failures.join("\n"));
    }
}

#[test]
fn matrix_grow() {
    run_grid(ChurnModel::PreferentialGrowth { links_per_node: 3 }, 0x6A00);
}

#[test]
fn matrix_rewire() {
    run_grid(ChurnModel::RandomRewire, 0x4E00);
}

#[test]
fn matrix_hotspot() {
    run_grid(ChurnModel::HotSpotBurst { burst: 12 }, 0x1500);
}

// ---------------------------------------------------------------------------
// Kill-chaos cells: a worker crash (no drain, no goodbye — the thread just
// stops) landed at each protocol moment — mid-diffusion, mid-handoff,
// mid-rebase, mid-serve — over both transports. Every cell asserts the
// crash was detected and recovered (`recoveries == crashes`), that exact
// fluid conservation held through the recovery, and that the recovered
// fixed point equals a sequential cold solve on the same graph (per-lane
// unit PPR mass for the serving cell). The recomputation identity
// `F = b − (I−P)·H` is what makes these exact rather than approximate:
// fluid lost with the dead worker is rebuilt from checkpointed H, never
// replayed.

#[derive(Clone, Copy, PartialEq)]
enum KillMoment {
    Diffusion,
    Handoff,
    Rebase,
    Serve,
}

impl KillMoment {
    fn name(self) -> &'static str {
        match self {
            KillMoment::Diffusion => "diffusion",
            KillMoment::Handoff => "handoff",
            KillMoment::Rebase => "rebase",
            KillMoment::Serve => "serve",
        }
    }
}

/// Crash-tolerant config shared by every kill cell: fast incremental
/// checkpoints, a heartbeat, and an inert elastic policy (pool headroom
/// for the handoff cell without the scheduler firing its own events).
fn kill_cfg(seed: u64, transport: Option<TransportKind>) -> DistributedConfig {
    let mut cfg = DistributedConfig::new(Partition::contiguous(N, K).unwrap())
        .with_tol(1e-9)
        .with_seed(seed)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_rebase(RebaseMode::Gather)
        .with_checkpoint_every(Duration::from_millis(2))
        .with_heartbeat(Duration::from_millis(500))
        .with_elastic(ElasticConfig {
            max_workers: K + 1,
            spawn_threshold: 0.0,
            retire_idle: Duration::from_secs(3600),
            interval: Duration::from_millis(5),
            min_part: 2,
            min_workers: 1,
            max_ops: 10_000,
        });
    cfg.max_wall = Duration::from_secs(60);
    if let Some(t) = transport {
        cfg = cfg.with_transport(t);
    }
    cfg
}

fn run_kill_stream(moment: KillMoment, transport: Option<TransportKind>, seed: u64) {
    let g = power_law_web_graph(N, 5, 0.1, seed);
    let mg = MutableDigraph::from_digraph(&g, N);
    let mut engine = StreamingEngine::new(mg, 0.85, true, kill_cfg(seed, transport)).unwrap();
    let init = engine.converge().unwrap();
    assert!(init.solution.converged, "init residual {:.3e}", init.solution.residual);
    let mut stream = MutationStream::new(ChurnModel::RandomRewire, seed ^ 0xD117);
    match moment {
        KillMoment::Diffusion => {
            // stir an epoch but stop well before convergence, so the
            // crash lands with fluid genuinely mid-flight
            engine.set_max_wall(Duration::from_millis(2));
            let batch = stream.next_batch(engine.graph(), BATCH_SIZE);
            let _ = engine.apply_batch(&batch).unwrap();
            engine.set_max_wall(Duration::from_secs(60));
            engine.pool_mut().kill(1);
        }
        KillMoment::Handoff => {
            engine.set_max_wall(Duration::from_millis(2));
            let batch = stream.next_batch(engine.graph(), BATCH_SIZE);
            let _ = engine.apply_batch(&batch).unwrap();
            engine.set_max_wall(Duration::from_secs(60));
            // plan an ownership move out of pid 1, then crash the
            // shipper before its slice can settle — recovery must fold
            // the orphaned coordinates instead of fostering their fluid
            // forever
            let table = engine.pool_mut().table().clone();
            let part = table.partition();
            let own = part.part(1);
            let half: Vec<usize> = own[..own.len() / 2].to_vec();
            if let Ok(next) = part.transfer_elastic(&half, 2) {
                let _ = table.install_elastic(next);
            }
            engine.pool_mut().kill(1);
        }
        KillMoment::Rebase => {
            // crash first, give the thread time to actually exit with no
            // poll in between (kill() does not poll), then demand an
            // epoch transition: the rebase itself — not a converge loop —
            // must detect and recover the dead worker before freezing
            // the ownership table
            engine.pool_mut().kill(1);
            std::thread::sleep(Duration::from_millis(50));
            let batch = stream.next_batch(engine.graph(), BATCH_SIZE);
            let _ = engine.apply_batch(&batch).unwrap();
        }
        KillMoment::Serve => unreachable!("serve cells run through run_kill_serve"),
    }
    let report = engine.converge().unwrap();
    assert!(report.solution.converged, "residual {:.3e}", report.solution.residual);
    common::assert_fixed_point(&engine, &report.solution.x, 1e-6, moment.name());
    let stats = engine.pool_stats();
    engine.finish().unwrap();
    assert!(stats.crashes >= 1, "{}: no crash detected: {stats:?}", moment.name());
    assert_eq!(
        stats.recoveries, stats.crashes,
        "{}: every detected crash must be recovered: {stats:?}",
        moment.name()
    );
}

fn run_kill_serve(transport: Option<TransportKind>, seed: u64) {
    const LANES: usize = 2;
    const EPS: f64 = 1e-7;
    let g = power_law_web_graph(N, 5, 0.1, seed);
    let mg = MutableDigraph::from_digraph(&g, N);
    let serve_cfg = ServeConfig {
        queue_cap: 8,
        default_eps: EPS,
        ..Default::default()
    };
    let mut serve =
        ServeEngine::new(mg, 0.85, true, kill_cfg(seed, transport), serve_cfg, LANES).unwrap();
    let mut qids = Vec::new();
    for i in 0..LANES {
        let seeds = [(i * 7 + 3) % N, (i * 13 + 5) % N];
        qids.push(
            serve
                .submit(Query::ppr(&seeds, 0.85, EPS))
                .expect("queue has room"),
        );
    }
    // crash a worker while every lane's PPR fluid is mid-flight; the
    // serving loop's own pump must detect, recover (re-claiming seeds
    // the dead worker held), and still complete each tenant exactly
    serve.engine_mut().pool_mut().kill(1);
    let done = serve.drain(Duration::from_secs(60)).unwrap();
    assert_eq!(done.len(), qids.len(), "tenants wedged across the crash");
    for d in &done {
        assert_eq!(d.state, QueryState::Served, "no deadlines configured");
        let x = d.x.as_ref().expect("served queries carry a readout");
        assert!(
            (norm1(x) - 1.0).abs() < 1e-5,
            "qid {}: PPR mass leaked through the crash — ‖x‖₁ = {}",
            d.qid,
            norm1(x)
        );
    }
    let stats = serve.engine().pool_stats();
    serve.finish().unwrap();
    assert!(stats.crashes >= 1, "serve: no crash detected: {stats:?}");
    assert_eq!(
        stats.recoveries, stats.crashes,
        "serve: every detected crash must be recovered: {stats:?}"
    );
}

/// All {moment × transport} kill cells, failures collected by name like
/// the churn grids above.
fn run_kill_grid() {
    let mut failures: Vec<String> = Vec::new();
    let mut idx = 0u64;
    for moment in [
        KillMoment::Diffusion,
        KillMoment::Handoff,
        KillMoment::Rebase,
        KillMoment::Serve,
    ] {
        for transport in [None, Some(TransportKind::Wire)] {
            idx += 1;
            let seed = 0xC4A5 + idx;
            let name = format!(
                "kill-{}-{}-s{seed}",
                moment.name(),
                if transport.is_some() { "wire" } else { "bus" },
            );
            let result = catch_unwind(AssertUnwindSafe(|| {
                if moment == KillMoment::Serve {
                    run_kill_serve(transport, seed);
                } else {
                    run_kill_stream(moment, transport, seed);
                }
            }));
            if let Err(payload) = result {
                failures.push(format!("{name}: {}", common::panic_message(payload)));
            }
        }
    }
    if !failures.is_empty() {
        record_failures(&failures);
        panic!("{} kill cell(s) failed:\n{}", failures.len(), failures.join("\n"));
    }
}

#[test]
fn matrix_kill() {
    run_kill_grid();
}
