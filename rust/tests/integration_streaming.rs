//! Integration: the streaming engine — a continuous mutation stream must
//! keep the distributed computation on the moving fixed point, matching a
//! cold solve of whatever matrix the stream ends on (the arXiv:1203.1715 /
//! 1301.3007 live-update regime, end to end).

use std::time::Duration;

use diter::coordinator::{v2, DistributedConfig, RebaseMode, StreamingEngine};
use diter::graph::{power_law_web_graph, ChurnModel, MutableDigraph, Mutation, MutationStream};
use diter::linalg::vec_ops::{dist1, norm1};
use diter::partition::Partition;
use diter::solver::{DIteration, FixedPointProblem, SequenceKind, SolveOptions, Solver};

fn cold_solution(problem: &FixedPointProblem) -> Vec<f64> {
    let opts = SolveOptions {
        tol: 1e-13,
        max_cost: 200_000.0,
        trace_every: 0.0,
        exact: None,
    };
    DIteration::fluid_cyclic().solve(problem, &opts).unwrap().x
}

fn base_cfg(n: usize, k: usize, seed: u64) -> DistributedConfig {
    DistributedConfig::new(Partition::contiguous(n, k).unwrap())
        .with_tol(1e-10)
        .with_seed(seed)
}

#[test]
fn streamed_sequence_matches_cold_solve_on_final_matrix() {
    // the satellite acceptance property: after a seeded sequence of
    // mutation batches, the streamed solution equals (tolerance-bounded)
    // a cold solve of the final matrix
    let n = 300;
    let g = power_law_web_graph(n, 5, 0.1, 17);
    let mg = MutableDigraph::from_digraph(&g, n);
    let mut eng = StreamingEngine::new(mg, 0.85, true, base_cfg(n, 4, 17)).unwrap();
    eng.converge().unwrap();

    let mut stream = MutationStream::new(ChurnModel::RandomRewire, 99);
    for b in 0..5 {
        let batch = stream.next_batch(eng.graph(), 24);
        let report = eng.apply_batch(&batch).unwrap();
        assert!(
            report.solution.converged,
            "batch {b}: residual {:.3e}",
            report.solution.residual
        );
    }
    let want = cold_solution(eng.problem());
    let summary = eng.finish().unwrap();
    let delta = dist1(&summary.final_solution.x, &want);
    assert!(delta < 1e-7, "streamed vs cold Δ₁ = {delta:.3e}");
    assert_eq!(summary.epochs, 6, "initial solve + 5 batches");
}

#[test]
fn mid_flight_rebases_with_latency_and_coalescing() {
    // fluid conservation across the epoch boundary under the adversarial
    // transport settings: message latency keeps parcels in flight when
    // the first rebase lands (the engine must discard stale epochs and
    // hold future ones, never losing or inventing fluid)
    let n = 400;
    let g = power_law_web_graph(n, 5, 0.1, 3);
    let mg = MutableDigraph::from_digraph(&g, n);
    let mut cfg = base_cfg(n, 4, 3);
    cfg.latency = Some((Duration::from_micros(50), Duration::from_micros(400)));
    cfg.coalesce = diter::transport::CoalescePolicy {
        min_mass: 1e-5,
        max_entries: 64,
    };
    let mut eng = StreamingEngine::new(mg, 0.85, true, cfg).unwrap();
    // NO initial converge: the first batch rebases a mid-flight epoch 0
    let mut stream = MutationStream::new(ChurnModel::RandomRewire, 7);
    for _ in 0..3 {
        let batch = stream.next_batch(eng.graph(), 16);
        let report = eng.apply_batch(&batch).unwrap();
        assert!(
            report.solution.converged,
            "residual {:.3e}",
            report.solution.residual
        );
        // mass conservation: patched dangling makes x a probability vector
        assert!(
            (norm1(&report.solution.x) - 1.0).abs() < 1e-6,
            "‖x‖₁ = {}",
            norm1(&report.solution.x)
        );
    }
    let want = cold_solution(eng.problem());
    let got = eng.solution().unwrap();
    assert!(dist1(&got, &want) < 1e-7, "Δ₁ = {}", dist1(&got, &want));
    eng.finish().unwrap();
}

#[test]
fn growth_and_deactivation_renormalize_correctly() {
    // node adds (with re-normalization via fresh out-degrees) and node
    // removals must both land on the cold fixed point of the final graph
    let n = 200;
    let g = power_law_web_graph(150, 4, 0.1, 5); // 50 dormant coordinates
    let mg = MutableDigraph::from_digraph(&g, n);
    let mut eng = StreamingEngine::new(mg, 0.85, true, base_cfg(n, 3, 5)).unwrap();
    eng.converge().unwrap();

    let mut grow = MutationStream::new(ChurnModel::PreferentialGrowth { links_per_node: 3 }, 41);
    let batch = grow.next_batch(eng.graph(), 20);
    assert!(
        batch
            .iter()
            .any(|m| matches!(m, Mutation::NodeActivate { .. })),
        "growth model must activate dormant nodes"
    );
    let report = eng.apply_batch(&batch).unwrap();
    assert!(report.solution.converged);

    // deactivate a few pages and reweight an edge
    let mut batch2: Vec<Mutation> = (0..4)
        .map(|i| Mutation::NodeDeactivate { node: 10 + i })
        .collect();
    let snapshot = eng.graph().to_digraph();
    let reweight_to = *snapshot.out_neighbors(0).first().unwrap_or(&1);
    batch2.push(Mutation::EdgeReweight {
        from: 0,
        to: reweight_to,
        weight: 5.0,
    });
    let report = eng.apply_batch(&batch2).unwrap();
    assert!(report.solution.converged);

    let want = cold_solution(eng.problem());
    let summary = eng.finish().unwrap();
    let delta = dist1(&summary.final_solution.x, &want);
    assert!(delta < 1e-7, "Δ₁ = {delta:.3e}");
}

#[test]
fn hotspot_burst_shifts_rank_to_the_hot_page() {
    let n = 250;
    let g = power_law_web_graph(n, 5, 0.1, 19);
    let mg = MutableDigraph::from_digraph(&g, n);
    let mut eng = StreamingEngine::new(mg, 0.85, true, base_cfg(n, 4, 19)).unwrap();
    let before = eng.converge().unwrap().solution.x;

    // aim the burst at a concrete node so the rank shift is checkable
    let hot = 123usize;
    let batch: Vec<Mutation> = (0..60)
        .filter(|&s| s != hot)
        .map(|s| Mutation::EdgeInsert {
            from: s,
            to: hot,
            weight: 1.0,
        })
        .collect();
    let report = eng.apply_batch(&batch).unwrap();
    assert!(report.solution.converged);
    assert!(
        report.solution.x[hot] > before[hot] * 1.5,
        "hot page rank {} -> {} should jump",
        before[hot],
        report.solution.x[hot]
    );
    eng.finish().unwrap();
}

#[test]
fn warm_rebase_beats_cold_restart_in_updates() {
    // the headline economics: for small batches, reconvergence after a
    // warm rebase costs well under a cold V2 restart of the same matrix
    let n = 600;
    let g = power_law_web_graph(n, 6, 0.1, 29);
    let mg = MutableDigraph::from_digraph(&g, n);
    let cfg = base_cfg(n, 4, 29).with_sequence(SequenceKind::GreedyMaxFluid);
    let cold_cfg = cfg.clone();
    let mut eng = StreamingEngine::new(mg, 0.85, true, cfg).unwrap();
    eng.converge().unwrap();

    let mut stream = MutationStream::new(ChurnModel::RandomRewire, 47);
    let mut warm = 0u64;
    let mut cold = 0u64;
    for _ in 0..3 {
        let batch = stream.next_batch(eng.graph(), 10);
        let report = eng.apply_batch(&batch).unwrap();
        assert!(report.solution.converged);
        warm += report.solution.total_updates;
        let cold_sol = v2::solve_v2(eng.problem(), &cold_cfg).unwrap();
        assert!(cold_sol.converged);
        cold += cold_sol.total_updates;
    }
    eng.finish().unwrap();
    assert!(
        warm < cold,
        "warm rebases ({warm} updates) must beat cold restarts ({cold})"
    );
}

#[test]
fn local_rebase_skips_leader_and_exchanges_halo() {
    // a crafted cross-partition mutation pair: a dirty column owned by
    // PID 0 whose delta touches PID 2's rows, and one owned by PID 1
    // touching PID 0's — the local protocol MUST ship halo slices and
    // must never route a coordinate through the leader; the gather
    // protocol routes all of them and never touches the halo machinery.
    // Both must land on the same fixed point.
    let n = 90;
    let k = 3; // contiguous: Ω_0 = 0..30, Ω_1 = 30..60, Ω_2 = 60..90
    let g = power_law_web_graph(n, 5, 0.1, 23);
    // insert + reweight pairs: whichever of the two applies (the edge
    // may or may not exist in the random web graph), the source column
    // is certainly dirtied
    let batch = vec![
        Mutation::EdgeInsert {
            from: 5,
            to: 70,
            weight: 2.0,
        },
        Mutation::EdgeReweight {
            from: 5,
            to: 70,
            weight: 3.0,
        },
        Mutation::EdgeInsert {
            from: 35,
            to: 2,
            weight: 1.5,
        },
        Mutation::EdgeReweight {
            from: 35,
            to: 2,
            weight: 2.5,
        },
    ];
    let mut results: Vec<Vec<f64>> = Vec::new();
    for rebase in [RebaseMode::Local, RebaseMode::Gather] {
        let mg = MutableDigraph::from_digraph(&g, n);
        let cfg = base_cfg(n, k, 23).with_rebase(rebase);
        let mut eng = StreamingEngine::new(mg, 0.85, true, cfg).unwrap();
        eng.converge().unwrap();
        let report = eng.apply_batch(&batch).unwrap();
        assert!(report.solution.converged, "residual {:.3e}", report.solution.residual);
        let m = &report.solution.metrics;
        match rebase {
            RebaseMode::Local => {
                assert_eq!(
                    m["rebase_gather_coords"],
                    0,
                    "zero leader-side gather/scatter on the local path"
                );
                assert!(
                    m["halo_slices_sent"] >= 2,
                    "both cross-part dirty columns must ship halos: {m:?}"
                );
                assert!(m["halo_values_sent"] >= m["halo_slices_sent"]);
            }
            RebaseMode::Gather => {
                assert_eq!(
                    m["rebase_gather_coords"],
                    n as u64,
                    "gather routes every coordinate through the leader"
                );
                assert_eq!(m["halo_slices_sent"], 0, "no halo machinery on the gather path");
            }
        }
        results.push(report.solution.x.clone());
        eng.finish().unwrap();
    }
    let delta = dist1(&results[0], &results[1]);
    assert!(delta < 1e-7, "protocols disagree on the fixed point: Δ₁ = {delta:.3e}");
}

#[test]
fn local_rebase_with_mid_flight_handoff_and_latency() {
    // the property satellite's engine half: a leader-planned ownership
    // move is installed while the initial diffusion is mid-flight, then a
    // local-protocol epoch transition lands on top of it (the rebase must
    // quiesce the handoff, halo-exchange against the post-move cover, and
    // still reach the mutated graph's exact fixed point)
    let n = 120;
    let k = 3;
    let g = power_law_web_graph(n, 5, 0.1, 31);
    let mg = MutableDigraph::from_digraph(&g, n);
    let mut cfg = base_cfg(n, k, 31)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_rebase(RebaseMode::Local);
    cfg.latency = Some((Duration::from_micros(50), Duration::from_micros(400)));
    let mut eng = StreamingEngine::new(mg, 0.85, true, cfg).unwrap();
    // no converge(): the handoff and the epoch transition both hit a
    // computation that is still diffusing hard
    {
        let table = eng.pool_mut().table().clone();
        let part = table.partition();
        let own = part.part(0).to_vec();
        let next = part.transfer_elastic(&own[..own.len() / 2], 1).unwrap();
        assert!(table.install_elastic(next).is_some(), "install must land");
    }
    let mut stream = MutationStream::new(ChurnModel::RandomRewire, 61);
    let batch = stream.next_batch(eng.graph(), 12);
    let report = eng.apply_batch(&batch).unwrap();
    assert!(report.solution.converged, "residual {:.3e}", report.solution.residual);
    assert_eq!(report.solution.metrics["rebase_gather_coords"], 0);
    assert!(
        report.solution.metrics["handoffs_total"] >= 1,
        "the installed move must have shipped a handoff"
    );
    assert!(
        (norm1(&report.solution.x) - 1.0).abs() < 1e-7,
        "mass through handoff + local rebase: ‖x‖₁ = {}",
        norm1(&report.solution.x)
    );
    let want = cold_solution(eng.problem());
    assert!(
        dist1(&report.solution.x, &want) < 1e-7,
        "Δ₁ = {:.3e}",
        dist1(&report.solution.x, &want)
    );
    eng.finish().unwrap();
}
