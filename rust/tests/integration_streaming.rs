//! Integration: the streaming engine — a continuous mutation stream must
//! keep the distributed computation on the moving fixed point, matching a
//! cold solve of whatever matrix the stream ends on (the arXiv:1203.1715 /
//! 1301.3007 live-update regime, end to end).

use std::time::Duration;

use diter::coordinator::{v2, DistributedConfig, StreamingEngine};
use diter::graph::{power_law_web_graph, ChurnModel, MutableDigraph, Mutation, MutationStream};
use diter::linalg::vec_ops::{dist1, norm1};
use diter::partition::Partition;
use diter::solver::{DIteration, FixedPointProblem, SequenceKind, SolveOptions, Solver};

fn cold_solution(problem: &FixedPointProblem) -> Vec<f64> {
    let opts = SolveOptions {
        tol: 1e-13,
        max_cost: 200_000.0,
        trace_every: 0.0,
        exact: None,
    };
    DIteration::fluid_cyclic().solve(problem, &opts).unwrap().x
}

fn base_cfg(n: usize, k: usize, seed: u64) -> DistributedConfig {
    DistributedConfig::new(Partition::contiguous(n, k).unwrap())
        .with_tol(1e-10)
        .with_seed(seed)
}

#[test]
fn streamed_sequence_matches_cold_solve_on_final_matrix() {
    // the satellite acceptance property: after a seeded sequence of
    // mutation batches, the streamed solution equals (tolerance-bounded)
    // a cold solve of the final matrix
    let n = 300;
    let g = power_law_web_graph(n, 5, 0.1, 17);
    let mg = MutableDigraph::from_digraph(&g, n);
    let mut eng = StreamingEngine::new(mg, 0.85, true, base_cfg(n, 4, 17)).unwrap();
    eng.converge().unwrap();

    let mut stream = MutationStream::new(ChurnModel::RandomRewire, 99);
    for b in 0..5 {
        let batch = stream.next_batch(eng.graph(), 24);
        let report = eng.apply_batch(&batch).unwrap();
        assert!(
            report.solution.converged,
            "batch {b}: residual {:.3e}",
            report.solution.residual
        );
    }
    let want = cold_solution(eng.problem());
    let summary = eng.finish().unwrap();
    let delta = dist1(&summary.final_solution.x, &want);
    assert!(delta < 1e-7, "streamed vs cold Δ₁ = {delta:.3e}");
    assert_eq!(summary.epochs, 6, "initial solve + 5 batches");
}

#[test]
fn mid_flight_rebases_with_latency_and_coalescing() {
    // fluid conservation across the epoch boundary under the adversarial
    // transport settings: message latency keeps parcels in flight when
    // the first rebase lands (the engine must discard stale epochs and
    // hold future ones, never losing or inventing fluid)
    let n = 400;
    let g = power_law_web_graph(n, 5, 0.1, 3);
    let mg = MutableDigraph::from_digraph(&g, n);
    let mut cfg = base_cfg(n, 4, 3);
    cfg.latency = Some((Duration::from_micros(50), Duration::from_micros(400)));
    cfg.coalesce = diter::transport::CoalescePolicy {
        min_mass: 1e-5,
        max_entries: 64,
    };
    let mut eng = StreamingEngine::new(mg, 0.85, true, cfg).unwrap();
    // NO initial converge: the first batch rebases a mid-flight epoch 0
    let mut stream = MutationStream::new(ChurnModel::RandomRewire, 7);
    for _ in 0..3 {
        let batch = stream.next_batch(eng.graph(), 16);
        let report = eng.apply_batch(&batch).unwrap();
        assert!(
            report.solution.converged,
            "residual {:.3e}",
            report.solution.residual
        );
        // mass conservation: patched dangling makes x a probability vector
        assert!(
            (norm1(&report.solution.x) - 1.0).abs() < 1e-6,
            "‖x‖₁ = {}",
            norm1(&report.solution.x)
        );
    }
    let want = cold_solution(eng.problem());
    let got = eng.solution().unwrap();
    assert!(dist1(&got, &want) < 1e-7, "Δ₁ = {}", dist1(&got, &want));
    eng.finish().unwrap();
}

#[test]
fn growth_and_deactivation_renormalize_correctly() {
    // node adds (with re-normalization via fresh out-degrees) and node
    // removals must both land on the cold fixed point of the final graph
    let n = 200;
    let g = power_law_web_graph(150, 4, 0.1, 5); // 50 dormant coordinates
    let mg = MutableDigraph::from_digraph(&g, n);
    let mut eng = StreamingEngine::new(mg, 0.85, true, base_cfg(n, 3, 5)).unwrap();
    eng.converge().unwrap();

    let mut grow = MutationStream::new(ChurnModel::PreferentialGrowth { links_per_node: 3 }, 41);
    let batch = grow.next_batch(eng.graph(), 20);
    assert!(
        batch
            .iter()
            .any(|m| matches!(m, Mutation::NodeActivate { .. })),
        "growth model must activate dormant nodes"
    );
    let report = eng.apply_batch(&batch).unwrap();
    assert!(report.solution.converged);

    // deactivate a few pages and reweight an edge
    let mut batch2: Vec<Mutation> = (0..4)
        .map(|i| Mutation::NodeDeactivate { node: 10 + i })
        .collect();
    let snapshot = eng.graph().to_digraph();
    let reweight_to = *snapshot.out_neighbors(0).first().unwrap_or(&1);
    batch2.push(Mutation::EdgeReweight {
        from: 0,
        to: reweight_to,
        weight: 5.0,
    });
    let report = eng.apply_batch(&batch2).unwrap();
    assert!(report.solution.converged);

    let want = cold_solution(eng.problem());
    let summary = eng.finish().unwrap();
    let delta = dist1(&summary.final_solution.x, &want);
    assert!(delta < 1e-7, "Δ₁ = {delta:.3e}");
}

#[test]
fn hotspot_burst_shifts_rank_to_the_hot_page() {
    let n = 250;
    let g = power_law_web_graph(n, 5, 0.1, 19);
    let mg = MutableDigraph::from_digraph(&g, n);
    let mut eng = StreamingEngine::new(mg, 0.85, true, base_cfg(n, 4, 19)).unwrap();
    let before = eng.converge().unwrap().solution.x;

    // aim the burst at a concrete node so the rank shift is checkable
    let hot = 123usize;
    let batch: Vec<Mutation> = (0..60)
        .filter(|&s| s != hot)
        .map(|s| Mutation::EdgeInsert {
            from: s,
            to: hot,
            weight: 1.0,
        })
        .collect();
    let report = eng.apply_batch(&batch).unwrap();
    assert!(report.solution.converged);
    assert!(
        report.solution.x[hot] > before[hot] * 1.5,
        "hot page rank {} -> {} should jump",
        before[hot],
        report.solution.x[hot]
    );
    eng.finish().unwrap();
}

#[test]
fn warm_rebase_beats_cold_restart_in_updates() {
    // the headline economics: for small batches, reconvergence after a
    // warm rebase costs well under a cold V2 restart of the same matrix
    let n = 600;
    let g = power_law_web_graph(n, 6, 0.1, 29);
    let mg = MutableDigraph::from_digraph(&g, n);
    let cfg = base_cfg(n, 4, 29).with_sequence(SequenceKind::GreedyMaxFluid);
    let cold_cfg = cfg.clone();
    let mut eng = StreamingEngine::new(mg, 0.85, true, cfg).unwrap();
    eng.converge().unwrap();

    let mut stream = MutationStream::new(ChurnModel::RandomRewire, 47);
    let mut warm = 0u64;
    let mut cold = 0u64;
    for _ in 0..3 {
        let batch = stream.next_batch(eng.graph(), 10);
        let report = eng.apply_batch(&batch).unwrap();
        assert!(report.solution.converged);
        warm += report.solution.total_updates;
        let cold_sol = v2::solve_v2(eng.problem(), &cold_cfg).unwrap();
        assert!(cold_sol.converged);
        cold += cold_sol.total_updates;
    }
    eng.finish().unwrap();
    assert!(
        warm < cold,
        "warm rebases ({warm} updates) must beat cold restarts ({cold})"
    );
}
