//! The wire transport's zero-allocation claim, asserted with a counting
//! global allocator: once the frame arena, column pools, receive rings,
//! and send queues have reached their high-water marks, a steady-state
//! ping-pong of real SoA parcels over loopback TCP — encode, vectored
//! flush, read, in-place decode, commit, ACK, echo — must not touch the
//! heap at all (DESIGN.md §8.8).

use std::time::{Duration, Instant};

use diter::coordinator::WorkerMsg;
use diter::perf::CountingAlloc;
use diter::transport::{BusConfig, FlushPolicy, Received, Transport, WireEndpoint, WireHub};

// Counts every heap allocation this test binary makes; the test below
// asserts a zero per-thread delta across measured bounce rounds.
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

/// Parcels kept circulating between the two endpoints.
const PARCELS: usize = 8;
/// Coordinates per parcel — one fixed shape so every pooled column and
/// frame buffer is warmed by the priming rounds.
const COORDS: usize = 64;
/// Parcel hops during warm-up (grows every pool to its high-water mark).
const WARM_MOVES: usize = 2_000;
/// Parcel hops during the measured window.
const MEASURE_MOVES: usize = 500;

/// Drain everything ripe at `e`, commit it, and echo the payload back to
/// `dest` — the received columns flow straight back out through the
/// pooled encode, so storage circulates and nothing is dropped. Returns
/// the number of parcels moved.
fn bounce(e: &mut WireEndpoint<WorkerMsg>, dest: usize) -> usize {
    let mut moved = 0;
    while let Some(Received {
        from,
        seq,
        mass,
        payload,
    }) = e.try_recv_uncommitted()
    {
        e.commit(from, seq, mass);
        Transport::send(e, dest, payload, mass, COORDS).expect("echo");
        moved += 1;
    }
    e.flush();
    e.collect_acks();
    moved
}

#[test]
fn wire_loopback_steady_state_is_allocation_free() {
    let cfg = BusConfig {
        flush: FlushPolicy {
            max_bytes: 1 << 20,
            max_frames: 4,
            deadline: Duration::from_micros(200),
        },
        ..BusConfig::default()
    };
    let hub = WireHub::<WorkerMsg>::loopback(&cfg, &[]);
    let mut a = hub.add_endpoint(0).expect("endpoint 0");
    let mut b = hub.add_endpoint(1).expect("endpoint 1");

    // prime the fabric: PARCELS fluid parcels a → b, all the same shape
    for s in 0..PARCELS {
        let coords: Vec<u32> = (0..COORDS as u32).map(|i| i * 3 + s as u32).collect();
        let mass: Vec<f64> = (0..COORDS).map(|i| 1.0 / (COORDS * (i + 1)) as f64).collect();
        let parcel = WorkerMsg::Fluid {
            epoch: 1,
            coords,
            mass,
            qids: vec![],
        };
        Transport::send(&mut a, 1, parcel, 1.0, COORDS).expect("prime send");
    }
    a.flush();

    // warm-up: every buffer in the cycle reaches its final capacity —
    // frame buffers grow to the parcel frame size (ACK-sized buffers
    // returned to the arena get regrown once each), column pools fill,
    // receive rings hit their high-water marks
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut warmed = 0;
    while warmed < WARM_MOVES {
        let m = bounce(&mut a, 1) + bounce(&mut b, 0);
        warmed += m;
        if m == 0 {
            assert!(
                Instant::now() < deadline,
                "warm-up stalled after {warmed} parcel hops"
            );
            std::thread::yield_now();
        }
    }

    // measured window: the same traffic, zero heap allocations
    let a0 = CountingAlloc::thread_allocations();
    let mut moved = 0;
    let deadline = Instant::now() + Duration::from_secs(30);
    while moved < MEASURE_MOVES {
        let m = bounce(&mut a, 1) + bounce(&mut b, 0);
        moved += m;
        if m == 0 {
            assert!(
                Instant::now() < deadline,
                "measured window stalled after {moved} parcel hops"
            );
            std::thread::yield_now();
        }
    }
    let allocs = CountingAlloc::thread_allocations() - a0;
    assert!(
        moved >= MEASURE_MOVES,
        "only {moved} parcels crossed the wire in the measured window"
    );
    assert_eq!(
        allocs, 0,
        "steady-state wire traffic allocated {allocs} times over {moved} \
         parcel hops; the pooled encode/decode cycle must not touch the \
         allocator"
    );

    // the batching fast path actually engaged: vectored writes carried
    // multiple frames per syscall
    let metrics = a.metrics();
    assert!(metrics.get("wire_writev_calls") > 0, "no vectored writes");
    assert!(
        metrics.get("wire_frames_per_write") >= 2,
        "writev batching never packed ≥2 frames into one syscall"
    );
    // every bounce re-sends immediately after committing, so at any
    // rest point the whole primed mass (PARCELS parcels of 1.0 each) is
    // in flight — and both endpoints must read it off the one shared
    // loopback account (split accounts would each hold only that
    // endpoint's sends minus its commits, nowhere near the total)
    for (name, inflight) in [("a", a.global_inflight()), ("b", b.global_inflight())] {
        assert!(
            (inflight - PARCELS as f64).abs() < 1e-9,
            "endpoint {name} reads an in-flight account of {inflight}; the shared \
             loopback account must hold exactly the {PARCELS} circulating parcels"
        );
    }
}
