//! Integration: all sequential solvers agree with each other, with the
//! direct LU oracle, and with the paper's worked examples.

use diter::graph::{
    block_coupled_matrix, pagerank_reference, pagerank_system, paper_matrix,
    power_law_web_graph,
};
use diter::linalg::vec_ops::{dist1, dist_inf, norm1};
use diter::linalg::DenseMat;
use diter::solver::{
    ConvergenceBound, DIteration, DIterationVariant, FixedPointProblem, GaussSeidel, Jacobi,
    PowerIteration, SequenceKind, SolveOptions, Solver, Sor,
};
use diter::sparse::{diag_eliminate, CsrMatrix, SparseMatrix};

fn all_solvers() -> Vec<Box<dyn Solver>> {
    vec![
        Box::new(Jacobi::new()),
        Box::new(GaussSeidel::new()),
        Box::new(Sor::new(0.9)),
        Box::new(DIteration::cyclic()),
        Box::new(DIteration::fluid_cyclic()),
        Box::new(DIteration::greedy()),
        Box::new(DIteration {
            sequence: SequenceKind::Random,
            variant: DIterationVariant::HForm,
            seed: 3,
        }),
    ]
}

#[test]
fn every_solver_agrees_with_lu_on_paper_matrices() {
    for which in 1..=4u8 {
        let problem =
            FixedPointProblem::from_linear_system(&paper_matrix(which), &[1.0; 4]).unwrap();
        let exact = problem.exact_solution().unwrap();
        for solver in all_solvers() {
            let sol = solver.solve(&problem, &SolveOptions::default()).unwrap();
            assert!(sol.converged, "A({which}) / {}", solver.name());
            assert!(
                dist_inf(&sol.x, &exact) < 1e-9,
                "A({which}) / {}: dist {}",
                solver.name(),
                dist_inf(&sol.x, &exact)
            );
        }
    }
}

#[test]
fn every_solver_agrees_on_random_block_systems() {
    for seed in [1u64, 2, 3] {
        let p = block_coupled_matrix(48, 4, 0.45, 0.2, 4, seed);
        let problem =
            FixedPointProblem::new(SparseMatrix::from_csr(p), vec![1.0; 48]).unwrap();
        let exact = problem.exact_solution().unwrap();
        for solver in all_solvers() {
            let opts = SolveOptions {
                tol: 1e-11,
                max_cost: 100_000.0,
                trace_every: 0.0,
                exact: None,
            };
            let sol = solver.solve(&problem, &opts).unwrap();
            assert!(sol.converged, "seed {seed} / {}", solver.name());
            assert!(
                dist_inf(&sol.x, &exact) < 1e-8,
                "seed {seed} / {}",
                solver.name()
            );
        }
    }
}

#[test]
fn diteration_beats_jacobi_cost_on_every_paper_matrix() {
    // the headline qualitative claim of Fig 1–3: D-iteration ≤ GS < Jacobi
    for which in 1..=3u8 {
        let problem =
            FixedPointProblem::from_linear_system(&paper_matrix(which), &[1.0; 4]).unwrap();
        let opts = SolveOptions {
            tol: 1e-10,
            ..Default::default()
        };
        let di = DIteration::cyclic().solve(&problem, &opts).unwrap();
        let gs = GaussSeidel::new().solve(&problem, &opts).unwrap();
        let ja = Jacobi::new().solve(&problem, &opts).unwrap();
        assert!(di.cost <= gs.cost, "A({which})");
        assert!(gs.cost < ja.cost, "A({which})");
    }
}

#[test]
fn greedy_no_worse_than_cyclic_on_skewed_fluid() {
    // a system where one coordinate dominates the fluid: greedy should not
    // lose (in updates) vs cyclic
    let mut m = DenseMat::zeros(16, 16);
    for i in 1..16 {
        m[(i, 0)] = 0.45; // everything depends on coordinate 0
        m[(0, i)] = 0.02;
    }
    let problem = FixedPointProblem::new(SparseMatrix::from_dense(&m), vec![1.0; 16]).unwrap();
    let opts = SolveOptions {
        tol: 1e-11,
        max_cost: 10_000.0,
        trace_every: 0.0,
        exact: None,
    };
    let greedy = DIteration::greedy().solve(&problem, &opts).unwrap();
    let cyclic = DIteration::fluid_cyclic().solve(&problem, &opts).unwrap();
    assert!(greedy.converged && cyclic.converged);
    assert!(greedy.cost <= cyclic.cost * 1.5);
}

#[test]
fn pagerank_diteration_matches_power_iteration() {
    let g = power_law_web_graph(800, 6, 0.12, 21);
    let sys = pagerank_system(&g, 0.85, true).unwrap();
    let problem = FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap();
    let di = DIteration::fluid_cyclic()
        .solve(
            &problem,
            &SolveOptions {
                tol: 1e-13,
                max_cost: 10_000.0,
                trace_every: 0.0,
                exact: None,
            },
        )
        .unwrap();
    assert!(di.converged);
    let pr = pagerank_reference(&sys, 1e-14, 20_000);
    assert!(dist1(&di.x, &pr) < 1e-9);
    assert!((norm1(&di.x) - 1.0).abs() < 1e-9, "probability mass");

    // eigenvector route (§1's Q.X = X): power iteration on d·S̄ runs fine
    let power = PowerIteration::default().run(&sys.matrix, None, None);
    assert!(power.is_ok());
}

#[test]
fn convergence_bound_is_sound_during_solve() {
    let g = power_law_web_graph(300, 5, 0.1, 5);
    let sys = pagerank_system(&g, 0.85, true).unwrap();
    let problem = FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap();
    let exact = DIteration::fluid_cyclic()
        .solve(
            &problem,
            &SolveOptions {
                tol: 1e-15,
                max_cost: 100_000.0,
                trace_every: 0.0,
                exact: None,
            },
        )
        .unwrap()
        .x;
    let bound = ConvergenceBound::for_matrix(problem.matrix(), Some(0.85));
    for budget in [2.0, 4.0, 8.0] {
        let sol = DIteration::cyclic()
            .solve(
                &problem,
                &SolveOptions {
                    tol: 0.0,
                    max_cost: budget,
                    trace_every: 0.0,
                    exact: None,
                },
            )
            .unwrap();
        let d = dist1(&sol.x, &exact);
        let bd = bound.distance(problem.residual_norm(&sol.x));
        assert!(d <= bd * (1.0 + 1e-9), "budget {budget}: {d} > {bd}");
    }
}

#[test]
fn diag_elimination_then_solve_matches_original() {
    // build a system WITH diagonal entries, eliminate, solve, compare
    let m = DenseMat::from_rows(&[
        &[0.3, 0.2, 0.1],
        &[0.05, 0.4, 0.1],
        &[0.1, 0.1, 0.2],
    ]);
    let b = vec![1.0, -2.0, 0.5];
    let original = FixedPointProblem::new(SparseMatrix::from_dense(&m), b.clone()).unwrap();
    let exact = original.exact_solution().unwrap();

    let elim = diag_eliminate(&CsrMatrix::from_dense(&m)).unwrap();
    let b2: Vec<f64> = b.iter().zip(&elim.scale).map(|(x, s)| x * s).collect();
    let transformed =
        FixedPointProblem::new(SparseMatrix::from_csr(elim.matrix.clone()), b2).unwrap();
    let sol = DIteration::cyclic()
        .solve(&transformed, &SolveOptions::default())
        .unwrap();
    assert!(sol.converged);
    assert!(dist_inf(&sol.x, &exact) < 1e-10);
}

#[test]
fn traces_record_error_against_exact() {
    let problem = FixedPointProblem::from_linear_system(&paper_matrix(2), &[1.0; 4]).unwrap();
    let exact = problem.exact_solution().unwrap();
    let opts = SolveOptions {
        exact: Some(exact.clone()),
        tol: 1e-12,
        ..Default::default()
    };
    let sol = DIteration::cyclic().solve(&problem, &opts).unwrap();
    assert!(sol.trace.points.len() > 3);
    // final trace point ≈ final true distance
    let last = sol.trace.points.last().unwrap();
    assert!((last.error - dist1(&sol.x, &exact)).abs() < 1e-12);
    // the time-to-tolerance helper works
    assert!(sol.trace.cost_to_reach(1e-6).is_some());
}
