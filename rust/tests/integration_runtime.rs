//! Integration: the PJRT runtime executing the AOT artifacts vs the pure
//! rust reference implementations — the cross-layer correctness signal
//! (L1 Pallas kernel ≡ L2 jax graph ≡ L3 rust oracle).
//!
//! Gated on `artifacts/manifest.txt` (produced by `make artifacts`); every
//! test no-ops with a notice when artifacts are absent so plain
//! `cargo test` stays green.

use diter::graph::paper_matrix;
use diter::linalg::vec_ops::{dist1, norm1};
use diter::prng::Xoshiro256pp;
use diter::runtime::{DenseAccelerator, Runtime};
use diter::solver::FixedPointProblem;

fn runtime_or_skip() -> Option<Runtime> {
    if !Runtime::artifacts_available() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load_default().expect("runtime loads"))
}

fn a1_problem() -> FixedPointProblem {
    FixedPointProblem::from_linear_system(&paper_matrix(1), &[1.0; 4]).unwrap()
}

/// rust oracle for the sweep the kernel implements.
fn sweep_ref(p_rows: &[f64], idx: &[i32], h: &[f64], b: &[f64], n: usize) -> Vec<f64> {
    let mut out = h.to_vec();
    for (t, &i) in idx.iter().enumerate() {
        let row = &p_rows[t * n..(t + 1) * n];
        let dot: f64 = row.iter().zip(out.iter()).map(|(a, b)| a * b).sum();
        out[i as usize] = dot + b[t];
    }
    out
}

#[test]
fn manifest_lists_every_program_kind() {
    let Some(rt) = runtime_or_skip() else { return };
    for kind in [
        "d_sweep",
        "d_round",
        "fluid_norm",
        "jacobi_step",
        "power_step",
        "pagerank_step",
    ] {
        assert!(
            !rt.manifest().shapes_of(kind).is_empty(),
            "missing artifacts for {kind}"
        );
    }
}

#[test]
fn d_sweep_2x4_matches_rust_oracle() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let problem = a1_problem();
    let owned = [0usize, 1];
    let p_rows = problem.matrix().csr().dense_row_block(&owned);
    let idx = [0i32, 1];
    let h = problem.b().to_vec();
    let b: Vec<f64> = owned.iter().map(|&i| problem.b()[i]).collect();
    let got = rt.d_sweep(2, 4, &p_rows, &idx, &h, &b).unwrap();
    let want = sweep_ref(&p_rows, &idx, &h, &b, 4);
    assert!(dist1(&got, &want) < 1e-13, "Δ = {}", dist1(&got, &want));
}

#[test]
fn d_sweep_random_shapes_match_oracle() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = Xoshiro256pp::seed_from_u64(99);
    for &(m, n) in &[(4usize, 4usize), (32, 128), (64, 256)] {
        if rt.manifest().find("d_sweep", &[m, n]).is_none() {
            continue;
        }
        let p_rows: Vec<f64> = (0..m * n).map(|_| rng.uniform(-0.01, 0.01)).collect();
        let idx: Vec<i32> = rng
            .sample_distinct(n, m)
            .into_iter()
            .map(|i| i as i32)
            .collect();
        let h: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let b: Vec<f64> = (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let got = rt.d_sweep(m, n, &p_rows, &idx, &h, &b).unwrap();
        let want = sweep_ref(&p_rows, &idx, &h, &b, n);
        assert!(
            dist1(&got, &want) < 1e-10,
            "shape {m}x{n}: Δ = {}",
            dist1(&got, &want)
        );
    }
}

#[test]
fn d_round_is_two_sweeps_plus_fluid() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let problem = a1_problem();
    let owned = [2usize, 3];
    let p_rows = problem.matrix().csr().dense_row_block(&owned);
    let idx = [2i32, 3];
    let h = problem.b().to_vec();
    let b: Vec<f64> = owned.iter().map(|&i| problem.b()[i]).collect();
    let (h2, fluid, rk) = rt.d_round(2, 4, &p_rows, &idx, &h, &b).unwrap();
    // rust: two sequential sweeps
    let want_h = sweep_ref(&p_rows, &idx, &sweep_ref(&p_rows, &idx, &h, &b, 4), &b, 4);
    assert!(dist1(&h2, &want_h) < 1e-13);
    // fluid = P_rows·H + B − H[idx]
    for (t, &i) in owned.iter().enumerate() {
        let row = &p_rows[t * 4..(t + 1) * 4];
        let dot: f64 = row.iter().zip(&h2).map(|(a, b)| a * b).sum();
        let want_f = dot + b[t] - h2[i];
        assert!((fluid[t] - want_f).abs() < 1e-13);
    }
    assert!((rk - norm1(&fluid)).abs() < 1e-13);
}

#[test]
fn jacobi_step_matches_rust() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let problem = a1_problem();
    let p = problem.matrix().csr().to_dense();
    let h = vec![0.1, 0.2, 0.3, 0.4];
    let got = rt
        .jacobi_step(4, p.data(), &h, problem.b())
        .unwrap();
    let mut want = problem.matrix().csr().matvec(&h).unwrap();
    for i in 0..4 {
        want[i] += problem.b()[i];
    }
    assert!(dist1(&got, &want) < 1e-13);
}

#[test]
fn fluid_norm_matches_residual() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let problem = a1_problem();
    let p = problem.matrix().csr().to_dense();
    let h = vec![0.3, 0.1, 0.2, 0.5];
    let got = rt.fluid_norm(4, p.data(), &h, problem.b()).unwrap();
    let want = problem.residual_norm(&h);
    assert!((got - want).abs() < 1e-13);
}

#[test]
fn power_step_normalizes() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = Xoshiro256pp::seed_from_u64(4);
    let p: Vec<f64> = (0..16).map(|_| rng.uniform(0.0, 1.0)).collect();
    let x = vec![0.25; 4];
    let got = rt.power_step(4, &p, &x).unwrap();
    assert!((norm1(&got) - 1.0).abs() < 1e-12);
}

#[test]
fn pagerank_step_conserves_mass() {
    let Some(mut rt) = runtime_or_skip() else { return };
    if rt.manifest().find("pagerank_step", &[256]).is_none() {
        return;
    }
    let n = 256;
    let mut rng = Xoshiro256pp::seed_from_u64(8);
    // column-stochastic dense S
    let mut s = vec![0.0f64; n * n];
    for j in 0..n {
        let mut col: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 1.0)).collect();
        let sum: f64 = col.iter().sum();
        for v in col.iter_mut() {
            *v /= sum;
        }
        for i in 0..n {
            s[i * n + j] = col[i];
        }
    }
    let x = vec![1.0 / n as f64; n];
    let tp = vec![1.0 / n as f64; n];
    let got = rt.pagerank_step(n, &s, &x, &tp, 0.85).unwrap();
    assert!((got.iter().sum::<f64>() - 1.0).abs() < 1e-10);
}

#[test]
fn dense_accelerator_full_solve_on_a1() {
    // end-to-end: iterate the PJRT d_round program to convergence and
    // compare with the LU oracle — the whole three-layer stack agrees.
    let Some(mut rt) = runtime_or_skip() else { return };
    let problem = a1_problem();
    if rt.manifest().find("d_round", &[2, 4]).is_none() {
        return;
    }
    let exact = problem.exact_solution().unwrap();
    let acc0 = DenseAccelerator::prepare(&rt, &problem, &[0, 1]).unwrap();
    let acc1 = DenseAccelerator::prepare(&rt, &problem, &[2, 3]).unwrap();
    let mut h = problem.b().to_vec();
    for _ in 0..60 {
        // lockstep 2-PID protocol on the PJRT path: each accelerator
        // updates its block (full-H view), then slices merge
        let (h_a, _f, _r) = acc0.round(&mut rt, &h).unwrap();
        let (h_b, _f, _r) = acc1.round(&mut rt, &h).unwrap();
        h[0] = h_a[0];
        h[1] = h_a[1];
        h[2] = h_b[2];
        h[3] = h_b[3];
    }
    assert!(dist1(&h, &exact) < 1e-12, "Δ = {}", dist1(&h, &exact));
}

#[test]
fn accelerator_shape_mismatch_is_reported() {
    let Some(rt) = runtime_or_skip() else { return };
    let problem = a1_problem();
    // 3-row block has no compiled artifact
    let err = DenseAccelerator::prepare(&rt, &problem, &[0, 1, 2]);
    assert!(err.is_err());
}
