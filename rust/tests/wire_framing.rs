//! Framing-layer coverage for the TCP wire transport (DESIGN.md §8):
//! encode ≡ decode over seeded-random [`WorkerMsg`] payloads (including
//! the empty and max-entry edge parcels), a rejection sweep proving a
//! truncated or mutated frame can never decode into a plausible
//! message, and a loopback-TCP round trip of real worker traffic
//! through the [`WireHub`] behind the [`Transport`] trait.

use diter::coordinator::{Handoff, WorkerMsg};
use diter::prng::Xoshiro256pp;
use diter::transport::{BusConfig, Transport, WireCodec, WireHub};

/// Ascending, distinct coordinates — the shape coalesced parcels have
/// on the real send path (the codec itself accepts any order).
fn random_coords(rng: &mut Xoshiro256pp, space: usize, count: usize) -> Vec<u32> {
    let mut coords = rng.sample_distinct(space, count);
    coords.sort_unstable();
    coords.into_iter().map(|c| c as u32).collect()
}

fn random_masses(rng: &mut Xoshiro256pp, count: usize) -> Vec<f64> {
    (0..count)
        .map(|_| {
            // span the magnitudes the diffusion actually produces,
            // sub-denormal tails included
            let exp = rng.uniform(-320.0, 2.0);
            let sign = if rng.chance(0.5) { 1.0 } else { -1.0 };
            sign * 10f64.powf(exp)
        })
        .collect()
}

fn random_msg(rng: &mut Xoshiro256pp) -> WorkerMsg {
    let count = match rng.below(4) {
        0 => 0,                     // empty parcel
        1 => 4096,                  // max coalesced entries and then some
        _ => rng.range(1, 64),
    };
    let space = (count * 3).max(8);
    match rng.below(3) {
        0 => WorkerMsg::Fluid {
            epoch: rng.next_u64() >> 20,
            coords: random_coords(rng, space, count),
            mass: random_masses(rng, count),
        },
        1 => WorkerMsg::Handoff(Handoff {
            pid_from: rng.below(64),
            pid_to: rng.below(64),
            version: rng.next_u64() >> 32,
            epoch: rng.next_u64() >> 32,
            coords: random_coords(rng, space, count)
                .into_iter()
                .map(|c| c as usize)
                .collect(),
            h_slice: random_masses(rng, count),
            b_slice: random_masses(rng, count),
            f_slice: random_masses(rng, count),
        }),
        _ => WorkerMsg::HaloSlice {
            epoch: rng.next_u64() >> 20,
            coords: random_coords(rng, space, count),
            h: random_masses(rng, count),
        },
    }
}

#[test]
fn worker_msg_round_trips_exactly() {
    let mut rng = Xoshiro256pp::seed_from_u64(0x0D17_E001);
    for case in 0..200 {
        let msg = random_msg(&mut rng);
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        let back = WorkerMsg::decode(&buf)
            .unwrap_or_else(|e| panic!("case {case}: decode failed: {e}"));
        assert_eq!(back, msg, "case {case}");
    }
}

/// Every strict prefix of a valid frame must be rejected — a partial
/// read can never surface as a smaller-but-valid message — and no
/// truncation may panic or abort.
#[test]
fn truncated_frames_never_decode() {
    let mut rng = Xoshiro256pp::seed_from_u64(0x0D17_E002);
    for _ in 0..20 {
        let msg = random_msg(&mut rng);
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        for cut in 0..buf.len() {
            assert!(
                WorkerMsg::decode(&buf[..cut]).is_err(),
                "prefix of length {cut}/{} decoded",
                buf.len()
            );
        }
        let mut longer = buf.clone();
        longer.push(0);
        assert!(longer.len() == buf.len() + 1 && WorkerMsg::decode(&longer).is_err());
    }
}

/// Single-byte corruption must either decode to *some* message (bit
/// flips in a mass column are indistinguishable from data) or fail
/// cleanly — it must never panic. Count and tag bytes additionally get
/// a targeted check that inflated counts are caught before allocation.
#[test]
fn corrupt_frames_fail_cleanly() {
    let mut rng = Xoshiro256pp::seed_from_u64(0x0D17_E003);
    for _ in 0..20 {
        let msg = random_msg(&mut rng);
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        for _ in 0..64 {
            let mut bad = buf.clone();
            let at = rng.below(bad.len());
            bad[at] ^= 1 << rng.below(8);
            let _ = WorkerMsg::decode(&bad); // must not panic
        }
    }
    // a count field claiming more entries than the frame holds
    let msg = WorkerMsg::Fluid {
        epoch: 1,
        coords: vec![2, 3],
        mass: vec![0.5, 0.25],
    };
    let mut buf = Vec::new();
    msg.encode(&mut buf);
    buf[2] = 0x7F; // count varint: claim 127 entries in a 2-entry frame
    assert!(WorkerMsg::decode(&buf).is_err());
}

/// Real worker traffic over a real socket: a fluid parcel and a handoff
/// cross the loopback wire through the [`Transport`] face, arrive
/// intact, and the shared account returns to zero once committed and
/// acked — the invariant the conservation monitor rests on.
#[test]
fn loopback_tcp_round_trip_conserves_accounting() {
    let hub = WireHub::<WorkerMsg>::loopback(&BusConfig::default(), &[]);
    let mut a = hub.add_endpoint(0).expect("endpoint 0");
    let mut b = hub.add_endpoint(1).expect("endpoint 1");
    let (a, b) = (&mut a as &mut dyn Transport<WorkerMsg>, &mut b);

    let parcel = WorkerMsg::Fluid {
        epoch: 2,
        coords: vec![7, 9, 10],
        mass: vec![0.5, 0.25, 0.25],
    };
    a.send(1, parcel.clone(), 1.0, 64).expect("send parcel");
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    let got = loop {
        if let Some(r) = b.try_recv_uncommitted() {
            break r;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "parcel never arrived over loopback TCP"
        );
        std::thread::yield_now();
    };
    assert_eq!(got.payload, parcel);
    assert_eq!(got.from, 0);
    assert!((got.mass - 1.0).abs() < 1e-15);
    assert!(
        a.global_inflight() >= 1.0,
        "mass must stay on the account until committed"
    );

    b.commit(got.from, got.seq, got.mass);
    assert_eq!(
        b.global_inflight(),
        0.0,
        "loopback commit settles the shared account"
    );
    // the ACK flows back and releases the sender's retention
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    loop {
        a.collect_acks();
        if a.unacked() == 0 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "ACK never released the retained parcel"
        );
        std::thread::yield_now();
    }
}
