//! Framing-layer coverage for the TCP wire transport (DESIGN.md §8):
//! encode ≡ decode over seeded-random [`WorkerMsg`] payloads (including
//! the empty and max-entry edge parcels), a rejection sweep proving a
//! truncated or mutated frame can never decode into a plausible
//! message, and a loopback-TCP round trip of real worker traffic
//! through the [`WireHub`] behind the [`Transport`] trait.

use diter::coordinator::{Handoff, WorkerMsg};
use diter::prng::Xoshiro256pp;
use diter::transport::wire::{
    encode_msg_frame, read_f64, read_varint, write_f64, write_varint, KIND_MSG, MAX_FRAME,
};
use diter::transport::{BusConfig, ColumnPools, Transport, WireCodec, WireHub};

/// Ascending, distinct coordinates — the shape coalesced parcels have
/// on the real send path (the codec itself accepts any order).
fn random_coords(rng: &mut Xoshiro256pp, space: usize, count: usize) -> Vec<u32> {
    let mut coords = rng.sample_distinct(space, count);
    coords.sort_unstable();
    coords.into_iter().map(|c| c as u32).collect()
}

fn random_masses(rng: &mut Xoshiro256pp, count: usize) -> Vec<f64> {
    (0..count)
        .map(|_| {
            // span the magnitudes the diffusion actually produces,
            // sub-denormal tails included
            let exp = rng.uniform(-320.0, 2.0);
            let sign = if rng.chance(0.5) { 1.0 } else { -1.0 };
            sign * 10f64.powf(exp)
        })
        .collect()
}

fn random_msg(rng: &mut Xoshiro256pp) -> WorkerMsg {
    let count = match rng.below(4) {
        0 => 0,                     // empty parcel
        1 => 4096,                  // max coalesced entries and then some
        _ => rng.range(1, 64),
    };
    let space = (count * 3).max(8);
    match rng.below(3) {
        0 => WorkerMsg::Fluid {
            epoch: rng.next_u64() >> 20,
            coords: random_coords(rng, space, count),
            mass: random_masses(rng, count),
            // half the corpus carries a query-id column (tag 0x13)
            qids: if rng.below(2) == 0 {
                Vec::new()
            } else {
                random_coords(rng, 1 << 16, count)
            },
        },
        1 => {
            // lanes > 1 exercises the lane-blocked 0x14 layout
            let lanes = rng.range(1, 4);
            WorkerMsg::Handoff(Handoff {
                pid_from: rng.below(64),
                pid_to: rng.below(64),
                version: rng.next_u64() >> 32,
                epoch: rng.next_u64() >> 32,
                coords: random_coords(rng, space, count)
                    .into_iter()
                    .map(|c| c as usize)
                    .collect(),
                h_slice: random_masses(rng, count * lanes),
                b_slice: random_masses(rng, count),
                f_slice: random_masses(rng, count * lanes),
            })
        }
        _ => {
            let lanes = rng.range(1, 4);
            WorkerMsg::HaloSlice {
                epoch: rng.next_u64() >> 20,
                coords: random_coords(rng, space, count),
                h: random_masses(rng, count * lanes),
            }
        }
    }
}

#[test]
fn worker_msg_round_trips_exactly() {
    let mut rng = Xoshiro256pp::seed_from_u64(0x0D17_E001);
    for case in 0..200 {
        let msg = random_msg(&mut rng);
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        let back = WorkerMsg::decode(&buf)
            .unwrap_or_else(|e| panic!("case {case}: decode failed: {e}"));
        assert_eq!(back, msg, "case {case}");
    }
}

/// Every strict prefix of a valid frame must be rejected — a partial
/// read can never surface as a smaller-but-valid message — and no
/// truncation may panic or abort.
#[test]
fn truncated_frames_never_decode() {
    let mut rng = Xoshiro256pp::seed_from_u64(0x0D17_E002);
    for _ in 0..20 {
        let msg = random_msg(&mut rng);
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        for cut in 0..buf.len() {
            assert!(
                WorkerMsg::decode(&buf[..cut]).is_err(),
                "prefix of length {cut}/{} decoded",
                buf.len()
            );
        }
        let mut longer = buf.clone();
        longer.push(0);
        assert!(longer.len() == buf.len() + 1 && WorkerMsg::decode(&longer).is_err());
    }
}

/// Single-byte corruption must either decode to *some* message (bit
/// flips in a mass column are indistinguishable from data) or fail
/// cleanly — it must never panic. Count and tag bytes additionally get
/// a targeted check that inflated counts are caught before allocation.
#[test]
fn corrupt_frames_fail_cleanly() {
    let mut rng = Xoshiro256pp::seed_from_u64(0x0D17_E003);
    for _ in 0..20 {
        let msg = random_msg(&mut rng);
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        for _ in 0..64 {
            let mut bad = buf.clone();
            let at = rng.below(bad.len());
            bad[at] ^= 1 << rng.below(8);
            let _ = WorkerMsg::decode(&bad); // must not panic
        }
    }
    // a count field claiming more entries than the frame holds
    let msg = WorkerMsg::Fluid {
        epoch: 1,
        coords: vec![2, 3],
        mass: vec![0.5, 0.25],
        qids: vec![],
    };
    let mut buf = Vec::new();
    msg.encode(&mut buf);
    buf[2] = 0x7F; // count varint: claim 127 entries in a 2-entry frame
    assert!(WorkerMsg::decode(&buf).is_err());
}

/// Real worker traffic over a real socket: a fluid parcel and a handoff
/// cross the loopback wire through the [`Transport`] face, arrive
/// intact, and the shared account returns to zero once committed and
/// acked — the invariant the conservation monitor rests on.
#[test]
fn loopback_tcp_round_trip_conserves_accounting() {
    let hub = WireHub::<WorkerMsg>::loopback(&BusConfig::default(), &[]);
    let mut a = hub.add_endpoint(0).expect("endpoint 0");
    let mut b = hub.add_endpoint(1).expect("endpoint 1");
    let (a, b) = (&mut a as &mut dyn Transport<WorkerMsg>, &mut b);

    let parcel = WorkerMsg::Fluid {
        epoch: 2,
        coords: vec![7, 9, 10],
        mass: vec![0.5, 0.25, 0.25],
        qids: vec![],
    };
    a.send(1, parcel.clone(), 1.0, 64).expect("send parcel");
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    let got = loop {
        // sends are deferred under the flush policy: the sender has to
        // keep being pumped for its deadline flush to fire
        a.collect_acks();
        if let Some(r) = b.try_recv_uncommitted() {
            break r;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "parcel never arrived over loopback TCP"
        );
        std::thread::yield_now();
    };
    assert_eq!(got.payload, parcel);
    assert_eq!(got.from, 0);
    assert!((got.mass - 1.0).abs() < 1e-15);
    assert!(
        a.global_inflight() >= 1.0,
        "mass must stay on the account until committed"
    );

    b.commit(got.from, got.seq, got.mass);
    assert_eq!(
        b.global_inflight(),
        0.0,
        "loopback commit settles the shared account"
    );
    // the ACK flows back and releases the sender's retention; the
    // receiver has to be pumped too so its queued ACK frame flushes
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    loop {
        b.collect_acks();
        a.collect_acks();
        if a.unacked() == 0 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "ACK never released the retained parcel"
        );
        std::thread::yield_now();
    }
}

/// The pooled in-place frame encode (length prefix reserved up front and
/// patched after the body lands) must be byte-identical to the PR 6
/// shape — encode the body into its own `Vec`, then prepend the length —
/// across the full seeded corpus, including when the frame buffer is a
/// dirty recycled one.
#[test]
fn pooled_frame_encode_is_byte_identical_to_vec_encode() {
    let mut rng = Xoshiro256pp::seed_from_u64(0x0D17_E001);
    let mut frame = vec![0xEEu8; 37]; // stale bytes from a previous frame
    for case in 0..200 {
        let msg = random_msg(&mut rng);
        let seq = rng.next_u64() >> 16;
        let mass = rng.uniform(-2.0, 2.0);

        let mut body = vec![KIND_MSG];
        write_varint(&mut body, seq);
        write_f64(&mut body, mass);
        msg.encode(&mut body);
        let mut expect = (body.len() as u32).to_le_bytes().to_vec();
        expect.extend_from_slice(&body);

        encode_msg_frame(&mut frame, seq, mass, &msg);
        assert_eq!(frame, expect, "case {case}: pooled encode diverged");
    }
}

/// The pooled column decode must accept exactly what the plain decode
/// accepts and produce equal messages, with the column vectors cycling
/// through the pools between cases.
#[test]
fn pooled_decode_matches_plain_decode_over_corpus() {
    let mut rng = Xoshiro256pp::seed_from_u64(0x0D17_E001);
    let mut pools = ColumnPools::new(8);
    for case in 0..200 {
        let msg = random_msg(&mut rng);
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        let plain = WorkerMsg::decode(&buf)
            .unwrap_or_else(|e| panic!("case {case}: plain decode failed: {e}"));
        let pooled = WorkerMsg::decode_pooled(&buf, &mut pools)
            .unwrap_or_else(|e| panic!("case {case}: pooled decode failed: {e}"));
        assert_eq!(pooled, plain, "case {case}");
        pooled.reclaim(&mut pools);
    }
}

/// Concatenate `msgs` into one `writev`-style buffer of back-to-back
/// frames, the exact byte stream a batched flush puts on the socket.
fn concat_frames(msgs: &[(u64, f64, WorkerMsg)]) -> Vec<u8> {
    let mut blob = Vec::new();
    let mut frame = Vec::new();
    for (seq, mass, msg) in msgs {
        encode_msg_frame(&mut frame, *seq, *mass, msg);
        blob.extend_from_slice(&frame);
    }
    blob
}

/// Byte offsets where each frame in `blob` ends (cumulative), plus a
/// leading 0 — the oracle for which frames are wholly inside a prefix.
fn frame_bounds(blob: &[u8]) -> Vec<usize> {
    let mut bounds = vec![0usize];
    let mut pos = 0;
    while pos < blob.len() {
        let len = u32::from_le_bytes(blob[pos..pos + 4].try_into().unwrap()) as usize;
        pos += 4 + len;
        bounds.push(pos);
    }
    bounds
}

/// Walk a concatenated buffer exactly like the endpoint's pump does:
/// length prefix, validity check, strict body decode. `Ok` carries the
/// messages decoded before an incomplete tail; `Err` carries the ones
/// decoded before a corrupt frame killed the stream.
#[allow(clippy::type_complexity)]
fn parse_frames(
    buf: &[u8],
) -> std::result::Result<Vec<(u64, f64, WorkerMsg)>, Vec<(u64, f64, WorkerMsg)>> {
    let mut out = Vec::new();
    let mut pos = 0;
    while buf.len() - pos >= 4 {
        let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
        if len == 0 || len > MAX_FRAME {
            return Err(out);
        }
        if buf.len() - pos - 4 < len {
            break; // incomplete tail: wait for more bytes
        }
        let body = &buf[pos + 4..pos + 4 + len];
        let parsed = (|| {
            if body.first() != Some(&KIND_MSG) {
                return None;
            }
            let mut p = 1;
            let seq = read_varint(body, &mut p).ok()?;
            let mass = read_f64(body, &mut p).ok()?;
            let msg = WorkerMsg::decode(&body[p..]).ok()?;
            Some((seq, mass, msg))
        })();
        match parsed {
            Some(t) => out.push(t),
            None => return Err(out),
        }
        pos += 4 + len;
    }
    Ok(out)
}

/// Truncating a multi-frame batched buffer at *every* byte offset must
/// yield exactly the frames wholly inside the cut — never a corrupt
/// stream, never a partially-decoded message, never a panic.
#[test]
fn multi_frame_truncation_yields_only_complete_prefix_frames() {
    let mut rng = Xoshiro256pp::seed_from_u64(0x0D17_E004);
    for round in 0..8 {
        let msgs: Vec<(u64, f64, WorkerMsg)> = (0..4)
            .map(|i| (rng.next_u64() >> 16, 0.25 * (i + 1) as f64, random_msg(&mut rng)))
            .collect();
        let blob = concat_frames(&msgs);
        let bounds = frame_bounds(&blob);
        for cut in 0..=blob.len() {
            let complete = bounds.iter().filter(|&&b| b > 0 && b <= cut).count();
            match parse_frames(&blob[..cut]) {
                Ok(got) => {
                    assert_eq!(
                        got.len(),
                        complete,
                        "round {round} cut {cut}: wrong frame count"
                    );
                    for (g, m) in got.iter().zip(&msgs) {
                        assert_eq!(g, m, "round {round} cut {cut}");
                    }
                }
                Err(_) => panic!(
                    "round {round} cut {cut}: truncation must read as \
                     incomplete, never as corruption"
                ),
            }
        }
    }
}

/// Flipping any single bit in a batched buffer must never panic and
/// must never disturb the frames that lie wholly before the flipped
/// byte — corruption detection is per-frame, not per-batch.
#[test]
fn multi_frame_bit_flips_never_forge_prior_frames() {
    let mut rng = Xoshiro256pp::seed_from_u64(0x0D17_E005);
    for _ in 0..4 {
        let msgs: Vec<(u64, f64, WorkerMsg)> = (0..4)
            .map(|_| (rng.next_u64() >> 16, 0.5, random_msg(&mut rng)))
            .collect();
        let blob = concat_frames(&msgs);
        let bounds = frame_bounds(&blob);
        for _ in 0..256 {
            let mut bad = blob.clone();
            let at = rng.below(bad.len());
            bad[at] ^= 1 << rng.below(8);
            // frames ending at or before the flipped byte are untouched
            let intact = bounds.iter().filter(|&&b| b > 0 && b <= at).count();
            let decoded = match parse_frames(&bad) {
                Ok(v) | Err(v) => v, // either way: must not panic
            };
            assert!(
                decoded.len() >= intact,
                "flip at {at}: lost {} intact prior frames",
                intact - decoded.len()
            );
            for (g, m) in decoded.iter().take(intact).zip(&msgs) {
                assert_eq!(g, m, "flip at {at} disturbed a prior frame");
            }
        }
    }
}
