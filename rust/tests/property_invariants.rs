//! Property tests over randomized inputs (via the in-crate `prop`
//! framework): the algebraic invariants the whole system rests on.

use diter::coordinator::{update, v2, DistributedConfig};
use diter::linalg::vec_ops::{dist1, dist_inf, norm1};
use diter::partition::Partition;
use diter::prop::{run_cases, Gen};
use diter::solver::{
    DIteration, FixedPointProblem, GaussSeidel, Jacobi, SolveOptions, Solver,
};
use diter::sparse::{diag_eliminate, SparseMatrix};

fn random_problem(g: &mut Gen, n: usize) -> FixedPointProblem {
    let m = g.contraction_matrix(n, 3.min(n), 0.85);
    let b = g.vec_f64(n, -2.0, 2.0);
    FixedPointProblem::new(SparseMatrix::from_csr(m), b).unwrap()
}

/// eq. (4): H + F = F₀ + P·H after every diffusion step, any sequence.
#[test]
fn prop_eq4_invariant_under_random_sequences() {
    run_cases(40, 0xE41, |g| {
        let n = g.usize_in(2, 24);
        let problem = random_problem(g, n);
        let mut h = vec![0.0; n];
        let mut f = problem.b().to_vec();
        let steps = g.usize_in(1, 4 * n);
        for _ in 0..steps {
            let i = g.usize_in(0, n - 1);
            DIteration::diffuse_once(&problem, &mut h, &mut f, i);
        }
        let ph = problem.matrix().csr().matvec(&h).unwrap();
        for j in 0..n {
            let lhs = h[j] + f[j];
            let rhs = problem.b()[j] + ph[j];
            assert!(
                (lhs - rhs).abs() < 1e-11,
                "eq4 violated at {j}: {lhs} vs {rhs}"
            );
        }
    });
}

/// All solvers converge to the same fixed point on random contractions.
#[test]
fn prop_solver_agreement() {
    run_cases(15, 0xA9EE, |g| {
        let n = g.usize_in(2, 20);
        let problem = random_problem(g, n);
        let exact = problem.exact_solution().unwrap();
        let opts = SolveOptions {
            tol: 1e-12,
            max_cost: 50_000.0,
            trace_every: 0.0,
            exact: None,
        };
        for solver in [
            &Jacobi::new() as &dyn Solver,
            &GaussSeidel::new(),
            &DIteration::cyclic(),
            &DIteration::fluid_cyclic(),
        ] {
            let sol = solver.solve(&problem, &opts).unwrap();
            assert!(sol.converged, "{}", solver.name());
            assert!(
                dist_inf(&sol.x, &exact) < 1e-8,
                "{} diverged: {}",
                solver.name(),
                dist_inf(&sol.x, &exact)
            );
        }
    });
}

/// The distributed V2 scheme computes the sequential fixed point for any
/// random partition.
#[test]
fn prop_v2_any_partition_matches_exact() {
    run_cases(10, 0xD157, |g| {
        let n = g.usize_in(6, 36);
        let problem = random_problem(g, n);
        let exact = problem.exact_solution().unwrap();
        let k = g.usize_in(1, 4.min(n));
        // random owner map with all parts non-empty
        let owner: Vec<usize> = (0..n).map(|i| i % k).collect();
        let perm = g.permutation(n);
        let owner: Vec<usize> = perm.iter().map(|&i| owner[i]).collect();
        let partition = Partition::from_owner(owner, k).unwrap();
        partition.validate().unwrap();
        let cfg = DistributedConfig::new(partition).with_tol(1e-11);
        let sol = v2::solve_v2(&problem, &cfg).unwrap();
        assert!(sol.converged, "k={k} n={n} residual={}", sol.residual);
        assert!(dist_inf(&sol.x, &exact) < 1e-8);
    });
}

/// Partitions: split/merge preserve the exact-cover invariant.
#[test]
fn prop_partition_split_merge_cover() {
    run_cases(60, 0x9A27, |g| {
        let n = g.usize_in(4, 60);
        let k = g.usize_in(1, n.min(6));
        let mut part = Partition::contiguous(n, k).unwrap();
        for _ in 0..g.usize_in(0, 6) {
            if g.bool() {
                let target = g.usize_in(0, part.k() - 1);
                if part.part(target).len() >= 2 {
                    part = part.split_part(target).unwrap();
                }
            } else if part.k() >= 2 {
                let a = g.usize_in(0, part.k() - 1);
                let b = g.usize_in(0, part.k() - 1);
                if a != b {
                    part = part.merge_parts(a, b).unwrap();
                }
            }
            part.validate().unwrap();
        }
    });
}

/// CSR ↔ CSC ↔ dense round-trips are lossless.
#[test]
fn prop_sparse_roundtrips() {
    run_cases(50, 0x5BA2, |g| {
        let n = g.usize_in(1, 30);
        let m = g.contraction_matrix(n, 3.min(n), 0.9);
        let via_csc = m.to_csc().to_csr();
        assert_eq!(m.to_dense(), via_csc.to_dense());
        let via_dense = diter::sparse::CsrMatrix::from_dense(&m.to_dense());
        assert_eq!(m.to_dense(), via_dense.to_dense());
        // matvec consistency
        let x = g.vec_f64(n, -1.0, 1.0);
        let a = m.matvec(&x).unwrap();
        let b = m.to_dense().matvec(&x).unwrap();
        assert!(dist1(&a, &b) < 1e-12);
    });
}

/// Diagonal elimination never changes the fixed point.
#[test]
fn prop_diag_elimination_fixed_point() {
    run_cases(30, 0xD1A6, |g| {
        let n = g.usize_in(2, 16);
        // contraction + random sub-unit diagonal
        let base = g.contraction_matrix(n, 3.min(n), 0.6);
        let mut t = diter::sparse::TripletBuilder::new(n, n);
        for i in 0..n {
            let (idx, val) = base.row(i);
            for k in 0..idx.len() {
                t.push(i, idx[k], val[k]);
            }
            if g.chance(0.7) {
                t.push(i, i, g.f64_in(0.0, 0.3));
            }
        }
        let with_diag = t.to_csr();
        let b = g.vec_f64(n, -1.0, 1.0);
        let original =
            FixedPointProblem::new(SparseMatrix::from_csr(with_diag.clone()), b.clone())
                .unwrap();
        let exact = original.exact_solution().unwrap();
        let elim = diag_eliminate(&with_diag).unwrap();
        let b2: Vec<f64> = b.iter().zip(&elim.scale).map(|(x, s)| x * s).collect();
        let transformed =
            FixedPointProblem::new(SparseMatrix::from_csr(elim.matrix), b2).unwrap();
        let x2 = transformed.exact_solution().unwrap();
        assert!(dist_inf(&exact, &x2) < 1e-9);
    });
}

/// §3.2 rebase: warm continuation equals the cold solution of P'.
#[test]
fn prop_rebase_equals_cold_start() {
    run_cases(15, 0x3B2, |g| {
        let n = g.usize_in(2, 16);
        let old = random_problem(g, n);
        let new = random_problem(g, n);
        // partial progress on old
        let opts = SolveOptions {
            tol: 0.0,
            max_cost: g.usize_in(0, 8) as f64,
            trace_every: 0.0,
            exact: None,
        };
        let h = DIteration::cyclic().solve(&old, &opts).unwrap().x;
        let b_prime = update::rebase_b(new.matrix(), &h, new.b()).unwrap();
        let sub = FixedPointProblem::new(new.matrix().clone(), b_prime).unwrap();
        let tight = SolveOptions {
            tol: 1e-13,
            max_cost: 50_000.0,
            trace_every: 0.0,
            exact: None,
        };
        let y = DIteration::cyclic().solve(&sub, &tight).unwrap().x;
        let x: Vec<f64> = h.iter().zip(&y).map(|(a, b)| a + b).collect();
        let exact = new.exact_solution().unwrap();
        assert!(dist_inf(&x, &exact) < 1e-8);
    });
}

/// §3.2 distributed form: the full rebase `B' = P'·H + B − H` equals the
/// concatenation of per-PID `rebase_b_slice` results over ANY partition —
/// the identity the streaming engine's scatter step rests on.
#[test]
fn prop_rebase_b_equals_slice_concatenation() {
    run_cases(40, 0x511CE, |g| {
        let n = g.usize_in(2, 40);
        let problem = random_problem(g, n);
        let h = g.vec_f64(n, -2.0, 2.0);
        let full = update::rebase_b(problem.matrix(), &h, problem.b()).unwrap();
        // random (possibly wildly unbalanced) partition
        let k = g.usize_in(1, n.min(5));
        let owner_base: Vec<usize> = (0..n).map(|i| i % k).collect();
        let perm = g.permutation(n);
        let owner: Vec<usize> = perm.iter().map(|&i| owner_base[i]).collect();
        let part = Partition::from_owner(owner, k).unwrap();
        let mut assembled = vec![0.0; n];
        for kk in 0..part.k() {
            let slice = update::rebase_b_slice(problem.matrix(), part.part(kk), &h, problem.b());
            for (t, &i) in part.part(kk).iter().enumerate() {
                assembled[i] = slice[t];
            }
        }
        for i in 0..n {
            assert!(
                (assembled[i] - full[i]).abs() < 1e-12,
                "slice/full mismatch at {i}: {} vs {}",
                assembled[i],
                full[i]
            );
        }
    });
}

/// The V1 local rebase ≡ the leader's slice rebase: over random web
/// graphs, random mutation batches through the real churn generators
/// (so the dirty-column machinery is the production one), and random
/// exact covers (mid-flight handoffs leave ANY cover, not just a
/// contiguous one), applying `F + (P'−P)·H` per PID over the dirty halo
/// must equal `B' = P'·H + B − H` on every coordinate.
#[test]
fn prop_local_rebase_equals_leader_slice() {
    use diter::graph::{ChurnModel, MutableDigraph, MutationStream};

    run_cases(15, 0x10CA1, |g| {
        let n = g.usize_in(12, 60);
        let web = diter::graph::power_law_web_graph(n, 3, 0.1, g.case_seed);
        let mut mg = MutableDigraph::from_digraph(&web, n);
        let sys_old = mg.pagerank_system(0.85, true).unwrap();
        let p_old = FixedPointProblem::new(sys_old.matrix.clone(), sys_old.b.clone()).unwrap();
        let model = match g.usize_in(0, 2) {
            0 => ChurnModel::RandomRewire,
            1 => ChurnModel::HotSpotBurst { burst: 6 },
            _ => ChurnModel::PreferentialGrowth { links_per_node: 2 },
        };
        let mut stream = MutationStream::new(model, g.case_seed ^ 0x7);
        let batch = stream.next_batch(&mg, g.usize_in(1, 10));
        let applied = batch.iter().filter(|m| mg.apply(m)).count();
        let sys_new = mg.pagerank_system(0.85, true).unwrap();
        let p_new = FixedPointProblem::new(sys_new.matrix.clone(), sys_new.b.clone()).unwrap();
        let dirty: Vec<usize> = mg.last_build_dirty().expect("warm cache").to_vec();
        assert!(applied == 0 || !dirty.is_empty());
        // a partially-converged history and its consistent old-system fluid
        let h = g.vec_f64(n, 0.0, 1.0 / n as f64);
        let f_full = p_old.fluid(&h);
        // random exact cover: each coordinate at a random PID
        let k = g.usize_in(1, 4);
        let owner: Vec<usize> = (0..n).map(|_| g.usize_in(0, k - 1)).collect();
        let mut got = vec![0.0; n];
        for pid in 0..k {
            let owned: Vec<usize> = (0..n).filter(|&i| owner[i] == pid).collect();
            let mut local_of = vec![usize::MAX; n];
            for (t, &i) in owned.iter().enumerate() {
                local_of[i] = t;
            }
            let mut f: Vec<f64> = owned.iter().map(|&i| f_full[i]).collect();
            let halo: Vec<(usize, f64)> = dirty.iter().map(|&u| (u, h[u])).collect();
            update::rebase_b_slice_local(
                p_old.matrix().csc(),
                p_new.matrix().csc(),
                &halo,
                &local_of,
                &mut f,
            );
            for (t, &i) in owned.iter().enumerate() {
                got[i] = f[t];
            }
        }
        let want = update::rebase_b(p_new.matrix(), &h, p_new.b()).unwrap();
        for i in 0..n {
            assert!(
                (got[i] - want[i]).abs() < 1e-9,
                "coord {i}: local {} vs leader {} (dirty {dirty:?})",
                got[i],
                want[i]
            );
        }
    });
}

/// Streaming engine: a random mutation sequence lands on the cold fixed
/// point of the final matrix (threaded end-to-end, small cases).
#[test]
fn prop_streamed_mutations_match_cold_fixed_point() {
    use diter::coordinator::StreamingEngine;
    use diter::graph::{ChurnModel, MutableDigraph, MutationStream};
    run_cases(4, 0x57E4A, |g| {
        let n = g.usize_in(40, 90);
        let web = diter::graph::power_law_web_graph(n, 4, 0.1, g.case_seed);
        let mg = MutableDigraph::from_digraph(&web, n);
        let k = g.usize_in(1, 3);
        let cfg = DistributedConfig::new(Partition::contiguous(n, k).unwrap())
            .with_tol(1e-10)
            .with_seed(g.case_seed);
        let mut eng = StreamingEngine::new(mg, 0.85, true, cfg).unwrap();
        let mut stream = MutationStream::new(ChurnModel::RandomRewire, g.case_seed ^ 0xABCD);
        for _ in 0..g.usize_in(1, 3) {
            let batch = stream.next_batch(eng.graph(), g.usize_in(4, 16));
            let report = eng.apply_batch(&batch).unwrap();
            assert!(report.solution.converged, "residual {}", report.solution.residual);
        }
        let tight = SolveOptions {
            tol: 1e-13,
            max_cost: 100_000.0,
            trace_every: 0.0,
            exact: None,
        };
        let want = DIteration::fluid_cyclic()
            .solve(eng.problem(), &tight)
            .unwrap()
            .x;
        let summary = eng.finish().unwrap();
        let delta = dist1(&summary.final_solution.x, &want);
        assert!(delta < 1e-7, "streamed vs cold Δ₁ = {delta:.3e}");
    });
}

/// Incremental column-patched matrix rebuild ≡ full rebuild, for any
/// mutation sequence (the streaming epoch loop's correctness condition).
#[test]
fn prop_incremental_matrix_equals_full_rebuild() {
    use diter::graph::{ChurnModel, MutableDigraph, MutationStream};
    run_cases(8, 0x1AC5, |g| {
        let n = g.usize_in(30, 80);
        let cap = n + 8;
        let web = diter::graph::power_law_web_graph(n, 4, 0.1, g.case_seed);
        let mut mg = MutableDigraph::from_digraph(&web, cap);
        let model = if g.bool() {
            ChurnModel::RandomRewire
        } else {
            ChurnModel::PreferentialGrowth { links_per_node: 3 }
        };
        let mut stream = MutationStream::new(model, g.case_seed ^ 0x5EED);
        let patch = g.bool();
        mg.pagerank_system(0.85, patch).unwrap(); // warm the column cache
        for _ in 0..g.usize_in(1, 4) {
            let batch = stream.next_batch(&mg, g.usize_in(2, 12));
            for m in &batch {
                mg.apply(m);
            }
            let inc = mg.pagerank_system(0.85, patch).unwrap();
            let mut cold = MutableDigraph::new(cap);
            for (u, v, w) in mg.edges() {
                cold.insert_edge(u, v, w);
            }
            let full = cold.pagerank_system(0.85, patch).unwrap();
            assert_eq!(
                inc.matrix.csr().to_dense(),
                full.matrix.csr().to_dense(),
                "patched matrix must be bit-identical to a full rebuild"
            );
            assert_eq!(inc.b, full.b);
        }
    });
}

/// Ownership transfers preserve the exact cover for random move chains.
#[test]
fn prop_transfer_preserves_exact_cover() {
    run_cases(40, 0x7A5F, |g| {
        let n = g.usize_in(8, 60);
        let k = g.usize_in(2, 5.min(n / 2));
        let mut part = Partition::contiguous(n, k).unwrap();
        for _ in 0..g.usize_in(1, 8) {
            let from = g.usize_in(0, k - 1);
            let to = g.usize_in(0, k - 1);
            let members = part.part(from).to_vec();
            if members.len() < 2 || from == to {
                continue;
            }
            let take = g.usize_in(1, members.len() - 1);
            let coords: Vec<usize> = members[..take].to_vec();
            let next = part.transfer(&coords, to).unwrap();
            next.validate().unwrap();
            for &c in &coords {
                assert_eq!(next.owner(c), to);
            }
            part = next;
        }
    });
}

/// §4.3 split/merge round-trips preserve the exact cover.
#[test]
fn prop_split_merge_preserve_exact_cover() {
    run_cases(30, 0x5911, |g| {
        let n = g.usize_in(6, 60);
        let k = g.usize_in(2, 4.min(n / 2));
        let part = Partition::contiguous(n, k).unwrap();
        let target = g.usize_in(0, k - 1);
        if part.part(target).len() < 2 {
            return;
        }
        let split = part.split_part(target).unwrap();
        split.validate().unwrap();
        assert_eq!(split.k(), k + 1);
        let merged = split.merge_parts(target, k).unwrap();
        merged.validate().unwrap();
        assert_eq!(merged.k(), k);
        assert_eq!(merged.part(target), part.part(target));
    });
}

/// Fluid-form residual ‖F‖₁ equals the directly-computed remaining fluid.
#[test]
fn prop_fluid_norm_equals_residual() {
    run_cases(40, 0xF1, |g| {
        let n = g.usize_in(2, 20);
        let problem = random_problem(g, n);
        let mut h = vec![0.0; n];
        let mut f = problem.b().to_vec();
        for _ in 0..g.usize_in(0, 3 * n) {
            let i = g.usize_in(0, n - 1);
            DIteration::diffuse_once(&problem, &mut h, &mut f, i);
        }
        assert!((norm1(&f) - problem.residual_norm(&h)).abs() < 1e-11);
    });
}

/// PageRank-style mass conservation: for non-negative P with column sums
/// ≤ d and non-negative B, total H+F mass obeys the §4.4 accounting.
#[test]
fn prop_pagerank_bound_validity() {
    run_cases(10, 0xB0B, |g| {
        let n = g.usize_in(10, 60);
        let graph = diter::graph::power_law_web_graph(n, 4, 0.15, g.case_seed);
        let sys = diter::graph::pagerank_system(&graph, 0.85, true).unwrap();
        let problem = FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap();
        let tight = SolveOptions {
            tol: 1e-14,
            max_cost: 100_000.0,
            trace_every: 0.0,
            exact: None,
        };
        let exact = DIteration::fluid_cyclic().solve(&problem, &tight).unwrap().x;
        let budget = SolveOptions {
            tol: 0.0,
            max_cost: g.usize_in(1, 10) as f64,
            trace_every: 0.0,
            exact: None,
        };
        let partial = DIteration::fluid_cyclic().solve(&problem, &budget).unwrap();
        let bound = partial.residual / (1.0 - 0.85);
        let dist = dist1(&partial.x, &exact);
        assert!(dist <= bound * (1.0 + 1e-9), "dist {dist} > bound {bound}");
    });
}
