//! Integration: §3.2 live evolution of P — the running computation rebases
//! onto P' and converges to the new limit, warm start beating cold start.

use diter::coordinator::{sim, update, v2, DistributedConfig};
use diter::graph::{block_coupled_matrix, paper_matrix};
use diter::linalg::vec_ops::{dist1, dist_inf};
use diter::partition::Partition;
use diter::solver::{DIteration, FixedPointProblem, SolveOptions, Solver};
use diter::sparse::{SparseMatrix, TripletBuilder};

fn paper_problems() -> (FixedPointProblem, FixedPointProblem) {
    (
        FixedPointProblem::from_linear_system(&paper_matrix(1), &[1.0; 4]).unwrap(),
        FixedPointProblem::from_linear_system(&paper_matrix(4), &[1.0; 4]).unwrap(),
    )
}

#[test]
fn fig4_scenario_lockstep() {
    // P up to iteration 5, P' from 6 (paper §5.2), 2 PIDs
    let (p_old, p_new) = paper_problems();
    let cfg = sim::SimConfig {
        partition: Partition::contiguous(4, 2).unwrap(),
        sweeps_per_share: 2,
        max_cost: 60,
        switch_at: Some((6, p_new.clone())),
    };
    let snaps = sim::simulate_v1(&p_old, &cfg).unwrap();
    let exact_new = p_new.exact_solution().unwrap();
    let exact_old = p_old.exact_solution().unwrap();
    // before the switch we approach the old limit...
    let at5 = &snaps[5];
    assert!(dist1(&at5.x, &exact_old) < dist1(&at5.x, &exact_new));
    // ...after it we reach the new one
    assert!(dist1(&snaps.last().unwrap().x, &exact_new) < 1e-10);
}

#[test]
fn warm_restart_beats_cold_restart() {
    // a large-ish system with a small perturbation: continuing from the
    // old solution (with rebased B') must reach tolerance in fewer updates
    // than starting over.
    let n = 96;
    let csr = block_coupled_matrix(n, 4, 0.4, 0.15, 5, 17);
    let old = FixedPointProblem::new(SparseMatrix::from_csr(csr.clone()), vec![1.0; n]).unwrap();
    // perturb a handful of entries (P' = P + small delta)
    let mut b = TripletBuilder::new(n, n);
    for i in 0..n {
        let (idx, val) = csr.row(i);
        for t in 0..idx.len() {
            b.push(i, idx[t], val[t]);
        }
    }
    for j in 0..5 {
        b.push(j, (j + 7) % n, 0.02);
    }
    let new_m = SparseMatrix::from_csr(b.to_csr());
    let new = FixedPointProblem::new(new_m.clone(), vec![1.0; n]).unwrap();
    let exact_new = new.exact_solution().unwrap();

    // converge on the old system
    let opts_tight = SolveOptions {
        tol: 1e-12,
        max_cost: 100_000.0,
        trace_every: 0.0,
        exact: None,
    };
    let h = DIteration::cyclic().solve(&old, &opts_tight).unwrap().x;

    // warm: rebase B' = P'H + B − H, solve the correction system
    let b_prime = update::rebase_b(new.matrix(), &h, new.b()).unwrap();
    let sub = FixedPointProblem::new(new_m, b_prime).unwrap();
    let warm = DIteration::cyclic().solve(&sub, &opts_tight).unwrap();
    let warm_x: Vec<f64> = h.iter().zip(&warm.x).map(|(a, b)| a + b).collect();
    assert!(dist_inf(&warm_x, &exact_new) < 1e-9);

    // cold: full solve of the new system
    let cold = DIteration::cyclic().solve(&new, &opts_tight).unwrap();
    assert!(
        warm.cost < cold.cost,
        "warm {} vs cold {}",
        warm.cost,
        cold.cost
    );
}

#[test]
fn distributed_warm_restart_via_v2() {
    // each PID rebases its slice locally (no synchronization) and the V2
    // run on the correction system lands on the new limit
    let (p_old, p_new) = paper_problems();
    let opts = SolveOptions {
        tol: 0.0,
        max_cost: 5.0,
        trace_every: 0.0,
        exact: None,
    };
    let h = DIteration::cyclic().solve(&p_old, &opts).unwrap().x;
    // per-PID local rebase (slice API), then assemble B'
    let part = Partition::contiguous(4, 2).unwrap();
    let mut b_prime = vec![0.0; 4];
    for k in 0..part.k() {
        let slice = update::rebase_b_slice(p_new.matrix(), part.part(k), &h, p_new.b());
        for (t, &i) in part.part(k).iter().enumerate() {
            b_prime[i] = slice[t];
        }
    }
    let sub = FixedPointProblem::new(p_new.matrix().clone(), b_prime).unwrap();
    let cfg = DistributedConfig::new(part).with_tol(1e-12);
    let sol = v2::solve_v2(&sub, &cfg).unwrap();
    assert!(sol.converged);
    let x: Vec<f64> = h.iter().zip(&sol.x).map(|(a, b)| a + b).collect();
    let exact_new = p_new.exact_solution().unwrap();
    assert!(dist_inf(&x, &exact_new) < 1e-9);
}

#[test]
fn repeated_updates_chain() {
    // A → A' → back to A: two §3.2 rebases in sequence stay exact
    let (p_a, p_b) = paper_problems();
    let opts = SolveOptions {
        tol: 1e-13,
        max_cost: 10_000.0,
        trace_every: 0.0,
        exact: None,
    };
    // converge on A
    let x_a = DIteration::cyclic().solve(&p_a, &opts).unwrap().x;
    // rebase to A', converge
    let b1 = update::rebase_b(p_b.matrix(), &x_a, p_b.b()).unwrap();
    let sub1 = FixedPointProblem::new(p_b.matrix().clone(), b1).unwrap();
    let y1 = DIteration::cyclic().solve(&sub1, &opts).unwrap().x;
    let x_b: Vec<f64> = x_a.iter().zip(&y1).map(|(a, b)| a + b).collect();
    assert!(dist_inf(&x_b, &p_b.exact_solution().unwrap()) < 1e-10);
    // rebase back to A, converge
    let b2 = update::rebase_b(p_a.matrix(), &x_b, p_a.b()).unwrap();
    let sub2 = FixedPointProblem::new(p_a.matrix().clone(), b2).unwrap();
    let y2 = DIteration::cyclic().solve(&sub2, &opts).unwrap().x;
    let x_back: Vec<f64> = x_b.iter().zip(&y2).map(|(a, b)| a + b).collect();
    assert!(dist_inf(&x_back, &p_a.exact_solution().unwrap()) < 1e-10);
}
