//! Allocation discipline for the hot paths (DESIGN.md §9).
//!
//! The diffusion quantum, the coalesce flush, and the wire encoder all
//! sit inside loops that run millions of times per solve; a single
//! `Vec::with_capacity` per iteration turns the allocator into the
//! bottleneck long before the FPU is busy. This module collects the three
//! reusable pieces that keep those loops allocation-free in steady state:
//!
//! * [`VecQueue`] — a bounded scratch vector with an explicit
//!   capacity-reservation step and an unchecked push, so the inner loop
//!   carries no capacity branch and can never reallocate mid-batch;
//! * [`Arena`] — a recycling pool of `Vec<T>` buffers for values that
//!   must be *owned* at their point of use (bus parcels, wire frames)
//!   but whose backing storage can be reclaimed when the owner is done;
//! * [`CountingAlloc`] — a `System`-wrapping global allocator that counts
//!   allocations (process-wide and per-thread), used by the debug test
//!   and the hotpath bench to *assert* the zero-allocation claim instead
//!   of trusting it.
//!
//! [`pin_to_core`] and [`writev`] round the module out: opt-in Linux core
//! pinning for pool-spawned workers (`--pin-cores` / `DITER_PIN=1`) and a
//! vectored-write syscall for the wire transport's batched frame flush —
//! both raw syscalls so the zero-dependency policy holds.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

// ---------------------------------------------------------------------------
// VecQueue: bounded scratch with unchecked push

/// A scratch vector for bounded hot-loop batches: reserve once per batch
/// with [`VecQueue::reserve_total`], then append with
/// [`VecQueue::push_unchecked`] — no capacity check, no reallocation, no
/// allocator call on the append path. The backing buffer persists across
/// batches (and across quanta, when the queue lives in a worker), so a
/// warmed-up queue never touches the allocator again.
#[derive(Debug)]
pub struct VecQueue<T> {
    buf: Vec<T>,
}

impl<T> Default for VecQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> VecQueue<T> {
    /// An empty queue with no backing storage (first `reserve_total`
    /// allocates).
    pub fn new() -> Self {
        VecQueue { buf: Vec::new() }
    }

    /// Grow the backing buffer so that `cap` total elements fit. A no-op
    /// once the buffer has warmed up past `cap` — the steady-state path.
    pub fn reserve_total(&mut self, cap: usize) {
        let len = self.buf.len();
        if cap > len {
            self.buf.reserve(cap - len);
        }
    }

    /// Append without a capacity check.
    ///
    /// # Safety
    ///
    /// The caller must have established `len() < capacity()` — i.e. a
    /// preceding [`VecQueue::reserve_total`] covers every push since the
    /// last [`VecQueue::clear`]. Debug builds assert it.
    #[inline]
    pub unsafe fn push_unchecked(&mut self, v: T) {
        debug_assert!(self.buf.len() < self.buf.capacity(), "VecQueue overflow");
        let len = self.buf.len();
        std::ptr::write(self.buf.as_mut_ptr().add(len), v);
        self.buf.set_len(len + 1);
    }

    /// Checked append (cold paths; may reallocate).
    pub fn push(&mut self, v: T) {
        self.buf.push(v);
    }

    /// Drop the contents, keeping the backing storage warm.
    pub fn clear(&mut self) {
        self.buf.clear();
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.buf.capacity()
    }

    pub fn as_slice(&self) -> &[T] {
        &self.buf
    }
}

// ---------------------------------------------------------------------------
// Arena: recycling pool for owned buffers

/// A recycling pool of `Vec<T>` buffers for values that must be **owned**
/// where they are used — a bus parcel's SoA columns, a wire frame's body —
/// but whose backing storage can come back once the owner is done with it.
/// [`Arena::take`] hands out a cleared buffer with warm capacity (or a
/// fresh empty one when the pool is dry); [`Arena::give`] returns storage,
/// keeping at most `max_pooled` buffers so a burst cannot pin memory
/// forever. Buffers that cross a thread boundary and never come back are
/// simply replaced — the arena is a cache, not an accounting system.
#[derive(Debug)]
pub struct Arena<T> {
    pool: Vec<Vec<T>>,
    max_pooled: usize,
}

impl<T> Arena<T> {
    /// An empty arena retaining at most `max_pooled` returned buffers.
    pub fn new(max_pooled: usize) -> Self {
        Arena {
            pool: Vec::new(),
            max_pooled,
        }
    }

    /// A cleared buffer: recycled (warm capacity) when the pool has one,
    /// fresh otherwise.
    pub fn take(&mut self) -> Vec<T> {
        self.pool.pop().unwrap_or_default()
    }

    /// Return a buffer's storage to the pool (cleared first). Dropped on
    /// the floor once `max_pooled` buffers are already cached.
    pub fn give(&mut self, mut buf: Vec<T>) {
        if self.pool.len() < self.max_pooled && buf.capacity() > 0 {
            buf.clear();
            self.pool.push(buf);
        }
    }

    /// Buffers currently cached.
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }
}

// ---------------------------------------------------------------------------
// CountingAlloc: the zero-allocation claim, asserted

static TOTAL_ALLOCS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// A [`System`]-wrapping allocator that counts allocation calls — one
/// relaxed atomic increment process-wide plus a thread-local counter per
/// `alloc`/`alloc_zeroed`/`realloc` (`dealloc` is free). Install it in a
/// test or bench binary:
///
/// ```ignore
/// #[global_allocator]
/// static ALLOC: diter::perf::CountingAlloc = diter::perf::CountingAlloc::new();
/// ```
///
/// then bracket the region under test with
/// [`CountingAlloc::thread_allocations`] (immune to allocations from
/// concurrently running test threads) or
/// [`CountingAlloc::total_allocations`] (whole process, for multi-threaded
/// solves). This is how "zero heap allocations per quantum in steady
/// state" is *asserted* rather than assumed.
#[derive(Debug, Default)]
pub struct CountingAlloc;

impl CountingAlloc {
    pub const fn new() -> Self {
        CountingAlloc
    }

    #[inline]
    fn count() {
        TOTAL_ALLOCS.fetch_add(1, Ordering::Relaxed);
        // try_with: the allocator may be called while this thread's TLS is
        // being torn down — skip the per-thread count rather than panic
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
    }

    /// Allocation calls across the whole process since start.
    pub fn total_allocations() -> u64 {
        TOTAL_ALLOCS.load(Ordering::Relaxed)
    }

    /// Allocation calls made by the current thread since it started.
    pub fn thread_allocations() -> u64 {
        THREAD_ALLOCS.try_with(Cell::get).unwrap_or(0)
    }
}

// SAFETY: defers every operation to `System`, which upholds the
// GlobalAlloc contract; the counters never touch the returned memory.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        Self::count();
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        Self::count();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        Self::count();
        System.realloc(ptr, layout, new_size)
    }
}

// ---------------------------------------------------------------------------
// Core pinning: raw sched_setaffinity, zero dependencies

/// Whether [`pin_to_core`] can do anything on this target.
pub const fn pin_supported() -> bool {
    cfg!(all(
        target_os = "linux",
        any(target_arch = "x86_64", target_arch = "aarch64")
    ))
}

/// Pin the **calling thread** to one CPU core via `sched_setaffinity(2)`
/// (raw syscall — the crate has no libc dependency). Returns whether the
/// kernel accepted the mask; a `false` (unsupported target, cgroup
/// restriction, core out of range) leaves the thread where it was —
/// pinning is strictly best-effort. Workers call this from their own
/// spawned thread when `--pin-cores` / `DITER_PIN=1` is set, with
/// `core = pid % available_parallelism`, so elastic spawns land on
/// distinct cores instead of piling onto whichever core the scheduler
/// favors.
#[cfg(all(
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
))]
pub fn pin_to_core(core: usize) -> bool {
    const MASK_WORDS: usize = 16; // 1024 CPUs
    if core >= MASK_WORDS * 64 {
        return false;
    }
    let mut mask = [0u64; MASK_WORDS];
    mask[core / 64] = 1u64 << (core % 64);
    // pid 0 = the calling thread
    let ret = unsafe { sched_setaffinity_raw(0, std::mem::size_of_val(&mask), mask.as_ptr()) };
    ret == 0
}

/// Fallback for targets without the raw-syscall implementation: report
/// "not pinned" and do nothing.
#[cfg(not(all(
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
)))]
pub fn pin_to_core(_core: usize) -> bool {
    false
}

// SAFETY (both arches): the syscall reads `size` bytes from `mask`, which
// the caller keeps alive across the call; no memory is written.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
unsafe fn sched_setaffinity_raw(pid: i64, size: usize, mask: *const u64) -> i64 {
    let mut ret: i64;
    std::arch::asm!(
        "syscall",
        inlateout("rax") 203i64 => ret, // __NR_sched_setaffinity
        in("rdi") pid,
        in("rsi") size,
        in("rdx") mask,
        lateout("rcx") _,
        lateout("r11") _,
        options(nostack),
    );
    ret
}

#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
unsafe fn sched_setaffinity_raw(pid: i64, size: usize, mask: *const u64) -> i64 {
    let mut ret: i64;
    std::arch::asm!(
        "svc #0",
        in("x8") 122i64, // __NR_sched_setaffinity
        inlateout("x0") pid => ret,
        in("x1") size,
        in("x2") mask,
        options(nostack),
    );
    ret
}

// ---------------------------------------------------------------------------
// Vectored writes: raw writev, zero dependencies

/// Whether [`writev`] uses the raw `writev(2)` syscall on this target
/// (elsewhere it is not compiled; callers fall back to
/// `Write::write_vectored`, which issues one `write` per call on most
/// std implementations for `TcpStream`).
pub const fn writev_supported() -> bool {
    cfg!(all(
        target_os = "linux",
        any(target_arch = "x86_64", target_arch = "aarch64")
    ))
}

/// Gather-write `bufs` to `fd` with a single `writev(2)` syscall (raw —
/// the crate has no libc dependency). Returns the number of bytes
/// written, which may cover only a prefix of the slices (short write);
/// the caller advances its queue and retries, exactly as with `write`.
/// `std::io::IoSlice` is guaranteed ABI-compatible with `struct iovec`,
/// so the slice pointer is passed straight to the kernel.
///
/// Errors map from the raw `-errno` return: `EAGAIN`/`EWOULDBLOCK`
/// surfaces as [`std::io::ErrorKind::WouldBlock`], `EINTR` as
/// [`std::io::ErrorKind::Interrupted`] — the two the nonblocking flush
/// loop handles — and everything else as the corresponding OS error.
#[cfg(all(
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
))]
pub fn writev(fd: i32, bufs: &[std::io::IoSlice<'_>]) -> std::io::Result<usize> {
    if bufs.is_empty() {
        return Ok(0);
    }
    // SAFETY: the iovec array lives in `bufs` for the duration of the
    // call; the kernel only reads the described buffers.
    let ret = unsafe { writev_raw(fd as i64, bufs.as_ptr() as *const u8, bufs.len() as i64) };
    if ret < 0 {
        Err(std::io::Error::from_raw_os_error((-ret) as i32))
    } else {
        Ok(ret as usize)
    }
}

// SAFETY (both arches): writev(fd, iov, iovcnt) reads `iovcnt` iovec
// structs from `iov` and the buffers they describe; nothing is written
// to caller memory.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
unsafe fn writev_raw(fd: i64, iov: *const u8, iovcnt: i64) -> i64 {
    let mut ret: i64;
    std::arch::asm!(
        "syscall",
        inlateout("rax") 20i64 => ret, // __NR_writev
        in("rdi") fd,
        in("rsi") iov,
        in("rdx") iovcnt,
        lateout("rcx") _,
        lateout("r11") _,
        options(nostack),
    );
    ret
}

#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
unsafe fn writev_raw(fd: i64, iov: *const u8, iovcnt: i64) -> i64 {
    let mut ret: i64;
    std::arch::asm!(
        "svc #0",
        in("x8") 66i64, // __NR_writev
        inlateout("x0") fd => ret,
        in("x1") iov,
        in("x2") iovcnt,
        options(nostack),
    );
    ret
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_queue_reserve_then_push_unchecked() {
        let mut q: VecQueue<u32> = VecQueue::new();
        assert!(q.is_empty());
        q.reserve_total(8);
        assert!(q.capacity() >= 8);
        for i in 0..8 {
            // SAFETY: reserved 8 above, pushing exactly 8
            unsafe { q.push_unchecked(i) };
        }
        assert_eq!(q.len(), 8);
        assert_eq!(q.as_slice(), &[0, 1, 2, 3, 4, 5, 6, 7]);
        let cap = q.capacity();
        q.clear();
        assert!(q.is_empty());
        assert_eq!(q.capacity(), cap, "clear must keep the storage warm");
    }

    #[test]
    fn vec_queue_reserve_total_counts_live_elements() {
        let mut q: VecQueue<u8> = VecQueue::new();
        q.reserve_total(4);
        for _ in 0..4 {
            unsafe { q.push_unchecked(7) };
        }
        // 4 live + room for 4 more
        q.reserve_total(8);
        assert!(q.capacity() >= 8);
        for _ in 0..4 {
            unsafe { q.push_unchecked(9) };
        }
        assert_eq!(q.len(), 8);
    }

    #[test]
    fn arena_recycles_capacity() {
        let mut a: Arena<u32> = Arena::new(2);
        let mut b = a.take();
        assert!(b.is_empty());
        b.extend_from_slice(&[1, 2, 3]);
        let cap = b.capacity();
        a.give(b);
        assert_eq!(a.pooled(), 1);
        let b2 = a.take();
        assert!(b2.is_empty(), "recycled buffers come back cleared");
        assert_eq!(b2.capacity(), cap, "recycled buffers keep their storage");
        assert_eq!(a.pooled(), 0);
    }

    #[test]
    fn arena_bounds_the_pool() {
        let mut a: Arena<u8> = Arena::new(1);
        a.give(Vec::with_capacity(4));
        a.give(Vec::with_capacity(4)); // over the cap: dropped
        assert_eq!(a.pooled(), 1);
        a.give(Vec::new()); // zero capacity: nothing worth caching
        assert_eq!(a.pooled(), 1);
    }

    #[test]
    fn counting_alloc_counters_are_monotonic() {
        // the test binary may or may not have CountingAlloc installed as
        // its global allocator; either way the counters must be readable
        // and monotonic
        let t0 = CountingAlloc::total_allocations();
        let h0 = CountingAlloc::thread_allocations();
        let v: Vec<u64> = (0..64).collect();
        assert_eq!(v.len(), 64);
        assert!(CountingAlloc::total_allocations() >= t0);
        assert!(CountingAlloc::thread_allocations() >= h0);
    }

    #[cfg(all(
        target_os = "linux",
        any(target_arch = "x86_64", target_arch = "aarch64")
    ))]
    #[test]
    fn writev_gathers_multiple_slices_in_one_call() {
        use std::io::{IoSlice, Read};
        use std::os::fd::AsRawFd;

        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let tx = std::net::TcpStream::connect(addr).unwrap();
        let (mut rx, _) = listener.accept().unwrap();

        let parts: [&[u8]; 3] = [b"hello ", b"vectored ", b"world"];
        let slices = [
            IoSlice::new(parts[0]),
            IoSlice::new(parts[1]),
            IoSlice::new(parts[2]),
        ];
        let total: usize = parts.iter().map(|p| p.len()).sum();
        // a tiny blocking write to a fresh socket never short-writes
        let n = writev(tx.as_raw_fd(), &slices).unwrap();
        assert_eq!(n, total);

        let mut got = vec![0u8; total];
        rx.read_exact(&mut got).unwrap();
        assert_eq!(&got, b"hello vectored world");

        assert_eq!(writev(tx.as_raw_fd(), &[]).unwrap(), 0);
    }

    #[cfg(all(
        target_os = "linux",
        any(target_arch = "x86_64", target_arch = "aarch64")
    ))]
    #[test]
    fn writev_reports_errors_as_errno() {
        use std::io::IoSlice;
        // fd -1 is never valid: the raw -EBADF must surface as an error
        let err = writev(-1, &[IoSlice::new(b"x")]).unwrap_err();
        assert_eq!(err.raw_os_error(), Some(9), "expected EBADF, got {err:?}");
    }

    #[test]
    fn pin_to_core_is_best_effort() {
        // must not crash anywhere; success is environment-dependent
        // (cgroup CPU masks can exclude core 0), so only the contract
        // "unsupported target ⇒ false" is asserted
        let ok = pin_to_core(0);
        if !pin_supported() {
            assert!(!ok);
        }
        assert!(!pin_to_core(usize::MAX), "out-of-range core must fail");
    }
}
