//! CLI substrate: a small typed argument parser (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! subcommands. Produces the usage text for `diter --help`.

use std::collections::BTreeMap;

use crate::error::{DiterError, Result};

/// Declarative spec for one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// true = boolean flag (no value)
    pub is_flag: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| DiterError::Parse {
                location: format!("--{key}"),
                message: format!("expected integer, got `{v}`"),
            }),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| DiterError::Parse {
                location: format!("--{key}"),
                message: format!("expected integer, got `{v}`"),
            }),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| DiterError::Parse {
                location: format!("--{key}"),
                message: format!("expected float, got `{v}`"),
            }),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Parse `argv` (without the program name) against a spec.
pub fn parse_args(argv: &[String], spec: &[OptSpec]) -> Result<Args> {
    let mut args = Args::default();
    // seed defaults
    for s in spec {
        if let Some(d) = s.default {
            args.values.insert(s.name.to_string(), d.to_string());
        }
    }
    let mut i = 0;
    while i < argv.len() {
        let tok = &argv[i];
        if let Some(body) = tok.strip_prefix("--") {
            let (key, inline_val) = match body.split_once('=') {
                Some((k, v)) => (k.to_string(), Some(v.to_string())),
                None => (body.to_string(), None),
            };
            let s = spec.iter().find(|s| s.name == key).ok_or_else(|| {
                DiterError::Parse {
                    location: tok.clone(),
                    message: format!("unknown option --{key}"),
                }
            })?;
            if s.is_flag {
                if inline_val.is_some() {
                    return Err(DiterError::Parse {
                        location: tok.clone(),
                        message: format!("--{key} takes no value"),
                    });
                }
                args.flags.push(key);
            } else {
                let val = match inline_val {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i)
                            .ok_or_else(|| DiterError::Parse {
                                location: tok.clone(),
                                message: format!("--{key} requires a value"),
                            })?
                            .clone()
                    }
                };
                args.values.insert(key, val);
            }
        } else {
            args.positional.push(tok.clone());
        }
        i += 1;
    }
    Ok(args)
}

/// Render the usage block for a subcommand.
pub fn usage(cmd: &str, about: &str, spec: &[OptSpec]) -> String {
    let mut out = format!("{cmd} — {about}\n\noptions:\n");
    for s in spec {
        let head = if s.is_flag {
            format!("  --{}", s.name)
        } else {
            format!("  --{} <v>", s.name)
        };
        let default = s
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        out.push_str(&format!("{head:<28} {}{default}\n", s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<OptSpec> {
        vec![
            OptSpec {
                name: "nodes",
                help: "graph size",
                is_flag: false,
                default: Some("100"),
            },
            OptSpec {
                name: "alpha",
                help: "threshold divisor",
                is_flag: false,
                default: None,
            },
            OptSpec {
                name: "verbose",
                help: "print more",
                is_flag: true,
                default: None,
            },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = parse_args(&sv(&[]), &spec()).unwrap();
        assert_eq!(a.get_usize("nodes", 0).unwrap(), 100);
        let a = parse_args(&sv(&["--nodes", "500"]), &spec()).unwrap();
        assert_eq!(a.get_usize("nodes", 0).unwrap(), 500);
        let a = parse_args(&sv(&["--nodes=7"]), &spec()).unwrap();
        assert_eq!(a.get_usize("nodes", 0).unwrap(), 7);
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse_args(&sv(&["run", "--verbose", "x"]), &spec()).unwrap();
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional(), &["run".to_string(), "x".to_string()]);
    }

    #[test]
    fn float_parse() {
        let a = parse_args(&sv(&["--alpha", "2.5"]), &spec()).unwrap();
        assert_eq!(a.get_f64("alpha", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_f64("missing-ok", 1.5).unwrap(), 1.5);
    }

    #[test]
    fn errors() {
        assert!(parse_args(&sv(&["--unknown"]), &spec()).is_err());
        assert!(parse_args(&sv(&["--alpha"]), &spec()).is_err());
        assert!(parse_args(&sv(&["--verbose=1"]), &spec()).is_err());
        let a = parse_args(&sv(&["--nodes", "abc"]), &spec()).unwrap();
        assert!(a.get_usize("nodes", 0).is_err());
    }

    #[test]
    fn usage_renders() {
        let u = usage("diter solve", "solve a system", &spec());
        assert!(u.contains("--nodes"));
        assert!(u.contains("default: 100"));
    }
}
