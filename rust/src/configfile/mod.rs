//! Config-file substrate: a minimal TOML-subset parser (no `serde`/`toml`
//! crates available offline) + the typed run configuration used by the
//! launcher.
//!
//! Supported syntax: `[section]` headers, `key = value` with string
//! (`"..."`), integer, float, and boolean values, `#` comments, blank
//! lines. This covers everything the launcher needs.

use std::collections::BTreeMap;

use crate::error::{DiterError, Result};

/// A parsed config: `section -> key -> raw value`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

/// A scalar config value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl Config {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Config> {
        let mut cfg = Config::default();
        let mut section = String::new(); // "" = top level
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let loc = || format!("line {}", lineno + 1);
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| DiterError::Parse {
                    location: loc(),
                    message: "unterminated section header".into(),
                })?;
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| DiterError::Parse {
                location: loc(),
                message: format!("expected `key = value`, got `{line}`"),
            })?;
            let key = line[..eq].trim().to_string();
            let val = parse_value(line[eq + 1..].trim()).map_err(|m| DiterError::Parse {
                location: loc(),
                message: m,
            })?;
            if key.is_empty() {
                return Err(DiterError::Parse {
                    location: loc(),
                    message: "empty key".into(),
                });
            }
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(key, val);
        }
        Ok(cfg)
    }

    /// Load + parse a file.
    pub fn load(path: &str) -> Result<Config> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn get_str(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }

    pub fn get_int(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn get_float(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key)
            .and_then(Value::as_float)
            .unwrap_or(default)
    }

    pub fn get_bool(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key)
            .and_then(Value::as_bool)
            .unwrap_or(default)
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // a `#` inside a quoted string does not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value `{s}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# run configuration
name = "fig1"           # top-level
[solver]
scheme = "v1"
pids = 2
threshold_alpha = 2.0
verbose = false

[graph]
nodes = 10000
coupling = 0.15
"#;

    #[test]
    fn parses_sample() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_str("", "name", "?"), "fig1");
        assert_eq!(c.get_str("solver", "scheme", "?"), "v1");
        assert_eq!(c.get_int("solver", "pids", 0), 2);
        assert_eq!(c.get_float("solver", "threshold_alpha", 0.0), 2.0);
        assert!(!c.get_bool("solver", "verbose", true));
        assert_eq!(c.get_int("graph", "nodes", 0), 10_000);
        assert_eq!(c.get_float("graph", "coupling", 0.0), 0.15);
    }

    #[test]
    fn defaults_for_missing() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.get_int("x", "y", 7), 7);
        assert_eq!(c.get_str("x", "y", "d"), "d");
        assert!(c.get_bool("x", "y", true));
    }

    #[test]
    fn int_promotes_to_float() {
        let c = Config::parse("k = 3").unwrap();
        assert_eq!(c.get_float("", "k", 0.0), 3.0);
    }

    #[test]
    fn hash_inside_string_kept() {
        let c = Config::parse(r##"k = "a#b" # comment"##).unwrap();
        assert_eq!(c.get_str("", "k", ""), "a#b");
    }

    #[test]
    fn errors_are_located() {
        let e = Config::parse("line1 = 1\noops").unwrap_err();
        assert!(e.to_string().contains("line 2"), "{e}");
        let e = Config::parse("[broken").unwrap_err();
        assert!(e.to_string().contains("unterminated"), "{e}");
        let e = Config::parse("k = \"unclosed").unwrap_err();
        assert!(e.to_string().contains("string"), "{e}");
    }

    #[test]
    fn sections_iter() {
        let c = Config::parse(SAMPLE).unwrap();
        let names: Vec<&str> = c.sections().collect();
        assert!(names.contains(&"solver"));
        assert!(names.contains(&"graph"));
    }
}
