//! PageRank as a D-iteration fixed point.
//!
//! PageRank with damping `d` and teleport vector `v` solves
//! `X = d·S·X + (1−d)·v` (plus dangling-mass handling). That is exactly the
//! paper's `X = P·X + B` with `P = d·S` and `B = (1−d)·v`; §4.4 notes that
//! `(Σ_k r_k)/(1−d)` is then an exact distance to the limit (upper bound
//! with dangling nodes). We adopt the common convention of patching
//! dangling columns with the teleport vector so mass is conserved.

use super::Digraph;
use crate::error::Result;
use crate::sparse::{CsrMatrix, SparseMatrix, TripletBuilder};

/// A PageRank instance in fixed-point form `X = P·X + B`.
#[derive(Clone, Debug)]
pub struct PageRankSystem {
    /// `P = d·S̄` where S̄ is S with dangling columns replaced by teleport.
    pub matrix: SparseMatrix,
    /// `B = (1−d)·v`.
    pub b: Vec<f64>,
    /// damping factor
    pub damping: f64,
    pub n: usize,
}

/// Build the fixed-point system for a graph with uniform teleport.
///
/// `patch_dangling`: if true, dangling columns get the teleport
/// distribution (mass-conserving, P column-sums = d exactly, so the §4.4
/// distance `(Σ r_k)/(1−d)` is *exact*); if false, dangling mass is lost
/// and the same expression is an upper bound — both paper variants.
pub fn pagerank_system(g: &Digraph, damping: f64, patch_dangling: bool) -> Result<PageRankSystem> {
    pagerank_from_links(&g.link_matrix(), &g.dangling_nodes(), damping, patch_dangling)
}

/// Build the system from an already-normalized link matrix `S` plus its
/// dangling-column list — the shared back half of [`pagerank_system`],
/// also used by the streaming engine's [`crate::graph::MutableDigraph`]
/// (whose weighted columns renormalize on every mutation batch).
pub fn pagerank_from_links(
    s: &CsrMatrix,
    dangling: &[usize],
    damping: f64,
    patch_dangling: bool,
) -> Result<PageRankSystem> {
    let n = s.nrows();
    let uniform = 1.0 / n as f64;
    let mut b = TripletBuilder::with_capacity(n, n, s.nnz() + n);
    // d * S entries
    for i in 0..n {
        let (idx, val) = s.row(i);
        for k in 0..idx.len() {
            b.push(i, idx[k], damping * val[k]);
        }
    }
    if patch_dangling {
        for &u in dangling {
            let w = damping * uniform;
            for i in 0..n {
                b.push(i, u, w);
            }
        }
    }
    let matrix = SparseMatrix::from_csr(b.to_csr());
    let rhs = vec![(1.0 - damping) * uniform; n];
    Ok(PageRankSystem {
        matrix,
        b: rhs,
        damping,
        n,
    })
}

/// Reference sequential PageRank via (dense-vector) power-style fixed-point
/// iteration on the sparse system — used as ground truth at scale where LU
/// is out of reach.
pub fn pagerank_reference(sys: &PageRankSystem, tol: f64, max_iter: usize) -> Vec<f64> {
    let n = sys.n;
    let mut x = vec![1.0 / n as f64; n];
    for _ in 0..max_iter {
        let mut next = sys.matrix.csr().matvec(&x).expect("shape");
        for i in 0..n {
            next[i] += sys.b[i];
        }
        let delta: f64 = next
            .iter()
            .zip(&x)
            .map(|(a, b)| (a - b).abs())
            .sum();
        x = next;
        if delta < tol {
            break;
        }
    }
    x
}

/// Check that the matrix columns sum to ≤ d (exactly d when patched):
/// the §4.4 precondition for the `(Σ r)/(1−d)` bound.
pub fn verify_pagerank_matrix(p: &CsrMatrix, damping: f64) -> bool {
    p.col_l1_norms()
        .iter()
        .all(|&s| s <= damping + 1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::power_law_web_graph;
    use crate::linalg::vec_ops::norm1;

    fn tiny_graph() -> Digraph {
        // 0 → 1, 0 → 2, 1 → 2, 2 → 0, 3 dangling
        Digraph::from_edges(4, [(0, 1), (0, 2), (1, 2), (2, 0)])
    }

    #[test]
    fn columns_sum_to_damping_when_patched() {
        let sys = pagerank_system(&tiny_graph(), 0.85, true).unwrap();
        let cols = sys.matrix.csr().col_l1_norms();
        for c in cols {
            assert!((c - 0.85).abs() < 1e-12);
        }
        assert!(verify_pagerank_matrix(sys.matrix.csr(), 0.85));
    }

    #[test]
    fn solution_is_probability_vector() {
        let sys = pagerank_system(&tiny_graph(), 0.85, true).unwrap();
        let x = pagerank_reference(&sys, 1e-14, 10_000);
        assert!((norm1(&x) - 1.0).abs() < 1e-10, "‖x‖₁ = {}", norm1(&x));
        assert!(x.iter().all(|&v| v > 0.0));
        // node 2 has two in-links incl. from the hub — should outrank 3
        assert!(x[2] > x[3]);
    }

    #[test]
    fn unpatched_loses_mass() {
        let sys = pagerank_system(&tiny_graph(), 0.85, false).unwrap();
        let x = pagerank_reference(&sys, 1e-14, 10_000);
        assert!(norm1(&x) < 1.0);
    }

    #[test]
    fn fixed_point_property() {
        let sys = pagerank_system(&tiny_graph(), 0.85, true).unwrap();
        let x = pagerank_reference(&sys, 1e-15, 20_000);
        let px = sys.matrix.csr().matvec(&x).unwrap();
        for i in 0..sys.n {
            assert!((x[i] - (px[i] + sys.b[i])).abs() < 1e-12);
        }
    }

    #[test]
    fn scales_to_synthetic_web() {
        let g = power_law_web_graph(2000, 6, 0.1, 9);
        let sys = pagerank_system(&g, 0.85, true).unwrap();
        let x = pagerank_reference(&sys, 1e-12, 5_000);
        assert!((norm1(&x) - 1.0).abs() < 1e-8);
    }
}
