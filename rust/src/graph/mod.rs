//! Graph substrate: synthetic workload generators + PageRank matrices.
//!
//! The paper's future-work targets are the web graph and the paper–author
//! graph ([5]); neither dataset ships here, so per DESIGN.md §3 we generate
//! synthetic equivalents that exercise the same code paths: power-law
//! in/out degrees, dangling nodes, block structure with tunable coupling
//! (the knob behind the Fig 1 → Fig 3 progression).

pub mod generators;
pub mod pagerank;
pub mod stream;

pub use generators::{
    barabasi_albert_digraph, block_coupled_matrix, erdos_renyi_digraph, grid_digraph,
    paper_author_graph, paper_matrix, power_law_web_graph, PaperAuthorGraph,
};
pub use pagerank::{
    pagerank_from_links, pagerank_reference, pagerank_system, verify_pagerank_matrix,
    PageRankSystem,
};
pub use stream::{ChurnModel, MutableDigraph, Mutation, MutationStream};

use crate::sparse::TripletBuilder;

/// A simple directed graph as an adjacency list (edges `u → v`).
#[derive(Clone, Debug)]
pub struct Digraph {
    n: usize,
    /// out-adjacency: `adj[u]` = sorted targets of u (duplicates removed)
    adj: Vec<Vec<usize>>,
    m: usize,
}

impl Digraph {
    pub fn new(n: usize) -> Self {
        Self {
            n,
            adj: vec![Vec::new(); n],
            m: 0,
        }
    }

    /// Build from an edge list; self-loops and duplicates are dropped.
    pub fn from_edges(n: usize, edges: impl IntoIterator<Item = (usize, usize)>) -> Self {
        let mut g = Self::new(n);
        for (u, v) in edges {
            g.add_edge(u, v);
        }
        g.finish();
        g
    }

    /// Add one edge (u → v). Call [`Digraph::finish`] before reading.
    pub fn add_edge(&mut self, u: usize, v: usize) {
        if u == v || u >= self.n || v >= self.n {
            return;
        }
        self.adj[u].push(v);
    }

    /// Sort + dedup all adjacency lists and recount edges.
    pub fn finish(&mut self) {
        self.m = 0;
        for list in &mut self.adj {
            list.sort_unstable();
            list.dedup();
            self.m += list.len();
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of (deduplicated) edges.
    pub fn m(&self) -> usize {
        self.m
    }

    pub fn out_neighbors(&self, u: usize) -> &[usize] {
        &self.adj[u]
    }

    pub fn out_degree(&self, u: usize) -> usize {
        self.adj[u].len()
    }

    /// Nodes with no out-links (dangling pages in PageRank terms).
    pub fn dangling_nodes(&self) -> Vec<usize> {
        (0..self.n).filter(|&u| self.adj[u].is_empty()).collect()
    }

    /// Column-stochastic link matrix S: `s_{vu} = 1/outdeg(u)` for each edge
    /// u → v; dangling columns are all-zero (mass re-injected by the
    /// PageRank step itself).
    pub fn link_matrix(&self) -> crate::sparse::CsrMatrix {
        let mut b = TripletBuilder::with_capacity(self.n, self.n, self.m);
        for u in 0..self.n {
            let d = self.adj[u].len();
            if d == 0 {
                continue;
            }
            let w = 1.0 / d as f64;
            for &v in &self.adj[u] {
                b.push(v, u, w);
            }
        }
        b.to_csr()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_edges_dedups_and_drops_self_loops() {
        let g = Digraph::from_edges(4, [(0, 1), (0, 1), (1, 1), (2, 3), (3, 0)]);
        assert_eq!(g.m(), 3);
        assert_eq!(g.out_neighbors(0), &[1]);
        assert_eq!(g.out_degree(1), 0);
        assert_eq!(g.dangling_nodes(), vec![1]);
    }

    #[test]
    fn link_matrix_is_column_stochastic() {
        let g = Digraph::from_edges(4, [(0, 1), (0, 2), (1, 3), (2, 3), (3, 0)]);
        let s = g.link_matrix();
        let col_sums = s.col_l1_norms();
        for (u, cs) in col_sums.iter().enumerate() {
            if g.out_degree(u) > 0 {
                assert!((cs - 1.0).abs() < 1e-15, "col {u} sums to {cs}");
            } else {
                assert_eq!(*cs, 0.0);
            }
        }
        // edge 0→1 with outdeg 2: s[1,0] = 0.5
        assert_eq!(s.get(1, 0), 0.5);
    }
}
