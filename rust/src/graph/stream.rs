//! Streaming graph substrate: an editable weighted digraph plus seeded
//! churn generators — the workload side of the §3.2 live-evolution story.
//!
//! The papers "Evaluation of a Dynamic Partition Strategy" (1203.1715) and
//! "Convergence of the D-iteration algorithm" (1301.3007) study D-iteration
//! while the matrix changes underneath it; this module produces exactly
//! that regime: a [`MutableDigraph`] absorbs a stream of [`Mutation`]s
//! (edge insert/delete/reweight, node activate/deactivate) and re-derives
//! a column-renormalized PageRank system after every batch, and a
//! [`MutationStream`] generates reproducible churn under three models
//! (preferential-attachment growth, random rewire, hot-spot bursts).
//!
//! **Fixed coordinate capacity.** The engine keeps one coordinate per
//! potential node for the whole run: "node add" activates a dormant
//! coordinate (until then it behaves as a dangling page holding only its
//! teleport mass) and "node remove" deactivates one by dropping all its
//! incident edges. This keeps every history/fluid vector the same length
//! across rebases, which is what lets §3.2's `B' = P'·H + B − H` apply
//! without re-indexing a running computation.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use super::pagerank::PageRankSystem;
use super::Digraph;
use crate::error::Result;
use crate::prng::Xoshiro256pp;
use crate::sparse::{CscMatrix, SparseMatrix, TripletBuilder};

/// One atomic change to the evolving graph.
#[derive(Clone, Debug, PartialEq)]
pub enum Mutation {
    /// Add edge `from → to` with `weight` (no-op if it already exists).
    EdgeInsert { from: usize, to: usize, weight: f64 },
    /// Remove edge `from → to` (no-op if absent).
    EdgeDelete { from: usize, to: usize },
    /// Change the weight of an existing edge (no-op if absent); the
    /// column renormalization `w / Σw` happens at matrix-build time.
    EdgeReweight { from: usize, to: usize, weight: f64 },
    /// Activate a dormant node with an initial set of out-links.
    NodeActivate { node: usize, targets: Vec<usize> },
    /// Deactivate a node: drop all its in- and out-edges (the coordinate
    /// stays allocated and reverts to a pure teleport sink).
    NodeDeactivate { node: usize },
}

/// An editable weighted digraph with O(log deg) edge updates and a fixed
/// coordinate capacity.
///
/// The PageRank matrix `P = d·S̄` is cached between
/// [`MutableDigraph::pagerank_system`] calls: a mutation dirties only the
/// *source* node's column (entries `s_{vu} = w(u→v)/Σ_t w(u→t)` live in
/// column u), so the next build splices unchanged column slices from the
/// cached CSC and recomputes just the dirty ones — the epoch-loop cost
/// drops from "walk every adjacency map + sort all triplets" to one
/// O(nnz) splice plus O(Σ dirty column sizes) of real work.
#[derive(Clone, Debug)]
pub struct MutableDigraph {
    n: usize,
    /// out-adjacency with per-edge weights
    out: Vec<BTreeMap<usize, f64>>,
    /// in-adjacency (sources), kept in sync for node deactivation
    ins: Vec<BTreeSet<usize>>,
    /// explicitly-activated nodes (edge inserts auto-activate endpoints)
    active: Vec<bool>,
    m: usize,
    /// sources whose out-weights changed since the last matrix build
    dirty: BTreeSet<usize>,
    cache: Option<MatrixCache>,
    /// columns recomputed by the last build (None = full rebuild);
    /// shared so the epoch protocols can ship it to every worker (and,
    /// in the local protocol, slice it per PID) without copying
    last_dirty: Option<Arc<Vec<usize>>>,
}

/// The P matrix of the last build, kept in CSC (column-contiguous) form so
/// single columns can be patched.
#[derive(Clone, Debug)]
struct MatrixCache {
    damping: f64,
    patch_dangling: bool,
    csc: CscMatrix,
}

impl MutableDigraph {
    /// An empty graph with `capacity` coordinates, all dormant.
    pub fn new(capacity: usize) -> Self {
        Self {
            n: capacity,
            out: vec![BTreeMap::new(); capacity],
            ins: vec![BTreeSet::new(); capacity],
            active: vec![false; capacity],
            m: 0,
            dirty: BTreeSet::new(),
            cache: None,
            last_dirty: None,
        }
    }

    /// Seed from a static [`Digraph`] (unit weights), leaving
    /// `capacity − g.n()` dormant coordinates for future growth.
    pub fn from_digraph(g: &Digraph, capacity: usize) -> Self {
        assert!(capacity >= g.n(), "capacity must cover the seed graph");
        let mut mg = Self::new(capacity);
        for u in 0..g.n() {
            for &v in g.out_neighbors(u) {
                mg.insert_edge(u, v, 1.0);
            }
        }
        mg
    }

    /// Coordinate capacity (the fixed system dimension).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Current edge count.
    pub fn m(&self) -> usize {
        self.m
    }

    pub fn out_degree(&self, u: usize) -> usize {
        self.out[u].len()
    }

    pub fn in_degree(&self, v: usize) -> usize {
        self.ins[v].len()
    }

    pub fn is_active(&self, u: usize) -> bool {
        self.active[u]
    }

    /// Nodes never activated (or deactivated): candidates for growth.
    pub fn dormant_nodes(&self) -> Vec<usize> {
        (0..self.n).filter(|&u| !self.active[u]).collect()
    }

    pub fn active_count(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    /// Insert `u → v` with `weight`; returns whether the graph changed.
    pub fn insert_edge(&mut self, u: usize, v: usize, weight: f64) -> bool {
        if u == v || u >= self.n || v >= self.n || weight <= 0.0 {
            return false;
        }
        if self.out[u].contains_key(&v) {
            return false;
        }
        self.out[u].insert(v, weight);
        self.ins[v].insert(u);
        self.active[u] = true;
        self.active[v] = true;
        self.m += 1;
        self.dirty.insert(u);
        true
    }

    /// Remove `u → v`; returns whether the graph changed.
    pub fn remove_edge(&mut self, u: usize, v: usize) -> bool {
        if u >= self.n || v >= self.n {
            return false;
        }
        if self.out[u].remove(&v).is_none() {
            return false;
        }
        self.ins[v].remove(&u);
        self.m -= 1;
        self.dirty.insert(u);
        true
    }

    /// Reweight an existing edge; returns whether the graph changed.
    pub fn reweight_edge(&mut self, u: usize, v: usize, weight: f64) -> bool {
        if u >= self.n || v >= self.n || weight <= 0.0 {
            return false;
        }
        let changed = match self.out[u].get_mut(&v) {
            Some(w) if *w != weight => {
                *w = weight;
                true
            }
            _ => false,
        };
        if changed {
            self.dirty.insert(u);
        }
        changed
    }

    /// Drop all edges incident to `u` and mark it dormant. Returns the
    /// number of edges removed.
    pub fn deactivate_node(&mut self, u: usize) -> usize {
        if u >= self.n {
            return 0;
        }
        let outs: Vec<usize> = self.out[u].keys().copied().collect();
        let mut removed = 0;
        for v in outs {
            if self.remove_edge(u, v) {
                removed += 1;
            }
        }
        let sources: Vec<usize> = self.ins[u].iter().copied().collect();
        for s in sources {
            if self.remove_edge(s, u) {
                removed += 1;
            }
        }
        self.active[u] = false;
        removed
    }

    /// Apply one mutation; returns whether the graph changed.
    pub fn apply(&mut self, m: &Mutation) -> bool {
        match m {
            Mutation::EdgeInsert { from, to, weight } => self.insert_edge(*from, *to, *weight),
            Mutation::EdgeDelete { from, to } => self.remove_edge(*from, *to),
            Mutation::EdgeReweight { from, to, weight } => {
                self.reweight_edge(*from, *to, *weight)
            }
            Mutation::NodeActivate { node, targets } => {
                if *node >= self.n {
                    return false;
                }
                let mut changed = !self.active[*node];
                self.active[*node] = true;
                for &t in targets {
                    changed |= self.insert_edge(*node, t, 1.0);
                }
                changed
            }
            Mutation::NodeDeactivate { node } => {
                if *node >= self.n || !self.active[*node] {
                    return false;
                }
                self.deactivate_node(*node);
                true
            }
        }
    }

    /// All current edges as `(from, to, weight)` triples.
    pub fn edges(&self) -> Vec<(usize, usize, f64)> {
        let mut out = Vec::with_capacity(self.m);
        for u in 0..self.n {
            for (&v, &w) in &self.out[u] {
                out.push((u, v, w));
            }
        }
        out
    }

    /// Snapshot as a static (unweighted) [`Digraph`].
    pub fn to_digraph(&self) -> Digraph {
        Digraph::from_edges(self.n, self.edges().into_iter().map(|(u, v, _)| (u, v)))
    }

    /// Column-renormalized link matrix: `s_{vu} = w(u→v) / Σ_t w(u→t)` —
    /// this is where edge reweights and degree changes renormalize.
    /// Zero-out-degree columns stay empty (dangling).
    pub fn link_matrix(&self) -> crate::sparse::CsrMatrix {
        let mut b = TripletBuilder::with_capacity(self.n, self.n, self.m);
        for u in 0..self.n {
            let total: f64 = self.out[u].values().sum();
            if total <= 0.0 {
                continue;
            }
            for (&v, &w) in &self.out[u] {
                b.push(v, u, w / total);
            }
        }
        b.to_csr()
    }

    /// Nodes with no out-links (dangling in PageRank terms) — includes
    /// dormant coordinates by construction.
    pub fn dangling_nodes(&self) -> Vec<usize> {
        (0..self.n).filter(|&u| self.out[u].is_empty()).collect()
    }

    /// Build the current PageRank fixed-point system `X = P·X + B`,
    /// patching only the mutated columns of the cached matrix when one is
    /// available (bit-identical to a full rebuild — property-tested).
    pub fn pagerank_system(
        &mut self,
        damping: f64,
        patch_dangling: bool,
    ) -> Result<PageRankSystem> {
        let (csc, warm) = match self.cache.take() {
            Some(c) if c.damping == damping && c.patch_dangling == patch_dangling => {
                (self.patch_csc(&c.csc, damping, patch_dangling), true)
            }
            _ => (self.build_csc(damping, patch_dangling), false),
        };
        // record which columns this build actually recomputed: streaming
        // workers patch their LocalSystems with exactly this set, and the
        // local epoch protocol broadcasts it as the mutation delta
        self.last_dirty = if warm {
            Some(Arc::new(self.dirty.iter().copied().collect()))
        } else {
            None
        };
        self.dirty.clear();
        // one O(nnz) memcpy to keep the cache copy: the SparseMatrix needs
        // its own CSC for the workers' column walks, and sharing would put
        // an Arc inside SparseMatrix crate-wide. Still far cheaper than
        // the full rebuild this replaces (adjacency walk + triplet sort).
        self.cache = Some(MatrixCache {
            damping,
            patch_dangling,
            csc: csc.clone(),
        });
        let matrix = SparseMatrix::from_csc(csc);
        let uniform = 1.0 / self.n as f64;
        Ok(PageRankSystem {
            matrix,
            b: vec![(1.0 - damping) * uniform; self.n],
            damping,
            n: self.n,
        })
    }

    /// The columns the last [`MutableDigraph::pagerank_system`] call
    /// recomputed, ascending — `None` when that build was from scratch
    /// (parameter change or cold cache), i.e. "treat everything as
    /// changed". Feeds the workers' `LocalSystem` dirty-column patching
    /// across streaming epochs.
    pub fn last_build_dirty(&self) -> Option<&[usize]> {
        self.last_dirty.as_ref().map(|d| d.as_slice())
    }

    /// [`MutableDigraph::last_build_dirty`] as a shared handle: the epoch
    /// protocols fan the same list out to every worker (gather ships it
    /// inside `Ctrl::Resume` for LocalSystem patching; the local protocol
    /// broadcasts it as the whole mutation delta), so the coordinate list
    /// is allocated once per build, never per worker.
    pub fn last_build_dirty_shared(&self) -> Option<Arc<Vec<usize>>> {
        self.last_dirty.clone()
    }

    /// Column u of `P = d·S̄` (rows ascending): the renormalized out-links
    /// of u, or the dangling teleport patch. Matches
    /// [`super::pagerank::pagerank_from_links`] bit for bit.
    fn column_entries(
        &self,
        u: usize,
        damping: f64,
        patch_dangling: bool,
        out: &mut Vec<(usize, f64)>,
    ) {
        out.clear();
        let total: f64 = self.out[u].values().sum();
        if total > 0.0 {
            for (&v, &w) in &self.out[u] {
                out.push((v, damping * (w / total)));
            }
        } else if patch_dangling {
            let w = damping * (1.0 / self.n as f64);
            for i in 0..self.n {
                out.push((i, w));
            }
        }
    }

    /// Full column-by-column build of P in CSC form.
    fn build_csc(&self, damping: f64, patch_dangling: bool) -> CscMatrix {
        let mut indptr = Vec::with_capacity(self.n + 1);
        indptr.push(0);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        let mut col = Vec::new();
        for u in 0..self.n {
            self.column_entries(u, damping, patch_dangling, &mut col);
            for &(v, val) in &col {
                indices.push(v);
                values.push(val);
            }
            indptr.push(indices.len());
        }
        CscMatrix::from_parts(self.n, self.n, indptr, indices, values)
    }

    /// Splice unchanged column slices from the cached matrix, recomputing
    /// only the dirty columns.
    fn patch_csc(&self, old: &CscMatrix, damping: f64, patch_dangling: bool) -> CscMatrix {
        let mut indptr = Vec::with_capacity(self.n + 1);
        indptr.push(0);
        let mut indices = Vec::with_capacity(old.nnz());
        let mut values = Vec::with_capacity(old.nnz());
        let mut col = Vec::new();
        for u in 0..self.n {
            if self.dirty.contains(&u) {
                self.column_entries(u, damping, patch_dangling, &mut col);
                for &(v, val) in &col {
                    indices.push(v);
                    values.push(val);
                }
            } else {
                let (rows, vals) = old.col(u);
                indices.extend_from_slice(rows);
                values.extend_from_slice(vals);
            }
            indptr.push(indices.len());
        }
        CscMatrix::from_parts(self.n, self.n, indptr, indices, values)
    }
}

/// Churn model for the mutation generator.
#[derive(Clone, Debug, PartialEq)]
pub enum ChurnModel {
    /// Growth: activate dormant nodes, each linking to `links_per_node`
    /// targets chosen (approximately) proportional to popularity —
    /// preferential attachment, the web-growth null model.
    PreferentialGrowth { links_per_node: usize },
    /// Steady-state rewire: delete a random existing edge and insert a
    /// random new one (constant edge count, shifting structure).
    RandomRewire,
    /// A burst of `burst` new edges all pointing at one suddenly-popular
    /// node — the flash-crowd / breaking-news workload.
    HotSpotBurst { burst: usize },
}

impl ChurnModel {
    /// Parse a CLI name: `grow`, `rewire`, `hotspot`.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "grow" => Some(Self::PreferentialGrowth { links_per_node: 4 }),
            "rewire" => Some(Self::RandomRewire),
            "hotspot" => Some(Self::HotSpotBurst { burst: 32 }),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::PreferentialGrowth { .. } => "grow",
            Self::RandomRewire => "rewire",
            Self::HotSpotBurst { .. } => "hotspot",
        }
    }
}

/// Seeded generator of mutation batches against the current graph state.
#[derive(Clone, Debug)]
pub struct MutationStream {
    model: ChurnModel,
    rng: Xoshiro256pp,
}

impl MutationStream {
    pub fn new(model: ChurnModel, seed: u64) -> Self {
        Self {
            model,
            rng: Xoshiro256pp::seed_from_u64(seed),
        }
    }

    pub fn model(&self) -> &ChurnModel {
        &self.model
    }

    /// Pick an active node, biased towards high degree by sampling a
    /// random edge endpoint 70% of the time (the pool trick of the static
    /// generators — approximate preferential attachment).
    fn popular_node(&mut self, g: &MutableDigraph) -> Option<usize> {
        for _ in 0..64 {
            let u = self.rng.below(g.n());
            if g.out_degree(u) > 0 && self.rng.chance(0.7) {
                // follow a random out-edge: targets are in-degree biased
                let k = self.rng.below(g.out_degree(u));
                let (v, _) = g.edges_of(u)[k];
                return Some(v);
            }
            if g.is_active(u) {
                return Some(u);
            }
        }
        None
    }

    /// A uniformly-random existing edge (None if the graph is empty).
    fn random_edge(&mut self, g: &MutableDigraph) -> Option<(usize, usize)> {
        if g.m() == 0 {
            return None;
        }
        for _ in 0..256 {
            let u = self.rng.below(g.n());
            let d = g.out_degree(u);
            if d > 0 {
                let k = self.rng.below(d);
                let (v, _) = g.edges_of(u)[k];
                return Some((u, v));
            }
        }
        None
    }

    /// Generate the next batch of up to `size` mutations for `g`
    /// (`size == 0` yields an empty batch — a no-churn epoch).
    /// Deterministic given the seed and the sequence of graph states.
    pub fn next_batch(&mut self, g: &MutableDigraph, size: usize) -> Vec<Mutation> {
        if size == 0 {
            return Vec::new();
        }
        let mut batch = Vec::with_capacity(size);
        match self.model.clone() {
            ChurnModel::PreferentialGrowth { links_per_node } => {
                let dormant = g.dormant_nodes();
                let mut di = 0usize;
                while batch.len() < size && di < dormant.len() {
                    let node = dormant[di];
                    di += 1;
                    let mut targets = Vec::with_capacity(links_per_node);
                    for _ in 0..links_per_node {
                        if let Some(t) = self.popular_node(g) {
                            if t != node && !targets.contains(&t) {
                                targets.push(t);
                            }
                        }
                    }
                    if targets.is_empty() {
                        // bootstrap an empty graph: link to a random peer
                        let t = self.rng.below(g.n());
                        if t != node {
                            targets.push(t);
                        }
                    }
                    batch.push(Mutation::NodeActivate { node, targets });
                }
                // graph full: fall back to densification edges
                let mut tries = 0;
                while batch.len() < size && tries < 16 * size {
                    tries += 1;
                    let u = self.rng.below(g.n());
                    let v = self.rng.below(g.n());
                    if u != v {
                        batch.push(Mutation::EdgeInsert {
                            from: u,
                            to: v,
                            weight: 1.0,
                        });
                    }
                }
            }
            ChurnModel::RandomRewire => {
                // one reweight per batch first (so delete/insert pairs
                // filling the batch to an even size cannot truncate it away)
                if let Some((u, v)) = self.random_edge(g) {
                    batch.push(Mutation::EdgeReweight {
                        from: u,
                        to: v,
                        weight: self.rng.uniform(0.5, 4.0),
                    });
                }
                while batch.len() + 1 < size {
                    let Some((u, v)) = self.random_edge(g) else { break };
                    batch.push(Mutation::EdgeDelete { from: u, to: v });
                    // reconnect the source somewhere popular (or random)
                    let t = self
                        .popular_node(g)
                        .unwrap_or_else(|| self.rng.below(g.n()));
                    if t != u {
                        batch.push(Mutation::EdgeInsert {
                            from: u,
                            to: t,
                            weight: 1.0,
                        });
                    }
                }
            }
            ChurnModel::HotSpotBurst { burst } => {
                let hot = self
                    .popular_node(g)
                    .unwrap_or_else(|| self.rng.below(g.n()));
                let count = burst.min(size);
                let mut tries = 0;
                while batch.len() < count && tries < 16 * count {
                    tries += 1;
                    let src = self.rng.below(g.n());
                    if src != hot {
                        batch.push(Mutation::EdgeInsert {
                            from: src,
                            to: hot,
                            weight: 1.0,
                        });
                    }
                }
            }
        }
        batch.truncate(size);
        batch
    }
}

impl MutableDigraph {
    /// Out-edges of `u` as a materialized `(target, weight)` list (the
    /// BTreeMap has no random access; batch sizes are small).
    fn edges_of(&self, u: usize) -> Vec<(usize, f64)> {
        self.out[u].iter().map(|(&v, &w)| (v, w)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::power_law_web_graph;

    fn seeded(n: usize) -> MutableDigraph {
        let g = power_law_web_graph(n, 4, 0.1, 7);
        MutableDigraph::from_digraph(&g, n + 16)
    }

    #[test]
    fn edge_ops_keep_counts_consistent() {
        let mut g = MutableDigraph::new(8);
        assert!(g.insert_edge(0, 1, 1.0));
        assert!(!g.insert_edge(0, 1, 1.0), "duplicate rejected");
        assert!(!g.insert_edge(2, 2, 1.0), "self-loop rejected");
        assert!(g.insert_edge(1, 2, 2.0));
        assert_eq!(g.m(), 2);
        assert_eq!(g.in_degree(2), 1);
        assert!(g.remove_edge(0, 1));
        assert!(!g.remove_edge(0, 1));
        assert_eq!(g.m(), 1);
    }

    #[test]
    fn reweight_renormalizes_columns() {
        let mut g = MutableDigraph::new(4);
        g.insert_edge(0, 1, 1.0);
        g.insert_edge(0, 2, 1.0);
        let s = g.link_matrix();
        assert_eq!(s.get(1, 0), 0.5);
        assert!(g.reweight_edge(0, 1, 3.0));
        let s = g.link_matrix();
        assert!((s.get(1, 0) - 0.75).abs() < 1e-15);
        assert!((s.get(2, 0) - 0.25).abs() < 1e-15);
        assert!(!g.reweight_edge(0, 3, 1.0), "absent edge not reweighted");
    }

    #[test]
    fn deactivate_drops_both_directions() {
        let mut g = MutableDigraph::new(6);
        g.insert_edge(0, 1, 1.0);
        g.insert_edge(2, 1, 1.0);
        g.insert_edge(1, 3, 1.0);
        assert_eq!(g.deactivate_node(1), 3);
        assert_eq!(g.m(), 0);
        assert!(!g.is_active(1));
        assert!(g.is_active(0), "peers stay active");
    }

    #[test]
    fn mutations_apply_and_report_changes() {
        let mut g = MutableDigraph::new(8);
        assert!(g.apply(&Mutation::NodeActivate {
            node: 0,
            targets: vec![1, 2],
        }));
        assert!(g.apply(&Mutation::EdgeReweight {
            from: 0,
            to: 1,
            weight: 2.0,
        }));
        assert!(g.apply(&Mutation::EdgeDelete { from: 0, to: 2 }));
        assert!(!g.apply(&Mutation::EdgeDelete { from: 0, to: 2 }));
        assert!(g.apply(&Mutation::NodeDeactivate { node: 0 }));
        assert_eq!(g.m(), 0);
    }

    #[test]
    fn pagerank_system_matches_digraph_path() {
        // unit weights: the mutable path must produce the same system as
        // the static Digraph path
        let g = power_law_web_graph(200, 5, 0.1, 3);
        let mut mg = MutableDigraph::from_digraph(&g, 200);
        let a = crate::graph::pagerank_system(&g, 0.85, true).unwrap();
        let b = mg.pagerank_system(0.85, true).unwrap();
        assert_eq!(a.matrix.csr().to_dense(), b.matrix.csr().to_dense());
        assert_eq!(a.b, b.b);
    }

    #[test]
    fn incremental_rebuild_equals_full_rebuild() {
        // mutate, rebuild incrementally (cache warm), and compare against
        // a cache-cold clone of the same graph state — bit-identical
        let g = power_law_web_graph(80, 4, 0.1, 5);
        let mut mg = MutableDigraph::from_digraph(&g, 90);
        for (round, patch_dangling) in [true, false].into_iter().enumerate() {
            mg.pagerank_system(0.85, patch_dangling).unwrap(); // warm the cache
            assert!(mg.apply(&Mutation::EdgeInsert {
                from: 2 + round,
                to: 81,
                weight: 3.0,
            }));
            assert!(mg.apply(&Mutation::EdgeDelete {
                from: 2 + round,
                to: 81,
            }));
            // node 2+round is certainly active (we just inserted from it);
            // deactivation also dirties every in-neighbor's column
            assert!(mg.apply(&Mutation::NodeDeactivate { node: 2 + round }));
            let inc = mg.pagerank_system(0.85, patch_dangling).unwrap();
            let mut cold = MutableDigraph::new(90);
            for (u, v, w) in mg.edges() {
                cold.insert_edge(u, v, w);
            }
            let full = cold.pagerank_system(0.85, patch_dangling).unwrap();
            assert_eq!(inc.matrix.csr().to_dense(), full.matrix.csr().to_dense());
            assert_eq!(inc.b, full.b);
        }
    }

    #[test]
    fn last_build_dirty_reports_patched_columns() {
        let g = power_law_web_graph(40, 4, 0.1, 9);
        let mut mg = MutableDigraph::from_digraph(&g, 41);
        mg.pagerank_system(0.85, true).unwrap();
        assert!(mg.last_build_dirty().is_none(), "cold build patches nothing");
        // node 40 is dormant padding, so the edge is certainly new
        assert!(mg.apply(&Mutation::EdgeInsert {
            from: 3,
            to: 40,
            weight: 2.0,
        }));
        mg.pagerank_system(0.85, true).unwrap();
        assert_eq!(mg.last_build_dirty(), Some(&[3usize][..]));
        // the shared handle exposes the same list without copying
        let shared = mg.last_build_dirty_shared().unwrap();
        assert_eq!(shared.as_slice(), &[3usize]);
        // a no-mutation rebuild reports an empty dirty set
        mg.pagerank_system(0.85, true).unwrap();
        assert_eq!(mg.last_build_dirty(), Some::<&[usize]>(&[]));
        // a parameter change forces a full rebuild again
        mg.pagerank_system(0.90, true).unwrap();
        assert!(mg.last_build_dirty().is_none());
    }

    #[test]
    fn deactivation_dirties_in_neighbor_columns() {
        // removing node u's in-edges changes the *source* columns; the
        // incremental path must renormalize them
        let mut g = MutableDigraph::new(4);
        g.insert_edge(0, 1, 1.0);
        g.insert_edge(0, 2, 1.0);
        g.pagerank_system(0.85, true).unwrap();
        g.apply(&Mutation::NodeDeactivate { node: 1 });
        let sys = g.pagerank_system(0.85, true).unwrap();
        // column 0 renormalized onto the surviving edge 0→2
        assert!((sys.matrix.csr().get(2, 0) - 0.85).abs() < 1e-15);
        assert_eq!(sys.matrix.csr().get(1, 0), 0.0);
    }

    #[test]
    fn cache_invalidated_on_parameter_change() {
        let g = power_law_web_graph(40, 4, 0.1, 9);
        let mut mg = MutableDigraph::from_digraph(&g, 40);
        let a = mg.pagerank_system(0.85, true).unwrap();
        let b = mg.pagerank_system(0.90, true).unwrap(); // different damping
        assert!(a.matrix.csr().to_dense() != b.matrix.csr().to_dense());
        let mut cold = MutableDigraph::from_digraph(&g, 40);
        let want = cold.pagerank_system(0.90, true).unwrap();
        assert_eq!(b.matrix.csr().to_dense(), want.matrix.csr().to_dense());
    }

    #[test]
    fn growth_model_activates_dormant_nodes() {
        let mut g = seeded(100);
        let dormant_before = g.dormant_nodes().len();
        assert!(dormant_before >= 16, "padding provides dormant capacity");
        let mut stream = MutationStream::new(
            ChurnModel::PreferentialGrowth { links_per_node: 3 },
            11,
        );
        let batch = stream.next_batch(&g, 8);
        assert!(!batch.is_empty());
        let applied = batch.iter().filter(|m| g.apply(m)).count();
        assert!(applied > 0);
        assert!(g.dormant_nodes().len() < dormant_before);
    }

    #[test]
    fn rewire_model_preserves_edge_count_roughly() {
        let mut g = seeded(100);
        let m0 = g.m();
        let mut stream = MutationStream::new(ChurnModel::RandomRewire, 5);
        for _ in 0..4 {
            let batch = stream.next_batch(&g, 20);
            for m in &batch {
                g.apply(m);
            }
        }
        let m1 = g.m();
        let drift = (m1 as i64 - m0 as i64).unsigned_abs() as usize;
        assert!(drift <= 80, "rewire drifted too much: {m0} -> {m1}");
    }

    #[test]
    fn hotspot_model_concentrates_in_degree() {
        let mut g = seeded(100);
        let mut stream = MutationStream::new(ChurnModel::HotSpotBurst { burst: 24 }, 9);
        let batch = stream.next_batch(&g, 24);
        let mut targets: Vec<usize> = batch
            .iter()
            .filter_map(|m| match m {
                Mutation::EdgeInsert { to, .. } => Some(*to),
                _ => None,
            })
            .collect();
        targets.sort_unstable();
        targets.dedup();
        assert_eq!(targets.len(), 1, "one hot node per burst");
    }

    #[test]
    fn streams_are_deterministic_under_seed() {
        let g = seeded(60);
        let mut a = MutationStream::new(ChurnModel::RandomRewire, 42);
        let mut b = MutationStream::new(ChurnModel::RandomRewire, 42);
        assert_eq!(a.next_batch(&g, 10), b.next_batch(&g, 10));
    }
}
