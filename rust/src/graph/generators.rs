//! Synthetic workload generators (DESIGN.md §3 substitutions).

use super::Digraph;
use crate::linalg::DenseMat;
use crate::prng::Xoshiro256pp;
use crate::sparse::TripletBuilder;

/// Erdős–Rényi G(n, p) digraph (no self-loops).
pub fn erdos_renyi_digraph(n: usize, p: f64, seed: u64) -> Digraph {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut g = Digraph::new(n);
    for u in 0..n {
        for v in 0..n {
            if u != v && rng.chance(p) {
                g.add_edge(u, v);
            }
        }
    }
    g.finish();
    g
}

/// Preferential-attachment (Barabási–Albert style) digraph: each new node
/// links to `m_links` earlier nodes chosen ∝ in-degree+1. Produces the
/// heavy-tailed in-degree distribution of web-like graphs.
pub fn barabasi_albert_digraph(n: usize, m_links: usize, seed: u64) -> Digraph {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut g = Digraph::new(n);
    // target pool: nodes repeated once per in-link (+1 smoothing implied by
    // seeding each node once when it appears)
    let mut pool: Vec<usize> = Vec::with_capacity(2 * n * m_links);
    if n > 0 {
        pool.push(0);
    }
    for u in 1..n {
        let k = m_links.min(u);
        let mut chosen = Vec::with_capacity(k);
        let mut guard = 0;
        while chosen.len() < k && guard < 50 * k {
            let t = pool[rng.below(pool.len())];
            if t != u && !chosen.contains(&t) {
                chosen.push(t);
            }
            guard += 1;
        }
        // fall back to uniform picks if the pool was too concentrated
        while chosen.len() < k {
            let t = rng.below(u);
            if !chosen.contains(&t) {
                chosen.push(t);
            }
        }
        for &t in &chosen {
            g.add_edge(u, t);
            pool.push(t);
        }
        pool.push(u);
    }
    g.finish();
    g
}

/// Power-law "web-like" digraph: out-degrees ~ Zipf(s) capped at
/// `max_out`, targets chosen by preferential attachment, plus a fraction
/// of dangling nodes (pages with no out-links) — the workload shape of the
/// paper's intended PageRank application.
pub fn power_law_web_graph(
    n: usize,
    avg_out: usize,
    dangling_frac: f64,
    seed: u64,
) -> Digraph {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut g = Digraph::new(n);
    // in-degree-proportional target pool (seeded uniformly)
    let mut pool: Vec<usize> = (0..n).collect();
    let max_out = (avg_out * 10).max(4);
    for u in 0..n {
        if rng.chance(dangling_frac) {
            continue; // a dangling page
        }
        // Zipf out-degree with mean ≈ avg_out: draw z in 1..=max_out then
        // rescale towards the mean.
        let z = rng.zipf(max_out, 2.0);
        let deg = (z * avg_out).div_ceil(2).clamp(1, max_out);
        for _ in 0..deg {
            let t = if rng.chance(0.8) {
                pool[rng.below(pool.len())]
            } else {
                rng.below(n)
            };
            if t != u {
                g.add_edge(u, t);
                pool.push(t);
            }
        }
    }
    g.finish();
    g
}

/// 2-D torus grid digraph (each cell links to its 4 neighbors): the
/// maximal-locality workload for partitioning experiments.
pub fn grid_digraph(side: usize) -> Digraph {
    let n = side * side;
    let mut g = Digraph::new(n);
    let at = |r: usize, c: usize| r * side + c;
    for r in 0..side {
        for c in 0..side {
            let u = at(r, c);
            g.add_edge(u, at((r + 1) % side, c));
            g.add_edge(u, at((r + side - 1) % side, c));
            g.add_edge(u, at(r, (c + 1) % side));
            g.add_edge(u, at(r, (c + side - 1) % side));
        }
    }
    g.finish();
    g
}

/// Block-structured iteration matrix with tunable inter-block coupling —
/// the continuous version of the paper's A(1) → A(3) progression (Fig 1–3).
///
/// Builds a row-substochastic P with `k` diagonal blocks of size `n/k`;
/// within-block entries sum to `intra`, cross-block entries to `coupling`
/// per row (`intra + coupling < 1` keeps ρ(P) < 1). `coupling = 0`
/// reproduces the fully separable A(1) case.
pub fn block_coupled_matrix(
    n: usize,
    k: usize,
    intra: f64,
    coupling: f64,
    nnz_per_row: usize,
    seed: u64,
) -> crate::sparse::CsrMatrix {
    assert!(k >= 1 && n >= k, "need n >= k >= 1");
    assert!(
        intra + coupling < 1.0,
        "intra + coupling must stay below 1 for convergence"
    );
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let block = n / k;
    let mut b = TripletBuilder::with_capacity(n, n, n * nnz_per_row);
    for i in 0..n {
        let my_block = (i / block).min(k - 1);
        let (lo, hi) = block_range(n, k, my_block);
        // within-block targets
        let in_block: Vec<usize> = pick_distinct(&mut rng, lo, hi, nnz_per_row, i);
        let w_in = if in_block.is_empty() {
            0.0
        } else {
            intra / in_block.len() as f64
        };
        for &j in &in_block {
            // alternate signs like the paper's P (negative off-diagonals)
            let sign = if rng.chance(0.5) { -1.0 } else { 1.0 };
            b.push(i, j, sign * w_in);
        }
        // cross-block targets
        if coupling > 0.0 && k > 1 {
            let cross_cnt = nnz_per_row.div_ceil(2).max(1);
            let mut picked = Vec::with_capacity(cross_cnt);
            let mut guard = 0;
            while picked.len() < cross_cnt && guard < 100 {
                let j = rng.below(n);
                let jb = (j / block).min(k - 1);
                if jb != my_block && j != i && !picked.contains(&j) {
                    picked.push(j);
                }
                guard += 1;
            }
            if !picked.is_empty() {
                let w = coupling / picked.len() as f64;
                for &j in &picked {
                    let sign = if rng.chance(0.5) { -1.0 } else { 1.0 };
                    b.push(i, j, sign * w);
                }
            }
        }
    }
    b.to_csr()
}

fn block_range(n: usize, k: usize, blk: usize) -> (usize, usize) {
    let base = n / k;
    let lo = blk * base;
    let hi = if blk == k - 1 { n } else { lo + base };
    (lo, hi)
}

fn pick_distinct(
    rng: &mut Xoshiro256pp,
    lo: usize,
    hi: usize,
    want: usize,
    exclude: usize,
) -> Vec<usize> {
    let avail: Vec<usize> = (lo..hi).filter(|&j| j != exclude).collect();
    if avail.is_empty() {
        return Vec::new();
    }
    let k = want.min(avail.len());
    let idx = rng.sample_distinct(avail.len(), k);
    idx.into_iter().map(|t| avail[t]).collect()
}

/// A synthetic joint publications+authors graph (paper ref [5]): papers
/// cite older papers (power-law), authors write papers, and the joint
/// ranking couples the two node classes.
#[derive(Clone, Debug)]
pub struct PaperAuthorGraph {
    /// node ids: `0..n_papers` are papers, `n_papers..n_papers+n_authors`
    /// are authors.
    pub graph: Digraph,
    pub n_papers: usize,
    pub n_authors: usize,
}

/// Generate the paper–author graph: citation edges paper→paper, authorship
/// edges paper→author and author→paper (the mutual-reinforcement loops of
/// the joint ranking).
pub fn paper_author_graph(
    n_papers: usize,
    n_authors: usize,
    cites_per_paper: usize,
    authors_per_paper: usize,
    seed: u64,
) -> PaperAuthorGraph {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let n = n_papers + n_authors;
    let mut g = Digraph::new(n);
    // citation pool for preferential attachment among papers
    let mut pool: Vec<usize> = vec![0];
    for p in 1..n_papers {
        let k = cites_per_paper.min(p);
        for _ in 0..k {
            let t = if rng.chance(0.7) {
                pool[rng.below(pool.len())]
            } else {
                rng.below(p)
            };
            g.add_edge(p, t);
            pool.push(t);
        }
        pool.push(p);
    }
    // authorship: papers ↔ authors (author popularity is Zipf)
    for p in 0..n_papers {
        let k = authors_per_paper.max(1);
        for _ in 0..k {
            let a = n_papers + (rng.zipf(n_authors, 1.5) - 1);
            g.add_edge(p, a);
            g.add_edge(a, p);
        }
    }
    g.finish();
    PaperAuthorGraph {
        graph: g,
        n_papers,
        n_authors,
    }
}

/// The paper's worked 4×4 systems (§5.1/§5.2), as dense matrices.
pub fn paper_matrix(which: u8) -> DenseMat {
    match which {
        1 => DenseMat::from_rows(&[
            &[5.0, 3.0, 0.0, 0.0],
            &[3.0, 7.0, 0.0, 0.0],
            &[0.0, 0.0, 8.0, 4.0],
            &[0.0, 0.0, 2.0, 3.0],
        ]),
        2 => DenseMat::from_rows(&[
            &[5.0, 3.0, 1.0, 1.0],
            &[3.0, 7.0, 1.0, 0.0],
            &[1.0, 1.0, 8.0, 4.0],
            &[1.0, 1.0, 2.0, 3.0],
        ]),
        3 => DenseMat::from_rows(&[
            &[5.0, 3.0, 1.0, 1.0],
            &[3.0, 7.0, 1.0, 1.0],
            &[1.0, 1.0, 8.0, 4.0],
            &[1.0, 1.0, 2.0, 3.0],
        ]),
        // §5.2's A' (A(1) with entry (2,4) = 1, 1-indexed)
        4 => DenseMat::from_rows(&[
            &[5.0, 3.0, 0.0, 0.0],
            &[3.0, 7.0, 0.0, 1.0],
            &[0.0, 0.0, 8.0, 4.0],
            &[0.0, 0.0, 2.0, 3.0],
        ]),
        _ => panic!("paper_matrix: which must be 1..=4"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn er_edge_count_close_to_expectation() {
        let n = 200;
        let p = 0.05;
        let g = erdos_renyi_digraph(n, p, 42);
        let expected = (n * (n - 1)) as f64 * p;
        let got = g.m() as f64;
        assert!(
            (got - expected).abs() < expected * 0.25,
            "got {got}, expected ~{expected}"
        );
    }

    #[test]
    fn ba_graph_has_heavy_tail() {
        let g = barabasi_albert_digraph(500, 3, 7);
        // in-degree distribution: compute via link counts
        let mut indeg = vec![0usize; g.n()];
        for u in 0..g.n() {
            for &v in g.out_neighbors(u) {
                indeg[v] += 1;
            }
        }
        let max_in = *indeg.iter().max().unwrap();
        let mean_in = indeg.iter().sum::<usize>() as f64 / g.n() as f64;
        assert!(
            max_in as f64 > 6.0 * mean_in,
            "max {max_in} vs mean {mean_in}"
        );
    }

    #[test]
    fn web_graph_has_dangling_nodes() {
        let g = power_law_web_graph(1000, 8, 0.15, 3);
        let dangling = g.dangling_nodes().len();
        assert!(
            dangling > 50 && dangling < 400,
            "dangling={dangling} out of 1000"
        );
    }

    #[test]
    fn grid_degrees() {
        let g = grid_digraph(5);
        assert_eq!(g.n(), 25);
        for u in 0..g.n() {
            assert_eq!(g.out_degree(u), 4);
        }
    }

    #[test]
    fn block_matrix_contractive_and_blocky() {
        let p = block_coupled_matrix(64, 4, 0.6, 0.0, 4, 5);
        // zero coupling → all entries within diagonal blocks
        for i in 0..64 {
            let (idx, _) = p.row(i);
            for &j in idx {
                assert_eq!(i / 16, j / 16, "entry ({i},{j}) crosses blocks");
            }
        }
        let rows = p.row_l1_norms();
        assert!(rows.iter().all(|&r| r < 1.0));
    }

    #[test]
    fn block_matrix_coupling_crosses() {
        let p = block_coupled_matrix(64, 4, 0.4, 0.3, 4, 5);
        let crossing = (0..64)
            .flat_map(|i| {
                let (idx, _) = p.row(i);
                idx.iter().map(move |&j| (i, j))
            })
            .filter(|&(i, j)| i / 16 != j / 16)
            .count();
        assert!(crossing > 0);
        let rows = p.row_l1_norms();
        assert!(rows.iter().all(|&r| r < 1.0 + 1e-12));
    }

    #[test]
    fn paper_author_bipartite_structure() {
        let pa = paper_author_graph(100, 20, 3, 2, 11);
        assert_eq!(pa.graph.n(), 120);
        // authors only link to papers
        for a in 100..120 {
            for &t in pa.graph.out_neighbors(a) {
                assert!(t < 100, "author {a} links to non-paper {t}");
            }
        }
        // papers cite only older papers or authors
        for p in 0..100 {
            for &t in pa.graph.out_neighbors(p) {
                assert!(t < p || t >= 100);
            }
        }
    }

    #[test]
    fn paper_matrices_match_text() {
        let a1 = paper_matrix(1);
        let a2 = paper_matrix(2);
        let a3 = paper_matrix(3);
        let a4 = paper_matrix(4);
        assert_eq!(a1[(1, 3)], 0.0);
        assert_eq!(a2[(1, 3)], 0.0);
        assert_eq!(a3[(1, 3)], 1.0); // the single added entry of A(3)
        assert_eq!(a4[(1, 3)], 1.0); // A' of §5.2
        assert_eq!(a2[(0, 2)], 1.0);
        assert_eq!(a1[(0, 2)], 0.0);
    }
}
