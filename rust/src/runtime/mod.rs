//! PJRT runtime: load the AOT artifacts (HLO text lowered from JAX/Pallas
//! by `python/compile/aot.py`) and execute them from the rust hot path.
//!
//! The XLA-backed implementation lives in [`pjrt`] behind the **off-by-
//! default `pjrt` cargo feature**: a clean machine with no native XLA
//! toolchain builds the [`stub`] instead, which exposes the identical API
//! but reports artifacts as unavailable (so every call site and the
//! artifact-gated integration tests keep compiling and skip gracefully).
//!
//! Wiring of the real runtime (see /opt/xla-example): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Interchange is HLO **text** because the
//! crate's xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit-id protos.
//!
//! Python never runs here: once `make artifacts` has produced
//! `artifacts/*.hlo.txt` + `manifest.txt`, the rust binary is
//! self-contained. Executables are compiled once per (kind, shape) and
//! cached.

mod manifest;

pub use manifest::{ArtifactEntry, Manifest};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{DenseAccelerator, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{DenseAccelerator, Runtime};

use crate::error::{DiterError, Result};
use std::path::PathBuf;

/// Default artifact directory (next to the workspace root).
pub fn default_artifact_dir() -> PathBuf {
    // tests/benches run from the crate root; the env var wins
    std::env::var_os("DITER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

// only the feature-gated pjrt module calls this outside of tests
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
pub(crate) fn check_len(what: &'static str, got: usize, want: usize) -> Result<()> {
    if got != want {
        return Err(DiterError::shape(what, want, got));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_len_errors() {
        assert!(check_len("x", 3, 4).is_err());
        assert!(check_len("x", 4, 4).is_ok());
    }

    #[test]
    fn stub_or_real_runtime_reports_unavailable_without_artifacts() {
        // without DITER_ARTIFACTS pointing at a manifest, both the real
        // runtime and the stub must say "no artifacts" (the signal the
        // artifact-gated integration tests key off)
        if std::env::var_os("DITER_ARTIFACTS").is_none()
            && !default_artifact_dir().join("manifest.txt").exists()
        {
            assert!(!Runtime::artifacts_available());
        }
    }
}
