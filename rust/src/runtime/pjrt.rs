//! The XLA/PJRT-backed runtime (compiled only with the `pjrt` feature).
//!
//! NOTE: this module requires the `xla` crate (xla_extension 0.5.1) in
//! `[dependencies]`; it is intentionally not declared in Cargo.toml so the
//! default (feature-off) build resolves with zero registry access. Add
//! `xla = "0.5.1"` before enabling the feature.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use super::{check_len, default_artifact_dir, Manifest};
use crate::error::{DiterError, Result};

fn rt_err<E: std::fmt::Debug>(what: &'static str) -> impl FnOnce(E) -> DiterError {
    move |e| DiterError::Runtime(format!("{what}: {e:?}"))
}

/// The PJRT-backed kernel runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Default artifact directory (next to the workspace root).
    pub fn default_dir() -> PathBuf {
        default_artifact_dir()
    }

    /// True if the artifact directory looks usable.
    pub fn artifacts_available() -> bool {
        Self::default_dir().join("manifest.txt").exists()
    }

    /// Load the manifest and start a CPU PJRT client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(rt_err("PjRtClient::cpu"))?;
        Ok(Runtime {
            client,
            manifest,
            dir,
            cache: HashMap::new(),
        })
    }

    pub fn load_default() -> Result<Runtime> {
        Self::load(Self::default_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the executable for (kind, dims).
    fn executable(&mut self, kind: &str, dims: &[usize]) -> Result<&xla::PjRtLoadedExecutable> {
        let entry = self
            .manifest
            .find(kind, dims)
            .ok_or_else(|| {
                DiterError::Runtime(format!(
                    "no artifact for {kind} dims {dims:?} in {}",
                    self.dir.display()
                ))
            })?
            .clone();
        let key = entry.key();
        if !self.cache.contains_key(&key) {
            let proto = xla::HloModuleProto::from_text_file(&entry.file)
                .map_err(rt_err("HloModuleProto::from_text_file"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(rt_err("client.compile"))?;
            self.cache.insert(key.clone(), exe);
        }
        Ok(&self.cache[&key])
    }

    /// Execute an artifact on literals; returns the flattened tuple parts.
    fn exec(
        &mut self,
        kind: &str,
        dims: &[usize],
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(kind, dims)?;
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(rt_err("execute"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(rt_err("to_literal_sync"))?;
        // aot.py lowers with return_tuple=True
        lit.to_tuple().map_err(rt_err("to_tuple"))
    }

    /// One D-iteration sweep over a dense row block (`d_sweep` artifact):
    /// `H[idx[t]] ← P_rows[t]·H + B[t]` sequentially for t in 0..m.
    pub fn d_sweep(
        &mut self,
        m: usize,
        n: usize,
        p_rows: &[f64],
        idx: &[i32],
        h: &[f64],
        b: &[f64],
    ) -> Result<Vec<f64>> {
        check_len("p_rows", p_rows.len(), m * n)?;
        check_len("idx", idx.len(), m)?;
        check_len("h", h.len(), n)?;
        check_len("b", b.len(), m)?;
        let p_lit = xla::Literal::vec1(p_rows)
            .reshape(&[m as i64, n as i64])
            .map_err(rt_err("reshape p"))?;
        let args = [
            p_lit,
            xla::Literal::vec1(idx),
            xla::Literal::vec1(h),
            xla::Literal::vec1(b),
        ];
        let parts = self.exec("d_sweep", &[m, n], &args)?;
        parts[0].to_vec::<f64>().map_err(rt_err("to_vec"))
    }

    /// A PID work quantum (`d_round` artifact): two sweeps + block fluid.
    /// Returns (new H, block fluid, r_k).
    pub fn d_round(
        &mut self,
        m: usize,
        n: usize,
        p_rows: &[f64],
        idx: &[i32],
        h: &[f64],
        b: &[f64],
    ) -> Result<(Vec<f64>, Vec<f64>, f64)> {
        check_len("p_rows", p_rows.len(), m * n)?;
        let p_lit = xla::Literal::vec1(p_rows)
            .reshape(&[m as i64, n as i64])
            .map_err(rt_err("reshape p"))?;
        let args = [
            p_lit,
            xla::Literal::vec1(idx),
            xla::Literal::vec1(h),
            xla::Literal::vec1(b),
        ];
        let parts = self.exec("d_round", &[m, n], &args)?;
        let h2 = parts[0].to_vec::<f64>().map_err(rt_err("h"))?;
        let fluid = parts[1].to_vec::<f64>().map_err(rt_err("fluid"))?;
        let rk = parts[2].get_first_element::<f64>().map_err(rt_err("rk"))?;
        Ok((h2, fluid, rk))
    }

    /// One synchronous Jacobi step (`jacobi_step` artifact).
    pub fn jacobi_step(&mut self, n: usize, p: &[f64], h: &[f64], b: &[f64]) -> Result<Vec<f64>> {
        check_len("p", p.len(), n * n)?;
        let p_lit = xla::Literal::vec1(p)
            .reshape(&[n as i64, n as i64])
            .map_err(rt_err("reshape p"))?;
        let args = [p_lit, xla::Literal::vec1(h), xla::Literal::vec1(b)];
        let parts = self.exec("jacobi_step", &[n], &args)?;
        parts[0].to_vec::<f64>().map_err(rt_err("to_vec"))
    }

    /// Global remaining fluid (`fluid_norm` artifact).
    pub fn fluid_norm(&mut self, n: usize, p: &[f64], h: &[f64], b: &[f64]) -> Result<f64> {
        let p_lit = xla::Literal::vec1(p)
            .reshape(&[n as i64, n as i64])
            .map_err(rt_err("reshape p"))?;
        let args = [p_lit, xla::Literal::vec1(h), xla::Literal::vec1(b)];
        let parts = self.exec("fluid_norm", &[n], &args)?;
        parts[0].get_first_element::<f64>().map_err(rt_err("r"))
    }

    /// One normalized power step (`power_step` artifact).
    pub fn power_step(&mut self, n: usize, p: &[f64], x: &[f64]) -> Result<Vec<f64>> {
        let p_lit = xla::Literal::vec1(p)
            .reshape(&[n as i64, n as i64])
            .map_err(rt_err("reshape p"))?;
        let args = [p_lit, xla::Literal::vec1(x)];
        let parts = self.exec("power_step", &[n], &args)?;
        parts[0].to_vec::<f64>().map_err(rt_err("to_vec"))
    }

    /// One dense PageRank step (`pagerank_step` artifact).
    pub fn pagerank_step(
        &mut self,
        n: usize,
        s: &[f64],
        x: &[f64],
        teleport: &[f64],
        damping: f64,
    ) -> Result<Vec<f64>> {
        let s_lit = xla::Literal::vec1(s)
            .reshape(&[n as i64, n as i64])
            .map_err(rt_err("reshape s"))?;
        let args = [
            s_lit,
            xla::Literal::vec1(x),
            xla::Literal::vec1(teleport),
            xla::Literal::from(damping),
        ];
        let parts = self.exec("pagerank_step", &[n], &args)?;
        parts[0].to_vec::<f64>().map_err(rt_err("to_vec"))
    }
}

/// Hot-path dense-block engine for one partition: keeps the row block and
/// index data prepared so a PID's work quantum is a single PJRT call
/// (`d_round`: two sweeps + fluid + r_k in one fused program).
pub struct DenseAccelerator {
    m: usize,
    n: usize,
    p_rows: Vec<f64>,
    idx: Vec<i32>,
    b: Vec<f64>,
}

impl DenseAccelerator {
    /// Prepare a dense block for `owned` rows of `problem`. Fails if no
    /// artifact was compiled for this (m, n).
    pub fn prepare(
        runtime: &Runtime,
        problem: &crate::solver::FixedPointProblem,
        owned: &[usize],
    ) -> Result<DenseAccelerator> {
        let m = owned.len();
        let n = problem.n();
        if runtime.manifest().find("d_round", &[m, n]).is_none() {
            return Err(DiterError::Runtime(format!(
                "no d_round artifact for shape {m}x{n} — recompile via `make artifacts`"
            )));
        }
        let p_rows = problem.matrix().csr().dense_row_block(owned);
        let idx: Vec<i32> = owned.iter().map(|&i| i as i32).collect();
        let b: Vec<f64> = owned.iter().map(|&i| problem.b()[i]).collect();
        Ok(DenseAccelerator {
            m,
            n,
            p_rows,
            idx,
            b,
        })
    }

    /// Run one work quantum on the PJRT runtime. Returns (H', fluid, r_k).
    pub fn round(&self, runtime: &mut Runtime, h: &[f64]) -> Result<(Vec<f64>, Vec<f64>, f64)> {
        runtime.d_round(self.m, self.n, &self.p_rows, &self.idx, h, &self.b)
    }

    /// One plain sweep (d_sweep artifact), for callers that manage their
    /// own share cadence.
    pub fn sweep(&self, runtime: &mut Runtime, h: &[f64]) -> Result<Vec<f64>> {
        runtime.d_sweep(self.m, self.n, &self.p_rows, &self.idx, h, &self.b)
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.m, self.n)
    }
}

// NOTE on tests: everything PJRT-dependent lives in
// `rust/tests/integration_runtime.rs`, gated on artifacts being present, so
// `cargo test` stays green before `make artifacts`.
