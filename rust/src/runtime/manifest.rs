//! Parse `artifacts/manifest.txt` written by `python/compile/aot.py`.
//!
//! Format (v1): comment lines start with `#`; data lines are
//! `name kind dims(comma-separated) file`, e.g.
//! `d_sweep d_sweep 2,4 d_sweep_2x4.hlo.txt`.
//! Several lines may share a `kind` (one per compiled shape).

use std::path::{Path, PathBuf};

use crate::error::{DiterError, Result};

/// One AOT artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: String,
    pub dims: Vec<usize>,
    pub file: PathBuf,
}

impl ArtifactEntry {
    /// Unique key: kind + dims.
    pub fn key(&self) -> String {
        format!(
            "{}_{}",
            self.kind,
            self.dims
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join("x")
        )
    }
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn parse(text: &str, base_dir: &Path) -> Result<Manifest> {
        let mut entries = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 4 {
                return Err(DiterError::Parse {
                    location: format!("manifest line {}", lineno + 1),
                    message: format!("expected 4 fields, got {}", parts.len()),
                });
            }
            let dims: Vec<usize> = parts[2]
                .split(',')
                .map(|d| {
                    d.parse::<usize>().map_err(|_| DiterError::Parse {
                        location: format!("manifest line {}", lineno + 1),
                        message: format!("bad dim `{d}`"),
                    })
                })
                .collect::<Result<_>>()?;
            entries.push(ArtifactEntry {
                name: parts[0].to_string(),
                kind: parts[1].to_string(),
                dims,
                file: base_dir.join(parts[3]),
            });
        }
        Ok(Manifest { entries })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)?;
        Self::parse(&text, dir)
    }

    /// Exact shape lookup.
    pub fn find(&self, kind: &str, dims: &[usize]) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.dims == dims)
    }

    /// All compiled shapes for a kind.
    pub fn shapes_of(&self, kind: &str) -> Vec<&[usize]> {
        self.entries
            .iter()
            .filter(|e| e.kind == kind)
            .map(|e| e.dims.as_slice())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# diter AOT manifest v1
# name kind dims(comma) file
d_sweep d_sweep 2,4 d_sweep_2x4.hlo.txt
d_sweep d_sweep 32,128 d_sweep_32x128.hlo.txt
jacobi_step jacobi_step 4 jacobi_step_4.hlo.txt
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.entries.len(), 3);
        let e = m.find("d_sweep", &[2, 4]).unwrap();
        assert_eq!(e.file, Path::new("/tmp/a/d_sweep_2x4.hlo.txt"));
        assert_eq!(e.key(), "d_sweep_2x4");
        assert!(m.find("d_sweep", &[9, 9]).is_none());
        assert_eq!(m.shapes_of("d_sweep").len(), 2);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("too few fields", Path::new(".")).is_err());
        assert!(Manifest::parse("a b 1,x f.txt", Path::new(".")).is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let m = Manifest::parse("# hi\n\n# there\n", Path::new(".")).unwrap();
        assert!(m.entries.is_empty());
    }
}
