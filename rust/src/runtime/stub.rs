//! No-op runtime compiled when the `pjrt` feature is off (the default).
//!
//! Exposes the exact API of [`super::pjrt`] so call sites (CLI `artifacts`
//! subcommand, hotpath bench, artifact-gated integration tests) compile
//! unchanged: [`Runtime::artifacts_available`] always reports `false`,
//! every loader returns a [`DiterError::Runtime`] explaining the feature
//! flag, and no execution path can ever be reached.

use std::path::{Path, PathBuf};

use super::{default_artifact_dir, Manifest};
use crate::error::{DiterError, Result};

fn disabled() -> DiterError {
    DiterError::Runtime(
        "built without the `pjrt` feature — rebuild with `--features pjrt` \
         (requires the xla crate) to execute AOT artifacts"
            .into(),
    )
}

/// Stub runtime: never constructible, so all methods are unreachable in
/// practice but keep the call sites type-checking.
pub struct Runtime {
    manifest: Manifest,
}

impl Runtime {
    /// Default artifact directory (next to the workspace root).
    pub fn default_dir() -> PathBuf {
        default_artifact_dir()
    }

    /// Always false: artifacts cannot be executed without the feature.
    pub fn artifacts_available() -> bool {
        false
    }

    pub fn load(_dir: impl AsRef<Path>) -> Result<Runtime> {
        Err(disabled())
    }

    pub fn load_default() -> Result<Runtime> {
        Err(disabled())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        "none (pjrt feature disabled)".into()
    }

    pub fn d_sweep(
        &mut self,
        _m: usize,
        _n: usize,
        _p_rows: &[f64],
        _idx: &[i32],
        _h: &[f64],
        _b: &[f64],
    ) -> Result<Vec<f64>> {
        Err(disabled())
    }

    pub fn d_round(
        &mut self,
        _m: usize,
        _n: usize,
        _p_rows: &[f64],
        _idx: &[i32],
        _h: &[f64],
        _b: &[f64],
    ) -> Result<(Vec<f64>, Vec<f64>, f64)> {
        Err(disabled())
    }

    pub fn jacobi_step(
        &mut self,
        _n: usize,
        _p: &[f64],
        _h: &[f64],
        _b: &[f64],
    ) -> Result<Vec<f64>> {
        Err(disabled())
    }

    pub fn fluid_norm(&mut self, _n: usize, _p: &[f64], _h: &[f64], _b: &[f64]) -> Result<f64> {
        Err(disabled())
    }

    pub fn power_step(&mut self, _n: usize, _p: &[f64], _x: &[f64]) -> Result<Vec<f64>> {
        Err(disabled())
    }

    pub fn pagerank_step(
        &mut self,
        _n: usize,
        _s: &[f64],
        _x: &[f64],
        _teleport: &[f64],
        _damping: f64,
    ) -> Result<Vec<f64>> {
        Err(disabled())
    }
}

/// Stub accelerator with the same surface as the PJRT-backed one.
pub struct DenseAccelerator {
    m: usize,
    n: usize,
}

impl DenseAccelerator {
    pub fn prepare(
        _runtime: &Runtime,
        _problem: &crate::solver::FixedPointProblem,
        _owned: &[usize],
    ) -> Result<DenseAccelerator> {
        Err(disabled())
    }

    pub fn round(&self, _runtime: &mut Runtime, _h: &[f64]) -> Result<(Vec<f64>, Vec<f64>, f64)> {
        Err(disabled())
    }

    pub fn sweep(&self, _runtime: &mut Runtime, _h: &[f64]) -> Result<Vec<f64>> {
        Err(disabled())
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.m, self.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_disabled() {
        assert!(!Runtime::artifacts_available());
        let err = Runtime::load_default().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
