//! `diter` — launcher CLI for the D-iteration distributed computation stack.
//!
//! Subcommands:
//!   solve      solve a synthetic system with any scheme/solver
//!   pagerank   distributed PageRank on a synthetic web-like graph
//!   stream     online PageRank: continuous graph churn, warm rebases
//!   serve      multi-tenant PPR query serving over shared workers
//!   figure     regenerate a paper figure (1..4) as a text table
//!   artifacts  inspect the AOT artifact manifest / smoke-test PJRT
//!   help       this text
//!
//! Run configuration can also come from a TOML-subset file via `--config`
//! (see `configfile`); CLI flags override file values.

use std::process::ExitCode;
use std::time::Duration;

use diter::bench_harness::{fmt_secs, Table};
use diter::cli::{parse_args, usage, Args, OptSpec};
use diter::configfile::Config;
use diter::coordinator::remote::{self, RemoteParams};
use diter::coordinator::{
    v1, v2, AdaptiveConfig, AdaptivePolicy, DistributedConfig, ElasticConfig, KernelKind,
    Query, QueryState, RebaseMode, ServeConfig, ServeEngine, StreamingEngine, TransportKind,
};
use diter::graph::{
    block_coupled_matrix, pagerank_system, paper_matrix, power_law_web_graph, ChurnModel,
    MutableDigraph, MutationStream,
};
use diter::linalg::vec_ops::dist1;
use diter::partition::Partition;
use diter::runtime::Runtime;
use diter::solver::{
    ConvergenceBound, DIteration, FixedPointProblem, GaussSeidel, Jacobi, SequenceKind,
    SolveOptions, Solver,
};
use diter::sparse::SparseMatrix;
use diter::transport::FlushPolicy;

/// CLI-level result: any error renders through Display and exits non-zero.
type CliResult<T = ()> = Result<T, Box<dyn std::error::Error>>;

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    let result = match cmd {
        "solve" => cmd_solve(rest),
        "pagerank" => cmd_pagerank(rest),
        "stream" => cmd_stream(rest),
        "serve" => cmd_serve(rest),
        "figure" => cmd_figure(rest),
        "artifacts" => cmd_artifacts(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand `{other}`\n");
            print_help();
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!(
        "diter — D-iteration based asynchronous distributed computation\n\n\
         subcommands:\n\
         \x20 solve      solve a synthetic block-coupled system\n\
         \x20 pagerank   distributed PageRank on a synthetic web graph\n\
         \x20 stream     online PageRank under continuous graph churn\n\
         \x20 serve      multi-tenant PPR query serving over shared workers\n\
         \x20 figure     regenerate a paper figure (--id 1..4)\n\
         \x20 artifacts  inspect AOT artifacts / smoke-test the PJRT runtime\n\
         \x20 help       this text\n\n\
         `diter <cmd> --help` prints the options of each subcommand."
    );
}

fn solve_spec() -> Vec<OptSpec> {
    vec![
        OptSpec {
            name: "help",
            help: "show usage",
            is_flag: true,
            default: None,
        },
        OptSpec {
            name: "config",
            help: "TOML-subset config file",
            is_flag: false,
            default: None,
        },
        OptSpec {
            name: "nodes",
            help: "system size N",
            is_flag: false,
            default: Some("256"),
        },
        OptSpec {
            name: "pids",
            help: "number of PIDs K",
            is_flag: false,
            default: Some("4"),
        },
        OptSpec {
            name: "coupling",
            help: "inter-block coupling (0..0.5)",
            is_flag: false,
            default: Some("0.1"),
        },
        OptSpec {
            name: "scheme",
            help: "v1 | v2 | seq | jacobi | gs",
            is_flag: false,
            default: Some("v2"),
        },
        OptSpec {
            name: "sequence",
            help: "cyclic | random | greedy",
            is_flag: false,
            default: Some("cyclic"),
        },
        OptSpec {
            name: "tol",
            help: "target residual",
            is_flag: false,
            default: Some("1e-10"),
        },
        OptSpec {
            name: "seed",
            help: "RNG seed",
            is_flag: false,
            default: Some("42"),
        },
        OptSpec {
            name: "alpha",
            help: "threshold divisor α",
            is_flag: false,
            default: Some("2.0"),
        },
    ]
}

fn merge_cfg(args: &Args) -> CliResult<Option<Config>> {
    Ok(match args.get("config") {
        Some(path) => Some(Config::load(path)?),
        None => None,
    })
}

fn cmd_solve(argv: &[String]) -> CliResult {
    let spec = solve_spec();
    let args = parse_args(argv, &spec)?;
    if args.has_flag("help") {
        print!("{}", usage("diter solve", "solve a synthetic system", &spec));
        return Ok(());
    }
    let file = merge_cfg(&args)?;
    let get_f = |key: &str, d: f64| -> CliResult<f64> {
        match file.as_ref() {
            Some(c) if args.get(key).is_none() => Ok(c.get_float("solve", key, d)),
            _ => Ok(args.get_f64(key, d)?),
        }
    };
    let n = args.get_usize("nodes", 256)?;
    let k = args.get_usize("pids", 4)?;
    let coupling = get_f("coupling", 0.1)?;
    let tol = get_f("tol", 1e-10)?;
    let alpha = get_f("alpha", 2.0)?;
    let seed = args.get_u64("seed", 42)?;
    let scheme = args.get_str("scheme", "v2");
    let sequence = SequenceKind::parse(&args.get_str("sequence", "cyclic"))
        .ok_or("bad --sequence (expected cyclic | random | greedy)")?;

    let p = block_coupled_matrix(n, k, 0.5, coupling, 6, seed);
    let problem = FixedPointProblem::new(SparseMatrix::from_csr(p), vec![1.0; n])?;
    println!(
        "system: N={n}, K={k}, coupling={coupling}, nnz={}, scheme={scheme}",
        problem.matrix().nnz()
    );

    match scheme.as_str() {
        "v1" | "v2" => {
            let mut cfg = DistributedConfig::new(Partition::contiguous(n, k)?)
                .with_tol(tol)
                .with_seed(seed)
                .with_sequence(sequence);
            cfg.threshold_alpha = alpha;
            let sol = if scheme == "v1" {
                v1::solve_v1(&problem, &cfg)?
            } else {
                v2::solve_v2(&problem, &cfg)?
            };
            println!(
                "converged={} residual={:.3e} parallel-cost={:.1} updates={} wall={:.3}s ({:.2e} upd/s)",
                sol.converged,
                sol.residual,
                sol.cost,
                sol.total_updates,
                sol.wall_secs,
                sol.updates_per_sec()
            );
            println!("transport: {:?}", sol.metrics);
        }
        "seq" | "jacobi" | "gs" => {
            let solver: Box<dyn Solver> = match scheme.as_str() {
                "seq" => Box::new(DIteration {
                    sequence,
                    variant: diter::solver::DIterationVariant::HForm,
                    seed,
                }),
                "jacobi" => Box::new(Jacobi::new()),
                _ => Box::new(GaussSeidel::new()),
            };
            let opts = SolveOptions {
                tol,
                ..Default::default()
            };
            let sol = solver.solve(&problem, &opts)?;
            println!(
                "{}: converged={} residual={:.3e} cost={:.1}",
                solver.name(),
                sol.converged,
                sol.residual,
                sol.cost
            );
        }
        other => return Err(format!("unknown scheme `{other}`").into()),
    }
    Ok(())
}

fn pagerank_spec() -> Vec<OptSpec> {
    vec![
        OptSpec {
            name: "help",
            help: "show usage",
            is_flag: true,
            default: None,
        },
        OptSpec {
            name: "nodes",
            help: "pages in the web graph",
            is_flag: false,
            default: Some("10000"),
        },
        OptSpec {
            name: "pids",
            help: "number of PIDs",
            is_flag: false,
            default: Some("4"),
        },
        OptSpec {
            name: "damping",
            help: "PageRank damping d",
            is_flag: false,
            default: Some("0.85"),
        },
        OptSpec {
            name: "out-deg",
            help: "average out-degree",
            is_flag: false,
            default: Some("8"),
        },
        OptSpec {
            name: "tol",
            help: "total-fluid target",
            is_flag: false,
            default: Some("1e-9"),
        },
        OptSpec {
            name: "seed",
            help: "RNG seed",
            is_flag: false,
            default: Some("7"),
        },
        OptSpec {
            name: "top",
            help: "print the top-k pages",
            is_flag: false,
            default: Some("10"),
        },
    ]
}

fn cmd_pagerank(argv: &[String]) -> CliResult {
    let spec = pagerank_spec();
    let args = parse_args(argv, &spec)?;
    if args.has_flag("help") {
        print!("{}", usage("diter pagerank", "distributed PageRank", &spec));
        return Ok(());
    }
    let n = args.get_usize("nodes", 10_000)?;
    let k = args.get_usize("pids", 4)?;
    let d = args.get_f64("damping", 0.85)?;
    let out_deg = args.get_usize("out-deg", 8)?;
    let tol = args.get_f64("tol", 1e-9)?;
    let seed = args.get_u64("seed", 7)?;
    let topk = args.get_usize("top", 10)?;

    println!("generating web-like graph: N={n}, avg out-degree={out_deg} ...");
    let g = power_law_web_graph(n, out_deg, 0.1, seed);
    println!("graph: {} edges, {} dangling", g.m(), g.dangling_nodes().len());
    let sys = pagerank_system(&g, d, true)?;
    let problem = FixedPointProblem::new(sys.matrix.clone(), sys.b.clone())?;
    let bound = ConvergenceBound::for_matrix(&sys.matrix, Some(d));

    let cfg = DistributedConfig::new(Partition::contiguous(n, k)?)
        .with_tol(tol)
        .with_seed(seed)
        .with_sequence(SequenceKind::GreedyMaxFluid);
    let sol = v2::solve_v2(&problem, &cfg)?;
    println!(
        "V2 x {k} PIDs: converged={} residual={:.3e} (≤ {:.3e} from limit per §4.4) wall={:.3}s  {:.2e} upd/s",
        sol.converged,
        sol.residual,
        bound.distance(sol.residual),
        sol.wall_secs,
        sol.updates_per_sec()
    );
    println!("transport: {:?}", sol.metrics);
    let mut ranked: Vec<(usize, f64)> = sol.x.iter().copied().enumerate().collect();
    ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("top {topk} pages:");
    for (rank, (page, score)) in ranked.iter().take(topk).enumerate() {
        println!("  #{:<3} page {:<8} score {:.6e}", rank + 1, page, score);
    }
    Ok(())
}

fn stream_spec() -> Vec<OptSpec> {
    vec![
        OptSpec {
            name: "help",
            help: "show usage",
            is_flag: true,
            default: None,
        },
        OptSpec {
            name: "nodes",
            help: "coordinate capacity N",
            is_flag: false,
            default: Some("5000"),
        },
        OptSpec {
            name: "pids",
            help: "number of PIDs",
            is_flag: false,
            default: Some("4"),
        },
        OptSpec {
            name: "damping",
            help: "PageRank damping d",
            is_flag: false,
            default: Some("0.85"),
        },
        OptSpec {
            name: "batches",
            help: "mutation batches to stream",
            is_flag: false,
            default: Some("8"),
        },
        OptSpec {
            name: "batch-size",
            help: "mutations per batch",
            is_flag: false,
            default: Some("64"),
        },
        OptSpec {
            name: "model",
            help: "churn model: grow | rewire | hotspot",
            is_flag: false,
            default: Some("rewire"),
        },
        OptSpec {
            name: "tol",
            help: "total-fluid target",
            is_flag: false,
            default: Some("1e-9"),
        },
        OptSpec {
            name: "seed",
            help: "RNG seed",
            is_flag: false,
            default: Some("7"),
        },
        OptSpec {
            name: "compare-cold",
            help: "also run a cold V2 restart per batch",
            is_flag: true,
            default: None,
        },
        OptSpec {
            name: "kernel",
            help: "diffusion kernel: local (block+remnant) | blocked (batched, unrolled) | global (baseline walk)",
            is_flag: false,
            default: Some("local"),
        },
        OptSpec {
            name: "pin-cores",
            help: "pin each worker thread to a core (Linux; also DITER_PIN=1)",
            is_flag: true,
            default: None,
        },
        OptSpec {
            name: "rebase",
            help: "epoch protocol: gather (leader rebase) | local (V1 halo rebase, no gather/scatter)",
            is_flag: false,
            default: Some("gather"),
        },
        OptSpec {
            name: "adaptive",
            help: "live §4.3 repartitioning (ownership handoff between PIDs)",
            is_flag: true,
            default: None,
        },
        OptSpec {
            name: "split-ratio",
            help: "straggler threshold: split below this × median rate",
            is_flag: false,
            default: Some("0.5"),
        },
        OptSpec {
            name: "adapt-every-ms",
            help: "rebalance observation window (ms)",
            is_flag: false,
            default: Some("40"),
        },
        OptSpec {
            name: "min-part",
            help: "never shrink a PID's share below this many coords",
            is_flag: false,
            default: Some("2"),
        },
        OptSpec {
            name: "straggler",
            help: "throttle this PID (straggler injection)",
            is_flag: false,
            default: None,
        },
        OptSpec {
            name: "straggler-ups",
            help: "throttled PID's max updates/sec",
            is_flag: false,
            default: Some("50000"),
        },
        OptSpec {
            name: "elastic",
            help: "elastic worker pool: spawn/retire PIDs at runtime",
            is_flag: true,
            default: None,
        },
        OptSpec {
            name: "max-workers",
            help: "elastic pool: cap on concurrently-live workers",
            is_flag: false,
            default: Some("8"),
        },
        OptSpec {
            name: "spawn-threshold",
            help: "elastic pool: spawn when a PID falls below this x median rate",
            is_flag: false,
            default: Some("0.5"),
        },
        OptSpec {
            name: "retire-idle-ms",
            help: "elastic pool: retire a worker idle this long (ms)",
            is_flag: false,
            default: Some("250"),
        },
        OptSpec {
            name: "transport",
            help: "message fabric: bus (in-process) | wire (loopback TCP); default from DITER_TRANSPORT",
            is_flag: false,
            default: None,
        },
        OptSpec {
            name: "wire-flush-bytes",
            help: "wire transport: flush a connection once this many bytes are queued",
            is_flag: false,
            default: Some("65536"),
        },
        OptSpec {
            name: "wire-flush-frames",
            help: "wire transport: flush a connection once this many frames are queued",
            is_flag: false,
            default: Some("64"),
        },
        OptSpec {
            name: "wire-flush-us",
            help: "wire transport: flush deadline in microseconds (staleness bound)",
            is_flag: false,
            default: Some("1000"),
        },
        OptSpec {
            name: "checkpoint-every-ms",
            help: "crash tolerance: incremental per-worker H checkpoints at this interval (0 = off)",
            is_flag: false,
            default: Some("0"),
        },
        OptSpec {
            name: "heartbeat-ms",
            help: "crash tolerance: declare a worker dead after this much silence (0 = off)",
            is_flag: false,
            default: Some("0"),
        },
        OptSpec {
            name: "listen",
            help: "coordinator role: accept --pids worker processes on ADDR (one-shot remote solve)",
            is_flag: false,
            default: None,
        },
        OptSpec {
            name: "connect",
            help: "worker role: join the coordinator at ADDR",
            is_flag: false,
            default: None,
        },
        OptSpec {
            name: "bind",
            help: "worker role: local IP the data-plane listener binds",
            is_flag: false,
            default: Some("127.0.0.1"),
        },
    ]
}

fn cmd_stream(argv: &[String]) -> CliResult {
    let spec = stream_spec();
    let args = parse_args(argv, &spec)?;
    if args.has_flag("help") {
        print!(
            "{}",
            usage("diter stream", "online PageRank under continuous churn", &spec)
        );
        return Ok(());
    }
    let n = args.get_usize("nodes", 5_000)?;
    let k = args.get_usize("pids", 4)?;
    let damping = args.get_f64("damping", 0.85)?;
    let batches = args.get_usize("batches", 8)?;
    let batch_size = args.get_usize("batch-size", 64)?;
    let tol = args.get_f64("tol", 1e-9)?;
    let seed = args.get_u64("seed", 7)?;
    let model = ChurnModel::parse(&args.get_str("model", "rewire"))
        .ok_or("bad --model (expected grow | rewire | hotspot)")?;
    let kernel = KernelKind::parse(&args.get_str("kernel", "local"))
        .ok_or("bad --kernel (expected local | blocked | global)")?;
    let rebase = RebaseMode::parse(&args.get_str("rebase", "gather"))
        .ok_or("bad --rebase (expected gather | local)")?;
    let compare_cold = args.has_flag("compare-cold");
    let checkpoint_every_ms = args.get_u64("checkpoint-every-ms", 0)?;
    let heartbeat_ms = args.get_u64("heartbeat-ms", 0)?;

    // Process-per-worker roles (DESIGN.md §8.6): a one-shot remote solve
    // over TCP instead of the in-process streaming run.
    if let Some(connect) = args.get("connect") {
        let bind = args
            .get_str("bind", "127.0.0.1")
            .parse()
            .map_err(|_| "bad --bind (expected an IP address)")?;
        println!("worker: joining coordinator at {connect}");
        remote::run_worker(connect, bind)?;
        println!("worker: done");
        return Ok(());
    }
    if let Some(listen) = args.get("listen") {
        let params = RemoteParams {
            n,
            avg_out: 8,
            damping,
            seed,
            tol,
            max_wall: Duration::from_secs(120),
            // remote workers are one-shot: staleness fails the run fast
            // (DiterError::WorkerDied) rather than respawning anyone
            heartbeat: (heartbeat_ms > 0).then(|| Duration::from_millis(heartbeat_ms)),
        };
        println!("coordinator: waiting for {k} workers on {listen}");
        let summary = remote::run_coordinator(listen, k, &params)?;
        println!(
            "remote solve: converged={} residual={:.2e} wall={} ({} updates across {k} processes)",
            summary.converged,
            summary.residual,
            fmt_secs(summary.wall_secs),
            summary.total_updates
        );
        if !summary.converged {
            return Err("remote solve did not converge inside the wall cap".into());
        }
        return Ok(());
    }
    let transport = match args.get("transport") {
        Some(name) => {
            TransportKind::parse(name).ok_or("bad --transport (expected bus | wire)")?
        }
        None => TransportKind::from_env(),
    };
    let wire_flush = FlushPolicy {
        max_bytes: args.get_usize("wire-flush-bytes", 64 * 1024)?,
        max_frames: args.get_usize("wire-flush-frames", 64)?,
        deadline: Duration::from_micros(args.get_u64("wire-flush-us", 1000)?),
    };

    // seed graph uses ~90% of the capacity so the growth model has room
    let seed_nodes = if matches!(model, ChurnModel::PreferentialGrowth { .. }) {
        n * 9 / 10
    } else {
        n
    };
    println!(
        "streaming PageRank: capacity N={n} (seed graph {seed_nodes}), K={k} PIDs, \
         model={}, kernel={}, rebase={}, transport={}, {batches} batches x {batch_size}",
        model.name(),
        kernel.name(),
        rebase.name(),
        transport.name()
    );
    let g = power_law_web_graph(seed_nodes, 8, 0.1, seed);
    let mg = MutableDigraph::from_digraph(&g, n);
    let mut cfg = DistributedConfig::new(Partition::contiguous(n, k)?)
        .with_tol(tol)
        .with_seed(seed)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_kernel(kernel)
        .with_rebase(rebase)
        .with_transport(transport)
        .with_wire_flush(wire_flush);
    if args.has_flag("pin-cores") {
        cfg = cfg.with_pin_cores(true);
    }
    if checkpoint_every_ms > 0 {
        cfg = cfg.with_checkpoint_every(Duration::from_millis(checkpoint_every_ms));
    }
    if heartbeat_ms > 0 {
        cfg = cfg.with_heartbeat(Duration::from_millis(heartbeat_ms));
    }
    cfg.max_wall = Duration::from_secs(120);
    if args.get("straggler").is_some() {
        let pid = args.get_usize("straggler", 0)?;
        if pid >= k {
            return Err(format!("--straggler {pid} out of range (pids = {k})").into());
        }
        cfg = cfg.with_straggler(pid, args.get_f64("straggler-ups", 50_000.0)?);
    }
    let adaptive = args.has_flag("adaptive");
    if adaptive {
        let policy = AdaptivePolicy {
            split_ratio: args.get_f64("split-ratio", 0.5)?,
            min_part: args.get_usize("min-part", 2)?,
            ..Default::default()
        };
        cfg = cfg.with_adaptive(AdaptiveConfig {
            policy,
            interval: Duration::from_millis(args.get_u64("adapt-every-ms", 40)?),
            ..Default::default()
        });
    }
    let elastic = args.has_flag("elastic");
    if elastic {
        let max_workers = args.get_usize("max-workers", 8)?;
        if max_workers < k {
            return Err(format!(
                "--max-workers {max_workers} below the initial --pids {k}"
            )
            .into());
        }
        cfg = cfg.with_elastic(ElasticConfig {
            max_workers,
            spawn_threshold: args.get_f64("spawn-threshold", 0.5)?,
            retire_idle: Duration::from_millis(args.get_u64("retire-idle-ms", 250)?),
            interval: Duration::from_millis(args.get_u64("adapt-every-ms", 40)?),
            min_part: args.get_usize("min-part", 2)?,
            ..Default::default()
        });
    }
    let cold_cfg = {
        // the cold baseline is always a static, unthrottled solve
        let mut c = cfg.clone();
        c.adaptive = None;
        c.elastic = None;
        c.straggler = None;
        c
    };

    let mut engine = StreamingEngine::new(mg, damping, true, cfg)?;
    let init = engine.converge()?;
    println!(
        "initial solve: converged={} residual={:.2e} wall={} ({} updates)\n",
        init.solution.converged,
        init.solution.residual,
        fmt_secs(init.solution.wall_secs),
        init.solution.total_updates
    );

    let mut stream = MutationStream::new(model, seed ^ 0xC0FFEE);
    let cold_header = [
        "batch",
        "applied",
        "edges",
        "warm-wall",
        "warm-upd",
        "cold-wall",
        "cold-upd",
        "speedup",
        "residual",
    ];
    let warm_header = [
        "batch",
        "applied",
        "edges",
        "warm-wall",
        "warm-upd",
        "upd/s",
        "residual",
    ];
    let mut table = Table::new(if compare_cold {
        &cold_header[..]
    } else {
        &warm_header[..]
    });
    for b in 0..batches {
        let batch = stream.next_batch(engine.graph(), batch_size);
        let report = engine.apply_batch(&batch)?;
        if !report.solution.converged {
            return Err(format!(
                "batch {b}: did not reconverge (residual {:.3e})",
                report.solution.residual
            )
            .into());
        }
        if compare_cold {
            let cold = v2::solve_v2(engine.problem(), &cold_cfg)?;
            let speedup = cold.total_updates as f64 / report.solution.total_updates.max(1) as f64;
            table.row(&[
                b.to_string(),
                report.mutations_applied.to_string(),
                engine.graph().m().to_string(),
                fmt_secs(report.solution.wall_secs),
                report.solution.total_updates.to_string(),
                fmt_secs(cold.wall_secs),
                cold.total_updates.to_string(),
                format!("{speedup:.1}x"),
                format!("{:.1e}", report.solution.residual),
            ]);
        } else {
            table.row(&[
                b.to_string(),
                report.mutations_applied.to_string(),
                engine.graph().m().to_string(),
                fmt_secs(report.solution.wall_secs),
                report.solution.total_updates.to_string(),
                format!("{:.2e}", engine.steady_updates_per_sec()),
                format!("{:.1e}", report.solution.residual),
            ]);
        }
    }
    print!("{}", table.render());
    let ownership = engine.ownership();
    let update_counts = engine.update_counts();
    let pool_stats = engine.pool_stats();
    let summary = engine.finish()?;
    println!(
        "\n{} epochs, {} mutations; steady-state {:.2e} upd/s; final residual {:.2e}",
        summary.epochs,
        summary.mutations_applied,
        summary.steady_updates_per_sec,
        summary.final_solution.residual
    );
    println!("\nstats:");
    for (name, v) in &summary.final_solution.metrics {
        println!("  {name:<22} {v}");
    }
    println!("  {:<22} {:.3}", "load_imbalance", ownership.imbalance());
    for (kk, size) in ownership.part_sizes().iter().enumerate() {
        println!("  pid {kk}: |Ω| = {size:<6} updates = {}", update_counts[kk]);
    }
    if adaptive {
        let moves = summary.final_solution.metrics.get("handoffs_planned");
        let shipped = summary.final_solution.metrics.get("handoffs_total");
        println!(
            "  ownership moved {} times ({} handoffs shipped)",
            moves.copied().unwrap_or(0),
            shipped.copied().unwrap_or(0)
        );
    }
    if elastic {
        println!(
            "  pool: spawned {} retired {} sheds {} peak {} live {}",
            pool_stats.spawned,
            pool_stats.retired,
            pool_stats.sheds,
            pool_stats.peak_live,
            pool_stats.live
        );
    }
    if pool_stats.crashes > 0 || checkpoint_every_ms > 0 || heartbeat_ms > 0 {
        println!(
            "  crash tolerance: crashes {} recoveries {}",
            pool_stats.crashes, pool_stats.recoveries
        );
    }
    Ok(())
}

fn serve_spec() -> Vec<OptSpec> {
    vec![
        OptSpec {
            name: "help",
            help: "show usage",
            is_flag: true,
            default: None,
        },
        OptSpec {
            name: "nodes",
            help: "pages in the web graph",
            is_flag: false,
            default: Some("2000"),
        },
        OptSpec {
            name: "pids",
            help: "number of PIDs",
            is_flag: false,
            default: Some("3"),
        },
        OptSpec {
            name: "lanes",
            help: "concurrent query lanes (in-flight cap)",
            is_flag: false,
            default: Some("2"),
        },
        OptSpec {
            name: "queries",
            help: "PPR queries to submit",
            is_flag: false,
            default: Some("6"),
        },
        OptSpec {
            name: "seeds-per-query",
            help: "teleport seeds per query",
            is_flag: false,
            default: Some("2"),
        },
        OptSpec {
            name: "eps",
            help: "per-query convergence target ε",
            is_flag: false,
            default: Some("1e-6"),
        },
        OptSpec {
            name: "deadline-ms",
            help: "per-query deadline (0 = none; expired tenants are evicted)",
            is_flag: false,
            default: Some("0"),
        },
        OptSpec {
            name: "queue-cap",
            help: "admission queue depth beyond the lane cap (overflow is rejected)",
            is_flag: false,
            default: Some("32"),
        },
        OptSpec {
            name: "damping",
            help: "PageRank damping d",
            is_flag: false,
            default: Some("0.85"),
        },
        OptSpec {
            name: "tol",
            help: "base-lane total-fluid target",
            is_flag: false,
            default: Some("1e-9"),
        },
        OptSpec {
            name: "churn-every",
            help: "apply a mutation batch after every this many completed queries (0 = no churn)",
            is_flag: false,
            default: Some("2"),
        },
        OptSpec {
            name: "batch-size",
            help: "mutations per churn batch",
            is_flag: false,
            default: Some("16"),
        },
        OptSpec {
            name: "elastic",
            help: "elastic worker pool: spawn/retire PIDs while serving",
            is_flag: true,
            default: None,
        },
        OptSpec {
            name: "max-workers",
            help: "elastic pool: cap on concurrently-live workers",
            is_flag: false,
            default: Some("8"),
        },
        OptSpec {
            name: "transport",
            help: "message fabric: bus (in-process) | wire (loopback TCP); default from DITER_TRANSPORT",
            is_flag: false,
            default: None,
        },
        OptSpec {
            name: "max-wall-secs",
            help: "overall serving wall cap",
            is_flag: false,
            default: Some("60"),
        },
        OptSpec {
            name: "seed",
            help: "RNG seed",
            is_flag: false,
            default: Some("7"),
        },
    ]
}

/// Multi-tenant serving demo: N concurrent personalized-PageRank queries
/// multiplexed through one worker pool as extra fluid lanes, with
/// queue-or-reject admission and graph churn running underneath
/// (DESIGN.md §10). Exits non-zero when an admitted query fails to reach
/// its ε (deadline evictions are failures only when no deadline was
/// requested — with `--deadline-ms` they are the configured policy).
fn cmd_serve(argv: &[String]) -> CliResult {
    let spec = serve_spec();
    let args = parse_args(argv, &spec)?;
    if args.has_flag("help") {
        print!(
            "{}",
            usage("diter serve", "multi-tenant PPR query serving", &spec)
        );
        return Ok(());
    }
    let n = args.get_usize("nodes", 2000)?;
    let k = args.get_usize("pids", 3)?;
    let lanes = args.get_usize("lanes", 2)?.max(1);
    let total_queries = args.get_usize("queries", 6)?;
    let seeds_per_query = args.get_usize("seeds-per-query", 2)?.max(1);
    let eps = args.get_f64("eps", 1e-6)?;
    let deadline_ms = args.get_u64("deadline-ms", 0)?;
    let queue_cap = args.get_usize("queue-cap", 32)?;
    let damping = args.get_f64("damping", 0.85)?;
    let tol = args.get_f64("tol", 1e-9)?;
    let churn_every = args.get_usize("churn-every", 2)?;
    let batch_size = args.get_usize("batch-size", 16)?;
    let max_wall = Duration::from_secs(args.get_u64("max-wall-secs", 60)?);
    let seed = args.get_u64("seed", 7)?;
    let transport = match args.get("transport") {
        Some(name) => {
            TransportKind::parse(name).ok_or("bad --transport (expected bus | wire)")?
        }
        None => TransportKind::from_env(),
    };

    let g = power_law_web_graph(n, 8, 0.1, seed);
    let mg = MutableDigraph::from_digraph(&g, n);
    let mut cfg = DistributedConfig::new(Partition::contiguous(n, k)?)
        .with_tol(tol)
        .with_seed(seed)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_transport(transport);
    cfg.max_wall = max_wall;
    if args.has_flag("elastic") {
        let max_workers = args.get_usize("max-workers", 8)?;
        if max_workers < k {
            return Err(format!(
                "--max-workers {max_workers} below the initial --pids {k}"
            )
            .into());
        }
        cfg = cfg.with_elastic(ElasticConfig {
            max_workers,
            ..Default::default()
        });
    }
    let serve_cfg = ServeConfig {
        queue_cap,
        default_eps: eps,
        default_deadline: (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms)),
        ..Default::default()
    };
    println!(
        "serving PPR: N={n}, K={k} PIDs, {lanes} query lanes, {total_queries} queries \
         (ε={eps:.1e}), transport={}, churn every {churn_every} completions",
        transport.name()
    );
    let mut serve = ServeEngine::new(mg, damping, true, cfg, serve_cfg, lanes)?;

    let mut rng = diter::prng::Xoshiro256pp::seed_from_u64(seed ^ 0x5EED);
    let mut churn = MutationStream::new(ChurnModel::RandomRewire, seed ^ 0xC0FFEE);
    let mut submitted = 0usize;
    let mut rejected_at_submit = 0usize;
    let mut finished: Vec<(u32, usize, QueryState, Option<f64>)> = Vec::new();
    let mut since_churn = 0usize;
    let t0 = std::time::Instant::now();
    while finished.len() + rejected_at_submit < total_queries {
        while submitted < total_queries {
            let seeds: Vec<usize> = (0..seeds_per_query).map(|_| rng.below(n)).collect();
            let q = Query::ppr(&seeds, damping, eps);
            if serve.submit(q).is_none() {
                rejected_at_submit += 1;
            }
            submitted += 1;
        }
        for done in serve.poll()? {
            finished.push((done.qid, done.lane, done.state, done.time_to_eps_secs));
            since_churn += 1;
            if churn_every > 0 && since_churn >= churn_every {
                since_churn = 0;
                let batch = churn.next_batch(serve.engine().graph(), batch_size);
                let applied = serve.apply_mutations(&batch)?;
                println!("  churn: {applied} mutations applied (epoch {})", serve.engine().epoch());
            }
        }
        if t0.elapsed() >= max_wall {
            break;
        }
        std::thread::sleep(Duration::from_micros(200));
    }

    let (admitted, served, rejected) = serve.counts();
    let mut table = Table::new(&["qid", "lane", "state", "time-to-ε"]);
    for (qid, lane, state, tte) in &finished {
        table.row(&[
            qid.to_string(),
            lane.to_string(),
            format!("{state:?}"),
            tte.map(|s| fmt_secs(s)).unwrap_or_else(|| "-".into()),
        ]);
    }
    print!("{}", table.render());
    println!(
        "\nadmitted {admitted}, served {served}, rejected {rejected}; \
         freshness {:.2} q/s; pool {:?}",
        serve.freshness().unwrap_or(0.0),
        serve.engine().pool_stats(),
    );
    let summary = serve.finish()?;
    println!("stats:");
    for (name, v) in &summary.final_solution.metrics {
        println!("  {name:<22} {v}");
    }
    let evicted = finished
        .iter()
        .filter(|(_, _, s, _)| *s == QueryState::Evicted)
        .count();
    let pending = submitted - rejected_at_submit - finished.len();
    if pending > 0 {
        return Err(format!(
            "{pending} admitted queries did not reach ε inside the wall cap"
        )
        .into());
    }
    if evicted > 0 && deadline_ms == 0 {
        return Err(format!("{evicted} queries evicted without a deadline policy").into());
    }
    Ok(())
}

fn figure_spec() -> Vec<OptSpec> {
    vec![
        OptSpec {
            name: "help",
            help: "show usage",
            is_flag: true,
            default: None,
        },
        OptSpec {
            name: "id",
            help: "paper figure id (1..4)",
            is_flag: false,
            default: Some("1"),
        },
        OptSpec {
            name: "max-cost",
            help: "iterations to chart",
            is_flag: false,
            default: Some("20"),
        },
    ]
}

fn cmd_figure(argv: &[String]) -> CliResult {
    let spec = figure_spec();
    let args = parse_args(argv, &spec)?;
    if args.has_flag("help") {
        print!("{}", usage("diter figure", "regenerate a paper figure", &spec));
        return Ok(());
    }
    let id = args.get_usize("id", 1)?;
    let max_cost = args.get_usize("max-cost", 20)?;
    let table = diter::figures::render_figure(id as u8, max_cost)?;
    print!("{table}");
    Ok(())
}

fn artifacts_spec() -> Vec<OptSpec> {
    vec![
        OptSpec {
            name: "help",
            help: "show usage",
            is_flag: true,
            default: None,
        },
        OptSpec {
            name: "smoke",
            help: "execute the 2x4 d_sweep artifact",
            is_flag: true,
            default: None,
        },
    ]
}

fn cmd_artifacts(argv: &[String]) -> CliResult {
    let spec = artifacts_spec();
    let args = parse_args(argv, &spec)?;
    if args.has_flag("help") {
        print!("{}", usage("diter artifacts", "inspect AOT artifacts", &spec));
        return Ok(());
    }
    if cfg!(not(feature = "pjrt")) {
        return Err(
            "built without the `pjrt` feature — rebuild with `--features pjrt` \
             (requires the xla crate) to use the AOT artifact runtime"
                .into(),
        );
    }
    if !Runtime::artifacts_available() {
        return Err(format!(
            "no artifacts at {:?} — run `make artifacts` first",
            Runtime::default_dir()
        )
        .into());
    }
    let mut rt = Runtime::load_default()?;
    println!("PJRT platform: {}", rt.platform());
    let mut table = Table::new(&["kind", "dims", "file"]);
    for e in &rt.manifest().entries.clone() {
        table.row(&[
            e.kind.clone(),
            format!("{:?}", e.dims),
            e.file.file_name().unwrap().to_string_lossy().to_string(),
        ]);
    }
    print!("{}", table.render());
    if args.has_flag("smoke") {
        // the paper's A(1), block {0,1}: one sweep via PJRT vs rust
        let problem = FixedPointProblem::from_linear_system(&paper_matrix(1), &[1.0; 4])?;
        let owned = [0usize, 1];
        let p_rows = problem.matrix().csr().dense_row_block(&owned);
        let idx = [0i32, 1];
        let h = problem.b().to_vec();
        let b: Vec<f64> = owned.iter().map(|&i| problem.b()[i]).collect();
        let got = rt.d_sweep(2, 4, &p_rows, &idx, &h, &b)?;
        // rust reference
        let csr = problem.matrix().csr();
        let mut want = h.clone();
        for &i in &owned {
            want[i] = csr.row_dot(i, &want) + problem.b()[i];
        }
        let delta = dist1(&got, &want);
        println!("smoke d_sweep_2x4: PJRT vs rust Δ₁ = {delta:.3e}");
        if !(delta.is_finite() && delta < 1e-12) {
            return Err(format!("PJRT/rust mismatch: Δ₁ = {delta:.3e}").into());
        }
        println!("smoke OK");
    }
    Ok(())
}
