//! # diter — D-iteration based asynchronous distributed computation
//!
//! A production-grade reproduction of *"D-iteration based asynchronous
//! distributed computation"* (Dohy Hong, Alcatel-Lucent Bell Labs, 2012).
//!
//! The D-iteration solves the fixed point `X = P·X + B` (spectral radius
//! `ρ(P) < 1`) through a *fluid diffusion* process tracked by two vectors:
//! the fluid `F_n` and the history `H_n`, tied by the invariant
//! `H_n + F_n = F_0 + P·H_n` (paper eq. 4). The paper contributes two
//! **asynchronous distributed** schemes over a partition `Ω_1..Ω_K` of the
//! coordinates, one worker (`PID_k`) per part:
//!
//! * **V1** ([`coordinator::v1`]) — each PID keeps the full history vector,
//!   sweeps its own coordinates (eq. 6), and broadcasts its slice when its
//!   local remaining fluid `r_k` drops below a threshold `T_k` (then
//!   `T_k ← T_k/α`), or when it receives a peer update.
//! * **V2** ([`coordinator::v2`]) — each PID keeps only its local slice of
//!   `(B, H, F)` and *ships fluid* `f·p_{ji}` to the owner of `j`,
//!   coalescing small parcels and retaining every parcel until it is
//!   acknowledged (no fluid may be lost — "as TCP").
//!
//! Layering (see `DESIGN.md`): this crate is **Layer 3** — the coordinator,
//! the substrates it needs (sparse matrices, graph generators, baseline
//! solvers, transport, partitioning, metrics, config, CLI), and the PJRT
//! [`runtime`] that loads the **Layer 1/2** JAX + Pallas programs AOT-lowered
//! to HLO text by `python/compile/aot.py`. Python never runs on the request
//! path.
//!
//! The XLA-backed runtime is compiled only with the off-by-default `pjrt`
//! cargo feature (see `runtime/pjrt.rs`); the default build substitutes a
//! stub with the same API so the crate builds and tests on a clean machine
//! with no native XLA toolchain and zero external dependencies.
//!
//! ## Transports
//!
//! Worker-to-worker messaging goes through the
//! [`transport::Transport`] / [`transport::TransportHub`] trait pair,
//! with two implementations selected by
//! [`transport::TransportKind`] (CLI `--transport`, env
//! `DITER_TRANSPORT`):
//!
//! * **bus** — the in-process channel fabric
//!   ([`transport::Endpoint`] / [`transport::BusHub`]): exact shared
//!   accounting, optional simulated latency, the default;
//! * **wire** — length-prefixed TCP framing
//!   ([`transport::WireEndpoint`] / [`transport::WireHub`],
//!   spec in `DESIGN.md` §8): the same fluid parcels and control
//!   messages as bytes on a socket, either as a single-process
//!   loopback harness (the whole test-suite re-runs over it
//!   unchanged) or process-per-worker via
//!   `diter stream --listen/--connect`
//!   ([`coordinator::remote`]).
//!
//! ## Quick start
//!
//! ```
//! use diter::linalg::DenseMat;
//! use diter::solver::{FixedPointProblem, DIteration, Solver, SolveOptions};
//!
//! // The paper's A(1) example: solve A.X = 1 via X = P.X + B.
//! let a = DenseMat::from_rows(&[
//!     &[5.0, 3.0, 0.0, 0.0],
//!     &[3.0, 7.0, 0.0, 0.0],
//!     &[0.0, 0.0, 8.0, 4.0],
//!     &[0.0, 0.0, 2.0, 3.0],
//! ]);
//! let problem = FixedPointProblem::from_linear_system(&a, &[1.0; 4]).unwrap();
//! let sol = DIteration::cyclic().solve(&problem, &SolveOptions::default()).unwrap();
//! let x = problem.verify_solution(&sol.x, 1e-10).unwrap();
//! assert!(x.residual < 1e-10);
//! ```

pub mod bench_harness;
pub mod cli;
pub mod configfile;
pub mod coordinator;
pub mod error;
pub mod figures;
pub mod graph;
pub mod linalg;
pub mod metrics;
pub mod partition;
pub mod perf;
pub mod prng;
pub mod prop;
pub mod runtime;
pub mod solver;
pub mod sparse;
pub mod transport;

pub use error::{DiterError, Result};
