//! §1 reductions: the D-iteration solves more than `X = P·X + B`.
//!
//! The paper's introduction lists three problem families, all reduced to
//! the fixed-point form:
//!
//! 1. `X = P·X + B` — native (ρ(P) < 1);
//! 2. `Q·X = X` (eigenvector, ρ(Q) = 1) — via damping: the fixed point of
//!    `X = d·Q·X + (1−d)·v` converges to the dominant eigenvector as
//!    d → 1 (the PageRank construction, exact for stochastic Q when v is
//!    a probability vector);
//! 3. `A·X = B` (general linear system) — via a splitting: the paper's §5
//!    uses the Jacobi splitting `P = −a_ij/a_ii, B_i = b_i/a_ii`
//!    ([`super::FixedPointProblem::from_linear_system`]); this module adds
//!    the **Richardson** splitting `P = I − ω·A, B = ω·b`, which needs no
//!    nonzero diagonal and converges for `0 < ω < 2/λ_max(A)` (SPD A).

use crate::error::{DiterError, Result};
use crate::linalg::DenseMat;
use crate::sparse::{CsrMatrix, SparseMatrix, TripletBuilder};

use super::FixedPointProblem;

/// Reduction 2: eigenproblem `Q·X = X` with damping `d` and anchor
/// distribution `v` (uniform if `None`). For column-stochastic Q this is
/// exactly the PageRank construction; the fixed point is the stationary
/// vector of `d·Q + (1−d)·v·1ᵗ`.
pub fn eigen_problem(
    q: &CsrMatrix,
    damping: f64,
    anchor: Option<Vec<f64>>,
) -> Result<FixedPointProblem> {
    if q.nrows() != q.ncols() {
        return Err(DiterError::shape(
            "eigen_problem",
            "square",
            format!("{}x{}", q.nrows(), q.ncols()),
        ));
    }
    if !(0.0 < damping && damping < 1.0) {
        return Err(DiterError::NotContractive(format!(
            "damping must be in (0,1), got {damping}"
        )));
    }
    let n = q.nrows();
    let v = match anchor {
        Some(v) => {
            if v.len() != n {
                return Err(DiterError::shape("eigen_problem anchor", n, v.len()));
            }
            v
        }
        None => vec![1.0 / n as f64; n],
    };
    let mut b = TripletBuilder::with_capacity(n, n, q.nnz());
    for i in 0..n {
        let (idx, val) = q.row(i);
        for k in 0..idx.len() {
            b.push(i, idx[k], damping * val[k]);
        }
    }
    let rhs: Vec<f64> = v.iter().map(|x| (1.0 - damping) * x).collect();
    FixedPointProblem::new(SparseMatrix::from_csr(b.to_csr()), rhs)
}

/// Reduction 3 (alternative splitting): Richardson iteration for
/// `A·X = B` — `P = I − ω·A`, `B' = ω·B`. Returns an error if the
/// resulting P is clearly non-contractive (‖P‖∞ ≥ 1 **and** ‖P‖₁ ≥ 1 —
/// a cheap necessary check; spectral contraction may still hold for SPD A,
/// so this only rejects the hopeless symmetric-norm case when both
/// induced-norm bounds fail by a wide margin).
pub fn richardson_problem(a: &DenseMat, b: &[f64], omega: f64) -> Result<FixedPointProblem> {
    if !a.is_square() {
        return Err(DiterError::shape(
            "richardson_problem",
            "square",
            format!("{}x{}", a.rows(), a.cols()),
        ));
    }
    if b.len() != a.rows() {
        return Err(DiterError::shape("richardson_problem", a.rows(), b.len()));
    }
    if omega <= 0.0 {
        return Err(DiterError::NotContractive(format!(
            "omega must be positive, got {omega}"
        )));
    }
    let n = a.rows();
    let mut p = DenseMat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let idm = if i == j { 1.0 } else { 0.0 };
            p[(i, j)] = idm - omega * a[(i, j)];
        }
    }
    let rhs: Vec<f64> = b.iter().map(|x| omega * x).collect();
    FixedPointProblem::new(SparseMatrix::from_dense(&p), rhs)
}

/// Estimate a safe Richardson ω for an SPD matrix via a few power-method
/// steps on A (λ_max estimate), returning `1/λ̂_max` (conservative half of
/// the `2/λ_max` stability window).
pub fn richardson_omega(a: &DenseMat, iters: usize) -> Result<f64> {
    if !a.is_square() || a.rows() == 0 {
        return Err(DiterError::shape("richardson_omega", "square nonempty", "-"));
    }
    let n = a.rows();
    let mut x = vec![1.0 / (n as f64).sqrt(); n];
    let mut lambda = 1.0;
    for _ in 0..iters.max(1) {
        let y = a.matvec(&x)?;
        let norm = crate::linalg::vec_ops::norm2(&y);
        if norm == 0.0 {
            return Err(DiterError::NotContractive("A ≈ 0".into()));
        }
        lambda = norm;
        x = y.into_iter().map(|v| v / norm).collect();
    }
    Ok(1.0 / lambda)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vec_ops::{dist1, dist_inf, norm1};
    use crate::linalg::solve_dense;
    use crate::solver::{DIteration, PowerIteration, SolveOptions, Solver};

    #[test]
    fn eigen_problem_recovers_stationary_vector() {
        // column-stochastic 3x3 chain with known stationary vector
        let q = CsrMatrix::from_dense(&DenseMat::from_rows(&[
            &[0.5, 0.25, 0.25],
            &[0.25, 0.5, 0.25],
            &[0.25, 0.25, 0.5],
        ]));
        // symmetric doubly-stochastic → uniform stationary vector
        let problem = eigen_problem(&q, 0.99, None).unwrap();
        let sol = DIteration::fluid_cyclic()
            .solve(&problem, &SolveOptions::default())
            .unwrap();
        assert!(sol.converged);
        // fixed point of X = dQX + (1-d)/n: for uniform stationary Q the
        // answer is exactly uniform
        for v in &sol.x {
            assert!((v - 1.0 / 3.0).abs() < 1e-10, "{v}");
        }
        // matches the power method on Q itself
        let power = PowerIteration::default()
            .run(&SparseMatrix::from_csr(q), None, None)
            .unwrap();
        let x_norm: Vec<f64> = sol.x.iter().map(|v| v / norm1(&sol.x)).collect();
        assert!(dist1(&x_norm, &power.x) < 1e-8);
    }

    #[test]
    fn eigen_problem_damping_validation() {
        let q = CsrMatrix::from_dense(&DenseMat::identity(2));
        assert!(eigen_problem(&q, 1.0, None).is_err());
        assert!(eigen_problem(&q, 0.0, None).is_err());
        assert!(eigen_problem(&q, 0.5, Some(vec![1.0])).is_err());
    }

    #[test]
    fn richardson_solves_spd_system() {
        // SPD matrix with zero diagonal entries would break the Jacobi
        // splitting — Richardson handles any SPD A
        let a = DenseMat::from_rows(&[
            &[4.0, 1.0, 0.0],
            &[1.0, 3.0, 1.0],
            &[0.0, 1.0, 2.0],
        ]);
        let b = vec![1.0, 2.0, 3.0];
        let omega = richardson_omega(&a, 50).unwrap();
        let problem = richardson_problem(&a, &b, omega).unwrap();
        let sol = DIteration::cyclic()
            .solve(
                &problem,
                &SolveOptions {
                    tol: 1e-12,
                    max_cost: 100_000.0,
                    trace_every: 0.0,
                    exact: None,
                },
            )
            .unwrap();
        assert!(sol.converged);
        let exact = solve_dense(&a, &b).unwrap();
        assert!(dist_inf(&sol.x, &exact) < 1e-9);
    }

    #[test]
    fn richardson_rejects_bad_inputs() {
        let a = DenseMat::identity(2);
        assert!(richardson_problem(&a, &[1.0], 0.5).is_err());
        assert!(richardson_problem(&a, &[1.0, 1.0], 0.0).is_err());
        let rect = DenseMat::zeros(2, 3);
        assert!(richardson_problem(&rect, &[1.0, 1.0], 0.5).is_err());
    }

    #[test]
    fn richardson_omega_estimates_lambda_max() {
        // diag(4, 1): λ_max = 4 → ω ≈ 1/4
        let a = DenseMat::from_rows(&[&[4.0, 0.0], &[0.0, 1.0]]);
        let w = richardson_omega(&a, 100).unwrap();
        assert!((w - 0.25).abs() < 1e-6, "ω = {w}");
    }

    #[test]
    fn jacobi_and_richardson_agree() {
        let a = DenseMat::from_rows(&[&[5.0, 1.0], &[2.0, 7.0]]);
        let b = [1.0, -1.0];
        let jac = FixedPointProblem::from_linear_system(&a, &b).unwrap();
        let ric = richardson_problem(&a, &b, 0.2).unwrap();
        let opts = SolveOptions {
            tol: 1e-13,
            max_cost: 100_000.0,
            trace_every: 0.0,
            exact: None,
        };
        let x1 = DIteration::cyclic().solve(&jac, &opts).unwrap().x;
        let x2 = DIteration::cyclic().solve(&ric, &opts).unwrap().x;
        assert!(dist_inf(&x1, &x2) < 1e-9);
    }
}
