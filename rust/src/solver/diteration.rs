//! The sequential D-iteration, in both of the paper's formulations.
//!
//! * **H-form** (eq. 5): keep only H; diffusing `i` sets
//!   `H_i ← L_i(P)·H + B_i`. With the free start `H_0 = B` (§2.1.1).
//! * **Fluid form** (eq. 2–3): keep (H, F); diffusing `i` moves the fluid
//!   `f = F_i` into `H_i` and pushes `p_{ji}·f` to each out-entry of
//!   column i. `‖F‖₁` *is* the remaining fluid — convergence monitoring is
//!   free, which is why the distributed V2 scheme uses this form.
//!
//! Both forms compute the same fixed point; the fluid form additionally
//! maintains the invariant `H + F = F₀ + P·H` (eq. 4) *exactly* at every
//! step, which the property tests assert.

use super::sequence::{SequenceKind, SequenceState};
use super::{FixedPointProblem, Solution, SolveOptions, Solver};
use crate::error::Result;
use crate::linalg::vec_ops::norm1;
use crate::metrics::ConvergenceTrace;

/// Which formulation to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DIterationVariant {
    /// eq. (5): history vector only
    HForm,
    /// eq. (2)+(3): explicit fluid + history vectors
    FluidForm,
}

/// Sequential D-iteration solver.
#[derive(Clone, Debug)]
pub struct DIteration {
    pub sequence: SequenceKind,
    pub variant: DIterationVariant,
    /// seed for the random sequence strategy
    pub seed: u64,
}

impl DIteration {
    /// The paper's default: cyclic sequence, H-form, free start H₀ = B.
    pub fn cyclic() -> Self {
        Self {
            sequence: SequenceKind::Cyclic,
            variant: DIterationVariant::HForm,
            seed: 0,
        }
    }

    pub fn greedy() -> Self {
        Self {
            sequence: SequenceKind::GreedyMaxFluid,
            variant: DIterationVariant::FluidForm,
            seed: 0,
        }
    }

    pub fn fluid_cyclic() -> Self {
        Self {
            sequence: SequenceKind::Cyclic,
            variant: DIterationVariant::FluidForm,
            seed: 0,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

impl Solver for DIteration {
    fn name(&self) -> &str {
        match (self.variant, self.sequence) {
            (DIterationVariant::HForm, SequenceKind::Cyclic) => "diter",
            (DIterationVariant::HForm, SequenceKind::Random) => "diter-rand",
            (DIterationVariant::HForm, SequenceKind::GreedyMaxFluid) => "diter-greedy",
            (DIterationVariant::FluidForm, SequenceKind::Cyclic) => "diter-fluid",
            (DIterationVariant::FluidForm, SequenceKind::Random) => "diter-fluid-rand",
            (DIterationVariant::FluidForm, SequenceKind::GreedyMaxFluid) => "diter-fluid-greedy",
        }
    }

    fn solve(&self, problem: &FixedPointProblem, opts: &SolveOptions) -> Result<Solution> {
        match self.variant {
            DIterationVariant::HForm => self.solve_h_form(problem, opts),
            DIterationVariant::FluidForm => self.solve_fluid_form(problem, opts),
        }
    }
}

impl DIteration {
    fn solve_h_form(&self, problem: &FixedPointProblem, opts: &SolveOptions) -> Result<Solution> {
        let n = problem.n();
        let csr = problem.matrix().csr();
        let b = problem.b();
        // §2.1.1: choosing i_1..i_N = 1..N from H₀ = 0 yields H_N = B when
        // P's diagonal is zero — so start directly at H = B for free.
        let mut h = b.to_vec();
        let mut seq = SequenceState::new(self.sequence, (0..n).collect(), self.seed);
        let mut trace = ConvergenceTrace::new(self.name());
        let mut cost = 0.0;
        if opts.trace_every > 0.0 {
            trace.push(0.0, opts.trace_error(problem, &h));
        }
        let mut residual = problem.residual_norm(&h);
        let mut updates_since_trace = 0usize;
        // greedy H-form needs a fluid estimate: recompute per pass
        let mut fluid = if self.sequence == SequenceKind::GreedyMaxFluid {
            problem.fluid(&h)
        } else {
            Vec::new()
        };
        let updates_per_unit = n.max(1);
        while residual > opts.tol && cost < opts.max_cost {
            for _ in 0..updates_per_unit {
                let i = seq.next(&fluid);
                let new = csr.row_dot(i, &h) + b[i];
                if self.sequence == SequenceKind::GreedyMaxFluid {
                    // maintain the fluid vector incrementally: changing H_i
                    // changes F_j for every j with p_{ji} ≠ 0, and zeroes F_i.
                    let delta = new - h[i];
                    h[i] = new;
                    fluid[i] = 0.0;
                    let (rows, vals) = problem.matrix().csc().col(i);
                    for k in 0..rows.len() {
                        fluid[rows[k]] += vals[k] * delta;
                    }
                } else {
                    h[i] = new;
                }
            }
            cost += 1.0;
            updates_since_trace += updates_per_unit;
            residual = problem.residual_norm(&h);
            if opts.trace_every > 0.0
                && updates_since_trace >= (opts.trace_every * updates_per_unit as f64) as usize
            {
                trace.push(cost, opts.trace_error(problem, &h));
                updates_since_trace = 0;
            }
        }
        Ok(Solution {
            x: h,
            cost,
            residual,
            converged: residual <= opts.tol,
            trace,
        })
    }

    fn solve_fluid_form(
        &self,
        problem: &FixedPointProblem,
        opts: &SolveOptions,
    ) -> Result<Solution> {
        let n = problem.n();
        let csc = problem.matrix().csc();
        // F₀ = B, H₀ = 0 (eq. 2/3 initial condition)
        let mut f = problem.b().to_vec();
        let mut h = vec![0.0; n];
        let mut trace = ConvergenceTrace::new(self.name());
        let mut cost = 0.0;
        if opts.trace_every > 0.0 {
            trace.push(0.0, opts.trace_error(problem, &h));
        }
        let updates_per_unit = n.max(1);
        let mut residual = norm1(&f);
        // greedy uses the exponent-bucket queue (O(1) amortized per pick —
        // §Perf iterations 1-3); other sequences use SequenceState
        let use_heap = self.sequence == SequenceKind::GreedyMaxFluid;
        let mut heap = super::greedy_heap::GreedyQueue::new(n);
        if use_heap {
            for (i, &fi) in f.iter().enumerate() {
                heap.push(i, fi.abs());
            }
        }
        let mut seq = SequenceState::new(self.sequence, (0..n).collect(), self.seed);
        while residual > opts.tol && cost < opts.max_cost {
            for _ in 0..updates_per_unit {
                let i = if use_heap {
                    match heap.pop_valid(|t| f[t]) {
                        Some(i) => i,
                        None => break, // fully drained
                    }
                } else {
                    seq.next(&f)
                };
                let fi = f[i];
                if fi == 0.0 {
                    continue;
                }
                // diffuse node i: H absorbs the fluid, column i re-emits it
                h[i] += fi;
                f[i] = 0.0;
                let (rows, vals) = csc.col(i);
                for k in 0..rows.len() {
                    let j = rows[k];
                    f[j] += vals[k] * fi;
                    if use_heap {
                        heap.push(j, f[j].abs());
                    }
                }
            }
            cost += 1.0;
            residual = norm1(&f); // free convergence monitoring (§3.3)
            if opts.trace_every > 0.0 && (cost / opts.trace_every).fract() == 0.0 {
                trace.push(cost, opts.trace_error(problem, &h));
            }
        }
        Ok(Solution {
            x: h,
            cost,
            residual,
            converged: residual <= opts.tol,
            trace,
        })
    }

    /// One eq.-(2) diffusion step on explicit state — exposed for the
    /// invariant property tests and the V2 distributed scheme.
    pub fn diffuse_once(
        problem: &FixedPointProblem,
        h: &mut [f64],
        f: &mut [f64],
        i: usize,
    ) {
        let fi = f[i];
        h[i] += fi;
        f[i] = 0.0;
        let (rows, vals) = problem.matrix().csc().col(i);
        for k in 0..rows.len() {
            f[rows[k]] += vals[k] * fi;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::paper_matrix;
    use crate::linalg::vec_ops::{dist1, dist_inf};
    use crate::solver::{GaussSeidel, Jacobi};

    fn problem(which: u8) -> FixedPointProblem {
        FixedPointProblem::from_linear_system(&paper_matrix(which), &[1.0; 4]).unwrap()
    }

    #[test]
    fn h_form_converges_all_paper_matrices() {
        for which in 1..=4u8 {
            let p = problem(which);
            let sol = DIteration::cyclic().solve(&p, &SolveOptions::default()).unwrap();
            assert!(sol.converged, "A({which})");
            assert!(dist_inf(&sol.x, &p.exact_solution().unwrap()) < 1e-10);
        }
    }

    #[test]
    fn fluid_form_converges_and_matches_h_form() {
        let p = problem(2);
        let opts = SolveOptions::default();
        let a = DIteration::cyclic().solve(&p, &opts).unwrap();
        let b = DIteration::fluid_cyclic().solve(&p, &opts).unwrap();
        assert!(b.converged);
        assert!(dist1(&a.x, &b.x) < 1e-9);
    }

    #[test]
    fn greedy_variants_converge() {
        let p = problem(3);
        let opts = SolveOptions::default();
        for solver in [
            DIteration::greedy(),
            DIteration {
                sequence: SequenceKind::GreedyMaxFluid,
                variant: DIterationVariant::HForm,
                seed: 0,
            },
            DIteration {
                sequence: SequenceKind::Random,
                variant: DIterationVariant::FluidForm,
                seed: 7,
            },
        ] {
            let sol = solver.solve(&p, &opts).unwrap();
            assert!(sol.converged, "{}", solver.name());
            assert!(dist_inf(&sol.x, &p.exact_solution().unwrap()) < 1e-9);
        }
    }

    #[test]
    fn eq4_invariant_holds_exactly_under_any_sequence() {
        // H + F = F0 + P·H after every diffusion (eq. 4)
        let p = problem(3);
        let n = p.n();
        let mut h = vec![0.0; n];
        let mut f = p.b().to_vec();
        let seq = [2usize, 0, 3, 3, 1, 0, 2, 1, 3, 0];
        for &i in &seq {
            DIteration::diffuse_once(&p, &mut h, &mut f, i);
            let ph = p.matrix().csr().matvec(&h).unwrap();
            for j in 0..n {
                let lhs = h[j] + f[j];
                let rhs = p.b()[j] + ph[j];
                assert!((lhs - rhs).abs() < 1e-13, "invariant broke at j={j}");
            }
        }
    }

    #[test]
    fn beats_or_matches_baselines_on_a1() {
        // the paper's headline: D-iteration converges at least as fast as
        // GS and much faster than Jacobi (in cost units) on A(1)
        let p = problem(1);
        let opts = SolveOptions {
            tol: 1e-10,
            ..Default::default()
        };
        let di = DIteration::cyclic().solve(&p, &opts).unwrap();
        let gs = GaussSeidel::new().solve(&p, &opts).unwrap();
        let ja = Jacobi::new().solve(&p, &opts).unwrap();
        assert!(di.cost <= gs.cost, "diter {} vs gs {}", di.cost, gs.cost);
        assert!(di.cost < ja.cost, "diter {} vs jacobi {}", di.cost, ja.cost);
    }

    #[test]
    fn free_start_is_one_pass_ahead_of_gs() {
        // D-iteration's H after k cycles equals GS's x after k+1 sweeps
        // (H₀ = B is exactly one GS sweep from 0 when diag(P)=0... for the
        // first coordinate pattern; verify the weaker but exact statement
        // that diter's trace error at cost c ≤ GS's at cost c).
        let p = problem(1);
        let exact = p.exact_solution().unwrap();
        let opts = SolveOptions {
            exact: Some(exact),
            tol: 1e-12,
            ..Default::default()
        };
        let di = DIteration::cyclic().solve(&p, &opts).unwrap();
        let gs = GaussSeidel::new().solve(&p, &opts).unwrap();
        for (dp, gp) in di.trace.points.iter().zip(gs.trace.points.iter()) {
            assert!(dp.error <= gp.error + 1e-12);
        }
    }

    #[test]
    fn fluid_residual_equals_f_norm() {
        let p = problem(2);
        let mut h = vec![0.0; 4];
        let mut f = p.b().to_vec();
        for &i in &[0usize, 1, 2] {
            DIteration::diffuse_once(&p, &mut h, &mut f, i);
        }
        let direct = p.residual_norm(&h);
        assert!((norm1(&f) - direct).abs() < 1e-13);
    }
}
