//! Gauss–Seidel and SOR baselines: in-place sweeps `H_i ← L_i(P)·H + B_i`.
//!
//! On the fixed-point form `X = P·X + B`, a cyclic in-place sweep *is*
//! Gauss–Seidel on the underlying `A·X = rhs` after the paper's §5
//! splitting — which is also exactly the D-iteration's eq. (6) with the
//! cyclic sequence starting from `H_0 = 0`. The D-iteration differs by its
//! free start `H_0 = B` (§2.1.1), by arbitrary/greedy sequences, and by
//! its distributed variants.

use super::{FixedPointProblem, Solution, SolveOptions, Solver};
use crate::error::Result;
use crate::metrics::ConvergenceTrace;

/// Classic Gauss–Seidel (cyclic in-place sweeps from zero).
#[derive(Clone, Debug, Default)]
pub struct GaussSeidel;

impl GaussSeidel {
    pub fn new() -> Self {
        Self
    }
}

impl Solver for GaussSeidel {
    fn name(&self) -> &str {
        "gauss-seidel"
    }

    fn solve(&self, problem: &FixedPointProblem, opts: &SolveOptions) -> Result<Solution> {
        sweep_solver(self.name(), problem, opts, 1.0)
    }
}

/// Successive over-relaxation: `H_i ← (1−ω)·H_i + ω·(L_i(P)·H + B_i)`.
#[derive(Clone, Debug)]
pub struct Sor {
    pub omega: f64,
}

impl Sor {
    pub fn new(omega: f64) -> Self {
        assert!(omega > 0.0 && omega < 2.0, "SOR requires 0 < ω < 2");
        Self { omega }
    }
}

impl Solver for Sor {
    fn name(&self) -> &str {
        "sor"
    }

    fn solve(&self, problem: &FixedPointProblem, opts: &SolveOptions) -> Result<Solution> {
        sweep_solver(self.name(), problem, opts, self.omega)
    }
}

fn sweep_solver(
    name: &str,
    problem: &FixedPointProblem,
    opts: &SolveOptions,
    omega: f64,
) -> Result<Solution> {
    let n = problem.n();
    let csr = problem.matrix().csr();
    let mut h = vec![0.0; n];
    let mut trace = ConvergenceTrace::new(name);
    let mut cost = 0.0;
    if opts.trace_every > 0.0 {
        trace.push(0.0, opts.trace_error(problem, &h));
    }
    let mut residual = problem.residual_norm(&h);
    while residual > opts.tol && cost < opts.max_cost {
        for i in 0..n {
            let gs = csr.row_dot(i, &h) + problem.b()[i];
            h[i] = (1.0 - omega) * h[i] + omega * gs;
        }
        cost += 1.0;
        residual = problem.residual_norm(&h);
        if opts.trace_every > 0.0 && (cost / opts.trace_every).fract() == 0.0 {
            trace.push(cost, opts.trace_error(problem, &h));
        }
    }
    Ok(Solution {
        x: h,
        cost,
        residual,
        converged: residual <= opts.tol,
        trace,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::paper_matrix;
    use crate::linalg::vec_ops::dist_inf;
    use crate::solver::Jacobi;

    #[test]
    fn gs_converges_on_all_paper_matrices() {
        for which in 1..=4u8 {
            let p =
                FixedPointProblem::from_linear_system(&paper_matrix(which), &[1.0; 4]).unwrap();
            let sol = GaussSeidel::new().solve(&p, &SolveOptions::default()).unwrap();
            assert!(sol.converged, "A({which})");
            let x = p.exact_solution().unwrap();
            assert!(dist_inf(&sol.x, &x) < 1e-10, "A({which})");
        }
    }

    #[test]
    fn gs_faster_than_jacobi_on_a1() {
        let p = FixedPointProblem::from_linear_system(&paper_matrix(1), &[1.0; 4]).unwrap();
        let opts = SolveOptions {
            tol: 1e-10,
            ..Default::default()
        };
        let gs = GaussSeidel::new().solve(&p, &opts).unwrap();
        let ja = Jacobi::new().solve(&p, &opts).unwrap();
        assert!(
            gs.cost < ja.cost,
            "GS {} vs Jacobi {}",
            gs.cost,
            ja.cost
        );
    }

    #[test]
    fn sor_omega_one_equals_gs() {
        let p = FixedPointProblem::from_linear_system(&paper_matrix(2), &[1.0; 4]).unwrap();
        let opts = SolveOptions {
            max_cost: 5.0,
            tol: 0.0,
            ..Default::default()
        };
        let gs = GaussSeidel::new().solve(&p, &opts).unwrap();
        let sor = Sor::new(1.0).solve(&p, &opts).unwrap();
        assert_eq!(gs.x, sor.x);
    }

    #[test]
    fn sor_converges_with_under_relaxation() {
        let p = FixedPointProblem::from_linear_system(&paper_matrix(3), &[1.0; 4]).unwrap();
        let sol = Sor::new(0.8).solve(&p, &SolveOptions::default()).unwrap();
        assert!(sol.converged);
    }

    #[test]
    #[should_panic]
    fn sor_rejects_bad_omega() {
        let _ = Sor::new(2.5);
    }
}
