//! Jacobi iteration baseline: `H' = P·H + B` (synchronous full update).

use super::{FixedPointProblem, Solution, SolveOptions, Solver};
use crate::error::Result;
use crate::metrics::ConvergenceTrace;

/// The synchronous baseline the paper's figures compare against.
#[derive(Clone, Debug, Default)]
pub struct Jacobi {
    /// start from B (like the D-iteration's free `H_0 = B`, §2.1.1) rather
    /// than from zero, for a like-for-like comparison.
    pub warm_start: bool,
}

impl Jacobi {
    pub fn new() -> Self {
        Self { warm_start: false }
    }
}

impl Solver for Jacobi {
    fn name(&self) -> &str {
        "jacobi"
    }

    fn solve(&self, problem: &FixedPointProblem, opts: &SolveOptions) -> Result<Solution> {
        let n = problem.n();
        let mut h = if self.warm_start {
            problem.b().to_vec()
        } else {
            vec![0.0; n]
        };
        let mut trace = ConvergenceTrace::new(self.name());
        let mut cost = 0.0;
        if opts.trace_every > 0.0 {
            trace.push(0.0, opts.trace_error(problem, &h));
        }
        let mut residual = problem.residual_norm(&h);
        while residual > opts.tol && cost < opts.max_cost {
            // H' = P.H + B  — one full pass, cost 1
            let mut next = problem.matrix().csr().matvec(&h)?;
            for i in 0..n {
                next[i] += problem.b()[i];
            }
            h = next;
            cost += 1.0;
            residual = problem.residual_norm(&h);
            if opts.trace_every > 0.0 && (cost / opts.trace_every).fract() == 0.0 {
                trace.push(cost, opts.trace_error(problem, &h));
            }
        }
        Ok(Solution {
            x: h,
            cost,
            residual,
            converged: residual <= opts.tol,
            trace,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::paper_matrix;
    use crate::linalg::vec_ops::dist_inf;

    #[test]
    fn converges_on_a1() {
        let p = FixedPointProblem::from_linear_system(&paper_matrix(1), &[1.0; 4]).unwrap();
        let sol = Jacobi::new().solve(&p, &SolveOptions::default()).unwrap();
        assert!(sol.converged);
        let x = p.exact_solution().unwrap();
        assert!(dist_inf(&sol.x, &x) < 1e-10);
    }

    #[test]
    fn trace_is_monotone_decreasing_on_a1() {
        let p = FixedPointProblem::from_linear_system(&paper_matrix(1), &[1.0; 4]).unwrap();
        let opts = SolveOptions {
            exact: Some(p.exact_solution().unwrap()),
            ..Default::default()
        };
        let sol = Jacobi::new().solve(&p, &opts).unwrap();
        let errs: Vec<f64> = sol.trace.points.iter().map(|pt| pt.error).collect();
        assert!(errs.len() > 3);
        for w in errs.windows(2) {
            assert!(w[1] <= w[0] + 1e-15, "not decreasing: {w:?}");
        }
    }

    #[test]
    fn respects_max_cost() {
        let p = FixedPointProblem::from_linear_system(&paper_matrix(1), &[1.0; 4]).unwrap();
        let opts = SolveOptions {
            tol: 0.0,
            max_cost: 3.0,
            ..Default::default()
        };
        let sol = Jacobi::new().solve(&p, &opts).unwrap();
        assert!(!sol.converged);
        assert_eq!(sol.cost, 3.0);
    }
}
