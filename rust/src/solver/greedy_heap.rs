//! Approximate-max selector for the greedy diffusion sequence (§4.2).
//!
//! The textbook greedy rule — "diffuse the coordinate with the largest
//! remaining fluid" — costs O(m) per pick as a scan, and a binary heap
//! with one snapshot per fluid *increment* explodes on hub-heavy graphs
//! (a hub's column updates hundreds of coordinates per diffusion; the
//! paper-author workload pushed ~190 snapshots per pop and the heap grew
//! into the hundreds of millions — EXPERIMENTS.md §Perf, iterations 1–2).
//!
//! [`GreedyQueue`] is the standard fix: **bucket by binary exponent**.
//! Each coordinate has at most ONE live entry, sitting in the bucket of
//! its current |fluid| exponent; an update enqueues only when the
//! exponent *changes* (within-bucket growth is free). Pops scan from the
//! highest non-empty bucket, lazily re-filing entries whose fluid moved.
//! The returned coordinate is within 2× of the true maximum — exactly as
//! good for the D-iteration, which only needs to follow the bulk of the
//! fluid (the paper leaves optimal sequences open). All operations are
//! O(1) amortized.

/// Number of distinct f64 biased exponents (0 = zero/subnormal, 2046 max
/// finite). NaN/inf never enter: priorities are |fluid| of finite sums.
const BUCKETS: usize = 2047;
const NONE: u16 = u16::MAX;

#[derive(Debug)]
pub struct GreedyQueue {
    /// bucket b holds coordinates whose |fluid| has biased exponent b
    buckets: Vec<Vec<u32>>,
    /// the bucket each coordinate's live entry is filed under (NONE = out)
    filed: Vec<u16>,
    /// highest bucket that may be non-empty
    top: usize,
    len: usize,
}

#[inline]
fn bucket_of(v: f64) -> usize {
    debug_assert!(v >= 0.0 && v.is_finite());
    ((v.to_bits() >> 52) & 0x7ff) as usize
}

impl GreedyQueue {
    /// A queue over coordinates `0..n`.
    pub fn new(n: usize) -> Self {
        Self {
            buckets: vec![Vec::new(); BUCKETS],
            filed: vec![NONE; n],
            top: 0,
            len: 0,
        }
    }

    /// Extend the coordinate space to `0..n` (no-op if already covered).
    /// Queues are sized to a worker's *owned slice*, not the global
    /// coordinate space — handoff adoption grows them on demand.
    pub fn grow(&mut self, n: usize) {
        if n > self.filed.len() {
            self.filed.resize(n, NONE);
        }
    }

    /// Empty the queue over a (possibly resized) coordinate space
    /// `0..n`, keeping every bucket's backing storage warm. Epoch rebases
    /// refile the whole owned slice; building a fresh queue there would
    /// put ~2k bucket allocations back into the streaming path that the
    /// counting-allocator test asserts is allocation-free.
    pub fn reset(&mut self, n: usize) {
        for b in &mut self.buckets {
            b.clear();
        }
        self.filed.clear();
        self.filed.resize(n, NONE);
        self.top = 0;
        self.len = 0;
    }

    /// Coordinate capacity (the valid `t` range for `push`).
    pub fn capacity(&self) -> usize {
        self.filed.len()
    }

    /// Record that coordinate `t` now carries `|fluid| = priority`.
    /// O(1); a no-op unless the exponent bucket changed.
    #[inline]
    pub fn push(&mut self, t: usize, priority: f64) {
        if priority == 0.0 {
            return;
        }
        let b = bucket_of(priority);
        if self.filed[t] == b as u16 {
            return; // still filed in the right bucket
        }
        // the entry in the old bucket (if any) becomes stale; it will be
        // dropped when encountered because `filed` no longer matches
        if self.filed[t] == NONE {
            self.len += 1;
        }
        self.filed[t] = b as u16;
        self.buckets[b].push(t as u32);
        if b > self.top {
            self.top = b;
        }
    }

    /// Pop the (approximately) largest live coordinate. `live(t)` returns
    /// the coordinate's current |fluid| (0 = dead). The returned
    /// coordinate's fluid is within 2× of the maximum live fluid.
    pub fn pop_valid(&mut self, mut live: impl FnMut(usize) -> f64) -> Option<usize> {
        loop {
            while self.top > 0 && self.buckets[self.top].is_empty() {
                self.top -= 1;
            }
            if self.buckets[self.top].is_empty() {
                return None;
            }
            let t = self.buckets[self.top].pop().unwrap() as usize;
            if self.filed[t] != self.top as u16 {
                continue; // stale entry: the coordinate moved buckets
            }
            let v = live(t).abs();
            if v == 0.0 {
                self.filed[t] = NONE;
                self.len -= 1;
                continue;
            }
            let b = bucket_of(v);
            if b >= self.top {
                // still (at least) in this bucket: take it
                self.filed[t] = NONE;
                self.len -= 1;
                return Some(t);
            }
            // fluid shrank below this bucket: re-file and keep scanning
            self.filed[t] = b as u16;
            self.buckets[b].push(t as u32);
        }
    }

    /// Live coordinate count (filed entries).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_near_priority_order() {
        let mut q = GreedyQueue::new(3);
        let f = [0.1, 0.9, 0.4];
        for (t, &v) in f.iter().enumerate() {
            q.push(t, v);
        }
        // 0.9 (exp -1 bucket) strictly above 0.4 (exp -2) and 0.1 (exp -4)
        assert_eq!(q.pop_valid(|t| f[t]), Some(1));
        assert_eq!(q.pop_valid(|t| f[t]), Some(2));
        assert_eq!(q.pop_valid(|t| f[t]), Some(0));
        assert_eq!(q.pop_valid(|t| f[t]), None);
    }

    #[test]
    fn within_bucket_is_approximate() {
        let mut q = GreedyQueue::new(2);
        let f = [0.6, 0.9]; // same exponent bucket
        q.push(0, f[0]);
        q.push(1, f[1]);
        let first = q.pop_valid(|t| f[t]).unwrap();
        let second = q.pop_valid(|t| f[t]).unwrap();
        assert_ne!(first, second);
        // 2x guarantee: whichever pops first is within 2x of the max
        assert!(f[first] * 2.0 >= f[1].max(f[0]));
    }

    #[test]
    fn skips_dead_and_refiles_shrunk() {
        let mut q = GreedyQueue::new(3);
        let mut f = [0.5, 0.9, 0.0];
        q.push(0, 0.5);
        q.push(1, 0.9);
        q.push(2, 0.7);
        f[2] = 0.0; // died after push... (already 0 in live view)
        f[1] = 0.01; // shrank: must be re-filed below 0.5
        assert_eq!(q.pop_valid(|t| f[t]), Some(0));
        assert_eq!(q.pop_valid(|t| f[t]), Some(1));
        assert_eq!(q.pop_valid(|t| f[t]), None);
    }

    #[test]
    fn within_bucket_growth_is_free() {
        let mut q = GreedyQueue::new(1);
        q.push(0, 0.5);
        q.push(0, 0.6);
        q.push(0, 0.7); // same exponent: single filed entry
        assert_eq!(q.len(), 1);
        let f = [0.7];
        assert_eq!(q.pop_valid(|t| f[t]), Some(0));
        assert_eq!(q.pop_valid(|t| f[t]), None);
    }

    #[test]
    fn zero_priority_not_filed() {
        let mut q = GreedyQueue::new(2);
        q.push(0, 0.0);
        assert!(q.is_empty());
        q.push(1, 1e-300); // subnormal is fine (bucket 0 or 1)
        assert_eq!(q.len(), 1);
        let f = [0.0, 1e-300];
        assert_eq!(q.pop_valid(|t| f[t]), Some(1));
    }

    #[test]
    fn drain_visits_every_live_coordinate_once() {
        let mut q = GreedyQueue::new(64);
        let mut f = vec![0.0f64; 64];
        for round in 1..=20 {
            for t in 0..64 {
                f[t] += 0.001 * ((round * (t + 3)) % 17) as f64;
                q.push(t, f[t]);
            }
        }
        // note: coordinates whose increments are ≡ 0 mod 17 every round
        // (e.g. t = 14) never become live and must NOT be returned
        let live_set: Vec<bool> = f.iter().map(|&v| v > 0.0).collect();
        let mut seen = vec![false; 64];
        while let Some(t) = q.pop_valid(|t| f[t]) {
            assert!(!seen[t], "coordinate {t} returned twice");
            seen[t] = true;
            f[t] = 0.0;
        }
        for t in 0..64 {
            assert_eq!(seen[t], live_set[t], "coordinate {t} mismatch");
        }
    }

    #[test]
    fn grow_extends_coordinate_space() {
        let mut q = GreedyQueue::new(2);
        assert_eq!(q.capacity(), 2);
        q.push(0, 0.5);
        q.grow(5);
        assert_eq!(q.capacity(), 5);
        q.push(4, 0.9);
        let f = [0.5, 0.0, 0.0, 0.0, 0.9];
        assert_eq!(q.pop_valid(|t| f[t]), Some(4));
        assert_eq!(q.pop_valid(|t| f[t]), Some(0));
        q.grow(3); // shrinking is a no-op
        assert_eq!(q.capacity(), 5);
    }

    #[test]
    fn reset_empties_but_keeps_bucket_storage() {
        let mut q = GreedyQueue::new(4);
        for (t, v) in [(0usize, 0.9f64), (1, 0.4), (2, 0.1), (3, 0.05)] {
            q.push(t, v);
        }
        assert_eq!(q.len(), 4);
        q.reset(6);
        assert!(q.is_empty());
        assert_eq!(q.capacity(), 6);
        let f = [0.0, 0.0, 0.0, 0.0, 0.7, 0.0];
        assert_eq!(q.pop_valid(|t| f[t]), None, "reset must drop old entries");
        q.push(4, 0.7);
        assert_eq!(q.pop_valid(|t| f[t]), Some(4));
    }

    #[test]
    fn reinsert_after_pop_works() {
        let mut q = GreedyQueue::new(2);
        let mut f = [0.5, 0.0];
        q.push(0, 0.5);
        assert_eq!(q.pop_valid(|t| f[t]), Some(0));
        f[0] = 0.0;
        f[1] = 0.25;
        q.push(1, 0.25);
        assert_eq!(q.pop_valid(|t| f[t]), Some(1));
    }
}
