//! Diffusion-sequence strategies (§4.2): the order `I = {i_1, i_2, ...}` in
//! which coordinates are diffused. The paper's default is cyclic; greedy
//! (largest remaining fluid first) follows [3, 4]; random-fair is the
//! stochastic baseline. Finding the optimal sequence is explicitly open.

use crate::prng::Xoshiro256pp;

/// Which sequence strategy to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SequenceKind {
    /// 0, 1, ..., n-1, 0, 1, ... (within the owned set)
    Cyclic,
    /// uniformly random but fair-in-expectation picks
    Random,
    /// argmax |F_i| over the owned set — the greedy rule of [3, 4]
    GreedyMaxFluid,
}

impl SequenceKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "cyclic" => Some(Self::Cyclic),
            "random" => Some(Self::Random),
            "greedy" => Some(Self::GreedyMaxFluid),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Cyclic => "cyclic",
            Self::Random => "random",
            Self::GreedyMaxFluid => "greedy",
        }
    }
}

/// Stateful sequence generator over an owned index set.
#[derive(Clone, Debug)]
pub struct SequenceState {
    kind: SequenceKind,
    owned: Vec<usize>,
    pos: usize,
    rng: Xoshiro256pp,
}

impl SequenceState {
    pub fn new(kind: SequenceKind, owned: Vec<usize>, seed: u64) -> Self {
        assert!(!owned.is_empty(), "sequence over empty set");
        Self {
            kind,
            owned,
            pos: 0,
            rng: Xoshiro256pp::seed_from_u64(seed),
        }
    }

    pub fn kind(&self) -> SequenceKind {
        self.kind
    }

    pub fn owned(&self) -> &[usize] {
        &self.owned
    }

    /// Next coordinate to diffuse. `fluid` is the *global* fluid vector
    /// (only the owned entries are inspected); greedy uses it, the others
    /// ignore it.
    pub fn next(&mut self, fluid: &[f64]) -> usize {
        match self.kind {
            SequenceKind::Cyclic => {
                let i = self.owned[self.pos];
                self.pos = (self.pos + 1) % self.owned.len();
                i
            }
            SequenceKind::Random => self.owned[self.rng.below(self.owned.len())],
            SequenceKind::GreedyMaxFluid => {
                let mut best = self.owned[0];
                let mut best_v = fluid[best].abs();
                for &i in &self.owned[1..] {
                    let v = fluid[i].abs();
                    if v > best_v {
                        best_v = v;
                        best = i;
                    }
                }
                best
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cyclic_cycles() {
        let mut s = SequenceState::new(SequenceKind::Cyclic, vec![3, 5, 7], 0);
        let f = vec![0.0; 8];
        let picks: Vec<usize> = (0..6).map(|_| s.next(&f)).collect();
        assert_eq!(picks, vec![3, 5, 7, 3, 5, 7]);
    }

    #[test]
    fn random_is_fair() {
        let mut s = SequenceState::new(SequenceKind::Random, vec![0, 1, 2], 42);
        let f = vec![0.0; 3];
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[s.next(&f)] += 1;
        }
        for c in counts {
            assert!(c > 800, "unfair: {counts:?}");
        }
    }

    #[test]
    fn greedy_picks_max_fluid() {
        let mut s = SequenceState::new(SequenceKind::GreedyMaxFluid, vec![0, 2, 4], 0);
        let f = vec![0.1, 9.0, -0.5, 9.0, 0.2];
        assert_eq!(s.next(&f), 2); // |−0.5| is the max among owned {0,2,4}
        let f = vec![0.1, 9.0, -0.5, 9.0, -0.9];
        assert_eq!(s.next(&f), 4);
    }

    #[test]
    fn parse_roundtrip() {
        for k in [
            SequenceKind::Cyclic,
            SequenceKind::Random,
            SequenceKind::GreedyMaxFluid,
        ] {
            assert_eq!(SequenceKind::parse(k.name()), Some(k));
        }
        assert_eq!(SequenceKind::parse("nope"), None);
    }
}
