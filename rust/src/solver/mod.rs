//! Sequential solvers: the problem type, baselines (Jacobi, Gauss–Seidel,
//! SOR, power iteration) and the sequential D-iteration in both of the
//! paper's forms (H-only eq. 5 and fluid F/H eq. 2–3).
//!
//! Cost convention used by every trace (and all figures): **1 cost unit =
//! N scalar coordinate updates** ("one equivalent full pass"). A Jacobi
//! step, a Gauss–Seidel sweep and a full cyclic D-iteration cycle each cost
//! 1; in the distributed runs each PID's local updates are charged to that
//! PID and the *parallel* cost of a round is the max over PIDs.

mod convergence;
mod diteration;
mod gauss_seidel;
mod greedy_heap;
mod jacobi;
mod power;
mod reductions;
mod sequence;

pub use convergence::{distance_bound_epsilon, distance_bound_pagerank, ConvergenceBound};
pub use diteration::{DIteration, DIterationVariant};
pub use greedy_heap::GreedyQueue;
pub use gauss_seidel::{GaussSeidel, Sor};
pub use jacobi::Jacobi;
pub use power::PowerIteration;
pub use reductions::{eigen_problem, richardson_omega, richardson_problem};
pub use sequence::{SequenceKind, SequenceState};

use crate::error::{DiterError, Result};
use crate::linalg::{solve_dense, DenseMat};
use crate::metrics::ConvergenceTrace;
use crate::sparse::SparseMatrix;

/// A fixed-point problem `X = P·X + B` with ρ(P) < 1.
#[derive(Clone, Debug)]
pub struct FixedPointProblem {
    matrix: SparseMatrix,
    b: Vec<f64>,
}

impl FixedPointProblem {
    /// From an iteration matrix and offset vector directly.
    pub fn new(matrix: SparseMatrix, b: Vec<f64>) -> Result<Self> {
        if matrix.n() != b.len() {
            return Err(DiterError::shape("FixedPointProblem", matrix.n(), b.len()));
        }
        Ok(Self { matrix, b })
    }

    /// The paper §5 construction: from `A·X = rhs` build `P = −a_ij/a_ii`
    /// (zero diagonal) and `B = rhs_i/a_ii`.
    pub fn from_linear_system(a: &DenseMat, rhs: &[f64]) -> Result<Self> {
        if !a.is_square() {
            return Err(DiterError::shape(
                "from_linear_system",
                "square",
                format!("{}x{}", a.rows(), a.cols()),
            ));
        }
        if rhs.len() != a.rows() {
            return Err(DiterError::shape("from_linear_system", a.rows(), rhs.len()));
        }
        let n = a.rows();
        let mut p = DenseMat::zeros(n, n);
        let mut b = vec![0.0; n];
        for i in 0..n {
            let aii = a[(i, i)];
            if aii == 0.0 {
                return Err(DiterError::NotContractive(format!(
                    "a[{i},{i}] = 0: Jacobi-style splitting undefined"
                )));
            }
            for j in 0..n {
                if j != i {
                    p[(i, j)] = -a[(i, j)] / aii;
                }
            }
            b[i] = rhs[i] / aii;
        }
        Ok(Self {
            matrix: SparseMatrix::from_dense(&p),
            b,
        })
    }

    pub fn n(&self) -> usize {
        self.b.len()
    }

    pub fn matrix(&self) -> &SparseMatrix {
        &self.matrix
    }

    pub fn b(&self) -> &[f64] {
        &self.b
    }

    /// Exact solution via dense LU on `(I − P)·x = b` (small/medium N).
    pub fn exact_solution(&self) -> Result<Vec<f64>> {
        let n = self.n();
        let p = self.matrix.csr().to_dense();
        let mut a = DenseMat::identity(n);
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] -= p[(i, j)];
            }
        }
        solve_dense(&a, &self.b)
    }

    /// Fluid vector `F = P·H + B − H` (eq. 4 rearranged).
    pub fn fluid(&self, h: &[f64]) -> Vec<f64> {
        let mut f = self.matrix.csr().matvec(h).expect("shape");
        for i in 0..self.n() {
            f[i] += self.b[i] - h[i];
        }
        f
    }

    /// Remaining-fluid norm `Σ_i |L_i(P)·H + B_i − H_i|` (§4.1's Σ r_k).
    pub fn residual_norm(&self, h: &[f64]) -> f64 {
        let csr = self.matrix.csr();
        let mut acc = 0.0;
        for i in 0..self.n() {
            acc += (csr.row_dot(i, h) + self.b[i] - h[i]).abs();
        }
        acc
    }

    /// Check `x` against the fixed-point equation; returns the residual.
    pub fn verify_solution(&self, x: &[f64], tol: f64) -> Result<Verified> {
        if x.len() != self.n() {
            return Err(DiterError::shape("verify_solution", self.n(), x.len()));
        }
        let residual = self.residual_norm(x);
        if residual > tol {
            return Err(DiterError::DidNotConverge {
                iterations: 0,
                residual,
                tol,
            });
        }
        Ok(Verified { residual })
    }
}

/// Successful verification report.
#[derive(Clone, Copy, Debug)]
pub struct Verified {
    pub residual: f64,
}

/// Options common to every solver.
#[derive(Clone, Debug)]
pub struct SolveOptions {
    /// stop when the remaining-fluid norm drops below this
    pub tol: f64,
    /// hard cap in cost units (equivalent full passes)
    pub max_cost: f64,
    /// if set, traces record L1 distance to this exact solution;
    /// otherwise they record the residual norm
    pub exact: Option<Vec<f64>>,
    /// record a point every `trace_every` cost units (0 = no trace)
    pub trace_every: f64,
}

impl Default for SolveOptions {
    fn default() -> Self {
        Self {
            tol: 1e-12,
            max_cost: 10_000.0,
            exact: None,
            trace_every: 1.0,
        }
    }
}

impl SolveOptions {
    /// Error measure for traces: distance to exact if known, else residual.
    pub fn trace_error(&self, problem: &FixedPointProblem, h: &[f64]) -> f64 {
        match &self.exact {
            Some(x) => crate::linalg::vec_ops::dist1(h, x),
            None => problem.residual_norm(h),
        }
    }
}

/// Result of a sequential solve.
#[derive(Clone, Debug)]
pub struct Solution {
    pub x: Vec<f64>,
    /// total cost in equivalent full passes
    pub cost: f64,
    pub residual: f64,
    pub converged: bool,
    pub trace: ConvergenceTrace,
}

/// Common interface for all sequential solvers.
pub trait Solver {
    fn name(&self) -> &str;
    fn solve(&self, problem: &FixedPointProblem, opts: &SolveOptions) -> Result<Solution>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::paper_matrix;

    #[test]
    fn from_linear_system_matches_paper() {
        let p = FixedPointProblem::from_linear_system(&paper_matrix(1), &[1.0; 4]).unwrap();
        let d = p.matrix().csr().to_dense();
        assert!((d[(0, 1)] - (-0.6)).abs() < 1e-15);
        assert!((d[(1, 0)] - (-3.0 / 7.0)).abs() < 1e-15);
        assert!((d[(2, 3)] - (-0.5)).abs() < 1e-15);
        assert!((d[(3, 2)] - (-2.0 / 3.0)).abs() < 1e-15);
        assert!((p.b()[0] - 0.2).abs() < 1e-15);
    }

    #[test]
    fn exact_solution_solves_fixed_point() {
        let p = FixedPointProblem::from_linear_system(&paper_matrix(2), &[1.0; 4]).unwrap();
        let x = p.exact_solution().unwrap();
        assert!(p.residual_norm(&x) < 1e-12);
        assert!(p.verify_solution(&x, 1e-10).is_ok());
    }

    #[test]
    fn fluid_consistent_with_residual() {
        let p = FixedPointProblem::from_linear_system(&paper_matrix(3), &[1.0; 4]).unwrap();
        let h = vec![0.1, 0.2, 0.3, 0.4];
        let f = p.fluid(&h);
        let norm: f64 = f.iter().map(|v| v.abs()).sum();
        assert!((norm - p.residual_norm(&h)).abs() < 1e-14);
    }

    #[test]
    fn zero_diagonal_rejected() {
        let a = DenseMat::from_rows(&[&[0.0, 1.0], &[1.0, 1.0]]);
        assert!(FixedPointProblem::from_linear_system(&a, &[1.0, 1.0]).is_err());
    }

    #[test]
    fn verify_rejects_bad_solution() {
        let p = FixedPointProblem::from_linear_system(&paper_matrix(1), &[1.0; 4]).unwrap();
        assert!(p.verify_solution(&[0.0; 4], 1e-10).is_err());
    }
}
