//! Power iteration: the eigenvector baseline (`Q·X = X` form of §1).
//!
//! For PageRank-style matrices the fixed point of `X = P·X + B` coincides
//! (up to scale) with the dominant eigenvector of the Google matrix; the
//! power method is the classical way to compute it and the natural third
//! baseline next to Jacobi/GS.

use crate::error::{DiterError, Result};
use crate::linalg::vec_ops::{dist1, norm1};
use crate::metrics::ConvergenceTrace;
use crate::sparse::SparseMatrix;

/// Power iteration on a non-negative matrix `Q` (column-stochastic up to
/// dangling loss): `x ← Q·x / ‖Q·x‖₁`.
#[derive(Clone, Debug)]
pub struct PowerIteration {
    pub tol: f64,
    pub max_iter: usize,
}

impl Default for PowerIteration {
    fn default() -> Self {
        Self {
            tol: 1e-12,
            max_iter: 100_000,
        }
    }
}

/// Result of a power-method run.
#[derive(Clone, Debug)]
pub struct PowerSolution {
    pub x: Vec<f64>,
    pub iterations: usize,
    pub delta: f64,
    pub converged: bool,
    pub trace: ConvergenceTrace,
}

impl PowerIteration {
    /// Run until `‖x_{k+1} − x_k‖₁ < tol`. `exact` (optional) switches the
    /// trace to distance-to-limit.
    pub fn run(
        &self,
        q: &SparseMatrix,
        x0: Option<Vec<f64>>,
        exact: Option<&[f64]>,
    ) -> Result<PowerSolution> {
        let n = q.n();
        let mut x = x0.unwrap_or_else(|| vec![1.0 / n as f64; n]);
        if x.len() != n {
            return Err(DiterError::shape("power x0", n, x.len()));
        }
        let mut trace = ConvergenceTrace::new("power");
        let mut delta = f64::INFINITY;
        let mut it = 0;
        while it < self.max_iter {
            let mut next = q.csr().matvec(&x)?;
            let norm = norm1(&next);
            if norm == 0.0 {
                return Err(DiterError::NotContractive(
                    "power iteration hit the zero vector".into(),
                ));
            }
            for v in next.iter_mut() {
                *v /= norm;
            }
            delta = dist1(&next, &x);
            x = next;
            it += 1;
            match exact {
                Some(e) => trace.push(it as f64, dist1(&x, e)),
                None => trace.push(it as f64, delta),
            }
            if delta < self.tol {
                break;
            }
        }
        Ok(PowerSolution {
            x,
            iterations: it,
            delta,
            converged: delta < self.tol,
            trace,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMat;

    #[test]
    fn finds_dominant_eigenvector() {
        // column-stochastic 2x2: stationary distribution is (2/3, 1/3)
        // for q = [[0.8, 0.4], [0.2, 0.6]]
        let q = SparseMatrix::from_dense(&DenseMat::from_rows(&[&[0.8, 0.4], &[0.2, 0.6]]));
        let sol = PowerIteration::default().run(&q, None, None).unwrap();
        assert!(sol.converged);
        assert!((sol.x[0] - 2.0 / 3.0).abs() < 1e-9);
        assert!((sol.x[1] - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn normalized_every_step() {
        let q = SparseMatrix::from_dense(&DenseMat::from_rows(&[&[0.5, 0.5], &[0.5, 0.5]]));
        let sol = PowerIteration::default().run(&q, None, None).unwrap();
        assert!((norm1(&sol.x) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn zero_matrix_fails() {
        let q = SparseMatrix::from_dense(&DenseMat::zeros(3, 3));
        assert!(PowerIteration::default().run(&q, None, None).is_err());
    }

    #[test]
    fn respects_max_iter() {
        let q = SparseMatrix::from_dense(&DenseMat::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]));
        // period-2 oscillation never converges
        let p = PowerIteration {
            tol: 1e-15,
            max_iter: 10,
        };
        let sol = p.run(&q, Some(vec![0.9, 0.1]), None).unwrap();
        assert!(!sol.converged);
        assert_eq!(sol.iterations, 10);
    }
}
