//! Distance-to-the-limit bounds (§4.4).
//!
//! At any point of the computation let `r = Σ_k r_k` be the total remaining
//! fluid. The paper gives:
//!
//! * **PageRank-style** (`P = d·S̄`, columns summing to d): `r/(1−d)` is an
//!   *exact* L1 distance to the limit (upper bound with unpatched dangling
//!   nodes) for non-negative fluids;
//! * **general**: with `ε = min_i (1 − Σ_j |p_{ji}|) > 0`, `r/ε` is an
//!   upper bound on `‖X − H‖₁`.

use crate::sparse::SparseMatrix;

/// A computable distance-to-limit bound for a given matrix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ConvergenceBound {
    /// `r / (1 − d)` — PageRank-style, exact when mass-conserving.
    PageRank { damping: f64 },
    /// `r / ε` with `ε = min_i (1 − Σ_j |p_{ji}|)`.
    Epsilon { epsilon: f64 },
    /// no bound applies (ε ≤ 0): report the raw residual only.
    None,
}

impl ConvergenceBound {
    /// Choose the best available bound for `p` (PageRank if a damping is
    /// supplied and the column check passes, else ε, else none).
    pub fn for_matrix(p: &SparseMatrix, damping: Option<f64>) -> Self {
        if let Some(d) = damping {
            if d > 0.0
                && d < 1.0
                && p.csr().col_l1_norms().iter().all(|&s| s <= d + 1e-12)
            {
                return ConvergenceBound::PageRank { damping: d };
            }
        }
        let eps = p.epsilon();
        if eps > 0.0 {
            ConvergenceBound::Epsilon { epsilon: eps }
        } else {
            ConvergenceBound::None
        }
    }

    /// Turn a residual (total remaining fluid) into a distance bound.
    /// `None` bound returns the residual unchanged (best effort).
    pub fn distance(&self, residual: f64) -> f64 {
        match self {
            ConvergenceBound::PageRank { damping } => residual / (1.0 - damping),
            ConvergenceBound::Epsilon { epsilon } => residual / epsilon,
            ConvergenceBound::None => residual,
        }
    }

    /// The residual level needed to guarantee distance ≤ `target`.
    pub fn residual_target(&self, target: f64) -> f64 {
        match self {
            ConvergenceBound::PageRank { damping } => target * (1.0 - damping),
            ConvergenceBound::Epsilon { epsilon } => target * epsilon,
            ConvergenceBound::None => target,
        }
    }
}

/// Convenience: the PageRank bound `r/(1−d)`.
pub fn distance_bound_pagerank(residual: f64, damping: f64) -> f64 {
    residual / (1.0 - damping)
}

/// Convenience: the general bound `r/ε`.
pub fn distance_bound_epsilon(residual: f64, epsilon: f64) -> f64 {
    residual / epsilon
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{pagerank_system, power_law_web_graph};
    use crate::linalg::vec_ops::dist1;
    use crate::solver::{DIteration, FixedPointProblem, SolveOptions, Solver};

    #[test]
    fn pagerank_bound_is_valid_along_the_run() {
        let g = power_law_web_graph(300, 5, 0.1, 4);
        let sys = pagerank_system(&g, 0.85, true).unwrap();
        let problem = FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap();
        let exact = {
            // converge hard to get the limit
            let opts = SolveOptions {
                tol: 1e-15,
                max_cost: 100_000.0,
                trace_every: 0.0,
                exact: None,
            };
            DIteration::fluid_cyclic().solve(&problem, &opts).unwrap().x
        };
        let bound = ConvergenceBound::for_matrix(&sys.matrix, Some(0.85));
        assert!(matches!(bound, ConvergenceBound::PageRank { .. }));
        // partially converge, then check distance ≤ bound
        for max_cost in [1.0, 2.0, 5.0, 10.0] {
            let opts = SolveOptions {
                tol: 0.0,
                max_cost,
                trace_every: 0.0,
                exact: None,
            };
            let sol = DIteration::fluid_cyclic().solve(&problem, &opts).unwrap();
            let dist = dist1(&sol.x, &exact);
            let bnd = bound.distance(sol.residual);
            assert!(
                dist <= bnd * (1.0 + 1e-9),
                "cost {max_cost}: dist {dist} > bound {bnd}"
            );
        }
    }

    #[test]
    fn epsilon_bound_selected_when_no_damping() {
        let g = power_law_web_graph(100, 4, 0.1, 5);
        let sys = pagerank_system(&g, 0.85, true).unwrap();
        let b = ConvergenceBound::for_matrix(&sys.matrix, None);
        match b {
            ConvergenceBound::Epsilon { epsilon } => {
                assert!((epsilon - 0.15).abs() < 1e-9, "ε = {epsilon}");
            }
            other => panic!("expected epsilon bound, got {other:?}"),
        }
    }

    #[test]
    fn residual_target_roundtrip() {
        let b = ConvergenceBound::PageRank { damping: 0.85 };
        let t = b.residual_target(1e-6);
        assert!((b.distance(t) - 1e-6).abs() < 1e-18);
        let e = ConvergenceBound::Epsilon { epsilon: 0.2 };
        assert!((e.distance(e.residual_target(0.5)) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn none_bound_passthrough() {
        assert_eq!(ConvergenceBound::None.distance(0.3), 0.3);
        assert_eq!(ConvergenceBound::None.residual_target(0.3), 0.3);
    }
}
