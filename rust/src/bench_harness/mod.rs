//! Benchmark substrate (no `criterion` offline): warmup + timed repeats,
//! robust stats, aligned table rendering used by every `cargo bench`
//! target to print the paper's tables/figures as text series, and the
//! [`Json`] emitter behind the committed `BENCH_*.json` perf trajectory.

mod json;

pub use json::{bench_json_dir, Json};

use std::fmt::Write as _;
use std::time::Instant;

/// Timing statistics over repeated runs (seconds).
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p99: f64,
    pub max: f64,
}

impl Stats {
    pub fn from_samples(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats {
            n,
            mean,
            std: var.sqrt(),
            min: xs[0],
            p50: percentile(&xs, 0.50),
            p99: percentile(&xs, 0.99),
            max: xs[n - 1],
        }
    }
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Time `f` with `warmup` unmeasured runs then `reps` measured ones.
/// Returns per-run wall-clock stats. The closure's return value is
/// black-boxed so the work cannot be optimized away.
pub fn bench<T>(warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    Stats::from_samples(samples)
}

/// Minimal black_box (stable): volatile read through a pointer.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66 — use it.
    std::hint::black_box(x)
}

/// An aligned text table builder for bench output.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize], out: &mut String| {
            for (c, cell) in cells.iter().enumerate() {
                let _ = write!(out, "{:>width$}  ", cell, width = widths[c]);
            }
            out.push('\n');
        };
        fmt_row(&self.header, &widths, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * ncol;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &widths, &mut out);
        }
        out
    }
}

/// Format seconds human-readably for tables.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

/// Standard header printed by every bench binary.
pub fn bench_header(name: &str, what: &str) {
    println!("==============================================================");
    println!("bench: {name}");
    println!("{what}");
    println!("==============================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_known_values() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_single() {
        let s = Stats::from_samples(vec![5.0]);
        assert_eq!(s.p50, 5.0);
        assert_eq!(s.p99, 5.0);
    }

    #[test]
    fn bench_runs_and_measures() {
        let mut count = 0usize;
        let s = bench(2, 5, || {
            count += 1;
            count
        });
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["k", "time"]);
        t.row(&["1".into(), "10ms".into()]);
        t.row(&["200".into(), "5ms".into()]);
        let r = t.render();
        assert!(r.contains("k"));
        assert_eq!(r.lines().count(), 4);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }
}
