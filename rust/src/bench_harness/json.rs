//! Minimal JSON emission for the machine-readable bench artifacts
//! (`BENCH_*.json`) — no serde offline; just enough structure for the CI
//! perf-regression gate (`tools/bench_gate.py`) and the repo's recorded
//! perf trajectory.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// Where the `BENCH_*.json` artifacts go: `DITER_BENCH_JSON_DIR`
/// (absolute, or relative to the **workspace root** — cargo runs benches
/// with cwd = the package root `rust/`, so a plain relative path would
/// silently land one level too deep), defaulting to the workspace root
/// where the committed baselines live. The directory is created.
pub fn bench_json_dir() -> PathBuf {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap_or_else(|| Path::new("."))
        .to_path_buf();
    let dir = match std::env::var("DITER_BENCH_JSON_DIR") {
        Ok(d) if Path::new(&d).is_absolute() => PathBuf::from(d),
        Ok(d) => root.join(d),
        Err(_) => root,
    };
    let _ = std::fs::create_dir_all(&dir);
    dir
}

/// A JSON object builder (insertion-ordered, chainable).
#[derive(Clone, Debug, Default)]
pub struct Json {
    fields: Vec<(String, String)>,
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn num(v: f64) -> String {
    // JSON has no NaN/inf literals; record them as null
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

impl Json {
    pub fn new() -> Json {
        Json::default()
    }

    fn raw(mut self, name: &str, rendered: String) -> Json {
        self.fields.push((name.to_string(), rendered));
        self
    }

    pub fn str_field(self, name: &str, value: &str) -> Json {
        let rendered = format!("\"{}\"", esc(value));
        self.raw(name, rendered)
    }

    pub fn num_field(self, name: &str, value: f64) -> Json {
        let rendered = num(value);
        self.raw(name, rendered)
    }

    pub fn int_field(self, name: &str, value: u64) -> Json {
        self.raw(name, value.to_string())
    }

    pub fn bool_field(self, name: &str, value: bool) -> Json {
        self.raw(name, value.to_string())
    }

    pub fn null_field(self, name: &str) -> Json {
        self.raw(name, "null".into())
    }

    pub fn obj_field(self, name: &str, inner: Json) -> Json {
        let rendered = inner.render();
        self.raw(name, rendered)
    }

    pub fn arr_num_field(self, name: &str, values: &[f64]) -> Json {
        let rendered = format!(
            "[{}]",
            values.iter().map(|&v| num(v)).collect::<Vec<_>>().join(", ")
        );
        self.raw(name, rendered)
    }

    /// Render to a JSON object string.
    pub fn render(&self) -> String {
        let mut out = String::from("{");
        for (i, (name, rendered)) in self.fields.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "\n  \"{}\": {}", esc(name), rendered);
        }
        out.push_str("\n}");
        out
    }

    /// Render and write to `path` (with a trailing newline).
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.render() + "\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_field_kinds() {
        let j = Json::new()
            .str_field("name", "streaming_churn")
            .int_field("n", 10_000)
            .num_field("rate", 2.5e6)
            .bool_field("measured", true)
            .null_field("absent")
            .arr_num_field("walls", &[0.5, 1.25])
            .obj_field("inner", Json::new().num_field("x", 1.0));
        let s = j.render();
        assert!(s.contains("\"name\": \"streaming_churn\""));
        assert!(s.contains("\"n\": 10000"));
        assert!(s.contains("\"rate\": 2500000"));
        assert!(s.contains("\"measured\": true"));
        assert!(s.contains("\"absent\": null"));
        assert!(s.contains("[0.5, 1.25]"));
        assert!(s.contains("\"x\": 1"));
        assert!(s.starts_with('{') && s.ends_with('}'));
    }

    #[test]
    fn escapes_strings_and_nulls_non_finite() {
        let s = Json::new()
            .str_field("msg", "a \"b\"\\\n\t")
            .num_field("nan", f64::NAN)
            .num_field("inf", f64::INFINITY)
            .render();
        assert!(s.contains("a \\\"b\\\"\\\\\\n\\t"));
        assert!(s.contains("\"nan\": null"));
        assert!(s.contains("\"inf\": null"));
    }

    #[test]
    fn writes_to_disk() {
        let dir = std::env::temp_dir().join("diter_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.json");
        Json::new().int_field("v", 7).write(&path).unwrap();
        let got = std::fs::read_to_string(&path).unwrap();
        assert_eq!(got, "{\n  \"v\": 7\n}\n");
        let _ = std::fs::remove_file(&path);
    }
}
