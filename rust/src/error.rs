//! Crate-wide error type.

use thiserror::Error;

/// All errors produced by the `diter` crate.
#[derive(Debug, Error)]
pub enum DiterError {
    /// Dimension mismatch between operands (`what` describes the operation).
    #[error("shape mismatch in {what}: expected {expected}, got {got}")]
    ShapeMismatch {
        what: &'static str,
        expected: String,
        got: String,
    },

    /// The iteration matrix does not satisfy the convergence precondition
    /// (spectral radius / diagonal-dominance check failed).
    #[error("convergence precondition violated: {0}")]
    NotContractive(String),

    /// Singular or near-singular matrix in a direct solve.
    #[error("singular matrix: pivot {pivot} at column {col}")]
    Singular { col: usize, pivot: f64 },

    /// An iterative method hit its iteration cap before reaching tolerance.
    #[error("did not converge: residual {residual} after {iterations} iterations (tol {tol})")]
    DidNotConverge {
        iterations: usize,
        residual: f64,
        tol: f64,
    },

    /// Partition is not an exact cover of `0..n`.
    #[error("invalid partition: {0}")]
    InvalidPartition(String),

    /// Config file / CLI parse errors.
    #[error("parse error at {location}: {message}")]
    Parse { location: String, message: String },

    /// Transport-level failure (closed endpoint, lost ack, ...).
    #[error("transport error: {0}")]
    Transport(String),

    /// Coordinator-level failure (worker panic, protocol violation, ...).
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// PJRT runtime failure (artifact missing, compile/execute error).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Generic I/O.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

pub type Result<T> = std::result::Result<T, DiterError>;

impl DiterError {
    /// Helper for shape errors.
    pub fn shape(what: &'static str, expected: impl ToString, got: impl ToString) -> Self {
        DiterError::ShapeMismatch {
            what,
            expected: expected.to_string(),
            got: got.to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages() {
        let e = DiterError::shape("matvec", "4", "5");
        assert!(e.to_string().contains("matvec"));
        let e = DiterError::DidNotConverge {
            iterations: 10,
            residual: 0.5,
            tol: 1e-9,
        };
        assert!(e.to_string().contains("10"));
    }
}
