//! Crate-wide error type.
//!
//! Display/Error are implemented by hand: the crate builds with zero
//! external dependencies so it compiles on a clean machine with no
//! registry access (no `thiserror`).

use std::fmt;

/// All errors produced by the `diter` crate.
#[derive(Debug)]
pub enum DiterError {
    /// Dimension mismatch between operands (`what` describes the operation).
    ShapeMismatch {
        what: &'static str,
        expected: String,
        got: String,
    },

    /// The iteration matrix does not satisfy the convergence precondition
    /// (spectral radius / diagonal-dominance check failed).
    NotContractive(String),

    /// Singular or near-singular matrix in a direct solve.
    Singular { col: usize, pivot: f64 },

    /// An iterative method hit its iteration cap before reaching tolerance.
    DidNotConverge {
        iterations: usize,
        residual: f64,
        tol: f64,
    },

    /// Partition is not an exact cover of `0..n`.
    InvalidPartition(String),

    /// Config file / CLI parse errors.
    Parse { location: String, message: String },

    /// Transport-level failure (closed endpoint, lost ack, ...).
    Transport(String),

    /// Coordinator-level failure (worker panic, protocol violation, ...).
    Coordinator(String),

    /// A worker died mid-run (EOF/reset on its control connection, or a
    /// missed heartbeat deadline). Carries the PID so the caller can name
    /// the casualty instead of burning `max_wall` on a peer that will
    /// never report again.
    WorkerDied(usize),

    /// PJRT runtime failure (artifact missing, compile/execute error).
    Runtime(String),

    /// Generic I/O.
    Io(std::io::Error),
}

impl fmt::Display for DiterError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DiterError::ShapeMismatch {
                what,
                expected,
                got,
            } => write!(f, "shape mismatch in {what}: expected {expected}, got {got}"),
            DiterError::NotContractive(msg) => {
                write!(f, "convergence precondition violated: {msg}")
            }
            DiterError::Singular { col, pivot } => {
                write!(f, "singular matrix: pivot {pivot} at column {col}")
            }
            DiterError::DidNotConverge {
                iterations,
                residual,
                tol,
            } => write!(
                f,
                "did not converge: residual {residual} after {iterations} iterations (tol {tol})"
            ),
            DiterError::InvalidPartition(msg) => write!(f, "invalid partition: {msg}"),
            DiterError::Parse { location, message } => {
                write!(f, "parse error at {location}: {message}")
            }
            DiterError::Transport(msg) => write!(f, "transport error: {msg}"),
            DiterError::Coordinator(msg) => write!(f, "coordinator error: {msg}"),
            DiterError::WorkerDied(pid) => write!(f, "worker {pid} died mid-run"),
            DiterError::Runtime(msg) => write!(f, "runtime error: {msg}"),
            DiterError::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for DiterError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DiterError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for DiterError {
    fn from(e: std::io::Error) -> Self {
        DiterError::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, DiterError>;

impl DiterError {
    /// Helper for shape errors.
    pub fn shape(what: &'static str, expected: impl ToString, got: impl ToString) -> Self {
        DiterError::ShapeMismatch {
            what,
            expected: expected.to_string(),
            got: got.to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages() {
        let e = DiterError::shape("matvec", "4", "5");
        assert!(e.to_string().contains("matvec"));
        let e = DiterError::DidNotConverge {
            iterations: 10,
            residual: 0.5,
            tol: 1e-9,
        };
        assert!(e.to_string().contains("10"));
        let e = DiterError::WorkerDied(3);
        assert!(e.to_string().contains("worker 3"));
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "missing");
        let e: DiterError = io.into();
        assert!(e.to_string().contains("missing"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
