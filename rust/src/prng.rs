//! Deterministic PRNG substrate (no `rand` crate available offline).
//!
//! Everything that needs randomness in this crate — graph generators,
//! random diffusion sequences, property tests, latency injection — goes
//! through [`Xoshiro256pp`] seeded via [`SplitMix64`], so every experiment
//! is exactly reproducible from a single `u64` seed.

/// SplitMix64: used to expand a single seed into a full generator state.
/// Reference: Steele, Lea & Flood, "Fast splittable pseudorandom number
/// generators" (the standard seeding recipe for xoshiro).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, 256-bit state general-purpose PRNG.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed from a single `u64` (expanded through SplitMix64).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // all-zero state is invalid; SplitMix64 cannot produce 4 zeros from
        // any seed, but belt-and-braces:
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)` (53-bit mantissa method).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform usize in `[0, n)` via Lemire's multiply-shift (unbiased
    /// enough for simulation purposes; n is tiny vs 2^64).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        let mut v: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            v.swap(i, j);
        }
        v.truncate(k);
        v
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Geometric-ish integer from a Zipf(s) distribution over `1..=n`,
    /// by inverse-CDF on a precomputed table-free approximation
    /// (rejection sampling, Devroye). Good enough for degree sequences.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // rejection method for Zipf (Devroye, Non-Uniform Random Variate
        // Generation, p. 550) — exact for s > 1, approximate via clamp else.
        let s = s.max(1.001);
        let b = 2f64.powf(s - 1.0);
        loop {
            let u = self.next_f64();
            let v = self.next_f64();
            let x = (u.powf(-1.0 / (s - 1.0))).floor();
            if x < 1.0 {
                continue;
            }
            let t = (1.0 + 1.0 / x).powf(s - 1.0);
            if v * x * (t - 1.0) / (b - 1.0) <= t / b {
                let k = x as usize;
                if k <= n {
                    return k;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Xoshiro256pp::seed_from_u64(42);
        let mut b = Xoshiro256pp::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256pp::seed_from_u64(1);
        let mut b = Xoshiro256pp::seed_from_u64(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256pp::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Xoshiro256pp::seed_from_u64(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Xoshiro256pp::seed_from_u64(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Xoshiro256pp::seed_from_u64(5);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Xoshiro256pp::seed_from_u64(6);
        let s = r.sample_distinct(50, 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256pp::seed_from_u64(8);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut r = Xoshiro256pp::seed_from_u64(10);
        let mut count1 = 0;
        for _ in 0..2000 {
            let k = r.zipf(100, 2.0);
            assert!((1..=100).contains(&k));
            if k == 1 {
                count1 += 1;
            }
        }
        // Zipf(2): P(1) = 1/zeta(2) ~ 0.61 — heavily skewed to 1.
        assert!(count1 > 800, "count1={count1}");
    }

    #[test]
    fn shuffle_preserves_elements() {
        let mut r = Xoshiro256pp::seed_from_u64(11);
        let mut v: Vec<u32> = (0..64).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..64).collect::<Vec<u32>>());
    }
}
