//! Fluid coalescing (§3.3): "the fluid transmission can be delayed and
//! regrouped — we can regroup (f₁+f₂+…+f_m)·p_{ji} so that this quantity
//! is not too small; we don't need to know who sent the fluid."
//!
//! A [`CoalesceBuffer`] accumulates per-destination-coordinate fluid and
//! releases a batch when the policy says the parcel is worth a message.

use std::collections::HashMap;

/// When to flush a destination's accumulated fluid.
#[derive(Clone, Copy, Debug)]
pub struct CoalescePolicy {
    /// flush when a destination buffer holds at least this much |fluid|
    pub min_mass: f64,
    /// flush when a destination buffer has this many distinct coordinates
    pub max_entries: usize,
}

impl Default for CoalescePolicy {
    fn default() -> Self {
        Self {
            min_mass: 1e-9,
            max_entries: 4096,
        }
    }
}

/// Per-destination coalescing buffer: coordinate → accumulated fluid.
#[derive(Debug)]
pub struct CoalesceBuffer {
    policy: CoalescePolicy,
    /// dest PID → (coordinate → fluid)
    buffers: Vec<HashMap<usize, f64>>,
    /// dest PID → Σ|fluid| currently buffered (approximate upper bound —
    /// opposite-sign merges only shrink the true mass)
    masses: Vec<f64>,
}

impl CoalesceBuffer {
    pub fn new(k: usize, policy: CoalescePolicy) -> Self {
        Self {
            policy,
            buffers: (0..k).map(|_| HashMap::new()).collect(),
            masses: vec![0.0; k],
        }
    }

    /// Accumulate `fluid` for coordinate `j` owned by `dest`.
    pub fn add(&mut self, dest: usize, j: usize, fluid: f64) {
        *self.buffers[dest].entry(j).or_insert(0.0) += fluid;
        self.masses[dest] += fluid.abs();
    }

    /// Destinations whose buffer the policy says should flush now.
    pub fn ready(&self) -> Vec<usize> {
        (0..self.buffers.len())
            .filter(|&d| {
                !self.buffers[d].is_empty()
                    && (self.masses[d] >= self.policy.min_mass
                        || self.buffers[d].len() >= self.policy.max_entries)
            })
            .collect()
    }

    /// Take dest's batch (sorted by coordinate for determinism) + its mass.
    pub fn take(&mut self, dest: usize) -> (Vec<(usize, f64)>, f64) {
        let map = std::mem::take(&mut self.buffers[dest]);
        self.masses[dest] = 0.0;
        let mut batch: Vec<(usize, f64)> = map.into_iter().collect();
        batch.sort_unstable_by_key(|&(j, _)| j);
        let mass = batch.iter().map(|&(_, f)| f.abs()).sum();
        (batch, mass)
    }

    /// Force-flush everything buffered (end of a work quantum).
    pub fn take_all(&mut self) -> Vec<(usize, Vec<(usize, f64)>, f64)> {
        (0..self.buffers.len())
            .filter(|&d| !self.buffers[d].is_empty())
            .collect::<Vec<_>>()
            .into_iter()
            .map(|d| {
                let (batch, mass) = self.take(d);
                (d, batch, mass)
            })
            .collect()
    }

    /// Total |fluid| currently held back (upper bound) — counted by the
    /// convergence monitor as "not yet transmitted" local fluid.
    pub fn held_mass(&self) -> f64 {
        self.masses.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.buffers.iter().all(HashMap::is_empty)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_same_coordinate() {
        let mut c = CoalesceBuffer::new(2, CoalescePolicy::default());
        c.add(1, 7, 0.25);
        c.add(1, 7, 0.25);
        c.add(1, 3, -0.1);
        let (batch, mass) = c.take(1);
        assert_eq!(batch, vec![(3, -0.1), (7, 0.5)]);
        assert!((mass - 0.6).abs() < 1e-12);
        assert!(c.is_empty());
    }

    #[test]
    fn ready_respects_min_mass() {
        let policy = CoalescePolicy {
            min_mass: 1.0,
            max_entries: 100,
        };
        let mut c = CoalesceBuffer::new(2, policy);
        c.add(0, 1, 0.4);
        assert!(c.ready().is_empty());
        c.add(0, 2, 0.7);
        assert_eq!(c.ready(), vec![0]);
    }

    #[test]
    fn ready_respects_max_entries() {
        let policy = CoalescePolicy {
            min_mass: 1e9,
            max_entries: 3,
        };
        let mut c = CoalesceBuffer::new(1, policy);
        c.add(0, 1, 1e-12);
        c.add(0, 2, 1e-12);
        assert!(c.ready().is_empty());
        c.add(0, 3, 1e-12);
        assert_eq!(c.ready(), vec![0]);
    }

    #[test]
    fn take_all_flushes_everything() {
        let mut c = CoalesceBuffer::new(3, CoalescePolicy::default());
        c.add(0, 1, 0.1);
        c.add(2, 5, 0.2);
        let flushed = c.take_all();
        assert_eq!(flushed.len(), 2);
        assert!(c.is_empty());
        assert_eq!(c.held_mass(), 0.0);
    }

    #[test]
    fn held_mass_tracks_additions() {
        let mut c = CoalesceBuffer::new(1, CoalescePolicy::default());
        c.add(0, 0, 0.5);
        c.add(0, 1, -0.25);
        assert!((c.held_mass() - 0.75).abs() < 1e-12);
    }
}
