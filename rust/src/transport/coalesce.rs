//! Fluid coalescing (§3.3): "the fluid transmission can be delayed and
//! regrouped — we can regroup (f₁+f₂+…+f_m)·p_{ji} so that this quantity
//! is not too small; we don't need to know who sent the fluid."
//!
//! A [`CoalesceBuffer`] is a set of **per-destination dense scratch
//! accumulators**. Each destination interns its target coordinates into
//! stable slots (`intern`), so the worker hot loop accumulates with a
//! single indexed add (`add_slot`) — no hashing, no per-emission
//! allocation. A `touched` journal tracks which slots carry fluid since
//! the last flush, so flushing is O(touched), not O(boundary), and
//! produces flat **SoA parcels** `(coords: Vec<u32>, mass: Vec<f64>)` —
//! the wire format of [`crate::coordinator::WorkerMsg::Fluid`]. The
//! accumulator arrays themselves persist across flushes, and parcels are
//! backed by a [`crate::perf::Arena`]: a parcel must be *owned* (it
//! crosses a thread boundary), but storage that comes back — a failed
//! send re-routed by the worker ([`CoalesceBuffer::recycle`]), the
//! internal clear/compact paths — is pooled and reused by the next flush
//! instead of reallocated.
//!
//! The general keyed path (`add`) remains for cold routes — fluid
//! re-forwarded after an ownership change, fostered coordinates — and
//! interns on first sight.
//!
//! Over the wire transport this buffer is one stage of a **closed
//! storage cycle** (DESIGN.md §8.8): parcels decoded out of the receive
//! ring borrow pooled columns, the worker applies them and hands the
//! columns back here ([`CoalesceBuffer::recycle`]), the next flush
//! builds outbound parcels over that same storage, and the wire send
//! path reclaims it again once the parcel is encoded into a frame. The
//! policy-triggered `flush(all=true)` calls are also where the worker
//! invokes [`crate::transport::Transport::flush`], so a threshold
//! crossing or drain pushes the batched frames out immediately instead
//! of waiting for the wire's [`crate::transport::FlushPolicy`] deadline.

use std::collections::HashMap;

use crate::perf::Arena;

/// When to flush a destination's accumulated fluid.
#[derive(Clone, Copy, Debug)]
pub struct CoalescePolicy {
    /// flush when a destination buffer holds at least this much |fluid|
    pub min_mass: f64,
    /// flush when a destination buffer has this many touched coordinates
    pub max_entries: usize,
}

impl Default for CoalescePolicy {
    fn default() -> Self {
        Self {
            min_mass: 1e-9,
            max_entries: 4096,
        }
    }
}

/// One destination's dense scratch accumulator.
#[derive(Debug, Default)]
struct DestAcc {
    /// coordinate → slot (interning map; persists across flushes)
    slot_of: HashMap<usize, u32>,
    /// slot → global coordinate
    coords: Vec<u32>,
    /// slot → accumulated fluid since the last flush
    acc: Vec<f64>,
    is_touched: Vec<bool>,
    /// slots touched since the last flush (the flush work list)
    touched: Vec<u32>,
    /// Σ|fluid| added since the last flush (upper bound — opposite-sign
    /// merges only shrink the true mass)
    mass: f64,
}

impl DestAcc {
    fn intern(&mut self, coord: usize) -> u32 {
        if let Some(&s) = self.slot_of.get(&coord) {
            return s;
        }
        let s = self.coords.len() as u32;
        self.slot_of.insert(coord, s);
        self.coords.push(coord as u32);
        self.acc.push(0.0);
        self.is_touched.push(false);
        s
    }

    #[inline]
    fn add_slot(&mut self, slot: u32, fluid: f64) {
        let s = slot as usize;
        self.acc[s] += fluid;
        self.mass += fluid.abs();
        if !self.is_touched[s] {
            self.is_touched[s] = true;
            self.touched.push(slot);
        }
    }

    /// Drain touched slots into an SoA parcel built over the supplied
    /// (cleared, possibly recycled) buffers; zero entries (exact
    /// cancellation) are dropped. Returns (coords, mass, Σ|mass|).
    fn take_into(
        &mut self,
        mut coords: Vec<u32>,
        mut mass: Vec<f64>,
    ) -> (Vec<u32>, Vec<f64>, f64) {
        debug_assert!(coords.is_empty() && mass.is_empty());
        // no-ops on a recycled buffer that has warmed past touched.len()
        coords.reserve(self.touched.len());
        mass.reserve(self.touched.len());
        let mut total = 0.0;
        for &s in &self.touched {
            let si = s as usize;
            self.is_touched[si] = false;
            let v = self.acc[si];
            self.acc[si] = 0.0;
            if v != 0.0 {
                coords.push(self.coords[si]);
                mass.push(v);
                total += v.abs();
            }
        }
        self.touched.clear();
        self.mass = 0.0;
        (coords, mass, total)
    }
}

/// Per-destination coalescing accumulators (one [`DestAcc`] per PID).
///
/// The destination table **grows on demand**: an elastic worker pool
/// spawns PIDs at runtime, so a destination index seen for the first time
/// (a routing decision against a newer ownership map than the buffer was
/// sized for) simply extends the table. Vacated (retired) destinations
/// keep their empty accumulator — the slot is reused if the PID respawns.
#[derive(Debug)]
pub struct CoalesceBuffer {
    policy: CoalescePolicy,
    accs: Vec<DestAcc>,
    /// recycled parcel storage (coords / mass columns); filled by
    /// [`CoalesceBuffer::recycle`] and the internal clear/compact paths,
    /// drained by every parcel build
    coords_arena: Arena<u32>,
    mass_arena: Arena<f64>,
}

/// Parcel buffers pooled per column kind. Successful sends never return
/// their storage (it crosses a thread), so the pool only ever holds the
/// cold-path returns — a handful suffices.
const PARCEL_POOL: usize = 8;

impl CoalesceBuffer {
    /// A buffer addressing `k` destinations under `policy` (the table
    /// grows on demand when the PID pool widens).
    pub fn new(k: usize, policy: CoalescePolicy) -> Self {
        Self {
            policy,
            accs: (0..k).map(|_| DestAcc::default()).collect(),
            coords_arena: Arena::new(PARCEL_POOL),
            mass_arena: Arena::new(PARCEL_POOL),
        }
    }

    /// Return a parcel's backing storage (e.g. from a failed send whose
    /// fluid was re-routed): the next flush builds over it instead of
    /// allocating. Parcels that ship successfully cross a thread boundary
    /// and never come back — the arena is a bounded cache, not an
    /// accounting system.
    pub fn recycle(&mut self, coords: Vec<u32>, mass: Vec<f64>) {
        self.coords_arena.give(coords);
        self.mass_arena.give(mass);
    }

    /// Extend the destination table to cover `dest` (elastic PID pools
    /// grow K while workers hold buffers sized to an older map).
    #[inline]
    fn ensure(&mut self, dest: usize) {
        if dest >= self.accs.len() {
            self.accs.resize_with(dest + 1, DestAcc::default);
        }
    }

    /// Destinations currently addressable (diagnostics/tests).
    pub fn dests(&self) -> usize {
        self.accs.len()
    }

    /// Assign (or look up) the accumulator slot for coordinate `j` at
    /// `dest` — called at [`crate::sparse::LocalSystem`] build time so the
    /// hot loop can use [`CoalesceBuffer::add_slot`].
    pub fn intern(&mut self, dest: usize, j: usize) -> u32 {
        self.ensure(dest);
        self.accs[dest].intern(j)
    }

    /// Hot path: accumulate `fluid` into a pre-interned slot (slots only
    /// come from [`CoalesceBuffer::intern`], so the table already covers
    /// `dest`).
    #[inline]
    pub fn add_slot(&mut self, dest: usize, slot: u32, fluid: f64) {
        self.accs[dest].add_slot(slot, fluid);
    }

    /// Cold path: accumulate `fluid` for coordinate `j` owned by `dest`,
    /// interning the coordinate on first sight.
    pub fn add(&mut self, dest: usize, j: usize, fluid: f64) {
        self.ensure(dest);
        let slot = self.accs[dest].intern(j);
        self.accs[dest].add_slot(slot, fluid);
    }

    /// Flush destinations into SoA parcels: every non-empty destination
    /// when `all`, otherwise only those the policy says are worth a
    /// message. The sink receives `(dest, coords, mass, Σ|mass|)`.
    pub fn flush(&mut self, all: bool, mut sink: impl FnMut(usize, Vec<u32>, Vec<f64>, f64)) {
        for d in 0..self.accs.len() {
            let a = &mut self.accs[d];
            if a.touched.is_empty() {
                continue;
            }
            if !all && a.mass < self.policy.min_mass && a.touched.len() < self.policy.max_entries
            {
                continue;
            }
            let (coords, mass, total) =
                a.take_into(self.coords_arena.take(), self.mass_arena.take());
            if coords.is_empty() {
                // every touched entry cancelled exactly: no message, and
                // the storage goes straight back to the pool
                self.coords_arena.give(coords);
                self.mass_arena.give(mass);
            } else {
                sink(d, coords, mass, total);
            }
        }
    }

    /// Take one destination's parcel unconditionally (tests/benches).
    pub fn take(&mut self, dest: usize) -> (Vec<u32>, Vec<f64>, f64) {
        let coords = self.coords_arena.take();
        let mass = self.mass_arena.take();
        self.accs[dest].take_into(coords, mass)
    }

    /// Discard everything buffered (epoch transitions: buffered outbound
    /// fluid of the old epoch is obsolete by construction). Interned slots
    /// survive — they stay valid for the patched [`crate::sparse::LocalSystem`].
    pub fn clear(&mut self) {
        for a in &mut self.accs {
            let (coords, mass, _) =
                a.take_into(self.coords_arena.take(), self.mass_arena.take());
            self.coords_arena.give(coords);
            self.mass_arena.give(mass);
        }
    }

    /// Drop every interned slot, preserving pending fluid by re-interning
    /// it fresh. Without this the interner accretes one slot per
    /// coordinate ever routed to a destination (ownership churn +
    /// forwarded fluid trend it toward O(n) per dest over a long run).
    /// Callers must re-intern any slots they cached — the worker core
    /// compacts only immediately before a full `LocalSystem` rebuild,
    /// which re-interns the whole remnant anyway.
    pub fn compact(&mut self) {
        for a in &mut self.accs {
            let (coords, mass, _) =
                a.take_into(self.coords_arena.take(), self.mass_arena.take());
            *a = DestAcc::default();
            for (u, &c) in coords.iter().enumerate() {
                let s = a.intern(c as usize);
                a.add_slot(s, mass[u]);
            }
            self.coords_arena.give(coords);
            self.mass_arena.give(mass);
        }
    }

    /// Interned slot count for a destination (diagnostics/tests).
    pub fn interned(&self, dest: usize) -> usize {
        self.accs[dest].coords.len()
    }

    /// Total |fluid| currently held back (upper bound) — counted by the
    /// convergence monitor as "not yet transmitted" local fluid.
    pub fn held_mass(&self) -> f64 {
        self.accs.iter().map(|a| a.mass).sum()
    }

    /// Whether no destination holds any unflushed fluid.
    pub fn is_empty(&self) -> bool {
        self.accs.iter().all(|a| a.touched.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sorted(mut batch: Vec<(u32, f64)>) -> Vec<(u32, f64)> {
        batch.sort_unstable_by_key(|&(j, _)| j);
        batch
    }

    fn zip(coords: Vec<u32>, mass: Vec<f64>) -> Vec<(u32, f64)> {
        coords.into_iter().zip(mass).collect()
    }

    #[test]
    fn accumulates_same_coordinate() {
        let mut c = CoalesceBuffer::new(2, CoalescePolicy::default());
        c.add(1, 7, 0.25);
        c.add(1, 7, 0.25);
        c.add(1, 3, -0.1);
        let (coords, mass, total) = c.take(1);
        assert_eq!(sorted(zip(coords, mass)), vec![(3, -0.1), (7, 0.5)]);
        assert!((total - 0.6).abs() < 1e-12);
        assert!(c.is_empty());
    }

    #[test]
    fn interned_slots_match_keyed_path() {
        let mut c = CoalesceBuffer::new(2, CoalescePolicy::default());
        let s7 = c.intern(0, 7);
        let s9 = c.intern(0, 9);
        assert_ne!(s7, s9);
        assert_eq!(c.intern(0, 7), s7, "interning is stable");
        c.add_slot(0, s7, 0.5);
        c.add(0, 7, 0.25); // keyed path lands in the same slot
        c.add_slot(0, s9, 1.0);
        let (coords, mass, total) = c.take(0);
        assert_eq!(sorted(zip(coords, mass)), vec![(7, 0.75), (9, 1.0)]);
        assert!((total - 1.75).abs() < 1e-12);
        // slots survive the flush
        c.add_slot(0, s7, 2.0);
        let (coords, mass, _) = c.take(0);
        assert_eq!(zip(coords, mass), vec![(7, 2.0)]);
    }

    #[test]
    fn flush_respects_min_mass() {
        let policy = CoalescePolicy {
            min_mass: 1.0,
            max_entries: 100,
        };
        let mut c = CoalesceBuffer::new(2, policy);
        c.add(0, 1, 0.4);
        let mut flushed = Vec::new();
        c.flush(false, |d, coords, _, _| flushed.push((d, coords.len())));
        assert!(flushed.is_empty());
        c.add(0, 2, 0.7);
        c.flush(false, |d, coords, _, _| flushed.push((d, coords.len())));
        assert_eq!(flushed, vec![(0, 2)]);
        assert!(c.is_empty());
    }

    #[test]
    fn flush_respects_max_entries() {
        let policy = CoalescePolicy {
            min_mass: 1e9,
            max_entries: 3,
        };
        let mut c = CoalesceBuffer::new(1, policy);
        c.add(0, 1, 1e-12);
        c.add(0, 2, 1e-12);
        let mut n = 0;
        c.flush(false, |_, _, _, _| n += 1);
        assert_eq!(n, 0);
        c.add(0, 3, 1e-12);
        c.flush(false, |_, _, _, _| n += 1);
        assert_eq!(n, 1);
    }

    #[test]
    fn flush_all_takes_everything() {
        let mut c = CoalesceBuffer::new(3, CoalescePolicy::default());
        c.add(0, 1, 0.1);
        c.add(2, 5, 0.2);
        let mut dests = Vec::new();
        c.flush(true, |d, _, _, _| dests.push(d));
        assert_eq!(dests, vec![0, 2]);
        assert!(c.is_empty());
        assert_eq!(c.held_mass(), 0.0);
    }

    #[test]
    fn exact_cancellation_is_dropped_from_parcels() {
        let mut c = CoalesceBuffer::new(1, CoalescePolicy::default());
        c.add(0, 4, 0.5);
        c.add(0, 4, -0.5);
        c.add(0, 6, 0.25);
        // held mass is an upper bound: still counts the cancelled adds
        assert!((c.held_mass() - 1.25).abs() < 1e-12);
        let (coords, mass, total) = c.take(0);
        assert_eq!(zip(coords, mass), vec![(6, 0.25)]);
        assert!((total - 0.25).abs() < 1e-12);
    }

    #[test]
    fn clear_discards_but_keeps_slots_valid() {
        let mut c = CoalesceBuffer::new(2, CoalescePolicy::default());
        let s = c.intern(1, 10);
        c.add_slot(1, s, 0.7);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.held_mass(), 0.0);
        c.add_slot(1, s, 0.3);
        let (coords, mass, _) = c.take(1);
        assert_eq!(zip(coords, mass), vec![(10, 0.3)]);
    }

    #[test]
    fn compact_drops_stale_slots_but_keeps_pending_fluid() {
        let mut c = CoalesceBuffer::new(2, CoalescePolicy::default());
        for j in 0..100 {
            c.add(1, j, 0.01);
        }
        let _ = c.take(1); // flushed: 100 slots now stale
        c.add(1, 7, 0.5); // pending fluid that must survive
        assert_eq!(c.interned(1), 100);
        c.compact();
        assert_eq!(c.interned(1), 1, "only the pending coordinate survives");
        assert!((c.held_mass() - 0.5).abs() < 1e-12);
        let (coords, mass, total) = c.take(1);
        assert_eq!(zip(coords, mass), vec![(7, 0.5)]);
        assert!((total - 0.5).abs() < 1e-12);
    }

    #[test]
    fn dest_table_grows_with_the_pid_set() {
        // sized for K=2 at construction; the PID set then grows to 4
        let mut c = CoalesceBuffer::new(2, CoalescePolicy::default());
        c.add(1, 3, 0.5);
        c.add(3, 8, 0.25); // destination beyond the constructed K
        assert_eq!(c.dests(), 4);
        let s = c.intern(2, 5);
        c.add_slot(2, s, 0.125);
        assert!((c.held_mass() - 0.875).abs() < 1e-12);
        // flush after the K change must deliver every destination
        let mut flushed = Vec::new();
        c.flush(true, |d, coords, mass, total| {
            flushed.push((d, zip(coords, mass), total));
        });
        flushed.sort_by(|a, b| a.0.cmp(&b.0));
        assert_eq!(flushed.len(), 3);
        assert_eq!(flushed[0].0, 1);
        assert_eq!(flushed[0].1, vec![(3, 0.5)]);
        assert_eq!(flushed[1].0, 2);
        assert_eq!(flushed[1].1, vec![(5, 0.125)]);
        assert_eq!(flushed[2].0, 3);
        assert_eq!(flushed[2].1, vec![(8, 0.25)]);
        assert!(c.is_empty());
        // compact preserves the widened table
        c.add(3, 9, 0.1);
        c.compact();
        assert_eq!(c.dests(), 4);
        assert!((c.held_mass() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn recycled_storage_backs_the_next_parcel() {
        let mut c = CoalesceBuffer::new(1, CoalescePolicy::default());
        for j in 0..64 {
            c.add(0, j, 0.01);
        }
        let (coords, mass, _) = c.take(0);
        let cap = coords.capacity();
        assert!(cap >= 64);
        c.recycle(coords, mass);
        c.add(0, 3, 0.5);
        let (coords, mass, total) = c.take(0);
        assert!(
            coords.capacity() >= cap,
            "the next parcel must build over the recycled storage"
        );
        assert_eq!(zip(coords, mass), vec![(3, 0.5)]);
        assert!((total - 0.5).abs() < 1e-12);
    }

    #[test]
    fn held_mass_tracks_additions() {
        let mut c = CoalesceBuffer::new(1, CoalescePolicy::default());
        c.add(0, 0, 0.5);
        c.add(0, 1, -0.25);
        assert!((c.held_mass() - 0.75).abs() < 1e-12);
    }
}
