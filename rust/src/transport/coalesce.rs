//! Fluid coalescing (§3.3): "the fluid transmission can be delayed and
//! regrouped — we can regroup (f₁+f₂+…+f_m)·p_{ji} so that this quantity
//! is not too small; we don't need to know who sent the fluid."
//!
//! A [`CoalesceBuffer`] is a set of **per-destination dense scratch
//! accumulators**. Each destination interns its target coordinates into
//! stable slots (`intern`), so the worker hot loop accumulates with a
//! single indexed add (`add_slot`) — no hashing, no per-emission
//! allocation. A `touched` journal tracks which cells carry fluid since
//! the last flush, so flushing is O(touched), not O(boundary), and
//! produces flat **SoA parcels** `(coords: Vec<u32>, mass: Vec<f64>)` —
//! the wire format of [`crate::coordinator::WorkerMsg::Fluid`]. The
//! accumulator arrays themselves persist across flushes, and parcels are
//! backed by a [`crate::perf::Arena`]: a parcel must be *owned* (it
//! crosses a thread boundary), but storage that comes back — a failed
//! send re-routed by the worker ([`CoalesceBuffer::recycle`]), the
//! internal clear/compact paths — is pooled and reused by the next flush
//! instead of reallocated.
//!
//! **Query lanes (DESIGN.md §10).** When the engine serves a block of
//! right-hand sides, every interned slot fans out into `lanes`
//! accumulator cells, flat-indexed `slot*lanes + lane`. The hot entry
//! point becomes [`CoalesceBuffer::add_slot_lane`]; lane 0 is the base
//! problem and the single-lane wrappers (`add_slot`, `add`) reduce to
//! the exact pre-lane layout when `lanes == 1`. Parcels gain a third
//! column, `qlanes` (the lane index per entry), which is left **empty
//! when every entry is lane 0** so the single-query wire fast path is
//! byte-identical to the lane-free format. A per-destination
//! `lane_mass` ledger (Σ|adds| per lane since the last flush, errs
//! high, reset on every drain — the same discipline as the aggregate
//! `mass`) feeds the per-query undelivered accounting.
//!
//! The general keyed path (`add`) remains for cold routes — fluid
//! re-forwarded after an ownership change, fostered coordinates — and
//! interns on first sight.
//!
//! Over the wire transport this buffer is one stage of a **closed
//! storage cycle** (DESIGN.md §8.8): parcels decoded out of the receive
//! ring borrow pooled columns, the worker applies them and hands the
//! columns back here ([`CoalesceBuffer::recycle`]), the next flush
//! builds outbound parcels over that same storage, and the wire send
//! path reclaims it again once the parcel is encoded into a frame. The
//! policy-triggered `flush(all=true)` calls are also where the worker
//! invokes [`crate::transport::Transport::flush`], so a threshold
//! crossing or drain pushes the batched frames out immediately instead
//! of waiting for the wire's [`crate::transport::FlushPolicy`] deadline.

use std::collections::HashMap;

use crate::perf::Arena;

/// When to flush a destination's accumulated fluid.
#[derive(Clone, Copy, Debug)]
pub struct CoalescePolicy {
    /// flush when a destination buffer holds at least this much |fluid|
    pub min_mass: f64,
    /// flush when a destination buffer has this many touched cells
    pub max_entries: usize,
}

impl Default for CoalescePolicy {
    fn default() -> Self {
        Self {
            min_mass: 1e-9,
            max_entries: 4096,
        }
    }
}

/// One destination's dense scratch accumulator. Cells are flat-indexed
/// `slot*lanes + lane`; with `lanes == 1` this is the classic one cell
/// per coordinate layout.
#[derive(Debug)]
struct DestAcc {
    /// coordinate → slot (interning map; persists across flushes)
    slot_of: HashMap<usize, u32>,
    /// slot → global coordinate
    coords: Vec<u32>,
    /// flat cell → accumulated fluid since the last flush
    acc: Vec<f64>,
    is_touched: Vec<bool>,
    /// flat cells touched since the last flush (the flush work list)
    touched: Vec<u32>,
    /// Σ|fluid| added since the last flush (upper bound — opposite-sign
    /// merges only shrink the true mass)
    mass: f64,
    /// per-lane Σ|fluid| added since the last flush (same errs-high
    /// discipline as `mass`; reset on every drain)
    lane_mass: Vec<f64>,
}

impl DestAcc {
    fn new(lanes: usize) -> Self {
        Self {
            slot_of: HashMap::new(),
            coords: Vec::new(),
            acc: Vec::new(),
            is_touched: Vec::new(),
            touched: Vec::new(),
            mass: 0.0,
            lane_mass: vec![0.0; lanes],
        }
    }

    fn intern(&mut self, lanes: usize, coord: usize) -> u32 {
        if let Some(&s) = self.slot_of.get(&coord) {
            return s;
        }
        let s = self.coords.len() as u32;
        self.slot_of.insert(coord, s);
        self.coords.push(coord as u32);
        self.acc.resize(self.acc.len() + lanes, 0.0);
        self.is_touched.resize(self.is_touched.len() + lanes, false);
        s
    }

    #[inline]
    fn add_flat(&mut self, flat: u32, lane: u32, fluid: f64) {
        let s = flat as usize;
        self.acc[s] += fluid;
        let a = fluid.abs();
        self.mass += a;
        self.lane_mass[lane as usize] += a;
        if !self.is_touched[s] {
            self.is_touched[s] = true;
            self.touched.push(flat);
        }
    }

    /// Drain touched cells into an SoA parcel built over the supplied
    /// (cleared, possibly recycled) buffers; zero entries (exact
    /// cancellation) are dropped. `qlanes` comes back **empty** when
    /// every surviving entry is lane 0 (the single-query wire fast
    /// path). Returns (coords, qlanes, mass, Σ|mass|).
    fn take_into(
        &mut self,
        lanes: usize,
        mut coords: Vec<u32>,
        mut qlanes: Vec<u32>,
        mut mass: Vec<f64>,
    ) -> (Vec<u32>, Vec<u32>, Vec<f64>, f64) {
        debug_assert!(coords.is_empty() && qlanes.is_empty() && mass.is_empty());
        // no-ops on a recycled buffer that has warmed past touched.len()
        coords.reserve(self.touched.len());
        mass.reserve(self.touched.len());
        let mut total = 0.0;
        let mut any_lane = false;
        for &flat in &self.touched {
            let si = flat as usize;
            self.is_touched[si] = false;
            let v = self.acc[si];
            self.acc[si] = 0.0;
            if v != 0.0 {
                coords.push(self.coords[si / lanes]);
                mass.push(v);
                total += v.abs();
                if lanes > 1 {
                    let lane = flat % lanes as u32;
                    qlanes.push(lane);
                    any_lane |= lane != 0;
                }
            }
        }
        if !any_lane {
            qlanes.clear();
        }
        self.touched.clear();
        self.mass = 0.0;
        for m in &mut self.lane_mass {
            *m = 0.0;
        }
        (coords, qlanes, mass, total)
    }
}

/// Per-destination coalescing accumulators (one [`DestAcc`] per PID).
///
/// The destination table **grows on demand**: an elastic worker pool
/// spawns PIDs at runtime, so a destination index seen for the first time
/// (a routing decision against a newer ownership map than the buffer was
/// sized for) simply extends the table. Vacated (retired) destinations
/// keep their empty accumulator — the slot is reused if the PID respawns.
#[derive(Debug)]
pub struct CoalesceBuffer {
    policy: CoalescePolicy,
    /// lane count every destination accumulator fans out to (≥ 1)
    lanes: usize,
    accs: Vec<DestAcc>,
    /// recycled parcel storage (coords / qlanes / mass columns); filled
    /// by [`CoalesceBuffer::recycle`] and the internal clear/compact
    /// paths, drained by every parcel build. `qlanes` shares the u32
    /// pool with `coords`.
    coords_arena: Arena<u32>,
    mass_arena: Arena<f64>,
}

/// Parcel buffers pooled per column kind. Successful sends never return
/// their storage (it crosses a thread), so the pool only ever holds the
/// cold-path returns — a handful suffices.
const PARCEL_POOL: usize = 8;

impl CoalesceBuffer {
    /// A single-lane buffer addressing `k` destinations under `policy`
    /// (the table grows on demand when the PID pool widens).
    pub fn new(k: usize, policy: CoalescePolicy) -> Self {
        Self::with_lanes(k, 1, policy)
    }

    /// A buffer whose accumulators fan out to `lanes` query lanes per
    /// coordinate. `lanes == 1` is exactly [`CoalesceBuffer::new`].
    pub fn with_lanes(k: usize, lanes: usize, policy: CoalescePolicy) -> Self {
        assert!(lanes >= 1, "a coalesce buffer needs at least one lane");
        Self {
            policy,
            lanes,
            accs: (0..k).map(|_| DestAcc::new(lanes)).collect(),
            coords_arena: Arena::new(PARCEL_POOL),
            mass_arena: Arena::new(PARCEL_POOL),
        }
    }

    /// Lane count this buffer fans out to.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Return a parcel's backing storage (e.g. from a failed send whose
    /// fluid was re-routed): the next flush builds over it instead of
    /// allocating. Parcels that ship successfully cross a thread boundary
    /// and never come back — the arena is a bounded cache, not an
    /// accounting system. An empty `qlanes` (the all-lane-0 parcel
    /// shape) is still worth giving back: its capacity seeds the pool.
    pub fn recycle(&mut self, coords: Vec<u32>, qlanes: Vec<u32>, mass: Vec<f64>) {
        self.coords_arena.give(coords);
        self.coords_arena.give(qlanes);
        self.mass_arena.give(mass);
    }

    /// Extend the destination table to cover `dest` (elastic PID pools
    /// grow K while workers hold buffers sized to an older map).
    #[inline]
    fn ensure(&mut self, dest: usize) {
        if dest >= self.accs.len() {
            let lanes = self.lanes;
            self.accs.resize_with(dest + 1, || DestAcc::new(lanes));
        }
    }

    /// Destinations currently addressable (diagnostics/tests).
    pub fn dests(&self) -> usize {
        self.accs.len()
    }

    /// Assign (or look up) the accumulator slot for coordinate `j` at
    /// `dest` — called at [`crate::sparse::LocalSystem`] build time so the
    /// hot loop can use [`CoalesceBuffer::add_slot`] /
    /// [`CoalesceBuffer::add_slot_lane`].
    pub fn intern(&mut self, dest: usize, j: usize) -> u32 {
        self.ensure(dest);
        self.accs[dest].intern(self.lanes, j)
    }

    /// Hot path: accumulate `fluid` into lane 0 of a pre-interned slot
    /// (slots only come from [`CoalesceBuffer::intern`], so the table
    /// already covers `dest`).
    #[inline]
    pub fn add_slot(&mut self, dest: usize, slot: u32, fluid: f64) {
        self.add_slot_lane(dest, slot, 0, fluid);
    }

    /// Hot path, lane-addressed: accumulate `fluid` into `lane` of a
    /// pre-interned slot.
    #[inline]
    pub fn add_slot_lane(&mut self, dest: usize, slot: u32, lane: u32, fluid: f64) {
        let flat = slot * self.lanes as u32 + lane;
        self.accs[dest].add_flat(flat, lane, fluid);
    }

    /// Cold path: accumulate `fluid` for coordinate `j` owned by `dest`
    /// into lane 0, interning the coordinate on first sight.
    pub fn add(&mut self, dest: usize, j: usize, fluid: f64) {
        self.add_lane(dest, j, 0, fluid);
    }

    /// Cold path, lane-addressed: accumulate `fluid` for coordinate `j`
    /// owned by `dest` into `lane`, interning on first sight.
    pub fn add_lane(&mut self, dest: usize, j: usize, lane: u32, fluid: f64) {
        self.ensure(dest);
        let slot = self.accs[dest].intern(self.lanes, j);
        let flat = slot * self.lanes as u32 + lane;
        self.accs[dest].add_flat(flat, lane, fluid);
    }

    /// Flush destinations into SoA parcels: every non-empty destination
    /// when `all`, otherwise only those the policy says are worth a
    /// message. The sink receives `(dest, coords, qlanes, mass, Σ|mass|)`
    /// where `qlanes` is the per-entry lane column — **empty when every
    /// entry is lane 0** (see [`DestAcc::take_into`]).
    pub fn flush(
        &mut self,
        all: bool,
        mut sink: impl FnMut(usize, Vec<u32>, Vec<u32>, Vec<f64>, f64),
    ) {
        for d in 0..self.accs.len() {
            let a = &mut self.accs[d];
            if a.touched.is_empty() {
                continue;
            }
            if !all && a.mass < self.policy.min_mass && a.touched.len() < self.policy.max_entries
            {
                continue;
            }
            let (coords, qlanes, mass, total) = a.take_into(
                self.lanes,
                self.coords_arena.take(),
                self.coords_arena.take(),
                self.mass_arena.take(),
            );
            if coords.is_empty() {
                // every touched cell cancelled exactly: no message, and
                // the storage goes straight back to the pool
                self.coords_arena.give(coords);
                self.coords_arena.give(qlanes);
                self.mass_arena.give(mass);
            } else {
                sink(d, coords, qlanes, mass, total);
            }
        }
    }

    /// Take one destination's parcel unconditionally, discarding lane
    /// information (single-lane tests/benches; `lanes == 1` callers).
    pub fn take(&mut self, dest: usize) -> (Vec<u32>, Vec<f64>, f64) {
        let (coords, qlanes, mass, total) = self.take_lanes(dest);
        self.coords_arena.give(qlanes);
        (coords, mass, total)
    }

    /// Take one destination's parcel unconditionally with its lane
    /// column (tests).
    pub fn take_lanes(&mut self, dest: usize) -> (Vec<u32>, Vec<u32>, Vec<f64>, f64) {
        self.accs[dest].take_into(
            self.lanes,
            self.coords_arena.take(),
            self.coords_arena.take(),
            self.mass_arena.take(),
        )
    }

    /// Discard everything buffered (epoch transitions: buffered outbound
    /// fluid of the old epoch is obsolete by construction). Interned slots
    /// survive — they stay valid for the patched [`crate::sparse::LocalSystem`].
    pub fn clear(&mut self) {
        for a in &mut self.accs {
            let (coords, qlanes, mass, _) = a.take_into(
                self.lanes,
                self.coords_arena.take(),
                self.coords_arena.take(),
                self.mass_arena.take(),
            );
            self.coords_arena.give(coords);
            self.coords_arena.give(qlanes);
            self.mass_arena.give(mass);
        }
    }

    /// Discard one lane's pending fluid everywhere (query eviction: the
    /// lane's buffered outbound mass belongs to a query that no longer
    /// exists). The aggregate `mass` ledger sheds the *actual* |acc| of
    /// the zeroed cells — it stays an upper bound. Touched journal
    /// entries stay in place; the zeroed cells drop out of the next
    /// parcel as exact cancellations.
    pub fn clear_lane(&mut self, lane: u32) {
        let lanes = self.lanes as u32;
        for a in &mut self.accs {
            let mut shed = 0.0;
            for &flat in &a.touched {
                if flat % lanes == lane {
                    let si = flat as usize;
                    shed += a.acc[si].abs();
                    a.acc[si] = 0.0;
                }
            }
            a.mass = (a.mass - shed).max(0.0);
            a.lane_mass[lane as usize] = 0.0;
        }
    }

    /// Drop every interned slot, preserving pending fluid by re-interning
    /// it fresh. Without this the interner accretes one slot per
    /// coordinate ever routed to a destination (ownership churn +
    /// forwarded fluid trend it toward O(n) per dest over a long run).
    /// Callers must re-intern any slots they cached — the worker core
    /// compacts only immediately before a full `LocalSystem` rebuild,
    /// which re-interns the whole remnant anyway.
    pub fn compact(&mut self) {
        let lanes = self.lanes;
        for a in &mut self.accs {
            let (coords, qlanes, mass, _) = a.take_into(
                lanes,
                self.coords_arena.take(),
                self.coords_arena.take(),
                self.mass_arena.take(),
            );
            *a = DestAcc::new(lanes);
            for (u, &c) in coords.iter().enumerate() {
                let lane = if qlanes.is_empty() { 0 } else { qlanes[u] };
                let s = a.intern(lanes, c as usize);
                a.add_flat(s * lanes as u32 + lane, lane, mass[u]);
            }
            self.coords_arena.give(coords);
            self.coords_arena.give(qlanes);
            self.mass_arena.give(mass);
        }
    }

    /// Interned slot count for a destination (diagnostics/tests).
    pub fn interned(&self, dest: usize) -> usize {
        self.accs[dest].coords.len()
    }

    /// Total |fluid| currently held back (upper bound) — counted by the
    /// convergence monitor as "not yet transmitted" local fluid.
    pub fn held_mass(&self) -> f64 {
        self.accs.iter().map(|a| a.mass).sum()
    }

    /// Per-lane |fluid| currently held back (upper bound), accumulated
    /// across destinations into `out` (resized/zeroed to `lanes`). Feeds
    /// the per-query undelivered accounting in the worker publish pass.
    pub fn held_by_lane(&self, out: &mut Vec<f64>) {
        out.clear();
        out.resize(self.lanes, 0.0);
        for a in &self.accs {
            for (l, &m) in a.lane_mass.iter().enumerate() {
                out[l] += m;
            }
        }
    }

    /// Whether no destination holds any unflushed fluid.
    pub fn is_empty(&self) -> bool {
        self.accs.iter().all(|a| a.touched.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sorted(mut batch: Vec<(u32, f64)>) -> Vec<(u32, f64)> {
        batch.sort_unstable_by_key(|&(j, _)| j);
        batch
    }

    fn zip(coords: Vec<u32>, mass: Vec<f64>) -> Vec<(u32, f64)> {
        coords.into_iter().zip(mass).collect()
    }

    #[test]
    fn accumulates_same_coordinate() {
        let mut c = CoalesceBuffer::new(2, CoalescePolicy::default());
        c.add(1, 7, 0.25);
        c.add(1, 7, 0.25);
        c.add(1, 3, -0.1);
        let (coords, mass, total) = c.take(1);
        assert_eq!(sorted(zip(coords, mass)), vec![(3, -0.1), (7, 0.5)]);
        assert!((total - 0.6).abs() < 1e-12);
        assert!(c.is_empty());
    }

    #[test]
    fn interned_slots_match_keyed_path() {
        let mut c = CoalesceBuffer::new(2, CoalescePolicy::default());
        let s7 = c.intern(0, 7);
        let s9 = c.intern(0, 9);
        assert_ne!(s7, s9);
        assert_eq!(c.intern(0, 7), s7, "interning is stable");
        c.add_slot(0, s7, 0.5);
        c.add(0, 7, 0.25); // keyed path lands in the same slot
        c.add_slot(0, s9, 1.0);
        let (coords, mass, total) = c.take(0);
        assert_eq!(sorted(zip(coords, mass)), vec![(7, 0.75), (9, 1.0)]);
        assert!((total - 1.75).abs() < 1e-12);
        // slots survive the flush
        c.add_slot(0, s7, 2.0);
        let (coords, mass, _) = c.take(0);
        assert_eq!(zip(coords, mass), vec![(7, 2.0)]);
    }

    #[test]
    fn flush_respects_min_mass() {
        let policy = CoalescePolicy {
            min_mass: 1.0,
            max_entries: 100,
        };
        let mut c = CoalesceBuffer::new(2, policy);
        c.add(0, 1, 0.4);
        let mut flushed = Vec::new();
        c.flush(false, |d, coords, _, _, _| flushed.push((d, coords.len())));
        assert!(flushed.is_empty());
        c.add(0, 2, 0.7);
        c.flush(false, |d, coords, _, _, _| flushed.push((d, coords.len())));
        assert_eq!(flushed, vec![(0, 2)]);
        assert!(c.is_empty());
    }

    #[test]
    fn flush_respects_max_entries() {
        let policy = CoalescePolicy {
            min_mass: 1e9,
            max_entries: 3,
        };
        let mut c = CoalesceBuffer::new(1, policy);
        c.add(0, 1, 1e-12);
        c.add(0, 2, 1e-12);
        let mut n = 0;
        c.flush(false, |_, _, _, _, _| n += 1);
        assert_eq!(n, 0);
        c.add(0, 3, 1e-12);
        c.flush(false, |_, _, _, _, _| n += 1);
        assert_eq!(n, 1);
    }

    #[test]
    fn flush_all_takes_everything() {
        let mut c = CoalesceBuffer::new(3, CoalescePolicy::default());
        c.add(0, 1, 0.1);
        c.add(2, 5, 0.2);
        let mut dests = Vec::new();
        c.flush(true, |d, _, _, _, _| dests.push(d));
        assert_eq!(dests, vec![0, 2]);
        assert!(c.is_empty());
        assert_eq!(c.held_mass(), 0.0);
    }

    #[test]
    fn exact_cancellation_is_dropped_from_parcels() {
        let mut c = CoalesceBuffer::new(1, CoalescePolicy::default());
        c.add(0, 4, 0.5);
        c.add(0, 4, -0.5);
        c.add(0, 6, 0.25);
        // held mass is an upper bound: still counts the cancelled adds
        assert!((c.held_mass() - 1.25).abs() < 1e-12);
        let (coords, mass, total) = c.take(0);
        assert_eq!(zip(coords, mass), vec![(6, 0.25)]);
        assert!((total - 0.25).abs() < 1e-12);
    }

    #[test]
    fn clear_discards_but_keeps_slots_valid() {
        let mut c = CoalesceBuffer::new(2, CoalescePolicy::default());
        let s = c.intern(1, 10);
        c.add_slot(1, s, 0.7);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.held_mass(), 0.0);
        c.add_slot(1, s, 0.3);
        let (coords, mass, _) = c.take(1);
        assert_eq!(zip(coords, mass), vec![(10, 0.3)]);
    }

    #[test]
    fn compact_drops_stale_slots_but_keeps_pending_fluid() {
        let mut c = CoalesceBuffer::new(2, CoalescePolicy::default());
        for j in 0..100 {
            c.add(1, j, 0.01);
        }
        let _ = c.take(1); // flushed: 100 slots now stale
        c.add(1, 7, 0.5); // pending fluid that must survive
        assert_eq!(c.interned(1), 100);
        c.compact();
        assert_eq!(c.interned(1), 1, "only the pending coordinate survives");
        assert!((c.held_mass() - 0.5).abs() < 1e-12);
        let (coords, mass, total) = c.take(1);
        assert_eq!(zip(coords, mass), vec![(7, 0.5)]);
        assert!((total - 0.5).abs() < 1e-12);
    }

    #[test]
    fn dest_table_grows_with_the_pid_set() {
        // sized for K=2 at construction; the PID set then grows to 4
        let mut c = CoalesceBuffer::new(2, CoalescePolicy::default());
        c.add(1, 3, 0.5);
        c.add(3, 8, 0.25); // destination beyond the constructed K
        assert_eq!(c.dests(), 4);
        let s = c.intern(2, 5);
        c.add_slot(2, s, 0.125);
        assert!((c.held_mass() - 0.875).abs() < 1e-12);
        // flush after the K change must deliver every destination
        let mut flushed = Vec::new();
        c.flush(true, |d, coords, _, mass, total| {
            flushed.push((d, zip(coords, mass), total));
        });
        flushed.sort_by(|a, b| a.0.cmp(&b.0));
        assert_eq!(flushed.len(), 3);
        assert_eq!(flushed[0].0, 1);
        assert_eq!(flushed[0].1, vec![(3, 0.5)]);
        assert_eq!(flushed[1].0, 2);
        assert_eq!(flushed[1].1, vec![(5, 0.125)]);
        assert_eq!(flushed[2].0, 3);
        assert_eq!(flushed[2].1, vec![(8, 0.25)]);
        assert!(c.is_empty());
        // compact preserves the widened table
        c.add(3, 9, 0.1);
        c.compact();
        assert_eq!(c.dests(), 4);
        assert!((c.held_mass() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn recycled_storage_backs_the_next_parcel() {
        let mut c = CoalesceBuffer::new(1, CoalescePolicy::default());
        for j in 0..64 {
            c.add(0, j, 0.01);
        }
        let (coords, mass, _) = c.take(0);
        let cap = coords.capacity();
        assert!(cap >= 64);
        c.recycle(coords, Vec::new(), mass);
        c.add(0, 3, 0.5);
        let (coords, mass, total) = c.take(0);
        assert!(
            coords.capacity() >= cap,
            "the next parcel must build over the recycled storage"
        );
        assert_eq!(zip(coords, mass), vec![(3, 0.5)]);
        assert!((total - 0.5).abs() < 1e-12);
    }

    #[test]
    fn held_mass_tracks_additions() {
        let mut c = CoalesceBuffer::new(1, CoalescePolicy::default());
        c.add(0, 0, 0.5);
        c.add(0, 1, -0.25);
        assert!((c.held_mass() - 0.75).abs() < 1e-12);
    }

    // ------------------------------------------------------------------
    // query lanes (DESIGN.md §10)
    // ------------------------------------------------------------------

    #[test]
    fn lanes_accumulate_independently_per_coordinate() {
        let mut c = CoalesceBuffer::with_lanes(1, 3, CoalescePolicy::default());
        assert_eq!(c.lanes(), 3);
        let s = c.intern(0, 7);
        c.add_slot_lane(0, s, 0, 0.5);
        c.add_slot_lane(0, s, 2, 0.25);
        c.add_slot_lane(0, s, 2, 0.25);
        c.add_lane(0, 9, 1, -0.125); // cold path, same flat layout
        let (coords, qlanes, mass, total) = c.take_lanes(0);
        assert!((total - 1.125).abs() < 1e-12);
        let mut rows: Vec<(u32, u32, f64)> = coords
            .iter()
            .zip(&qlanes)
            .zip(&mass)
            .map(|((&cd, &l), &m)| (cd, l, m))
            .collect();
        rows.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        assert_eq!(rows, vec![(7, 0, 0.5), (7, 2, 0.5), (9, 1, -0.125)]);
        assert!(c.is_empty());
    }

    #[test]
    fn qlanes_column_is_empty_when_everything_is_lane_zero() {
        let mut c = CoalesceBuffer::with_lanes(1, 4, CoalescePolicy::default());
        c.add_lane(0, 3, 0, 0.5);
        c.add_lane(0, 5, 0, 0.25);
        let (coords, qlanes, mass, _) = c.take_lanes(0);
        assert!(
            qlanes.is_empty(),
            "all-lane-0 parcels keep the lane-free wire shape"
        );
        assert_eq!(sorted(zip(coords, mass)), vec![(3, 0.5), (5, 0.25)]);
        // a lane-carrying parcel does populate the column, 1:1 with coords
        c.add_lane(0, 3, 0, 0.5);
        c.add_lane(0, 5, 3, 0.25);
        let (coords, qlanes, _, _) = c.take_lanes(0);
        assert_eq!(qlanes.len(), coords.len());
    }

    #[test]
    fn held_by_lane_tracks_per_lane_additions() {
        let mut c = CoalesceBuffer::with_lanes(2, 2, CoalescePolicy::default());
        c.add_lane(0, 1, 0, 0.5);
        c.add_lane(0, 1, 1, -0.25);
        c.add_lane(1, 4, 1, 0.125);
        let mut by_lane = Vec::new();
        c.held_by_lane(&mut by_lane);
        assert_eq!(by_lane.len(), 2);
        assert!((by_lane[0] - 0.5).abs() < 1e-12);
        assert!((by_lane[1] - 0.375).abs() < 1e-12);
        // drain resets the per-lane ledger like the aggregate one
        c.flush(true, |_, _, _, _, _| {});
        c.held_by_lane(&mut by_lane);
        assert_eq!(by_lane, vec![0.0, 0.0]);
    }

    #[test]
    fn clear_lane_drops_one_lane_and_keeps_the_rest() {
        let mut c = CoalesceBuffer::with_lanes(1, 2, CoalescePolicy::default());
        c.add_lane(0, 3, 0, 0.5);
        c.add_lane(0, 3, 1, 0.25);
        c.add_lane(0, 8, 1, 0.125);
        c.clear_lane(1);
        let mut by_lane = Vec::new();
        c.held_by_lane(&mut by_lane);
        assert_eq!(by_lane[1], 0.0);
        // aggregate mass shed the evicted lane's actual |acc|
        assert!((c.held_mass() - 0.5).abs() < 1e-12);
        let (coords, qlanes, mass, total) = c.take_lanes(0);
        assert!(qlanes.is_empty(), "only lane-0 fluid survives eviction");
        assert_eq!(zip(coords, mass), vec![(3, 0.5)]);
        assert!((total - 0.5).abs() < 1e-12);
    }

    #[test]
    fn compact_preserves_lane_assignment() {
        let mut c = CoalesceBuffer::with_lanes(1, 3, CoalescePolicy::default());
        for j in 0..50 {
            c.add_lane(0, j, 0, 0.01);
        }
        let _ = c.take_lanes(0); // 50 stale slots
        c.add_lane(0, 7, 2, 0.5);
        c.add_lane(0, 9, 0, 0.25);
        c.compact();
        assert_eq!(c.interned(0), 2);
        let mut by_lane = Vec::new();
        c.held_by_lane(&mut by_lane);
        assert!((by_lane[0] - 0.25).abs() < 1e-12);
        assert!((by_lane[2] - 0.5).abs() < 1e-12);
        let (coords, qlanes, mass, _) = c.take_lanes(0);
        let mut rows: Vec<(u32, u32, f64)> = coords
            .iter()
            .zip(&qlanes)
            .zip(&mass)
            .map(|((&cd, &l), &m)| (cd, l, m))
            .collect();
        rows.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        assert_eq!(rows, vec![(7, 2, 0.5), (9, 0, 0.25)]);
    }

    #[test]
    fn single_lane_buffer_matches_the_pre_lane_layout() {
        // lanes == 1 must behave exactly like the historical buffer:
        // flat index == slot, no qlanes column ever emitted
        let mut c = CoalesceBuffer::with_lanes(1, 1, CoalescePolicy::default());
        let s = c.intern(0, 11);
        c.add_slot(0, s, 0.5);
        c.add_slot_lane(0, s, 0, 0.25);
        let (coords, qlanes, mass, total) = c.take_lanes(0);
        assert!(qlanes.is_empty());
        assert_eq!(zip(coords, mass), vec![(11, 0.75)]);
        assert!((total - 0.75).abs() < 1e-12);
    }
}
