//! Transport substrate: the asynchronous message fabric between PIDs.
//!
//! The paper's schemes only need three properties from the network (§3.3):
//! *asynchrony* (no global synchronization), *no fluid loss* (parcels are
//! retained by the sender until acknowledged, "as TCP"), and the ability to
//! *regroup* small fluid parcels to bound overhead. This module provides
//! exactly that as an in-process bus between worker threads, plus optional
//! latency injection so experiments can explore delay sensitivity, and
//! global **in-flight fluid accounting** — the quantity the paper adds to
//! `Σ_k ‖F_k‖₁` to monitor convergence exactly.
//!
//! Substitution note (DESIGN.md §3): real deployments put PIDs on separate
//! machines over TCP; an in-process bus with explicit ack/retention and
//! delay injection reproduces the protocol-visible behaviour (reordering
//! across endpoints, delay, conservation) deterministically under a seed.
//!
//! ## Transports
//!
//! All of the above is expressed once, abstractly, by the [`Transport`] /
//! [`TransportHub`] trait pair, with two implementations behind it:
//!
//! * the in-process **bus** ([`Endpoint`] / [`BusHub`]) — mpsc channels
//!   between worker threads, the default and the deterministic test
//!   substrate;
//! * the **wire** ([`WireEndpoint`] / [`WireHub`], module [`wire`]) — a
//!   TCP backend speaking the length-prefixed frame protocol specified in
//!   DESIGN.md §8, usable both as a loopback harness inside one process
//!   and across real processes via `diter stream --listen/--connect`.
//!
//! Code above this module selects between them with [`TransportKind`]
//! (or the `DITER_TRANSPORT` environment variable) and builds the fabric
//! through [`fabric`]; everything downstream holds `Box<dyn Transport>`
//! and cannot tell the difference — which is precisely the property the
//! conservation test-suite exercises.
//!
//! ## Elastic endpoints
//!
//! The bus is **elastic**: endpoints can be added and removed while the
//! fabric is live (the worker-pool scheduler spawns and retires PIDs
//! mid-convergence). The channel directory lives behind a shared
//! [`BusHub`]; each send resolves its destination through the directory
//! under a read lock, so [`BusHub::remove_endpoint`] (a write) strictly
//! orders with in-progress sends — after removal returns, every
//! successfully-sent envelope is already in the removed endpoint's queue
//! (its owner drains them before exiting) and every later send fails fast
//! at the sender, which re-routes instead of losing fluid (see
//! [`Endpoint::try_send`]).

mod atomic_f64;
mod coalesce;
pub mod wire;

pub use atomic_f64::AtomicF64;
pub use coalesce::{CoalesceBuffer, CoalescePolicy};
pub use wire::{ColumnPools, WireCodec, WireEndpoint, WireHub};

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use crate::error::{DiterError, Result};
use crate::metrics::MetricSet;
use crate::prng::Xoshiro256pp;

/// Metric names registered by the bus.
pub const BUS_METRICS: &[&str] = &[
    "msgs_sent",
    "msgs_recv",
    "acks",
    "fluid_entries_sent",
    "bytes_sent",
    "inflight_peak_ppm", // peak in-flight fluid × 1e6 (fixed point)
];

/// Configuration for the bus.
#[derive(Clone, Debug, Default)]
pub struct BusConfig {
    /// simulated one-way latency range (None = deliver immediately)
    pub latency: Option<(Duration, Duration)>,
    /// seed for latency jitter
    pub seed: u64,
    /// when the wire transport flushes its per-connection send queue
    /// (the in-process bus delivers directly and ignores this)
    pub flush: FlushPolicy,
    /// wire transport: release a parcel from the in-flight account when
    /// the receiver's ACK arrives instead of locally at encode time.
    /// Slightly laggier accounting on the happy path, but it makes every
    /// unit of crash-stranded wire mass attributable to some live
    /// sender's retention list, which is what exact crash recovery needs
    /// (DESIGN.md §11). The in-process bus ignores this — its envelopes
    /// are reconciled by the dying endpoint's own drop glue.
    pub ack_release: bool,
}

/// When the wire transport pushes queued frames to the socket (DESIGN.md
/// §8.8). Outgoing frames accumulate per connection and are flushed with
/// one vectored `writev` as soon as **any** bound trips:
///
/// * `max_bytes` — queued payload reaches this many bytes;
/// * `max_frames` — this many frames are queued;
/// * `deadline` — the oldest queued frame has waited this long (checked
///   on every pump, so any endpoint activity bounds staleness).
///
/// The degenerate policy `max_frames = 1` (or `max_bytes = 1`) recovers
/// flush-per-send. The iteration tolerates arbitrary message delay and
/// reordering, so batching is purely a throughput/latency trade — it can
/// never affect convergence or conservation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FlushPolicy {
    /// flush once this many bytes are queued on a connection
    pub max_bytes: usize,
    /// flush once this many frames are queued on a connection
    pub max_frames: usize,
    /// flush a connection whose oldest queued frame is this stale
    pub deadline: Duration,
}

impl Default for FlushPolicy {
    fn default() -> Self {
        FlushPolicy {
            max_bytes: 64 * 1024,
            max_frames: 64,
            deadline: Duration::from_micros(1000),
        }
    }
}

/// An addressed envelope with fluid-mass accounting.
struct Envelope<T> {
    from: usize,
    seq: u64,
    /// |fluid| carried (for the global in-flight account)
    mass: f64,
    ready_at: Instant,
    payload: T,
}

/// Heap ordering by ready time (earliest first).
struct Ripening<T>(Envelope<T>);

impl<T> PartialEq for Ripening<T> {
    fn eq(&self, other: &Self) -> bool {
        self.0.ready_at == other.0.ready_at
    }
}
impl<T> Eq for Ripening<T> {}
impl<T> PartialOrd for Ripening<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Ripening<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.0.ready_at.cmp(&self.0.ready_at) // min-heap
    }
}

/// A received message. If obtained via [`Endpoint::try_recv_uncommitted`],
/// the receiver MUST call [`Endpoint::commit`] after *applying* the payload
/// — the fluid stays on the global in-flight account until then, so the
/// convergence monitor can never observe fluid that is nowhere.
#[derive(Debug)]
pub struct Received<T> {
    pub from: usize,
    pub seq: u64,
    /// |fluid| carried (still in-flight until committed)
    pub mass: f64,
    pub payload: T,
}

/// Shared bus state.
struct Shared {
    /// total |fluid| currently sent-but-not-applied — the monitor's
    /// "fluids being transmitted" term
    inflight: AtomicF64,
    /// retained (unacked) parcel count across all endpoints
    retained: AtomicU64,
    /// messages sent but not yet *committed* by their receiver — the
    /// monitor's quiescence condition (stop only when 0)
    undelivered: AtomicU64,
    metrics: Arc<MetricSet>,
}

/// The live channel directory: slot `k` holds PID k's inbound channels,
/// `None` for a retired (or never-spawned) endpoint.
struct Directory<T> {
    txs: Vec<Option<Sender<Envelope<T>>>>,
    /// ack channels: `ack_txs[k]` sends acked seqs back to endpoint k
    ack_txs: Vec<Option<Sender<u64>>>,
    /// per-slot registration generation, bumped on every `add_endpoint`:
    /// a dropped endpoint only clears its slot if no successor has
    /// re-registered there in the meantime (crash recovery respawns the
    /// slot before the dead thread's stack necessarily unwinds).
    gens: Vec<u64>,
}

/// A shared handle onto the bus fabric that can register and deregister
/// endpoints while workers are running — the transport face of the
/// elastic worker pool. Cloneable; all clones see the same directory.
pub struct BusHub<T> {
    dir: Arc<RwLock<Directory<T>>>,
    shared: Arc<Shared>,
    latency: Option<(Duration, Duration)>,
    seed: u64,
}

impl<T> Clone for BusHub<T> {
    fn clone(&self) -> Self {
        BusHub {
            dir: self.dir.clone(),
            shared: self.shared.clone(),
            latency: self.latency,
            seed: self.seed,
        }
    }
}

impl<T: Send> BusHub<T> {
    /// Register a new endpoint at slot `id`: either a vacant (retired)
    /// slot, or exactly one past the current end (the directory never has
    /// gaps of unknown width). Errors if the slot is occupied.
    pub fn add_endpoint(&self, id: usize) -> Result<Endpoint<T>> {
        let mut d = self.dir.write().unwrap_or_else(|e| e.into_inner());
        if id > d.txs.len() {
            return Err(DiterError::Transport(format!(
                "endpoint {id} would leave a gap (directory holds {})",
                d.txs.len()
            )));
        }
        if id < d.txs.len() && d.txs[id].is_some() {
            return Err(DiterError::Transport(format!("endpoint {id} already live")));
        }
        let (tx, rx) = channel::<Envelope<T>>();
        let (ack_tx, ack_rx) = channel::<u64>();
        if id == d.txs.len() {
            d.txs.push(Some(tx));
            d.ack_txs.push(Some(ack_tx));
            d.gens.push(0);
        } else {
            d.txs[id] = Some(tx);
            d.ack_txs[id] = Some(ack_tx);
            d.gens[id] += 1;
        }
        let gen = d.gens[id];
        Ok(Endpoint {
            id,
            gen,
            dir: self.dir.clone(),
            rx,
            ack_rx,
            retained: Vec::new(),
            delayed: BinaryHeap::new(),
            next_seq: 0,
            shared: self.shared.clone(),
            latency: self.latency,
            rng: Xoshiro256pp::seed_from_u64(self.seed ^ (id as u64).wrapping_mul(0x9E3779B9)),
        })
    }

    /// Deregister slot `id`: subsequent sends to it fail fast at the
    /// sender (which re-routes the fluid). The write lock orders this
    /// against in-progress sends — envelopes that made it into the queue
    /// before removal are still drained by the endpoint's owner.
    pub fn remove_endpoint(&self, id: usize) {
        let mut d = self.dir.write().unwrap_or_else(|e| e.into_inner());
        if id < d.txs.len() {
            d.txs[id] = None;
            d.ack_txs[id] = None;
        }
    }

    /// Directory width (live + vacant slots).
    pub fn capacity(&self) -> usize {
        self.dir.read().unwrap_or_else(|e| e.into_inner()).txs.len()
    }

    /// Whether slot `id` currently has a live endpoint.
    pub fn is_live(&self, id: usize) -> bool {
        let d = self.dir.read().unwrap_or_else(|e| e.into_inner());
        d.txs.get(id).is_some_and(Option::is_some)
    }

    /// A monitor handle onto the shared accounting.
    pub fn monitor(&self) -> BusMonitor {
        BusMonitor {
            shared: self.shared.clone(),
        }
    }

    /// The bus-wide metric set.
    pub fn metrics(&self) -> Arc<MetricSet> {
        self.shared.metrics.clone()
    }
}

/// One PID's endpoint: owned by exactly one worker thread.
pub struct Endpoint<T> {
    id: usize,
    /// registration generation of this endpoint's slot (see `Directory`)
    gen: u64,
    dir: Arc<RwLock<Directory<T>>>,
    rx: Receiver<Envelope<T>>,
    ack_rx: Receiver<u64>,
    /// parcels retained until acked (seq → mass); "as TCP"
    retained: Vec<(u64, f64)>,
    delayed: BinaryHeap<Ripening<T>>,
    next_seq: u64,
    shared: Arc<Shared>,
    latency: Option<(Duration, Duration)>,
    rng: Xoshiro256pp,
}

/// Build a fully-connected bus of `k` endpoints.
pub fn bus<T: Send>(k: usize, cfg: &BusConfig) -> (Vec<Endpoint<T>>, Arc<MetricSet>) {
    bus_with_metrics(k, cfg, &[])
}

/// Build a bus whose [`MetricSet`] also registers `extra` counter names —
/// layers above the transport (e.g. the coordinator's worker core) share
/// the bus metric set so one snapshot captures the whole run.
pub fn bus_with_metrics<T: Send>(
    k: usize,
    cfg: &BusConfig,
    extra: &[&'static str],
) -> (Vec<Endpoint<T>>, Arc<MetricSet>) {
    let (endpoints, _hub, metrics) = bus_elastic(k, cfg, extra);
    (endpoints, metrics)
}

/// [`bus_with_metrics`], returning the [`BusHub`] as well so endpoints
/// can be added and removed at runtime (the elastic worker pool).
pub fn bus_elastic<T: Send>(
    k: usize,
    cfg: &BusConfig,
    extra: &[&'static str],
) -> (Vec<Endpoint<T>>, BusHub<T>, Arc<MetricSet>) {
    let names: Vec<&'static str> = BUS_METRICS.iter().chain(extra).copied().collect();
    let metrics = Arc::new(MetricSet::new(&names));
    let shared = Arc::new(Shared {
        inflight: AtomicF64::new(0.0),
        retained: AtomicU64::new(0),
        undelivered: AtomicU64::new(0),
        metrics: metrics.clone(),
    });
    let hub = BusHub {
        dir: Arc::new(RwLock::new(Directory {
            txs: Vec::with_capacity(k),
            ack_txs: Vec::with_capacity(k),
            gens: Vec::with_capacity(k),
        })),
        shared,
        latency: cfg.latency,
        seed: cfg.seed,
    };
    let endpoints = (0..k)
        .map(|id| hub.add_endpoint(id).expect("fresh directory has no gaps"))
        .collect();
    (endpoints, hub, metrics)
}

impl<T: Send> Endpoint<T> {
    /// This endpoint's PID (its slot in the directory).
    pub fn id(&self) -> usize {
        self.id
    }

    /// Directory width (live + vacant slots).
    pub fn peers(&self) -> usize {
        self.dir.read().unwrap_or_else(|e| e.into_inner()).txs.len()
    }

    /// Send `payload` carrying `mass` units of |fluid| to `to`.
    /// The parcel is retained locally until the receiver acknowledges it.
    pub fn send(&mut self, to: usize, payload: T, mass: f64, approx_bytes: usize) -> Result<()> {
        self.try_send(to, payload, mass, approx_bytes)
            .map_err(|_| DiterError::Transport(format!("no endpoint {to}")))
    }

    /// Like [`Endpoint::send`], but hands the payload back when the
    /// destination endpoint is missing or closed, so the caller can
    /// re-route it — a retiring PID's fluid must never be dropped. On the
    /// error path the in-flight accounting is fully undone (the fluid
    /// never left the caller), which transiently errs high, never low.
    pub fn try_send(
        &mut self,
        to: usize,
        payload: T,
        mass: f64,
        approx_bytes: usize,
    ) -> std::result::Result<(), T> {
        self.collect_acks();
        let delay = match self.latency {
            None => Duration::ZERO,
            Some((lo, hi)) => {
                let span = hi.saturating_sub(lo);
                lo + Duration::from_nanos(
                    (self.rng.next_f64() * span.as_nanos() as f64) as u64,
                )
            }
        };
        let seq = self.next_seq;
        let env = Envelope {
            from: self.id,
            seq,
            mass,
            ready_at: Instant::now() + delay,
            payload,
        };
        // the directory read lock is held across the accounting AND the
        // channel push: endpoint removal (a write) therefore strictly
        // orders with this send — after remove_endpoint returns, either
        // this envelope is already queued at the (still-draining) peer, or
        // the lookup below fails and the caller re-routes
        let d = self.dir.read().unwrap_or_else(|e| e.into_inner());
        let Some(tx) = d.txs.get(to).and_then(Option::as_ref) else {
            drop(d);
            return Err(env.payload);
        };
        // in-flight accounting BEFORE the send so the monitor can never
        // observe fluid vanishing (conservation must err on the high side).
        // `undelivered` goes up FIRST: the monitor treats the float
        // `inflight` accumulator as authoritative only while undelivered>0
        // (repeated interleaved ±mass leaves O(ε) residue that would
        // otherwise never clear — see BusMonitor::inflight_or_zero).
        self.shared.undelivered.fetch_add(1, Ordering::AcqRel);
        let now_inflight = self.shared.inflight.add(mass);
        self.shared
            .metrics
            .max("inflight_peak_ppm", (now_inflight * 1e6) as u64);
        match tx.send(env) {
            Ok(()) => {
                drop(d);
                self.next_seq += 1;
                self.retained.push((seq, mass));
                self.shared.retained.fetch_add(1, Ordering::Relaxed);
                self.shared.metrics.incr("msgs_sent");
                self.shared.metrics.add("bytes_sent", approx_bytes as u64);
                Ok(())
            }
            Err(send_err) => {
                // receiver dropped (worker exiting): undo the accounting —
                // the fluid never left the caller
                drop(d);
                self.shared.inflight.add(-mass);
                self.shared.undelivered.fetch_sub(1, Ordering::AcqRel);
                Err(send_err.0.payload)
            }
        }
    }

    /// Broadcast to every live endpoint; `payload` must be cloneable.
    pub fn broadcast(&mut self, payload: &T, mass: f64, approx_bytes: usize) -> Result<()>
    where
        T: Clone,
    {
        for to in 0..self.peers() {
            if to != self.id {
                // vacant slots are skipped, closed peers are not an error
                let _ = self.try_send(to, payload.clone(), mass, approx_bytes);
            }
        }
        Ok(())
    }

    /// Send one payload to each endpoint in `dests` (cloned per peer) —
    /// the targeted middle ground between [`Endpoint::send`] and
    /// [`Endpoint::broadcast`], used by state-plane exchanges (e.g. the
    /// V1 halo slices) whose recipient set is computed, not "everyone".
    /// Self and closed/vacant destinations are skipped — the caller's
    /// protocol must tolerate an absent peer (a retiring PID owns no
    /// coordinates, so a state multicast loses nothing by skipping it).
    /// Returns how many sends were delivered.
    pub fn multicast(
        &mut self,
        dests: &[usize],
        payload: &T,
        mass: f64,
        approx_bytes: usize,
    ) -> usize
    where
        T: Clone,
    {
        let mut delivered = 0;
        for &to in dests {
            if to != self.id && self.try_send(to, payload.clone(), mass, approx_bytes).is_ok() {
                delivered += 1;
            }
        }
        delivered
    }

    /// Non-blocking receive of the next ripe message WITHOUT committing:
    /// the fluid stays on the in-flight account and the message stays on
    /// the undelivered count until [`Endpoint::commit`] is called. Use this
    /// when applying the payload takes time and the monitor must never see
    /// the fluid vanish in between.
    pub fn try_recv_uncommitted(&mut self) -> Option<Received<T>> {
        // drain the channel into the ripening heap
        while let Ok(env) = self.rx.try_recv() {
            self.delayed.push(Ripening(env));
        }
        let now = Instant::now();
        if let Some(top) = self.delayed.peek() {
            if top.0.ready_at <= now {
                let env = self.delayed.pop().unwrap().0;
                self.shared.metrics.incr("msgs_recv");
                return Some(Received {
                    from: env.from,
                    seq: env.seq,
                    mass: env.mass,
                    payload: env.payload,
                });
            }
        }
        None
    }

    /// Confirm that a received message's payload has been fully applied:
    /// releases its fluid from the in-flight account, marks it delivered,
    /// and acknowledges to the sender ("as TCP"). Acks to a sender that
    /// has since retired are dropped — its retention list died with it.
    pub fn commit(&mut self, from: usize, seq: u64, mass: f64) {
        self.shared.inflight.add(-mass);
        self.shared.undelivered.fetch_sub(1, Ordering::AcqRel);
        let d = self.dir.read().unwrap_or_else(|e| e.into_inner());
        if let Some(tx) = d.ack_txs.get(from).and_then(Option::as_ref) {
            let _ = tx.send(seq);
        }
        drop(d);
        self.shared.metrics.incr("acks");
    }

    /// Non-blocking receive with immediate commit (small payloads that are
    /// applied on the spot).
    pub fn try_recv(&mut self) -> Option<Received<T>> {
        let r = self.try_recv_uncommitted()?;
        self.commit(r.from, r.seq, r.mass);
        Some(r)
    }

    /// Drain everything ripe right now (immediate commit).
    pub fn drain(&mut self) -> Vec<Received<T>> {
        let mut out = Vec::new();
        while let Some(m) = self.try_recv() {
            out.push(m);
        }
        out
    }

    /// Drain everything ripe right now WITHOUT committing.
    pub fn drain_uncommitted(&mut self) -> Vec<Received<T>> {
        let mut out = Vec::new();
        while let Some(m) = self.try_recv_uncommitted() {
            out.push(m);
        }
        out
    }

    /// Process acknowledgments: drop retained parcels the peers confirmed.
    pub fn collect_acks(&mut self) {
        while let Ok(seq) = self.ack_rx.try_recv() {
            if let Some(pos) = self.retained.iter().position(|&(s, _)| s == seq) {
                self.retained.swap_remove(pos);
                self.shared.retained.fetch_sub(1, Ordering::Relaxed);
            }
        }
    }

    /// Parcels still awaiting acknowledgment.
    pub fn unacked(&self) -> usize {
        self.retained.len()
    }

    /// Envelopes received but not yet ripe (latency injection). A
    /// draining shutdown polls this to avoid stranding accounted mass in
    /// the ripening heap: the inbound channel is swept into the heap
    /// first, so a zero return means nothing queued is waiting out a
    /// delay at this instant.
    pub fn pending_delayed(&mut self) -> usize {
        while let Ok(env) = self.rx.try_recv() {
            self.delayed.push(Ripening(env));
        }
        self.delayed.len()
    }

    /// Global in-flight fluid (sent but not yet applied anywhere).
    pub fn global_inflight(&self) -> f64 {
        self.shared.inflight.get()
    }

    /// The bus-wide metric set (shared by all endpoints).
    pub fn metrics(&self) -> Arc<MetricSet> {
        self.shared.metrics.clone()
    }
}

/// Crash reconciliation: an endpoint that dies with envelopes still
/// queued (a worker thread panicking or killed mid-run) would otherwise
/// strand their mass on the in-flight account and their count on
/// `undelivered` forever — the monitor could never again prove
/// quiescence. Dropping the endpoint settles the books exactly:
///
/// 1. deregister the slot under the directory write lock (generation-
///    guarded — a recovery respawn may already occupy it), so every
///    later send fails fast at the sender and re-routes;
/// 2. drain the inbound queue and the ripening heap, and for each
///    envelope release its mass from `inflight`, mark it delivered, and
///    ack the sender (its retention entry dies here — the fluid itself
///    is gone and will be reconstructed from H, see DESIGN.md §11);
/// 3. forget this endpoint's own retained parcels (delivered copies are
///    either applied or reconciled by their receiver's own drop).
///
/// A normally-retiring worker drains before exiting, so this finds
/// empty queues and costs two lock acquisitions — the no-failure path
/// is unchanged.
impl<T> Drop for Endpoint<T> {
    fn drop(&mut self) {
        {
            let mut d = self.dir.write().unwrap_or_else(|e| e.into_inner());
            if d.gens.get(self.id) == Some(&self.gen) && self.id < d.txs.len() {
                d.txs[self.id] = None;
                d.ack_txs[self.id] = None;
            }
        }
        // after the write lock: no sender can still enqueue to this rx
        while let Ok(env) = self.rx.try_recv() {
            self.delayed.push(Ripening(env));
        }
        if !self.delayed.is_empty() {
            let d = self.dir.read().unwrap_or_else(|e| e.into_inner());
            while let Some(Ripening(env)) = self.delayed.pop() {
                // undelivered strictly pairs the send-side increment:
                // these envelopes were never committed anywhere else
                self.shared.inflight.add(-env.mass);
                self.shared.undelivered.fetch_sub(1, Ordering::AcqRel);
                if let Some(tx) = d.ack_txs.get(env.from).and_then(Option::as_ref) {
                    let _ = tx.send(env.seq);
                }
            }
        }
        let orphaned = self.retained.len() as u64;
        if orphaned > 0 {
            self.shared.retained.fetch_sub(orphaned, Ordering::Relaxed);
        }
    }
}

/// A read-only monitor handle onto the bus state (for the coordinator's
/// convergence monitor thread).
pub struct BusMonitor {
    shared: Arc<Shared>,
}

impl BusMonitor {
    /// Total |fluid| currently sent-but-not-applied (raw accumulator —
    /// see [`BusMonitor::inflight_or_zero`] for the monitor-safe read).
    pub fn inflight(&self) -> f64 {
        self.shared.inflight.get()
    }

    /// Parcels retained by senders awaiting acknowledgement.
    pub fn retained(&self) -> u64 {
        self.shared.retained.load(Ordering::Relaxed)
    }

    /// Messages sent but not yet committed by their receiver — must be 0
    /// before the monitor may declare convergence.
    pub fn undelivered(&self) -> u64 {
        self.shared.undelivered.load(Ordering::Acquire)
    }

    /// The in-flight fluid, logically zeroed when nothing is undelivered:
    /// the f64 accumulator keeps O(ε)·msgs of non-associativity residue
    /// after many interleaved ±mass updates, and `undelivered == 0`
    /// *proves* the true in-flight mass is exactly zero (sends bump the
    /// undelivered count before adding their mass).
    pub fn inflight_or_zero(&self) -> f64 {
        if self.undelivered() == 0 {
            0.0
        } else {
            self.inflight()
        }
    }
}

/// Obtain a monitor for the same bus as `endpoint`.
pub fn monitor_of<T>(endpoint: &Endpoint<T>) -> BusMonitor {
    BusMonitor {
        shared: endpoint.shared.clone(),
    }
}

/// The abstract worker-side face of the message fabric: everything a PID
/// worker needs from its transport, object-safe so the coordinator can
/// hold `Box<dyn Transport<WorkerMsg>>` and swap the substrate (in-process
/// bus vs TCP wire) without recompiling a single worker line.
///
/// The contract mirrors the paper's three transport requirements (§3.3):
///
/// * **asynchrony** — every method is non-blocking;
/// * **no fluid loss** — [`Transport::try_send`] books the parcel's mass
///   on the global in-flight account *before* it leaves, retains it until
///   acknowledged, and hands the payload back (accounting undone) when the
///   destination is gone, so the caller re-routes instead of dropping;
/// * **exact accounting** — a received parcel's mass stays in flight until
///   the receiver [`Transport::commit`]s it, so the convergence monitor
///   can never observe fluid that is nowhere.
///
/// A minimal send/receive/commit round-trip, written against the trait so
/// it runs identically over any implementation:
///
/// ```
/// use diter::transport::{bus, BusConfig, Transport};
///
/// let (mut eps, _metrics) = bus::<&'static str>(2, &BusConfig::default());
/// let mut b = eps.pop().unwrap();
/// let mut a = eps.pop().unwrap();
/// // view both ends purely through the trait
/// let a: &mut dyn Transport<&'static str> = &mut a;
/// let b: &mut dyn Transport<&'static str> = &mut b;
///
/// a.send(1, "parcel", 0.25, 6).unwrap();
/// let got = b.try_recv_uncommitted().expect("ripe immediately");
/// assert_eq!((got.from, got.payload), (0, "parcel"));
/// assert_eq!(b.global_inflight(), 0.25, "still in flight until committed");
/// b.commit(got.from, got.seq, got.mass);
/// assert_eq!(b.global_inflight(), 0.0);
/// a.collect_acks();
/// assert_eq!(a.unacked(), 0, "ack released the sender's retention");
/// ```
pub trait Transport<T: Clone>: Send {
    /// This endpoint's PID (its address on the fabric).
    fn id(&self) -> usize;

    /// Directory width (live + vacant slots).
    fn peers(&self) -> usize;

    /// Send `payload` carrying `mass` units of |fluid| to `to`, handing
    /// the payload back when the destination is missing or closed so the
    /// caller can re-route it — a retiring PID's fluid must never be
    /// dropped. On the error path the in-flight accounting is fully
    /// undone (the fluid never left the caller), which transiently errs
    /// high, never low. `approx_bytes` feeds the `bytes_sent` metric.
    fn try_send(
        &mut self,
        to: usize,
        payload: T,
        mass: f64,
        approx_bytes: usize,
    ) -> std::result::Result<(), T>;

    /// Non-blocking receive of the next ripe message WITHOUT committing:
    /// the fluid stays on the in-flight account until
    /// [`Transport::commit`] is called with the message's coordinates.
    fn try_recv_uncommitted(&mut self) -> Option<Received<T>>;

    /// Confirm that a received message's payload has been fully applied:
    /// releases its fluid from the in-flight account, marks it delivered,
    /// and acknowledges to the sender ("as TCP"). Acks to a sender that
    /// has since retired are dropped — its retention list died with it.
    fn commit(&mut self, from: usize, seq: u64, mass: f64);

    /// Process acknowledgments: drop retained parcels the peers confirmed.
    fn collect_acks(&mut self);

    /// Parcels still awaiting acknowledgment.
    fn unacked(&self) -> usize;

    /// Messages received but not yet ripe (latency injection) or not yet
    /// surfaced. A draining shutdown polls this until it reaches zero to
    /// avoid stranding accounted mass inside the transport.
    fn pending_delayed(&mut self) -> usize;

    /// Global in-flight fluid (sent but not yet applied anywhere this
    /// transport can see; a multi-process wire sees its own process).
    fn global_inflight(&self) -> f64;

    /// The fabric-wide metric set (shared by all endpoints).
    fn metrics(&self) -> Arc<MetricSet>;

    /// Push any queued outgoing frames to the network **now**, regardless
    /// of the flush policy — called at latency-sensitive moments (epoch
    /// edges, drains, shutdown) to bound staleness. The default is a
    /// no-op: transports that deliver eagerly (the bus) have nothing
    /// queued.
    fn flush(&mut self) {}

    /// Reconcile state addressed to a peer that crashed: sever any
    /// connections to `pid`, forget retained parcels destined for it and
    /// release their mass from the in-flight account — that fluid died
    /// with the peer and is reconstructed from H by recovery (DESIGN.md
    /// §11). Called by the pool at each surviving worker during the
    /// recovery barrier, after the survivor is paused (so no new sends
    /// race it) and before the dead slot is re-registered. The default is
    /// a no-op: the bus needs none — a dead bus endpoint settles its own
    /// books in its drop glue and acks the survivors' retention away.
    fn peer_reset(&mut self, _pid: usize) {}

    /// [`Transport::try_send`] that converts the returned payload into a
    /// transport error (for destinations that must exist).
    fn send(&mut self, to: usize, payload: T, mass: f64, approx_bytes: usize) -> Result<()> {
        self.try_send(to, payload, mass, approx_bytes)
            .map_err(|_| DiterError::Transport(format!("no endpoint {to}")))
    }

    /// Send a clone of `payload` to every live peer (vacant slots and
    /// closed peers are skipped without error).
    fn broadcast(&mut self, payload: &T, mass: f64, approx_bytes: usize) -> Result<()> {
        for to in 0..self.peers() {
            if to != self.id() {
                let _ = self.try_send(to, payload.clone(), mass, approx_bytes);
            }
        }
        Ok(())
    }

    /// Send one payload to each endpoint in `dests` (cloned per peer).
    /// Self and closed/vacant destinations are skipped — the caller's
    /// protocol must tolerate an absent peer. Returns how many sends
    /// were delivered.
    fn multicast(&mut self, dests: &[usize], payload: &T, mass: f64, approx_bytes: usize) -> usize {
        let mut delivered = 0;
        for &to in dests {
            if to != self.id() && self.try_send(to, payload.clone(), mass, approx_bytes).is_ok() {
                delivered += 1;
            }
        }
        delivered
    }

    /// Non-blocking receive with immediate commit (small payloads that
    /// are applied on the spot).
    fn try_recv(&mut self) -> Option<Received<T>> {
        let r = self.try_recv_uncommitted()?;
        self.commit(r.from, r.seq, r.mass);
        Some(r)
    }

    /// Drain everything ripe right now (immediate commit).
    fn drain(&mut self) -> Vec<Received<T>> {
        let mut out = Vec::new();
        while let Some(m) = self.try_recv() {
            out.push(m);
        }
        out
    }

    /// Drain everything ripe right now WITHOUT committing.
    fn drain_uncommitted(&mut self) -> Vec<Received<T>> {
        let mut out = Vec::new();
        while let Some(m) = self.try_recv_uncommitted() {
            out.push(m);
        }
        out
    }
}

/// The abstract fabric-management face: registering and deregistering
/// endpoints while workers run (the elastic pool's transport seam) plus
/// the shared conservation accounting. Counterpart of [`Transport`];
/// implemented by [`BusHub`] and [`WireHub`].
pub trait TransportHub<T: Clone>: Send {
    /// Register a new endpoint at slot `id`: either a vacant (retired)
    /// slot, or exactly one past the current end (the directory never
    /// has gaps of unknown width). Errors if the slot is occupied.
    fn add_endpoint(&self, id: usize) -> Result<Box<dyn Transport<T>>>;

    /// Deregister slot `id`: subsequent sends to it fail fast at the
    /// sender (which re-routes the fluid). Strictly ordered against
    /// in-progress sends — messages that were accepted before removal
    /// are still drained by the endpoint's owner.
    fn remove_endpoint(&self, id: usize);

    /// Directory width (live + vacant slots).
    fn capacity(&self) -> usize;

    /// Whether slot `id` currently has a live endpoint.
    fn is_live(&self, id: usize) -> bool;

    /// A monitor handle onto the shared conservation accounting.
    fn monitor(&self) -> BusMonitor;

    /// The fabric-wide metric set.
    fn metrics(&self) -> Arc<MetricSet>;
}

impl<T: Send + Clone + 'static> Transport<T> for Endpoint<T> {
    fn id(&self) -> usize {
        Endpoint::id(self)
    }
    fn peers(&self) -> usize {
        Endpoint::peers(self)
    }
    fn try_send(
        &mut self,
        to: usize,
        payload: T,
        mass: f64,
        approx_bytes: usize,
    ) -> std::result::Result<(), T> {
        Endpoint::try_send(self, to, payload, mass, approx_bytes)
    }
    fn try_recv_uncommitted(&mut self) -> Option<Received<T>> {
        Endpoint::try_recv_uncommitted(self)
    }
    fn commit(&mut self, from: usize, seq: u64, mass: f64) {
        Endpoint::commit(self, from, seq, mass)
    }
    fn collect_acks(&mut self) {
        Endpoint::collect_acks(self)
    }
    fn unacked(&self) -> usize {
        Endpoint::unacked(self)
    }
    fn pending_delayed(&mut self) -> usize {
        Endpoint::pending_delayed(self)
    }
    fn global_inflight(&self) -> f64 {
        Endpoint::global_inflight(self)
    }
    fn metrics(&self) -> Arc<MetricSet> {
        Endpoint::metrics(self)
    }
}

impl<T: Send + Clone + 'static> TransportHub<T> for BusHub<T> {
    fn add_endpoint(&self, id: usize) -> Result<Box<dyn Transport<T>>> {
        Ok(Box::new(BusHub::add_endpoint(self, id)?))
    }
    fn remove_endpoint(&self, id: usize) {
        BusHub::remove_endpoint(self, id)
    }
    fn capacity(&self) -> usize {
        BusHub::capacity(self)
    }
    fn is_live(&self, id: usize) -> bool {
        BusHub::is_live(self, id)
    }
    fn monitor(&self) -> BusMonitor {
        BusHub::monitor(self)
    }
    fn metrics(&self) -> Arc<MetricSet> {
        BusHub::metrics(self)
    }
}

/// Which [`Transport`] implementation carries the worker fabric.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// The in-process bus: mpsc channels between worker threads.
    #[default]
    Bus,
    /// The TCP wire (loopback sockets when built through [`fabric`]).
    Wire,
}

impl TransportKind {
    /// Parse `"bus" | "wire" | "tcp"` (the CLI/config surface).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "bus" => Some(TransportKind::Bus),
            "wire" | "tcp" => Some(TransportKind::Wire),
            _ => None,
        }
    }

    /// Stable lower-case name (inverse of [`TransportKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Bus => "bus",
            TransportKind::Wire => "wire",
        }
    }

    /// The default transport, overridable through the `DITER_TRANSPORT`
    /// environment variable — the seam that lets the whole conservation
    /// test-suite run unchanged over the wire (`DITER_TRANSPORT=wire`).
    ///
    /// # Panics
    ///
    /// On an unrecognised value: a CI job that *believes* it is testing
    /// the wire must never silently fall back to the bus.
    pub fn from_env() -> Self {
        match std::env::var("DITER_TRANSPORT") {
            Ok(v) => TransportKind::parse(v.trim()).unwrap_or_else(|| {
                panic!("DITER_TRANSPORT={v:?} is not a transport (expected bus | wire)")
            }),
            Err(_) => TransportKind::Bus,
        }
    }
}

/// What [`fabric`] builds: the endpoints (boxed, worker-owned), the hub
/// (for the elastic pool), and the shared [`MetricSet`].
pub type Fabric<T> = (
    Vec<Box<dyn Transport<T>>>,
    Box<dyn TransportHub<T>>,
    Arc<MetricSet>,
);

/// Build a `k`-endpoint worker fabric of the chosen [`TransportKind`],
/// registering `extra` metric names beyond the transport's own.
/// `T: WireCodec` even for the bus arm — the message type must be
/// wire-encodable for the fabric to be substitutable.
pub fn fabric<T: WireCodec + Send + Clone + 'static>(
    kind: TransportKind,
    k: usize,
    cfg: &BusConfig,
    extra: &[&'static str],
) -> Result<Fabric<T>> {
    match kind {
        TransportKind::Bus => {
            let (eps, hub, metrics) = bus_elastic::<T>(k, cfg, extra);
            let eps = eps
                .into_iter()
                .map(|e| Box::new(e) as Box<dyn Transport<T>>)
                .collect();
            Ok((eps, Box::new(hub), metrics))
        }
        TransportKind::Wire => {
            let hub = WireHub::<T>::loopback(cfg, extra);
            let metrics = WireHub::metrics(&hub);
            let eps = (0..k)
                .map(|id| {
                    WireHub::add_endpoint(&hub, id)
                        .map(|e| Box::new(e) as Box<dyn Transport<T>>)
                })
                .collect::<Result<Vec<_>>>()?;
            Ok((eps, Box::new(hub), metrics))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_to_point_delivery() {
        let (mut eps, metrics) = bus::<String>(2, &BusConfig::default());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, "hello".into(), 0.5, 5).unwrap();
        let got = b.try_recv().unwrap();
        assert_eq!(got.payload, "hello");
        assert_eq!(got.from, 0);
        assert_eq!(metrics.get("msgs_sent"), 1);
        assert_eq!(metrics.get("msgs_recv"), 1);
    }

    #[test]
    fn inflight_accounting_conserves() {
        let (mut eps, _m) = bus::<u32>(2, &BusConfig::default());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        assert_eq!(a.global_inflight(), 0.0);
        a.send(1, 7, 1.25, 4).unwrap();
        a.send(1, 8, 0.75, 4).unwrap();
        assert!((a.global_inflight() - 2.0).abs() < 1e-12);
        let _ = b.try_recv().unwrap();
        assert!((b.global_inflight() - 0.75).abs() < 1e-12);
        let _ = b.try_recv().unwrap();
        assert_eq!(b.global_inflight(), 0.0);
    }

    #[test]
    fn acks_release_retention() {
        let (mut eps, _m) = bus::<u32>(2, &BusConfig::default());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, 1, 0.1, 4).unwrap();
        a.send(1, 2, 0.1, 4).unwrap();
        assert_eq!(a.unacked(), 2);
        b.drain();
        a.collect_acks();
        assert_eq!(a.unacked(), 0);
    }

    #[test]
    fn multicast_reaches_exactly_the_dest_set() {
        let (mut eps, hub, _m) = bus_elastic::<u8>(4, &BusConfig::default(), &[]);
        let mut rest: Vec<_> = eps.drain(1..).collect();
        let mut a = eps.pop().unwrap();
        // dead peer 3 and self are skipped without error
        hub.remove_endpoint(3);
        let delivered = a.multicast(&[0, 1, 3], &7, 0.0, 1);
        assert_eq!(delivered, 1, "self and the dead peer are skipped");
        assert_eq!(rest[0].try_recv().unwrap().payload, 7); // endpoint 1
        assert!(rest[1].try_recv().is_none(), "endpoint 2 was not addressed");
        assert_eq!(a.global_inflight(), 0.0);
    }

    #[test]
    fn broadcast_reaches_all_peers() {
        let (mut eps, _m) = bus::<u8>(4, &BusConfig::default());
        let mut rest: Vec<_> = eps.drain(1..).collect();
        let mut a = eps.pop().unwrap();
        a.broadcast(&42, 0.0, 1).unwrap();
        for ep in rest.iter_mut() {
            let got = ep.try_recv().unwrap();
            assert_eq!(got.payload, 42);
        }
        assert!(a.try_recv().is_none(), "no self-delivery");
    }

    #[test]
    fn latency_delays_delivery() {
        let cfg = BusConfig {
            latency: Some((Duration::from_millis(30), Duration::from_millis(40))),
            seed: 1,
            ..BusConfig::default()
        };
        let (mut eps, _m) = bus::<u8>(2, &cfg);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, 9, 0.0, 1).unwrap();
        assert!(b.try_recv().is_none(), "must not arrive instantly");
        std::thread::sleep(Duration::from_millis(60));
        assert!(b.try_recv().is_some());
    }

    #[test]
    fn ordering_within_latency_heap() {
        // two messages with different delays must pop earliest-first
        let cfg = BusConfig {
            latency: Some((Duration::from_millis(1), Duration::from_millis(50))),
            seed: 3,
            ..BusConfig::default()
        };
        let (mut eps, _m) = bus::<u32>(2, &cfg);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        for i in 0..10 {
            a.send(1, i, 0.0, 4).unwrap();
        }
        std::thread::sleep(Duration::from_millis(80));
        let got = b.drain();
        assert_eq!(got.len(), 10);
    }

    #[test]
    fn extra_metric_names_registered() {
        let (eps, metrics) = bus_with_metrics::<u8>(2, &BusConfig::default(), &["handoffs_total"]);
        metrics.incr("handoffs_total");
        assert_eq!(metrics.get("handoffs_total"), 1);
        assert_eq!(eps[0].metrics().get("handoffs_total"), 1, "shared set");
    }

    #[test]
    fn send_to_missing_endpoint_fails() {
        let (mut eps, _m) = bus::<u8>(1, &BusConfig::default());
        let mut a = eps.pop().unwrap();
        assert!(a.send(3, 0, 0.0, 1).is_err());
    }

    #[test]
    fn hub_adds_endpoint_at_runtime() {
        let (mut eps, hub, metrics) = bus_elastic::<u8>(2, &BusConfig::default(), &[]);
        assert_eq!(hub.capacity(), 2);
        // fluid can be addressed to the new slot the moment it registers
        let mut c = hub.add_endpoint(2).unwrap();
        assert_eq!(hub.capacity(), 3);
        assert!(hub.is_live(2));
        eps[0].send(2, 7, 0.5, 1).unwrap();
        let got = c.try_recv().unwrap();
        assert_eq!(got.payload, 7);
        assert_eq!(got.from, 0);
        // and the late joiner can send back
        c.send(1, 9, 0.25, 1).unwrap();
        assert_eq!(eps[1].try_recv().unwrap().payload, 9);
        eps[0].collect_acks();
        assert_eq!(eps[0].unacked(), 0);
        assert_eq!(metrics.get("msgs_recv"), 2);
        // gaps are rejected, occupied slots are rejected
        assert!(hub.add_endpoint(5).is_err());
        assert!(hub.add_endpoint(1).is_err());
    }

    #[test]
    fn removed_endpoint_fails_fast_and_returns_payload() {
        let (mut eps, hub, _m) = bus_elastic::<u32>(2, &BusConfig::default(), &[]);
        hub.remove_endpoint(1);
        assert!(!hub.is_live(1));
        // try_send hands the payload back with accounting fully undone
        let a = &mut eps[0];
        assert_eq!(a.try_send(1, 42, 1.5, 4), Err(42));
        assert_eq!(a.global_inflight(), 0.0);
        assert_eq!(a.unacked(), 0);
        assert!(a.send(1, 42, 1.5, 4).is_err());
        let mon = monitor_of(a);
        assert_eq!(mon.undelivered(), 0);
    }

    #[test]
    fn retired_slot_can_be_reused() {
        let (mut eps, hub, _m) = bus_elastic::<u8>(3, &BusConfig::default(), &[]);
        let c = eps.pop().unwrap(); // endpoint 2
        drop(c);
        hub.remove_endpoint(2);
        let mut c2 = hub.add_endpoint(2).unwrap();
        assert_eq!(c2.id(), 2);
        eps[0].send(2, 5, 0.0, 1).unwrap();
        assert_eq!(c2.try_recv().unwrap().payload, 5);
        assert_eq!(hub.capacity(), 3, "slot reused, not appended");
    }

    #[test]
    fn closed_receiver_returns_payload_with_accounting_undone() {
        let (mut eps, _hub, _m) = bus_elastic::<u32>(2, &BusConfig::default(), &[]);
        let b = eps.pop().unwrap();
        drop(b); // receiver gone but slot still registered
        let mut a = eps.pop().unwrap();
        assert_eq!(a.try_send(1, 11, 0.75, 4), Err(11));
        assert_eq!(a.global_inflight(), 0.0);
        let mon = monitor_of(&a);
        assert_eq!(mon.undelivered(), 0);
        assert_eq!(mon.retained(), 0);
    }

    #[test]
    fn pending_delayed_counts_ripening_envelopes() {
        let cfg = BusConfig {
            latency: Some((Duration::from_millis(25), Duration::from_millis(30))),
            seed: 5,
            ..BusConfig::default()
        };
        let (mut eps, _m) = bus::<u8>(2, &cfg);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, 1, 0.0, 1).unwrap();
        assert!(b.try_recv().is_none());
        assert_eq!(b.pending_delayed(), 1, "delayed envelope is visible");
        std::thread::sleep(Duration::from_millis(50));
        assert!(b.try_recv().is_some());
        assert_eq!(b.pending_delayed(), 0);
    }

    #[test]
    fn dropped_endpoint_settles_queued_mass_and_acks_senders() {
        let (mut eps, hub, _m) = bus_elastic::<u32>(2, &BusConfig::default(), &[]);
        let b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, 7, 0.5, 4).unwrap();
        a.send(1, 8, 0.25, 4).unwrap();
        let mon = monitor_of(&a);
        assert_eq!(mon.undelivered(), 2);
        assert!((mon.inflight() - 0.75).abs() < 1e-12);
        assert_eq!(a.unacked(), 2);
        // b "crashes" with both envelopes still queued
        drop(b);
        assert_eq!(mon.undelivered(), 0, "queued envelopes settled");
        assert_eq!(mon.inflight_or_zero(), 0.0, "their mass released");
        a.collect_acks();
        assert_eq!(a.unacked(), 0, "sender retention acked away");
        assert_eq!(mon.retained(), 0);
        assert!(!hub.is_live(1), "slot deregistered by the drop");
        // the slot is immediately respawnable
        let mut b2 = hub.add_endpoint(1).unwrap();
        a.send(1, 9, 0.125, 4).unwrap();
        assert_eq!(b2.try_recv().unwrap().payload, 9);
    }

    #[test]
    fn dropped_endpoint_spares_respawned_successor() {
        // generation guard: a slow-dying first registration must not
        // deregister the successor that recovery already installed
        let (mut eps, hub, _m) = bus_elastic::<u32>(2, &BusConfig::default(), &[]);
        let b1 = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        hub.remove_endpoint(1); // detection clears the slot first
        let mut b2 = hub.add_endpoint(1).unwrap(); // recovery respawns it
        drop(b1); // the dead worker's stack unwinds late
        assert!(hub.is_live(1), "successor registration survives");
        a.send(1, 3, 0.0, 4).unwrap();
        assert_eq!(b2.try_recv().unwrap().payload, 3);
    }

    #[test]
    fn cross_thread_usage() {
        let (mut eps, metrics) = bus::<u64>(2, &BusConfig::default());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let t = std::thread::spawn(move || {
            for i in 0..100u64 {
                a.send(1, i, 0.01, 8).unwrap();
            }
            a
        });
        let mut seen = 0;
        let deadline = Instant::now() + Duration::from_secs(5);
        while seen < 100 && Instant::now() < deadline {
            if b.try_recv().is_some() {
                seen += 1;
            }
        }
        let mut a = t.join().unwrap();
        a.collect_acks();
        assert_eq!(seen, 100);
        assert_eq!(a.unacked(), 0);
        assert_eq!(metrics.get("msgs_recv"), 100);
        assert!(b.global_inflight().abs() < 1e-12);
    }
}
