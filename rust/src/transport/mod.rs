//! Transport substrate: the asynchronous message fabric between PIDs.
//!
//! The paper's schemes only need three properties from the network (§3.3):
//! *asynchrony* (no global synchronization), *no fluid loss* (parcels are
//! retained by the sender until acknowledged, "as TCP"), and the ability to
//! *regroup* small fluid parcels to bound overhead. This module provides
//! exactly that as an in-process bus between worker threads, plus optional
//! latency injection so experiments can explore delay sensitivity, and
//! global **in-flight fluid accounting** — the quantity the paper adds to
//! `Σ_k ‖F_k‖₁` to monitor convergence exactly.
//!
//! Substitution note (DESIGN.md §3): real deployments put PIDs on separate
//! machines over TCP; an in-process bus with explicit ack/retention and
//! delay injection reproduces the protocol-visible behaviour (reordering
//! across endpoints, delay, conservation) deterministically under a seed.

mod atomic_f64;
mod coalesce;

pub use atomic_f64::AtomicF64;
pub use coalesce::{CoalesceBuffer, CoalescePolicy};

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{DiterError, Result};
use crate::metrics::MetricSet;
use crate::prng::Xoshiro256pp;

/// Metric names registered by the bus.
pub const BUS_METRICS: &[&str] = &[
    "msgs_sent",
    "msgs_recv",
    "acks",
    "fluid_entries_sent",
    "bytes_sent",
    "inflight_peak_ppm", // peak in-flight fluid × 1e6 (fixed point)
];

/// Configuration for the bus.
#[derive(Clone, Debug, Default)]
pub struct BusConfig {
    /// simulated one-way latency range (None = deliver immediately)
    pub latency: Option<(Duration, Duration)>,
    /// seed for latency jitter
    pub seed: u64,
}

/// An addressed envelope with fluid-mass accounting.
struct Envelope<T> {
    from: usize,
    seq: u64,
    /// |fluid| carried (for the global in-flight account)
    mass: f64,
    ready_at: Instant,
    payload: T,
}

/// Heap ordering by ready time (earliest first).
struct Ripening<T>(Envelope<T>);

impl<T> PartialEq for Ripening<T> {
    fn eq(&self, other: &Self) -> bool {
        self.0.ready_at == other.0.ready_at
    }
}
impl<T> Eq for Ripening<T> {}
impl<T> PartialOrd for Ripening<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Ripening<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.0.ready_at.cmp(&self.0.ready_at) // min-heap
    }
}

/// A received message. If obtained via [`Endpoint::try_recv_uncommitted`],
/// the receiver MUST call [`Endpoint::commit`] after *applying* the payload
/// — the fluid stays on the global in-flight account until then, so the
/// convergence monitor can never observe fluid that is nowhere.
#[derive(Debug)]
pub struct Received<T> {
    pub from: usize,
    pub seq: u64,
    /// |fluid| carried (still in-flight until committed)
    pub mass: f64,
    pub payload: T,
}

/// Shared bus state.
struct Shared {
    /// total |fluid| currently sent-but-not-applied — the monitor's
    /// "fluids being transmitted" term
    inflight: AtomicF64,
    /// retained (unacked) parcel count across all endpoints
    retained: AtomicU64,
    /// messages sent but not yet *committed* by their receiver — the
    /// monitor's quiescence condition (stop only when 0)
    undelivered: AtomicU64,
    metrics: Arc<MetricSet>,
}

/// One PID's endpoint: owned by exactly one worker thread.
pub struct Endpoint<T> {
    id: usize,
    txs: Vec<Sender<Envelope<T>>>,
    rx: Receiver<Envelope<T>>,
    /// ack channels: acks[k] sends (seq) back to endpoint k
    ack_txs: Vec<Sender<u64>>,
    ack_rx: Receiver<u64>,
    /// parcels retained until acked (seq → mass); "as TCP"
    retained: Vec<(u64, f64)>,
    delayed: BinaryHeap<Ripening<T>>,
    next_seq: u64,
    shared: Arc<Shared>,
    latency: Option<(Duration, Duration)>,
    rng: Xoshiro256pp,
}

/// Build a fully-connected bus of `k` endpoints.
pub fn bus<T: Send>(k: usize, cfg: &BusConfig) -> (Vec<Endpoint<T>>, Arc<MetricSet>) {
    bus_with_metrics(k, cfg, &[])
}

/// Build a bus whose [`MetricSet`] also registers `extra` counter names —
/// layers above the transport (e.g. the coordinator's worker core) share
/// the bus metric set so one snapshot captures the whole run.
pub fn bus_with_metrics<T: Send>(
    k: usize,
    cfg: &BusConfig,
    extra: &[&'static str],
) -> (Vec<Endpoint<T>>, Arc<MetricSet>) {
    let names: Vec<&'static str> = BUS_METRICS.iter().chain(extra).copied().collect();
    let metrics = Arc::new(MetricSet::new(&names));
    let shared = Arc::new(Shared {
        inflight: AtomicF64::new(0.0),
        retained: AtomicU64::new(0),
        undelivered: AtomicU64::new(0),
        metrics: metrics.clone(),
    });
    let mut txs = Vec::with_capacity(k);
    let mut rxs = Vec::with_capacity(k);
    for _ in 0..k {
        let (tx, rx) = channel::<Envelope<T>>();
        txs.push(tx);
        rxs.push(rx);
    }
    let mut ack_txs = Vec::with_capacity(k);
    let mut ack_rxs = Vec::with_capacity(k);
    for _ in 0..k {
        let (tx, rx) = channel::<u64>();
        ack_txs.push(tx);
        ack_rxs.push(rx);
    }
    let mut endpoints = Vec::with_capacity(k);
    for (id, (rx, ack_rx)) in rxs.into_iter().zip(ack_rxs).enumerate() {
        endpoints.push(Endpoint {
            id,
            txs: txs.clone(),
            rx,
            ack_txs: ack_txs.clone(),
            ack_rx,
            retained: Vec::new(),
            delayed: BinaryHeap::new(),
            next_seq: 0,
            shared: shared.clone(),
            latency: cfg.latency,
            rng: Xoshiro256pp::seed_from_u64(cfg.seed ^ (id as u64).wrapping_mul(0x9E3779B9)),
        });
    }
    (endpoints, metrics)
}

impl<T: Send> Endpoint<T> {
    pub fn id(&self) -> usize {
        self.id
    }

    pub fn peers(&self) -> usize {
        self.txs.len()
    }

    /// Send `payload` carrying `mass` units of |fluid| to `to`.
    /// The parcel is retained locally until the receiver acknowledges it.
    pub fn send(&mut self, to: usize, payload: T, mass: f64, approx_bytes: usize) -> Result<()> {
        if to >= self.txs.len() {
            return Err(DiterError::Transport(format!("no endpoint {to}")));
        }
        self.collect_acks();
        let seq = self.next_seq;
        self.next_seq += 1;
        let delay = match self.latency {
            None => Duration::ZERO,
            Some((lo, hi)) => {
                let span = hi.saturating_sub(lo);
                lo + Duration::from_nanos(
                    (self.rng.next_f64() * span.as_nanos() as f64) as u64,
                )
            }
        };
        let env = Envelope {
            from: self.id,
            seq,
            mass,
            ready_at: Instant::now() + delay,
            payload,
        };
        // in-flight accounting BEFORE the send so the monitor can never
        // observe fluid vanishing (conservation must err on the high side).
        // `undelivered` goes up FIRST: the monitor treats the float
        // `inflight` accumulator as authoritative only while undelivered>0
        // (repeated interleaved ±mass leaves O(ε) residue that would
        // otherwise never clear — see BusMonitor::inflight_or_zero).
        self.shared.undelivered.fetch_add(1, Ordering::AcqRel);
        let now_inflight = self.shared.inflight.add(mass);
        self.shared
            .metrics
            .max("inflight_peak_ppm", (now_inflight * 1e6) as u64);
        self.retained.push((seq, mass));
        self.shared.retained.fetch_add(1, Ordering::Relaxed);
        self.txs[to]
            .send(env)
            .map_err(|_| DiterError::Transport(format!("endpoint {to} closed")))?;
        self.shared.metrics.incr("msgs_sent");
        self.shared.metrics.add("bytes_sent", approx_bytes as u64);
        Ok(())
    }

    /// Broadcast to every other endpoint; `payload` must be cloneable.
    pub fn broadcast(&mut self, payload: &T, mass: f64, approx_bytes: usize) -> Result<()>
    where
        T: Clone,
    {
        for to in 0..self.txs.len() {
            if to != self.id {
                self.send(to, payload.clone(), mass, approx_bytes)?;
            }
        }
        Ok(())
    }

    /// Non-blocking receive of the next ripe message WITHOUT committing:
    /// the fluid stays on the in-flight account and the message stays on
    /// the undelivered count until [`Endpoint::commit`] is called. Use this
    /// when applying the payload takes time and the monitor must never see
    /// the fluid vanish in between.
    pub fn try_recv_uncommitted(&mut self) -> Option<Received<T>> {
        // drain the channel into the ripening heap
        while let Ok(env) = self.rx.try_recv() {
            self.delayed.push(Ripening(env));
        }
        let now = Instant::now();
        if let Some(top) = self.delayed.peek() {
            if top.0.ready_at <= now {
                let env = self.delayed.pop().unwrap().0;
                self.shared.metrics.incr("msgs_recv");
                return Some(Received {
                    from: env.from,
                    seq: env.seq,
                    mass: env.mass,
                    payload: env.payload,
                });
            }
        }
        None
    }

    /// Confirm that a received message's payload has been fully applied:
    /// releases its fluid from the in-flight account, marks it delivered,
    /// and acknowledges to the sender ("as TCP").
    pub fn commit(&mut self, from: usize, seq: u64, mass: f64) {
        self.shared.inflight.add(-mass);
        self.shared.undelivered.fetch_sub(1, Ordering::AcqRel);
        let _ = self.ack_txs[from].send(seq);
        self.shared.metrics.incr("acks");
    }

    /// Non-blocking receive with immediate commit (small payloads that are
    /// applied on the spot).
    pub fn try_recv(&mut self) -> Option<Received<T>> {
        let r = self.try_recv_uncommitted()?;
        self.commit(r.from, r.seq, r.mass);
        Some(r)
    }

    /// Drain everything ripe right now (immediate commit).
    pub fn drain(&mut self) -> Vec<Received<T>> {
        let mut out = Vec::new();
        while let Some(m) = self.try_recv() {
            out.push(m);
        }
        out
    }

    /// Drain everything ripe right now WITHOUT committing.
    pub fn drain_uncommitted(&mut self) -> Vec<Received<T>> {
        let mut out = Vec::new();
        while let Some(m) = self.try_recv_uncommitted() {
            out.push(m);
        }
        out
    }

    /// Process acknowledgments: drop retained parcels the peers confirmed.
    pub fn collect_acks(&mut self) {
        while let Ok(seq) = self.ack_rx.try_recv() {
            if let Some(pos) = self.retained.iter().position(|&(s, _)| s == seq) {
                self.retained.swap_remove(pos);
                self.shared.retained.fetch_sub(1, Ordering::Relaxed);
            }
        }
    }

    /// Parcels still awaiting acknowledgment.
    pub fn unacked(&self) -> usize {
        self.retained.len()
    }

    /// Global in-flight fluid (sent but not yet applied anywhere).
    pub fn global_inflight(&self) -> f64 {
        self.shared.inflight.get()
    }

    /// The bus-wide metric set (shared by all endpoints).
    pub fn metrics(&self) -> Arc<MetricSet> {
        self.shared.metrics.clone()
    }
}

/// A read-only monitor handle onto the bus state (for the coordinator's
/// convergence monitor thread).
pub struct BusMonitor {
    shared: Arc<Shared>,
}

impl BusMonitor {
    pub fn inflight(&self) -> f64 {
        self.shared.inflight.get()
    }

    pub fn retained(&self) -> u64 {
        self.shared.retained.load(Ordering::Relaxed)
    }

    /// Messages sent but not yet committed by their receiver — must be 0
    /// before the monitor may declare convergence.
    pub fn undelivered(&self) -> u64 {
        self.shared.undelivered.load(Ordering::Acquire)
    }

    /// The in-flight fluid, logically zeroed when nothing is undelivered:
    /// the f64 accumulator keeps O(ε)·msgs of non-associativity residue
    /// after many interleaved ±mass updates, and `undelivered == 0`
    /// *proves* the true in-flight mass is exactly zero (sends bump the
    /// undelivered count before adding their mass).
    pub fn inflight_or_zero(&self) -> f64 {
        if self.undelivered() == 0 {
            0.0
        } else {
            self.inflight()
        }
    }
}

/// Obtain a monitor for the same bus as `endpoint`.
pub fn monitor_of<T>(endpoint: &Endpoint<T>) -> BusMonitor {
    BusMonitor {
        shared: endpoint.shared.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_to_point_delivery() {
        let (mut eps, metrics) = bus::<String>(2, &BusConfig::default());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, "hello".into(), 0.5, 5).unwrap();
        let got = b.try_recv().unwrap();
        assert_eq!(got.payload, "hello");
        assert_eq!(got.from, 0);
        assert_eq!(metrics.get("msgs_sent"), 1);
        assert_eq!(metrics.get("msgs_recv"), 1);
    }

    #[test]
    fn inflight_accounting_conserves() {
        let (mut eps, _m) = bus::<u32>(2, &BusConfig::default());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        assert_eq!(a.global_inflight(), 0.0);
        a.send(1, 7, 1.25, 4).unwrap();
        a.send(1, 8, 0.75, 4).unwrap();
        assert!((a.global_inflight() - 2.0).abs() < 1e-12);
        let _ = b.try_recv().unwrap();
        assert!((b.global_inflight() - 0.75).abs() < 1e-12);
        let _ = b.try_recv().unwrap();
        assert_eq!(b.global_inflight(), 0.0);
    }

    #[test]
    fn acks_release_retention() {
        let (mut eps, _m) = bus::<u32>(2, &BusConfig::default());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, 1, 0.1, 4).unwrap();
        a.send(1, 2, 0.1, 4).unwrap();
        assert_eq!(a.unacked(), 2);
        b.drain();
        a.collect_acks();
        assert_eq!(a.unacked(), 0);
    }

    #[test]
    fn broadcast_reaches_all_peers() {
        let (mut eps, _m) = bus::<u8>(4, &BusConfig::default());
        let mut rest: Vec<_> = eps.drain(1..).collect();
        let mut a = eps.pop().unwrap();
        a.broadcast(&42, 0.0, 1).unwrap();
        for ep in rest.iter_mut() {
            let got = ep.try_recv().unwrap();
            assert_eq!(got.payload, 42);
        }
        assert!(a.try_recv().is_none(), "no self-delivery");
    }

    #[test]
    fn latency_delays_delivery() {
        let cfg = BusConfig {
            latency: Some((Duration::from_millis(30), Duration::from_millis(40))),
            seed: 1,
        };
        let (mut eps, _m) = bus::<u8>(2, &cfg);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, 9, 0.0, 1).unwrap();
        assert!(b.try_recv().is_none(), "must not arrive instantly");
        std::thread::sleep(Duration::from_millis(60));
        assert!(b.try_recv().is_some());
    }

    #[test]
    fn ordering_within_latency_heap() {
        // two messages with different delays must pop earliest-first
        let cfg = BusConfig {
            latency: Some((Duration::from_millis(1), Duration::from_millis(50))),
            seed: 3,
        };
        let (mut eps, _m) = bus::<u32>(2, &cfg);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        for i in 0..10 {
            a.send(1, i, 0.0, 4).unwrap();
        }
        std::thread::sleep(Duration::from_millis(80));
        let got = b.drain();
        assert_eq!(got.len(), 10);
    }

    #[test]
    fn extra_metric_names_registered() {
        let (eps, metrics) = bus_with_metrics::<u8>(2, &BusConfig::default(), &["handoffs_total"]);
        metrics.incr("handoffs_total");
        assert_eq!(metrics.get("handoffs_total"), 1);
        assert_eq!(eps[0].metrics().get("handoffs_total"), 1, "shared set");
    }

    #[test]
    fn send_to_missing_endpoint_fails() {
        let (mut eps, _m) = bus::<u8>(1, &BusConfig::default());
        let mut a = eps.pop().unwrap();
        assert!(a.send(3, 0, 0.0, 1).is_err());
    }

    #[test]
    fn cross_thread_usage() {
        let (mut eps, metrics) = bus::<u64>(2, &BusConfig::default());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let t = std::thread::spawn(move || {
            for i in 0..100u64 {
                a.send(1, i, 0.01, 8).unwrap();
            }
            a
        });
        let mut seen = 0;
        let deadline = Instant::now() + Duration::from_secs(5);
        while seen < 100 && Instant::now() < deadline {
            if b.try_recv().is_some() {
                seen += 1;
            }
        }
        let mut a = t.join().unwrap();
        a.collect_acks();
        assert_eq!(seen, 100);
        assert_eq!(a.unacked(), 0);
        assert_eq!(metrics.get("msgs_recv"), 100);
        assert!(b.global_inflight().abs() < 1e-12);
    }
}
