//! Lock-free f64 accumulator (no portable `AtomicF64` in std): CAS over
//! the bit pattern. Used for the global in-flight fluid account, which
//! every endpoint updates on every send/receive — a mutex here would
//! serialize the whole bus.

use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Debug)]
pub struct AtomicF64 {
    bits: AtomicU64,
}

impl AtomicF64 {
    pub fn new(v: f64) -> Self {
        Self {
            bits: AtomicU64::new(v.to_bits()),
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Acquire))
    }

    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Release);
    }

    /// Atomically add `delta`; returns the new value.
    pub fn add(&self, delta: f64) -> f64 {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + delta).to_bits();
            match self
                .bits
                .compare_exchange_weak(cur, new, Ordering::AcqRel, Ordering::Relaxed)
            {
                Ok(_) => return f64::from_bits(new),
                Err(actual) => cur = actual,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn basic_ops() {
        let a = AtomicF64::new(1.5);
        assert_eq!(a.get(), 1.5);
        a.set(2.0);
        assert_eq!(a.get(), 2.0);
        assert_eq!(a.add(0.5), 2.5);
        assert_eq!(a.add(-2.5), 0.0);
    }

    #[test]
    fn concurrent_adds_conserve() {
        let a = Arc::new(AtomicF64::new(0.0));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let a = a.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..10_000 {
                    a.add(1.0);
                    a.add(-1.0);
                }
                a.add(0.125);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!((a.get() - 1.0).abs() < 1e-12);
    }
}
