//! TCP wire transport: the multi-process implementation of [`Transport`].
//!
//! Frame-level protocol is specified normatively in DESIGN.md §8; this
//! module is one conforming implementation. In one sentence: every
//! connection carries length-prefixed frames; a `MSG` frame books its
//! fluid mass on the sender's in-flight account before the first byte is
//! written and keeps the parcel retained until the receiver's `ACK`
//! ("as TCP", §3.3 of the paper), so conservation accounting errs high,
//! never low, across process boundaries.
//!
//! Two deployment shapes share this code:
//!
//! * **loopback harness** ([`WireHub::loopback`]): all endpoints live in
//!   one process and share one accounting block, so the convergence
//!   monitor sees *exactly* the in-process bus semantics while every
//!   parcel genuinely rides a TCP socket — this is how the scenario
//!   matrix and the conservation fuzzer run unchanged over the wire
//!   (`DITER_TRANSPORT=wire`);
//! * **process-per-worker** ([`WireHub::remote`]): each process holds one
//!   endpoint plus a directory of peer socket addresses learned from the
//!   coordinator (`diter stream --listen/--connect`, see
//!   `coordinator::remote`). Accounting is then sender-side: mass is
//!   released when the `ACK` arrives, not when the remote receiver
//!   commits, which still errs high and still reaches zero at
//!   quiescence.
//!
//! ## Zero-copy fast path (DESIGN.md §8.8)
//!
//! Both directions avoid per-message allocation and per-message syscalls
//! in steady state:
//!
//! * **send**: [`encode_msg_frame`] encodes straight into an
//!   [`Arena`]-pooled frame buffer (length prefix reserved up front,
//!   patched after the body is written — no intermediate `Vec`); frames
//!   accumulate in a per-connection queue and flush with one vectored
//!   `writev` ([`crate::perf::writev`], a raw syscall like
//!   `pin_to_core`) under a [`FlushPolicy`] — size/frame caps flush
//!   early, a deadline bounds staleness under light load;
//! * **receive**: each connection owns one growable ring buffer; frames
//!   are parsed and dispatched *in place* (no body copy), and the pooled
//!   codec hooks ([`WireCodec::decode_pooled`] / [`WireCodec::reclaim`])
//!   cycle SoA column storage through [`ColumnPools`] so decoding a
//!   parcel and encoding the next one reuse the same vectors.
//!
//! The encoding helpers ([`write_varint`], [`zigzag`],
//! [`write_deltas`], …) are exported because the message-type codecs
//! (`coordinator::codec`) and the framing tests are built from them.

use std::collections::{BinaryHeap, VecDeque};
use std::io::{ErrorKind, IoSlice, Read, Write};
use std::marker::PhantomData;
use std::net::{IpAddr, Ipv4Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use super::{
    BusConfig, BusMonitor, Envelope, FlushPolicy, Received, Ripening, Shared, Transport,
    TransportHub, BUS_METRICS,
};
use crate::error::{DiterError, Result};
use crate::metrics::MetricSet;
use crate::perf::Arena;
use crate::prng::Xoshiro256pp;
use crate::transport::AtomicF64;

/// Wire protocol version carried by every `HELLO` (DESIGN.md §8.2).
pub const PROTO_VERSION: u8 = 1;

/// Hard upper bound on a frame body (corruption guard): a length prefix
/// above this is treated as a corrupt stream, not an allocation request.
pub const MAX_FRAME: usize = 256 << 20;

// Frame kinds (first byte of every frame body) — DESIGN.md §8.2. Public
// so the framing tests can build frames byte-for-byte.
/// First frame on a dialed connection: `[pid varint][version u8]`.
pub const KIND_HELLO: u8 = 0x01;
/// A fluid-bearing message: `[seq varint][mass f64][payload]`.
pub const KIND_MSG: u8 = 0x02;
/// Acknowledgment of a `MSG`: `[seq varint]`.
pub const KIND_ACK: u8 = 0x03;
/// Orderly close.
pub const KIND_BYE: u8 = 0x04;

/// Metric names registered by the wire transport (on top of
/// [`BUS_METRICS`], which it shares with the bus).
pub const WIRE_METRICS: &[&str] = &[
    "wire_bytes_sent",
    "wire_bytes_recv",
    "wire_frames_sent",
    "wire_frames_recv",
    "wire_writev_calls",
    "wire_frames_per_write", // peak frames completed by one writev
    "wire_flush_deadline_hits",
    "wire_dial_failures", // connect/setup failures in conn_to (peer dead?)
];

/// Fairness cap: at most this many frames parsed per connection per pump
/// entry, so a chatty peer cannot starve the send/flush half of the pump
/// (deferred frames stay in the ring for the next pump).
const PUMP_FRAMES_PER_CONN: usize = 64;

/// Read granularity of the receive ring.
const READ_CHUNK: usize = 16 * 1024;

/// Per-connection read budget per pump, and the ring high-water mark
/// above which reading pauses until parsing catches up (TCP backpressure
/// then throttles the sender). The mark is frame-aware: a frame whose
/// `4+len` exceeds it keeps reading until complete, because parsing only
/// consumes complete frames and pausing would wedge the connection.
const READ_BUDGET: usize = 256 * 1024;

/// Max frames gathered into a single `writev`.
const WRITEV_BATCH: usize = 64;

/// Frame buffers pooled per endpoint: a full default send-queue batch
/// ([`FlushPolicy::max_frames`] = 64) plus HELLO/ACK traffic, so a
/// flushed batch returns every buffer instead of dropping the overflow.
const FRAME_POOL: usize = 80;

/// Column vectors pooled per endpoint, per element type (the pooled
/// decode/encode cycle of [`ColumnPools`]).
const COLUMN_POOL: usize = 16;

/// Construct the canonical corrupt-frame error.
pub fn corrupt(what: &str) -> DiterError {
    DiterError::Transport(format!("corrupt frame: {what}"))
}

// ---------------------------------------------------------------------------
// Encoding primitives (DESIGN.md §8.1)
// ---------------------------------------------------------------------------

/// Append `v` as an LEB128 varint (7 data bits per byte, high bit = more).
pub fn write_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Read an LEB128 varint at `*pos`, advancing it. Rejects truncation and
/// encodings that overflow 64 bits.
pub fn read_varint(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v: u64 = 0;
    let mut shift: u32 = 0;
    loop {
        let Some(&byte) = buf.get(*pos) else {
            return Err(corrupt("varint truncated"));
        };
        *pos += 1;
        if shift > 63 || (shift == 63 && (byte & 0x7f) > 1) {
            return Err(corrupt("varint overflows u64"));
        }
        v |= u64::from(byte & 0x7f) << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// Zigzag-map a signed delta onto an unsigned varint-friendly value
/// (small magnitudes of either sign become small numbers).
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Inverse of [`zigzag`].
pub fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

/// Append an `f64` in IEEE-754 little-endian (8 bytes, exact).
pub fn write_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Read an `f64` at `*pos`, advancing it.
pub fn read_f64(buf: &[u8], pos: &mut usize) -> Result<f64> {
    let end = pos.checked_add(8).ok_or_else(|| corrupt("f64 offset"))?;
    let Some(bytes) = buf.get(*pos..end) else {
        return Err(corrupt("f64 truncated"));
    };
    *pos = end;
    Ok(f64::from_le_bytes(bytes.try_into().expect("8-byte slice")))
}

/// Append `vals` back to back as little-endian `f64`s (the SoA mass
/// column of a fluid parcel: one bulk copy, no per-entry framing).
pub fn write_f64_slice(out: &mut Vec<u8>, vals: &[f64]) {
    out.reserve(vals.len() * 8);
    for &v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Read `count` little-endian `f64`s at `*pos` into `out` (cleared
/// first), advancing `pos`. The count is validated against the remaining
/// buffer *before* reserving — the in-place variant behind
/// [`read_f64_slice`], used by the pooled decoders so a recycled vector
/// with warm capacity never touches the allocator.
pub fn read_f64_slice_into(
    buf: &[u8],
    pos: &mut usize,
    count: usize,
    out: &mut Vec<f64>,
) -> Result<()> {
    if buf.len().saturating_sub(*pos) < count.saturating_mul(8) {
        return Err(corrupt("f64 column truncated"));
    }
    out.clear();
    out.reserve(count);
    for _ in 0..count {
        out.push(read_f64(buf, pos)?);
    }
    Ok(())
}

/// Read `count` little-endian `f64`s at `*pos`, advancing it. The count
/// is validated against the remaining buffer *before* allocating.
pub fn read_f64_slice(buf: &[u8], pos: &mut usize, count: usize) -> Result<Vec<f64>> {
    let mut out = Vec::new();
    read_f64_slice_into(buf, pos, count, &mut out)?;
    Ok(out)
}

/// Append a coordinate column delta-encoded (DESIGN.md §8.1): the first
/// value is written absolutely, each subsequent value as the zigzag
/// difference from its predecessor — sorted SoA columns (fluid parcels,
/// halo slices) collapse to ~1 byte per coordinate.
pub fn write_deltas(out: &mut Vec<u8>, vals: impl IntoIterator<Item = u64>) {
    let mut prev: i64 = 0;
    for v in vals {
        let v = v as i64;
        write_varint(out, zigzag(v.wrapping_sub(prev)));
        prev = v;
    }
}

/// Read a `count`-entry delta-encoded coordinate column at `*pos`,
/// advancing it. Rejects columns that decode to a negative coordinate
/// and counts that cannot fit in the remaining buffer.
pub fn read_deltas(buf: &[u8], pos: &mut usize, count: usize) -> Result<Vec<u64>> {
    // every delta is at least one byte, so an honest count is bounded
    // by the remaining bytes — reject before allocating
    if count > buf.len().saturating_sub(*pos) {
        return Err(corrupt("coordinate count exceeds frame"));
    }
    let mut out = Vec::with_capacity(count);
    let mut prev: i64 = 0;
    for _ in 0..count {
        let v = prev
            .checked_add(unzigzag(read_varint(buf, pos)?))
            .ok_or_else(|| corrupt("coordinate delta overflow"))?;
        if v < 0 {
            return Err(corrupt("negative coordinate"));
        }
        out.push(v as u64);
        prev = v;
    }
    Ok(out)
}

/// Read a `count`-entry delta-encoded coordinate column at `*pos` into
/// `out` (cleared first) as `u32`s, advancing `pos` — the in-place
/// variant used by the pooled `WorkerMsg` decoders. Rejects everything
/// [`read_deltas`] rejects, plus coordinates above `u32::MAX`.
pub fn read_deltas_u32_into(
    buf: &[u8],
    pos: &mut usize,
    count: usize,
    out: &mut Vec<u32>,
) -> Result<()> {
    if count > buf.len().saturating_sub(*pos) {
        return Err(corrupt("coordinate count exceeds frame"));
    }
    out.clear();
    out.reserve(count);
    let mut prev: i64 = 0;
    for _ in 0..count {
        let v = prev
            .checked_add(unzigzag(read_varint(buf, pos)?))
            .ok_or_else(|| corrupt("coordinate delta overflow"))?;
        if v < 0 {
            return Err(corrupt("negative coordinate"));
        }
        if v > i64::from(u32::MAX) {
            return Err(corrupt("coordinate exceeds u32"));
        }
        out.push(v as u32);
        prev = v;
    }
    Ok(())
}

/// [`read_deltas_u32_into`] for `usize` columns (handoff slices carry
/// global coordinates).
pub fn read_deltas_usize_into(
    buf: &[u8],
    pos: &mut usize,
    count: usize,
    out: &mut Vec<usize>,
) -> Result<()> {
    if count > buf.len().saturating_sub(*pos) {
        return Err(corrupt("coordinate count exceeds frame"));
    }
    out.clear();
    out.reserve(count);
    let mut prev: i64 = 0;
    for _ in 0..count {
        let v = prev
            .checked_add(unzigzag(read_varint(buf, pos)?))
            .ok_or_else(|| corrupt("coordinate delta overflow"))?;
        if v < 0 {
            return Err(corrupt("negative coordinate"));
        }
        out.push(v as usize);
        prev = v;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Codec trait + pooled column storage
// ---------------------------------------------------------------------------

/// Recycled SoA column storage shared by the pooled codec paths
/// ([`WireCodec::decode_pooled`] / [`WireCodec::reclaim`]): one arena per
/// column element type. Decoders take cleared, warm-capacity vectors from
/// here; the send path gives an encoded payload's storage back — a closed
/// cycle (pools → decoded parcel → worker → coalesce → outgoing parcel →
/// pools) that keeps steady-state wire traffic off the allocator.
#[derive(Debug)]
pub struct ColumnPools {
    /// u32 coordinate columns (fluid parcels, halo slices)
    pub u32s: Arena<u32>,
    /// usize coordinate columns (handoff slices)
    pub usizes: Arena<usize>,
    /// f64 mass/value columns
    pub f64s: Arena<f64>,
}

impl ColumnPools {
    /// Pools retaining at most `max_pooled` buffers per element type.
    pub fn new(max_pooled: usize) -> Self {
        ColumnPools {
            u32s: Arena::new(max_pooled),
            usizes: Arena::new(max_pooled),
            f64s: Arena::new(max_pooled),
        }
    }
}

/// A message type that can ride the wire. Implemented by the
/// coordinator's `WorkerMsg` (see `coordinator::codec`) and by the
/// control-plane messages of remote mode.
///
/// `decode` must be the exact inverse of `encode` and must consume the
/// whole buffer — trailing bytes are a framing error, which is what the
/// corrupt-frame tests pin down.
pub trait WireCodec: Sized {
    /// Append this message's payload encoding to `out`.
    fn encode(&self, out: &mut Vec<u8>);

    /// Decode a payload produced by [`WireCodec::encode`].
    fn decode(buf: &[u8]) -> Result<Self>;

    /// [`WireCodec::decode`], drawing any owned column storage from
    /// `pools` instead of the allocator — the receive path's
    /// zero-allocation steady state. Must produce exactly the value
    /// `decode` would. The default ignores the pools.
    fn decode_pooled(buf: &[u8], _pools: &mut ColumnPools) -> Result<Self> {
        Self::decode(buf)
    }

    /// Return this message's owned column storage to `pools` — called by
    /// the wire send path once the payload is encoded into a frame, so
    /// the vectors decoded out of one message can carry the next. The
    /// default just drops the message.
    fn reclaim(self, _pools: &mut ColumnPools) {}
}

/// Encode one complete data frame — `[u32 len][KIND_MSG][seq varint]
/// [mass f64][payload]` — in place into `frame` (cleared first): four
/// zero bytes are reserved for the length prefix up front and patched
/// once the body is encoded, so no intermediate body `Vec` exists. With
/// a recycled warm-capacity buffer this is the allocation-free send
/// encode; the bytes produced are identical to framing a separately
/// encoded body (the property test pins this).
pub fn encode_msg_frame<T: WireCodec>(frame: &mut Vec<u8>, seq: u64, mass: f64, payload: &T) {
    frame.clear();
    frame.extend_from_slice(&[0u8; 4]);
    frame.push(KIND_MSG);
    write_varint(frame, seq);
    write_f64(frame, mass);
    payload.encode(frame);
    let len = (frame.len() - 4) as u32;
    frame[..4].copy_from_slice(&len.to_le_bytes());
}

// ---------------------------------------------------------------------------
// Blocking control-plane framing (used by coordinator::remote)
// ---------------------------------------------------------------------------

/// Write one `[u32 length][payload]` frame of `msg` to a blocking stream
/// and flush it — the control-plane counterpart of the non-blocking data
/// path (remote mode's JOIN/ASSIGN/REPORT traffic).
pub fn write_ctrl_frame<T: WireCodec>(stream: &mut TcpStream, msg: &T) -> Result<()> {
    let mut body = Vec::new();
    msg.encode(&mut body);
    let mut frame = Vec::with_capacity(body.len() + 4);
    frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
    frame.extend_from_slice(&body);
    stream.write_all(&frame)?;
    stream.flush()?;
    Ok(())
}

/// Read one `[u32 length][payload]` frame of `T` from a blocking stream.
pub fn read_ctrl_frame<T: WireCodec>(stream: &mut TcpStream) -> Result<T> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len) as usize;
    if len == 0 || len > MAX_FRAME {
        return Err(corrupt("control frame length"));
    }
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    T::decode(&body)
}

// ---------------------------------------------------------------------------
// The hub: directory of peer addresses + shared accounting
// ---------------------------------------------------------------------------

/// Address directory: slot `k` holds PID k's listening address, `None`
/// for a retired (or never-spawned) endpoint. The wire analogue of the
/// bus's channel directory, with the same locking discipline: sends
/// resolve (and queue their frame) under a read lock, removal takes the
/// write lock, so removal strictly orders with in-progress sends.
struct WireDirectory {
    addrs: Vec<Option<SocketAddr>>,
}

/// A shared handle onto the wire fabric that can register and deregister
/// endpoints while workers are running — the TCP implementation of
/// [`TransportHub`]. Cloneable; all clones see the same directory.
pub struct WireHub<T> {
    dir: Arc<RwLock<WireDirectory>>,
    shared: Arc<Shared>,
    latency: Option<(Duration, Duration)>,
    seed: u64,
    bind_ip: IpAddr,
    policy: FlushPolicy,
    /// true in the loopback harness: all endpoints share this process's
    /// accounting block, so a receiver commit settles the account
    /// directly (exact bus semantics). false per-process: commits only
    /// emit the ACK and the *sender* releases on ACK receipt.
    local_commit: bool,
    _msg: PhantomData<fn() -> T>,
}

impl<T> Clone for WireHub<T> {
    fn clone(&self) -> Self {
        WireHub {
            dir: self.dir.clone(),
            shared: self.shared.clone(),
            latency: self.latency,
            seed: self.seed,
            bind_ip: self.bind_ip,
            policy: self.policy,
            local_commit: self.local_commit,
            _msg: PhantomData,
        }
    }
}

fn new_shared(extra: &[&'static str]) -> Arc<Shared> {
    let names: Vec<&'static str> = BUS_METRICS
        .iter()
        .chain(WIRE_METRICS)
        .chain(extra)
        .copied()
        .collect();
    Arc::new(Shared {
        inflight: AtomicF64::new(0.0),
        retained: AtomicU64::new(0),
        undelivered: AtomicU64::new(0),
        metrics: Arc::new(MetricSet::new(&names)),
    })
}

impl<T: WireCodec + Send + 'static> WireHub<T> {
    /// An empty single-process hub on `127.0.0.1`: every
    /// [`WireHub::add_endpoint`] binds a fresh loopback listener, and
    /// commits settle the shared account exactly like the in-process
    /// bus. This is the harness behind `DITER_TRANSPORT=wire`.
    pub fn loopback(cfg: &BusConfig, extra: &[&'static str]) -> WireHub<T> {
        WireHub {
            dir: Arc::new(RwLock::new(WireDirectory { addrs: Vec::new() })),
            shared: new_shared(extra),
            latency: cfg.latency,
            seed: cfg.seed,
            bind_ip: IpAddr::V4(Ipv4Addr::LOCALHOST),
            policy: cfg.flush,
            // ack_release opts the loopback harness into the per-process
            // accounting discipline (release on ACK receipt at the
            // sender): crash recovery needs every unit of stranded mass
            // attributable to some live sender's retention list
            local_commit: !cfg.ack_release,
            _msg: PhantomData,
        }
    }

    /// A process-per-worker hub: `width` directory slots (filled in by
    /// [`WireHub::set_peer_addr`] as the coordinator's PEERS table
    /// arrives), local endpoints bound on `bind_ip`, and sender-side
    /// accounting (in-flight mass is released on ACK receipt).
    pub fn remote(width: usize, bind_ip: IpAddr, cfg: &BusConfig, extra: &[&'static str]) -> WireHub<T> {
        WireHub {
            dir: Arc::new(RwLock::new(WireDirectory {
                addrs: vec![None; width],
            })),
            shared: new_shared(extra),
            latency: cfg.latency,
            seed: cfg.seed,
            bind_ip,
            policy: cfg.flush,
            local_commit: false,
            _msg: PhantomData,
        }
    }

    /// Register a new endpoint at slot `id`, binding a listener on an
    /// OS-assigned port. Slot rules match the bus exactly: a vacant
    /// (retired) slot or exactly one past the current end; occupied
    /// slots and gaps are errors.
    pub fn add_endpoint(&self, id: usize) -> Result<WireEndpoint<T>> {
        let listener = TcpListener::bind((self.bind_ip, 0))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        {
            let mut d = self.dir.write().unwrap_or_else(|e| e.into_inner());
            if id > d.addrs.len() {
                return Err(DiterError::Transport(format!(
                    "endpoint {id} would leave a gap (directory holds {})",
                    d.addrs.len()
                )));
            }
            if id < d.addrs.len() && d.addrs[id].is_some() {
                return Err(DiterError::Transport(format!("endpoint {id} already live")));
            }
            if id == d.addrs.len() {
                d.addrs.push(Some(addr));
            } else {
                d.addrs[id] = Some(addr);
            }
        }
        Ok(WireEndpoint {
            id,
            listener,
            local_addr: addr,
            dir: self.dir.clone(),
            shared: self.shared.clone(),
            conns: Vec::new(),
            inbox: BinaryHeap::new(),
            retained: Vec::new(),
            next_seq: 0,
            latency: self.latency,
            rng: Xoshiro256pp::seed_from_u64(self.seed ^ (id as u64).wrapping_mul(0x9E3779B9)),
            local_commit: self.local_commit,
            policy: self.policy,
            frames: Arena::new(FRAME_POOL),
            pools: ColumnPools::new(COLUMN_POOL),
        })
    }

    /// Install a *remote* peer's listening address in slot `id` (growing
    /// the directory if needed) — remote mode's PEERS table. Sends to
    /// `id` dial this address.
    pub fn set_peer_addr(&self, id: usize, addr: SocketAddr) {
        let mut d = self.dir.write().unwrap_or_else(|e| e.into_inner());
        if id >= d.addrs.len() {
            d.addrs.resize(id + 1, None);
        }
        d.addrs[id] = Some(addr);
    }

    /// Deregister slot `id`: subsequent sends to it fail fast at the
    /// sender, which re-routes the fluid. Each send resolves the slot
    /// (and queues its frame) under the directory read lock, so this
    /// write-locked removal strictly orders with in-progress sends:
    /// after it returns, every accepted frame is at worst in its
    /// sender's send queue, bounded by that sender's flush deadline —
    /// see the retirement-drain note in DESIGN.md §8.8.
    pub fn remove_endpoint(&self, id: usize) {
        let mut d = self.dir.write().unwrap_or_else(|e| e.into_inner());
        if id < d.addrs.len() {
            d.addrs[id] = None;
        }
    }

    /// Directory width (live + vacant slots).
    pub fn capacity(&self) -> usize {
        self.dir.read().unwrap_or_else(|e| e.into_inner()).addrs.len()
    }

    /// Whether slot `id` currently has a live (addressable) endpoint.
    pub fn is_live(&self, id: usize) -> bool {
        let d = self.dir.read().unwrap_or_else(|e| e.into_inner());
        d.addrs.get(id).is_some_and(Option::is_some)
    }

    /// A monitor handle onto this process's conservation accounting.
    pub fn monitor(&self) -> BusMonitor {
        BusMonitor {
            shared: self.shared.clone(),
        }
    }

    /// The fabric-wide metric set.
    pub fn metrics(&self) -> Arc<MetricSet> {
        self.shared.metrics.clone()
    }
}

impl<T: WireCodec + Send + Clone + 'static> TransportHub<T> for WireHub<T> {
    fn add_endpoint(&self, id: usize) -> Result<Box<dyn Transport<T>>> {
        Ok(Box::new(WireHub::add_endpoint(self, id)?))
    }
    fn remove_endpoint(&self, id: usize) {
        WireHub::remove_endpoint(self, id)
    }
    fn capacity(&self) -> usize {
        WireHub::capacity(self)
    }
    fn is_live(&self, id: usize) -> bool {
        WireHub::is_live(self, id)
    }
    fn monitor(&self) -> BusMonitor {
        WireHub::monitor(self)
    }
    fn metrics(&self) -> Arc<MetricSet> {
        WireHub::metrics(self)
    }
}

// ---------------------------------------------------------------------------
// The endpoint
// ---------------------------------------------------------------------------

/// Raw `writev` where the target supports it (Linux x86-64/aarch64, via
/// `perf::writev`), falling back to `Write::write_vectored` elsewhere.
#[cfg(all(
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
))]
fn writev_stream(stream: &mut TcpStream, bufs: &[IoSlice<'_>]) -> std::io::Result<usize> {
    use std::os::fd::AsRawFd;
    crate::perf::writev(stream.as_raw_fd(), bufs)
}

#[cfg(not(all(
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
)))]
fn writev_stream(stream: &mut TcpStream, bufs: &[IoSlice<'_>]) -> std::io::Result<usize> {
    stream.write_vectored(bufs)
}

/// One connection's receive buffer: a growable ring that frames are
/// parsed out of **in place** — bytes land once (`read` into the tail),
/// the dispatcher borrows the frame body straight from the buffer, and
/// `consume` advances the head. Compaction is a `copy_within` when the
/// head has moved; the backing storage only ever grows to its high-water
/// mark, so a warmed-up connection never reallocates.
#[derive(Default)]
struct RecvRing {
    buf: Vec<u8>,
    pos: usize,
    end: usize,
}

impl RecvRing {
    fn buffered(&self) -> usize {
        self.end - self.pos
    }

    fn readable(&self) -> &[u8] {
        &self.buf[self.pos..self.end]
    }

    fn consume(&mut self, n: usize) {
        self.pos += n;
        if self.pos == self.end {
            self.pos = 0;
            self.end = 0;
        }
    }

    fn clear(&mut self) {
        self.pos = 0;
        self.end = 0;
    }

    /// Ensure at least `min_free` writable bytes after `end`, compacting
    /// (and, cold, growing) as needed.
    fn make_room(&mut self, min_free: usize) {
        if self.buf.len() - self.end >= min_free {
            return;
        }
        if self.pos > 0 {
            self.buf.copy_within(self.pos..self.end, 0);
            self.end -= self.pos;
            self.pos = 0;
        }
        if self.buf.len() - self.end < min_free {
            self.buf.resize(self.end + min_free, 0);
        }
    }

    fn space(&mut self) -> &mut [u8] {
        &mut self.buf[self.end..]
    }

    fn filled(&mut self, n: usize) {
        self.end += n;
    }

    /// Whether a complete, well-formed-length frame is parseable right
    /// now (used to keep an EOF'd connection alive until the fairness
    /// cap has let its backlog drain, and to report deferred frames to
    /// quiescence checks).
    fn has_complete_frame(&self) -> bool {
        let avail = self.buffered();
        if avail < 4 {
            return false;
        }
        let b = self.readable();
        let len = u32::from_le_bytes(b[..4].try_into().expect("4-byte slice")) as usize;
        len != 0 && len <= MAX_FRAME && avail >= 4 + len
    }

    /// Bytes the frame at the head of the ring still needs before it is
    /// parseable — zero when the head frame is complete, its length
    /// prefix is corrupt (the parse phase will kill the connection), or
    /// fewer than 4 bytes are buffered. Parsing only consumes complete
    /// frames, so the read phase must keep reading past the high-water
    /// mark while this is non-zero: a frame larger than `READ_BUDGET`
    /// could otherwise never finish arriving.
    fn head_frame_deficit(&self) -> usize {
        let avail = self.buffered();
        if avail < 4 {
            return 0;
        }
        let b = self.readable();
        let len = u32::from_le_bytes(b[..4].try_into().expect("4-byte slice")) as usize;
        if len == 0 || len > MAX_FRAME {
            return 0;
        }
        (4 + len).saturating_sub(avail)
    }
}

/// One live connection (inbound-accepted or outbound-dialed; the
/// protocol is full duplex, so either kind carries traffic both ways).
struct Conn {
    stream: TcpStream,
    /// peer PID: set at dial time (outbound) or by the peer's HELLO
    /// (inbound); frames on an unidentified connection are a protocol
    /// error except HELLO itself
    peer: Option<usize>,
    rbuf: RecvRing,
    /// complete `[len][body]` frames queued for the next vectored flush
    /// (buffers from the endpoint's frame arena; returned when written)
    sendq: VecDeque<Vec<u8>>,
    /// bytes of `sendq[0]` already written (partial-write progress)
    head_off: usize,
    /// unwritten bytes across the queue (the FlushPolicy byte cap)
    queued_bytes: usize,
    /// when the oldest queued frame was queued (the deadline trigger)
    queued_since: Option<Instant>,
    alive: bool,
}

impl Conn {
    fn new(stream: TcpStream, peer: Option<usize>) -> Conn {
        Conn {
            stream,
            peer,
            rbuf: RecvRing::default(),
            sendq: VecDeque::new(),
            head_off: 0,
            queued_bytes: 0,
            queued_since: None,
            alive: true,
        }
    }

    /// Flush queued frames with vectored writes: one `writev` per batch
    /// of up to [`WRITEV_BATCH`] frames, looping until the queue empties
    /// or the socket pushes back (`WouldBlock` — a later pump resumes).
    /// Fully written frame buffers return to the arena; a partial write
    /// leaves the head frame with an offset. A write error kills the
    /// connection; frames stranded in the queue stay *accounted* (the
    /// monitor errs high, exactly like frames lost in a dead socket).
    fn flush(&mut self, metrics: &MetricSet, frames: &mut Arena<u8>) {
        while self.alive && !self.sendq.is_empty() {
            let empty: &[u8] = &[];
            let mut iovs = [IoSlice::new(empty); WRITEV_BATCH];
            let mut n_iov = 0;
            for f in self.sendq.iter() {
                if n_iov == WRITEV_BATCH {
                    break;
                }
                let start = if n_iov == 0 { self.head_off } else { 0 };
                iovs[n_iov] = IoSlice::new(&f[start..]);
                n_iov += 1;
            }
            match writev_stream(&mut self.stream, &iovs[..n_iov]) {
                Ok(0) => {
                    self.alive = false;
                }
                Ok(mut n) => {
                    metrics.incr("wire_writev_calls");
                    metrics.add("wire_bytes_sent", n as u64);
                    self.queued_bytes -= n;
                    let mut completed: u64 = 0;
                    while n > 0 {
                        let rem = self.sendq.front().expect("bytes imply a frame").len()
                            - self.head_off;
                        if n >= rem {
                            n -= rem;
                            frames.give(self.sendq.pop_front().expect("nonempty"));
                            self.head_off = 0;
                            completed += 1;
                        } else {
                            self.head_off += n;
                            n = 0;
                        }
                    }
                    metrics.add("wire_frames_sent", completed);
                    metrics.max("wire_frames_per_write", completed);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.alive = false;
                }
            }
        }
        if self.sendq.is_empty() {
            self.queued_since = None;
        }
    }
}

/// One PID's wire endpoint: a nonblocking listener plus its connection
/// set, owned by exactly one worker (thread or process). The TCP
/// implementation of [`Transport`].
pub struct WireEndpoint<T: WireCodec> {
    id: usize,
    listener: TcpListener,
    local_addr: SocketAddr,
    dir: Arc<RwLock<WireDirectory>>,
    shared: Arc<Shared>,
    conns: Vec<Conn>,
    /// decoded MSG frames ripening through latency injection (applied on
    /// the receive side here; protocol-equivalent to the bus's
    /// sender-side stamping)
    inbox: BinaryHeap<Ripening<T>>,
    /// parcels retained until acked (seq, mass, dest); "as TCP". The
    /// destination makes crash recovery exact: [`WireEndpoint::peer_reset`]
    /// drops and releases precisely the entries addressed to a dead PID.
    retained: Vec<(u64, f64, usize)>,
    next_seq: u64,
    latency: Option<(Duration, Duration)>,
    rng: Xoshiro256pp,
    local_commit: bool,
    /// when queued frames get pushed to the sockets (see [`FlushPolicy`])
    policy: FlushPolicy,
    /// recycled frame buffers: each MSG/ACK/HELLO frame is encoded into
    /// one, queued, and the buffer returns here after its writev
    frames: Arena<u8>,
    /// recycled SoA column storage for the pooled codec paths
    pools: ColumnPools,
}

impl<T: WireCodec + Send + 'static> WireEndpoint<T> {
    /// The address this endpoint's listener is bound to (advertised to
    /// peers through the directory, or remote mode's JOINED message).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// This endpoint's PID.
    pub fn id(&self) -> usize {
        self.id
    }

    /// Directory width (live + vacant slots).
    pub fn peers(&self) -> usize {
        self.dir.read().unwrap_or_else(|e| e.into_inner()).addrs.len()
    }

    fn sample_delay(&mut self) -> Duration {
        match self.latency {
            None => Duration::ZERO,
            Some((lo, hi)) => {
                let span = hi.saturating_sub(lo);
                lo + Duration::from_nanos((self.rng.next_f64() * span.as_nanos() as f64) as u64)
            }
        }
    }

    /// Flush connection `ci`'s send queue now (vectored writes).
    fn flush_conn(&mut self, ci: usize) {
        self.conns[ci].flush(&self.shared.metrics, &mut self.frames);
    }

    /// Push every queued frame on every connection to the network now,
    /// regardless of the flush policy — see [`Transport::flush`]. Called
    /// at latency-sensitive moments (threshold crossings, drains,
    /// retirement) where staleness matters more than batching.
    pub fn flush(&mut self) {
        for ci in 0..self.conns.len() {
            self.flush_conn(ci);
        }
    }

    /// Queue one complete `[len][body]` frame on connection `ci`. The
    /// frame is *accepted* from this point on: it will reach the socket
    /// at the next policy-triggered or explicit flush.
    fn enqueue_frame(&mut self, ci: usize, frame: Vec<u8>) {
        let c = &mut self.conns[ci];
        c.queued_bytes += frame.len();
        if c.queued_since.is_none() {
            c.queued_since = Some(Instant::now());
        }
        c.sendq.push_back(frame);
    }

    /// Flush `ci` if its queue trips the size or frame cap.
    fn maybe_flush(&mut self, ci: usize) {
        let c = &self.conns[ci];
        if c.sendq.len() >= self.policy.max_frames || c.queued_bytes >= self.policy.max_bytes {
            self.flush_conn(ci);
        }
    }

    /// Accept pending connections, flush deadline-stale send queues,
    /// read everything readable into the per-connection rings, and
    /// dispatch complete frames in place. Every non-blocking entry point
    /// starts with a pump, so progress needs no background thread.
    fn pump(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let _ = stream.set_nonblocking(true);
                    let _ = stream.set_nodelay(true);
                    self.conns.push(Conn::new(stream, None));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
        // deadline flush first: any endpoint activity bounds how long a
        // queued frame can wait, even if no further send ever comes
        let now = Instant::now();
        for ci in 0..self.conns.len() {
            let due = self.conns[ci]
                .queued_since
                .is_some_and(|t| now.duration_since(t) >= self.policy.deadline);
            if due {
                self.shared.metrics.incr("wire_flush_deadline_hits");
                self.flush_conn(ci);
            }
        }
        // read phase: budgeted per connection, and paused while a ring
        // is over its high-water mark — parsing (capped below for
        // fairness) catches up and TCP backpressure throttles the peer.
        // The pause is frame-aware: an in-progress frame with 4+len over
        // the mark keeps reading (budget raised to cover its deficit,
        // bounded by MAX_FRAME via the length check) because parsing
        // only consumes complete frames — pausing on such a frame would
        // wedge the connection forever
        for ci in 0..self.conns.len() {
            let c = &mut self.conns[ci];
            let deficit = c.rbuf.head_frame_deficit();
            if !c.alive || (c.rbuf.buffered() >= READ_BUDGET && deficit == 0) {
                continue;
            }
            let mut budget = READ_BUDGET.max(deficit);
            loop {
                c.rbuf.make_room(READ_CHUNK);
                match c.stream.read(c.rbuf.space()) {
                    Ok(0) => {
                        // EOF: no more bytes will come, but complete
                        // frames already in the ring still get parsed
                        c.alive = false;
                        break;
                    }
                    Ok(n) => {
                        c.rbuf.filled(n);
                        self.shared.metrics.add("wire_bytes_recv", n as u64);
                        budget = budget.saturating_sub(n);
                        if budget == 0 {
                            break;
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        c.alive = false;
                        break;
                    }
                }
            }
        }
        // parse phase: frames dispatch straight out of the ring (no body
        // copy), at most PUMP_FRAMES_PER_CONN per connection per pump so
        // a flooding peer cannot starve the others or the send half
        for ci in 0..self.conns.len() {
            let mut ring = std::mem::take(&mut self.conns[ci].rbuf);
            let mut parsed = 0;
            while parsed < PUMP_FRAMES_PER_CONN {
                let avail = ring.buffered();
                if avail < 4 {
                    break;
                }
                let len = u32::from_le_bytes(ring.readable()[..4].try_into().expect("4-byte slice"))
                    as usize;
                if len == 0 || len > MAX_FRAME {
                    self.conns[ci].alive = false; // corrupt stream: stop parsing it
                    ring.clear();
                    break;
                }
                if avail < 4 + len {
                    break;
                }
                self.dispatch(ci, &ring.readable()[4..4 + len]);
                ring.consume(4 + len);
                parsed += 1;
                self.shared.metrics.incr("wire_frames_recv");
                if !self.conns[ci].alive {
                    // dispatch killed the connection (BYE or protocol
                    // error): nothing after this frame is trustworthy
                    ring.clear();
                    break;
                }
            }
            self.conns[ci].rbuf = ring;
        }
        // a dead connection sticks around only while its ring still
        // holds complete frames the fairness cap deferred (an EOF'd
        // backlog drains across pumps); corrupt streams were cleared
        // above, so they cull immediately
        self.conns.retain(|c| c.alive || c.rbuf.has_complete_frame());
    }

    /// Handle one complete frame received on connection `ci`. `frame`
    /// borrows the connection's receive ring — decoding pulls column
    /// storage from the pools rather than copying the body anywhere.
    fn dispatch(&mut self, ci: usize, frame: &[u8]) {
        let kill = |conns: &mut Vec<Conn>, ci: usize| conns[ci].alive = false;
        let Some(&kind) = frame.first() else {
            return kill(&mut self.conns, ci);
        };
        let body = &frame[1..];
        match kind {
            KIND_HELLO => {
                let mut pos = 0;
                let Ok(pid) = read_varint(body, &mut pos) else {
                    return kill(&mut self.conns, ci);
                };
                if body.get(pos).copied() != Some(PROTO_VERSION) {
                    return kill(&mut self.conns, ci);
                }
                self.conns[ci].peer = Some(pid as usize);
            }
            KIND_MSG => {
                // sender attribution comes from the connection's HELLO
                let Some(from) = self.conns[ci].peer else {
                    return kill(&mut self.conns, ci);
                };
                let pools = &mut self.pools;
                let mut pos = 0;
                let decoded = read_varint(body, &mut pos).and_then(|seq| {
                    let mass = read_f64(body, &mut pos)?;
                    let payload = T::decode_pooled(&body[pos..], pools)?;
                    Ok((seq, mass, payload))
                });
                let Ok((seq, mass, payload)) = decoded else {
                    return kill(&mut self.conns, ci);
                };
                let ready_at = Instant::now() + self.sample_delay();
                self.inbox.push(Ripening(Envelope {
                    from,
                    seq,
                    mass,
                    ready_at,
                    payload,
                }));
            }
            KIND_ACK => {
                let mut pos = 0;
                let Ok(seq) = read_varint(body, &mut pos) else {
                    return kill(&mut self.conns, ci);
                };
                if let Some(p) = self.retained.iter().position(|&(s, _, _)| s == seq) {
                    let (_, mass, _) = self.retained.swap_remove(p);
                    self.shared.retained.fetch_sub(1, Ordering::Relaxed);
                    if !self.local_commit {
                        // sender-side release: the remote receiver has
                        // applied the parcel, its mass leaves this
                        // process's in-flight account now
                        self.shared.inflight.add(-mass);
                        self.shared.undelivered.fetch_sub(1, Ordering::AcqRel);
                    }
                }
            }
            KIND_BYE => kill(&mut self.conns, ci),
            _ => kill(&mut self.conns, ci),
        }
    }

    /// A live connection to PID `to`, dialing `addr` if none exists.
    /// Outbound connections introduce themselves with HELLO first (the
    /// send queue is FIFO, so HELLO leads the first flushed batch and
    /// the peer can attribute every later frame).
    fn conn_to(&mut self, to: usize, addr: SocketAddr) -> Option<usize> {
        if let Some(ci) = self.conns.iter().position(|c| c.alive && c.peer == Some(to)) {
            return Some(ci);
        }
        let Ok(stream) = TcpStream::connect_timeout(&addr, Duration::from_secs(5)) else {
            // record the cause instead of collapsing it into a silent
            // re-route: a burst of dial failures to one address is the
            // wire-side symptom of a dead peer
            self.shared.metrics.incr("wire_dial_failures");
            return None;
        };
        let _ = stream.set_nodelay(true);
        if stream.set_nonblocking(true).is_err() {
            self.shared.metrics.incr("wire_dial_failures");
            return None;
        }
        let ci = self.conns.len();
        self.conns.push(Conn::new(stream, Some(to)));
        let mut hello = self.frames.take();
        hello.extend_from_slice(&[0u8; 4]);
        hello.push(KIND_HELLO);
        write_varint(&mut hello, self.id as u64);
        hello.push(PROTO_VERSION);
        let len = (hello.len() - 4) as u32;
        hello[..4].copy_from_slice(&len.to_le_bytes());
        self.enqueue_frame(ci, hello);
        Some(ci)
    }

    /// See [`Transport::try_send`]. The destination address is resolved
    /// under a short directory read lock, any dial happens with the lock
    /// *released* (a blocking `connect_timeout` must not stall directory
    /// writers or other senders), and the slot is re-checked under a
    /// fresh read lock — the frame is queued under that lock — so
    /// [`WireHub::remove_endpoint`] (a write) strictly orders with
    /// in-progress sends exactly like the bus: after removal returns,
    /// every accepted frame is queued (its flush deadline bounds
    /// delivery) and every later send fails fast and re-routes; a
    /// removal or re-registration that raced the dial fails the send
    /// before any accounting. A cached connection is deliberately *not*
    /// trusted across that boundary.
    ///
    /// Once the frame is queued the send has **succeeded**: accounting
    /// happened before queueing, and a connection that later dies during
    /// its flush strands that mass on the in-flight account — the
    /// monitor errs high (exactly like bytes lost in a dead socket's
    /// buffer), never low. Directory misses and dial failures still fail
    /// fast *before* any accounting and hand the payload back.
    pub fn try_send(
        &mut self,
        to: usize,
        payload: T,
        mass: f64,
        approx_bytes: usize,
    ) -> std::result::Result<(), T> {
        self.pump();
        let dir = self.dir.clone();
        let addr = {
            let d = dir.read().unwrap_or_else(|e| e.into_inner());
            match d.addrs.get(to).and_then(|a| *a) {
                Some(addr) => addr,
                None => return Err(payload),
            }
        };
        // dial (if needed) with the lock released, then re-validate the
        // slot under a fresh read lock before accounting and queueing
        let Some(ci) = self.conn_to(to, addr) else {
            return Err(payload);
        };
        let d = dir.read().unwrap_or_else(|e| e.into_inner());
        if d.addrs.get(to).and_then(|a| *a) != Some(addr) {
            return Err(payload);
        }
        let seq = self.next_seq;
        // encode in place into a recycled frame buffer — length prefix
        // reserved up front, patched after the body (no body Vec)
        let mut frame = self.frames.take();
        frame.reserve(approx_bytes + 21);
        encode_msg_frame(&mut frame, seq, mass, &payload);
        let frame_len = frame.len();
        // in-flight accounting BEFORE the frame is queued so the monitor
        // can never observe fluid vanishing; `undelivered` first (see
        // the bus) so the float accumulator is authoritative only while
        // it is >0
        self.shared.undelivered.fetch_add(1, Ordering::AcqRel);
        let now_inflight = self.shared.inflight.add(mass);
        self.shared
            .metrics
            .max("inflight_peak_ppm", (now_inflight * 1e6) as u64);
        self.enqueue_frame(ci, frame);
        drop(d);
        // the payload's column storage feeds the next decode
        payload.reclaim(&mut self.pools);
        self.next_seq += 1;
        self.retained.push((seq, mass, to));
        self.shared.retained.fetch_add(1, Ordering::Relaxed);
        self.shared.metrics.incr("msgs_sent");
        self.shared.metrics.add("bytes_sent", frame_len as u64);
        self.maybe_flush(ci);
        Ok(())
    }

    /// See [`Transport::try_recv_uncommitted`].
    pub fn try_recv_uncommitted(&mut self) -> Option<Received<T>> {
        self.pump();
        let now = Instant::now();
        if let Some(top) = self.inbox.peek() {
            if top.0.ready_at <= now {
                let env = self.inbox.pop().expect("peeked").0;
                self.shared.metrics.incr("msgs_recv");
                return Some(Received {
                    from: env.from,
                    seq: env.seq,
                    mass: env.mass,
                    payload: env.payload,
                });
            }
        }
        None
    }

    /// See [`Transport::commit`]. In the loopback harness this settles
    /// the shared account directly (exact bus semantics) and the ACK
    /// only releases the sender's retention; per-process, the ACK *is*
    /// the release — the sender's accounting drops when it arrives. The
    /// ACK rides the send queue like any frame (flush policy applies);
    /// the sender's retention is memory, not mass, so ACK staleness is
    /// bounded by the deadline and costs nothing else.
    pub fn commit(&mut self, from: usize, seq: u64, mass: f64) {
        if self.local_commit {
            self.shared.inflight.add(-mass);
            self.shared.undelivered.fetch_sub(1, Ordering::AcqRel);
        }
        let mut ack = self.frames.take();
        ack.extend_from_slice(&[0u8; 4]);
        ack.push(KIND_ACK);
        write_varint(&mut ack, seq);
        let len = (ack.len() - 4) as u32;
        ack[..4].copy_from_slice(&len.to_le_bytes());
        // reuse a live connection back to the sender, else dial — unless
        // the sender retired, in which case the ack is dropped (its
        // retention list died with it) and the buffer goes back to the pool
        let ci = self
            .conns
            .iter()
            .position(|c| c.alive && c.peer == Some(from))
            .or_else(|| {
                let addr = {
                    let dir = self.dir.clone();
                    let d = dir.read().unwrap_or_else(|e| e.into_inner());
                    d.addrs.get(from).and_then(|a| *a)
                };
                addr.and_then(|addr| self.conn_to(from, addr))
            });
        match ci {
            Some(ci) => {
                self.enqueue_frame(ci, ack);
                self.maybe_flush(ci);
            }
            None => self.frames.give(ack),
        }
        self.shared.metrics.incr("acks");
    }

    /// See [`Transport::collect_acks`] (on the wire, acks arrive through
    /// the same pump as everything else).
    pub fn collect_acks(&mut self) {
        self.pump();
    }

    /// See [`Transport::unacked`].
    pub fn unacked(&self) -> usize {
        self.retained.len()
    }

    /// See [`Transport::peer_reset`]: sever connections to a crashed
    /// `pid` and settle every retained parcel addressed to it — those
    /// parcels died (unapplied) with the peer, so under ack-release
    /// accounting their mass leaves the in-flight account here and the
    /// recovered worker's reconstructed F covers the fluid itself. With
    /// eager local-commit accounting (`ack_release` off) the sweep only
    /// frees retention memory; mass was never held past the send. Called
    /// while this worker is paused at the recovery barrier, so no send
    /// can race the sweep.
    pub fn peer_reset(&mut self, pid: usize) {
        for c in self.conns.iter_mut() {
            if c.peer == Some(pid) {
                // unparsed frames from the dead peer are dropped with the
                // connection: a stale ACK would no-op (position-guarded)
                // and a stale MSG's fluid is covered by reconstruction
                c.alive = false;
                c.rbuf.clear();
            }
        }
        self.conns.retain(|c| c.alive || c.rbuf.has_complete_frame());
        let mut i = 0;
        while i < self.retained.len() {
            if self.retained[i].2 == pid {
                let (_, mass, _) = self.retained.swap_remove(i);
                self.shared.retained.fetch_sub(1, Ordering::Relaxed);
                if !self.local_commit {
                    self.shared.inflight.add(-mass);
                    self.shared.undelivered.fetch_sub(1, Ordering::AcqRel);
                }
            } else {
                i += 1;
            }
        }
    }

    /// See [`Transport::pending_delayed`]: everything readable is pumped
    /// first, and the count covers both the inbox (frames waiting out
    /// their latency) and every connection whose ring still holds a
    /// complete frame the per-pump fairness cap deferred — so a zero
    /// return really does mean no received frame is waiting anywhere at
    /// this instant. A deferred frame may turn out to be a control frame
    /// (ACK/HELLO), which errs conservative: quiescence checks observe a
    /// non-zero count until the next pump parses it, never a false zero.
    pub fn pending_delayed(&mut self) -> usize {
        self.pump();
        let deferred = self
            .conns
            .iter()
            .filter(|c| c.rbuf.has_complete_frame())
            .count();
        self.inbox.len() + deferred
    }

    /// See [`Transport::global_inflight`] (this process's account).
    pub fn global_inflight(&self) -> f64 {
        self.shared.inflight.get()
    }

    /// The fabric-wide metric set (shared by all endpoints of this hub).
    pub fn metrics(&self) -> Arc<MetricSet> {
        self.shared.metrics.clone()
    }
}

impl<T: WireCodec> Drop for WireEndpoint<T> {
    /// Best-effort goodbye: drive queued frames out (a peer may be
    /// waiting on a queued ACK) with a bounded retry loop, then send BYE
    /// so peers close promptly instead of discovering the EOF later.
    ///
    /// Deliberately does NOT release unapplied inbox mass in per-process
    /// mode and does not touch the loopback account for frames a peer
    /// may still commit — inventing a release here would let the monitor
    /// observe mass destruction. The retirement protocol (drain, then
    /// re-route) is what removes mass correctly; in the loopback harness
    /// the inbox is drained by `WorkerCore::finish` before the endpoint
    /// drops, and undrained mass after an abnormal exit keeps the
    /// monitor (correctly) above zero.
    fn drop(&mut self) {
        for _ in 0..50 {
            let mut queued = 0;
            for c in self.conns.iter_mut() {
                if c.alive {
                    c.flush(&self.shared.metrics, &mut self.frames);
                    queued += c.sendq.len();
                }
            }
            if queued == 0 {
                break;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
        let bye = [1u8, 0, 0, 0, KIND_BYE];
        for c in self.conns.iter_mut() {
            if c.alive {
                let _ = c.stream.write_all(&bye);
            }
        }
        // these parcels were delivered or lost with the sockets; nobody
        // will ack them. Under eager local-commit accounting this is
        // bookkeeping only (a count, not mass); under ack-release
        // accounting the sender still holds their mass, and a dying
        // endpoint settles its own books here — crash recovery's
        // reconstructed F covers the fluid (DESIGN.md §11)
        if !self.retained.is_empty() {
            self.shared
                .retained
                .fetch_sub(self.retained.len() as u64, Ordering::Relaxed);
            if !self.local_commit {
                for &(_, mass, _) in &self.retained {
                    self.shared.inflight.add(-mass);
                    self.shared.undelivered.fetch_sub(1, Ordering::AcqRel);
                }
            }
        }
    }
}

impl<T: WireCodec + Send + Clone + 'static> Transport<T> for WireEndpoint<T> {
    fn id(&self) -> usize {
        WireEndpoint::id(self)
    }
    fn peers(&self) -> usize {
        WireEndpoint::peers(self)
    }
    fn try_send(
        &mut self,
        to: usize,
        payload: T,
        mass: f64,
        approx_bytes: usize,
    ) -> std::result::Result<(), T> {
        WireEndpoint::try_send(self, to, payload, mass, approx_bytes)
    }
    fn try_recv_uncommitted(&mut self) -> Option<Received<T>> {
        WireEndpoint::try_recv_uncommitted(self)
    }
    fn commit(&mut self, from: usize, seq: u64, mass: f64) {
        WireEndpoint::commit(self, from, seq, mass)
    }
    fn collect_acks(&mut self) {
        WireEndpoint::collect_acks(self)
    }
    fn unacked(&self) -> usize {
        WireEndpoint::unacked(self)
    }
    fn pending_delayed(&mut self) -> usize {
        WireEndpoint::pending_delayed(self)
    }
    fn global_inflight(&self) -> f64 {
        WireEndpoint::global_inflight(self)
    }
    fn metrics(&self) -> Arc<MetricSet> {
        WireEndpoint::metrics(self)
    }
    fn flush(&mut self) {
        WireEndpoint::flush(self)
    }
    fn peer_reset(&mut self, pid: usize) {
        WireEndpoint::peer_reset(self, pid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal test payload: a tag byte plus a varint, exercising the
    /// strict no-trailing-bytes rule.
    #[derive(Clone, Debug, PartialEq)]
    struct Probe(u64);

    impl WireCodec for Probe {
        fn encode(&self, out: &mut Vec<u8>) {
            out.push(0x7E);
            write_varint(out, self.0);
        }
        fn decode(buf: &[u8]) -> Result<Self> {
            if buf.first() != Some(&0x7E) {
                return Err(corrupt("probe tag"));
            }
            let mut pos = 1;
            let v = read_varint(buf, &mut pos)?;
            if pos != buf.len() {
                return Err(corrupt("probe trailing bytes"));
            }
            Ok(Probe(v))
        }
    }

    fn pair() -> (WireEndpoint<Probe>, WireEndpoint<Probe>, WireHub<Probe>) {
        let hub = WireHub::<Probe>::loopback(&BusConfig::default(), &[]);
        let a = hub.add_endpoint(0).unwrap();
        let b = hub.add_endpoint(1).unwrap();
        (a, b, hub)
    }

    fn hub_with(policy: FlushPolicy) -> WireHub<Probe> {
        let cfg = BusConfig {
            flush: policy,
            ..BusConfig::default()
        };
        WireHub::<Probe>::loopback(&cfg, &[])
    }

    /// Drive `recv` until a message ripens or the deadline passes (TCP
    /// delivery needs a pump or two even on loopback).
    fn recv_within(ep: &mut WireEndpoint<Probe>, ms: u64) -> Option<Received<Probe>> {
        let deadline = Instant::now() + Duration::from_millis(ms);
        while Instant::now() < deadline {
            if let Some(r) = ep.try_recv_uncommitted() {
                return Some(r);
            }
            std::thread::yield_now();
        }
        None
    }

    #[test]
    fn ack_release_holds_mass_until_ack_returns() {
        let cfg = BusConfig {
            ack_release: true,
            ..BusConfig::default()
        };
        let hub = WireHub::<Probe>::loopback(&cfg, &[]);
        let mut a = hub.add_endpoint(0).unwrap();
        let mut b = hub.add_endpoint(1).unwrap();
        a.send(1, Probe(5), 0.5, 8).unwrap();
        a.flush();
        let got = recv_within(&mut b, 2000).expect("delivered");
        b.commit(got.from, got.seq, got.mass);
        b.flush();
        // the receiver's commit only emitted the ACK: the mass is still
        // on the account until the sender processes that ACK
        assert!((a.global_inflight() - 0.5).abs() < 1e-12);
        let deadline = Instant::now() + Duration::from_secs(2);
        while a.unacked() > 0 && Instant::now() < deadline {
            a.collect_acks();
            std::thread::yield_now();
        }
        assert_eq!(a.unacked(), 0, "ACK released the retention");
        let mon = hub.monitor();
        assert_eq!(mon.undelivered(), 0);
        assert_eq!(mon.inflight_or_zero(), 0.0);
    }

    #[test]
    fn peer_reset_releases_retention_to_dead_peer() {
        let cfg = BusConfig {
            ack_release: true,
            ..BusConfig::default()
        };
        let hub = WireHub::<Probe>::loopback(&cfg, &[]);
        let mut a = hub.add_endpoint(0).unwrap();
        let b = hub.add_endpoint(1).unwrap();
        a.send(1, Probe(9), 0.75, 8).unwrap();
        assert_eq!(a.unacked(), 1);
        let mon = hub.monitor();
        assert_eq!(mon.undelivered(), 1);
        // the peer dies before committing; its drop glue releases only
        // its OWN retained sends (none here) — the stranded parcel is
        // ours to settle
        drop(b);
        a.peer_reset(1);
        assert_eq!(a.unacked(), 0, "retention to the dead peer swept");
        assert_eq!(mon.undelivered(), 0);
        assert_eq!(mon.inflight_or_zero(), 0.0, "its mass released");
    }

    #[test]
    fn varint_round_trip_and_overflow() {
        for v in [0u64, 1, 127, 128, 16_383, 16_384, u64::MAX] {
            let mut buf = Vec::new();
            write_varint(&mut buf, v);
            let mut pos = 0;
            assert_eq!(read_varint(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
        // 11 continuation bytes can never be a u64
        let over = [0xFFu8; 11];
        let mut pos = 0;
        assert!(read_varint(&over, &mut pos).is_err());
        let mut pos = 0;
        assert!(read_varint(&[0x80], &mut pos).is_err(), "truncated");
    }

    #[test]
    fn zigzag_round_trip() {
        for v in [0i64, 1, -1, 63, -64, i64::MAX, i64::MIN] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        assert_eq!(zigzag(-1), 1, "small magnitudes stay small");
        assert_eq!(zigzag(1), 2);
    }

    #[test]
    fn delta_coords_round_trip_and_rejection() {
        let coords: Vec<u64> = vec![3, 4, 7, 100, 101, 9000];
        let mut buf = Vec::new();
        write_deltas(&mut buf, coords.iter().copied());
        assert!(buf.len() <= 9, "sorted columns compress to ~1 byte/coord");
        let mut pos = 0;
        assert_eq!(read_deltas(&buf, &mut pos, coords.len()).unwrap(), coords);
        // a count larger than the remaining bytes is rejected pre-alloc
        let mut pos = 0;
        assert!(read_deltas(&buf, &mut pos, usize::MAX).is_err());
        // a column decoding below zero is rejected
        let mut neg = Vec::new();
        write_deltas(&mut neg, [5u64].into_iter());
        write_varint(&mut neg, zigzag(-9)); // 5 - 9 < 0
        let mut pos = 0;
        assert!(read_deltas(&neg, &mut pos, 2).is_err());
    }

    #[test]
    fn in_place_delta_readers_match_and_reject_overflow() {
        let coords: Vec<u64> = vec![0, 2, 5, 1000, 1001];
        let mut buf = Vec::new();
        write_deltas(&mut buf, coords.iter().copied());
        let mut out32: Vec<u32> = Vec::new();
        let mut pos = 0;
        read_deltas_u32_into(&buf, &mut pos, coords.len(), &mut out32).unwrap();
        assert_eq!(out32, vec![0u32, 2, 5, 1000, 1001]);
        assert_eq!(pos, buf.len());
        let mut outus: Vec<usize> = Vec::new();
        let mut pos = 0;
        read_deltas_usize_into(&buf, &mut pos, coords.len(), &mut outus).unwrap();
        assert_eq!(outus, vec![0usize, 2, 5, 1000, 1001]);
        // recycled storage comes back cleared even when it had content
        let mut pos = 0;
        read_deltas_u32_into(&buf, &mut pos, 2, &mut out32).unwrap();
        assert_eq!(out32, vec![0u32, 2]);
        // a coordinate above u32::MAX is rejected by the u32 reader
        let mut big = Vec::new();
        write_deltas(&mut big, [1u64 << 33].into_iter());
        let mut pos = 0;
        let mut out: Vec<u32> = Vec::new();
        assert!(read_deltas_u32_into(&big, &mut pos, 1, &mut out).is_err());
        let mut pos = 0;
        let mut outus: Vec<usize> = Vec::new();
        assert!(read_deltas_usize_into(&big, &mut pos, 1, &mut outus).is_ok());
    }

    #[test]
    fn f64_slice_round_trip_and_truncation() {
        let vals = [0.0, -1.5, f64::MIN_POSITIVE, 1e300];
        let mut buf = Vec::new();
        write_f64_slice(&mut buf, &vals);
        let mut pos = 0;
        assert_eq!(read_f64_slice(&buf, &mut pos, 4).unwrap(), vals);
        let mut pos = 0;
        assert!(read_f64_slice(&buf, &mut pos, 5).is_err(), "truncated");
        // the in-place reader clears recycled storage first
        let mut out = vec![9.0; 3];
        let mut pos = 0;
        read_f64_slice_into(&buf, &mut pos, 2, &mut out).unwrap();
        assert_eq!(out, vec![0.0, -1.5]);
    }

    #[test]
    fn encode_msg_frame_matches_separate_body_framing() {
        // the in-place patched-prefix encode must be byte-identical to
        // the PR 6 shape: encode the body into its own Vec, then frame
        for (seq, mass, v) in [(0u64, 0.0f64, 0u64), (300, -2.5, 1 << 40), (7, 1e-12, 127)] {
            let probe = Probe(v);
            let mut body = Vec::new();
            body.push(KIND_MSG);
            write_varint(&mut body, seq);
            write_f64(&mut body, mass);
            probe.encode(&mut body);
            let mut expect = (body.len() as u32).to_le_bytes().to_vec();
            expect.extend_from_slice(&body);
            let mut frame = vec![0xAB; 3]; // stale content must not leak
            encode_msg_frame(&mut frame, seq, mass, &probe);
            assert_eq!(frame, expect);
        }
    }

    #[test]
    fn point_to_point_over_tcp() {
        let (mut a, mut b, _hub) = pair();
        let t: &mut dyn Transport<Probe> = &mut a;
        t.send(1, Probe(7), 0.5, 3).unwrap();
        a.flush();
        let got = recv_within(&mut b, 2000).expect("delivered");
        assert_eq!(got.payload, Probe(7));
        assert_eq!(got.from, 0);
        assert!((b.global_inflight() - 0.5).abs() < 1e-15, "uncommitted");
        b.commit(got.from, got.seq, got.mass);
        assert_eq!(b.global_inflight(), 0.0);
        let deadline = Instant::now() + Duration::from_secs(2);
        while a.unacked() > 0 && Instant::now() < deadline {
            b.collect_acks(); // drives b's deadline flush of the queued ACK
            a.collect_acks();
        }
        assert_eq!(a.unacked(), 0, "ack released retention");
        assert_eq!(a.metrics().get("msgs_sent"), 1);
        assert_eq!(a.metrics().get("msgs_recv"), 1);
        assert_eq!(a.metrics().get("acks"), 1);
        assert!(a.metrics().get("wire_writev_calls") >= 1);
        assert!(a.metrics().get("wire_bytes_sent") > 0);
        assert!(a.metrics().get("wire_bytes_recv") > 0);
    }

    #[test]
    fn removed_endpoint_fails_fast_and_returns_payload() {
        let (mut a, mut b, hub) = pair();
        // warm a connection so the per-send directory check, not the
        // dial, is what must refuse after removal
        a.try_send(1, Probe(1), 0.25, 1).unwrap();
        a.flush();
        let got = recv_within(&mut b, 2000).unwrap();
        b.commit(got.from, got.seq, got.mass);
        hub.remove_endpoint(1);
        assert!(!hub.is_live(1));
        assert_eq!(a.try_send(1, Probe(42), 1.5, 1), Err(Probe(42)));
        let deadline = Instant::now() + Duration::from_secs(2);
        while (a.unacked() > 0 || a.global_inflight() != 0.0) && Instant::now() < deadline {
            b.collect_acks();
            a.collect_acks();
        }
        assert_eq!(a.global_inflight(), 0.0);
        assert_eq!(a.unacked(), 0);
        assert_eq!(hub.monitor().undelivered(), 0);
    }

    #[test]
    fn latency_delays_tcp_delivery() {
        let cfg = BusConfig {
            latency: Some((Duration::from_millis(30), Duration::from_millis(40))),
            seed: 1,
            ..BusConfig::default()
        };
        let hub = WireHub::<Probe>::loopback(&cfg, &[]);
        let mut a = hub.add_endpoint(0).unwrap();
        let mut b = hub.add_endpoint(1).unwrap();
        a.try_send(1, Probe(9), 0.0, 1).unwrap();
        a.flush();
        // let the frame arrive, then confirm it ripens late
        let deadline = Instant::now() + Duration::from_secs(2);
        while b.pending_delayed() == 0 && Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(b.pending_delayed(), 1, "arrived but not ripe");
        assert!(b.try_recv_uncommitted().is_none(), "not before its delay");
        std::thread::sleep(Duration::from_millis(60));
        assert!(b.try_recv_uncommitted().is_some());
        assert_eq!(b.pending_delayed(), 0);
    }

    #[test]
    fn deadline_flush_fires_under_light_load() {
        let hub = hub_with(FlushPolicy {
            max_bytes: usize::MAX,
            max_frames: usize::MAX,
            deadline: Duration::from_millis(5),
        });
        let mut a = hub.add_endpoint(0).unwrap();
        let mut b = hub.add_endpoint(1).unwrap();
        a.try_send(1, Probe(7), 0.0, 1).unwrap();
        // no cap will ever trip; only a's deadline (observed by any pump
        // of a) pushes the parcel out
        let deadline = Instant::now() + Duration::from_secs(2);
        let mut got = None;
        while got.is_none() && Instant::now() < deadline {
            a.collect_acks();
            got = b.try_recv_uncommitted();
            std::thread::yield_now();
        }
        let got = got.expect("deadline flush delivered the parcel");
        assert_eq!(got.payload, Probe(7));
        assert!(a.metrics().get("wire_flush_deadline_hits") >= 1);
    }

    #[test]
    fn frame_cap_triggers_early_flush_in_one_writev() {
        let hub = hub_with(FlushPolicy {
            max_bytes: usize::MAX,
            max_frames: 4,
            deadline: Duration::from_secs(3600),
        });
        let mut a = hub.add_endpoint(0).unwrap();
        let mut b = hub.add_endpoint(1).unwrap();
        // warm the connection so HELLO is long gone from the queue
        a.try_send(1, Probe(0), 0.0, 1).unwrap();
        a.flush();
        let got = recv_within(&mut b, 2000).expect("warm-up");
        b.commit(got.from, got.seq, got.mass);
        let calls0 = a.metrics().get("wire_writev_calls");
        for i in 1..=3u64 {
            a.try_send(1, Probe(i), 0.0, 1).unwrap();
        }
        // three queued frames sit below the cap: nothing may arrive
        let t0 = Instant::now();
        while Instant::now() < t0 + Duration::from_millis(80) {
            assert!(b.try_recv_uncommitted().is_none(), "leaked before the cap");
            std::thread::yield_now();
        }
        // the fourth send trips max_frames: all four flush as one batch
        a.try_send(1, Probe(4), 0.0, 1).unwrap();
        let mut got = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(2);
        while got.len() < 4 && Instant::now() < deadline {
            if let Some(r) = b.try_recv_uncommitted() {
                got.push(r.payload.0);
            }
        }
        got.sort_unstable();
        assert_eq!(got, vec![1, 2, 3, 4]);
        assert_eq!(
            a.metrics().get("wire_writev_calls") - calls0,
            1,
            "one vectored write for the whole batch"
        );
        assert!(a.metrics().get("wire_frames_per_write") >= 4);
    }

    #[test]
    fn byte_cap_triggers_early_flush() {
        let hub = hub_with(FlushPolicy {
            max_bytes: 64,
            max_frames: usize::MAX,
            deadline: Duration::from_secs(3600),
        });
        let mut a = hub.add_endpoint(0).unwrap();
        let mut b = hub.add_endpoint(1).unwrap();
        // each Probe MSG frame is ~16 bytes; five sends cross the 64-byte
        // cap inside try_send, with no explicit or deadline flush
        for i in 1..=5u64 {
            a.try_send(1, Probe(i), 0.0, 1).unwrap();
        }
        let mut got = 0;
        let deadline = Instant::now() + Duration::from_secs(2);
        while got < 5 && Instant::now() < deadline {
            if b.try_recv_uncommitted().is_some() {
                got += 1;
            } else {
                // the tail below the cap still needs a's deadline… no:
                // drive a so any sub-cap remainder flushes too
                a.collect_acks();
                std::thread::yield_now();
            }
        }
        assert_eq!(got, 5, "byte cap must flush without explicit flushes");
    }

    #[test]
    fn fairness_cap_bounds_frames_per_pump_and_flood_still_drains() {
        let (mut a, mut b, _hub) = pair();
        // flood b from a raw socket: HELLO then 300 tiny MSG frames in
        // one concatenated writev-style blob
        let mut s = TcpStream::connect(b.local_addr()).unwrap();
        let mut blob = Vec::new();
        let mut hello = vec![KIND_HELLO];
        write_varint(&mut hello, 7);
        hello.push(PROTO_VERSION);
        blob.extend_from_slice(&(hello.len() as u32).to_le_bytes());
        blob.extend_from_slice(&hello);
        for i in 0..300u64 {
            let mut msg = vec![KIND_MSG];
            write_varint(&mut msg, i);
            write_f64(&mut msg, 0.0);
            Probe(i).encode(&mut msg);
            blob.extend_from_slice(&(msg.len() as u32).to_le_bytes());
            blob.extend_from_slice(&msg);
        }
        s.write_all(&blob).unwrap();
        s.flush().unwrap();
        std::thread::sleep(Duration::from_millis(50));
        // the first pump of b sees the whole backlog but may parse at
        // most PUMP_FRAMES_PER_CONN frames of it into the inbox ...
        let after_one = b.pending_delayed();
        assert!(after_one >= 1, "flood arrived");
        let parsed = b.inbox.len();
        assert!(
            parsed <= PUMP_FRAMES_PER_CONN,
            "one pump parsed {parsed} frames; the fairness cap is {PUMP_FRAMES_PER_CONN}"
        );
        // ... and pending_delayed still reports the deferred ring
        // backlog on top, so the cap cannot fake quiescence
        assert!(
            after_one > parsed,
            "pending_delayed ({after_one}) must count the complete frames \
             the fairness cap left in the ring beyond the {parsed} parsed"
        );
        // the flooded endpoint's send half is not starved: it can still
        // ship a parcel of its own mid-flood
        b.try_send(0, Probe(9), 0.0, 1).unwrap();
        b.flush();
        assert!(
            recv_within(&mut a, 2000).is_some(),
            "flooded endpoint must still send"
        );
        // and repeated pumps drain the whole flood
        let mut drained = 0;
        let deadline = Instant::now() + Duration::from_secs(5);
        while drained < 300 && Instant::now() < deadline {
            if b.try_recv_uncommitted().is_some() {
                drained += 1;
            } else {
                std::thread::yield_now();
            }
        }
        assert_eq!(drained, 300, "the flood must drain completely");
        drop(s);
    }

    /// A payload whose encoded frame can be made arbitrarily large
    /// (Probe is a single varint, which can't cross the read budget).
    #[derive(Debug, Clone, PartialEq)]
    struct Blob(Vec<u8>);
    impl WireCodec for Blob {
        fn encode(&self, out: &mut Vec<u8>) {
            write_varint(out, self.0.len() as u64);
            out.extend_from_slice(&self.0);
        }
        fn decode(buf: &[u8]) -> Result<Self> {
            let mut pos = 0;
            let n = read_varint(buf, &mut pos)? as usize;
            if buf.len() - pos != n {
                return Err(corrupt("blob length"));
            }
            Ok(Blob(buf[pos..].to_vec()))
        }
    }

    /// Regression: a single frame with `4+len > READ_BUDGET` must still
    /// arrive. Parsing only consumes complete frames, so a high-water
    /// pause that is not frame-aware stops reading such a frame midway
    /// and the connection wedges forever — the sender's in-flight mass
    /// never releases and the epoch/handoff protocol hangs.
    #[test]
    fn frame_larger_than_read_budget_is_received() {
        let hub = WireHub::<Blob>::loopback(&BusConfig::default(), &[]);
        let mut a = hub.add_endpoint(0).unwrap();
        let mut b = hub.add_endpoint(1).unwrap();
        let big = vec![0x5A; 3 * READ_BUDGET + 13];
        a.try_send(1, Blob(big.clone()), 1.0, big.len()).unwrap();
        a.flush();
        let deadline = Instant::now() + Duration::from_secs(10);
        let got = loop {
            if let Some(r) = b.try_recv_uncommitted() {
                break r;
            }
            assert!(
                Instant::now() < deadline,
                "oversized frame never completed: the high-water mark wedged the connection"
            );
            // keep draining a's send queue (partial writev progress) and
            // collecting the eventual ACK
            a.collect_acks();
            std::thread::yield_now();
        };
        assert_eq!(got.payload.0.len(), big.len());
        assert_eq!(got.payload.0, big);
        b.commit(got.from, got.seq, got.mass);
        let deadline = Instant::now() + Duration::from_secs(5);
        while a.unacked() > 0 && Instant::now() < deadline {
            b.collect_acks();
            a.collect_acks();
        }
        assert_eq!(a.unacked(), 0, "the oversized parcel must be acked");
        assert!(a.global_inflight().abs() < 1e-12);
    }

    #[test]
    fn corrupt_length_prefix_kills_connection_not_process() {
        let (_a, mut b, _hub) = pair();
        // dial b's listener raw and write a poisoned length prefix
        let mut s = TcpStream::connect(b.local_addr()).unwrap();
        s.write_all(&u32::MAX.to_le_bytes()).unwrap();
        s.write_all(&[0xAA; 64]).unwrap();
        s.flush().unwrap();
        // the poisoned connection must die without delivering anything:
        // pump until the accepted connection has been culled again
        let t0 = Instant::now();
        while Instant::now() < t0 + Duration::from_millis(300) {
            assert!(b.try_recv_uncommitted().is_none());
            std::thread::yield_now();
        }
        assert!(
            b.conns.is_empty(),
            "the corrupt connection must be culled"
        );
    }

    #[test]
    fn corrupt_payload_kills_connection() {
        let (_a, mut b, _hub) = pair();
        let mut s = TcpStream::connect(b.local_addr()).unwrap();
        // a well-formed HELLO for pid 5 ...
        let mut hello = vec![KIND_HELLO];
        write_varint(&mut hello, 5);
        hello.push(PROTO_VERSION);
        let mut frame = (hello.len() as u32).to_le_bytes().to_vec();
        frame.extend_from_slice(&hello);
        // ... then a MSG whose payload fails to decode
        let mut msg = vec![KIND_MSG];
        write_varint(&mut msg, 0);
        write_f64(&mut msg, 0.0);
        msg.extend_from_slice(&[0xFF, 0xFF, 0xFF]); // not a Probe
        frame.extend_from_slice(&(msg.len() as u32).to_le_bytes());
        frame.extend_from_slice(&msg);
        s.write_all(&frame).unwrap();
        s.flush().unwrap();
        let t0 = Instant::now();
        while Instant::now() < t0 + Duration::from_millis(300) {
            assert!(
                b.try_recv_uncommitted().is_none(),
                "a corrupt payload must never surface"
            );
            std::thread::yield_now();
        }
        assert!(
            !b.conns.iter().any(|c| c.peer == Some(5) && c.alive),
            "the connection carrying the corrupt payload must be dead"
        );
    }

    #[test]
    fn hub_slot_rules_match_the_bus() {
        let hub = WireHub::<Probe>::loopback(&BusConfig::default(), &[]);
        let _a = hub.add_endpoint(0).unwrap();
        let _b = hub.add_endpoint(1).unwrap();
        assert_eq!(hub.capacity(), 2);
        assert!(hub.add_endpoint(5).is_err(), "gaps rejected");
        assert!(hub.add_endpoint(1).is_err(), "occupied rejected");
        hub.remove_endpoint(1);
        assert!(!hub.is_live(1));
        let c = hub.add_endpoint(1).unwrap();
        assert_eq!(c.id(), 1);
        assert_eq!(hub.capacity(), 2, "slot reused, not appended");
    }

    #[test]
    fn remote_mode_releases_on_ack_receipt() {
        // two hubs = two accounting domains, as in process-per-worker
        let cfg = BusConfig::default();
        let bind = IpAddr::V4(Ipv4Addr::LOCALHOST);
        let hub_a = WireHub::<Probe>::remote(2, bind, &cfg, &[]);
        let hub_b = WireHub::<Probe>::remote(2, bind, &cfg, &[]);
        let mut a = hub_a.add_endpoint(0).unwrap();
        let mut b = hub_b.add_endpoint(1).unwrap();
        hub_a.set_peer_addr(1, b.local_addr());
        hub_b.set_peer_addr(0, a.local_addr());
        a.try_send(1, Probe(3), 0.75, 1).unwrap();
        assert!((a.global_inflight() - 0.75).abs() < 1e-15);
        assert_eq!(hub_a.monitor().undelivered(), 1);
        a.flush();
        let got = recv_within(&mut b, 2000).expect("delivered");
        // the receiving process never saw the increment, so commit must
        // not touch its account
        b.commit(got.from, got.seq, got.mass);
        assert_eq!(b.global_inflight(), 0.0);
        assert_eq!(hub_b.monitor().undelivered(), 0);
        // the sender releases when the ACK lands (b's deadline flush
        // pushes it out as soon as b is driven again)
        let deadline = Instant::now() + Duration::from_secs(2);
        while hub_a.monitor().undelivered() > 0 && Instant::now() < deadline {
            b.collect_acks();
            a.collect_acks();
        }
        assert_eq!(a.global_inflight(), 0.0);
        assert_eq!(hub_a.monitor().undelivered(), 0);
        assert_eq!(a.unacked(), 0);
    }

    #[test]
    fn ctrl_frame_round_trip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let got: Probe = read_ctrl_frame(&mut s).unwrap();
            write_ctrl_frame(&mut s, &Probe(got.0 + 1)).unwrap();
        });
        let mut s = TcpStream::connect(addr).unwrap();
        write_ctrl_frame(&mut s, &Probe(41)).unwrap();
        let back: Probe = read_ctrl_frame(&mut s).unwrap();
        assert_eq!(back, Probe(42));
        t.join().unwrap();
    }

    #[test]
    fn cross_thread_wire_traffic() {
        let (mut a, mut b, _hub) = pair();
        let t = std::thread::spawn(move || {
            for i in 0..100u64 {
                let mut payload = Probe(i);
                loop {
                    match a.try_send(1, payload, 0.01, 8) {
                        Ok(()) => break,
                        Err(p) => payload = p,
                    }
                }
            }
            // push any tail below the flush caps before handing a back
            a.flush();
            a
        });
        let mut seen = 0;
        let deadline = Instant::now() + Duration::from_secs(10);
        while seen < 100 && Instant::now() < deadline {
            if let Some(r) = b.try_recv_uncommitted() {
                b.commit(r.from, r.seq, r.mass);
                seen += 1;
            }
        }
        let mut a = t.join().unwrap();
        assert_eq!(seen, 100);
        let deadline = Instant::now() + Duration::from_secs(5);
        while a.unacked() > 0 && Instant::now() < deadline {
            b.collect_acks(); // b's queued ACKs flush on its deadline
            a.collect_acks();
        }
        assert_eq!(a.unacked(), 0);
        assert!(b.global_inflight().abs() < 1e-12);
    }
}
