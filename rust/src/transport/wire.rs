//! TCP wire transport: the multi-process implementation of [`Transport`].
//!
//! Frame-level protocol is specified normatively in DESIGN.md §8; this
//! module is one conforming implementation. In one sentence: every
//! connection carries length-prefixed frames; a `MSG` frame books its
//! fluid mass on the sender's in-flight account before the first byte is
//! written and keeps the parcel retained until the receiver's `ACK`
//! ("as TCP", §3.3 of the paper), so conservation accounting errs high,
//! never low, across process boundaries.
//!
//! Two deployment shapes share this code:
//!
//! * **loopback harness** ([`WireHub::loopback`]): all endpoints live in
//!   one process and share one accounting block, so the convergence
//!   monitor sees *exactly* the in-process bus semantics while every
//!   parcel genuinely rides a TCP socket — this is how the scenario
//!   matrix and the conservation fuzzer run unchanged over the wire
//!   (`DITER_TRANSPORT=wire`);
//! * **process-per-worker** ([`WireHub::remote`]): each process holds one
//!   endpoint plus a directory of peer socket addresses learned from the
//!   coordinator (`diter stream --listen/--connect`, see
//!   `coordinator::remote`). Accounting is then sender-side: mass is
//!   released when the `ACK` arrives, not when the remote receiver
//!   commits, which still errs high and still reaches zero at
//!   quiescence.
//!
//! The encoding helpers ([`write_varint`], [`zigzag`],
//! [`write_deltas`], …) are exported because the message-type codecs
//! (`coordinator::codec`) and the framing tests are built from them.

use std::collections::BinaryHeap;
use std::io::{ErrorKind, Read, Write};
use std::marker::PhantomData;
use std::net::{IpAddr, Ipv4Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use super::{
    BusConfig, BusMonitor, Envelope, Received, Ripening, Shared, Transport, TransportHub,
    BUS_METRICS,
};
use crate::error::{DiterError, Result};
use crate::metrics::MetricSet;
use crate::perf::Arena;
use crate::prng::Xoshiro256pp;
use crate::transport::AtomicF64;

/// Wire protocol version carried by every `HELLO` (DESIGN.md §8.2).
pub const PROTO_VERSION: u8 = 1;

/// Hard upper bound on a frame body (corruption guard): a length prefix
/// above this is treated as a corrupt stream, not an allocation request.
pub const MAX_FRAME: usize = 256 << 20;

// Frame kinds (first byte of every frame body) — DESIGN.md §8.2.
const KIND_HELLO: u8 = 0x01;
const KIND_MSG: u8 = 0x02;
const KIND_ACK: u8 = 0x03;
const KIND_BYE: u8 = 0x04;

/// Construct the canonical corrupt-frame error.
pub fn corrupt(what: &str) -> DiterError {
    DiterError::Transport(format!("corrupt frame: {what}"))
}

// ---------------------------------------------------------------------------
// Encoding primitives (DESIGN.md §8.1)
// ---------------------------------------------------------------------------

/// Append `v` as an LEB128 varint (7 data bits per byte, high bit = more).
pub fn write_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Read an LEB128 varint at `*pos`, advancing it. Rejects truncation and
/// encodings that overflow 64 bits.
pub fn read_varint(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v: u64 = 0;
    let mut shift: u32 = 0;
    loop {
        let Some(&byte) = buf.get(*pos) else {
            return Err(corrupt("varint truncated"));
        };
        *pos += 1;
        if shift > 63 || (shift == 63 && (byte & 0x7f) > 1) {
            return Err(corrupt("varint overflows u64"));
        }
        v |= u64::from(byte & 0x7f) << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// Zigzag-map a signed delta onto an unsigned varint-friendly value
/// (small magnitudes of either sign become small numbers).
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Inverse of [`zigzag`].
pub fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

/// Append an `f64` in IEEE-754 little-endian (8 bytes, exact).
pub fn write_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Read an `f64` at `*pos`, advancing it.
pub fn read_f64(buf: &[u8], pos: &mut usize) -> Result<f64> {
    let end = pos.checked_add(8).ok_or_else(|| corrupt("f64 offset"))?;
    let Some(bytes) = buf.get(*pos..end) else {
        return Err(corrupt("f64 truncated"));
    };
    *pos = end;
    Ok(f64::from_le_bytes(bytes.try_into().expect("8-byte slice")))
}

/// Append `vals` back to back as little-endian `f64`s (the SoA mass
/// column of a fluid parcel: one bulk copy, no per-entry framing).
pub fn write_f64_slice(out: &mut Vec<u8>, vals: &[f64]) {
    out.reserve(vals.len() * 8);
    for &v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Read `count` little-endian `f64`s at `*pos`, advancing it. The count
/// is validated against the remaining buffer *before* allocating.
pub fn read_f64_slice(buf: &[u8], pos: &mut usize, count: usize) -> Result<Vec<f64>> {
    if buf.len().saturating_sub(*pos) < count.saturating_mul(8) {
        return Err(corrupt("f64 column truncated"));
    }
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        out.push(read_f64(buf, pos)?);
    }
    Ok(out)
}

/// Append a coordinate column delta-encoded (DESIGN.md §8.1): the first
/// value is written absolutely, each subsequent value as the zigzag
/// difference from its predecessor — sorted SoA columns (fluid parcels,
/// halo slices) collapse to ~1 byte per coordinate.
pub fn write_deltas(out: &mut Vec<u8>, vals: impl IntoIterator<Item = u64>) {
    let mut prev: i64 = 0;
    for v in vals {
        let v = v as i64;
        write_varint(out, zigzag(v.wrapping_sub(prev)));
        prev = v;
    }
}

/// Read a `count`-entry delta-encoded coordinate column at `*pos`,
/// advancing it. Rejects columns that decode to a negative coordinate
/// and counts that cannot fit in the remaining buffer.
pub fn read_deltas(buf: &[u8], pos: &mut usize, count: usize) -> Result<Vec<u64>> {
    // every delta is at least one byte, so an honest count is bounded
    // by the remaining bytes — reject before allocating
    if count > buf.len().saturating_sub(*pos) {
        return Err(corrupt("coordinate count exceeds frame"));
    }
    let mut out = Vec::with_capacity(count);
    let mut prev: i64 = 0;
    for _ in 0..count {
        let v = prev
            .checked_add(unzigzag(read_varint(buf, pos)?))
            .ok_or_else(|| corrupt("coordinate delta overflow"))?;
        if v < 0 {
            return Err(corrupt("negative coordinate"));
        }
        out.push(v as u64);
        prev = v;
    }
    Ok(out)
}

/// A message type that can ride the wire. Implemented by the
/// coordinator's `WorkerMsg` (see `coordinator::codec`) and by the
/// control-plane messages of remote mode.
///
/// `decode` must be the exact inverse of `encode` and must consume the
/// whole buffer — trailing bytes are a framing error, which is what the
/// corrupt-frame tests pin down.
pub trait WireCodec: Sized {
    /// Append this message's payload encoding to `out`.
    fn encode(&self, out: &mut Vec<u8>);
    /// Decode a payload produced by [`WireCodec::encode`].
    fn decode(buf: &[u8]) -> Result<Self>;
}

// ---------------------------------------------------------------------------
// Blocking control-plane framing (used by coordinator::remote)
// ---------------------------------------------------------------------------

/// Write one `[u32 length][payload]` frame of `msg` to a blocking stream
/// and flush it — the control-plane counterpart of the non-blocking data
/// path (remote mode's JOIN/ASSIGN/REPORT traffic).
pub fn write_ctrl_frame<T: WireCodec>(stream: &mut TcpStream, msg: &T) -> Result<()> {
    let mut body = Vec::new();
    msg.encode(&mut body);
    let mut frame = Vec::with_capacity(body.len() + 4);
    frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
    frame.extend_from_slice(&body);
    stream.write_all(&frame)?;
    stream.flush()?;
    Ok(())
}

/// Read one `[u32 length][payload]` frame of `T` from a blocking stream.
pub fn read_ctrl_frame<T: WireCodec>(stream: &mut TcpStream) -> Result<T> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len) as usize;
    if len == 0 || len > MAX_FRAME {
        return Err(corrupt("control frame length"));
    }
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    T::decode(&body)
}

// ---------------------------------------------------------------------------
// The hub: directory of peer addresses + shared accounting
// ---------------------------------------------------------------------------

/// Address directory: slot `k` holds PID k's listening address, `None`
/// for a retired (or never-spawned) endpoint. The wire analogue of the
/// bus's channel directory, with the same locking discipline: sends
/// resolve (and write) under a read lock, removal takes the write lock,
/// so removal strictly orders with in-progress sends.
struct WireDirectory {
    addrs: Vec<Option<SocketAddr>>,
}

/// A shared handle onto the wire fabric that can register and deregister
/// endpoints while workers are running — the TCP implementation of
/// [`TransportHub`]. Cloneable; all clones see the same directory.
pub struct WireHub<T> {
    dir: Arc<RwLock<WireDirectory>>,
    shared: Arc<Shared>,
    latency: Option<(Duration, Duration)>,
    seed: u64,
    bind_ip: IpAddr,
    /// true in the loopback harness: all endpoints share this process's
    /// accounting block, so a receiver commit settles the account
    /// directly (exact bus semantics). false per-process: commits only
    /// emit the ACK and the *sender* releases on ACK receipt.
    local_commit: bool,
    _msg: PhantomData<fn() -> T>,
}

impl<T> Clone for WireHub<T> {
    fn clone(&self) -> Self {
        WireHub {
            dir: self.dir.clone(),
            shared: self.shared.clone(),
            latency: self.latency,
            seed: self.seed,
            bind_ip: self.bind_ip,
            local_commit: self.local_commit,
            _msg: PhantomData,
        }
    }
}

fn new_shared(extra: &[&'static str]) -> Arc<Shared> {
    let names: Vec<&'static str> = BUS_METRICS.iter().chain(extra).copied().collect();
    Arc::new(Shared {
        inflight: AtomicF64::new(0.0),
        retained: AtomicU64::new(0),
        undelivered: AtomicU64::new(0),
        metrics: Arc::new(MetricSet::new(&names)),
    })
}

impl<T: WireCodec + Send + 'static> WireHub<T> {
    /// An empty single-process hub on `127.0.0.1`: every
    /// [`WireHub::add_endpoint`] binds a fresh loopback listener, and
    /// commits settle the shared account exactly like the in-process
    /// bus. This is the harness behind `DITER_TRANSPORT=wire`.
    pub fn loopback(cfg: &BusConfig, extra: &[&'static str]) -> WireHub<T> {
        WireHub {
            dir: Arc::new(RwLock::new(WireDirectory { addrs: Vec::new() })),
            shared: new_shared(extra),
            latency: cfg.latency,
            seed: cfg.seed,
            bind_ip: IpAddr::V4(Ipv4Addr::LOCALHOST),
            local_commit: true,
            _msg: PhantomData,
        }
    }

    /// A process-per-worker hub: `width` directory slots (filled in by
    /// [`WireHub::set_peer_addr`] as the coordinator's PEERS table
    /// arrives), local endpoints bound on `bind_ip`, and sender-side
    /// accounting (in-flight mass is released on ACK receipt).
    pub fn remote(width: usize, bind_ip: IpAddr, cfg: &BusConfig, extra: &[&'static str]) -> WireHub<T> {
        WireHub {
            dir: Arc::new(RwLock::new(WireDirectory {
                addrs: vec![None; width],
            })),
            shared: new_shared(extra),
            latency: cfg.latency,
            seed: cfg.seed,
            bind_ip,
            local_commit: false,
            _msg: PhantomData,
        }
    }

    /// Register a new endpoint at slot `id`, binding a listener on an
    /// OS-assigned port. Slot rules match the bus exactly: a vacant
    /// (retired) slot or exactly one past the current end; occupied
    /// slots and gaps are errors.
    pub fn add_endpoint(&self, id: usize) -> Result<WireEndpoint<T>> {
        let listener = TcpListener::bind((self.bind_ip, 0))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        {
            let mut d = self.dir.write().unwrap_or_else(|e| e.into_inner());
            if id > d.addrs.len() {
                return Err(DiterError::Transport(format!(
                    "endpoint {id} would leave a gap (directory holds {})",
                    d.addrs.len()
                )));
            }
            if id < d.addrs.len() && d.addrs[id].is_some() {
                return Err(DiterError::Transport(format!("endpoint {id} already live")));
            }
            if id == d.addrs.len() {
                d.addrs.push(Some(addr));
            } else {
                d.addrs[id] = Some(addr);
            }
        }
        Ok(WireEndpoint {
            id,
            listener,
            local_addr: addr,
            dir: self.dir.clone(),
            shared: self.shared.clone(),
            conns: Vec::new(),
            inbox: BinaryHeap::new(),
            retained: Vec::new(),
            next_seq: 0,
            latency: self.latency,
            rng: Xoshiro256pp::seed_from_u64(self.seed ^ (id as u64).wrapping_mul(0x9E3779B9)),
            local_commit: self.local_commit,
            scratch: Arena::new(FRAME_POOL),
        })
    }

    /// Install a *remote* peer's listening address in slot `id` (growing
    /// the directory if needed) — remote mode's PEERS table. Sends to
    /// `id` dial this address.
    pub fn set_peer_addr(&self, id: usize, addr: SocketAddr) {
        let mut d = self.dir.write().unwrap_or_else(|e| e.into_inner());
        if id >= d.addrs.len() {
            d.addrs.resize(id + 1, None);
        }
        d.addrs[id] = Some(addr);
    }

    /// Deregister slot `id`: subsequent sends to it fail fast at the
    /// sender, which re-routes the fluid. Because each send resolves the
    /// slot (and writes its frame) under the directory read lock, every
    /// frame accepted before this write-locked removal returns is
    /// already in the retiree's socket buffer, where its final drain
    /// will find it.
    pub fn remove_endpoint(&self, id: usize) {
        let mut d = self.dir.write().unwrap_or_else(|e| e.into_inner());
        if id < d.addrs.len() {
            d.addrs[id] = None;
        }
    }

    /// Directory width (live + vacant slots).
    pub fn capacity(&self) -> usize {
        self.dir.read().unwrap_or_else(|e| e.into_inner()).addrs.len()
    }

    /// Whether slot `id` currently has a live (addressable) endpoint.
    pub fn is_live(&self, id: usize) -> bool {
        let d = self.dir.read().unwrap_or_else(|e| e.into_inner());
        d.addrs.get(id).is_some_and(Option::is_some)
    }

    /// A monitor handle onto this process's conservation accounting.
    pub fn monitor(&self) -> BusMonitor {
        BusMonitor {
            shared: self.shared.clone(),
        }
    }

    /// The fabric-wide metric set.
    pub fn metrics(&self) -> Arc<MetricSet> {
        self.shared.metrics.clone()
    }
}

impl<T: WireCodec + Send + Clone + 'static> TransportHub<T> for WireHub<T> {
    fn add_endpoint(&self, id: usize) -> Result<Box<dyn Transport<T>>> {
        Ok(Box::new(WireHub::add_endpoint(self, id)?))
    }
    fn remove_endpoint(&self, id: usize) {
        WireHub::remove_endpoint(self, id)
    }
    fn capacity(&self) -> usize {
        WireHub::capacity(self)
    }
    fn is_live(&self, id: usize) -> bool {
        WireHub::is_live(self, id)
    }
    fn monitor(&self) -> BusMonitor {
        WireHub::monitor(self)
    }
    fn metrics(&self) -> Arc<MetricSet> {
        WireHub::metrics(self)
    }
}

// ---------------------------------------------------------------------------
// The endpoint
// ---------------------------------------------------------------------------

/// One live connection (inbound-accepted or outbound-dialed; the
/// protocol is full duplex, so either kind carries traffic both ways).
struct Conn {
    stream: TcpStream,
    /// peer PID: set at dial time (outbound) or by the peer's HELLO
    /// (inbound); frames on an unidentified connection are a protocol
    /// error except HELLO itself
    peer: Option<usize>,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    alive: bool,
}

/// One PID's wire endpoint: a nonblocking listener plus its connection
/// set, owned by exactly one worker (thread or process). The TCP
/// implementation of [`Transport`].
pub struct WireEndpoint<T: WireCodec> {
    id: usize,
    listener: TcpListener,
    local_addr: SocketAddr,
    dir: Arc<RwLock<WireDirectory>>,
    shared: Arc<Shared>,
    conns: Vec<Conn>,
    /// decoded MSG frames ripening through latency injection (applied on
    /// the receive side here; protocol-equivalent to the bus's
    /// sender-side stamping)
    inbox: BinaryHeap<Ripening<T>>,
    /// parcels retained until acked (seq → mass); "as TCP"
    retained: Vec<(u64, f64)>,
    next_seq: u64,
    latency: Option<(Duration, Duration)>,
    rng: Xoshiro256pp,
    local_commit: bool,
    /// recycled frame/body buffers: the encoder takes one per
    /// MSG/ACK/inbound frame and gives it back as soon as the bytes are
    /// in a connection buffer, so steady-state framing allocates nothing
    scratch: Arena<u8>,
}

/// Frame buffers pooled per endpoint — MSG body, ACK, and inbound frame
/// all share the arena, and each is returned before the next is taken.
const FRAME_POOL: usize = 4;

impl<T: WireCodec + Send + 'static> WireEndpoint<T> {
    /// The address this endpoint's listener is bound to (advertised to
    /// peers through the directory, or remote mode's JOINED message).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// This endpoint's PID.
    pub fn id(&self) -> usize {
        self.id
    }

    /// Directory width (live + vacant slots).
    pub fn peers(&self) -> usize {
        self.dir.read().unwrap_or_else(|e| e.into_inner()).addrs.len()
    }

    fn sample_delay(&mut self) -> Duration {
        match self.latency {
            None => Duration::ZERO,
            Some((lo, hi)) => {
                let span = hi.saturating_sub(lo);
                lo + Duration::from_nanos((self.rng.next_f64() * span.as_nanos() as f64) as u64)
            }
        }
    }

    /// Accept pending connections, flush pending writes, read and parse
    /// everything readable, and dispatch complete frames. Every
    /// non-blocking entry point starts with a pump, so progress needs no
    /// background thread.
    fn pump(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let _ = stream.set_nonblocking(true);
                    let _ = stream.set_nodelay(true);
                    self.conns.push(Conn {
                        stream,
                        peer: None,
                        rbuf: Vec::new(),
                        wbuf: Vec::new(),
                        alive: true,
                    });
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
        let mut scratch = [0u8; 16 * 1024];
        for ci in 0..self.conns.len() {
            let c = &mut self.conns[ci];
            if !c.alive {
                continue;
            }
            let _ = Self::flush_wbuf(c);
            loop {
                match c.stream.read(&mut scratch) {
                    Ok(0) => {
                        // EOF: no more bytes will come, but frames already
                        // in rbuf still get parsed below
                        c.alive = false;
                        break;
                    }
                    Ok(n) => c.rbuf.extend_from_slice(&scratch[..n]),
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        c.alive = false;
                        break;
                    }
                }
            }
        }
        for ci in 0..self.conns.len() {
            loop {
                let len = {
                    let c = &mut self.conns[ci];
                    if c.rbuf.len() < 4 {
                        break;
                    }
                    let len =
                        u32::from_le_bytes(c.rbuf[..4].try_into().expect("4-byte slice")) as usize;
                    if len == 0 || len > MAX_FRAME {
                        c.alive = false; // corrupt stream: stop parsing it
                        break;
                    }
                    if c.rbuf.len() < 4 + len {
                        break;
                    }
                    len
                };
                // copy out through a recycled buffer (dispatch needs &mut
                // self, so the frame cannot stay borrowed from rbuf) —
                // per-frame allocation becomes a per-frame arena cycle
                let mut frame = self.scratch.take();
                frame.extend_from_slice(&self.conns[ci].rbuf[4..4 + len]);
                self.conns[ci].rbuf.drain(..4 + len);
                self.dispatch(ci, &frame);
                self.scratch.give(frame);
            }
        }
        // complete frames were already dispatched above, so a dead
        // connection has nothing left to contribute
        self.conns.retain(|c| c.alive);
    }

    /// Handle one complete frame received on connection `ci`.
    fn dispatch(&mut self, ci: usize, frame: &[u8]) {
        let kill = |conns: &mut Vec<Conn>, ci: usize| conns[ci].alive = false;
        let Some(&kind) = frame.first() else {
            return kill(&mut self.conns, ci);
        };
        let body = &frame[1..];
        match kind {
            KIND_HELLO => {
                let mut pos = 0;
                let Ok(pid) = read_varint(body, &mut pos) else {
                    return kill(&mut self.conns, ci);
                };
                if body.get(pos).copied() != Some(PROTO_VERSION) {
                    return kill(&mut self.conns, ci);
                }
                self.conns[ci].peer = Some(pid as usize);
            }
            KIND_MSG => {
                // sender attribution comes from the connection's HELLO
                let Some(from) = self.conns[ci].peer else {
                    return kill(&mut self.conns, ci);
                };
                let mut pos = 0;
                let decoded = read_varint(body, &mut pos).and_then(|seq| {
                    let mass = read_f64(body, &mut pos)?;
                    let payload = T::decode(&body[pos..])?;
                    Ok((seq, mass, payload))
                });
                let Ok((seq, mass, payload)) = decoded else {
                    return kill(&mut self.conns, ci);
                };
                let ready_at = Instant::now() + self.sample_delay();
                self.inbox.push(Ripening(Envelope {
                    from,
                    seq,
                    mass,
                    ready_at,
                    payload,
                }));
            }
            KIND_ACK => {
                let mut pos = 0;
                let Ok(seq) = read_varint(body, &mut pos) else {
                    return kill(&mut self.conns, ci);
                };
                if let Some(p) = self.retained.iter().position(|&(s, _)| s == seq) {
                    let (_, mass) = self.retained.swap_remove(p);
                    self.shared.retained.fetch_sub(1, Ordering::Relaxed);
                    if !self.local_commit {
                        // sender-side release: the remote receiver has
                        // applied the parcel, its mass leaves this
                        // process's in-flight account now
                        self.shared.inflight.add(-mass);
                        self.shared.undelivered.fetch_sub(1, Ordering::AcqRel);
                    }
                }
            }
            KIND_BYE => kill(&mut self.conns, ci),
            _ => kill(&mut self.conns, ci),
        }
    }

    /// Flush as much of `wbuf` as the socket accepts right now.
    fn flush_wbuf(c: &mut Conn) -> std::io::Result<()> {
        while !c.wbuf.is_empty() {
            match c.stream.write(&c.wbuf) {
                Ok(0) => {
                    c.alive = false;
                    return Err(std::io::Error::new(ErrorKind::WriteZero, "peer closed"));
                }
                Ok(n) => {
                    c.wbuf.drain(..n);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(()), // resumed by a later pump
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    c.alive = false;
                    return Err(e);
                }
            }
        }
        Ok(())
    }

    /// Queue `[len][body]` on connection `ci` and try to flush.
    fn write_frame(&mut self, ci: usize, body: &[u8]) -> std::io::Result<()> {
        let c = &mut self.conns[ci];
        if !c.alive {
            return Err(std::io::Error::new(ErrorKind::NotConnected, "dead connection"));
        }
        c.wbuf.extend_from_slice(&(body.len() as u32).to_le_bytes());
        c.wbuf.extend_from_slice(body);
        Self::flush_wbuf(c)
    }

    /// A live connection to PID `to`, dialing `addr` if none exists.
    /// Outbound connections introduce themselves with HELLO first, so
    /// the peer can attribute every later frame.
    fn conn_to(&mut self, to: usize, addr: SocketAddr) -> Option<usize> {
        if let Some(ci) = self.conns.iter().position(|c| c.alive && c.peer == Some(to)) {
            return Some(ci);
        }
        let stream = TcpStream::connect_timeout(&addr, Duration::from_secs(5)).ok()?;
        let _ = stream.set_nodelay(true);
        stream.set_nonblocking(true).ok()?;
        let ci = self.conns.len();
        self.conns.push(Conn {
            stream,
            peer: Some(to),
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            alive: true,
        });
        let mut hello = Vec::with_capacity(11);
        hello.push(KIND_HELLO);
        write_varint(&mut hello, self.id as u64);
        hello.push(PROTO_VERSION);
        if self.write_frame(ci, &hello).is_err() {
            return None;
        }
        Some(ci)
    }

    /// See [`Transport::try_send`]. The destination address is resolved
    /// — and the frame queued — under the directory read lock on *every*
    /// send, so [`WireHub::remove_endpoint`] (a write) strictly orders
    /// with in-progress sends exactly like the bus: after removal
    /// returns, every accepted frame is already in the retiree's socket
    /// buffer and every later send fails fast and re-routes. A cached
    /// connection is deliberately *not* trusted across that boundary.
    pub fn try_send(
        &mut self,
        to: usize,
        payload: T,
        mass: f64,
        approx_bytes: usize,
    ) -> std::result::Result<(), T> {
        self.pump();
        let dir = self.dir.clone();
        let d = dir.read().unwrap_or_else(|e| e.into_inner());
        let Some(addr) = d.addrs.get(to).and_then(|a| *a) else {
            return Err(payload);
        };
        let Some(ci) = self.conn_to(to, addr) else {
            return Err(payload);
        };
        let seq = self.next_seq;
        // encode over a recycled buffer; returned to the arena once the
        // bytes sit in the connection's write buffer
        let mut body = self.scratch.take();
        body.reserve(approx_bytes + 16);
        body.push(KIND_MSG);
        write_varint(&mut body, seq);
        write_f64(&mut body, mass);
        payload.encode(&mut body);
        // in-flight accounting BEFORE the write so the monitor can never
        // observe fluid vanishing; `undelivered` first (see the bus) so
        // the float accumulator is authoritative only while it is >0
        self.shared.undelivered.fetch_add(1, Ordering::AcqRel);
        let now_inflight = self.shared.inflight.add(mass);
        self.shared
            .metrics
            .max("inflight_peak_ppm", (now_inflight * 1e6) as u64);
        if self.write_frame(ci, &body).is_err() {
            // connection died before the frame was fully written: undo —
            // the fluid never left the caller, who re-routes it
            self.shared.inflight.add(-mass);
            self.shared.undelivered.fetch_sub(1, Ordering::AcqRel);
            self.scratch.give(body);
            return Err(payload);
        }
        drop(d);
        self.next_seq += 1;
        self.retained.push((seq, mass));
        self.shared.retained.fetch_add(1, Ordering::Relaxed);
        self.shared.metrics.incr("msgs_sent");
        self.shared.metrics.add("bytes_sent", (body.len() + 4) as u64);
        self.scratch.give(body);
        Ok(())
    }

    /// See [`Transport::try_recv_uncommitted`].
    pub fn try_recv_uncommitted(&mut self) -> Option<Received<T>> {
        self.pump();
        let now = Instant::now();
        if let Some(top) = self.inbox.peek() {
            if top.0.ready_at <= now {
                let env = self.inbox.pop().expect("peeked").0;
                self.shared.metrics.incr("msgs_recv");
                return Some(Received {
                    from: env.from,
                    seq: env.seq,
                    mass: env.mass,
                    payload: env.payload,
                });
            }
        }
        None
    }

    /// See [`Transport::commit`]. In the loopback harness this settles
    /// the shared account directly (exact bus semantics) and the ACK
    /// only releases the sender's retention; per-process, the ACK *is*
    /// the release — the sender's accounting drops when it arrives.
    pub fn commit(&mut self, from: usize, seq: u64, mass: f64) {
        if self.local_commit {
            self.shared.inflight.add(-mass);
            self.shared.undelivered.fetch_sub(1, Ordering::AcqRel);
        }
        let mut ack = self.scratch.take();
        ack.push(KIND_ACK);
        write_varint(&mut ack, seq);
        if let Some(ci) = self.conns.iter().position(|c| c.alive && c.peer == Some(from)) {
            let _ = self.write_frame(ci, &ack);
        } else {
            // no live connection back: dial, unless the sender retired —
            // then the ack is dropped, its retention list died with it
            let addr = {
                let dir = self.dir.clone();
                let d = dir.read().unwrap_or_else(|e| e.into_inner());
                d.addrs.get(from).and_then(|a| *a)
            };
            if let Some(addr) = addr {
                if let Some(ci) = self.conn_to(from, addr) {
                    let _ = self.write_frame(ci, &ack);
                }
            }
        }
        self.scratch.give(ack);
        self.shared.metrics.incr("acks");
    }

    /// See [`Transport::collect_acks`] (on the wire, acks arrive through
    /// the same pump as everything else).
    pub fn collect_acks(&mut self) {
        self.pump();
    }

    /// See [`Transport::unacked`].
    pub fn unacked(&self) -> usize {
        self.retained.len()
    }

    /// See [`Transport::pending_delayed`]: everything readable is pumped
    /// into the inbox first, so a zero return means no received frame is
    /// waiting out its latency at this instant.
    pub fn pending_delayed(&mut self) -> usize {
        self.pump();
        self.inbox.len()
    }

    /// See [`Transport::global_inflight`] (this process's account).
    pub fn global_inflight(&self) -> f64 {
        self.shared.inflight.get()
    }

    /// The fabric-wide metric set (shared by all endpoints of this hub).
    pub fn metrics(&self) -> Arc<MetricSet> {
        self.shared.metrics.clone()
    }
}

impl<T: WireCodec> Drop for WireEndpoint<T> {
    /// Best-effort goodbye: flush buffered frames (a peer may be waiting
    /// on a buffered ACK) and send BYE so peers close promptly instead
    /// of discovering the EOF later.
    ///
    /// Deliberately does NOT release unapplied inbox mass in per-process
    /// mode and does not touch the loopback account for frames a peer
    /// may still commit — inventing a release here would let the monitor
    /// observe mass destruction. The retirement protocol (drain, then
    /// re-route) is what removes mass correctly; in the loopback harness
    /// the inbox is drained by `WorkerCore::finish` before the endpoint
    /// drops, and undrained mass after an abnormal exit keeps the
    /// monitor (correctly) above zero.
    fn drop(&mut self) {
        let bye = [1u8, 0, 0, 0, KIND_BYE];
        for c in self.conns.iter_mut() {
            if c.alive {
                let _ = Self::flush_wbuf(c);
                let _ = c.stream.write_all(&bye);
            }
        }
        // retention bookkeeping only (a count, not mass): these parcels
        // were delivered or lost with the sockets; nobody will ack them
        if !self.retained.is_empty() {
            self.shared
                .retained
                .fetch_sub(self.retained.len() as u64, Ordering::Relaxed);
        }
    }
}

impl<T: WireCodec + Send + Clone + 'static> Transport<T> for WireEndpoint<T> {
    fn id(&self) -> usize {
        WireEndpoint::id(self)
    }
    fn peers(&self) -> usize {
        WireEndpoint::peers(self)
    }
    fn try_send(
        &mut self,
        to: usize,
        payload: T,
        mass: f64,
        approx_bytes: usize,
    ) -> std::result::Result<(), T> {
        WireEndpoint::try_send(self, to, payload, mass, approx_bytes)
    }
    fn try_recv_uncommitted(&mut self) -> Option<Received<T>> {
        WireEndpoint::try_recv_uncommitted(self)
    }
    fn commit(&mut self, from: usize, seq: u64, mass: f64) {
        WireEndpoint::commit(self, from, seq, mass)
    }
    fn collect_acks(&mut self) {
        WireEndpoint::collect_acks(self)
    }
    fn unacked(&self) -> usize {
        WireEndpoint::unacked(self)
    }
    fn pending_delayed(&mut self) -> usize {
        WireEndpoint::pending_delayed(self)
    }
    fn global_inflight(&self) -> f64 {
        WireEndpoint::global_inflight(self)
    }
    fn metrics(&self) -> Arc<MetricSet> {
        WireEndpoint::metrics(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal test payload: a tag byte plus a varint, exercising the
    /// strict no-trailing-bytes rule.
    #[derive(Clone, Debug, PartialEq)]
    struct Probe(u64);

    impl WireCodec for Probe {
        fn encode(&self, out: &mut Vec<u8>) {
            out.push(0x7E);
            write_varint(out, self.0);
        }
        fn decode(buf: &[u8]) -> Result<Self> {
            if buf.first() != Some(&0x7E) {
                return Err(corrupt("probe tag"));
            }
            let mut pos = 1;
            let v = read_varint(buf, &mut pos)?;
            if pos != buf.len() {
                return Err(corrupt("probe trailing bytes"));
            }
            Ok(Probe(v))
        }
    }

    fn pair() -> (WireEndpoint<Probe>, WireEndpoint<Probe>, WireHub<Probe>) {
        let hub = WireHub::<Probe>::loopback(&BusConfig::default(), &[]);
        let a = hub.add_endpoint(0).unwrap();
        let b = hub.add_endpoint(1).unwrap();
        (a, b, hub)
    }

    /// Drive `recv` until a message ripens or the deadline passes (TCP
    /// delivery needs a pump or two even on loopback).
    fn recv_within(ep: &mut WireEndpoint<Probe>, ms: u64) -> Option<Received<Probe>> {
        let deadline = Instant::now() + Duration::from_millis(ms);
        while Instant::now() < deadline {
            if let Some(r) = ep.try_recv_uncommitted() {
                return Some(r);
            }
            std::thread::yield_now();
        }
        None
    }

    #[test]
    fn varint_round_trip_and_overflow() {
        for v in [0u64, 1, 127, 128, 16_383, 16_384, u64::MAX] {
            let mut buf = Vec::new();
            write_varint(&mut buf, v);
            let mut pos = 0;
            assert_eq!(read_varint(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
        // 11 continuation bytes can never be a u64
        let over = [0xFFu8; 11];
        let mut pos = 0;
        assert!(read_varint(&over, &mut pos).is_err());
        let mut pos = 0;
        assert!(read_varint(&[0x80], &mut pos).is_err(), "truncated");
    }

    #[test]
    fn zigzag_round_trip() {
        for v in [0i64, 1, -1, 63, -64, i64::MAX, i64::MIN] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        assert_eq!(zigzag(-1), 1, "small magnitudes stay small");
        assert_eq!(zigzag(1), 2);
    }

    #[test]
    fn delta_coords_round_trip_and_rejection() {
        let coords: Vec<u64> = vec![3, 4, 7, 100, 101, 9000];
        let mut buf = Vec::new();
        write_deltas(&mut buf, coords.iter().copied());
        assert!(buf.len() <= 9, "sorted columns compress to ~1 byte/coord");
        let mut pos = 0;
        assert_eq!(read_deltas(&buf, &mut pos, coords.len()).unwrap(), coords);
        // a count larger than the remaining bytes is rejected pre-alloc
        let mut pos = 0;
        assert!(read_deltas(&buf, &mut pos, usize::MAX).is_err());
        // a column decoding below zero is rejected
        let mut neg = Vec::new();
        write_deltas(&mut neg, [5u64].into_iter());
        write_varint(&mut neg, zigzag(-9)); // 5 - 9 < 0
        let mut pos = 0;
        assert!(read_deltas(&neg, &mut pos, 2).is_err());
    }

    #[test]
    fn f64_slice_round_trip_and_truncation() {
        let vals = [0.0, -1.5, f64::MIN_POSITIVE, 1e300];
        let mut buf = Vec::new();
        write_f64_slice(&mut buf, &vals);
        let mut pos = 0;
        assert_eq!(read_f64_slice(&buf, &mut pos, 4).unwrap(), vals);
        let mut pos = 0;
        assert!(read_f64_slice(&buf, &mut pos, 5).is_err(), "truncated");
    }

    #[test]
    fn point_to_point_over_tcp() {
        let (mut a, mut b, _hub) = pair();
        let t: &mut dyn Transport<Probe> = &mut a;
        t.send(1, Probe(7), 0.5, 3).unwrap();
        let got = recv_within(&mut b, 2000).expect("delivered");
        assert_eq!(got.payload, Probe(7));
        assert_eq!(got.from, 0);
        assert!((b.global_inflight() - 0.5).abs() < 1e-15, "uncommitted");
        b.commit(got.from, got.seq, got.mass);
        assert_eq!(b.global_inflight(), 0.0);
        let deadline = Instant::now() + Duration::from_secs(2);
        while a.unacked() > 0 && Instant::now() < deadline {
            a.collect_acks();
        }
        assert_eq!(a.unacked(), 0, "ack released retention");
        assert_eq!(a.metrics().get("msgs_sent"), 1);
        assert_eq!(a.metrics().get("msgs_recv"), 1);
        assert_eq!(a.metrics().get("acks"), 1);
    }

    #[test]
    fn removed_endpoint_fails_fast_and_returns_payload() {
        let (mut a, mut b, hub) = pair();
        // warm a connection so the per-send directory check, not the
        // dial, is what must refuse after removal
        a.try_send(1, Probe(1), 0.25, 1).unwrap();
        let got = recv_within(&mut b, 2000).unwrap();
        b.commit(got.from, got.seq, got.mass);
        hub.remove_endpoint(1);
        assert!(!hub.is_live(1));
        assert_eq!(a.try_send(1, Probe(42), 1.5, 1), Err(Probe(42)));
        let deadline = Instant::now() + Duration::from_secs(2);
        while (a.unacked() > 0 || a.global_inflight() != 0.0) && Instant::now() < deadline {
            a.collect_acks();
        }
        assert_eq!(a.global_inflight(), 0.0);
        assert_eq!(a.unacked(), 0);
        assert_eq!(hub.monitor().undelivered(), 0);
    }

    #[test]
    fn latency_delays_tcp_delivery() {
        let cfg = BusConfig {
            latency: Some((Duration::from_millis(30), Duration::from_millis(40))),
            seed: 1,
        };
        let hub = WireHub::<Probe>::loopback(&cfg, &[]);
        let mut a = hub.add_endpoint(0).unwrap();
        let mut b = hub.add_endpoint(1).unwrap();
        a.try_send(1, Probe(9), 0.0, 1).unwrap();
        // let the frame arrive, then confirm it ripens late
        let deadline = Instant::now() + Duration::from_secs(2);
        while b.pending_delayed() == 0 && Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(b.pending_delayed(), 1, "arrived but not ripe");
        assert!(b.try_recv_uncommitted().is_none(), "not before its delay");
        std::thread::sleep(Duration::from_millis(60));
        assert!(b.try_recv_uncommitted().is_some());
        assert_eq!(b.pending_delayed(), 0);
    }

    #[test]
    fn corrupt_length_prefix_kills_connection_not_process() {
        let (_a, mut b, _hub) = pair();
        // dial b's listener raw and write a poisoned length prefix
        let mut s = TcpStream::connect(b.local_addr()).unwrap();
        s.write_all(&u32::MAX.to_le_bytes()).unwrap();
        s.write_all(&[0xAA; 64]).unwrap();
        s.flush().unwrap();
        // the poisoned connection must die without delivering anything:
        // pump until the accepted connection has been culled again
        let t0 = Instant::now();
        while Instant::now() < t0 + Duration::from_millis(300) {
            assert!(b.try_recv_uncommitted().is_none());
            std::thread::yield_now();
        }
        assert!(
            b.conns.is_empty(),
            "the corrupt connection must be culled"
        );
    }

    #[test]
    fn corrupt_payload_kills_connection() {
        let (_a, mut b, _hub) = pair();
        let mut s = TcpStream::connect(b.local_addr()).unwrap();
        // a well-formed HELLO for pid 5 ...
        let mut hello = vec![KIND_HELLO];
        write_varint(&mut hello, 5);
        hello.push(PROTO_VERSION);
        let mut frame = (hello.len() as u32).to_le_bytes().to_vec();
        frame.extend_from_slice(&hello);
        // ... then a MSG whose payload fails to decode
        let mut msg = vec![KIND_MSG];
        write_varint(&mut msg, 0);
        write_f64(&mut msg, 0.0);
        msg.extend_from_slice(&[0xFF, 0xFF, 0xFF]); // not a Probe
        frame.extend_from_slice(&(msg.len() as u32).to_le_bytes());
        frame.extend_from_slice(&msg);
        s.write_all(&frame).unwrap();
        s.flush().unwrap();
        let t0 = Instant::now();
        while Instant::now() < t0 + Duration::from_millis(300) {
            assert!(
                b.try_recv_uncommitted().is_none(),
                "a corrupt payload must never surface"
            );
            std::thread::yield_now();
        }
        assert!(
            !b.conns.iter().any(|c| c.peer == Some(5) && c.alive),
            "the connection carrying the corrupt payload must be dead"
        );
    }

    #[test]
    fn hub_slot_rules_match_the_bus() {
        let hub = WireHub::<Probe>::loopback(&BusConfig::default(), &[]);
        let _a = hub.add_endpoint(0).unwrap();
        let _b = hub.add_endpoint(1).unwrap();
        assert_eq!(hub.capacity(), 2);
        assert!(hub.add_endpoint(5).is_err(), "gaps rejected");
        assert!(hub.add_endpoint(1).is_err(), "occupied rejected");
        hub.remove_endpoint(1);
        assert!(!hub.is_live(1));
        let c = hub.add_endpoint(1).unwrap();
        assert_eq!(c.id(), 1);
        assert_eq!(hub.capacity(), 2, "slot reused, not appended");
    }

    #[test]
    fn remote_mode_releases_on_ack_receipt() {
        // two hubs = two accounting domains, as in process-per-worker
        let cfg = BusConfig::default();
        let bind = IpAddr::V4(Ipv4Addr::LOCALHOST);
        let hub_a = WireHub::<Probe>::remote(2, bind, &cfg, &[]);
        let hub_b = WireHub::<Probe>::remote(2, bind, &cfg, &[]);
        let mut a = hub_a.add_endpoint(0).unwrap();
        let mut b = hub_b.add_endpoint(1).unwrap();
        hub_a.set_peer_addr(1, b.local_addr());
        hub_b.set_peer_addr(0, a.local_addr());
        a.try_send(1, Probe(3), 0.75, 1).unwrap();
        assert!((a.global_inflight() - 0.75).abs() < 1e-15);
        assert_eq!(hub_a.monitor().undelivered(), 1);
        let got = recv_within(&mut b, 2000).expect("delivered");
        // the receiving process never saw the increment, so commit must
        // not touch its account
        b.commit(got.from, got.seq, got.mass);
        assert_eq!(b.global_inflight(), 0.0);
        assert_eq!(hub_b.monitor().undelivered(), 0);
        // the sender releases when the ACK lands
        let deadline = Instant::now() + Duration::from_secs(2);
        while hub_a.monitor().undelivered() > 0 && Instant::now() < deadline {
            a.collect_acks();
        }
        assert_eq!(a.global_inflight(), 0.0);
        assert_eq!(hub_a.monitor().undelivered(), 0);
        assert_eq!(a.unacked(), 0);
    }

    #[test]
    fn ctrl_frame_round_trip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let got: Probe = read_ctrl_frame(&mut s).unwrap();
            write_ctrl_frame(&mut s, &Probe(got.0 + 1)).unwrap();
        });
        let mut s = TcpStream::connect(addr).unwrap();
        write_ctrl_frame(&mut s, &Probe(41)).unwrap();
        let back: Probe = read_ctrl_frame(&mut s).unwrap();
        assert_eq!(back, Probe(42));
        t.join().unwrap();
    }

    #[test]
    fn cross_thread_wire_traffic() {
        let (mut a, mut b, _hub) = pair();
        let t = std::thread::spawn(move || {
            for i in 0..100u64 {
                let mut payload = Probe(i);
                loop {
                    match a.try_send(1, payload, 0.01, 8) {
                        Ok(()) => break,
                        Err(p) => payload = p,
                    }
                }
            }
            a
        });
        let mut seen = 0;
        let deadline = Instant::now() + Duration::from_secs(10);
        while seen < 100 && Instant::now() < deadline {
            if let Some(r) = b.try_recv_uncommitted() {
                b.commit(r.from, r.seq, r.mass);
                seen += 1;
            }
        }
        let mut a = t.join().unwrap();
        assert_eq!(seen, 100);
        let deadline = Instant::now() + Duration::from_secs(5);
        while a.unacked() > 0 && Instant::now() < deadline {
            a.collect_acks();
        }
        assert_eq!(a.unacked(), 0);
        assert!(b.global_inflight().abs() < 1e-12);
    }
}
