//! Metrics substrate: counters, stopwatches, convergence traces, CSV dump.
//!
//! Every experiment in EXPERIMENTS.md is regenerated from a
//! [`ConvergenceTrace`] (error-vs-cost series, one per solver/scheme) and a
//! [`MetricSet`] (scalar counters: messages, bytes, shares, acks, ...).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// A single error-vs-cost series. `cost` is the experiment's x-axis —
/// for the paper's figures it is "equivalent scalar updates / N" (so
/// sequential iteration k costs k, and a K-PID parallel round costs the
/// max of the PIDs' local updates).
#[derive(Clone, Debug, Default)]
pub struct ConvergenceTrace {
    pub name: String,
    pub points: Vec<TracePoint>,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TracePoint {
    /// x-axis: equivalent full iterations (see above)
    pub cost: f64,
    /// y-axis: L1 distance to the exact limit
    pub error: f64,
}

impl ConvergenceTrace {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            points: Vec::new(),
        }
    }

    pub fn push(&mut self, cost: f64, error: f64) {
        self.points.push(TracePoint { cost, error });
    }

    /// First cost at which the error drops (and stays) below `tol`;
    /// `None` if never reached.
    pub fn cost_to_reach(&self, tol: f64) -> Option<f64> {
        let mut reached: Option<f64> = None;
        for p in &self.points {
            if p.error <= tol {
                if reached.is_none() {
                    reached = Some(p.cost);
                }
            } else {
                reached = None;
            }
        }
        reached
    }

    /// Final recorded error.
    pub fn final_error(&self) -> Option<f64> {
        self.points.last().map(|p| p.error)
    }
}

/// Render several traces as an aligned text table (the bench harness's
/// figure output): one row per cost step, one column per trace.
pub fn render_traces_table(traces: &[ConvergenceTrace]) -> String {
    let mut out = String::new();
    let mut costs: Vec<f64> = traces
        .iter()
        .flat_map(|t| t.points.iter().map(|p| p.cost))
        .collect();
    costs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    costs.dedup_by(|a, b| (*a - *b).abs() < 1e-12);

    let _ = write!(out, "{:>10}", "cost");
    for t in traces {
        let _ = write!(out, " {:>14}", truncate(&t.name, 14));
    }
    out.push('\n');
    for &c in &costs {
        let _ = write!(out, "{c:>10.2}");
        for t in traces {
            match lookup(t, c) {
                Some(e) => {
                    let _ = write!(out, " {e:>14.6e}");
                }
                None => {
                    let _ = write!(out, " {:>14}", "-");
                }
            }
        }
        out.push('\n');
    }
    out
}

/// CSV dump of traces (long format: name,cost,error).
pub fn traces_to_csv(traces: &[ConvergenceTrace]) -> String {
    let mut out = String::from("series,cost,error\n");
    for t in traces {
        for p in &t.points {
            let _ = writeln!(out, "{},{},{}", t.name, p.cost, p.error);
        }
    }
    out
}

fn lookup(t: &ConvergenceTrace, cost: f64) -> Option<f64> {
    t.points
        .iter()
        .find(|p| (p.cost - cost).abs() < 1e-12)
        .map(|p| p.error)
}

fn truncate(s: &str, n: usize) -> &str {
    if s.len() <= n {
        s
    } else {
        &s[..n]
    }
}

/// Thread-safe named counters (shared by transport + coordinator).
#[derive(Debug, Default)]
pub struct MetricSet {
    counters: BTreeMap<&'static str, AtomicU64>,
}

impl MetricSet {
    pub fn new(names: &[&'static str]) -> Self {
        let mut counters = BTreeMap::new();
        for &n in names {
            counters.insert(n, AtomicU64::new(0));
        }
        Self { counters }
    }

    /// Add to a counter (no-op if the name was not registered).
    pub fn add(&self, name: &'static str, v: u64) {
        if let Some(c) = self.counters.get(name) {
            c.fetch_add(v, Ordering::Relaxed);
        }
    }

    pub fn incr(&self, name: &'static str) {
        self.add(name, 1);
    }

    pub fn get(&self, name: &'static str) -> u64 {
        self.counters
            .get(name)
            .map_or(0, |c| c.load(Ordering::Relaxed))
    }

    /// Peak-tracking max update.
    pub fn max(&self, name: &'static str, v: u64) {
        if let Some(c) = self.counters.get(name) {
            c.fetch_max(v, Ordering::Relaxed);
        }
    }

    /// Gauge-style overwrite (no-op if the name was not registered) — for
    /// level metrics like the current partition load imbalance, where the
    /// latest observation replaces the previous one.
    pub fn set(&self, name: &'static str, v: u64) {
        if let Some(c) = self.counters.get(name) {
            c.store(v, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> BTreeMap<&'static str, u64> {
        self.counters
            .iter()
            .map(|(k, v)| (*k, v.load(Ordering::Relaxed)))
            .collect()
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.snapshot() {
            let _ = writeln!(out, "{k:<28} {v}");
        }
        out
    }
}

/// Steady-state rate estimator (events/second) for long-running streams:
/// each epoch folds one `(events, secs)` observation into an EWMA, so the
/// streaming engine can report a stable updates/sec figure that is not
/// dominated by the first (cold) epoch.
#[derive(Clone, Debug)]
pub struct RateMeter {
    alpha: f64,
    ewma: Option<f64>,
    total_events: u64,
    total_secs: f64,
}

impl RateMeter {
    /// `alpha` ∈ (0, 1]: weight of the newest observation (1.0 = last-only).
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha must be in (0, 1]");
        Self {
            alpha,
            ewma: None,
            total_events: 0,
            total_secs: 0.0,
        }
    }

    /// Record one observation window. Zero-length windows are ignored.
    pub fn record(&mut self, events: u64, secs: f64) {
        if secs <= 0.0 {
            return;
        }
        self.total_events += events;
        self.total_secs += secs;
        let r = events as f64 / secs;
        self.ewma = Some(match self.ewma {
            None => r,
            Some(prev) => self.alpha * r + (1.0 - self.alpha) * prev,
        });
    }

    /// Smoothed steady-state rate (None until the first observation).
    pub fn rate(&self) -> Option<f64> {
        self.ewma
    }

    /// Lifetime mean rate over every recorded window.
    pub fn mean_rate(&self) -> f64 {
        if self.total_secs == 0.0 {
            0.0
        } else {
            self.total_events as f64 / self.total_secs
        }
    }
}

/// A simple stopwatch for coarse phase timing.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_cost_to_reach_requires_staying_below() {
        let mut t = ConvergenceTrace::new("x");
        t.push(1.0, 1e-2);
        t.push(2.0, 1e-4); // dips...
        t.push(3.0, 1e-2); // ...but comes back up
        t.push(4.0, 1e-5);
        t.push(5.0, 1e-6);
        assert_eq!(t.cost_to_reach(1e-3), Some(4.0));
        assert_eq!(t.cost_to_reach(1e-9), None);
        assert_eq!(t.final_error(), Some(1e-6));
    }

    #[test]
    fn table_renders_all_series() {
        let mut a = ConvergenceTrace::new("jacobi");
        a.push(1.0, 0.5);
        a.push(2.0, 0.25);
        let mut b = ConvergenceTrace::new("diter");
        b.push(1.0, 0.1);
        let table = render_traces_table(&[a.clone(), b.clone()]);
        assert!(table.contains("jacobi"));
        assert!(table.contains("diter"));
        assert_eq!(table.lines().count(), 3); // header + 2 cost rows
        let csv = traces_to_csv(&[a, b]);
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.starts_with("series,cost,error"));
    }

    #[test]
    fn rate_meter_smooths_and_totals() {
        let mut r = RateMeter::new(0.5);
        assert!(r.rate().is_none());
        r.record(100, 1.0); // 100/s
        r.record(300, 1.0); // 300/s -> ewma 200
        assert!((r.rate().unwrap() - 200.0).abs() < 1e-9);
        assert!((r.mean_rate() - 200.0).abs() < 1e-9);
        r.record(0, 0.0); // ignored
        assert!((r.mean_rate() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn metric_set_counts() {
        let m = MetricSet::new(&["msgs", "bytes"]);
        m.incr("msgs");
        m.add("bytes", 100);
        m.add("bytes", 20);
        m.max("msgs", 5);
        assert_eq!(m.get("msgs"), 5);
        assert_eq!(m.get("bytes"), 120);
        assert_eq!(m.get("unknown"), 0);
        m.set("bytes", 7);
        assert_eq!(m.get("bytes"), 7, "set overwrites");
        m.set("unknown", 1); // unregistered: silently ignored
        assert_eq!(m.get("unknown"), 0);
        assert!(m.render().contains("bytes"));
    }
}
