//! Sparse preprocessing ops — notably §2.1.2 *diagonal link elimination*.
//!
//! When `p_ii ≠ 0` the self-loop can be folded away: the fluid a node keeps
//! re-sending to itself forms the geometric series `1/(1−p_ii)`, so one can
//! (a) rescale `B_i ← B_i/(1−p_ii)` and (b) rescale everything *arriving*
//! at i by the same factor — equivalently, scale row i of P by `1/(1−p_ii)`
//! and zero the diagonal. The fixed point of the transformed system equals
//! the original one.

use super::{CsrMatrix, TripletBuilder};
use crate::error::{DiterError, Result};

/// Result of diagonal elimination: transformed matrix + B rescale factors.
#[derive(Clone, Debug)]
pub struct DiagElimination {
    /// P with zeroed diagonal and rescaled incoming weights.
    pub matrix: CsrMatrix,
    /// `scale[i] = 1/(1 - p_ii)`; apply to `B_i` (and record for fluids).
    pub scale: Vec<f64>,
    /// How many diagonal entries were actually eliminated.
    pub eliminated: usize,
}

/// Eliminate all diagonal entries of a square iteration matrix (§2.1.2).
///
/// Fails if any `p_ii ≥ 1` (the geometric series diverges — the iteration
/// would not have converged anyway).
pub fn diag_eliminate(p: &CsrMatrix) -> Result<DiagElimination> {
    if p.nrows() != p.ncols() {
        return Err(DiterError::shape(
            "diag_eliminate",
            "square",
            format!("{}x{}", p.nrows(), p.ncols()),
        ));
    }
    let n = p.nrows();
    let mut scale = vec![1.0; n];
    let mut eliminated = 0usize;
    for i in 0..n {
        let pii = p.get(i, i);
        if pii != 0.0 {
            if pii >= 1.0 {
                return Err(DiterError::NotContractive(format!(
                    "p[{i},{i}] = {pii} >= 1; diagonal elimination impossible"
                )));
            }
            scale[i] = 1.0 / (1.0 - pii);
            eliminated += 1;
        }
    }
    let mut b = TripletBuilder::with_capacity(n, n, p.nnz());
    for i in 0..n {
        let (idx, val) = p.row(i);
        for k in 0..idx.len() {
            let j = idx[k];
            if j == i {
                continue; // the eliminated self-loop
            }
            // all fluid arriving at i is multiplied by scale[i]; folding the
            // factor into row i of P keeps the fixed point identical.
            b.push(i, j, val[k] * scale[i]);
        }
    }
    Ok(DiagElimination {
        matrix: b.to_csr(),
        scale,
        eliminated,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{solve_dense, DenseMat};

    /// Fixed point of X = PX + B must be invariant under elimination.
    #[test]
    fn fixed_point_preserved() {
        let p = DenseMat::from_rows(&[
            &[0.3, 0.2, 0.0],
            &[0.1, 0.0, 0.4],
            &[0.0, 0.25, 0.25],
        ]);
        let b = vec![1.0, 2.0, 3.0];
        // exact solve of (I - P) x = b
        let mut a = DenseMat::identity(3);
        for i in 0..3 {
            for j in 0..3 {
                a[(i, j)] -= p[(i, j)];
            }
        }
        let x_orig = solve_dense(&a, &b).unwrap();

        let csr = CsrMatrix::from_dense(&p);
        let elim = diag_eliminate(&csr).unwrap();
        assert_eq!(elim.eliminated, 2);
        // transformed system: X = P'X + B' with B'_i = scale_i * B_i
        let p2 = elim.matrix.to_dense();
        let b2: Vec<f64> = b.iter().zip(&elim.scale).map(|(x, s)| x * s).collect();
        let mut a2 = DenseMat::identity(3);
        for i in 0..3 {
            for j in 0..3 {
                a2[(i, j)] -= p2[(i, j)];
            }
        }
        let x_new = solve_dense(&a2, &b2).unwrap();
        for i in 0..3 {
            assert!((x_orig[i] - x_new[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn no_diagonal_is_noop() {
        let p = DenseMat::from_rows(&[&[0.0, 0.5], &[0.5, 0.0]]);
        let csr = CsrMatrix::from_dense(&p);
        let elim = diag_eliminate(&csr).unwrap();
        assert_eq!(elim.eliminated, 0);
        assert_eq!(elim.scale, vec![1.0, 1.0]);
        assert_eq!(elim.matrix.to_dense(), p);
    }

    #[test]
    fn diverging_diagonal_rejected() {
        let p = DenseMat::from_rows(&[&[1.0, 0.0], &[0.0, 0.0]]);
        let csr = CsrMatrix::from_dense(&p);
        assert!(diag_eliminate(&csr).is_err());
    }

    #[test]
    fn diagonal_gone_after_elimination() {
        let p = DenseMat::from_rows(&[&[0.5, 0.2], &[0.3, 0.4]]);
        let elim = diag_eliminate(&CsrMatrix::from_dense(&p)).unwrap();
        for i in 0..2 {
            assert_eq!(elim.matrix.get(i, i), 0.0);
        }
        // off-diagonals rescaled by 1/(1-p_ii) of the *row*
        assert!((elim.matrix.get(0, 1) - 0.2 / 0.5).abs() < 1e-15);
        assert!((elim.matrix.get(1, 0) - 0.3 / 0.6).abs() < 1e-15);
    }
}
