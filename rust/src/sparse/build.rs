//! COO triplet builder → CSR.

use super::CsrMatrix;

/// Accumulates `(row, col, value)` triplets; duplicates are summed on
/// [`TripletBuilder::to_csr`] (the standard COO semantics, handy for graph
/// generators that may emit parallel edges).
#[derive(Clone, Debug)]
pub struct TripletBuilder {
    nrows: usize,
    ncols: usize,
    rows: Vec<usize>,
    cols: Vec<usize>,
    vals: Vec<f64>,
}

impl TripletBuilder {
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Self {
            nrows,
            ncols,
            rows: Vec::new(),
            cols: Vec::new(),
            vals: Vec::new(),
        }
    }

    pub fn with_capacity(nrows: usize, ncols: usize, cap: usize) -> Self {
        let mut b = Self::new(nrows, ncols);
        b.rows.reserve(cap);
        b.cols.reserve(cap);
        b.vals.reserve(cap);
        b
    }

    /// Add `value` at `(i, j)`. Panics on out-of-range in debug builds.
    #[inline]
    pub fn push(&mut self, i: usize, j: usize, value: f64) {
        debug_assert!(i < self.nrows && j < self.ncols, "({i},{j}) out of range");
        self.rows.push(i);
        self.cols.push(j);
        self.vals.push(value);
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Sort by (row, col), sum duplicates, emit CSR. Exact zeros arising
    /// from duplicate cancellation are kept (harmless, rare).
    pub fn to_csr(&self) -> CsrMatrix {
        let nnz = self.vals.len();
        // counting sort by row
        let mut row_counts = vec![0usize; self.nrows + 1];
        for &r in &self.rows {
            row_counts[r + 1] += 1;
        }
        for i in 0..self.nrows {
            row_counts[i + 1] += row_counts[i];
        }
        let mut order: Vec<usize> = vec![0; nnz];
        let mut next = row_counts.clone();
        for k in 0..nnz {
            let r = self.rows[k];
            order[next[r]] = k;
            next[r] += 1;
        }
        // per-row sort by column + merge duplicates
        let mut indptr = vec![0usize; self.nrows + 1];
        let mut indices: Vec<usize> = Vec::with_capacity(nnz);
        let mut values: Vec<f64> = Vec::with_capacity(nnz);
        let mut scratch: Vec<(usize, f64)> = Vec::new();
        for i in 0..self.nrows {
            scratch.clear();
            for &k in &order[row_counts[i]..row_counts[i + 1]] {
                scratch.push((self.cols[k], self.vals[k]));
            }
            scratch.sort_unstable_by_key(|&(c, _)| c);
            let mut iter = scratch.iter().copied();
            if let Some((mut cur_c, mut cur_v)) = iter.next() {
                for (c, v) in iter {
                    if c == cur_c {
                        cur_v += v;
                    } else {
                        indices.push(cur_c);
                        values.push(cur_v);
                        cur_c = c;
                        cur_v = v;
                    }
                }
                indices.push(cur_c);
                values.push(cur_v);
            }
            indptr[i + 1] = indices.len();
        }
        CsrMatrix::from_parts(self.nrows, self.ncols, indptr, indices, values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_matrix() {
        let b = TripletBuilder::new(3, 3);
        let m = b.to_csr();
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.matvec(&[1.0; 3]).unwrap(), vec![0.0; 3]);
    }

    #[test]
    fn duplicates_are_summed() {
        let mut b = TripletBuilder::new(2, 2);
        b.push(0, 1, 1.0);
        b.push(0, 1, 2.5);
        b.push(1, 0, -1.0);
        let m = b.to_csr();
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.get(0, 1), 3.5);
        assert_eq!(m.get(1, 0), -1.0);
    }

    #[test]
    fn unsorted_input_sorted_output() {
        let mut b = TripletBuilder::new(1, 5);
        for j in [4usize, 0, 2, 3, 1] {
            b.push(0, j, j as f64);
        }
        let m = b.to_csr();
        let (idx, val) = m.row(0);
        assert_eq!(idx, &[0, 1, 2, 3, 4]);
        assert_eq!(val, &[0.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn rows_out_of_order() {
        let mut b = TripletBuilder::new(3, 3);
        b.push(2, 0, 1.0);
        b.push(0, 2, 2.0);
        b.push(1, 1, 3.0);
        let m = b.to_csr();
        assert_eq!(m.get(2, 0), 1.0);
        assert_eq!(m.get(0, 2), 2.0);
        assert_eq!(m.get(1, 1), 3.0);
    }
}
