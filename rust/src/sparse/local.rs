//! Partition-local view of the iteration matrix: the worker hot loop's
//! indirection-free fast path.
//!
//! The V2 diffusion of a locally-owned coordinate walks its *column* of P.
//! Doing that against the global CSC costs a `local_of` lookup per entry
//! (a random read into an n-sized map) plus an owner lookup and a hashed
//! coalesce insert for every cross-part entry. A [`LocalSystem`] pays all
//! of that **once per (re)build** instead of once per diffusion:
//!
//! * the **local block** is the owned columns reindexed into local-slot
//!   space — `block_col(t)` yields `(local slots, p_{ji} values)` with the
//!   values contiguous, so the intra-part inner loop is two array reads
//!   and a fused multiply-add per entry;
//! * the **remnant** is everything that leaves the part, with each entry
//!   resolved at build time to `(destination PID, accumulator slot)` —
//!   the slot indexes a per-PID dense scratch accumulator (see
//!   [`crate::transport::CoalesceBuffer`]), so a cross-part emission is a
//!   single indexed add, no hashing and no owner lookup.
//!
//! Rebuilds are **handoff-atomic**: the worker core rebuilds the whole
//! structure from its post-fold owned set before the next diffusion
//! quantum, so the kernel never observes a half-updated view. Across
//! streaming epochs the structure is instead **patched**: only the
//! columns the [`crate::graph::MutableDigraph`] build reported dirty are
//! re-extracted, the rest are spliced from the previous epoch's arrays —
//! the same dirty-column strategy the matrix cache itself uses.
//!
//! Ownership changes are patched too, not rebuilt: [`LocalSystem::shed`]
//! drops shipped columns and flips block entries that pointed at them
//! into remnant entries, [`LocalSystem::adopt`] appends the received
//! columns and flips remnant entries whose target just became local, and
//! [`LocalSystem::retarget`] re-routes remnant destinations in place
//! after a peer-to-peer move this worker is not part of. Shed and adopt
//! still copy the surviving entries (one linear splice over the arrays),
//! but they avoid what made the full rebuild expensive: the global-CSC
//! column walks and the per-entry interner hashing, which now happen
//! only for flipped, re-routed, or freshly-received entries. Spawn-time
//! adoption (an elastic worker starting from an empty Ω) is the limit
//! case: O(nnz(received)) total.

use super::CscMatrix;

/// The reindexed local block + cross-part remnant for one worker's owned
/// coordinate range. Column `t` corresponds to `owned[t]`.
#[derive(Clone, Debug, PartialEq)]
pub struct LocalSystem {
    /// number of local columns (owned slots)
    m: usize,
    blk_indptr: Vec<usize>,
    /// local-slot row indices of intra-part entries
    blk_rows: Vec<u32>,
    blk_vals: Vec<f64>,
    rem_indptr: Vec<usize>,
    /// destination PID of each cross-part entry
    rem_dest: Vec<u32>,
    /// destination accumulator slot (interned at build time)
    rem_slot: Vec<u32>,
    /// global coordinate of each cross-part target — what lets the
    /// ownership patches re-route entries without the global CSC
    rem_coord: Vec<u32>,
    rem_vals: Vec<f64>,
}

impl LocalSystem {
    /// Build from the global CSC over `owned` (the held coordinate range,
    /// `local_of[owned[t]] == t`, `usize::MAX` elsewhere). `owner` is the
    /// current coordinate → PID map; `intern(dest, coord)` assigns (or
    /// returns) the destination accumulator slot for a cross-part target.
    pub fn build(
        csc: &CscMatrix,
        owned: &[usize],
        local_of: &[usize],
        owner: &[usize],
        mut intern: impl FnMut(usize, usize) -> u32,
    ) -> LocalSystem {
        let m = owned.len();
        let mut sys = LocalSystem::empty(m);
        for &i in owned {
            extract_column(csc, i, local_of, owner, &mut intern, &mut sys);
            sys.blk_indptr.push(sys.blk_rows.len());
            sys.rem_indptr.push(sys.rem_dest.len());
        }
        sys
    }

    /// An `m`-column shell with open indptrs (one sentinel pushed).
    fn empty(m: usize) -> LocalSystem {
        let mut sys = LocalSystem {
            m,
            blk_indptr: Vec::with_capacity(m + 1),
            blk_rows: Vec::new(),
            blk_vals: Vec::new(),
            rem_indptr: Vec::with_capacity(m + 1),
            rem_dest: Vec::new(),
            rem_slot: Vec::new(),
            rem_coord: Vec::new(),
            rem_vals: Vec::new(),
        };
        sys.blk_indptr.push(0);
        sys.rem_indptr.push(0);
        sys
    }

    /// Re-extract only the `dirty` global columns (ascending) against a
    /// new epoch's matrix, splicing every clean column from the previous
    /// arrays. Requires the owned set (and therefore `local_of`) to be
    /// unchanged since the last build — which the streaming rebase
    /// guarantees by quiescing handoffs before swapping the matrix.
    #[allow(clippy::too_many_arguments)]
    pub fn patch(
        &mut self,
        csc: &CscMatrix,
        owned: &[usize],
        local_of: &[usize],
        owner: &[usize],
        dirty: &[usize],
        mut intern: impl FnMut(usize, usize) -> u32,
    ) {
        assert_eq!(
            owned.len(),
            self.m,
            "LocalSystem::patch requires an unchanged owned set"
        );
        // epoch transitions patch unconditionally; a delta that misses
        // this worker's columns entirely must not pay the full
        // splice-copy (only OWNED columns live in the structure — a
        // dirty column elsewhere never changes it)
        if dirty.is_empty() || !owned.iter().any(|i| dirty.binary_search(i).is_ok()) {
            return;
        }
        let mut next = LocalSystem::empty(self.m);
        for (t, &i) in owned.iter().enumerate() {
            if dirty.binary_search(&i).is_ok() {
                extract_column(csc, i, local_of, owner, &mut intern, &mut next);
            } else {
                let (blo, bhi) = (self.blk_indptr[t], self.blk_indptr[t + 1]);
                next.blk_rows.extend_from_slice(&self.blk_rows[blo..bhi]);
                next.blk_vals.extend_from_slice(&self.blk_vals[blo..bhi]);
                let (rlo, rhi) = (self.rem_indptr[t], self.rem_indptr[t + 1]);
                next.rem_dest.extend_from_slice(&self.rem_dest[rlo..rhi]);
                next.rem_slot.extend_from_slice(&self.rem_slot[rlo..rhi]);
                next.rem_coord.extend_from_slice(&self.rem_coord[rlo..rhi]);
                next.rem_vals.extend_from_slice(&self.rem_vals[rlo..rhi]);
            }
            next.blk_indptr.push(next.blk_rows.len());
            next.rem_indptr.push(next.rem_dest.len());
        }
        *self = next;
    }

    /// Ownership shed (handoff shipped): drop the columns whose old slot
    /// is marked in `shipped`, renumber kept block rows through `new_slot`
    /// (old local slot → compacted slot), and flip block entries that
    /// pointed at a shipped slot into remnant entries routed by the *new*
    /// `owner` map. Surviving remnant entries are re-routed through
    /// `owner` too (the same install may have moved third-party
    /// coordinates). One pass over the existing arrays — hashing only on
    /// flipped or re-routed entries, never a global-CSC walk.
    pub fn shed(
        &mut self,
        old_owned: &[usize],
        shipped: &[bool],
        new_slot: &[u32],
        owner: &[usize],
        mut intern: impl FnMut(usize, usize) -> u32,
    ) {
        debug_assert_eq!(shipped.len(), self.m, "one shipped flag per old slot");
        debug_assert_eq!(old_owned.len(), self.m);
        let m_new = shipped.iter().filter(|&&s| !s).count();
        let mut next = LocalSystem::empty(m_new);
        for t in 0..self.m {
            if shipped[t] {
                continue;
            }
            let (blo, bhi) = (self.blk_indptr[t], self.blk_indptr[t + 1]);
            for e in blo..bhi {
                let r = self.blk_rows[e] as usize;
                if shipped[r] {
                    // the target left the part: block entry becomes remnant
                    let j = old_owned[r];
                    let d = owner[j];
                    next.rem_dest.push(d as u32);
                    next.rem_slot.push(intern(d, j));
                    next.rem_coord.push(j as u32);
                    next.rem_vals.push(self.blk_vals[e]);
                } else {
                    next.blk_rows.push(new_slot[r]);
                    next.blk_vals.push(self.blk_vals[e]);
                }
            }
            let (rlo, rhi) = (self.rem_indptr[t], self.rem_indptr[t + 1]);
            for e in rlo..rhi {
                let j = self.rem_coord[e] as usize;
                let d = owner[j];
                let slot = if d == self.rem_dest[e] as usize {
                    self.rem_slot[e] // destination unchanged: slot still valid
                } else {
                    intern(d, j)
                };
                next.rem_dest.push(d as u32);
                next.rem_slot.push(slot);
                next.rem_coord.push(j as u32);
                next.rem_vals.push(self.rem_vals[e]);
            }
            next.blk_indptr.push(next.blk_rows.len());
            next.rem_indptr.push(next.rem_dest.len());
        }
        *self = next;
    }

    /// Ownership adoption (handoff received): append the `added` columns
    /// (extracted from the CSC — the only fresh extraction, O(nnz(added)))
    /// and flip existing remnant entries whose target coordinate is now
    /// held locally (per `local_of`) into block entries. Remnant entries
    /// staying remote are re-routed through the new `owner` map. Existing
    /// block rows keep their slots — adoption appends, it never renumbers.
    pub fn adopt(
        &mut self,
        csc: &CscMatrix,
        added: &[usize],
        local_of: &[usize],
        owner: &[usize],
        mut intern: impl FnMut(usize, usize) -> u32,
    ) {
        let mut next = LocalSystem::empty(self.m + added.len());
        for t in 0..self.m {
            let (blo, bhi) = (self.blk_indptr[t], self.blk_indptr[t + 1]);
            next.blk_rows.extend_from_slice(&self.blk_rows[blo..bhi]);
            next.blk_vals.extend_from_slice(&self.blk_vals[blo..bhi]);
            let (rlo, rhi) = (self.rem_indptr[t], self.rem_indptr[t + 1]);
            for e in rlo..rhi {
                let j = self.rem_coord[e] as usize;
                let lt = local_of[j];
                if lt != usize::MAX {
                    // the target just became ours: remnant entry turns block
                    next.blk_rows.push(lt as u32);
                    next.blk_vals.push(self.rem_vals[e]);
                } else {
                    let d = owner[j];
                    let slot = if d == self.rem_dest[e] as usize {
                        self.rem_slot[e]
                    } else {
                        intern(d, j)
                    };
                    next.rem_dest.push(d as u32);
                    next.rem_slot.push(slot);
                    next.rem_coord.push(j as u32);
                    next.rem_vals.push(self.rem_vals[e]);
                }
            }
            next.blk_indptr.push(next.blk_rows.len());
            next.rem_indptr.push(next.rem_dest.len());
        }
        for &i in added {
            extract_column(csc, i, local_of, owner, &mut intern, &mut next);
            next.blk_indptr.push(next.blk_rows.len());
            next.rem_indptr.push(next.rem_dest.len());
        }
        *self = next;
    }

    /// Re-route remnant destinations in place after a peer-to-peer
    /// ownership move this worker is not part of (its own columns are
    /// untouched — only where cross-part fluid must be sent changed).
    /// Returns `false` (caller must rebuild) if any remnant target became
    /// local, which would change the block structure; that cannot happen
    /// on the no-outgoing/no-incoming refresh path (adoption goes through
    /// [`LocalSystem::adopt`]), so this is a cheap O(remnant) sweep.
    pub fn retarget(
        &mut self,
        local_of: &[usize],
        owner: &[usize],
        mut intern: impl FnMut(usize, usize) -> u32,
    ) -> bool {
        for e in 0..self.rem_dest.len() {
            let j = self.rem_coord[e] as usize;
            if local_of[j] != usize::MAX {
                return false;
            }
            let d = owner[j];
            if d as u32 != self.rem_dest[e] {
                self.rem_dest[e] = d as u32;
                self.rem_slot[e] = intern(d, j);
            }
        }
        true
    }

    /// Local columns (owned slots).
    pub fn cols(&self) -> usize {
        self.m
    }

    /// Intra-part entries of local column `t`: (local slots, values).
    #[inline]
    pub fn block_col(&self, t: usize) -> (&[u32], &[f64]) {
        let (lo, hi) = (self.blk_indptr[t], self.blk_indptr[t + 1]);
        (&self.blk_rows[lo..hi], &self.blk_vals[lo..hi])
    }

    /// Cross-part entries of local column `t`:
    /// (destination PIDs, accumulator slots, values).
    #[inline]
    pub fn remnant_col(&self, t: usize) -> (&[u32], &[u32], &[f64]) {
        let (lo, hi) = (self.rem_indptr[t], self.rem_indptr[t + 1]);
        (
            &self.rem_dest[lo..hi],
            &self.rem_slot[lo..hi],
            &self.rem_vals[lo..hi],
        )
    }

    /// Intra-part nonzeros.
    pub fn block_nnz(&self) -> usize {
        self.blk_vals.len()
    }

    /// Cross-part nonzeros — the partition-cut weight the remnant pays.
    pub fn remnant_nnz(&self) -> usize {
        self.rem_vals.len()
    }
}

/// Extract global column `i` into the (open, indptrs not yet closed)
/// tail of `sys`, splitting entries into block vs remnant.
fn extract_column(
    csc: &CscMatrix,
    i: usize,
    local_of: &[usize],
    owner: &[usize],
    intern: &mut impl FnMut(usize, usize) -> u32,
    sys: &mut LocalSystem,
) {
    let (rows, vals) = csc.col(i);
    for e in 0..rows.len() {
        let j = rows[e];
        let t = local_of[j];
        if t != usize::MAX {
            sys.blk_rows.push(t as u32);
            sys.blk_vals.push(vals[e]);
        } else {
            // routing is decided at build time; a coordinate the table
            // assigns to us but whose handoff has not landed yet routes to
            // ourselves over the bus (same semantics as the global walk)
            let d = owner[j];
            sys.rem_dest.push(d as u32);
            sys.rem_slot.push(intern(d, j));
            sys.rem_coord.push(j as u32);
            sys.rem_vals.push(vals[e]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMat;
    use crate::sparse::CsrMatrix;
    use std::collections::HashMap;

    /// A trivially-inspectable interner: slot = insertion order per dest.
    struct Interner {
        maps: Vec<HashMap<usize, u32>>,
        coords: Vec<Vec<usize>>,
    }

    impl Interner {
        fn new(k: usize) -> Interner {
            Interner {
                maps: (0..k).map(|_| HashMap::new()).collect(),
                coords: vec![Vec::new(); k],
            }
        }

        fn intern(&mut self, d: usize, j: usize) -> u32 {
            if let Some(&s) = self.maps[d].get(&j) {
                return s;
            }
            let s = self.coords[d].len() as u32;
            self.maps[d].insert(j, s);
            self.coords[d].push(j);
            s
        }
    }

    fn fixture() -> (CscMatrix, Vec<usize>, Vec<usize>, Vec<usize>) {
        // 4x4, columns: 0 -> {1: .5, 2: .25}, 1 -> {0: .3}, 2 -> {3: .4},
        // 3 -> {0: .1, 2: .2}
        let d = DenseMat::from_rows(&[
            &[0.0, 0.3, 0.0, 0.1],
            &[0.5, 0.0, 0.0, 0.0],
            &[0.25, 0.0, 0.0, 0.2],
            &[0.0, 0.0, 0.4, 0.0],
        ]);
        let csc = CsrMatrix::from_dense(&d).to_csc();
        // PID 0 owns {0, 1}, PID 1 owns {2, 3}
        let owner = vec![0, 0, 1, 1];
        let owned = vec![0, 1];
        let mut local_of = vec![usize::MAX; 4];
        local_of[0] = 0;
        local_of[1] = 1;
        (csc, owned, local_of, owner)
    }

    #[test]
    fn build_splits_block_and_remnant() {
        let (csc, owned, local_of, owner) = fixture();
        let mut it = Interner::new(2);
        let sys = LocalSystem::build(&csc, &owned, &local_of, &owner, |d, j| it.intern(d, j));
        assert_eq!(sys.cols(), 2);
        // column 0 of P: rows {1: .5, 2: .25} — 1 is local slot 1, 2 is remote
        let (rows, vals) = sys.block_col(0);
        assert_eq!(rows, &[1]);
        assert_eq!(vals, &[0.5]);
        let (dests, slots, rvals) = sys.remnant_col(0);
        assert_eq!(dests, &[1]);
        assert_eq!(it.coords[1][slots[0] as usize], 2);
        assert_eq!(rvals, &[0.25]);
        // column 1 of P: row {0: .3} — fully local
        let (rows, vals) = sys.block_col(1);
        assert_eq!(rows, &[0]);
        assert_eq!(vals, &[0.3]);
        assert_eq!(sys.remnant_col(1).0.len(), 0);
        assert_eq!(sys.block_nnz(), 2);
        assert_eq!(sys.remnant_nnz(), 1);
    }

    #[test]
    fn remote_targets_interned_per_destination() {
        let (csc, _, _, owner) = fixture();
        let owned = vec![2, 3];
        let mut local_of = vec![usize::MAX; 4];
        local_of[2] = 0;
        local_of[3] = 1;
        let mut it = Interner::new(2);
        let sys = LocalSystem::build(&csc, &owned, &local_of, &owner, |d, j| it.intern(d, j));
        // column 2 -> {3: .4} local; column 3 -> {0: .1 remote, 2: .2 local}
        assert_eq!(sys.block_nnz(), 2);
        assert_eq!(sys.remnant_nnz(), 1);
        assert_eq!(it.coords[0], vec![0]);
    }

    #[test]
    fn patch_matches_fresh_build() {
        let (csc, owned, local_of, owner) = fixture();
        let mut it = Interner::new(2);
        let mut sys =
            LocalSystem::build(&csc, &owned, &local_of, &owner, |d, j| it.intern(d, j));
        // new epoch: column 0 changes (entry to 3 appears, weights move)
        let d2 = DenseMat::from_rows(&[
            &[0.0, 0.3, 0.0, 0.1],
            &[0.6, 0.0, 0.0, 0.0],
            &[0.1, 0.0, 0.0, 0.2],
            &[0.2, 0.0, 0.4, 0.0],
        ]);
        let csc2 = CsrMatrix::from_dense(&d2).to_csc();
        sys.patch(&csc2, &owned, &local_of, &owner, &[0], |d, j| {
            it.intern(d, j)
        });
        let mut it2 = Interner::new(2);
        let fresh =
            LocalSystem::build(&csc2, &owned, &local_of, &owner, |d, j| it2.intern(d, j));
        // same structure; slots may differ between interners, so compare
        // through the resolved coordinates
        assert_eq!(sys.blk_indptr, fresh.blk_indptr);
        assert_eq!(sys.blk_rows, fresh.blk_rows);
        assert_eq!(sys.blk_vals, fresh.blk_vals);
        assert_eq!(sys.rem_indptr, fresh.rem_indptr);
        assert_eq!(sys.rem_dest, fresh.rem_dest);
        assert_eq!(sys.rem_coord, fresh.rem_coord);
        assert_eq!(sys.rem_vals, fresh.rem_vals);
        for e in 0..sys.rem_slot.len() {
            let d = sys.rem_dest[e] as usize;
            assert_eq!(
                it.coords[d][sys.rem_slot[e] as usize],
                it2.coords[d][fresh.rem_slot[e] as usize]
            );
        }
    }

    /// Resolve a LocalSystem into an interner-independent, order-
    /// independent form: per column, sorted (local slot, val) block
    /// entries and sorted (dest, coord, val) remnant entries.
    #[allow(clippy::type_complexity)]
    fn canonical(
        sys: &LocalSystem,
        it: &Interner,
    ) -> Vec<(Vec<(u32, f64)>, Vec<(usize, usize, f64)>)> {
        (0..sys.cols())
            .map(|t| {
                let (rows, vals) = sys.block_col(t);
                let mut blk: Vec<(u32, f64)> =
                    rows.iter().copied().zip(vals.iter().copied()).collect();
                blk.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let (dests, slots, vals) = sys.remnant_col(t);
                let mut rem: Vec<(usize, usize, f64)> = (0..dests.len())
                    .map(|e| {
                        let d = dests[e] as usize;
                        (d, it.coords[d][slots[e] as usize], vals[e])
                    })
                    .collect();
                rem.sort_by(|a, b| a.partial_cmp(b).unwrap());
                (blk, rem)
            })
            .collect()
    }

    #[test]
    fn shed_matches_fresh_build_on_remaining_columns() {
        let (csc, owned, local_of, owner) = fixture();
        let mut it = Interner::new(2);
        let mut sys =
            LocalSystem::build(&csc, &owned, &local_of, &owner, |d, j| it.intern(d, j));
        // ship coordinate 1 (old slot 1) away to PID 1
        let mut new_owner = owner.clone();
        new_owner[1] = 1;
        let shipped = vec![false, true];
        let new_slot = vec![0u32, u32::MAX];
        sys.shed(&owned, &shipped, &new_slot, &new_owner, |d, j| {
            it.intern(d, j)
        });
        assert_eq!(sys.cols(), 1);
        // reference: fresh build over the shrunken owned set + new owners
        let mut lo2 = vec![usize::MAX; 4];
        lo2[0] = 0;
        let mut it2 = Interner::new(2);
        let fresh = LocalSystem::build(&csc, &[0], &lo2, &new_owner, |d, j| it2.intern(d, j));
        assert_eq!(canonical(&sys, &it), canonical(&fresh, &it2));
        // the entry 0 → 1 (p₁₀ = .5) must have flipped from block to remnant
        assert_eq!(sys.block_col(0).0.len(), 0);
        assert_eq!(sys.remnant_col(0).0.len(), 2);
    }

    #[test]
    fn adopt_matches_fresh_build_and_flips_remnant_to_block() {
        let (csc, owned, mut local_of, mut owner) = fixture();
        let mut it = Interner::new(2);
        let mut sys =
            LocalSystem::build(&csc, &owned, &local_of, &owner, |d, j| it.intern(d, j));
        // adopt coordinate 2 from PID 1 (appended as local slot 2)
        owner[2] = 0;
        local_of[2] = 2;
        sys.adopt(&csc, &[2], &local_of, &owner, |d, j| it.intern(d, j));
        assert_eq!(sys.cols(), 3);
        let mut it2 = Interner::new(2);
        let fresh =
            LocalSystem::build(&csc, &[0, 1, 2], &local_of, &owner, |d, j| it2.intern(d, j));
        assert_eq!(canonical(&sys, &it), canonical(&fresh, &it2));
        // column 0's entry to 2 (p₂₀ = .25) must now be a block entry
        let (rows, vals) = sys.block_col(0);
        assert!(rows.contains(&2), "{rows:?} {vals:?}");
    }

    #[test]
    fn retarget_reroutes_after_peer_to_peer_move() {
        let (csc, owned, local_of, owner) = fixture();
        // three parts so a move between 1 and 2 is peer-to-peer for PID 0
        let owner3: Vec<usize> = owner.iter().map(|&o| if o == 1 { 2 } else { o }).collect();
        let mut it = Interner::new(3);
        let mut sys =
            LocalSystem::build(&csc, &owned, &local_of, &owner3, |d, j| it.intern(d, j));
        // coordinate 2 moves from PID 2 to PID 1
        let mut moved = owner3.clone();
        moved[2] = 1;
        assert!(sys.retarget(&local_of, &moved, |d, j| it.intern(d, j)));
        let mut it2 = Interner::new(3);
        let fresh = LocalSystem::build(&csc, &owned, &local_of, &moved, |d, j| it2.intern(d, j));
        assert_eq!(canonical(&sys, &it), canonical(&fresh, &it2));
        // a target that became local must force a rebuild instead
        let mut lo2 = local_of.clone();
        lo2[2] = 2;
        assert!(!sys.retarget(&lo2, &moved, |d, j| it.intern(d, j)));
    }

    #[test]
    fn patch_misses_are_noops() {
        let (csc, owned, local_of, owner) = fixture();
        let mut it = Interner::new(2);
        let mut sys =
            LocalSystem::build(&csc, &owned, &local_of, &owner, |d, j| it.intern(d, j));
        let before = sys.clone();
        // empty delta, and a delta entirely outside the owned columns
        sys.patch(&csc, &owned, &local_of, &owner, &[], |d, j| it.intern(d, j));
        assert_eq!(sys, before);
        sys.patch(&csc, &owned, &local_of, &owner, &[2, 3], |d, j| {
            it.intern(d, j)
        });
        assert_eq!(sys, before, "foreign dirty columns change nothing");
    }

    #[test]
    #[should_panic(expected = "unchanged owned set")]
    fn patch_rejects_resized_owned_set() {
        let (csc, owned, local_of, owner) = fixture();
        let mut it = Interner::new(2);
        let mut sys =
            LocalSystem::build(&csc, &owned, &local_of, &owner, |d, j| it.intern(d, j));
        sys.patch(&csc, &[0], &local_of, &owner, &[], |d, j| it.intern(d, j));
    }
}
