//! Sparse matrix substrate: COO builder, CSR and CSC forms.
//!
//! The D-iteration's two distributed schemes read P in two different ways:
//!
//! * **V1** (full-H scheme) sweeps *rows* `L_i(P)` — CSR is the natural
//!   layout for the per-PID local updates `H_i ← L_i(P)·H + B_i`.
//! * **V2** (fluid scheme) diffuses along *columns* `C_i(P)`: diffusing node
//!   i sends `f·p_{ji}` to every out-neighbor j, i.e. walks column i — CSC.
//!
//! [`SparseMatrix`] keeps both forms in sync so each scheme takes its
//! natural traversal with zero per-access conversion cost.
//!
//! [`LocalSystem`] is the per-worker view on top of the CSC: the owned
//! columns reindexed into local-slot space (the intra-part fast path) plus
//! the cross-part remnant resolved to destination accumulator slots — the
//! V2 hot loop runs against it instead of the global matrix.

mod build;
mod local;
mod ops;

pub use build::TripletBuilder;
pub use local::LocalSystem;
pub use ops::{diag_eliminate, DiagElimination};

use crate::error::{DiterError, Result};
use crate::linalg::DenseMat;

/// Compressed Sparse Row matrix (f64 entries).
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    nrows: usize,
    ncols: usize,
    /// `indptr[i]..indptr[i+1]` spans row i's entries.
    indptr: Vec<usize>,
    indices: Vec<usize>,
    values: Vec<f64>,
}

impl CsrMatrix {
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    pub fn ncols(&self) -> usize {
        self.ncols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub(crate) fn from_parts(
        nrows: usize,
        ncols: usize,
        indptr: Vec<usize>,
        indices: Vec<usize>,
        values: Vec<f64>,
    ) -> Self {
        debug_assert_eq!(indptr.len(), nrows + 1);
        debug_assert_eq!(indices.len(), values.len());
        Self {
            nrows,
            ncols,
            indptr,
            indices,
            values,
        }
    }

    /// Row i as (column indices, values) — the paper's `L_i(P)`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Sparse dot `L_i(P) · x`.
    #[inline]
    pub fn row_dot(&self, i: usize, x: &[f64]) -> f64 {
        let (idx, val) = self.row(i);
        let mut acc = 0.0;
        for k in 0..idx.len() {
            acc += val[k] * x[idx[k]];
        }
        acc
    }

    /// `y = P · x`.
    pub fn matvec(&self, x: &[f64]) -> Result<Vec<f64>> {
        if x.len() != self.ncols {
            return Err(DiterError::shape("csr matvec", self.ncols, x.len()));
        }
        let mut y = vec![0.0; self.nrows];
        for i in 0..self.nrows {
            y[i] = self.row_dot(i, x);
        }
        Ok(y)
    }

    /// Entry lookup (O(row nnz)); 0.0 if absent.
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let (idx, val) = self.row(i);
        idx.iter()
            .position(|&c| c == j)
            .map_or(0.0, |k| val[k])
    }

    /// Per-row L1 norms `Σ_j |p_ij|` (the L∞ contraction check).
    pub fn row_l1_norms(&self) -> Vec<f64> {
        (0..self.nrows)
            .map(|i| self.row(i).1.iter().map(|v| v.abs()).sum())
            .collect()
    }

    /// Per-column L1 norms `Σ_j |p_ji|` — §4.4 uses
    /// `ε = min_i (1 − Σ_j |p_ji|)` for the distance-to-limit bound.
    pub fn col_l1_norms(&self) -> Vec<f64> {
        let mut sums = vec![0.0; self.ncols];
        for k in 0..self.values.len() {
            sums[self.indices[k]] += self.values[k].abs();
        }
        sums
    }

    /// Convert to CSC (a transpose-like pass).
    pub fn to_csc(&self) -> CscMatrix {
        let mut counts = vec![0usize; self.ncols];
        for &j in &self.indices {
            counts[j] += 1;
        }
        let mut indptr = vec![0usize; self.ncols + 1];
        for j in 0..self.ncols {
            indptr[j + 1] = indptr[j] + counts[j];
        }
        let mut rows = vec![0usize; self.nnz()];
        let mut values = vec![0.0; self.nnz()];
        let mut next = indptr.clone();
        for i in 0..self.nrows {
            let (idx, val) = self.row(i);
            for k in 0..idx.len() {
                let j = idx[k];
                let slot = next[j];
                rows[slot] = i;
                values[slot] = val[k];
                next[j] += 1;
            }
        }
        CscMatrix {
            nrows: self.nrows,
            ncols: self.ncols,
            indptr,
            indices: rows,
            values,
        }
    }

    /// Dense copy (small matrices / tests / PJRT dense blocks).
    pub fn to_dense(&self) -> DenseMat {
        let mut d = DenseMat::zeros(self.nrows, self.ncols);
        for i in 0..self.nrows {
            let (idx, val) = self.row(i);
            for k in 0..idx.len() {
                d[(i, idx[k])] = val[k];
            }
        }
        d
    }

    /// Build from dense, dropping exact zeros.
    pub fn from_dense(d: &DenseMat) -> Self {
        let mut b = TripletBuilder::new(d.rows(), d.cols());
        for i in 0..d.rows() {
            for (j, &v) in d.row(i).iter().enumerate() {
                if v != 0.0 {
                    b.push(i, j, v);
                }
            }
        }
        b.to_csr()
    }

    /// Extract the dense row block for a set of rows (PJRT dense path):
    /// returns a row-major `rows.len() × ncols` buffer.
    pub fn dense_row_block(&self, rows: &[usize]) -> Vec<f64> {
        let mut out = vec![0.0; rows.len() * self.ncols];
        for (r, &i) in rows.iter().enumerate() {
            let (idx, val) = self.row(i);
            let base = r * self.ncols;
            for k in 0..idx.len() {
                out[base + idx[k]] = val[k];
            }
        }
        out
    }

    /// Density in [0,1].
    pub fn density(&self) -> f64 {
        if self.nrows == 0 || self.ncols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.nrows as f64 * self.ncols as f64)
    }
}

/// Compressed Sparse Column matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct CscMatrix {
    nrows: usize,
    ncols: usize,
    /// `indptr[j]..indptr[j+1]` spans column j's entries.
    indptr: Vec<usize>,
    indices: Vec<usize>,
    values: Vec<f64>,
}

impl CscMatrix {
    pub(crate) fn from_parts(
        nrows: usize,
        ncols: usize,
        indptr: Vec<usize>,
        indices: Vec<usize>,
        values: Vec<f64>,
    ) -> Self {
        debug_assert_eq!(indptr.len(), ncols + 1);
        debug_assert_eq!(indices.len(), values.len());
        Self {
            nrows,
            ncols,
            indptr,
            indices,
            values,
        }
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }

    pub fn ncols(&self) -> usize {
        self.ncols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Column j as (row indices, values) — the paper's `C_j(P)`, i.e. the
    /// targets of node j's diffusion in the V2 scheme.
    #[inline]
    pub fn col(&self, j: usize) -> (&[usize], &[f64]) {
        let (lo, hi) = (self.indptr[j], self.indptr[j + 1]);
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Convert to CSR.
    pub fn to_csr(&self) -> CsrMatrix {
        let mut counts = vec![0usize; self.nrows];
        for &i in &self.indices {
            counts[i] += 1;
        }
        let mut indptr = vec![0usize; self.nrows + 1];
        for i in 0..self.nrows {
            indptr[i + 1] = indptr[i] + counts[i];
        }
        let mut cols = vec![0usize; self.nnz()];
        let mut values = vec![0.0; self.nnz()];
        let mut next = indptr.clone();
        for j in 0..self.ncols {
            let (idx, val) = self.col(j);
            for k in 0..idx.len() {
                let i = idx[k];
                let slot = next[i];
                cols[slot] = j;
                values[slot] = val[k];
                next[i] += 1;
            }
        }
        CsrMatrix {
            nrows: self.nrows,
            ncols: self.ncols,
            indptr,
            indices: cols,
            values,
        }
    }
}

/// A square iteration matrix kept in both CSR (row sweeps, V1) and CSC
/// (column diffusion, V2) forms.
#[derive(Clone, Debug)]
pub struct SparseMatrix {
    csr: CsrMatrix,
    csc: CscMatrix,
}

impl SparseMatrix {
    pub fn from_csr(csr: CsrMatrix) -> Self {
        let csc = csr.to_csc();
        Self { csr, csc }
    }

    /// Build from an already-assembled CSC form (the incremental
    /// column-patch path of [`crate::graph::MutableDigraph`]), deriving
    /// the CSR twin without a triplet round-trip.
    pub fn from_csc(csc: CscMatrix) -> Self {
        let csr = csc.to_csr();
        Self { csr, csc }
    }

    pub fn from_dense(d: &DenseMat) -> Self {
        Self::from_csr(CsrMatrix::from_dense(d))
    }

    pub fn n(&self) -> usize {
        self.csr.nrows()
    }

    pub fn nnz(&self) -> usize {
        self.csr.nnz()
    }

    pub fn csr(&self) -> &CsrMatrix {
        &self.csr
    }

    pub fn csc(&self) -> &CscMatrix {
        &self.csc
    }

    /// Cheap sufficient condition for D-iteration convergence (§4.4):
    /// max column L1 norm < 1 ⇒ ρ(P) < 1 and the fluid bound applies.
    pub fn max_col_norm(&self) -> f64 {
        self.csr
            .col_l1_norms()
            .into_iter()
            .fold(0.0, f64::max)
    }

    /// `ε = min_i (1 − Σ_j |p_ji|)` from §4.4 (may be ≤ 0 when the bound
    /// does not apply).
    pub fn epsilon(&self) -> f64 {
        self.csr
            .col_l1_norms()
            .into_iter()
            .map(|s| 1.0 - s)
            .fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_p1() -> DenseMat {
        DenseMat::from_rows(&[
            &[0.0, -3.0 / 5.0, 0.0, 0.0],
            &[-3.0 / 7.0, 0.0, 0.0, 0.0],
            &[0.0, 0.0, 0.0, -4.0 / 8.0],
            &[0.0, 0.0, -2.0 / 3.0, 0.0],
        ])
    }

    #[test]
    fn csr_roundtrip_dense() {
        let d = paper_p1();
        let csr = CsrMatrix::from_dense(&d);
        assert_eq!(csr.nnz(), 4);
        assert_eq!(csr.to_dense(), d);
    }

    #[test]
    fn csr_row_access() {
        let csr = CsrMatrix::from_dense(&paper_p1());
        let (idx, val) = csr.row(0);
        assert_eq!(idx, &[1]);
        assert_eq!(val, &[-0.6]);
        assert_eq!(csr.get(0, 1), -0.6);
        assert_eq!(csr.get(0, 0), 0.0);
    }

    #[test]
    fn csr_matvec_matches_dense() {
        let d = paper_p1();
        let csr = CsrMatrix::from_dense(&d);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(csr.matvec(&x).unwrap(), d.matvec(&x).unwrap());
    }

    #[test]
    fn csc_roundtrip() {
        let csr = CsrMatrix::from_dense(&paper_p1());
        let back = csr.to_csc().to_csr();
        assert_eq!(back.to_dense(), paper_p1());
    }

    #[test]
    fn sparse_from_csc_matches_from_csr() {
        let csr = CsrMatrix::from_dense(&paper_p1());
        let a = SparseMatrix::from_csr(csr.clone());
        let b = SparseMatrix::from_csc(csr.to_csc());
        assert_eq!(a.csr().to_dense(), b.csr().to_dense());
        assert_eq!(a.csc(), b.csc());
    }

    #[test]
    fn csc_col_is_diffusion_targets() {
        let m = SparseMatrix::from_dense(&paper_p1());
        // column 3 of P: entries p_{2,3} = -0.5 → diffusing node 3 sends to 2
        let (rows, vals) = m.csc().col(3);
        assert_eq!(rows, &[2]);
        assert_eq!(vals, &[-0.5]);
    }

    #[test]
    fn norms_and_epsilon() {
        let m = SparseMatrix::from_dense(&paper_p1());
        let cols = m.csr().col_l1_norms();
        assert!((cols[0] - 3.0 / 7.0).abs() < 1e-15);
        assert!((cols[1] - 0.6).abs() < 1e-15);
        assert!(m.max_col_norm() < 1.0);
        assert!(m.epsilon() > 0.0);
    }

    #[test]
    fn dense_row_block_extraction() {
        let csr = CsrMatrix::from_dense(&paper_p1());
        let block = csr.dense_row_block(&[2, 3]);
        assert_eq!(block.len(), 8);
        assert_eq!(block[3], -0.5); // row 2, col 3
        assert_eq!(block[4 + 2], -2.0 / 3.0); // row 3, col 2
    }

    #[test]
    fn density() {
        let csr = CsrMatrix::from_dense(&paper_p1());
        assert!((csr.density() - 4.0 / 16.0).abs() < 1e-15);
    }

    #[test]
    fn matvec_shape_error() {
        let csr = CsrMatrix::from_dense(&paper_p1());
        assert!(csr.matvec(&[1.0; 3]).is_err());
    }
}
