//! Property-testing substrate (no `proptest` offline).
//!
//! A deliberately small framework: seeded generators + a runner that
//! reports the failing case number and its seed so any failure is exactly
//! reproducible. Used by the module tests and `rust/tests/property_invariants.rs`.
//!
//! ```
//! use diter::prop::{run_cases, Gen};
//! run_cases(64, 0xD17E12, |g| {
//!     let n = g.usize_in(1, 20);
//!     let xs = g.vec_f64(n, -1.0, 1.0);
//!     let sum: f64 = xs.iter().sum();
//!     assert!(sum.abs() <= n as f64);
//! });
//! ```

use crate::prng::Xoshiro256pp;

/// Random-input generator handed to each property case.
pub struct Gen {
    rng: Xoshiro256pp,
    /// the case's reproduction seed (printed on failure)
    pub case_seed: u64,
}

impl Gen {
    pub fn new(case_seed: u64) -> Self {
        Gen {
            rng: Xoshiro256pp::seed_from_u64(case_seed),
            case_seed,
        }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        self.rng.range(lo, hi + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.rng.uniform(lo, hi)).collect()
    }

    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        self.rng.permutation(n)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    /// A random row-substochastic (L∞-contractive) square matrix with
    /// `nnz_per_row` entries per row and row L1 norms ≤ `max_row_norm` —
    /// the standing precondition of the D-iteration.
    pub fn contraction_matrix(
        &mut self,
        n: usize,
        nnz_per_row: usize,
        max_row_norm: f64,
    ) -> crate::sparse::CsrMatrix {
        let mut b = crate::sparse::TripletBuilder::with_capacity(n, n, n * nnz_per_row);
        for i in 0..n {
            let k = nnz_per_row.min(n.saturating_sub(1)).max(1);
            let cols = self.rng.sample_distinct(n, k);
            let norm = self.rng.uniform(0.1, max_row_norm);
            let mut weights: Vec<f64> = (0..k).map(|_| self.rng.uniform(0.05, 1.0)).collect();
            let s: f64 = weights.iter().sum();
            for w in weights.iter_mut() {
                *w *= norm / s;
            }
            for (t, &j) in cols.iter().enumerate() {
                if j == i {
                    continue; // keep diagonal clear (paper's canonical form)
                }
                let sign = if self.rng.chance(0.5) { -1.0 } else { 1.0 };
                b.push(i, j, sign * weights[t]);
            }
        }
        b.to_csr()
    }

    /// Raw access to the underlying RNG for anything not covered above.
    pub fn rng(&mut self) -> &mut Xoshiro256pp {
        &mut self.rng
    }
}

/// Run `cases` property cases derived deterministically from `seed`.
/// Panics with the case index + seed on the first failing case.
pub fn run_cases(cases: usize, seed: u64, mut prop: impl FnMut(&mut Gen)) {
    let mut meta = Xoshiro256pp::seed_from_u64(seed);
    for case in 0..cases {
        let case_seed = meta.next_u64();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen::new(case_seed);
            prop(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed at case {case}/{cases} (repro: Gen::new({case_seed:#x})): {msg}"
            );
        }
    }
}

/// Run a single reproduction case (paste the seed from a failure report).
pub fn repro_case(case_seed: u64, mut prop: impl FnMut(&mut Gen)) {
    let mut g = Gen::new(case_seed);
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_are_deterministic() {
        let mut seen_a = Vec::new();
        run_cases(5, 42, |g| seen_a.push(g.usize_in(0, 1000)));
        let mut seen_b = Vec::new();
        run_cases(5, 42, |g| seen_b.push(g.usize_in(0, 1000)));
        assert_eq!(seen_a, seen_b);
    }

    #[test]
    fn failure_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            run_cases(10, 7, |g| {
                let v = g.usize_in(0, 100);
                assert!(v < 1000, "always true");
                if g.case_seed % 2 == 0 || g.case_seed % 2 == 1 {
                    // fail on the 3rd case only
                }
            });
        });
        assert!(result.is_ok());
        let result = std::panic::catch_unwind(|| {
            let mut count = 0;
            run_cases(10, 7, move |_g| {
                count += 1;
                assert!(count < 4, "boom");
            });
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("repro"), "{msg}");
    }

    #[test]
    fn contraction_matrix_is_contractive() {
        run_cases(20, 99, |g| {
            let n = g.usize_in(2, 30);
            let m = g.contraction_matrix(n, 3, 0.9);
            assert_eq!(m.nrows(), n);
            for r in m.row_l1_norms() {
                assert!(r < 0.95, "row norm {r}");
            }
            for i in 0..n {
                assert_eq!(m.get(i, i), 0.0);
            }
        });
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen::new(5);
        for _ in 0..100 {
            let v = g.usize_in(3, 7);
            assert!((3..=7).contains(&v));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
        let p = g.permutation(10);
        assert_eq!(p.len(), 10);
        let choice = *g.pick(&[1, 2, 3]);
        assert!([1, 2, 3].contains(&choice));
    }
}
