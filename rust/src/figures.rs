//! Paper-figure regeneration (shared by `diter figure` and the benches).
//!
//! Each figure is an error-vs-iteration chart; we reproduce it as a text
//! table with one column per series (Jacobi, Gauss–Seidel, sequential
//! D-iteration, 2-PID distributed D-iteration), using the exact protocol
//! of §5.1: cyclic sequences, partitions {1,2}/{3,4}, two local cycles
//! between shares. Figure 4 switches P → P' at iteration 6 (§5.2).

use crate::coordinator::sim;
use crate::error::Result;
use crate::graph::paper_matrix;
use crate::linalg::vec_ops::dist1;
use crate::metrics::{render_traces_table, traces_to_csv, ConvergenceTrace};
use crate::partition::Partition;
use crate::solver::{DIteration, FixedPointProblem, GaussSeidel, Jacobi};

/// All four series of one paper figure.
pub struct FigureData {
    pub id: u8,
    pub traces: Vec<ConvergenceTrace>,
}

/// Compute the series for paper figure `id` (1..=4) up to `max_cost`
/// equivalent iterations.
pub fn figure_data(id: u8, max_cost: usize) -> Result<FigureData> {
    assert!((1..=4).contains(&id), "figure id must be 1..4");
    let which = if id == 4 { 1 } else { id };
    let problem = FixedPointProblem::from_linear_system(&paper_matrix(which), &[1.0; 4])?;
    let switch_problem = if id == 4 {
        Some(FixedPointProblem::from_linear_system(
            &paper_matrix(4),
            &[1.0; 4],
        )?)
    } else {
        None
    };
    let exact = match &switch_problem {
        Some(p2) => p2.exact_solution()?,
        None => problem.exact_solution()?,
    };
    let switch_at = 6usize;
    let switch_ref = switch_problem.as_ref().map(|p| (switch_at, p));

    let to_trace = |name: &str, snaps: &[sim::Snapshot]| {
        let mut t = ConvergenceTrace::new(name);
        for s in snaps {
            t.push(s.cost, dist1(&s.x, &exact));
        }
        t
    };

    let mut traces = Vec::new();
    traces.push(to_trace(
        "jacobi",
        &sim::sequential_snapshots(&Jacobi::new(), &problem, max_cost, switch_ref)?,
    ));
    traces.push(to_trace(
        "gauss-seidel",
        &sim::sequential_snapshots(&GaussSeidel::new(), &problem, max_cost, switch_ref)?,
    ));
    traces.push(to_trace(
        "diter-1pid",
        &sim::sequential_snapshots(&DIteration::cyclic(), &problem, max_cost, switch_ref)?,
    ));
    let cfg = sim::SimConfig {
        partition: Partition::contiguous(4, 2)?,
        sweeps_per_share: 2,
        max_cost,
        switch_at: switch_problem.clone().map(|p| (switch_at, p)),
    };
    traces.push(to_trace("diter-2pids", &sim::simulate_v1(&problem, &cfg)?));
    Ok(FigureData { id, traces })
}

/// Render figure `id` as the bench/CLI text table.
pub fn render_figure(id: u8, max_cost: usize) -> Result<String> {
    let data = figure_data(id, max_cost)?;
    let mut out = format!(
        "# Figure {id}: L1 distance to the limit vs cost (1 unit = N scalar updates)\n"
    );
    if id == 4 {
        out.push_str("# matrix switches P -> P' at iteration 6 (section 3.2 rebase)\n");
    }
    out.push_str(&render_traces_table(&data.traces));
    Ok(out)
}

/// CSV form (long format) for plotting.
pub fn figure_csv(id: u8, max_cost: usize) -> Result<String> {
    Ok(traces_to_csv(&figure_data(id, max_cost)?.traces))
}

/// The qualitative headline of a figure: parallel-cost gain of the 2-PID
/// run over the 1-PID run at tolerance `tol` (≈2 for Fig 1, ≈1 for Fig 3).
pub fn figure_gain(id: u8, tol: f64, max_cost: usize) -> Result<Option<f64>> {
    let data = figure_data(id, max_cost)?;
    let find = |name: &str| {
        data.traces
            .iter()
            .find(|t| t.name == name)
            .and_then(|t| t.cost_to_reach(tol))
    };
    let (c1, c2) = match (find("diter-1pid"), find("diter-2pids")) {
        (Some(a), Some(b)) => (a, b),
        _ => return Ok(None),
    };
    // each 2-PID sweep is half the per-PID work of a sequential pass, so
    // equal sweep counts mean a ×2 gain in per-processor work
    Ok(Some(2.0 * c1 / c2.max(1.0)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figures_render_all_series() {
        for id in 1..=4u8 {
            let table = render_figure(id, 12).unwrap();
            for name in ["jacobi", "gauss-seidel", "diter-1pid", "diter-2pids"] {
                assert!(table.contains(name), "figure {id} missing {name}");
            }
        }
    }

    #[test]
    fn fig1_gain_about_two_fig3_gain_about_one() {
        let g1 = figure_gain(1, 1e-8, 120).unwrap().unwrap();
        let g3 = figure_gain(3, 1e-8, 300).unwrap().unwrap();
        assert!((1.5..3.0).contains(&g1), "fig1 gain {g1}");
        assert!(g3 < g1, "fig3 gain {g3} should be below fig1 gain {g1}");
    }

    #[test]
    fn csv_form_parses() {
        let csv = figure_csv(2, 8).unwrap();
        assert!(csv.starts_with("series,cost,error"));
        assert!(csv.lines().count() > 10);
    }
}
