//! Partition substrate: the `Ω_k` sets (§3), strategies to build them, and
//! the §4.3 split/merge adaptation for PIDs advancing at different speeds.
//!
//! The paper leaves the choice of partition as "an independent optimization
//! task" with the hint that *most links should stay within a set*. We
//! provide contiguous and round-robin baselines plus a greedy edge-cut
//! refinement (Kernighan–Lin flavored, single pass) and the cut/balance
//! metrics to compare them.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::error::{DiterError, Result};
use crate::sparse::CsrMatrix;

/// A partition of `0..n` into K disjoint, covering sets.
#[derive(Clone, Debug, PartialEq)]
pub struct Partition {
    n: usize,
    /// `owner[i]` = index of the part that owns coordinate i
    owner: Vec<usize>,
    /// `parts[k]` = sorted members of Ω_k
    parts: Vec<Vec<usize>>,
    /// `slot[i]` = position of i inside `parts[owner[i]]` — the
    /// **local-slot map** the worker cores use to reindex their owned
    /// range into local-slot space without re-deriving it per snapshot
    slot: Vec<u32>,
}

impl Partition {
    /// Build from an explicit owner map.
    pub fn from_owner(owner: Vec<usize>, k: usize) -> Result<Partition> {
        let p = Self::from_owner_elastic(owner, k)?;
        for (kk, part) in p.parts.iter().enumerate() {
            if part.is_empty() {
                return Err(DiterError::InvalidPartition(format!("Ω_{kk} is empty")));
            }
        }
        Ok(p)
    }

    /// Build from an explicit owner map, **allowing empty parts** — the
    /// elastic worker pool's view, where a part index is a stable PID
    /// slot that may be vacant (a retired worker) or not yet populated (a
    /// spawning worker whose handoff has not landed). The classic
    /// [`Partition::from_owner`] stays strict: the paper's Ω_1..Ω_K are
    /// non-empty by construction.
    pub fn from_owner_elastic(owner: Vec<usize>, k: usize) -> Result<Partition> {
        let n = owner.len();
        debug_assert!(n <= u32::MAX as usize, "coordinate space exceeds u32");
        let mut parts = vec![Vec::new(); k];
        let mut slot = vec![0u32; n];
        for (i, &o) in owner.iter().enumerate() {
            if o >= k {
                return Err(DiterError::InvalidPartition(format!(
                    "owner[{i}] = {o} out of range (k = {k})"
                )));
            }
            slot[i] = parts[o].len() as u32;
            parts[o].push(i);
        }
        Ok(Partition {
            n,
            owner,
            parts,
            slot,
        })
    }

    /// Contiguous ranges: Ω_k = [k·n/K, (k+1)·n/K). The paper's examples
    /// ({1,2} | {3,4}) are exactly this.
    pub fn contiguous(n: usize, k: usize) -> Result<Partition> {
        if k == 0 || k > n {
            return Err(DiterError::InvalidPartition(format!(
                "need 1 <= k <= n, got k={k}, n={n}"
            )));
        }
        let mut owner = vec![0usize; n];
        let base = n / k;
        let rem = n % k;
        let mut start = 0;
        for kk in 0..k {
            let len = base + usize::from(kk < rem);
            for i in start..start + len {
                owner[i] = kk;
            }
            start += len;
        }
        Self::from_owner(owner, k)
    }

    /// Round-robin: Ω_k = {i : i mod K = k} — the locality-oblivious
    /// baseline (worst case for block-structured P).
    pub fn round_robin(n: usize, k: usize) -> Result<Partition> {
        if k == 0 || k > n {
            return Err(DiterError::InvalidPartition(format!(
                "need 1 <= k <= n, got k={k}, n={n}"
            )));
        }
        Self::from_owner((0..n).map(|i| i % k).collect(), k)
    }

    /// Greedy edge-cut refinement: start contiguous, then single-pass move
    /// any node whose cut gain is positive (subject to balance slack).
    pub fn greedy_edge_cut(p: &CsrMatrix, k: usize, balance_slack: f64) -> Result<Partition> {
        let n = p.nrows();
        let mut part = Self::contiguous(n, k)?;
        if k == 1 {
            return Ok(part);
        }
        let target = n as f64 / k as f64;
        let max_size = (target * (1.0 + balance_slack)).ceil() as usize;
        let min_size = (target * (1.0 - balance_slack)).floor().max(1.0) as usize;
        // symmetric weight view: weight(i,j) = |p_ij| + |p_ji| — we only
        // have CSR, so accumulate both directions.
        // For each node, tally affinity to each part.
        for i in 0..n {
            let cur = part.owner[i];
            if part.parts[cur].len() <= min_size {
                continue;
            }
            let mut affinity = vec![0.0f64; k];
            let (idx, val) = p.row(i);
            for t in 0..idx.len() {
                affinity[part.owner[idx[t]]] += val[t].abs();
            }
            // incoming edges: scan column-ish via transpose-free pass is
            // costly; approximate with out-edges only (directional cut).
            let (best_k, best_aff) = affinity
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(kk, &a)| (kk, a))
                .unwrap();
            if best_k != cur && best_aff > affinity[cur] && part.parts[best_k].len() < max_size
            {
                part.move_node(i, best_k);
            }
        }
        Ok(part)
    }

    fn move_node(&mut self, i: usize, to: usize) {
        let from = self.owner[i];
        if from == to {
            return;
        }
        self.owner[i] = to;
        let pos = self.parts[from].binary_search(&i).expect("member");
        self.parts[from].remove(pos);
        for (s, &j) in self.parts[from].iter().enumerate().skip(pos) {
            self.slot[j] = s as u32;
        }
        let ins = self.parts[to].binary_search(&i).unwrap_err();
        self.parts[to].insert(ins, i);
        for (s, &j) in self.parts[to].iter().enumerate().skip(ins) {
            self.slot[j] = s as u32;
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn k(&self) -> usize {
        self.parts.len()
    }

    pub fn owner(&self, i: usize) -> usize {
        self.owner[i]
    }

    pub fn owners(&self) -> &[usize] {
        &self.owner
    }

    /// Members of Ω_k (sorted).
    pub fn part(&self, k: usize) -> &[usize] {
        &self.parts[k]
    }

    /// Local slot of coordinate `i` within its part:
    /// `part(owner(i))[slot(i)] == i`. This is what lets a worker build
    /// its local-slot index in O(|Ω_k|) from any table snapshot.
    pub fn slot(&self, i: usize) -> usize {
        self.slot[i] as usize
    }

    /// Fraction of matrix weight crossing part boundaries:
    /// `Σ_{owner(i)≠owner(j)} |p_ij| / Σ |p_ij|` — the "correlation between
    /// Ω sets" that governs the Fig 1→3 gain loss.
    pub fn cut_fraction(&self, p: &CsrMatrix) -> f64 {
        let mut cut = 0.0;
        let mut total = 0.0;
        for i in 0..p.nrows() {
            let (idx, val) = p.row(i);
            for t in 0..idx.len() {
                let w = val[t].abs();
                total += w;
                if self.owner[i] != self.owner[idx[t]] {
                    cut += w;
                }
            }
        }
        if total == 0.0 {
            0.0
        } else {
            cut / total
        }
    }

    /// Size imbalance: max part size / ideal size.
    pub fn imbalance(&self) -> f64 {
        let ideal = self.n as f64 / self.k() as f64;
        self.parts
            .iter()
            .map(|p| p.len() as f64 / ideal)
            .fold(0.0, f64::max)
    }

    /// §4.3: split the largest part in two (speed adaptation for the
    /// slowest PID). Returns the new partition with K+1 parts.
    pub fn split_part(&self, k: usize) -> Result<Partition> {
        if k >= self.k() {
            return Err(DiterError::InvalidPartition(format!("no part {k}")));
        }
        if self.parts[k].len() < 2 {
            return Err(DiterError::InvalidPartition(format!(
                "Ω_{k} too small to split"
            )));
        }
        let new_k = self.k();
        let mut owner = self.owner.clone();
        let members = &self.parts[k];
        for &i in &members[members.len() / 2..] {
            owner[i] = new_k;
        }
        Self::from_owner(owner, new_k + 1)
    }

    /// Live-rebalance mechanics: move `coords` to part `to`, keeping the
    /// PID count fixed. This is how §4.3's split/merge is realized on a
    /// fixed worker pool — "splitting the slowest PID's Ω_k" becomes
    /// offloading part of it to a faster PID. Errors if the move would
    /// empty a part (the exact-cover invariant requires K non-empty sets).
    pub fn transfer(&self, coords: &[usize], to: usize) -> Result<Partition> {
        if to >= self.k() {
            return Err(DiterError::InvalidPartition(format!("no part {to}")));
        }
        let mut owner = self.owner.clone();
        for &i in coords {
            if i >= self.n {
                return Err(DiterError::InvalidPartition(format!(
                    "coordinate {i} out of range (n = {})",
                    self.n
                )));
            }
            owner[i] = to;
        }
        Self::from_owner(owner, self.k())
    }

    /// Elastic transfer: move `coords` to part `to`, where `to` may equal
    /// `k()` (growing K by one — a freshly spawned PID) and the source
    /// part may drain to empty (a retiring PID handing off its whole Ω).
    /// The live worker pool's sibling of [`Partition::transfer`], which
    /// keeps the strict non-empty invariant for the classic engines.
    pub fn transfer_elastic(&self, coords: &[usize], to: usize) -> Result<Partition> {
        if to > self.k() {
            return Err(DiterError::InvalidPartition(format!(
                "part {to} would leave a gap (k = {})",
                self.k()
            )));
        }
        let k = self.k().max(to + 1);
        let mut owner = self.owner.clone();
        for &i in coords {
            if i >= self.n {
                return Err(DiterError::InvalidPartition(format!(
                    "coordinate {i} out of range (n = {})",
                    self.n
                )));
            }
            owner[i] = to;
        }
        Self::from_owner_elastic(owner, k)
    }

    /// Grow to `k_new` parts by appending vacant (empty) PID slots.
    pub fn with_k(&self, k_new: usize) -> Result<Partition> {
        if k_new < self.k() {
            return Err(DiterError::InvalidPartition(format!(
                "with_k cannot shrink ({} -> {k_new})",
                self.k()
            )));
        }
        Self::from_owner_elastic(self.owner.clone(), k_new)
    }

    /// Sizes of every Ω_k (for load reports and rebalance policies).
    pub fn part_sizes(&self) -> Vec<usize> {
        self.parts.iter().map(Vec::len).collect()
    }

    /// §4.3: merge part `b` into part `a` (regrouping fast PIDs).
    pub fn merge_parts(&self, a: usize, b: usize) -> Result<Partition> {
        if a == b || a >= self.k() || b >= self.k() {
            return Err(DiterError::InvalidPartition(format!(
                "cannot merge {a} and {b} (k = {})",
                self.k()
            )));
        }
        let mut owner = Vec::with_capacity(self.n);
        for &o in &self.owner {
            let mut no = if o == b { a } else { o };
            // reindex: parts above b shift down by one
            if no > b {
                no -= 1;
            }
            owner.push(no);
        }
        Self::from_owner(owner, self.k() - 1)
    }

    /// Validate the exact-cover invariant (used by property tests).
    pub fn validate(&self) -> Result<()> {
        let mut seen = vec![false; self.n];
        for (kk, part) in self.parts.iter().enumerate() {
            for (s, &i) in part.iter().enumerate() {
                if i >= self.n || seen[i] {
                    return Err(DiterError::InvalidPartition(format!(
                        "duplicate or out-of-range member {i} in Ω_{kk}"
                    )));
                }
                if self.owner[i] != kk {
                    return Err(DiterError::InvalidPartition(format!(
                        "owner map disagrees for {i}"
                    )));
                }
                if self.slot[i] as usize != s {
                    return Err(DiterError::InvalidPartition(format!(
                        "local-slot map disagrees for {i} (slot {} != {s})",
                        self.slot[i]
                    )));
                }
                seen[i] = true;
            }
        }
        if seen.iter().any(|&s| !s) {
            return Err(DiterError::InvalidPartition("cover incomplete".into()));
        }
        Ok(())
    }
}

/// Lifecycle state of one PID slot in an elastic pool (DESIGN.md §6).
/// A fixed-pool run keeps every slot `Live` for its whole lifetime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PidState {
    /// Bus endpoint registered, worker thread starting; its Ω is empty
    /// until the spawn handoff lands.
    Spawning,
    /// Normal operation: holds (part of) the cover, acks versions.
    Live,
    /// Ownership transferred away; drains in-flight fluid, then exits.
    Draining,
    /// Thread joined, endpoint deregistered. The slot is vacant and may
    /// be reused by a later spawn. Retired slots are exempt from version
    /// acks — nobody is left to ack.
    Retired,
    /// Crashed: the worker thread (or remote process) died without
    /// draining. Unlike `Retired`, its Ω is still routed at the slot and
    /// its state is gone — the pool's recovery path must respawn it and
    /// reconstruct the lost fluid. Dead slots are exempt from version
    /// acks for the same reason retired ones are: nobody is left to ack.
    Dead,
}

/// The **versioned owner map** behind live repartitioning: one shared
/// table per run, consulted by every worker to route fluid and by the
/// coordinator to install rebalances.
///
/// The protocol invariants (DESIGN.md §4):
///
/// * every coordinate is *held* by exactly one worker at any instant;
///   holdings change only through `Handoff` messages on the bus;
/// * a worker whose cached version is stale still routes correctly in the
///   eventual sense — receivers re-route misdelivered fluid by consulting
///   the (always current) table;
/// * `handoffs_inflight` counts slices shipped but not yet folded into
///   the recipient's state; the streaming rebase freezes the table and
///   waits for it to reach zero so a checkpoint can never miss history.
///
/// With an elastic pool (DESIGN.md §6) the PID set itself is dynamic:
/// the table's width ([`OwnershipTable::width`]) grows as workers spawn,
/// each slot carries a [`PidState`], and [`OwnershipTable::all_acked`]
/// skips retired slots (their threads are gone; their final ack was the
/// drain that emptied their Ω).
pub struct OwnershipTable {
    /// (version, partition) — swapped atomically under the lock
    current: RwLock<(u64, Arc<Partition>)>,
    /// cached copy of the version for cheap lock-free polling
    version: AtomicU64,
    /// while frozen no new version may be installed (epoch transitions)
    frozen: AtomicBool,
    /// handoff slices shipped but not yet applied by their recipient
    inflight: AtomicU64,
    /// lifetime handoff count (the `handoffs_total` gauge's source)
    total: AtomicU64,
    /// per-PID highest version fully synced (every coordinate the map
    /// takes away from the PID has been shipped by the time it acks);
    /// behind a lock only so the elastic pool can widen it — ack reads
    /// and writes stay atomic ops under the (uncontended) read lock
    acked: RwLock<Vec<AtomicU64>>,
    /// per-PID lifecycle state, same width as `acked`
    liveness: RwLock<Vec<PidState>>,
}

impl OwnershipTable {
    pub fn new(p: Partition) -> Arc<OwnershipTable> {
        let k = p.k();
        Arc::new(OwnershipTable {
            current: RwLock::new((0, Arc::new(p))),
            version: AtomicU64::new(0),
            frozen: AtomicBool::new(false),
            inflight: AtomicU64::new(0),
            total: AtomicU64::new(0),
            acked: RwLock::new((0..k).map(|_| AtomicU64::new(0)).collect()),
            liveness: RwLock::new(vec![PidState::Live; k]),
        })
    }

    /// Current version (lock-free; workers poll this every loop).
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Consistent (version, partition) pair.
    pub fn snapshot(&self) -> (u64, Arc<Partition>) {
        let g = self.current.read().unwrap_or_else(|e| e.into_inner());
        (g.0, g.1.clone())
    }

    /// The current partition.
    pub fn partition(&self) -> Arc<Partition> {
        self.snapshot().1
    }

    /// Current owner of coordinate `i` (prefer a cached
    /// [`OwnershipTable::snapshot`] on hot paths — this takes the lock).
    pub fn owner(&self, i: usize) -> usize {
        self.partition().owner(i)
    }

    /// Install a new ownership map. Returns the new version, or `None`
    /// while the table is frozen (an epoch transition is in progress).
    /// The partition must keep the same n and K.
    pub fn install(&self, p: Partition) -> Option<u64> {
        debug_assert_eq!(p.k(), self.partition().k());
        self.install_elastic(p)
    }

    /// [`OwnershipTable::install`] for the elastic pool: the partition's
    /// K may differ from the current one, as long as the table has been
    /// widened first (see [`OwnershipTable::grow`]) so every part index
    /// has an ack slot and a liveness state.
    pub fn install_elastic(&self, p: Partition) -> Option<u64> {
        let mut g = self.current.write().unwrap_or_else(|e| e.into_inner());
        if self.frozen.load(Ordering::Acquire) {
            return None;
        }
        debug_assert_eq!(p.n(), g.1.n());
        debug_assert!(p.k() <= self.width(), "grow the table before installing");
        g.0 += 1;
        g.1 = Arc::new(p);
        self.version.store(g.0, Ordering::Release);
        Some(g.0)
    }

    /// PID slots tracked (live + vacant).
    pub fn width(&self) -> usize {
        self.acked.read().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// Widen the table to `k_new` PID slots. New slots start `Spawning`
    /// with their ack pre-set to the current version — a slot that owns
    /// nothing has vacuously shipped everything the map demands of it, so
    /// quiescence checks stay sound while the worker boots.
    pub fn grow(&self, k_new: usize) {
        let mut a = self.acked.write().unwrap_or_else(|e| e.into_inner());
        let mut l = self.liveness.write().unwrap_or_else(|e| e.into_inner());
        let v = self.version();
        while a.len() < k_new {
            a.push(AtomicU64::new(v));
            l.push(PidState::Spawning);
        }
    }

    /// Reuse a retired slot for a respawn: back to `Spawning`, ack reset
    /// to the current version (same vacuous-truth argument as `grow`).
    pub fn reactivate(&self, pid: usize) {
        let a = self.acked.read().unwrap_or_else(|e| e.into_inner());
        a[pid].store(self.version(), Ordering::Release);
        drop(a);
        self.set_liveness(pid, PidState::Spawning);
    }

    /// Current lifecycle state of a PID slot.
    pub fn liveness(&self, pid: usize) -> PidState {
        self.liveness.read().unwrap_or_else(|e| e.into_inner())[pid]
    }

    pub fn set_liveness(&self, pid: usize, s: PidState) {
        self.liveness.write().unwrap_or_else(|e| e.into_inner())[pid] = s;
    }

    /// Snapshot of every slot's lifecycle state.
    pub fn liveness_states(&self) -> Vec<PidState> {
        self.liveness.read().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Slots currently backed by a worker thread (everything but Retired
    /// and Dead — a crashed slot has no thread until recovery respawns it).
    pub fn live_slots(&self) -> usize {
        self.liveness
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .filter(|s| !matches!(s, PidState::Retired | PidState::Dead))
            .count()
    }

    /// Block installs (workers may still finish in-flight handoffs).
    pub fn freeze(&self) {
        self.frozen.store(true, Ordering::Release);
    }

    pub fn unfreeze(&self) {
        self.frozen.store(false, Ordering::Release);
    }

    pub fn is_frozen(&self) -> bool {
        self.frozen.load(Ordering::Acquire)
    }

    /// A worker is about to ship a handoff slice.
    pub fn begin_handoff(&self) {
        self.inflight.fetch_add(1, Ordering::AcqRel);
        self.total.fetch_add(1, Ordering::Relaxed);
    }

    /// The recipient folded the slice into its local state.
    pub fn end_handoff(&self) {
        self.inflight.fetch_sub(1, Ordering::AcqRel);
    }

    pub fn handoffs_inflight(&self) -> u64 {
        self.inflight.load(Ordering::Acquire)
    }

    pub fn handoffs_total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Worker `pid` has fully synced with `version`: every coordinate the
    /// map takes away from it was shipped (and booked via
    /// [`OwnershipTable::begin_handoff`]) *before* this ack.
    pub fn ack_version(&self, pid: usize, version: u64) {
        let a = self.acked.read().unwrap_or_else(|e| e.into_inner());
        a[pid].store(version, Ordering::Release);
    }

    /// Highest version `pid` has fully synced with.
    pub fn acked_version(&self, pid: usize) -> u64 {
        let a = self.acked.read().unwrap_or_else(|e| e.into_inner());
        a[pid].load(Ordering::Acquire)
    }

    /// Every worker has synced with `version`. Together with
    /// `handoffs_inflight() == 0` (checked AFTER this, matching the
    /// begin-before-ack ordering on the worker side) this proves no
    /// ownership migration is pending anywhere — the quiescence condition
    /// the streaming rebase needs before gathering H. Retired slots are
    /// exempt: their Ω drained to empty before their thread joined, so
    /// no version can demand anything of them.
    pub fn all_acked(&self, version: u64) -> bool {
        let a = self.acked.read().unwrap_or_else(|e| e.into_inner());
        let l = self.liveness.read().unwrap_or_else(|e| e.into_inner());
        a.iter().zip(l.iter()).all(|(a, s)| {
            matches!(s, PidState::Retired | PidState::Dead)
                || a.load(Ordering::Acquire) >= version
        })
    }

    /// Force the in-flight handoff count back to zero. ONLY the crash
    /// recovery path may call this, after its quiesce deadline expires: a
    /// slice shipped *at* a worker that then died will never fold, so the
    /// count would stay above zero forever and wedge every later
    /// quiescence proof. The lost slice's mass is not dropped — recovery
    /// recomputes every worker's fluid from `F = B + (P−I)·H` under a new
    /// epoch, which covers the moving range (with its H rewound to the
    /// last checkpoint or zero).
    pub fn clear_handoffs(&self) -> u64 {
        self.inflight.swap(0, Ordering::AcqRel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::block_coupled_matrix;

    #[test]
    fn contiguous_covers_exactly() {
        for (n, k) in [(4, 2), (10, 3), (7, 7), (100, 8)] {
            let p = Partition::contiguous(n, k).unwrap();
            p.validate().unwrap();
            assert_eq!(p.k(), k);
            assert!(p.imbalance() < 1.6);
        }
    }

    #[test]
    fn paper_partition_is_contiguous_2() {
        let p = Partition::contiguous(4, 2).unwrap();
        assert_eq!(p.part(0), &[0, 1]);
        assert_eq!(p.part(1), &[2, 3]);
    }

    #[test]
    fn round_robin_interleaves() {
        let p = Partition::round_robin(6, 2).unwrap();
        assert_eq!(p.part(0), &[0, 2, 4]);
        assert_eq!(p.part(1), &[1, 3, 5]);
        p.validate().unwrap();
    }

    #[test]
    fn invalid_inputs_rejected() {
        assert!(Partition::contiguous(3, 0).is_err());
        assert!(Partition::contiguous(3, 4).is_err());
        assert!(Partition::from_owner(vec![0, 2], 2).is_err()); // owner 2 out of range
        assert!(Partition::from_owner(vec![0, 0], 2).is_err()); // Ω_1 empty
    }

    #[test]
    fn cut_fraction_zero_on_block_diagonal() {
        let p = block_coupled_matrix(32, 2, 0.5, 0.0, 3, 1);
        let part = Partition::contiguous(32, 2).unwrap();
        assert_eq!(part.cut_fraction(&p), 0.0);
        // round-robin on the same matrix cuts heavily (≈50% of the weight
        // crosses in expectation for 2 interleaved parts)
        let rr = Partition::round_robin(32, 2).unwrap();
        assert!(rr.cut_fraction(&p) > 0.3);
    }

    #[test]
    fn greedy_improves_round_robin_cut() {
        let m = block_coupled_matrix(64, 4, 0.5, 0.1, 4, 2);
        let contiguous = Partition::contiguous(64, 4).unwrap();
        let greedy = Partition::greedy_edge_cut(&m, 4, 0.3).unwrap();
        greedy.validate().unwrap();
        // the generator's blocks are contiguous, so contiguous is near
        // optimal; greedy must not be (much) worse
        assert!(greedy.cut_fraction(&m) <= contiguous.cut_fraction(&m) + 0.05);
    }

    #[test]
    fn split_and_merge_roundtrip_cover() {
        let p = Partition::contiguous(10, 2).unwrap();
        let split = p.split_part(0).unwrap();
        assert_eq!(split.k(), 3);
        split.validate().unwrap();
        let merged = split.merge_parts(0, 2).unwrap();
        assert_eq!(merged.k(), 2);
        merged.validate().unwrap();
        // contents of part 0 back to the original
        assert_eq!(merged.part(0), p.part(0));
    }

    #[test]
    fn split_too_small_rejected() {
        let p = Partition::contiguous(2, 2).unwrap();
        assert!(p.split_part(0).is_err());
    }

    #[test]
    fn merge_bad_args_rejected() {
        let p = Partition::contiguous(6, 3).unwrap();
        assert!(p.merge_parts(1, 1).is_err());
        assert!(p.merge_parts(0, 9).is_err());
    }

    #[test]
    fn transfer_moves_coords_and_preserves_cover() {
        let p = Partition::contiguous(10, 2).unwrap();
        let next = p.transfer(&[3, 4], 1).unwrap();
        next.validate().unwrap();
        assert_eq!(next.k(), 2);
        assert_eq!(next.owner(3), 1);
        assert_eq!(next.owner(4), 1);
        assert_eq!(next.part_sizes(), vec![3, 7]);
        // moving a coord to its current owner is a no-op partition-wise
        let same = next.transfer(&[3], 1).unwrap();
        assert_eq!(same, next);
    }

    #[test]
    fn transfer_rejects_emptying_and_bad_args() {
        let p = Partition::contiguous(4, 2).unwrap();
        assert!(p.transfer(&[0, 1], 1).is_err(), "would empty Ω_0");
        assert!(p.transfer(&[0], 5).is_err(), "no such part");
        assert!(p.transfer(&[9], 1).is_err(), "coord out of range");
    }

    #[test]
    fn local_slot_map_consistent_across_operations() {
        let p = Partition::round_robin(12, 3).unwrap();
        for i in 0..12 {
            assert_eq!(p.part(p.owner(i))[p.slot(i)], i);
        }
        let moved = p.transfer(&[1, 4], 2).unwrap();
        moved.validate().unwrap();
        for i in 0..12 {
            assert_eq!(moved.part(moved.owner(i))[moved.slot(i)], i);
        }
        // move_node path (greedy refinement) must keep slots in sync too
        let m = block_coupled_matrix(32, 2, 0.5, 0.1, 3, 1);
        let greedy = Partition::greedy_edge_cut(&m, 2, 0.4).unwrap();
        greedy.validate().unwrap();
        for i in 0..32 {
            assert_eq!(greedy.part(greedy.owner(i))[greedy.slot(i)], i);
        }
    }

    #[test]
    fn elastic_transfer_grows_k_and_allows_empty_parts() {
        let p = Partition::contiguous(10, 2).unwrap();
        // spawn: move the upper half of Ω_1 to a brand-new part 2
        let coords: Vec<usize> = p.part(1)[3..].to_vec();
        let grown = p.transfer_elastic(&coords, 2).unwrap();
        assert_eq!(grown.k(), 3);
        grown.validate().unwrap();
        assert_eq!(grown.part_sizes(), vec![5, 3, 2]);
        // retire: drain part 1 entirely into part 0 — slot stays, empty
        let drain: Vec<usize> = grown.part(1).to_vec();
        let drained = grown.transfer_elastic(&drain, 0).unwrap();
        assert_eq!(drained.k(), 3);
        drained.validate().unwrap();
        assert_eq!(drained.part_sizes(), vec![8, 0, 2]);
        // respawn into the vacant slot
        let back = drained.transfer_elastic(&drained.part(0)[..2].to_vec(), 1).unwrap();
        assert_eq!(back.part_sizes(), vec![6, 2, 2]);
        // gaps rejected; strict transfer still refuses to empty a part
        assert!(drained.transfer_elastic(&[0], 5).is_err());
        assert!(Partition::contiguous(4, 2).unwrap().transfer(&[0, 1], 1).is_err());
    }

    #[test]
    fn with_k_appends_vacant_slots() {
        let p = Partition::contiguous(6, 2).unwrap();
        let wide = p.with_k(4).unwrap();
        assert_eq!(wide.k(), 4);
        assert_eq!(wide.part_sizes(), vec![3, 3, 0, 0]);
        wide.validate().unwrap();
        assert!(wide.with_k(1).is_err(), "with_k never shrinks");
        // strict from_owner still rejects the vacancy
        assert!(Partition::from_owner(wide.owners().to_vec(), 4).is_err());
    }

    #[test]
    fn ownership_table_grows_and_tracks_liveness() {
        let t = OwnershipTable::new(Partition::contiguous(8, 2).unwrap());
        assert_eq!(t.width(), 2);
        assert_eq!(t.live_slots(), 2);
        assert_eq!(t.liveness(0), PidState::Live);
        // widen for a spawn: new slot starts Spawning, pre-acked; the
        // table must be grown before a wider partition may install
        t.grow(3);
        let v0 = t.install_elastic(t.partition().with_k(3).unwrap()).unwrap();
        assert_eq!(t.width(), 3);
        assert_eq!(t.liveness(2), PidState::Spawning);
        assert!(t.all_acked(0), "pre-acked slot does not block quiescence");
        t.set_liveness(2, PidState::Live);
        // the move install now demands acks of everyone incl. the spawn
        let coords: Vec<usize> = t.partition().part(0)[..2].to_vec();
        let v = t
            .install_elastic(t.partition().transfer_elastic(&coords, 2).unwrap())
            .unwrap();
        assert_eq!(v, v0 + 1);
        assert!(!t.all_acked(v));
        t.ack_version(0, v);
        t.ack_version(1, v);
        t.ack_version(2, v);
        assert!(t.all_acked(v));
        assert_eq!(t.acked_version(2), v);
        // retire slot 2: drain install + Retired exempts it from acks
        let drain: Vec<usize> = t.partition().part(2).to_vec();
        let v = t
            .install_elastic(t.partition().transfer_elastic(&drain, 0).unwrap())
            .unwrap();
        t.ack_version(0, v);
        t.ack_version(1, v);
        t.ack_version(2, v);
        t.set_liveness(2, PidState::Retired);
        assert_eq!(t.live_slots(), 2);
        let v = t
            .install_elastic(t.partition().transfer_elastic(&[0], 1).unwrap())
            .unwrap();
        t.ack_version(0, v);
        t.ack_version(1, v);
        assert!(t.all_acked(v), "retired slots never block quiescence");
        // respawn reuses the slot
        t.reactivate(2);
        assert_eq!(t.liveness(2), PidState::Spawning);
        assert_eq!(t.acked_version(2), v);
        assert_eq!(t.liveness_states(), vec![PidState::Live, PidState::Live, PidState::Spawning]);
    }

    #[test]
    fn ownership_table_versions_and_freeze() {
        let t = OwnershipTable::new(Partition::contiguous(8, 2).unwrap());
        assert_eq!(t.version(), 0);
        let next = t.partition().transfer(&[1], 1).unwrap();
        assert_eq!(t.install(next.clone()), Some(1));
        assert_eq!(t.version(), 1);
        assert_eq!(t.owner(1), 1);
        t.freeze();
        assert!(t.is_frozen());
        assert_eq!(t.install(next), None, "frozen table rejects installs");
        assert_eq!(t.version(), 1);
        t.unfreeze();
        let (v, part) = t.snapshot();
        assert_eq!(v, 1);
        assert_eq!(part.owner(1), 1);
    }

    #[test]
    fn ownership_table_handoff_accounting() {
        let t = OwnershipTable::new(Partition::contiguous(4, 2).unwrap());
        assert_eq!(t.handoffs_inflight(), 0);
        t.begin_handoff();
        t.begin_handoff();
        assert_eq!(t.handoffs_inflight(), 2);
        assert_eq!(t.handoffs_total(), 2);
        t.end_handoff();
        t.end_handoff();
        assert_eq!(t.handoffs_inflight(), 0);
        assert_eq!(t.handoffs_total(), 2, "total never decreases");
    }

    #[test]
    fn ownership_table_version_acks() {
        let t = OwnershipTable::new(Partition::contiguous(8, 2).unwrap());
        assert!(t.all_acked(0), "initial version is trivially synced");
        let next = t.partition().transfer(&[1], 1).unwrap();
        let v = t.install(next).unwrap();
        assert!(!t.all_acked(v), "no worker has synced with v1 yet");
        t.ack_version(0, v);
        assert!(!t.all_acked(v));
        t.ack_version(1, v);
        assert!(t.all_acked(v));
    }
}
