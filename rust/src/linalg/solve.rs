//! LU direct solver (partial pivoting) — the ground-truth oracle.
//!
//! Every figure of the paper plots error against the exact limit X, so the
//! bench harness needs X to machine precision. For the N≤ a few thousand
//! dense systems in the experiments, plain LU is exactly right.

use super::DenseMat;
use crate::error::{DiterError, Result};

/// LU factorization with row pivoting: `P·A = L·U` stored compactly.
#[derive(Clone, Debug)]
pub struct LuFactors {
    /// Combined L (unit lower, below diag) and U (upper incl. diag).
    lu: DenseMat,
    /// Row permutation: `perm[i]` is the original row now at position i.
    perm: Vec<usize>,
}

/// Factor a square matrix. Fails on (near-)singularity.
pub fn lu_decompose(a: &DenseMat) -> Result<LuFactors> {
    if !a.is_square() {
        return Err(DiterError::shape(
            "lu_decompose",
            "square",
            format!("{}x{}", a.rows(), a.cols()),
        ));
    }
    let n = a.rows();
    let mut lu = a.clone();
    let mut perm: Vec<usize> = (0..n).collect();

    for k in 0..n {
        // partial pivot: largest |entry| in column k at/below row k
        let mut p = k;
        let mut best = lu[(k, k)].abs();
        for i in k + 1..n {
            let v = lu[(i, k)].abs();
            if v > best {
                best = v;
                p = i;
            }
        }
        if best < 1e-300 {
            return Err(DiterError::Singular { col: k, pivot: best });
        }
        if p != k {
            perm.swap(p, k);
            for j in 0..n {
                let tmp = lu[(k, j)];
                lu[(k, j)] = lu[(p, j)];
                lu[(p, j)] = tmp;
            }
        }
        let pivot = lu[(k, k)];
        for i in k + 1..n {
            let m = lu[(i, k)] / pivot;
            lu[(i, k)] = m;
            if m != 0.0 {
                for j in k + 1..n {
                    let ukj = lu[(k, j)];
                    lu[(i, j)] -= m * ukj;
                }
            }
        }
    }
    Ok(LuFactors { lu, perm })
}

/// Solve `A·x = b` given factors of A.
pub fn lu_solve(f: &LuFactors, b: &[f64]) -> Result<Vec<f64>> {
    let n = f.lu.rows();
    if b.len() != n {
        return Err(DiterError::shape("lu_solve", n, b.len()));
    }
    // apply permutation, forward-substitute L (unit diagonal)
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[f.perm[i]];
        for j in 0..i {
            s -= f.lu[(i, j)] * y[j];
        }
        y[i] = s;
    }
    // back-substitute U
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for j in i + 1..n {
            s -= f.lu[(i, j)] * x[j];
        }
        x[i] = s / f.lu[(i, i)];
    }
    Ok(x)
}

/// One-shot dense solve `A·x = b`.
pub fn solve_dense(a: &DenseMat, b: &[f64]) -> Result<Vec<f64>> {
    lu_solve(&lu_decompose(a)?, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vec_ops::dist_inf;
    use crate::prng::Xoshiro256pp;

    #[test]
    fn solve_identity() {
        let a = DenseMat::identity(4);
        let b = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(solve_dense(&a, &b).unwrap(), b);
    }

    #[test]
    fn solve_paper_a1() {
        // A(1) from paper §5.1
        let a = DenseMat::from_rows(&[
            &[5.0, 3.0, 0.0, 0.0],
            &[3.0, 7.0, 0.0, 0.0],
            &[0.0, 0.0, 8.0, 4.0],
            &[0.0, 0.0, 2.0, 3.0],
        ]);
        let x = solve_dense(&a, &[1.0; 4]).unwrap();
        // block 1: [5 3;3 7] x = [1;1] => x = [4,2]/26 = [2/13, 1/13]
        assert!((x[0] - 2.0 / 13.0).abs() < 1e-14);
        assert!((x[1] - 1.0 / 13.0).abs() < 1e-14);
        // block 2: [8 4;2 3] x = [1;1] => det=16, x=[-1/16? ...]
        // [3-4, 8-2]/16 = [-1/16, 6/16]
        assert!((x[2] - (-1.0 / 16.0)).abs() < 1e-14);
        assert!((x[3] - 6.0 / 16.0).abs() < 1e-14);
    }

    #[test]
    fn solve_requires_pivoting() {
        // zero leading pivot forces a row swap
        let a = DenseMat::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let x = solve_dense(&a, &[2.0, 3.0]).unwrap();
        assert_eq!(x, vec![3.0, 2.0]);
    }

    #[test]
    fn singular_detected() {
        let a = DenseMat::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(matches!(
            solve_dense(&a, &[1.0, 2.0]),
            Err(DiterError::Singular { .. })
        ));
    }

    #[test]
    fn random_roundtrip() {
        let mut rng = Xoshiro256pp::seed_from_u64(123);
        for n in [1usize, 2, 5, 20, 50] {
            let mut a = DenseMat::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    a[(i, j)] = rng.uniform(-1.0, 1.0);
                }
                a[(i, i)] += n as f64; // well-conditioned
            }
            let x_true: Vec<f64> = (0..n).map(|_| rng.uniform(-2.0, 2.0)).collect();
            let b = a.matvec(&x_true).unwrap();
            let x = solve_dense(&a, &b).unwrap();
            assert!(dist_inf(&x, &x_true) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn reuse_factors_for_many_rhs() {
        let a = DenseMat::from_rows(&[&[4.0, 1.0], &[1.0, 3.0]]);
        let f = lu_decompose(&a).unwrap();
        for b in [[1.0, 0.0], [0.0, 1.0], [2.0, 5.0]] {
            let x = lu_solve(&f, &b).unwrap();
            let back = a.matvec(&x).unwrap();
            assert!(dist_inf(&back, &b) < 1e-12);
        }
    }
}
