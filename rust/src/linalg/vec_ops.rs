//! Vector helpers used across solvers and the coordinator hot path.
//!
//! These are the innermost loops of the whole system (a PID's sweep is a
//! sequence of sparse/dense dots + axpys), so they are written to
//! auto-vectorize: plain indexed loops over equal-length slices.

/// Dot product (panics on length mismatch in debug; hot path is unchecked).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    // 4-way unrolled accumulators help the autovectorizer and reduce the
    // sequential FP dependency chain.
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    for i in chunks * 4..a.len() {
        acc += a[i] * b[i];
    }
    acc + ((s0 + s1) + (s2 + s3))
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// L1 norm.
#[inline]
pub fn norm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// L2 norm.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

/// L∞ norm.
#[inline]
pub fn norm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0, |m, v| m.max(v.abs()))
}

/// L1 distance between two vectors.
#[inline]
pub fn dist1(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

/// L∞ distance.
#[inline]
pub fn dist_inf(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).fold(0.0, |m, (x, y)| m.max((x - y).abs()))
}

/// Elementwise `a - b`.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Scale in place.
#[inline]
pub fn scale(x: &mut [f64], alpha: f64) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// Sum of entries (signed).
#[inline]
pub fn sum(x: &[f64]) -> f64 {
    x.iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        // exercise the unrolled path with lengths around the 4-chunk edge
        for n in [0usize, 1, 3, 4, 5, 7, 8, 13] {
            let a: Vec<f64> = (0..n).map(|i| i as f64 + 0.5).collect();
            let b: Vec<f64> = (0..n).map(|i| 2.0 - i as f64).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-12, "n={n}");
        }
    }

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0];
        let mut y = [10.0, 20.0];
        axpy(0.5, &x, &mut y);
        assert_eq!(y, [10.5, 21.0]);
    }

    #[test]
    fn norms_known() {
        let x = [3.0, -4.0];
        assert_eq!(norm1(&x), 7.0);
        assert_eq!(norm2(&x), 5.0);
        assert_eq!(norm_inf(&x), 4.0);
    }

    #[test]
    fn distances() {
        let a = [1.0, 2.0, 3.0];
        let b = [2.0, 0.0, 3.0];
        assert_eq!(dist1(&a, &b), 3.0);
        assert_eq!(dist_inf(&a, &b), 2.0);
        assert_eq!(sub(&a, &b), vec![-1.0, 2.0, 0.0]);
    }

    #[test]
    fn scale_and_sum() {
        let mut x = [1.0, -2.0, 3.0];
        scale(&mut x, 2.0);
        assert_eq!(x, [2.0, -4.0, 6.0]);
        assert_eq!(sum(&x), 4.0);
    }
}
