//! Dense linear algebra substrate.
//!
//! Small and boring on purpose: row-major dense matrices, the vector helpers
//! the solvers need, and an LU direct solver used to compute the *exact*
//! solution X for the error-vs-iteration plots (every figure of the paper
//! charts distance to the limit, so a ground truth is required).

mod solve;
pub mod vec_ops;

pub use solve::{lu_decompose, lu_solve, solve_dense, LuFactors};

use crate::error::{DiterError, Result};

/// Row-major dense matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl DenseMat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Identity.
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from row slices (panics if ragged).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self {
            rows: r,
            cols: c,
            data,
        }
    }

    /// Build from a flat row-major vec.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(DiterError::shape(
                "DenseMat::from_vec",
                rows * cols,
                data.len(),
            ));
        }
        Ok(Self { rows, cols, data })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Flat row-major data.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// `y = self · x`.
    pub fn matvec(&self, x: &[f64]) -> Result<Vec<f64>> {
        if x.len() != self.cols {
            return Err(DiterError::shape("matvec", self.cols, x.len()));
        }
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            y[i] = vec_ops::dot(self.row(i), x);
        }
        Ok(y)
    }

    /// Matrix product `self · other`.
    pub fn matmul(&self, other: &DenseMat) -> Result<DenseMat> {
        if self.cols != other.rows {
            return Err(DiterError::shape("matmul", self.cols, other.rows));
        }
        let mut out = DenseMat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        Ok(out)
    }

    /// Transposed copy.
    pub fn transpose(&self) -> DenseMat {
        let mut t = DenseMat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Elementwise difference `self - other`.
    pub fn sub(&self, other: &DenseMat) -> Result<DenseMat> {
        if self.rows != other.rows || self.cols != other.cols {
            return Err(DiterError::shape(
                "DenseMat::sub",
                format!("{}x{}", self.rows, self.cols),
                format!("{}x{}", other.rows, other.cols),
            ));
        }
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Ok(DenseMat {
            rows: self.rows,
            cols: self.cols,
            data,
        })
    }

    /// Max row sum of |entries| — the induced L∞ norm, a cheap upper bound
    /// on the spectral radius.
    pub fn inf_norm(&self) -> f64 {
        (0..self.rows)
            .map(|i| self.row(i).iter().map(|v| v.abs()).sum::<f64>())
            .fold(0.0, f64::max)
    }

    /// Max column sum of |entries| — induced L1 norm, also bounds ρ(P).
    pub fn one_norm(&self) -> f64 {
        let mut sums = vec![0.0; self.cols];
        for i in 0..self.rows {
            for (j, v) in self.row(i).iter().enumerate() {
                sums[j] += v.abs();
            }
        }
        sums.into_iter().fold(0.0, f64::max)
    }
}

impl std::ops::Index<(usize, usize)> for DenseMat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for DenseMat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matvec() {
        let i = DenseMat::identity(3);
        let x = vec![1.0, 2.0, 3.0];
        assert_eq!(i.matvec(&x).unwrap(), x);
    }

    #[test]
    fn matvec_known() {
        let m = DenseMat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(m.matvec(&[1.0, 1.0]).unwrap(), vec![3.0, 7.0]);
    }

    #[test]
    fn matvec_shape_error() {
        let m = DenseMat::zeros(2, 3);
        assert!(m.matvec(&[1.0, 2.0]).is_err());
    }

    #[test]
    fn matmul_known() {
        let a = DenseMat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = DenseMat::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let c = a.matmul(&b).unwrap();
        assert_eq!(c, DenseMat::from_rows(&[&[2.0, 1.0], &[4.0, 3.0]]));
    }

    #[test]
    fn transpose_roundtrip() {
        let a = DenseMat::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn norms() {
        let a = DenseMat::from_rows(&[&[1.0, -2.0], &[0.5, 0.25]]);
        assert_eq!(a.inf_norm(), 3.0);
        assert_eq!(a.one_norm(), 2.25);
    }

    #[test]
    fn from_vec_shape_check() {
        assert!(DenseMat::from_vec(2, 2, vec![1.0; 3]).is_err());
        assert!(DenseMat::from_vec(2, 2, vec![1.0; 4]).is_ok());
    }
}
