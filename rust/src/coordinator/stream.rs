//! Streaming D-iteration engine: a long-running incremental solve that
//! keeps V2 fluid workers diffusing while the graph mutates underneath
//! them — §3.2's live matrix evolution promoted from a pair of free
//! functions to a subsystem.
//!
//! ## Epoch / rebase protocol
//!
//! Two selectable protocols drive the epoch transition
//! ([`super::RebaseMode`], `stream --rebase gather|local`): the
//! **gather** protocol below (PR 1's leader-side rebase), and the
//! **local** protocol (§3.1 / V1 full-history: the coordinator
//! broadcasts only the dirty-column delta, workers exchange halo H
//! values peer-to-peer and recompute their own fluid slices in place via
//! `F' = F + (P'−P)·H` — no leader gather, no scatter, and non-dirty
//! diffusion never stops; see DESIGN.md §7).
//!
//! The engine owns one persistent worker thread per PID (the shared
//! [`super::worker::WorkerCore`] loop, same partial-state fluid scheme as
//! [`super::v2`]) plus a coordinator-side control channel. Applying a
//! mutation batch advances an **epoch**:
//!
//! 1. **Quiesce handoffs** — with live repartitioning the coordinate →
//!    PID map is dynamic: the engine freezes the
//!    [`crate::partition::OwnershipTable`] (no new rebalances) and waits
//!    for `handoffs_inflight == 0`, so no `(H, F)` slice is riding the
//!    bus when the history is gathered. Workers keep diffusing.
//! 2. **Checkpoint** — each worker is asked to pause; it replies with the
//!    coordinate range it *currently holds* and its history slice `H_k`
//!    over that range, and waits. Any H snapshot is a valid rebase point:
//!    the §3.2 identity `B' = P'·H + B − H` holds for *whatever* H the
//!    computation has reached, converged or not.
//! 3. **Rebuild** — the mutated [`MutableDigraph`] re-derives the
//!    column-renormalized PageRank system `(P', B)` (patching only the
//!    mutated columns of the cached matrix).
//! 4. **Rebase + scatter** — the coordinator assembles the full H,
//!    computes each PID's slice of the new fluid `F' = B' = P'·H + B − H`
//!    over its held range via [`update::rebase_b_slice`] (only those rows
//!    of P' are read), and resumes every worker with its slice. Workers
//!    keep their H — **the computation never restarts**.
//! 5. **Converge** — workers diffuse under the new matrix until the
//!    monitored total fluid drops below tolerance; with `cfg.adaptive`
//!    set, the §4.3 rebalance driver runs inside this wait and may move
//!    ownership between PIDs mid-epoch.
//!
//! ## No bus draining
//!
//! Fluid parcels are tagged with their epoch. The rebase does **not** wait
//! for the bus to empty: B' is a function of H alone, so every parcel
//! from an older epoch is obsolete by construction — receivers discard it
//! on arrival and commit its mass so the global in-flight account clears.
//! Parcels from a *newer* epoch (a peer resumed first) are stashed
//! uncommitted and applied once the local epoch catches up, so no
//! new-epoch fluid is ever lost and the monitor can never observe an
//! under-count.

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::adaptive::AdaptiveDriver;
use super::monitor::MonitorState;
use super::pool::{PoolStats, WorkerPool};
use super::update;
use super::{DistributedConfig, DistributedSolution, RebaseMode};
use crate::error::{DiterError, Result};
use crate::graph::{MutableDigraph, Mutation};
use crate::linalg::vec_ops::norm1;
use crate::metrics::{ConvergenceTrace, MetricSet, RateMeter};
use crate::partition::{OwnershipTable, Partition};
use crate::solver::FixedPointProblem;
use crate::transport::BusMonitor;

/// Report for one epoch (one mutation batch, or the initial solve).
#[derive(Clone, Debug)]
pub struct EpochReport {
    /// epoch id (0 = initial cold solve)
    pub epoch: u64,
    /// mutations that actually changed the graph this epoch
    pub mutations_applied: usize,
    /// the converged state, costed over THIS epoch only (updates, wall,
    /// parallel cost and trace all restart at the rebase)
    pub solution: DistributedSolution,
}

/// Summary returned by [`StreamingEngine::finish`].
#[derive(Clone, Debug)]
pub struct StreamSummary {
    /// final assembled solution with whole-run cumulative counters
    pub final_solution: DistributedSolution,
    /// epochs completed (rebases + the initial solve)
    pub epochs: u64,
    /// total mutations that changed the graph
    pub mutations_applied: u64,
    /// EWMA steady-state updates/sec across epochs
    pub steady_updates_per_sec: f64,
}

/// The streaming engine: owns the evolving graph, the worker pool (the
/// persistent V2 workers behind their versioned ownership table), and
/// the epoch protocol.
pub struct StreamingEngine {
    graph: MutableDigraph,
    damping: f64,
    patch_dangling: bool,
    cfg: DistributedConfig,
    pool: WorkerPool,
    table: Arc<OwnershipTable>,
    problem: Arc<FixedPointProblem>,
    shared: Arc<MonitorState>,
    bus_mon: BusMonitor,
    bus_metrics: Arc<MetricSet>,
    driver: Option<AdaptiveDriver>,
    epoch: u64,
    /// per-PID update counters at the current epoch's start
    epoch_base: Vec<u64>,
    epochs_done: u64,
    mutations_applied: u64,
    rate: RateMeter,
    /// wall seconds of the most recent epoch transition (quiesce through
    /// resume/acks) — the quantity the gather-vs-local bench head-to-head
    /// compares
    last_rebase_secs: f64,
}

impl StreamingEngine {
    /// Spawn the engine over `graph` (epoch 0 starts immediately from the
    /// cold state `H = 0, F = B`; call [`StreamingEngine::converge`] to
    /// wait for the initial solve). The partition in `cfg` must cover the
    /// graph's coordinate capacity.
    pub fn new(
        graph: MutableDigraph,
        damping: f64,
        patch_dangling: bool,
        cfg: DistributedConfig,
    ) -> Result<StreamingEngine> {
        let mut graph = graph;
        let n = graph.n();
        if cfg.partition.n() != n {
            return Err(DiterError::shape("StreamingEngine partition", n, cfg.partition.n()));
        }
        let sys = graph.pagerank_system(damping, patch_dangling)?;
        let problem = Arc::new(FixedPointProblem::new(sys.matrix, sys.b)?);
        let k = cfg.partition.k();
        // the elastic pool subsumes the shed-only driver: its scheduler
        // sheds to the fastest peer once it is out of spawn headroom, and
        // the driver's fixed-K window state cannot follow a growing pool
        let driver = if cfg.elastic.is_some() {
            None
        } else {
            cfg.adaptive
                .as_ref()
                .map(|a| AdaptiveDriver::new(a, k, cfg.tol))
        };
        let pool = WorkerPool::new(problem.clone(), cfg.clone())?;
        let table = pool.table().clone();
        let shared = pool.state().clone();
        let bus_mon = pool.monitor();
        let bus_metrics = pool.metrics().clone();
        let epoch_base = shared.update_counts();
        Ok(StreamingEngine {
            graph,
            damping,
            patch_dangling,
            cfg,
            pool,
            table,
            problem,
            shared,
            bus_mon,
            bus_metrics,
            driver,
            epoch: 0,
            epoch_base,
            epochs_done: 0,
            mutations_applied: 0,
            rate: RateMeter::new(0.4),
            last_rebase_secs: 0.0,
        })
    }

    /// The current epoch id.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Fluid lanes per slot (1 = the single-query layout; ≥ 2 when a
    /// [`super::query::QuerySet`] multiplexes extra RHS vectors through
    /// the same workers — DESIGN.md §10).
    pub fn lanes(&self) -> usize {
        self.cfg.lanes.max(1)
    }

    /// The fabric metric set (worker + pool + query counters).
    pub fn metrics(&self) -> &Arc<MetricSet> {
        &self.bus_metrics
    }

    /// Read-only view of the evolving graph.
    pub fn graph(&self) -> &MutableDigraph {
        &self.graph
    }

    /// The fixed-point system of the current epoch.
    pub fn problem(&self) -> &FixedPointProblem {
        &self.problem
    }

    /// The current coordinate → PID ownership map (moves under adaptive
    /// repartitioning).
    pub fn ownership(&self) -> Arc<Partition> {
        self.table.partition()
    }

    /// Ownership handoffs shipped so far.
    pub fn handoffs_total(&self) -> u64 {
        self.table.handoffs_total()
    }

    /// Elastic pool lifecycle counters (all zero on a fixed pool).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Per-PID cumulative scalar-update counts.
    pub fn update_counts(&self) -> Vec<u64> {
        self.shared.update_counts()
    }

    /// EWMA steady-state updates/sec over completed epochs.
    pub fn steady_updates_per_sec(&self) -> f64 {
        self.rate.rate().unwrap_or(0.0)
    }

    /// Wall seconds the most recent epoch transition took (0.0 before the
    /// first mutation batch): handoff quiesce through worker resume. This
    /// is the latency the `--rebase local|gather` protocols trade — the
    /// reconvergence after it is common to both.
    pub fn last_rebase_secs(&self) -> f64 {
        self.last_rebase_secs
    }

    /// Mutable access to the worker pool, for tests and external
    /// lifecycle drivers (the conservation fuzz harness fires
    /// spawn/retire/handoff events directly between epochs). Production
    /// policy goes through [`super::ElasticConfig`] and the poll loop.
    pub fn pool_mut(&mut self) -> &mut WorkerPool {
        &mut self.pool
    }

    /// Change the per-epoch convergence deadline (streaming deployments
    /// often want a batch SLA rather than one global wall cap).
    pub fn set_max_wall(&mut self, max_wall: Duration) {
        self.cfg.max_wall = max_wall;
    }

    /// Apply a mutation batch: mutate the graph, rebase the running
    /// computation onto the new matrix (without restarting it and without
    /// draining the bus), then wait for reconvergence.
    pub fn apply_batch(&mut self, batch: &[Mutation]) -> Result<EpochReport> {
        let applied = batch.iter().filter(|m| self.graph.apply(m)).count();
        self.mutations_applied += applied as u64;
        if applied > 0 {
            self.rebase()?;
        }
        let mut report = self.converge()?;
        report.mutations_applied = applied;
        Ok(report)
    }

    /// [`StreamingEngine::apply_batch`] without the convergence wait:
    /// mutate the graph and rebase the running computation, then return
    /// immediately. The serving loop ([`super::query::ServeEngine`])
    /// uses this so admission keeps flowing while the new epoch's fluid
    /// settles; callers judge per-lane convergence themselves.
    pub fn apply_batch_async(&mut self, batch: &[Mutation]) -> Result<usize> {
        let applied = batch.iter().filter(|m| self.graph.apply(m)).count();
        self.mutations_applied += applied as u64;
        if applied > 0 {
            self.rebase()?;
        }
        Ok(applied)
    }

    /// One non-blocking monitor tick: read the global fluid estimate,
    /// run the adaptive driver and the elastic pool scheduler once, and
    /// return the observed total. This is the body of [`converge`]'s
    /// wait loop exposed for callers that interleave their own work
    /// (the serving loop) with the engine's housekeeping.
    ///
    /// [`converge`]: StreamingEngine::converge
    pub fn pump(&mut self) -> f64 {
        let total = self.shared.published_total() + self.bus_mon.inflight_or_zero();
        if let Some(d) = self.driver.as_mut() {
            d.poll(
                &self.table,
                &self.shared.update_counts(),
                &self.shared.published_values(),
                total,
                &self.bus_metrics,
                Some(self.problem.matrix()),
            );
        }
        self.pool.poll(total);
        total
    }

    /// Wait for the current epoch to reach the configured tolerance and
    /// return its report (epoch-scoped cost/wall/trace). With adaptation
    /// enabled, the §4.3 rebalance driver runs inside this wait.
    pub fn converge(&mut self) -> Result<EpochReport> {
        let n = self.problem.n();
        let t0 = Instant::now();
        let deadline = t0 + self.cfg.max_wall;
        let poll = Duration::from_micros(200);
        let stable_needed = 3usize;
        let mut stable = 0usize;
        let mut converged = false;
        let mut trace = ConvergenceTrace::new(format!("stream-epoch-{}", self.epoch));
        let tol = self.cfg.tol;
        loop {
            let total = self.shared.published_total() + self.bus_mon.inflight_or_zero();
            let cost = self.epoch_cost(n);
            if total.is_finite() {
                trace.push(cost, total);
            }
            if let Some(d) = self.driver.as_mut() {
                d.poll(
                    &self.table,
                    &self.shared.update_counts(),
                    &self.shared.published_values(),
                    total,
                    &self.bus_metrics,
                    Some(self.problem.matrix()),
                );
            }
            // the elastic scheduler + crash tolerance: spawn for
            // stragglers, retire the idle, detect/recover worker deaths
            // — lifecycle transitions run between polls while the
            // diffusion continues. A completed recovery restarts the
            // stability window: the reconstructed fluid re-converges
            // from checkpoint H, so a stale sub-tol reading from just
            // before the crash must not count toward quiescence.
            if self.pool.poll(total) {
                stable = 0;
            }
            // quiescence needs every sent parcel applied or discarded —
            // stashed future-epoch parcels stay uncommitted, so a rebase
            // racing this check can never fake convergence; the same
            // check covers in-flight handoff slices (they ride the bus)
            if total < tol && self.bus_mon.undelivered() == 0 {
                stable += 1;
                if stable >= stable_needed {
                    converged = true;
                    break;
                }
            } else {
                stable = 0;
            }
            if Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(poll);
        }
        let wall = t0.elapsed().as_secs_f64();
        let x = self.gather()?;
        let residual = self.problem.residual_norm(&x);
        let counts = self.shared.update_counts();
        let epoch_updates: u64 = counts
            .iter()
            .zip(&self.epoch_base)
            .map(|(now, base)| now - base)
            .sum();
        let cost = self.epoch_cost(n);
        self.rate.record(epoch_updates, wall);
        self.epochs_done += 1;
        // subsequent converge() calls report from here
        self.epoch_base = counts;
        Ok(EpochReport {
            epoch: self.epoch,
            mutations_applied: 0,
            solution: DistributedSolution {
                residual,
                converged: converged && residual <= self.cfg.tol * 10.0,
                cost,
                total_updates: epoch_updates,
                wall_secs: wall,
                trace,
                metrics: self.bus_metrics.snapshot(),
                x,
            },
        })
    }

    /// Assemble the current solution estimate without pausing the workers.
    pub fn solution(&self) -> Result<Vec<f64>> {
        self.gather()
    }

    /// Assemble one lane's solution estimate (lane 0 = the base system;
    /// lanes ≥ 1 = the query tenants) without pausing the workers. The
    /// snapshot H slices are lane-blocked; this reads the lane's stride.
    pub fn gather_lane(&self, lane: usize) -> Result<Vec<f64>> {
        let lanes = self.lanes();
        assert!(lane < lanes, "lane {lane} out of range ({lanes} lanes)");
        let n = self.problem.n();
        self.quiesce_handoffs(Duration::from_secs(2));
        let mut x = vec![0.0; n];
        for (_kk, coords, slice) in self.pool.snapshot()? {
            debug_assert_eq!(slice.len(), coords.len() * lanes);
            for (t, &i) in coords.iter().enumerate() {
                x[i] = slice[t * lanes + lane];
            }
        }
        Ok(x)
    }

    /// Shut the workers down and return the whole-run summary. The
    /// returned solution is lane 0 (the base system); query-lane
    /// readouts happen through [`StreamingEngine::gather_lane`] while
    /// the engine is live.
    pub fn finish(self) -> Result<StreamSummary> {
        let n = self.problem.n();
        let lanes = self.lanes();
        let mut x = vec![0.0; n];
        for (owned, values) in self.pool.finish()? {
            for (t, &i) in owned.iter().enumerate() {
                x[i] = values[t * lanes];
            }
        }
        let residual = self.problem.residual_norm(&x);
        let counts = self.shared.update_counts();
        let total_updates: u64 = counts.iter().sum();
        let cost = counts.iter().copied().max().unwrap_or(0) as f64 / n as f64;
        Ok(StreamSummary {
            final_solution: DistributedSolution {
                residual,
                converged: residual <= self.cfg.tol * 10.0,
                cost,
                total_updates,
                wall_secs: 0.0,
                trace: ConvergenceTrace::new("stream-final"),
                metrics: self.bus_metrics.snapshot(),
                x,
            },
            epochs: self.epochs_done,
            mutations_applied: self.mutations_applied,
            steady_updates_per_sec: self.rate.rate().unwrap_or(0.0),
        })
    }

    /// Parallel cost of the current epoch so far (max PID delta / N).
    fn epoch_cost(&self, n: usize) -> f64 {
        self.shared
            .update_counts()
            .iter()
            .zip(&self.epoch_base)
            .map(|(now, base)| now - base)
            .max()
            .unwrap_or(0) as f64
            / n as f64
    }

    /// The epoch transition. Common to both protocols: quiesce handoffs,
    /// rebuild the system from the mutated graph. Then either the
    /// **gather** protocol (checkpoint → leader-side per-PID rebase →
    /// scatter/resume, the PR 1 scheme) or the **local** protocol
    /// (broadcast the mutation delta; workers exchange halo H values and
    /// rebase their own slices in place — no leader gather, no scatter,
    /// non-dirty diffusion never stops). See the module docs and
    /// DESIGN.md §7 for the invariants.
    fn rebase(&mut self) -> Result<()> {
        // no ownership installs while the epoch transition is in progress
        // (this also parks the elastic scheduler: its poll is a no-op on
        // a frozen table, so no spawn/retire can straddle the rebase)
        let t0 = Instant::now();
        // a worker that died since the last tick must be detected and
        // recovered BEFORE the freeze: the transition checkpoints (or
        // broadcasts to) every occupied slot and would error on a dead
        // one — and a dead worker can neither ack the frozen version nor
        // fold a handoff, so the quiesce below would time out anyway
        let total = self.shared.published_total() + self.bus_mon.inflight_or_zero();
        self.pool.poll(total);
        self.table.freeze();
        let r = self.rebase_frozen();
        self.table.unfreeze();
        self.last_rebase_secs = t0.elapsed().as_secs_f64();
        r
    }

    fn rebase_frozen(&mut self) -> Result<()> {
        let n = self.problem.n();
        // 1. wait until every worker has synced with the final (frozen)
        //    ownership version AND every shipped (H, F) slice has folded
        //    into its recipient — only then is the held-coordinate cover
        //    (and, for gather, the assembled history) guaranteed
        //    complete. Workers keep running meanwhile (they are the ones
        //    applying the handoffs). The ack must be checked BEFORE the
        //    inflight count: workers book begin_handoff before acking, so
        //    this order can never observe a spurious zero.
        let v = self.table.version();
        let quiesce_deadline = Instant::now() + Duration::from_secs(10);
        while !(self.table.all_acked(v) && self.table.handoffs_inflight() == 0) {
            if Instant::now() >= quiesce_deadline {
                return Err(DiterError::Coordinator(
                    "handoff quiesce timed out before rebase".into(),
                ));
            }
            std::thread::sleep(Duration::from_micros(100));
        }
        // 2. rebuild the system from the mutated graph; the incremental
        //    build reports which columns it recomputed — the workers'
        //    LocalSystem patch set, and the local protocol's whole
        //    mutation delta
        let sys = self.graph.pagerank_system(self.damping, self.patch_dangling)?;
        let dirty = self.graph.last_build_dirty_shared();
        let problem = Arc::new(FixedPointProblem::new(sys.matrix, sys.b)?);
        // crash recovery bumps the pool's epoch behind the engine's back
        // (its fence against crash-era parcels) — re-sync before the
        // increment so the new epoch is strictly ahead of both counters
        self.epoch = self.epoch.max(self.pool.epoch());
        self.epoch += 1;
        match self.cfg.rebase {
            RebaseMode::Local => {
                // §3.1 (V1): workers hold the history; each recomputes its
                // own slice from the delta. A cold-cache build (dirty
                // unknown) degenerates to the literal full-history
                // exchange — every column treated as changed — which is
                // still exact, just no longer cheap.
                let dirty = dirty.unwrap_or_else(|| Arc::new((0..n).collect::<Vec<usize>>()));
                self.pool.rebase_local(self.epoch, problem.clone(), dirty)?;
            }
            RebaseMode::Gather => self.rebase_gather(n, problem.clone(), dirty)?,
        }
        self.problem = problem;
        self.epoch_base = self.shared.update_counts();
        Ok(())
    }

    /// The PR 1 leader protocol: checkpoint every live worker (they pause
    /// as the requests land; workers still running only produce old-epoch
    /// parcels, which the new epoch discards on arrival), assemble the
    /// full H, compute each PID's new fluid slice, scatter and resume.
    /// With an elastic pool the worker set is whatever survived
    /// spawn/retire — the replies carry the coords, and the quiesce in
    /// `rebase_frozen` guarantees they form an exact cover.
    fn rebase_gather(
        &mut self,
        n: usize,
        problem: Arc<FixedPointProblem>,
        dirty: Option<Arc<Vec<usize>>>,
    ) -> Result<()> {
        let lanes = self.lanes();
        let checkpointed = self.pool.checkpoint()?;
        // deinterleave the lane-blocked H slices into one full H per lane
        let mut hs = vec![vec![0.0; n]; lanes];
        let mut held: Vec<(usize, Vec<usize>)> = Vec::with_capacity(checkpointed.len());
        for (kk, coords, slice) in checkpointed {
            debug_assert_eq!(slice.len(), coords.len() * lanes);
            for (t, &i) in coords.iter().enumerate() {
                for (l, h) in hs.iter_mut().enumerate() {
                    h[i] = slice[t * lanes + l];
                }
            }
            held.push((kk, coords));
        }
        // per-lane B: lane 0 is the base system's RHS; each query lane's
        // is its tenant's seed vector (linearity in B is what lets every
        // lane rebase through the same matrix walk). The claim-all marks
        // any still-pending seeds claimed — the recomputed F' = P'·H+B−H
        // injects them, so workers must not claim them again.
        let qs = self.cfg.queries.clone();
        let lane_b: Vec<Vec<f64>> = (0..lanes)
            .map(|l| {
                if l == 0 {
                    problem.b().to_vec()
                } else {
                    qs.as_ref()
                        .and_then(|q| q.lane_b_claim_all(l, n))
                        .unwrap_or_else(|| vec![0.0; n])
                }
            })
            .collect();
        let mut slices = Vec::with_capacity(held.len());
        for (kk, coords) in held {
            // the leader-side round-trip the local protocol eliminates —
            // the scenario matrix asserts this counter stays 0 there
            self.bus_metrics.add("rebase_gather_coords", coords.len() as u64);
            let mut f_slice = vec![0.0; coords.len() * lanes];
            let mut aggregate = 0.0;
            for l in 0..lanes {
                let f_l =
                    update::rebase_b_slice(problem.matrix(), &coords, &hs[l], &lane_b[l]);
                let mass = norm1(&f_l);
                aggregate += mass;
                if l >= 1 {
                    // pre-publish the lane account too: the tenant's
                    // unclaimed mass was just zeroed by the claim-all,
                    // and its workers are paused — this keeps lane_total
                    // erring high across the swap
                    if let Some(q) = qs.as_ref() {
                        q.publish_lane(kk, l, mass);
                    }
                }
                for (t, v) in f_l.into_iter().enumerate() {
                    f_slice[t * lanes + l] = v;
                }
            }
            // pre-publish so the monitor can't see a stale near-zero total
            self.shared.publish(kk, aggregate);
            slices.push((kk, f_slice));
        }
        self.pool.resume(self.epoch, problem, slices, dirty)
    }

    /// Gather the assembled lane-0 H from all workers without pausing
    /// them.
    fn gather(&self) -> Result<Vec<f64>> {
        self.gather_lane(0)
    }

    /// Best-effort handoff quiesce before a snapshot: a handoff slice in
    /// flight is held by neither worker, so snapshotting mid-migration
    /// would read zeros for the moving range. No installs can race this
    /// (the adaptive driver and the pool scheduler run on this same
    /// thread), so waiting terminates; the deadline only guards against
    /// a wedged worker.
    fn quiesce_handoffs(&self, deadline: Duration) {
        let v = self.table.version();
        let until = Instant::now() + deadline;
        while !(self.table.all_acked(v) && self.table.handoffs_inflight() == 0)
            && Instant::now() < until
        {
            std::thread::sleep(Duration::from_micros(100));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{power_law_web_graph, ChurnModel, MutationStream};
    use crate::linalg::vec_ops::dist1;
    use crate::solver::{DIteration, SequenceKind, SolveOptions, Solver};

    fn engine(n: usize, k: usize, seed: u64) -> StreamingEngine {
        let g = power_law_web_graph(n, 5, 0.1, seed);
        let mg = MutableDigraph::from_digraph(&g, n);
        let cfg = DistributedConfig::new(Partition::contiguous(n, k).unwrap())
            .with_tol(1e-10)
            .with_seed(seed);
        StreamingEngine::new(mg, 0.85, true, cfg).unwrap()
    }

    fn cold_solution(problem: &FixedPointProblem) -> Vec<f64> {
        let opts = SolveOptions {
            tol: 1e-13,
            max_cost: 200_000.0,
            trace_every: 0.0,
            exact: None,
        };
        DIteration::fluid_cyclic().solve(problem, &opts).unwrap().x
    }

    #[test]
    fn initial_epoch_matches_cold_solve() {
        let mut eng = engine(120, 3, 11);
        let report = eng.converge().unwrap();
        assert!(report.solution.converged, "residual {}", report.solution.residual);
        let want = cold_solution(eng.problem());
        assert!(dist1(&report.solution.x, &want) < 1e-7);
        let summary = eng.finish().unwrap();
        assert_eq!(summary.epochs, 1);
    }

    #[test]
    fn empty_batch_is_a_noop_epoch() {
        let mut eng = engine(80, 2, 3);
        eng.converge().unwrap();
        let report = eng.apply_batch(&[]).unwrap();
        assert_eq!(report.epoch, 0, "no graph change, no rebase");
        assert_eq!(report.mutations_applied, 0);
        assert!(report.solution.converged);
        eng.finish().unwrap();
    }

    #[test]
    fn mutation_batch_reconverges_to_new_fixed_point() {
        let mut eng = engine(100, 4, 7);
        eng.converge().unwrap();
        let batch = vec![
            Mutation::EdgeInsert {
                from: 3,
                to: 42,
                weight: 1.0,
            },
            Mutation::EdgeInsert {
                from: 42,
                to: 3,
                weight: 2.0,
            },
            Mutation::EdgeDelete { from: 3, to: 42 },
        ];
        let report = eng.apply_batch(&batch).unwrap();
        assert_eq!(report.epoch, 1);
        assert!(report.mutations_applied >= 2);
        assert!(report.solution.converged, "residual {}", report.solution.residual);
        let want = cold_solution(eng.problem());
        assert!(
            dist1(&report.solution.x, &want) < 1e-7,
            "Δ₁ = {}",
            dist1(&report.solution.x, &want)
        );
        eng.finish().unwrap();
    }

    #[test]
    fn mid_flight_rebase_conserves_the_computation() {
        // rebase BEFORE the initial solve converges: the checkpointed H is
        // a partial state, and the §3.2 identity must still land the run
        // on the new system's exact fixed point (fluid conservation across
        // the epoch boundary).
        let mut eng = engine(100, 4, 13);
        // no converge() here — workers are mid-diffusion
        let mut stream = MutationStream::new(ChurnModel::RandomRewire, 5);
        let batch = stream.next_batch(eng.graph(), 12);
        let report = eng.apply_batch(&batch).unwrap();
        assert!(report.solution.converged, "residual {}", report.solution.residual);
        let want = cold_solution(eng.problem());
        assert!(
            dist1(&report.solution.x, &want) < 1e-7,
            "Δ₁ = {}",
            dist1(&report.solution.x, &want)
        );
        eng.finish().unwrap();
    }

    #[test]
    fn local_rebase_reconverges_to_new_fixed_point() {
        let n = 100;
        let g = power_law_web_graph(n, 5, 0.1, 7);
        let mg = MutableDigraph::from_digraph(&g, n);
        let cfg = DistributedConfig::new(Partition::contiguous(n, 4).unwrap())
            .with_tol(1e-10)
            .with_seed(7)
            .with_rebase(RebaseMode::Local);
        let mut eng = StreamingEngine::new(mg, 0.85, true, cfg).unwrap();
        eng.converge().unwrap();
        let mut stream = MutationStream::new(ChurnModel::RandomRewire, 19);
        for _ in 0..2 {
            let batch = stream.next_batch(eng.graph(), 10);
            let report = eng.apply_batch(&batch).unwrap();
            assert!(report.solution.converged, "residual {}", report.solution.residual);
            // the defining property: no leader gather/scatter ever ran
            assert_eq!(report.solution.metrics["rebase_gather_coords"], 0);
            let want = cold_solution(eng.problem());
            assert!(
                dist1(&report.solution.x, &want) < 1e-7,
                "Δ₁ = {}",
                dist1(&report.solution.x, &want)
            );
        }
        assert!(eng.last_rebase_secs() > 0.0);
        eng.finish().unwrap();
    }

    #[test]
    fn local_rebase_mid_flight_conserves_the_computation() {
        // the local transition fires BEFORE the initial solve converges:
        // halo snapshots are then genuinely partial history, and the
        // delta form must still land on the new system's fixed point
        let n = 100;
        let g = power_law_web_graph(n, 5, 0.1, 13);
        let mg = MutableDigraph::from_digraph(&g, n);
        let cfg = DistributedConfig::new(Partition::contiguous(n, 4).unwrap())
            .with_tol(1e-10)
            .with_seed(13)
            .with_rebase(RebaseMode::Local);
        let mut eng = StreamingEngine::new(mg, 0.85, true, cfg).unwrap();
        // no converge() here — workers are mid-diffusion
        let mut stream = MutationStream::new(ChurnModel::RandomRewire, 5);
        let batch = stream.next_batch(eng.graph(), 12);
        let report = eng.apply_batch(&batch).unwrap();
        assert!(report.solution.converged, "residual {}", report.solution.residual);
        let want = cold_solution(eng.problem());
        assert!(
            dist1(&report.solution.x, &want) < 1e-7,
            "Δ₁ = {}",
            dist1(&report.solution.x, &want)
        );
        eng.finish().unwrap();
    }

    #[test]
    fn greedy_sequence_streams_too() {
        let n = 90;
        let g = power_law_web_graph(n, 5, 0.1, 21);
        let mg = MutableDigraph::from_digraph(&g, n);
        let cfg = DistributedConfig::new(Partition::contiguous(n, 3).unwrap())
            .with_tol(1e-10)
            .with_sequence(SequenceKind::GreedyMaxFluid)
            .with_seed(21);
        let mut eng = StreamingEngine::new(mg, 0.85, true, cfg).unwrap();
        eng.converge().unwrap();
        let batch = vec![Mutation::EdgeInsert {
            from: 1,
            to: 50,
            weight: 3.0,
        }];
        let report = eng.apply_batch(&batch).unwrap();
        assert!(report.solution.converged);
        let want = cold_solution(eng.problem());
        assert!(dist1(&report.solution.x, &want) < 1e-7);
        eng.finish().unwrap();
    }

    #[test]
    fn partition_mismatch_rejected() {
        let g = power_law_web_graph(50, 4, 0.1, 2);
        let mg = MutableDigraph::from_digraph(&g, 50);
        let cfg = DistributedConfig::new(Partition::contiguous(40, 2).unwrap());
        assert!(StreamingEngine::new(mg, 0.85, true, cfg).is_err());
    }
}
