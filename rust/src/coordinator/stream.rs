//! Streaming D-iteration engine: a long-running incremental solve that
//! keeps V2 fluid workers diffusing while the graph mutates underneath
//! them — §3.2's live matrix evolution promoted from a pair of free
//! functions to a subsystem.
//!
//! ## Epoch / rebase protocol
//!
//! The engine owns one persistent worker thread per PID (the same
//! partial-state fluid scheme as [`super::v2`]) plus a coordinator-side
//! control channel. Applying a mutation batch advances an **epoch**:
//!
//! 1. **Checkpoint** — each worker is asked to pause; it replies with its
//!    owned history slice `H_k` and waits. Any H snapshot is a valid
//!    rebase point: the §3.2 identity `B' = P'·H + B − H` holds for
//!    *whatever* H the computation has reached, converged or not.
//! 2. **Rebuild** — the mutated [`MutableDigraph`] re-derives the
//!    column-renormalized PageRank system `(P', B)`.
//! 3. **Rebase + scatter** — the coordinator assembles the full H,
//!    computes each PID's slice of the new fluid `F' = B' = P'·H + B − H`
//!    via [`update::rebase_b_slice`] (the per-PID form: only the PID's
//!    rows of P' are read), and resumes every worker with its slice.
//!    Workers keep their H — **the computation never restarts**.
//! 4. **Converge** — workers diffuse under the new matrix until the
//!    monitored total fluid drops below tolerance.
//!
//! ## No bus draining
//!
//! Fluid parcels are tagged with their epoch. The rebase does **not** wait
//! for the bus to empty: B' is a function of H alone, so every parcel
//! from an older epoch is obsolete by construction — receivers discard it
//! on arrival and commit its mass so the global in-flight account clears.
//! Parcels from a *newer* epoch (a peer resumed first) are stashed
//! uncommitted and applied once the local epoch catches up, so no
//! new-epoch fluid is ever lost and the monitor can never observe an
//! under-count.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::update;
use super::{DistributedConfig, DistributedSolution};
use crate::error::{DiterError, Result};
use crate::graph::{MutableDigraph, Mutation};
use crate::linalg::vec_ops::norm1;
use crate::metrics::{ConvergenceTrace, MetricSet, RateMeter};
use crate::partition::Partition;
use crate::solver::{FixedPointProblem, GreedyQueue, SequenceKind, SequenceState};
use crate::transport::{
    bus, monitor_of, AtomicF64, BusConfig, BusMonitor, CoalesceBuffer, Endpoint, Received,
};

/// Epoch-tagged V2 fluid message.
#[derive(Clone, Debug)]
pub struct EpochFluid {
    pub epoch: u64,
    pub parcels: Vec<(usize, f64)>,
}

/// Coordinator → worker control messages.
enum Ctrl {
    /// Pause, reply with the owned H slice, wait for `Resume`.
    Checkpoint { reply: Sender<(usize, Vec<f64>)> },
    /// New epoch: swap the matrix, reset the fluid slice, keep H.
    Resume {
        epoch: u64,
        problem: Arc<FixedPointProblem>,
        f_slice: Vec<f64>,
    },
    /// Non-pausing read of the owned H slice (worker keeps running).
    Snapshot { reply: Sender<(usize, Vec<f64>)> },
    /// Terminate; the final H slice comes back through the join handle.
    Shutdown,
}

/// Leader/worker shared state (the per-epoch convergence monitor's view).
struct StreamShared {
    /// per-PID published remaining fluid (local F + held coalesce mass)
    published: Vec<AtomicF64>,
    /// per-PID cumulative scalar-update counters
    updates: Vec<AtomicU64>,
}

impl StreamShared {
    fn new(k: usize) -> Arc<Self> {
        Arc::new(Self {
            published: (0..k).map(|_| AtomicF64::new(f64::INFINITY)).collect(),
            updates: (0..k).map(|_| AtomicU64::new(0)).collect(),
        })
    }

    fn published_total(&self) -> f64 {
        self.published.iter().map(AtomicF64::get).sum()
    }

    fn update_counts(&self) -> Vec<u64> {
        self.updates
            .iter()
            .map(|u| u.load(Ordering::Relaxed))
            .collect()
    }
}

/// Report for one epoch (one mutation batch, or the initial solve).
#[derive(Clone, Debug)]
pub struct EpochReport {
    /// epoch id (0 = initial cold solve)
    pub epoch: u64,
    /// mutations that actually changed the graph this epoch
    pub mutations_applied: usize,
    /// the converged state, costed over THIS epoch only (updates, wall,
    /// parallel cost and trace all restart at the rebase)
    pub solution: DistributedSolution,
}

/// Summary returned by [`StreamingEngine::finish`].
#[derive(Clone, Debug)]
pub struct StreamSummary {
    /// final assembled solution with whole-run cumulative counters
    pub final_solution: DistributedSolution,
    /// epochs completed (rebases + the initial solve)
    pub epochs: u64,
    /// total mutations that changed the graph
    pub mutations_applied: u64,
    /// EWMA steady-state updates/sec across epochs
    pub steady_updates_per_sec: f64,
}

/// The streaming engine: owns the evolving graph, the persistent V2
/// workers, and the epoch protocol.
pub struct StreamingEngine {
    graph: MutableDigraph,
    damping: f64,
    patch_dangling: bool,
    cfg: DistributedConfig,
    partition: Arc<Partition>,
    problem: Arc<FixedPointProblem>,
    shared: Arc<StreamShared>,
    bus_mon: BusMonitor,
    bus_metrics: Arc<MetricSet>,
    ctrl: Vec<Sender<Ctrl>>,
    handles: Vec<JoinHandle<(Vec<usize>, Vec<f64>)>>,
    epoch: u64,
    /// per-PID update counters at the current epoch's start
    epoch_base: Vec<u64>,
    epochs_done: u64,
    mutations_applied: u64,
    rate: RateMeter,
}

impl StreamingEngine {
    /// Spawn the engine over `graph` (epoch 0 starts immediately from the
    /// cold state `H = 0, F = B`; call [`StreamingEngine::converge`] to
    /// wait for the initial solve). The partition in `cfg` must cover the
    /// graph's coordinate capacity.
    pub fn new(
        graph: MutableDigraph,
        damping: f64,
        patch_dangling: bool,
        cfg: DistributedConfig,
    ) -> Result<StreamingEngine> {
        let n = graph.n();
        if cfg.partition.n() != n {
            return Err(DiterError::shape("StreamingEngine partition", n, cfg.partition.n()));
        }
        let sys = graph.pagerank_system(damping, patch_dangling)?;
        let problem = Arc::new(FixedPointProblem::new(sys.matrix, sys.b)?);
        let k = cfg.partition.k();
        let shared = StreamShared::new(k);
        let (endpoints, bus_metrics) = bus::<EpochFluid>(
            k,
            &BusConfig {
                latency: cfg.latency,
                seed: cfg.seed,
            },
        );
        let bus_mon = monitor_of(&endpoints[0]);
        let partition = Arc::new(cfg.partition.clone());

        let mut ctrl = Vec::with_capacity(k);
        let mut handles = Vec::with_capacity(k);
        for (kk, ep) in endpoints.into_iter().enumerate() {
            let (tx, rx) = channel::<Ctrl>();
            ctrl.push(tx);
            let worker = StreamWorker::new(
                kk,
                ep,
                rx,
                problem.clone(),
                partition.clone(),
                shared.clone(),
                cfg.clone(),
            );
            handles.push(std::thread::spawn(move || worker.run()));
        }
        Ok(StreamingEngine {
            graph,
            damping,
            patch_dangling,
            cfg,
            partition,
            problem,
            shared,
            bus_mon,
            bus_metrics,
            ctrl,
            handles,
            epoch: 0,
            epoch_base: vec![0; k],
            epochs_done: 0,
            mutations_applied: 0,
            rate: RateMeter::new(0.4),
        })
    }

    /// The current epoch id.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Read-only view of the evolving graph.
    pub fn graph(&self) -> &MutableDigraph {
        &self.graph
    }

    /// The fixed-point system of the current epoch.
    pub fn problem(&self) -> &FixedPointProblem {
        &self.problem
    }

    /// EWMA steady-state updates/sec over completed epochs.
    pub fn steady_updates_per_sec(&self) -> f64 {
        self.rate.rate().unwrap_or(0.0)
    }

    /// Change the per-epoch convergence deadline (streaming deployments
    /// often want a batch SLA rather than one global wall cap).
    pub fn set_max_wall(&mut self, max_wall: Duration) {
        self.cfg.max_wall = max_wall;
    }

    /// Apply a mutation batch: mutate the graph, rebase the running
    /// computation onto the new matrix (without restarting it and without
    /// draining the bus), then wait for reconvergence.
    pub fn apply_batch(&mut self, batch: &[Mutation]) -> Result<EpochReport> {
        let applied = batch.iter().filter(|m| self.graph.apply(m)).count();
        self.mutations_applied += applied as u64;
        if applied > 0 {
            self.rebase()?;
        }
        let mut report = self.converge()?;
        report.mutations_applied = applied;
        Ok(report)
    }

    /// Wait for the current epoch to reach the configured tolerance and
    /// return its report (epoch-scoped cost/wall/trace).
    pub fn converge(&mut self) -> Result<EpochReport> {
        let n = self.problem.n();
        let t0 = Instant::now();
        let deadline = t0 + self.cfg.max_wall;
        let poll = Duration::from_micros(200);
        let stable_needed = 3usize;
        let mut stable = 0usize;
        let mut converged = false;
        let mut trace = ConvergenceTrace::new(format!("stream-epoch-{}", self.epoch));
        loop {
            let total = self.shared.published_total() + self.bus_mon.inflight_or_zero();
            let cost = self.epoch_cost(n);
            if total.is_finite() {
                trace.push(cost, total);
            }
            // quiescence needs every sent parcel applied or discarded —
            // stashed future-epoch parcels stay uncommitted, so a rebase
            // racing this check can never fake convergence
            if total < self.cfg.tol && self.bus_mon.undelivered() == 0 {
                stable += 1;
                if stable >= stable_needed {
                    converged = true;
                    break;
                }
            } else {
                stable = 0;
            }
            if Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(poll);
        }
        let wall = t0.elapsed().as_secs_f64();
        let x = self.gather()?;
        let residual = self.problem.residual_norm(&x);
        let counts = self.shared.update_counts();
        let epoch_updates: u64 = counts
            .iter()
            .zip(&self.epoch_base)
            .map(|(now, base)| now - base)
            .sum();
        let cost = self.epoch_cost(n);
        self.rate.record(epoch_updates, wall);
        self.epochs_done += 1;
        // subsequent converge() calls report from here
        self.epoch_base = counts;
        Ok(EpochReport {
            epoch: self.epoch,
            mutations_applied: 0,
            solution: DistributedSolution {
                residual,
                converged: converged && residual <= self.cfg.tol * 10.0,
                cost,
                total_updates: epoch_updates,
                wall_secs: wall,
                trace,
                metrics: self.bus_metrics.snapshot(),
                x,
            },
        })
    }

    /// Assemble the current solution estimate without pausing the workers.
    pub fn solution(&self) -> Result<Vec<f64>> {
        self.gather()
    }

    /// Shut the workers down and return the whole-run summary.
    pub fn finish(mut self) -> Result<StreamSummary> {
        for tx in &self.ctrl {
            let _ = tx.send(Ctrl::Shutdown);
        }
        self.ctrl.clear();
        let n = self.problem.n();
        let mut x = vec![0.0; n];
        for h in self.handles.drain(..) {
            let (owned, values) = h
                .join()
                .map_err(|_| DiterError::Coordinator("stream worker panicked".into()))?;
            for (t, &i) in owned.iter().enumerate() {
                x[i] = values[t];
            }
        }
        let residual = self.problem.residual_norm(&x);
        let counts = self.shared.update_counts();
        let total_updates: u64 = counts.iter().sum();
        let cost = counts.iter().copied().max().unwrap_or(0) as f64 / n as f64;
        Ok(StreamSummary {
            final_solution: DistributedSolution {
                residual,
                converged: residual <= self.cfg.tol * 10.0,
                cost,
                total_updates,
                wall_secs: 0.0,
                trace: ConvergenceTrace::new("stream-final"),
                metrics: self.bus_metrics.snapshot(),
                x,
            },
            epochs: self.epochs_done,
            mutations_applied: self.mutations_applied,
            steady_updates_per_sec: self.rate.rate().unwrap_or(0.0),
        })
    }

    /// Parallel cost of the current epoch so far (max PID delta / N).
    fn epoch_cost(&self, n: usize) -> f64 {
        self.shared
            .update_counts()
            .iter()
            .zip(&self.epoch_base)
            .map(|(now, base)| now - base)
            .max()
            .unwrap_or(0) as f64
            / n as f64
    }

    /// The epoch transition: checkpoint → rebuild → per-PID rebase →
    /// resume. See the module docs for the protocol invariants.
    fn rebase(&mut self) -> Result<()> {
        let n = self.problem.n();
        let k = self.partition.k();
        // 1. checkpoint every worker (they pause as the requests land;
        //    workers still running only produce old-epoch parcels, which
        //    the new epoch discards on arrival)
        let (tx, rx) = channel::<(usize, Vec<f64>)>();
        for c in &self.ctrl {
            c.send(Ctrl::Checkpoint { reply: tx.clone() })
                .map_err(|_| DiterError::Coordinator("stream worker gone".into()))?;
        }
        drop(tx);
        let mut h = vec![0.0; n];
        for _ in 0..k {
            let (kk, slice) = rx
                .recv_timeout(Duration::from_secs(30))
                .map_err(|_| DiterError::Coordinator("checkpoint reply timed out".into()))?;
            for (t, &i) in self.partition.part(kk).iter().enumerate() {
                h[i] = slice[t];
            }
        }
        // 2. rebuild the system from the mutated graph
        let sys = self.graph.pagerank_system(self.damping, self.patch_dangling)?;
        let problem = Arc::new(FixedPointProblem::new(sys.matrix, sys.b)?);
        // 3. per-PID rebase (only the PID's rows of P' are read) + resume
        self.epoch += 1;
        for (kk, c) in self.ctrl.iter().enumerate() {
            let owned = self.partition.part(kk);
            let f_slice = update::rebase_b_slice(problem.matrix(), owned, &h, problem.b());
            // pre-publish so the monitor can't see a stale near-zero total
            self.shared.published[kk].set(norm1(&f_slice));
            c.send(Ctrl::Resume {
                epoch: self.epoch,
                problem: problem.clone(),
                f_slice,
            })
            .map_err(|_| DiterError::Coordinator("stream worker gone".into()))?;
        }
        self.problem = problem;
        self.epoch_base = self.shared.update_counts();
        Ok(())
    }

    /// Gather the assembled H from all workers without pausing them.
    fn gather(&self) -> Result<Vec<f64>> {
        let n = self.problem.n();
        let k = self.partition.k();
        let (tx, rx) = channel::<(usize, Vec<f64>)>();
        for c in &self.ctrl {
            c.send(Ctrl::Snapshot { reply: tx.clone() })
                .map_err(|_| DiterError::Coordinator("stream worker gone".into()))?;
        }
        drop(tx);
        let mut x = vec![0.0; n];
        for _ in 0..k {
            let (kk, slice) = rx
                .recv_timeout(Duration::from_secs(30))
                .map_err(|_| DiterError::Coordinator("snapshot reply timed out".into()))?;
            for (t, &i) in self.partition.part(kk).iter().enumerate() {
                x[i] = slice[t];
            }
        }
        Ok(x)
    }
}

impl Drop for StreamingEngine {
    fn drop(&mut self) {
        // dropping the control senders terminates the worker loops; the
        // threads unwind on their own (finish() joins them explicitly)
        for tx in &self.ctrl {
            let _ = tx.send(Ctrl::Shutdown);
        }
    }
}

/// One persistent PID worker: the V2 fluid loop plus epoch handling.
struct StreamWorker {
    k: usize,
    ep: Endpoint<EpochFluid>,
    ctrl: Receiver<Ctrl>,
    problem: Arc<FixedPointProblem>,
    partition: Arc<Partition>,
    shared: Arc<StreamShared>,
    cfg: DistributedConfig,
    epoch: u64,
    owned: Vec<usize>,
    local_of: Vec<usize>,
    h: Vec<f64>,
    f: Vec<f64>,
    coalesce: CoalesceBuffer,
    heap: GreedyQueue,
    seq: SequenceState,
    use_heap: bool,
    threshold: f64,
    absorb_eps: f64,
    /// future-epoch parcels held uncommitted until the epoch catches up
    pending: Vec<Received<EpochFluid>>,
}

impl StreamWorker {
    #[allow(clippy::too_many_arguments)]
    fn new(
        k: usize,
        ep: Endpoint<EpochFluid>,
        ctrl: Receiver<Ctrl>,
        problem: Arc<FixedPointProblem>,
        partition: Arc<Partition>,
        shared: Arc<StreamShared>,
        cfg: DistributedConfig,
    ) -> StreamWorker {
        let n = problem.n();
        let owned: Vec<usize> = partition.part(k).to_vec();
        let m = owned.len();
        let mut local_of = vec![usize::MAX; n];
        for (t, &i) in owned.iter().enumerate() {
            local_of[i] = t;
        }
        // epoch 0 cold state: F₀ = B on the owned slice, H₀ = 0
        let f: Vec<f64> = owned.iter().map(|&i| problem.b()[i]).collect();
        let h = vec![0.0; m];
        let use_heap = cfg.sequence == SequenceKind::GreedyMaxFluid;
        let mut heap = GreedyQueue::new(m);
        if use_heap {
            for (t, &fv) in f.iter().enumerate() {
                heap.push(t, fv.abs());
            }
        }
        let seq = SequenceState::new(
            cfg.sequence,
            (0..m).collect(),
            cfg.seed ^ (k as u64).wrapping_mul(0x9E3779B97F4A7C15),
        );
        let coalesce = CoalesceBuffer::new(partition.k(), cfg.coalesce);
        let threshold = cfg.threshold0;
        // same absorb floor as v2: ≤ tol/10 extra residual, kills the
        // sub-denormal ping-pong tail
        let absorb_eps = (cfg.tol / (10.0 * n as f64)).max(1e-300);
        StreamWorker {
            k,
            ep,
            ctrl,
            problem,
            partition,
            shared,
            cfg,
            epoch: 0,
            owned,
            local_of,
            h,
            f,
            coalesce,
            heap,
            seq,
            use_heap,
            threshold,
            absorb_eps,
            pending: Vec::new(),
        }
    }

    fn run(mut self) -> (Vec<usize>, Vec<f64>) {
        loop {
            match self.ctrl.try_recv() {
                Ok(c) => {
                    if !self.handle_ctrl(c) {
                        break;
                    }
                    continue; // drain further control messages first
                }
                Err(TryRecvError::Empty) => {}
                Err(TryRecvError::Disconnected) => break,
            }
            let got_fluid = self.absorb_bus();
            let (did_work, r_k) = self.diffuse_quantum();
            self.ship(did_work, r_k);
            self.publish();
            if !got_fluid && r_k == 0.0 && self.coalesce.is_empty() {
                std::thread::sleep(Duration::from_micros(50));
            }
        }
        self.ep.collect_acks();
        (self.owned, self.h)
    }

    /// Returns false when the worker must terminate.
    fn handle_ctrl(&mut self, c: Ctrl) -> bool {
        match c {
            Ctrl::Snapshot { reply } => {
                let _ = reply.send((self.k, self.h.clone()));
                true
            }
            Ctrl::Shutdown => false,
            Ctrl::Checkpoint { reply } => {
                let _ = reply.send((self.k, self.h.clone()));
                // paused: block until the coordinator resumes us
                loop {
                    match self.ctrl.recv() {
                        Ok(Ctrl::Resume {
                            epoch,
                            problem,
                            f_slice,
                        }) => {
                            self.enter_epoch(epoch, problem, f_slice);
                            return true;
                        }
                        Ok(Ctrl::Snapshot { reply }) => {
                            let _ = reply.send((self.k, self.h.clone()));
                        }
                        Ok(Ctrl::Checkpoint { reply }) => {
                            let _ = reply.send((self.k, self.h.clone()));
                        }
                        Ok(Ctrl::Shutdown) | Err(_) => return false,
                    }
                }
            }
            Ctrl::Resume {
                epoch,
                problem,
                f_slice,
            } => {
                // resume without a checkpoint (defensive: coordinator
                // always checkpoints first, but the transition is safe
                // from any state)
                self.enter_epoch(epoch, problem, f_slice);
                true
            }
        }
    }

    /// Install a new epoch: new matrix, rebased fluid, H kept warm.
    fn enter_epoch(&mut self, epoch: u64, problem: Arc<FixedPointProblem>, f_slice: Vec<f64>) {
        self.epoch = epoch;
        self.problem = problem;
        self.f = f_slice;
        // old-epoch outbound fluid still buffered is obsolete — B' already
        // accounts for everything H absorbed; drop it
        if !self.coalesce.is_empty() {
            let _ = self.coalesce.take_all();
        }
        self.heap = GreedyQueue::new(self.owned.len());
        if self.use_heap {
            for (t, &fv) in self.f.iter().enumerate() {
                self.heap.push(t, fv.abs());
            }
        }
        self.threshold = self.cfg.threshold0;
        // stashed parcels for exactly this epoch become applicable now;
        // anything older is obsolete — commit both so the bus clears
        let pending = std::mem::take(&mut self.pending);
        for msg in pending {
            if msg.payload.epoch == self.epoch {
                for &(j, fl) in &msg.payload.parcels {
                    let t = self.local_of[j];
                    self.f[t] += fl;
                    if self.use_heap {
                        self.heap.push(t, self.f[t].abs());
                    }
                }
                self.ep.commit(msg.from, msg.seq, msg.mass);
            } else if msg.payload.epoch < self.epoch {
                self.ep.commit(msg.from, msg.seq, msg.mass);
            } else {
                self.pending.push(msg);
            }
        }
        self.publish();
    }

    /// Drain the bus: apply current-epoch parcels, discard stale ones,
    /// stash future ones. Returns whether any current-epoch fluid landed.
    fn absorb_bus(&mut self) -> bool {
        let received = self.ep.drain_uncommitted();
        if received.is_empty() {
            self.ep.collect_acks();
            return false;
        }
        let mut got = false;
        let mut to_commit: Vec<(usize, u64, f64)> = Vec::new();
        for msg in received {
            match msg.payload.epoch.cmp(&self.epoch) {
                std::cmp::Ordering::Equal => {
                    for &(j, fl) in &msg.payload.parcels {
                        let t = self.local_of[j];
                        self.f[t] += fl;
                        if self.use_heap {
                            self.heap.push(t, self.f[t].abs());
                        }
                    }
                    got = true;
                    to_commit.push((msg.from, msg.seq, msg.mass));
                }
                std::cmp::Ordering::Less => {
                    // obsolete epoch: discard, release its accounting
                    to_commit.push((msg.from, msg.seq, msg.mass));
                }
                std::cmp::Ordering::Greater => self.pending.push(msg),
            }
        }
        if got {
            // publish the post-apply total BEFORE committing receipt, so
            // the monitor always sees the fluid in at least one account
            self.publish();
        }
        for (from, seq, mass) in to_commit {
            self.ep.commit(from, seq, mass);
        }
        self.ep.collect_acks();
        got
    }

    /// One diffusion work quantum (identical math to the v2 worker).
    fn diffuse_quantum(&mut self) -> (bool, f64) {
        let m = self.owned.len();
        // persistent workers idle between epochs: skip the whole quantum
        // (sweeps_per_round · m sequence scans) once the slice is drained,
        // so a quiescent engine doesn't contend with cold-restart baselines
        if self.f.iter().all(|&v| v == 0.0) {
            return (false, 0.0);
        }
        let quanta = self.cfg.sweeps_per_round * m;
        let mut did_work = false;
        let mut work_count = 0u64;
        for _ in 0..quanta {
            let t = if self.use_heap {
                match self.heap.pop_valid(|t| self.f[t]) {
                    Some(t) => t,
                    None => break, // locally drained
                }
            } else {
                self.seq.next(&self.f)
            };
            let fi = self.f[t];
            if fi == 0.0 {
                continue;
            }
            if fi.abs() < self.absorb_eps {
                self.h[t] += fi;
                self.f[t] = 0.0;
                continue;
            }
            did_work = true;
            work_count += 1;
            self.h[t] += fi;
            self.f[t] = 0.0;
            let global_i = self.owned[t];
            let csc = self.problem.matrix().csc();
            let (rows, vals) = csc.col(global_i);
            for u in 0..rows.len() {
                let j = rows[u];
                let contrib = vals[u] * fi;
                let lj = self.local_of[j];
                if lj != usize::MAX {
                    self.f[lj] += contrib;
                    if self.use_heap {
                        self.heap.push(lj, self.f[lj].abs());
                    }
                } else {
                    self.coalesce.add(self.partition.owner(j), j, contrib);
                }
            }
        }
        self.shared.updates[self.k].fetch_add(work_count, Ordering::Relaxed);
        (did_work, norm1(&self.f))
    }

    /// Ship coalesced parcels under the current epoch tag (§4.3 triggers).
    fn ship(&mut self, did_work: bool, r_k: f64) {
        let threshold_hit = did_work && r_k < self.threshold;
        if threshold_hit || r_k < self.cfg.tol {
            for (dest, batch, mass) in self.coalesce.take_all() {
                self.send_batch(dest, batch, mass);
            }
        } else {
            for dest in self.coalesce.ready() {
                let (batch, mass) = self.coalesce.take(dest);
                self.send_batch(dest, batch, mass);
            }
        }
        if threshold_hit && self.threshold > self.cfg.tol * 1e-3 {
            self.threshold /= self.cfg.threshold_alpha;
        }
    }

    fn send_batch(&mut self, dest: usize, batch: Vec<(usize, f64)>, mass: f64) {
        if batch.is_empty() {
            return;
        }
        let bytes = batch.len() * 16 + 24;
        let _ = self.ep.send(
            dest,
            EpochFluid {
                epoch: self.epoch,
                parcels: batch,
            },
            mass,
            bytes,
        );
    }

    fn publish(&self) {
        self.shared.published[self.k].set(norm1(&self.f) + self.coalesce.held_mass());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{power_law_web_graph, ChurnModel, MutationStream};
    use crate::linalg::vec_ops::dist1;
    use crate::solver::{DIteration, SolveOptions, Solver};

    fn engine(n: usize, k: usize, seed: u64) -> StreamingEngine {
        let g = power_law_web_graph(n, 5, 0.1, seed);
        let mg = MutableDigraph::from_digraph(&g, n);
        let cfg = DistributedConfig::new(Partition::contiguous(n, k).unwrap())
            .with_tol(1e-10)
            .with_seed(seed);
        StreamingEngine::new(mg, 0.85, true, cfg).unwrap()
    }

    fn cold_solution(problem: &FixedPointProblem) -> Vec<f64> {
        let opts = SolveOptions {
            tol: 1e-13,
            max_cost: 200_000.0,
            trace_every: 0.0,
            exact: None,
        };
        DIteration::fluid_cyclic().solve(problem, &opts).unwrap().x
    }

    #[test]
    fn initial_epoch_matches_cold_solve() {
        let mut eng = engine(120, 3, 11);
        let report = eng.converge().unwrap();
        assert!(report.solution.converged, "residual {}", report.solution.residual);
        let want = cold_solution(eng.problem());
        assert!(dist1(&report.solution.x, &want) < 1e-7);
        let summary = eng.finish().unwrap();
        assert_eq!(summary.epochs, 1);
    }

    #[test]
    fn empty_batch_is_a_noop_epoch() {
        let mut eng = engine(80, 2, 3);
        eng.converge().unwrap();
        let report = eng.apply_batch(&[]).unwrap();
        assert_eq!(report.epoch, 0, "no graph change, no rebase");
        assert_eq!(report.mutations_applied, 0);
        assert!(report.solution.converged);
        eng.finish().unwrap();
    }

    #[test]
    fn mutation_batch_reconverges_to_new_fixed_point() {
        let mut eng = engine(100, 4, 7);
        eng.converge().unwrap();
        let batch = vec![
            Mutation::EdgeInsert {
                from: 3,
                to: 42,
                weight: 1.0,
            },
            Mutation::EdgeInsert {
                from: 42,
                to: 3,
                weight: 2.0,
            },
            Mutation::EdgeDelete { from: 3, to: 42 },
        ];
        let report = eng.apply_batch(&batch).unwrap();
        assert_eq!(report.epoch, 1);
        assert!(report.mutations_applied >= 2);
        assert!(report.solution.converged, "residual {}", report.solution.residual);
        let want = cold_solution(eng.problem());
        assert!(
            dist1(&report.solution.x, &want) < 1e-7,
            "Δ₁ = {}",
            dist1(&report.solution.x, &want)
        );
        eng.finish().unwrap();
    }

    #[test]
    fn mid_flight_rebase_conserves_the_computation() {
        // rebase BEFORE the initial solve converges: the checkpointed H is
        // a partial state, and the §3.2 identity must still land the run
        // on the new system's exact fixed point (fluid conservation across
        // the epoch boundary).
        let mut eng = engine(100, 4, 13);
        // no converge() here — workers are mid-diffusion
        let mut stream = MutationStream::new(ChurnModel::RandomRewire, 5);
        let batch = stream.next_batch(eng.graph(), 12);
        let report = eng.apply_batch(&batch).unwrap();
        assert!(report.solution.converged, "residual {}", report.solution.residual);
        let want = cold_solution(eng.problem());
        assert!(
            dist1(&report.solution.x, &want) < 1e-7,
            "Δ₁ = {}",
            dist1(&report.solution.x, &want)
        );
        eng.finish().unwrap();
    }

    #[test]
    fn greedy_sequence_streams_too() {
        let n = 90;
        let g = power_law_web_graph(n, 5, 0.1, 21);
        let mg = MutableDigraph::from_digraph(&g, n);
        let cfg = DistributedConfig::new(Partition::contiguous(n, 3).unwrap())
            .with_tol(1e-10)
            .with_sequence(SequenceKind::GreedyMaxFluid)
            .with_seed(21);
        let mut eng = StreamingEngine::new(mg, 0.85, true, cfg).unwrap();
        eng.converge().unwrap();
        let batch = vec![Mutation::EdgeInsert {
            from: 1,
            to: 50,
            weight: 3.0,
        }];
        let report = eng.apply_batch(&batch).unwrap();
        assert!(report.solution.converged);
        let want = cold_solution(eng.problem());
        assert!(dist1(&report.solution.x, &want) < 1e-7);
        eng.finish().unwrap();
    }

    #[test]
    fn partition_mismatch_rejected() {
        let g = power_law_web_graph(50, 4, 0.1, 2);
        let mg = MutableDigraph::from_digraph(&g, 50);
        let cfg = DistributedConfig::new(Partition::contiguous(40, 2).unwrap());
        assert!(StreamingEngine::new(mg, 0.85, true, cfg).is_err());
    }
}
