//! The shared per-PID worker core: one fluid-diffusion loop, two engines.
//!
//! [`super::v2`] (one-shot solves) and [`super::stream`] (the streaming
//! engine) used to carry two copies of the same loop; both now instantiate
//! [`WorkerCore`]. The core's defining difference from the old workers is
//! that it routes through a **versioned [`OwnershipTable`]** instead of a
//! static `Arc<Partition>` — which is what turns §4.3's speed adaptation
//! into a *live* operation.
//!
//! ## The hot path (DESIGN.md §5)
//!
//! The inner diffusion loop runs entirely in **local-slot space** against
//! a per-worker [`LocalSystem`]: the owned columns of P reindexed into a
//! local CSC block (intra-part contributions are two array reads and an
//! FMA) plus a cross-part remnant whose entries were resolved at build
//! time to `(destination PID, accumulator slot)` — a cross-part emission
//! is one indexed add into a dense scratch accumulator, no `local_of`
//! lookup, no owner lookup, no hashing. Accumulators flush to the bus as
//! flat SoA parcels (`coords: Vec<u32>, mass: Vec<f64>`). The LocalSystem
//! is rebuilt **handoff-atomically** whenever the held range or the owner
//! map changes, and **patched** (dirty columns only) across streaming
//! epochs. Three kernels share this machinery and stay selectable in the
//! same binary for measured A/B: the scalar local walk
//! ([`super::KernelKind::LocalBlock`], the default), the batched
//! allocation-free variant ([`super::KernelKind::Blocked`], DESIGN.md §9),
//! and the pre-refactor global walk ([`super::KernelKind::GlobalWalk`]).
//!
//! ## The handoff protocol (DESIGN.md §4)
//!
//! The bus carries two message classes: fluid parcels (the §3.3 data
//! plane) and [`Handoff`] control messages. When the coordinator installs
//! a new ownership map (version v+1), the worker holding a reassigned
//! coordinate range notices on its next loop iteration, freezes the range,
//! and ships its `(H, B, F)` slice to the new owner in a single `Handoff`
//! tagged with the ownership version and the streaming epoch. Invariants:
//!
//! * **single holder** — every coordinate is held by exactly one worker;
//!   holdings change only through handoff messages (never by spontaneous
//!   adoption from a table read), so the final gather is an exact cover;
//! * **no fluid lost** — a handoff's `‖F‖₁` rides the bus's in-flight
//!   account like any parcel; the shipper publishes its shrunken local
//!   total only *after* the send is accounted, so the monitor's
//!   `Σ_k ‖F_k‖₁ + in-flight` total errs high, never low, through every
//!   transfer — the paper's exact convergence monitor stays valid;
//! * **re-routing** — fluid that arrives for a coordinate the receiver no
//!   longer owns is forwarded to the current owner (consulting the table);
//!   fluid that arrives *ahead* of the handoff ("table says mine, slice
//!   still in flight") is fostered — held on the local account — and
//!   folded in when the slice lands;
//! * **no stranded history** — `OwnershipTable::handoffs_inflight` counts
//!   shipped-but-unapplied slices; the streaming rebase freezes the table
//!   and waits for zero before checkpointing, so the gathered H used for
//!   `B' = P'·H + B − H` is always complete.
//!
//! ## Query lanes (DESIGN.md §10)
//!
//! D-iteration is linear in B, so one worker can run L right-hand sides
//! against the same matrix walk: `f`/`h` become **lane-blocked** (slot-
//! major, `lanes` cells per slot; `lanes == 1` is the flat pre-lane
//! layout), the greedy rule generalizes to "largest |fluid| across any
//! lane", and a popped column drains every lane in one walk — the
//! expensive part (the column) is shared, the per-lane work is one FMA
//! stream each. Lane 0 is always the base problem; lanes ≥ 1 are query
//! tenants managed by the shared [`QuerySet`] registry (admission,
//! ε targets, per-lane conservation accounting). Parcels carry a global
//! query-id column so in-flight fluid survives admit/evict races; every
//! per-query account transition keeps the lane total erring high, never
//! low, mirroring the aggregate monitor's discipline.

use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::monitor::MonitorState;
use super::{update, DistributedConfig, KernelKind, RebaseMode};
use crate::linalg::vec_ops::norm1;
use crate::metrics::MetricSet;
use crate::partition::{OwnershipTable, Partition};
use crate::perf::VecQueue;
use crate::solver::{FixedPointProblem, GreedyQueue, SequenceKind, SequenceState};
use super::query::{QuerySet, FREE_LANE};
use crate::sparse::LocalSystem;
use crate::transport::{CoalesceBuffer, Received, Transport};

/// Metric names the worker core registers on top of the bus metrics.
pub const WORKER_METRICS: &[&str] = &[
    "handoffs_total",      // handoff slices shipped between PIDs
    "handoffs_planned",    // rebalance decisions installed by the leader
    "handoff_coords",      // coordinates moved across all handoffs
    "fluid_forwarded",     // parcels re-routed after an ownership change
    "load_imbalance_ppm",  // current max Ω size / ideal × 1e6 (gauge)
    "halo_slices_sent",    // V1-style halo messages between peers
    "halo_values_sent",    // dirty-column H values shipped in halos
    "rebase_gather_coords", // coords through the leader's gather/scatter
];

/// Ownership patches applied to a LocalSystem before the next full
/// rebuild. The delta paths (shed/adopt/retarget) skip the coalesce-
/// interner compaction — cached accumulator slots must stay valid — so a
/// periodic full rebuild bounds the interner's accretion under churn to
/// O(coords routed per window), the same bound the pre-patch code had
/// per ownership event.
const PATCHES_PER_REBUILD: u32 = 64;

/// Slots drained per [`KernelKind::Blocked`] batch. Small enough that the
/// greedy order stays fresh (fluid snapshots are only approximate within
/// a batch), large enough to amortize the deferred heap-refiling pass and
/// keep four independent column accumulations in flight.
const BLOCK_BATCH: usize = 8;

/// While queries are being served, force a full transport flush at least
/// this often. Sub-threshold query tails otherwise ride the coalesce
/// policy + wire flush deadline indefinitely while the base problem is
/// busy, and time-to-ε is the serving layer's product metric.
const SERVE_FLUSH_INTERVAL: Duration = Duration::from_millis(2);

/// Everything that travels between PIDs: the fluid data plane plus the
/// repartitioning control plane.
#[derive(Clone, Debug, PartialEq)]
pub enum WorkerMsg {
    /// Epoch-tagged fluid as a flat SoA parcel: `coords[u]` receives
    /// `mass[u]` (a one-shot solve stays at epoch 0). The split layout
    /// replaces `Vec<(usize, f64)>` — 12 bytes/entry instead of 16, and
    /// the receiver walks two contiguous arrays.
    Fluid {
        epoch: u64,
        coords: Vec<u32>,
        mass: Vec<f64>,
        /// Per-entry **global query ids** (DESIGN.md §10): `qids[u]` says
        /// which query's lane `mass[u]` belongs to. Empty ⇒ every entry is
        /// lane 0 (the base problem) — the dominant case, and byte-
        /// identical to the pre-lane wire format (tag 0x10). Senders
        /// translate their buffer-local lane indices to global ids at
        /// flush; receivers map ids back through their own lane table, so
        /// a stale id (query evicted in flight) is detected and dropped.
        qids: Vec<u32>,
    },
    /// Ownership transfer of a coordinate range with its local state.
    Handoff(Handoff),
    /// V1-style history exchange for the **local** epoch protocol
    /// ([`super::RebaseMode::Local`]): the sender's H snapshot over the
    /// dirty columns it owns, taken at each column's switch instant.
    /// [`super::v1::SliceMsg`] generalized to the pool bus — it carries
    /// state, not fluid mass, so it rides with `mass = 0.0`; the real
    /// fluid adjustment happens at each receiver when it folds the halo
    /// into its delta rebase (`update::rebase_b_slice_local`).
    HaloSlice {
        /// the epoch this transition enters
        epoch: u64,
        /// dirty columns owned by the sender (ascending)
        coords: Vec<u32>,
        /// `H_u` for each coord, frozen at the switch instant
        h: Vec<f64>,
    },
}

/// One ownership transfer: the shipped `(H, B, F)` slices for `coords`.
/// `b_slice` is carried for protocol fidelity — a real multi-machine
/// deployment has no shared `FixedPointProblem`, so the offset slice must
/// travel with the range (in-process the recipient could read it from the
/// shared problem).
#[derive(Clone, Debug, PartialEq)]
pub struct Handoff {
    pub pid_from: usize,
    pub pid_to: usize,
    /// ownership-table version this transfer implements
    pub version: u64,
    /// streaming epoch the slices belong to
    pub epoch: u64,
    pub coords: Vec<usize>,
    /// lane-blocked (`coords.len() * lanes`): slot-major, lane-minor —
    /// single-lane configs reduce to the flat pre-lane layout
    pub h_slice: Vec<f64>,
    /// lane 0 only (`coords.len()`): query RHS vectors live in the
    /// [`QuerySet`], never in handoffs
    pub b_slice: Vec<f64>,
    /// lane-blocked (`coords.len() * lanes`), like `h_slice`
    pub f_slice: Vec<f64>,
}

/// One PID's live state: the owned slice of `(B, H, F)`, the local-block
/// view of P, the coalescing accumulators, the diffusion-order state, and
/// the ownership-version cache.
pub struct WorkerCore {
    k: usize,
    /// the transport face: in-process bus or TCP wire, chosen by
    /// [`crate::transport::TransportKind`] — the core cannot tell
    ep: Box<dyn Transport<WorkerMsg>>,
    problem: Arc<FixedPointProblem>,
    table: Arc<OwnershipTable>,
    state: Arc<MonitorState>,
    metrics: Arc<MetricSet>,
    cfg: DistributedConfig,
    /// cached ownership snapshot (refreshed when the version moves)
    part: Arc<Partition>,
    version: u64,
    epoch: u64,
    owned: Vec<usize>,
    /// global index → local slot (usize::MAX = not held here)
    local_of: Vec<usize>,
    /// the reindexed local block + remnant (None under the global kernel)
    local: Option<LocalSystem>,
    /// lane-blocked history: `h[t * lanes + l]` is slot `t`'s H for lane
    /// `l` (lane 0 = the base problem; `lanes == 1` is the flat pre-lane
    /// layout)
    h: Vec<f64>,
    /// lane-blocked fluid, same indexing as `h`
    f: Vec<f64>,
    /// number of fluid lanes (`cfg.lanes`, ≥ 1); multi-lane requires the
    /// greedy max-fluid sequence (asserted in `new`)
    lanes: usize,
    /// the shared query registry, when this pool serves queries
    queries: Option<Arc<QuerySet>>,
    /// cached [`QuerySet::version`] — lane state resyncs on a bump
    qver: u64,
    /// cached lane → global query id table (lane 0 is always qid 0).
    /// Without a registry but `lanes > 1`, the identity map: both sides
    /// of the wire then agree that qid == lane index.
    lane_qids: Vec<u32>,
    /// cached per-lane ε targets (0.0 = free lane / no target)
    lane_eps: Vec<f64>,
    /// per-lane "crossed under ε" latch — set once by `publish`, forces
    /// an immediate transport flush so completion never waits out the
    /// coalesce/wire flush deadlines; reset on lane turnover and epoch
    /// entry
    endgame: Vec<bool>,
    /// set by `publish` when a lane first crosses under its ε; consumed
    /// by `step` as a full flush
    force_flush: bool,
    /// last forced full flush while queries were active (bounds how long
    /// a query tail can sit in the buffers)
    last_serve_flush: Instant,
    /// scratch: per-lane |f| sums (publish)
    lane_scratch: Vec<f64>,
    /// scratch: per-lane held coalesce mass (publish)
    held_scratch: Vec<f64>,
    /// scratch: per-lane in-flight charges (flush)
    charge_scratch: Vec<f64>,
    /// scratch: fresh lane→qid snapshot (sync_queries)
    qid_scratch: Vec<u32>,
    /// scratch: claimed seeds `(lane, qid, coord, mass)`
    seed_scratch: Vec<(usize, u32, usize, f64)>,
    /// per-lane `(qid, Σ|mass|)` in-flight releases accumulated while
    /// absorbing, settled only AFTER the new totals are published — the
    /// per-query account errs high, never low, like the aggregate one
    lane_release: Vec<(u32, f64)>,
    /// fluid received ahead of a handoff ("table says mine, slice in
    /// flight") — counted on the local account until folded into `f`;
    /// keyed `(coordinate, lane)` so query fluid fosters independently
    foster: HashMap<(usize, u32), f64>,
    coalesce: CoalesceBuffer,
    heap: GreedyQueue,
    seq: Option<SequenceState>,
    use_heap: bool,
    threshold: f64,
    absorb_eps: f64,
    /// future-epoch parcels held uncommitted until the epoch catches up
    /// (gather protocol only; the local protocol applies every epoch's
    /// fluid immediately — see `absorb_bus`)
    pending: Vec<Received<WorkerMsg>>,
    /// in-flight local (V1-style) epoch transition, if any
    pending_local: Option<LocalRebase>,
    /// halo slices that raced ahead of our `Ctrl::RebaseLocal`
    halo_stash: Vec<(u64, Vec<u32>, Vec<f64>)>,
    /// local slots whose diffusion is paused mid-transition (owned dirty
    /// columns: their H values are the halo peers compute deltas from,
    /// so they must not move until the epoch entry completes; incoming
    /// fluid still accumulates in F)
    frozen: HashSet<usize>,
    /// ownership patches since the last full LocalSystem rebuild
    patches: u32,
    /// exit path: fold incoming handoffs but never migrate ownership
    shutting_down: bool,
    /// count of nonzero entries in `f`, maintained at every write site
    /// (`add_f` / `clear_f` on the hot paths, `recount_f` after bulk
    /// rewrites) so the idle fast-path is O(1) instead of an O(m) scan
    /// per quantum
    nonzero_f: usize,
    /// preallocated scratch for the blocked kernel (batch + journal)
    blocked: BlockedScratch,
    /// ——— incremental checkpoint journal (crash tolerance) ———
    /// basis epoch of the last snapshot handed to the pool: a delta only
    /// merges onto a snapshot taken over the same owned set and epoch
    ckpt_epoch: u64,
    /// owned coordinates as of the last snapshot (empty = never taken)
    ckpt_coords: Vec<usize>,
    /// lane-blocked H as of the last snapshot, aligned with
    /// `ckpt_coords` — the dirty-slot detector for delta journals
    ckpt_shadow: Vec<f64>,
}

/// Reusable scratch for [`KernelKind::Blocked`]: the drained batch and
/// the touched-slot journal, preallocated so the steady-state quantum is
/// allocation-free (asserted by the counting-allocator test in
/// `tests/integration_hotpath.rs`).
#[derive(Default)]
struct BlockedScratch {
    /// `(lane cell, fluid)` pairs selected this batch — the cell is the
    /// flat index `slot * lanes + lane` (== slot when `lanes == 1`)
    batch: VecQueue<(u32, f64)>,
    /// flat lane cells written by this batch's column walks. Duplicates
    /// are allowed: the deferred refiling pass delegates dedup to the
    /// greedy queue's exponent-bucket no-op, keeping the append
    /// branchless.
    journal: VecQueue<u32>,
}

/// State of one in-flight V1-style epoch transition (`RebaseMode::Local`):
/// the halo H values collected so far and the dirty columns still awaited
/// from their owning peers. The worker keeps diffusing its non-frozen
/// slots the whole time — the transition is a state machine inside the
/// ordinary step loop, not a pause.
struct LocalRebase {
    epoch: u64,
    problem: Arc<FixedPointProblem>,
    dirty: Arc<Vec<usize>>,
    /// dirty columns whose H must still arrive from owning peers
    waiting: HashSet<usize>,
    /// dirty columns resolved so far (own + received)
    halo_coords: Vec<usize>,
    /// lane-blocked H snapshots aligned with `halo_coords`
    /// (`halo_coords.len() * lanes`): each lane's fluid is rebased from
    /// its own history, so halos carry every lane
    halo_h: Vec<f64>,
}

impl WorkerCore {
    pub fn new(
        k: usize,
        ep: Box<dyn Transport<WorkerMsg>>,
        problem: Arc<FixedPointProblem>,
        table: Arc<OwnershipTable>,
        state: Arc<MonitorState>,
        cfg: DistributedConfig,
    ) -> WorkerCore {
        let n = problem.n();
        assert!(n <= u32::MAX as usize, "SoA parcels carry u32 coordinates");
        let lanes = cfg.lanes.max(1);
        assert!(
            (n as u64).saturating_mul(lanes as u64) <= u32::MAX as u64,
            "lane cells are addressed as u32 (slot * lanes + lane)"
        );
        let use_heap = cfg.sequence == SequenceKind::GreedyMaxFluid;
        // a fixed sweep order ranks slots, not lanes: only the greedy
        // queue's "largest fluid across any lane" priority (the paper's
        // rule, generalized) is sound for multi-RHS serving
        assert!(
            lanes == 1 || use_heap,
            "multi-lane serving requires SequenceKind::GreedyMaxFluid"
        );
        let queries = cfg.queries.clone();
        if let Some(qs) = &queries {
            assert_eq!(qs.lanes(), lanes, "QuerySet and config disagree on lanes");
        }
        let (version, part) = table.snapshot();
        let owned: Vec<usize> = part.part(k).to_vec();
        let mut local_of = vec![usize::MAX; n];
        for &i in &owned {
            local_of[i] = part.slot(i);
        }
        // epoch 0 cold state: F₀ = B on the owned slice (lane 0; query
        // lanes start empty and fill by seed claiming), H₀ = 0
        let mut f = vec![0.0; owned.len() * lanes];
        for (t, &i) in owned.iter().enumerate() {
            f[t * lanes] = problem.b()[i];
        }
        let nonzero_f = f.iter().filter(|v| **v != 0.0).count();
        let h = vec![0.0; owned.len() * lanes];
        let (qver, lane_qids, lane_eps) = match &queries {
            Some(qs) => {
                let mut qids = Vec::new();
                let mut eps = Vec::new();
                qs.snapshot_qids(&mut qids);
                qs.snapshot_eps(&mut eps);
                (qs.version(), qids, eps)
            }
            // no registry: both wire endpoints use the identity lane map
            None => (0, (0..lanes as u32).collect(), vec![0.0; lanes]),
        };
        // sized to the owned slice, not the whole coordinate space (K
        // workers × n bucket state was the old cost); handoff adoption
        // grows it (see `adopt` / `rebuild_order`)
        let mut heap = GreedyQueue::new(owned.len());
        if use_heap {
            // cold state has fluid in lane 0 only, so the per-slot max is
            // just the lane-0 cell
            for t in 0..owned.len() {
                heap.push(t, f[t * lanes].abs());
            }
        }
        let seq = Self::make_seq(&cfg, k, owned.len());
        let coalesce = CoalesceBuffer::with_lanes(part.k(), lanes, cfg.coalesce);
        let threshold = cfg.threshold0;
        // absorb-without-propagation floor: ≤ tol/10 extra residual, kills
        // the sub-denormal ping-pong tail (see the v2 module docs)
        let absorb_eps = (cfg.tol / (10.0 * n as f64)).max(1e-300);
        let metrics = ep.metrics();
        table.ack_version(k, version);
        let mut core = WorkerCore {
            k,
            ep,
            problem,
            table,
            state,
            metrics,
            cfg,
            part,
            version,
            epoch: 0,
            owned,
            local_of,
            local: None,
            h,
            f,
            lanes,
            queries,
            qver,
            lane_qids,
            lane_eps,
            endgame: vec![false; lanes],
            force_flush: false,
            last_serve_flush: Instant::now(),
            lane_scratch: Vec::new(),
            held_scratch: Vec::new(),
            charge_scratch: Vec::new(),
            qid_scratch: Vec::new(),
            seed_scratch: Vec::new(),
            lane_release: vec![(0, 0.0); lanes],
            foster: HashMap::new(),
            coalesce,
            heap,
            seq,
            use_heap,
            threshold,
            absorb_eps,
            pending: Vec::new(),
            pending_local: None,
            halo_stash: Vec::new(),
            frozen: HashSet::new(),
            patches: 0,
            shutting_down: false,
            nonzero_f,
            blocked: BlockedScratch::default(),
            ckpt_epoch: 0,
            ckpt_coords: Vec::new(),
            ckpt_shadow: Vec::new(),
        };
        core.rebuild_local();
        core
    }

    /// Write `f[t] += dv` (t is a **flat lane cell**, `slot * lanes +
    /// lane`), maintaining the nonzero-fluid counter.
    #[inline]
    fn add_f(&mut self, t: usize, dv: f64) {
        let old = self.f[t];
        let new = old + dv;
        self.f[t] = new;
        self.nonzero_f += (new != 0.0) as usize;
        self.nonzero_f -= (old != 0.0) as usize;
    }

    /// Write `f[t] = 0.0` (flat lane cell), maintaining the counter.
    #[inline]
    fn clear_f(&mut self, t: usize) {
        self.nonzero_f -= (self.f[t] != 0.0) as usize;
        self.f[t] = 0.0;
    }

    /// Recount after a bulk rewrite of `f` (compact, epoch entry, local
    /// rebase — all rare events; the per-quantum sites maintain the
    /// counter incrementally).
    fn recount_f(&mut self) {
        self.nonzero_f = self.f.iter().filter(|v| **v != 0.0).count();
    }

    fn make_seq(cfg: &DistributedConfig, k: usize, m: usize) -> Option<SequenceState> {
        if m == 0 {
            return None;
        }
        Some(SequenceState::new(
            cfg.sequence,
            (0..m).collect(),
            cfg.seed ^ (k as u64).wrapping_mul(0x9E3779B97F4A7C15),
        ))
    }

    pub fn pid(&self) -> usize {
        self.k
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Coordinates currently held (the checkpoint/snapshot reply).
    pub fn owned(&self) -> &[usize] {
        &self.owned
    }

    /// The held history slice, aligned with [`WorkerCore::owned`] and
    /// **lane-blocked** (`owned.len() * lanes`; flat when `lanes == 1`).
    pub fn h(&self) -> &[f64] {
        &self.h
    }

    /// Number of fluid lanes this core runs (≥ 1).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Overwrite the held history with a restored snapshot (recovery's
    /// warm start). `h` must be lane-blocked and aligned with the current
    /// owned slice — recovery builds it from the last checkpoint over
    /// exactly this partition slice (absent coordinates cold-start at 0).
    /// H is a partial solution, valid under any epoch: restoring an older
    /// snapshot loses progress, never correctness (DESIGN.md §11).
    pub fn restore_history(&mut self, h: &[f64]) {
        assert_eq!(
            h.len(),
            self.owned.len() * self.lanes,
            "restored H must cover the owned slice, lane-blocked"
        );
        self.h.copy_from_slice(h);
    }

    /// Emit one incremental checkpoint journal entry:
    /// `(epoch, full, coords, h)` where `h` is lane-blocked and aligned
    /// with `coords`. When the snapshot basis moved (ownership or epoch
    /// changed since the last journal — or there was none) this is a
    /// **full** snapshot of the owned slice; otherwise a **delta** of
    /// just the slots whose H moved, detected against (and folded into)
    /// the shadow copy. The pool merges deltas coordinate-wise onto its
    /// stored snapshot; a full entry replaces it.
    pub fn journal(&mut self) -> (u64, bool, Vec<usize>, Vec<f64>) {
        let full = self.ckpt_epoch != self.epoch || self.ckpt_coords != self.owned;
        if full {
            self.ckpt_epoch = self.epoch;
            self.ckpt_coords.clear();
            self.ckpt_coords.extend_from_slice(&self.owned);
            self.ckpt_shadow.clear();
            self.ckpt_shadow.extend_from_slice(&self.h);
            return (self.epoch, true, self.owned.clone(), self.h.clone());
        }
        let lanes = self.lanes;
        let mut coords = Vec::new();
        let mut h = Vec::new();
        for (t, &i) in self.owned.iter().enumerate() {
            let row = &self.h[t * lanes..(t + 1) * lanes];
            let shadow = &mut self.ckpt_shadow[t * lanes..(t + 1) * lanes];
            if row != shadow {
                shadow.copy_from_slice(row);
                coords.push(i);
                h.extend_from_slice(row);
            }
        }
        (self.epoch, false, coords, h)
    }

    /// Crash-recovery seam: reconcile this worker's transport state with
    /// the death of `pid` (see [`Transport::peer_reset`]). Called while
    /// paused at the recovery barrier.
    pub fn reconcile_peer(&mut self, pid: usize) {
        self.ep.peer_reset(pid);
    }

    /// Greedy priority of a slot: the largest |fluid| across its lanes —
    /// the paper's rule generalized to multi-RHS (any lane's fluid makes
    /// the column worth draining, and one drain serves every lane).
    #[inline]
    fn lane_slot_max(&self, t: usize) -> f64 {
        let base = t * self.lanes;
        self.f[base..base + self.lanes]
            .iter()
            .fold(0.0f64, |m, v| m.max(v.abs()))
    }

    /// Map a global query id to its current lane, through the cached
    /// table (lane 0 ⇔ qid 0 always).
    #[inline]
    fn lane_of_qid(&self, qid: u32) -> Option<usize> {
        self.lane_qids.iter().position(|&q| q == qid)
    }

    /// Whether any query lane currently has a tenant (cached view).
    fn serving_active(&self) -> bool {
        self.lane_qids.iter().skip(1).any(|&q| q != FREE_LANE)
    }

    /// Whether nothing is buffered locally besides `f` itself.
    pub fn is_drained(&self) -> bool {
        self.coalesce.is_empty() && self.foster.is_empty()
    }

    /// One iteration of the fluid loop: ownership refresh, bus absorb,
    /// diffusion quantum, ship, publish. Returns `(got_fluid, r_k)` for
    /// the caller's idle-backoff decision.
    pub fn step(&mut self) -> (bool, f64) {
        self.sync_queries();
        self.refresh_ownership(false);
        let got = self.absorb_bus();
        self.claim_query_seeds();
        let (did_work, work_count, r_k) = self.diffuse_quantum();
        self.state.add_updates(self.k, work_count);
        self.throttle(work_count);
        self.ship(did_work, r_k);
        self.publish();
        if std::mem::take(&mut self.force_flush) {
            // a query lane just crossed under its ε target: push its tail
            // out NOW — completion must never wait out the coalesce
            // policy or the wire flush deadline
            self.flush_coalesce(true);
            self.publish();
        }
        (got, r_k)
    }

    /// Resync the cached lane tables after an admit/evict (one atomic
    /// load on the hot path; the body runs only on a version bump).
    /// Lanes whose tenant changed drop ALL local state for the old
    /// tenant — fluid, history, fosters, buffered outbound — before the
    /// new tenant's seeds can land.
    fn sync_queries(&mut self) {
        let Some(qs) = self.queries.clone() else { return };
        let v = qs.version();
        if v == self.qver {
            return;
        }
        self.qver = v;
        let mut fresh = std::mem::take(&mut self.qid_scratch);
        qs.snapshot_qids(&mut fresh);
        debug_assert_eq!(fresh.len(), self.lanes);
        let mut changed = false;
        for l in 1..self.lanes {
            if fresh[l] == self.lane_qids[l] {
                continue;
            }
            changed = true;
            self.endgame[l] = false;
            for t in 0..self.owned.len() {
                let flat = t * self.lanes + l;
                if self.f[flat] != 0.0 {
                    self.clear_f(flat);
                }
                self.h[flat] = 0.0;
            }
            let lane = l as u32;
            self.foster.retain(|&(_, fl), _| fl != lane);
            self.coalesce.clear_lane(lane);
        }
        std::mem::swap(&mut self.lane_qids, &mut fresh);
        self.qid_scratch = fresh;
        qs.snapshot_eps(&mut self.lane_eps);
        if changed {
            // the evicted tenant's mass vanishes from our published lane
            // totals immediately (its accounts were reset at evict; the
            // heap's stale priorities lazily refile on pop)
            self.publish();
        }
    }

    /// Inject any unclaimed query seeds whose coordinates we hold:
    /// fluid in first, totals published, THEN the unclaimed account
    /// released — the lane total errs high through admission, so the
    /// serving loop can never observe an ε crossing that is really just
    /// un-injected seed mass.
    fn claim_query_seeds(&mut self) {
        let Some(qs) = self.queries.clone() else { return };
        if qs.unclaimed_seed_count() == 0 {
            return;
        }
        let mut out = std::mem::take(&mut self.seed_scratch);
        out.clear();
        {
            let local_of = &self.local_of;
            qs.claim_seeds(|c| local_of[c] != usize::MAX, &mut out);
        }
        if out.is_empty() {
            self.seed_scratch = out;
            return;
        }
        for &(lane, _qid, coord, mass) in &out {
            let t = self.local_of[coord];
            debug_assert_ne!(t, usize::MAX, "claimed a seed we do not hold");
            let flat = t * self.lanes + lane;
            self.add_f(flat, mass);
            if self.use_heap {
                self.heap.push(t, self.f[flat].abs());
            }
        }
        self.publish();
        for &(lane, _qid, _coord, mass) in &out {
            qs.seed_settled(lane, mass);
        }
        out.clear();
        self.seed_scratch = out;
    }

    /// Accumulate a pending per-query in-flight release (settled after
    /// the next publish). If the lane turned over mid-drain, the
    /// displaced qid was evicted — its account was reset, so settling
    /// its remainder immediately is a guarded no-op at worst.
    fn accumulate_release(&mut self, lane: usize, qid: u32, mass: f64) {
        let e = &mut self.lane_release[lane];
        if e.1 != 0.0 && e.0 != qid {
            if let Some(qs) = &self.queries {
                qs.add_inflight(lane, e.0, -e.1);
            }
            e.1 = 0.0;
        }
        e.0 = qid;
        e.1 += mass;
    }

    /// Settle accumulated per-query in-flight releases. Callers publish
    /// first: each unit of query fluid stays visible in at least one of
    /// {in-flight, published, unclaimed} at every instant.
    fn settle_lane_releases(&mut self) {
        if self.lanes == 1 {
            return;
        }
        let Some(qs) = &self.queries else { return };
        for (l, e) in self.lane_release.iter_mut().enumerate().skip(1) {
            if e.1 != 0.0 {
                qs.add_inflight(l, e.0, -e.1);
                e.1 = 0.0;
            }
        }
    }

    /// A parcel discarded for epoch obsolescence still carried per-query
    /// in-flight charges: queue their release (stale qids no-op — the
    /// evicted tenant's account was already reset).
    fn release_discarded(&mut self, qids: &[u32], amounts: &[f64]) {
        if qids.is_empty() || self.queries.is_none() {
            return;
        }
        for (u, &q) in qids.iter().enumerate() {
            if q == 0 {
                continue;
            }
            if let Some(lane) = self.lane_of_qid(q) {
                self.accumulate_release(lane, q, amounts[u].abs());
            }
        }
    }

    /// Straggler injection: cap this PID's scalar-update rate.
    fn throttle(&self, work: u64) {
        if work == 0 {
            return;
        }
        if let Some(s) = self.cfg.straggler {
            if s.pid == self.k && s.updates_per_sec > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(work as f64 / s.updates_per_sec));
            }
        }
    }

    /// Sync with the ownership table; ship any coordinate range we hold
    /// that now belongs elsewhere. `force` re-runs the scan even when the
    /// cached version is current (after folding a handoff in, the range
    /// may already have been reassigned onward).
    fn refresh_ownership(&mut self, force: bool) {
        if self.shutting_down {
            return;
        }
        let version_moved = self.table.version() != self.version;
        if !force && !version_moved {
            return;
        }
        let (v, part) = self.table.snapshot();
        self.version = v;
        self.part = part;
        // the version is acked only at the END of this scan: by then every
        // range the new map takes from us has been booked via
        // begin_handoff, so `all_acked && inflight == 0` is a sound
        // quiescence proof for the rebase
        // fostered fluid whose designated owner moved on: forward it
        if !self.foster.is_empty() {
            let stale: Vec<(usize, u32)> = self
                .foster
                .keys()
                .copied()
                .filter(|&(j, _)| self.part.owner(j) != self.k)
                .collect();
            for key in stale {
                let fl = self.foster.remove(&key).unwrap();
                let (j, lane) = key;
                self.coalesce.add_lane(self.part.owner(j), j, lane, fl);
                self.metrics.incr("fluid_forwarded");
            }
        }
        // group the slots we must give up by their new owner
        let mut outgoing: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (t, &i) in self.owned.iter().enumerate() {
            let o = self.part.owner(i);
            if o != self.k {
                outgoing.entry(o).or_default().push(t);
            }
        }
        if outgoing.is_empty() {
            // the remnant's destination routing is stale whenever the
            // owner map moved (even a peer-to-peer transfer we are not
            // part of): re-route it in place (cheap O(remnant) sweep)
            // before the next quantum, rebuilding only when the patch
            // budget ran out
            if version_moved && !self.patch_local_retarget() {
                self.rebuild_local();
            }
            self.table.ack_version(self.k, v);
            return;
        }
        let lanes = self.lanes;
        let mut shipped = vec![false; self.owned.len()];
        for (dest, slots) in &outgoing {
            let coords: Vec<usize> = slots.iter().map(|&t| self.owned[t]).collect();
            let h_slice: Vec<f64> = slots
                .iter()
                .flat_map(|&t| self.h[t * lanes..(t + 1) * lanes].iter().copied())
                .collect();
            let f_slice: Vec<f64> = slots
                .iter()
                .flat_map(|&t| self.f[t * lanes..(t + 1) * lanes].iter().copied())
                .collect();
            let b_slice: Vec<f64> = coords.iter().map(|&i| self.problem.b()[i]).collect();
            let mass: f64 = f_slice.iter().map(|v| v.abs()).sum();
            let bytes = coords.len() * (16 * lanes + 16) + 48;
            // per-query fluid leaving with the slice rides each lane's
            // in-flight account until the recipient folds + republishes
            // (charged before the send, un-charged if the peer is gone)
            if lanes > 1 {
                if let Some(qs) = &self.queries {
                    for l in 1..lanes {
                        let moved: f64 =
                            (l..f_slice.len()).step_by(lanes).map(|u| f_slice[u].abs()).sum();
                        if moved > 0.0 {
                            qs.add_inflight(l, self.lane_qids[l], moved);
                        }
                    }
                }
            }
            let ho = Handoff {
                pid_from: self.k,
                pid_to: *dest,
                version: v,
                epoch: self.epoch,
                coords,
                h_slice,
                b_slice,
                f_slice,
            };
            // in-flight accounting FIRST (the send books the fluid mass,
            // begin_handoff books the slice) so neither the convergence
            // monitor nor the rebase quiescence check can under-count
            self.table.begin_handoff();
            let n_coords = ho.coords.len() as u64;
            if self
                .ep
                .send(*dest, WorkerMsg::Handoff(ho), mass, bytes)
                .is_ok()
            {
                self.metrics.incr("handoffs_total");
                self.metrics.add("handoff_coords", n_coords);
                for &t in slots {
                    shipped[t] = true;
                }
            } else {
                // peer already gone (shutdown race): keep holding the
                // range, and roll back the per-query charge (the slots
                // are untouched — nothing was compacted)
                self.table.end_handoff();
                if lanes > 1 {
                    if let Some(qs) = &self.queries {
                        for l in 1..lanes {
                            let moved: f64 = slots
                                .iter()
                                .map(|&t| self.f[t * lanes + l].abs())
                                .sum();
                            if moved > 0.0 {
                                qs.add_inflight(l, self.lane_qids[l], -moved);
                            }
                        }
                    }
                }
            }
        }
        if shipped.iter().any(|&s| s) {
            self.compact(&shipped);
            self.publish();
        } else if version_moved {
            self.rebuild_local();
        }
        self.table.ack_version(self.k, v);
    }

    /// Drop the shipped slots and rebuild the local index structures.
    fn compact(&mut self, shipped: &[bool]) {
        // patch the LocalSystem off the OLD owned set before compacting it
        let patched = self.patch_local_shed(shipped);
        let lanes = self.lanes;
        let mut owned = Vec::with_capacity(self.owned.len());
        let mut h = Vec::with_capacity(self.h.len());
        let mut f = Vec::with_capacity(self.f.len());
        for t in 0..self.owned.len() {
            if !shipped[t] {
                owned.push(self.owned[t]);
                h.extend_from_slice(&self.h[t * lanes..(t + 1) * lanes]);
                f.extend_from_slice(&self.f[t * lanes..(t + 1) * lanes]);
            } else {
                self.local_of[self.owned[t]] = usize::MAX;
            }
        }
        self.owned = owned;
        self.h = h;
        self.f = f;
        self.recount_f();
        for (t, &i) in self.owned.iter().enumerate() {
            self.local_of[i] = t;
        }
        self.rebuild_order();
        if !patched {
            self.rebuild_local();
        }
    }

    /// Incremental shed (ROADMAP's `patch_handoff`): splice the shipped
    /// columns out of the LocalSystem instead of re-extracting the whole
    /// owned range from the global CSC. Returns false when the caller
    /// must fall back to a full rebuild (global kernel, no system built
    /// yet, or the patch budget bounding interner accretion ran out).
    fn patch_local_shed(&mut self, shipped: &[bool]) -> bool {
        if !self.cfg.kernel.uses_local_system() || self.patches >= PATCHES_PER_REBUILD {
            return false;
        }
        let Some(local) = self.local.as_mut() else {
            return false;
        };
        let mut new_slot = vec![u32::MAX; shipped.len()];
        let mut s = 0u32;
        for (t, &sh) in shipped.iter().enumerate() {
            if !sh {
                new_slot[t] = s;
                s += 1;
            }
        }
        let coalesce = &mut self.coalesce;
        local.shed(&self.owned, shipped, &new_slot, self.part.owners(), |d, j| {
            coalesce.intern(d, j)
        });
        self.patches += 1;
        true
    }

    /// Incremental adoption: append only the received columns (extracted
    /// fresh) and flip remnant entries that now point at local slots.
    fn patch_local_adopt(&mut self, added: &[usize]) -> bool {
        if !self.cfg.kernel.uses_local_system() || self.patches >= PATCHES_PER_REBUILD {
            return false;
        }
        if self.local.is_none() {
            return false;
        }
        let csc = self.problem.matrix().csc();
        let local = self.local.as_mut().expect("checked above");
        let coalesce = &mut self.coalesce;
        local.adopt(csc, added, &self.local_of, self.part.owners(), |d, j| {
            coalesce.intern(d, j)
        });
        self.patches += 1;
        true
    }

    /// Incremental re-route after a peer-to-peer move (no columns of ours
    /// changed — only remnant destinations).
    fn patch_local_retarget(&mut self) -> bool {
        if !self.cfg.kernel.uses_local_system() || self.patches >= PATCHES_PER_REBUILD {
            return false;
        }
        let Some(local) = self.local.as_mut() else {
            return false;
        };
        let coalesce = &mut self.coalesce;
        let ok = local.retarget(&self.local_of, self.part.owners(), |d, j| {
            coalesce.intern(d, j)
        });
        if ok {
            self.patches += 1;
        }
        ok
    }

    /// Rebuild the diffusion-order state after local slots were re-indexed
    /// or appended (handoffs are rare; O(n + m) here is irrelevant).
    fn rebuild_order(&mut self) {
        if self.use_heap {
            // reset-in-place: the bucket storage stays warm across epoch
            // rebases (a fresh queue is ~2k vector allocations)
            self.heap.reset(self.owned.len());
            if self.lanes == 1 {
                for (t, &fv) in self.f.iter().enumerate() {
                    self.heap.push(t, fv.abs());
                }
            } else {
                for t in 0..self.owned.len() {
                    let p = self.lane_slot_max(t);
                    self.heap.push(t, p);
                }
            }
        }
        self.seq = Self::make_seq(&self.cfg, self.k, self.owned.len());
    }

    /// Rebuild the reindexed local block + remnant from the current owned
    /// set, matrix and owner map. Called handoff-atomically: always after
    /// the fold/compact completes, before the next diffusion quantum.
    fn rebuild_local(&mut self) {
        // every ownership REBUILD lands here under BOTH kernels: the one
        // safe point to drop stale accumulator slots (pending fluid is
        // preserved, and no cached slot survives this call — the local
        // kernel re-interns its whole remnant below, the global kernel
        // caches none). The incremental patch paths deliberately skip it
        // (their cached slots must stay valid); PATCHES_PER_REBUILD
        // forces a periodic pass through here so the interner cannot
        // accrete unboundedly under churn.
        self.patches = 0;
        self.coalesce.compact();
        if !self.cfg.kernel.uses_local_system() {
            return;
        }
        let csc = self.problem.matrix().csc();
        let coalesce = &mut self.coalesce;
        self.local = Some(LocalSystem::build(
            csc,
            &self.owned,
            &self.local_of,
            self.part.owners(),
            |d, j| coalesce.intern(d, j),
        ));
    }

    /// Take ownership of a coordinate we did not hold (handoff receipt).
    fn adopt(&mut self, j: usize) -> usize {
        debug_assert_eq!(self.local_of[j], usize::MAX);
        let t = self.owned.len();
        self.owned.push(j);
        self.h.extend(std::iter::repeat(0.0).take(self.lanes));
        self.f.extend(std::iter::repeat(0.0).take(self.lanes));
        self.local_of[j] = t;
        // keep the queue addressable until rebuild_order resizes it
        self.heap.grow(t + 1);
        t
    }

    /// Drain the bus: apply current-epoch fluid, discard stale parcels,
    /// stash future ones, fold handoffs in. Two-phase throughout: the new
    /// local totals are published BEFORE the receipts are committed, so
    /// the monitor always sees each unit of fluid in at least one account.
    fn absorb_bus(&mut self) -> bool {
        let received = self.ep.drain_uncommitted();
        if received.is_empty() {
            self.ep.collect_acks();
            return false;
        }
        let mut got = false;
        let mut to_commit: Vec<(usize, u64, f64)> = Vec::new();
        for msg in received {
            let Received {
                from,
                seq,
                mass,
                payload,
            } = msg;
            match payload {
                WorkerMsg::Fluid {
                    epoch,
                    coords,
                    mass: amounts,
                    qids,
                } => {
                    // under the LOCAL protocol epochs are fluid-continuous:
                    // the rebase patches F in place (F' = F + (P'−P)·H), so
                    // a parcel from ANY epoch still carries live mass and
                    // is applied on arrival. The GATHER protocol recomputes
                    // F from H, so its stale parcels are obsolete by
                    // construction and its future ones must wait.
                    if self.cfg.rebase == RebaseMode::Local || epoch == self.epoch {
                        got |= self.apply_parcels(&coords, &amounts, &qids);
                        to_commit.push((from, seq, mass));
                        // applied: the parcel's column storage backs the
                        // next outbound flush (wire decode → coalesce →
                        // wire encode, a closed allocation-free cycle)
                        self.coalesce.recycle(coords, qids, amounts);
                    } else if epoch < self.epoch {
                        // obsolete epoch: discard, release its accounting
                        // (bus mass at commit, per-query charges here)
                        self.release_discarded(&qids, &amounts);
                        to_commit.push((from, seq, mass));
                        self.coalesce.recycle(coords, qids, amounts);
                    } else {
                        self.pending.push(Received {
                            from,
                            seq,
                            mass,
                            payload: WorkerMsg::Fluid { epoch, coords, mass: amounts, qids },
                        });
                    }
                }
                WorkerMsg::Handoff(ho) => {
                    self.apply_handoff(ho);
                    got = true;
                    to_commit.push((from, seq, mass));
                }
                WorkerMsg::HaloSlice { epoch, coords, h } => {
                    self.recv_halo(epoch, &coords, &h);
                    got = true;
                    to_commit.push((from, seq, mass));
                }
            }
        }
        if got {
            self.publish();
        }
        // per-query in-flight releases settle only after the new totals
        // are visible, mirroring the publish-before-commit discipline
        self.settle_lane_releases();
        for (from, seq, mass) in to_commit {
            self.ep.commit(from, seq, mass);
        }
        self.ep.collect_acks();
        got
    }

    /// Apply a current-epoch SoA parcel, routing each coordinate: local →
    /// absorb; table says mine but slice in flight → foster; otherwise →
    /// forward to the current owner. Returns whether anything landed.
    ///
    /// Query entries (`qids` non-empty) route by **global query id**: the
    /// id maps to a lane through the cached table, resyncing once on a
    /// miss (an admit we have not seen yet). An id that is still unknown
    /// after the resync belongs to an evicted query — its entry is
    /// dropped, which is exact: eviction already reset every account the
    /// mass was carried in. Landed query mass queues a per-lane in-flight
    /// release, settled after the next publish.
    fn apply_parcels(&mut self, coords: &[u32], amounts: &[f64], qids: &[u32]) -> bool {
        debug_assert!(qids.is_empty() || qids.len() == coords.len());
        let mut any = false;
        for (u, &jj) in coords.iter().enumerate() {
            let j = jj as usize;
            let fl = amounts[u];
            let lane = if qids.is_empty() || qids[u] == 0 {
                0
            } else {
                let q = qids[u];
                let found = match self.lane_of_qid(q) {
                    Some(l) => Some(l),
                    None => {
                        self.sync_queries();
                        self.lane_of_qid(q)
                    }
                };
                let Some(l) = found else { continue };
                self.accumulate_release(l, q, fl.abs());
                l
            };
            let t = self.local_of[j];
            if t != usize::MAX {
                let flat = t * self.lanes + lane;
                self.add_f(flat, fl);
                if self.use_heap {
                    self.heap.push(t, self.f[flat].abs());
                }
            } else if self.part.owner(j) == self.k {
                *self.foster.entry((j, lane as u32)).or_insert(0.0) += fl;
            } else {
                self.coalesce.add_lane(self.part.owner(j), j, lane as u32, fl);
                self.metrics.incr("fluid_forwarded");
            }
            any = true;
        }
        any
    }

    /// Fold a received ownership transfer into the local state. H and F
    /// add linearly: the coordinate may already have accumulated fostered
    /// or diffused mass here, and the slices carry the remainder.
    fn apply_handoff(&mut self, ho: Handoff) {
        debug_assert_eq!(ho.pid_to, self.k);
        // an epoch transition quiesces handoffs first and holds the table
        // frozen, so a slice can never land while slots are pinned (the
        // fold below would invalidate the frozen slot indices)
        debug_assert!(
            self.pending_local.is_none() && self.frozen.is_empty(),
            "handoff during an epoch transition"
        );
        // in a multi-process deployment the shipped b_slice is the
        // recipient's only source of B for the range; in-process it must
        // agree with the shared problem (same epoch ⇒ same B)
        debug_assert!(
            ho.epoch != self.epoch
                || ho
                    .coords
                    .iter()
                    .zip(&ho.b_slice)
                    .all(|(&j, &b)| b == self.problem.b()[j]),
            "handoff b_slice disagrees with the shared problem"
        );
        let lanes = self.lanes;
        debug_assert!(
            ho.coords.is_empty() || ho.h_slice.len() == ho.coords.len() * lanes,
            "handoff slice lane count disagrees with this pool's config"
        );
        let mut adopted: Vec<usize> = Vec::with_capacity(ho.coords.len());
        for (s, &j) in ho.coords.iter().enumerate() {
            let t = if self.local_of[j] == usize::MAX {
                adopted.push(j);
                self.adopt(j)
            } else {
                self.local_of[j]
            };
            for l in 0..lanes {
                let flat = t * lanes + l;
                self.h[flat] += ho.h_slice[s * lanes + l];
                let mut add = ho.f_slice[s * lanes + l];
                if let Some(st) = self.foster.remove(&(j, l as u32)) {
                    add += st;
                }
                if add != 0.0 {
                    self.add_f(flat, add);
                }
            }
        }
        // the sender charged each query lane's shipped |F| to its
        // in-flight account; queue the matching release (settled after
        // the absorb loop's publish)
        if lanes > 1 && self.queries.is_some() {
            for l in 1..lanes {
                let landed: f64 =
                    (l..ho.f_slice.len()).step_by(lanes).map(|u| ho.f_slice[u].abs()).sum();
                if landed > 0.0 {
                    let qid = self.lane_qids[l];
                    self.accumulate_release(l, qid, landed);
                }
            }
        }
        self.rebuild_order();
        if !self.patch_local_adopt(&adopted) {
            self.rebuild_local();
        }
        // the range may already be reassigned onward: re-scan BEFORE
        // releasing the in-flight slot, so `handoffs_inflight` can never
        // dip to zero while coordinates are still migrating
        self.refresh_ownership(true);
        self.table.end_handoff();
    }

    /// Pick the next local slot to diffuse (greedy heap or sequence).
    /// Multi-lane, the heap ranks each slot by its largest |fluid| across
    /// lanes — one pop drains every lane of the winning column.
    #[inline]
    fn next_slot(&mut self) -> Option<usize> {
        if self.use_heap {
            let lanes = self.lanes;
            let f = &self.f;
            if lanes == 1 {
                self.heap.pop_valid(|t| f[t])
            } else {
                self.heap.pop_valid(|t| {
                    f[t * lanes..(t + 1) * lanes]
                        .iter()
                        .fold(0.0f64, |m, v| m.max(v.abs()))
                })
            }
        } else {
            // fixed sweeps exist only single-lane (asserted in `new`)
            self.seq.as_mut().map(|seq| seq.next(&self.f))
        }
    }

    /// One diffusion work quantum (the §3.3 inner loop). Returns
    /// `(did_work, work_count, r_k)`.
    fn diffuse_quantum(&mut self) -> (bool, u64, f64) {
        let m = self.owned.len();
        debug_assert_eq!(
            self.nonzero_f,
            self.f.iter().filter(|v| **v != 0.0).count(),
            "nonzero-fluid counter drifted from f"
        );
        // idle fast-path: persistent workers spin between epochs; skip the
        // whole quantum once the slice is drained. The counter is
        // maintained at the f write sites, so this is O(1) — not the old
        // O(m) scan per quantum.
        if m == 0 || self.nonzero_f == 0 {
            return (false, 0, 0.0);
        }
        match self.cfg.kernel {
            KernelKind::LocalBlock => self.diffuse_quantum_local(m),
            KernelKind::Blocked => self.diffuse_quantum_blocked(m),
            KernelKind::GlobalWalk => self.diffuse_quantum_global(m),
        }
    }

    /// The fast path: everything in local-slot space against the
    /// [`LocalSystem`], cross-part emissions into pre-interned slots.
    fn diffuse_quantum_local(&mut self, m: usize) -> (bool, u64, f64) {
        let local = self
            .local
            .take()
            .expect("LocalBlock kernel requires a built LocalSystem");
        let quanta = self.cfg.sweeps_per_round * m;
        let lanes = self.lanes;
        let mut did_work = false;
        let mut work_count = 0u64;
        for _ in 0..quanta {
            let Some(t) = self.next_slot() else { break };
            if !self.frozen.is_empty() && self.frozen.contains(&t) {
                continue; // mid-transition: this column's H is a halo snapshot
            }
            // drain every lane of the popped column: the column walk is
            // the expensive part and it is identical across lanes, so a
            // multi-RHS drain amortizes it L ways. Lanes never mix — lane
            // l's fluid lands only in lane l cells.
            let base = t * lanes;
            for lane in 0..lanes {
                let flat = base + lane;
                let fi = self.f[flat];
                if fi == 0.0 {
                    continue;
                }
                if fi.abs() < self.absorb_eps {
                    self.h[flat] += fi;
                    self.clear_f(flat);
                    continue;
                }
                did_work = true;
                work_count += 1;
                self.h[flat] += fi;
                self.clear_f(flat);
                let (rows, vals) = local.block_col(t);
                for u in 0..rows.len() {
                    let lj = rows[u] as usize;
                    let fj = lj * lanes + lane;
                    self.add_f(fj, vals[u] * fi); // stays local: no indirection
                    if self.use_heap {
                        self.heap.push(lj, self.f[fj].abs());
                    }
                }
                let (dests, slots, vals) = local.remnant_col(t);
                for u in 0..dests.len() {
                    // §3.3 regroup: one indexed add into the dest accumulator
                    self.coalesce
                        .add_slot_lane(dests[u] as usize, slots[u], lane as u32, vals[u] * fi);
                }
            }
        }
        self.local = Some(local);
        (did_work, work_count, norm1(&self.f))
    }

    /// The batched fast path (DESIGN.md §9). Three structural differences
    /// from [`Self::diffuse_quantum_local`], none of which move the fixed
    /// point:
    ///
    /// * **batch select** — up to [`BLOCK_BATCH`] slots are drained from
    ///   the greedy queue before any column is walked, and the frozen /
    ///   zero-fluid / `absorb_eps` branches run once per *selected slot*
    ///   here instead of inside the walk;
    /// * **4-wide unrolled column walk** — the local CSC block's rows are
    ///   processed in `chunks_exact(4)`, four independent accumulations
    ///   per step (every entry of a column targets a distinct local slot,
    ///   so the unroll cannot reorder adds into the same `f` entry);
    /// * **journal-deferred refiling** — instead of one `heap.push` per
    ///   edge, every touched slot is appended (unchecked, branchless) to
    ///   a journal and refiled in one pass after the batch; duplicate
    ///   entries are no-ops in the queue's exponent-bucket check.
    ///
    /// All scratch lives in the preallocated [`BlockedScratch`]; once the
    /// buffers have warmed up, a quantum performs zero heap allocations
    /// (asserted by the counting-allocator test).
    fn diffuse_quantum_blocked(&mut self, m: usize) -> (bool, u64, f64) {
        let local = self
            .local
            .take()
            .expect("Blocked kernel requires a built LocalSystem");
        let mut scratch = std::mem::take(&mut self.blocked);
        let lanes = self.lanes;
        // one selected slot can contribute up to `lanes` batch entries,
        // so the batch may overshoot BLOCK_BATCH by lanes - 1
        scratch.batch.reserve_total(BLOCK_BATCH + lanes);
        let quanta = self.cfg.sweeps_per_round * m;
        let mut did_work = false;
        let mut work_count = 0u64;
        let mut spent = 0usize;
        let mut drained = false;
        while spent < quanta && !drained {
            scratch.batch.clear();
            let mut journal_cap = 0usize;
            while scratch.batch.len() < BLOCK_BATCH && spent < quanta {
                spent += 1;
                let Some(t) = self.next_slot() else {
                    drained = true;
                    break;
                };
                if !self.frozen.is_empty() && self.frozen.contains(&t) {
                    continue; // mid-transition: this H is a halo snapshot
                }
                let base = t * lanes;
                for lane in 0..lanes {
                    let flat = base + lane;
                    let fi = self.f[flat];
                    if fi == 0.0 {
                        continue;
                    }
                    self.h[flat] += fi;
                    self.clear_f(flat);
                    if fi.abs() < self.absorb_eps {
                        continue; // absorbed without propagation
                    }
                    did_work = true;
                    work_count += 1;
                    journal_cap += local.block_col(t).0.len();
                    // SAFETY: `reserve_total(BLOCK_BATCH + lanes)` above,
                    // `len() < BLOCK_BATCH` at loop entry, ≤ lanes pushes
                    // per iteration
                    unsafe { scratch.batch.push_unchecked((flat as u32, fi)) };
                }
            }
            if scratch.batch.is_empty() {
                continue; // every selection was a skip; quanta still spent
            }
            scratch.journal.clear();
            // one reservation per batch (a no-op once warmed up) buys a
            // branchless unchecked append for every edge below
            scratch.journal.reserve_total(journal_cap);
            for &(cell, fi) in scratch.batch.as_slice() {
                let t = cell as usize / lanes;
                let lane = cell as usize - t * lanes;
                let lane32 = lane as u32;
                let (rows, vals) = local.block_col(t);
                let mut rc = rows.chunks_exact(4);
                let mut vc = vals.chunks_exact(4);
                for (r4, v4) in (&mut rc).zip(&mut vc) {
                    // four independent accumulations per step: distinct
                    // rows within a column mean no add can alias another
                    // (and lane cells of distinct rows never alias)
                    let c0 = r4[0] * lanes as u32 + lane32;
                    let c1 = r4[1] * lanes as u32 + lane32;
                    let c2 = r4[2] * lanes as u32 + lane32;
                    let c3 = r4[3] * lanes as u32 + lane32;
                    self.add_f(c0 as usize, v4[0] * fi);
                    self.add_f(c1 as usize, v4[1] * fi);
                    self.add_f(c2 as usize, v4[2] * fi);
                    self.add_f(c3 as usize, v4[3] * fi);
                    // SAFETY: journal reserved to the batch's total
                    // column length above
                    unsafe {
                        scratch.journal.push_unchecked(c0);
                        scratch.journal.push_unchecked(c1);
                        scratch.journal.push_unchecked(c2);
                        scratch.journal.push_unchecked(c3);
                    }
                }
                for (&r, &v) in rc.remainder().iter().zip(vc.remainder()) {
                    let c = r * lanes as u32 + lane32;
                    self.add_f(c as usize, v * fi);
                    // SAFETY: covered by the same per-batch reservation
                    unsafe { scratch.journal.push_unchecked(c) };
                }
                let (dests, slots, rvals) = local.remnant_col(t);
                for u in 0..dests.len() {
                    // §3.3 regroup: one indexed add into the accumulator
                    self.coalesce
                        .add_slot_lane(dests[u] as usize, slots[u], lane32, rvals[u] * fi);
                }
            }
            if self.use_heap {
                // the deferred refiling pass: duplicates land in the same
                // exponent bucket and are no-ops. The journal holds flat
                // lane cells; single-lane skips the division.
                if lanes == 1 {
                    for &lj in scratch.journal.as_slice() {
                        let lj = lj as usize;
                        self.heap.push(lj, self.f[lj].abs());
                    }
                } else {
                    for &cell in scratch.journal.as_slice() {
                        let cell = cell as usize;
                        self.heap.push(cell / lanes, self.f[cell].abs());
                    }
                }
            }
        }
        self.blocked = scratch;
        self.local = Some(local);
        (did_work, work_count, norm1(&self.f))
    }

    /// The pre-refactor kernel shape: walk the global CSC column and route
    /// every entry through `local_of` + the owner map. Kept selectable so
    /// the recorded perf trajectory measures the same binary both ways.
    fn diffuse_quantum_global(&mut self, m: usize) -> (bool, u64, f64) {
        let problem = self.problem.clone();
        let csc = problem.matrix().csc();
        let quanta = self.cfg.sweeps_per_round * m;
        let mut did_work = false;
        let mut work_count = 0u64;
        let lanes = self.lanes;
        for _ in 0..quanta {
            let Some(t) = self.next_slot() else { break };
            if !self.frozen.is_empty() && self.frozen.contains(&t) {
                continue; // mid-transition: this column's H is a halo snapshot
            }
            let base = t * lanes;
            for lane in 0..lanes {
                let flat = base + lane;
                let fi = self.f[flat];
                if fi == 0.0 {
                    continue;
                }
                if fi.abs() < self.absorb_eps {
                    self.h[flat] += fi;
                    self.clear_f(flat);
                    continue;
                }
                did_work = true;
                work_count += 1;
                self.h[flat] += fi;
                self.clear_f(flat);
                let (rows, vals) = csc.col(self.owned[t]);
                for u in 0..rows.len() {
                    let j = rows[u];
                    let contrib = vals[u] * fi;
                    let lj = self.local_of[j];
                    if lj != usize::MAX {
                        let fj = lj * lanes + lane;
                        self.add_f(fj, contrib); // stays local
                        if self.use_heap {
                            self.heap.push(lj, self.f[fj].abs());
                        }
                    } else {
                        // §3.3 regroup, routed by the live owner map
                        self.coalesce
                            .add_lane(self.part.owner(j), j, lane as u32, contrib);
                    }
                }
            }
        }
        (did_work, work_count, norm1(&self.f))
    }

    /// Ship coalesced parcels under the current epoch tag (§4.1/§4.3
    /// triggers: threshold crossing, or full flush when locally drained).
    fn ship(&mut self, did_work: bool, r_k: f64) {
        let threshold_hit = did_work && r_k < self.threshold;
        let mut flush_all = threshold_hit || r_k < self.cfg.tol;
        if self.queries.is_some() {
            // serving keeps callers waiting on per-lane totals: bound how
            // long any query tail can ride the coalesce buffers, whatever
            // the base problem's threshold schedule is doing
            if flush_all {
                self.last_serve_flush = Instant::now();
            } else if self.serving_active()
                && self.last_serve_flush.elapsed() >= SERVE_FLUSH_INTERVAL
            {
                flush_all = true;
                self.last_serve_flush = Instant::now();
            }
        }
        self.flush_coalesce(flush_all);
        if threshold_hit && self.threshold > self.cfg.tol * 1e-3 {
            self.threshold /= self.cfg.threshold_alpha;
        }
    }

    /// Flush coalesced parcels to the bus. A parcel whose destination
    /// endpoint is gone — a PID retired between our routing decision and
    /// this flush — comes back from [`Endpoint::try_send`] and is
    /// re-routed to the coordinates' **current** owners through the
    /// authoritative table (never the cached snapshot, which is what
    /// aimed at the dead PID in the first place). Fluid is never dropped.
    fn flush_coalesce(&mut self, flush_all: bool) {
        let epoch = self.epoch;
        let lanes = self.lanes;
        let ep = &mut self.ep;
        let lane_qids = &self.lane_qids;
        let queries = self.queries.as_deref();
        let charge = &mut self.charge_scratch;
        let mut failed: Vec<(Vec<u32>, Vec<u32>, Vec<f64>)> = Vec::new();
        self.coalesce.flush(flush_all, |dest, coords, mut qlanes, mass, total| {
            if !qlanes.is_empty() {
                // charge each query lane's shipped |mass| to its in-flight
                // account BEFORE the send (the receiver releases it after
                // folding + publishing, so the lane total errs high in
                // transit), then translate the buffer-local lane indices
                // into global query ids for the wire
                charge.clear();
                charge.resize(lanes, 0.0);
                for (u, &l) in qlanes.iter().enumerate() {
                    charge[l as usize] += mass[u].abs();
                }
                if let Some(qs) = queries {
                    for l in 1..lanes {
                        if charge[l] > 0.0 {
                            qs.add_inflight(l, lane_qids[l], charge[l]);
                        }
                    }
                }
                for q in qlanes.iter_mut() {
                    *q = lane_qids[*q as usize];
                }
            }
            let bytes = coords.len() * 12 + qlanes.len() * 4 + 24;
            let msg = WorkerMsg::Fluid { epoch, coords, mass, qids: qlanes };
            if let Err(msg) = ep.try_send(dest, msg, total, bytes) {
                if let WorkerMsg::Fluid { coords, mass, qids, .. } = msg {
                    // the parcel never left: roll back the charge
                    if let Some(qs) = queries {
                        for (u, &q) in qids.iter().enumerate() {
                            if q == 0 {
                                continue;
                            }
                            if let Some(l) = lane_qids.iter().position(|&x| x == q) {
                                qs.add_inflight(l, q, -mass[u].abs());
                            }
                        }
                    }
                    failed.push((coords, qids, mass));
                }
            }
        });
        if flush_all {
            // a full flush is a latency-sensitive moment (threshold
            // crossing, local drain, or a lane's ε endgame): push the
            // queued frames to the network now instead of waiting out
            // the wire flush policy
            self.ep.flush();
        }
        if failed.is_empty() {
            return;
        }
        let part = self.table.partition();
        for (coords, qids, mass) in failed {
            for (u, &j) in coords.iter().enumerate() {
                let j = j as usize;
                let lane = if qids.is_empty() {
                    0
                } else {
                    // evicted mid-flush ⇒ the entry's accounts are gone;
                    // dropping it is the exact move
                    match self.lane_of_qid(qids[u]) {
                        Some(l) => l,
                        None => continue,
                    }
                };
                self.coalesce.add_lane(part.owner(j), j, lane as u32, mass[u]);
            }
            self.metrics.incr("fluid_forwarded");
            // the parcel never left the process: its storage backs the
            // next flush instead of being dropped
            self.coalesce.recycle(coords, qids, mass);
        }
    }

    fn foster_mass(&self) -> f64 {
        self.foster.values().map(|v| v.abs()).sum()
    }

    /// Publish the locally-known remaining fluid: F + held coalesce mass +
    /// fostered mass. The aggregate (all lanes) feeds the monitor's
    /// conservation total exactly as before; multi-lane, each query
    /// lane's share is additionally published to the [`QuerySet`], and a
    /// lane observed under its ε target for the first time latches the
    /// endgame flush (`force_flush`, consumed by `step`).
    pub fn publish(&mut self) {
        let foster_total = self.foster_mass();
        if self.lanes == 1 {
            self.state.publish(
                self.k,
                norm1(&self.f) + self.coalesce.held_mass() + foster_total,
            );
            return;
        }
        let lanes = self.lanes;
        self.lane_scratch.clear();
        self.lane_scratch.resize(lanes, 0.0);
        for chunk in self.f.chunks_exact(lanes) {
            for (l, v) in chunk.iter().enumerate() {
                self.lane_scratch[l] += v.abs();
            }
        }
        for (&(_, lane), v) in &self.foster {
            self.lane_scratch[lane as usize] += v.abs();
        }
        self.coalesce.held_by_lane(&mut self.held_scratch);
        let total: f64 = self.lane_scratch.iter().sum::<f64>()
            + self.held_scratch.iter().sum::<f64>();
        self.state.publish(self.k, total);
        if let Some(qs) = &self.queries {
            let mut crossed = false;
            for l in 1..lanes {
                let lane_total = self.lane_scratch[l] + self.held_scratch[l];
                qs.publish_lane(self.k, l, lane_total);
                let eps = self.lane_eps[l];
                if eps > 0.0 && lane_total < eps && !self.endgame[l] {
                    self.endgame[l] = true;
                    crossed = true;
                }
            }
            if crossed {
                self.force_flush = true;
            }
        }
    }

    /// Install a new streaming epoch: new matrix, rebased fluid slice
    /// (aligned with the current owned set), H kept warm. Obsolete fluid —
    /// buffered outbound, fostered, or pending with an older tag — is
    /// dropped: `B' = P'·H + B − H` already accounts for everything H
    /// absorbed and replaces all fluid of the previous epoch. When `dirty`
    /// lists the matrix columns that changed (the incremental
    /// `MutableDigraph` build reports them), the LocalSystem is patched in
    /// place instead of rebuilt — the owned set cannot have changed, the
    /// rebase quiesced all handoffs first.
    pub fn enter_epoch(
        &mut self,
        epoch: u64,
        problem: Arc<FixedPointProblem>,
        f_slice: Vec<f64>,
        dirty: Option<&[usize]>,
    ) {
        assert_eq!(
            f_slice.len(),
            self.owned.len() * self.lanes,
            "rebased slice must align with the held range (lane-blocked)"
        );
        self.epoch = epoch;
        self.problem = problem;
        self.f = f_slice;
        self.recount_f();
        self.coalesce.clear();
        self.foster.clear();
        // every lane's fluid was recomputed from its own H and B: lanes
        // that were in their ε endgame may have fresh mass again
        for e in &mut self.endgame {
            *e = false;
        }
        self.rebuild_order();
        let mut patched = false;
        if self.cfg.kernel.uses_local_system() {
            if let (Some(local), Some(dirty)) = (self.local.as_mut(), dirty) {
                let csc = self.problem.matrix().csc();
                let coalesce = &mut self.coalesce;
                local.patch(
                    csc,
                    &self.owned,
                    &self.local_of,
                    self.part.owners(),
                    dirty,
                    |d, j| coalesce.intern(d, j),
                );
                patched = true;
            }
        }
        if !patched {
            self.rebuild_local();
        }
        self.threshold = self.cfg.threshold0;
        // stashed parcels for exactly this epoch become applicable now;
        // anything older is obsolete — commit both so the bus clears
        let pending = std::mem::take(&mut self.pending);
        let mut to_commit: Vec<(usize, u64, f64)> = Vec::new();
        for msg in pending {
            let Received {
                from,
                seq,
                mass,
                payload,
            } = msg;
            match payload {
                WorkerMsg::Fluid { epoch: e, coords, mass: amounts, qids } if e == self.epoch => {
                    self.apply_parcels(&coords, &amounts, &qids);
                    to_commit.push((from, seq, mass));
                    self.coalesce.recycle(coords, qids, amounts);
                }
                WorkerMsg::Fluid { epoch: e, coords, mass: amounts, qids } if e < self.epoch => {
                    self.release_discarded(&qids, &amounts);
                    to_commit.push((from, seq, mass));
                    self.coalesce.recycle(coords, qids, amounts);
                }
                payload => self.pending.push(Received {
                    from,
                    seq,
                    mass,
                    payload,
                }),
            }
        }
        self.publish();
        self.settle_lane_releases();
        for (from, seq, mass) in to_commit {
            self.ep.commit(from, seq, mass);
        }
        // epoch entry is a latency-sensitive edge: senders may be waiting
        // on the receipts just committed
        self.ep.flush();
    }

    /// Begin a V1-style **local** epoch transition (`RebaseMode::Local`,
    /// DESIGN.md §7): freeze the owned dirty columns (their H values are
    /// about to become halo snapshots), multicast those snapshots to every
    /// peer whose rows the delta touches, and record which halo values we
    /// must receive before we can enter the epoch ourselves. The worker
    /// keeps diffusing all non-frozen fluid throughout — there is no
    /// checkpoint pause and no leader round-trip.
    ///
    /// Preconditions (the coordinator enforces both before broadcasting):
    /// the ownership table is frozen and every handoff has folded, so the
    /// owner map is a consistent exact cover for the whole transition.
    pub fn begin_rebase_local(
        &mut self,
        epoch: u64,
        problem: Arc<FixedPointProblem>,
        dirty: Arc<Vec<usize>>,
    ) {
        debug_assert!(epoch > self.epoch, "epochs advance monotonically");
        debug_assert!(self.pending_local.is_none(), "one epoch transition at a time");
        let lanes = self.lanes;
        let old_csc = self.problem.matrix().csc();
        let new_csc = problem.matrix().csc();
        let mut own_coords: Vec<u32> = Vec::new();
        let mut own_h: Vec<f64> = Vec::new();
        let mut dests: BTreeSet<usize> = BTreeSet::new();
        let mut waiting: HashSet<usize> = HashSet::new();
        let mut halo_coords: Vec<usize> = Vec::new();
        let mut halo_h: Vec<f64> = Vec::new();
        for &u in dirty.iter() {
            let t = self.local_of[u];
            if t != usize::MAX {
                // ours: freeze + snapshot (every lane's H — each lane's
                // fluid rebases from its own history). The frozen slot
                // keeps accumulating incoming fluid in F; only its H is
                // pinned.
                self.frozen.insert(t);
                own_coords.push(u as u32);
                own_h.extend_from_slice(&self.h[t * lanes..(t + 1) * lanes]);
                halo_coords.push(u);
                halo_h.extend_from_slice(&self.h[t * lanes..(t + 1) * lanes]);
                // every owner of a row in the old or new column needs H_u
                for csc in [old_csc, new_csc] {
                    let (rows, _) = csc.col(u);
                    for &j in rows {
                        let o = self.part.owner(j);
                        if o != self.k {
                            dests.insert(o);
                        }
                    }
                }
            } else {
                // theirs: we need H_u iff the delta touches a row we own
                let needed = [old_csc, new_csc].iter().any(|csc| {
                    let (rows, _) = csc.col(u);
                    rows.iter().any(|&j| self.local_of[j] != usize::MAX)
                });
                if needed {
                    waiting.insert(u);
                }
            }
        }
        if !own_coords.is_empty() && !dests.is_empty() {
            // one slice per needing peer, all our dirty columns at once
            // (receivers ignore columns whose delta misses their rows —
            // both sides compute "need" from the same frozen owner map,
            // so neither waits on a message the other will not send)
            let dests: Vec<usize> = dests.into_iter().collect();
            let bytes = own_coords.len() * (4 + 8 * lanes) + 24;
            let n_vals = own_h.len() as u64;
            let sent = self.ep.multicast(
                &dests,
                &WorkerMsg::HaloSlice {
                    epoch,
                    coords: own_coords,
                    h: own_h,
                },
                0.0, // state plane: halo slices carry history, not fluid
                bytes,
            );
            self.metrics.add("halo_slices_sent", sent as u64);
            self.metrics.add("halo_values_sent", sent as u64 * n_vals);
            // peers block their own epoch entry on these slices: bypass
            // the wire flush policy rather than batch them
            self.ep.flush();
        }
        let mut pending = LocalRebase {
            epoch,
            problem,
            dirty,
            waiting,
            halo_coords,
            halo_h,
        };
        // halo slices that raced ahead of our control message
        let stashed = std::mem::take(&mut self.halo_stash);
        for (e, coords, h) in stashed {
            if e == epoch {
                Self::fold_halo(&mut pending, lanes, &coords, &h);
            }
        }
        self.pending_local = Some(pending);
        self.try_finish_rebase_local();
    }

    /// Route a received halo slice into the transition state machine.
    fn recv_halo(&mut self, epoch: u64, coords: &[u32], h: &[f64]) {
        let lanes = self.lanes;
        let folded = match self.pending_local.as_mut() {
            Some(p) if p.epoch == epoch => {
                Self::fold_halo(p, lanes, coords, h);
                true
            }
            _ => false,
        };
        if folded {
            self.try_finish_rebase_local();
        } else if epoch > self.epoch {
            // the peer's transition raced ahead of our Ctrl::RebaseLocal
            self.halo_stash.push((epoch, coords.to_vec(), h.to_vec()));
        }
        // anything else is a duplicate for a transition already completed
    }

    /// Fold received halo values into the pending transition, resolving
    /// only columns we are actually waiting for. `h` is lane-blocked
    /// (`coords.len() * lanes`), like every H slice on the bus.
    fn fold_halo(p: &mut LocalRebase, lanes: usize, coords: &[u32], h: &[f64]) {
        debug_assert_eq!(h.len(), coords.len() * lanes);
        for (idx, &c) in coords.iter().enumerate() {
            let u = c as usize;
            if p.waiting.remove(&u) {
                p.halo_coords.push(u);
                p.halo_h.extend_from_slice(&h[idx * lanes..(idx + 1) * lanes]);
            }
        }
    }

    /// Complete the local transition once every awaited halo value has
    /// arrived: apply the delta rebase `F ← F + (P'−P)·H` over the owned
    /// rows, swap the problem, patch the LocalSystem with the dirty
    /// columns (the owned set cannot have changed — the table is frozen
    /// and handoffs were quiesced), unfreeze, and requeue.
    fn try_finish_rebase_local(&mut self) {
        let ready = self
            .pending_local
            .as_ref()
            .map(|p| p.waiting.is_empty())
            .unwrap_or(false);
        if !ready {
            return;
        }
        let p = self.pending_local.take().expect("checked above");
        let lanes = self.lanes;
        // every lane rebases from its own history: F_l ← F_l + (P'−P)·H_l
        // (a query's B is seed mass in the registry, untouched by the
        // matrix delta, so the same formula serves every lane)
        let mut touched: Vec<usize> = Vec::new();
        for lane in 0..lanes {
            let halo: Vec<(usize, f64)> = p
                .halo_coords
                .iter()
                .enumerate()
                .map(|(i, &u)| (u, p.halo_h[i * lanes + lane]))
                .collect();
            let t = update::rebase_b_slice_local_lane(
                self.problem.matrix().csc(),
                p.problem.matrix().csc(),
                &halo,
                &self.local_of,
                &mut self.f,
                lanes,
                lane,
            );
            touched.extend(t);
        }
        self.recount_f();
        self.epoch = p.epoch;
        self.problem = p.problem;
        let mut patched = false;
        if self.cfg.kernel.uses_local_system() {
            if let Some(local) = self.local.as_mut() {
                let csc = self.problem.matrix().csc();
                let coalesce = &mut self.coalesce;
                local.patch(
                    csc,
                    &self.owned,
                    &self.local_of,
                    self.part.owners(),
                    &p.dirty,
                    |d, j| coalesce.intern(d, j),
                );
                patched = true;
            }
        }
        if !patched {
            self.rebuild_local();
        }
        // unfreeze + requeue: every pinned or delta-touched slot re-enters
        // the diffusion order with its current fluid
        if self.use_heap {
            let frozen = std::mem::take(&mut self.frozen);
            for &t in frozen.iter().chain(&touched) {
                let p = self.lane_slot_max(t);
                self.heap.push(t, p);
            }
        } else {
            self.frozen.clear();
        }
        self.threshold = self.cfg.threshold0;
        self.publish();
    }

    /// Exit path: stop migrating, fold any in-flight handoffs so no
    /// history is stranded on the bus, and return the held (Ω, H) pair.
    ///
    /// Data-plane fluid that arrives while draining is **re-routed, not
    /// dropped**: a retiring worker is shut down mid-convergence, so a
    /// parcel already in flight toward it (or buffered under a peer's
    /// stale owner snapshot) still carries mass the run needs. Parcels
    /// for coordinates we hold land in F; everything else forwards to the
    /// current owner, published before the receipt commits so the
    /// monitor's total errs high, never low, through the exit.
    ///
    /// The returned history is **lane-blocked** (`owned.len() * lanes`);
    /// single-lane callers see the flat pre-lane layout unchanged.
    pub fn finish(mut self) -> (Vec<usize>, Vec<f64>) {
        self.shutting_down = true;
        // Drain for a minimum grace window (catches slices shipped just
        // after the stop signal, before their begin_handoff was visible),
        // then keep draining while any handoff is still riding the bus —
        // its H slice exists nowhere else — or any delayed envelope is
        // still ripening toward us (its mass is accounted; abandoning it
        // would strand the in-flight account above zero forever). The
        // hard deadline only guards against a peer that died without
        // completing a send.
        let min_deadline = Instant::now() + Duration::from_millis(5);
        let hard_deadline = Instant::now() + Duration::from_secs(2);
        loop {
            let mut touched = false;
            while let Some(msg) = self.ep.try_recv_uncommitted() {
                let Received {
                    from,
                    seq,
                    mass,
                    payload,
                } = msg;
                match payload {
                    WorkerMsg::Handoff(ho) => {
                        self.apply_handoff(ho);
                        touched = true;
                    }
                    WorkerMsg::Fluid {
                        epoch,
                        coords,
                        mass: amounts,
                        qids,
                    } if epoch == self.epoch || self.cfg.rebase == RebaseMode::Local => {
                        // local protocol: every epoch's fluid is live
                        self.apply_parcels(&coords, &amounts, &qids);
                        touched = true;
                        self.coalesce.recycle(coords, qids, amounts);
                    }
                    // obsolete epoch: discard, keep the storage
                    WorkerMsg::Fluid {
                        coords,
                        mass: amounts,
                        qids,
                        ..
                    } => {
                        self.release_discarded(&qids, &amounts);
                        self.coalesce.recycle(coords, qids, amounts);
                    }
                    // a halo slice is state-plane; no transition can be in
                    // flight once the pool is shutting down (the engine's
                    // rebase holds the table frozen until every worker
                    // acked the epoch entry)
                    WorkerMsg::HaloSlice { .. } => {}
                }
                // publish before the commit releases the in-flight mass,
                // so each unit stays visible in at least one account
                self.publish();
                self.settle_lane_releases();
                self.ep.commit(from, seq, mass);
            }
            if touched {
                // forward whatever the re-routing put in the buffers
                self.flush_coalesce(true);
                self.publish();
            }
            // the receipts just committed may be queued behind the wire
            // flush policy; senders are waiting on them to release mass
            self.ep.flush();
            self.ep.collect_acks();
            let now = Instant::now();
            let quiesced =
                self.table.handoffs_inflight() == 0 && self.ep.pending_delayed() == 0;
            if (now >= min_deadline && quiesced) || now >= hard_deadline {
                break;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
        // last sweep: anything still buffered outbound goes onto the bus
        self.flush_coalesce(true);
        self.publish();
        if std::env::var_os("DITER_DEBUG").is_some() {
            let nonzero = self.f.iter().filter(|v| **v != 0.0).count();
            eprintln!(
                "[worker pid {}] exit: r_k={:.3e} held={:.3e} foster={:.3e} threshold={:.3e} unacked={} nonzero_f={}",
                self.k,
                norm1(&self.f),
                self.coalesce.held_mass(),
                self.foster_mass(),
                self.threshold,
                self.ep.unacked(),
                nonzero
            );
        }
        (self.owned, self.h)
    }
}
